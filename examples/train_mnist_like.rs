//! End-to-end driver: the full three-layer stack on the paper's §5
//! workload at laptop scale.
//!
//! - **L1/L2**: the gradient of every node, every round, is executed from
//!   the JAX/Pallas AOT artifact through the PJRT runtime (no native
//!   fallback on the full-gradient path — run `make artifacts` first);
//! - **L3**: eight node *threads* exchanging real serialized 2-bit frames
//!   over channels (the message-passing coordinator), non-smooth
//!   λ1‖x‖1 handled by the proximal step.
//!
//! The PJRT-wrapped problem is injected into the Experiment pipeline via
//! `with_problem`; the network, codec, oracle, prox, and coordinator
//! wiring all resolve from the one config. Logs the loss curve + training
//! accuracy and checks the run against the centralized reference.
//! Recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```sh
//! make artifacts && cargo run --release --example train_mnist_like
//! ```

use proxlead::exp::Experiment;
use proxlead::linalg::Mat;
use proxlead::problem::data::{blobs, heterogeneity_index, BlobSpec};
use proxlead::problem::{LogReg, Problem};
use proxlead::runner::{MetricPoint, Probe};
use proxlead::runtime::{default_artifact_dir, PjrtRuntime, XlaLogReg};
use std::sync::Arc;

/// A custom streaming probe: per-snapshot loss and training accuracy need
/// the stacked iterate, so this pairs each `on_sample` row with the
/// matching `on_iterate` matrix — metrics print *while* training runs.
struct TrainLog {
    problem: Arc<XlaLogReg>,
    lambda1: f64,
    last: Option<MetricPoint>,
    final_acc: f64,
}

impl Probe for TrainLog {
    fn on_sample(&mut self, m: &MetricPoint) {
        self.last = Some(*m);
    }

    fn on_iterate(&mut self, round: usize, x: &Mat) {
        let m = self.last.expect("on_iterate follows on_sample");
        let xbar = x.row_mean();
        let loss = self.problem.global_loss(&xbar)
            + self.lambda1 * xbar.iter().map(|v| v.abs()).sum::<f64>();
        let acc = self.problem.native().accuracy(&xbar, self.problem.native().shards());
        self.final_acc = acc;
        println!(
            "{round:>5} {loss:>10.5} {:>12.4e} {:>12.4e} {acc:>6.3} {:>8.2}",
            m.suboptimality,
            m.consensus,
            m.bits as f64 / 1e6,
        );
    }
}

fn main() {
    // the shipped artifact shape: 8 nodes × 240 samples × 64 features,
    // 10 classes, λ2 = 5e-3 (15 batches of 16 rows for the SGO)
    let spec = BlobSpec {
        nodes: 8,
        samples_per_node: 240,
        dim: 64,
        classes: 10,
        separation: 1.5,
        ..Default::default()
    };
    let shards = blobs(&spec);
    println!(
        "data: 8 × 240 samples, 64 features, 10 classes | heterogeneity {:.2} (label-sorted)",
        heterogeneity_index(&shards, 10)
    );
    let native = LogReg::new(shards, 10, 5e-3, 15);

    let rt = Arc::new(
        PjrtRuntime::load(&default_artifact_dir())
            .expect("run `make artifacts` first — this example exercises the PJRT path"),
    );
    println!("runtime: {} PJRT executables loaded", rt.len());
    let problem = Arc::new(XlaLogReg::new(native, rt).expect("artifact for (240,64,10)"));
    assert!(problem.batch_on_xla(), "batch artifact (16,64,10) should be compiled");

    // the coordinator scenario: ring-8, 2-bit frames, Prox-LEAD-SAGA
    // (1 PJRT batch-grad/round/node), η in the paper's tuned range
    let exp = Experiment::builder()
        .nodes(8)
        .set("samples_per_node", "240")
        .set("dim", "64")
        .set("classes", "10")
        .set("batches", "15")
        .lambda1(5e-3)
        .lambda2(5e-3)
        .bits(2)
        .oracle("saga")
        .eta(0.1)
        .rounds(400)
        .set("record_every", "25")
        .with_problem(Arc::clone(&problem) as Arc<dyn Problem>)
        .build()
        .expect("train_mnist_like experiment");

    println!("solving centralized reference x* (FISTA) …");
    let _ = exp.reference();

    println!("training: Prox-LEAD-SAGA (2bit) on 8 node threads, PJRT gradients…");
    println!("\nround   loss        subopt       consensus    acc     Mbit");
    // metrics stream through the unified run API's probe interface —
    // each row prints as the leader assembles the snapshot, not after the
    // run finishes
    let mut log = TrainLog {
        problem: Arc::clone(&problem),
        lambda1: exp.config.lambda1,
        last: None,
        final_acc: 0.0,
    };
    let res = exp.run_coordinator_probed(&exp.run_spec(), &mut [&mut log]);

    let final_sub = res.final_subopt();
    let acc = log.final_acc;
    println!(
        "\nelapsed {:.2?} | wire {} KiB | final suboptimality {final_sub:.3e} | \
         train acc {acc:.3} | stopped by {}",
        res.elapsed,
        res.wire_bytes() / 1024,
        res.stopped_by.name(),
    );
    assert!(final_sub < 1.0, "training must make real progress toward x*");
    assert!(acc > 0.8, "label-sorted blobs at sep 1.5 should be largely separable: {acc}");
    println!("train_mnist_like OK — all three layers composed");
}
