//! Compression ablation: how the bit budget, the scaling norm, and the
//! operator family affect Prox-LEAD (the paper's eq. 21 design choices).
//!
//! Sweeps b ∈ {2, 4, 8} for the ∞-norm quantizer (eq. 21), the QSGD-style
//! 2-norm quantizer, rand-k sparsification, and the 32-bit dense baseline,
//! reporting iterations and bits to reach 1e-10 suboptimality — the
//! "compression is almost free" claim, measured.
//!
//! ```sh
//! cargo run --release --example compression_study
//! ```

use proxlead::algorithm::{solve_reference, Hyper, ProxLead};
use proxlead::compress::{Compressor, Identity, InfNormQuantizer, L2NormQuantizer, RandK};
use proxlead::engine::{run, RunConfig};
use proxlead::graph::{mixing_matrix, Graph, MixingRule};
use proxlead::linalg::Mat;
use proxlead::oracle::OracleKind;
use proxlead::problem::data::BlobSpec;
use proxlead::problem::{LogReg, Problem};
use proxlead::prox::L1;

fn main() {
    let spec = BlobSpec {
        nodes: 8,
        samples_per_node: 120,
        dim: 32,
        classes: 10,
        separation: 1.0,
        ..Default::default()
    };
    let problem = LogReg::from_blobs(&spec, 0.05, 15);
    let graph = Graph::ring(8);
    let w = mixing_matrix(&graph, MixingRule::UniformMaxDegree);
    let lambda1 = 5e-3;
    let x_star = solve_reference(&problem, lambda1, 60_000, 1e-12);
    let eta = 0.5 / problem.smoothness();
    let x0 = Mat::zeros(8, problem.dim());
    let target = 1e-10;

    let compressors: Vec<(String, Box<dyn Compressor>)> = vec![
        ("dense 32bit".into(), Box::new(Identity::f32())),
        ("inf-norm 2bit".into(), Box::new(InfNormQuantizer::new(2, 256))),
        ("inf-norm 4bit".into(), Box::new(InfNormQuantizer::new(4, 256))),
        ("inf-norm 8bit".into(), Box::new(InfNormQuantizer::new(8, 256))),
        ("qsgd-2norm 2bit".into(), Box::new(L2NormQuantizer::new(2, 256))),
        ("qsgd-2norm 4bit".into(), Box::new(L2NormQuantizer::new(4, 256))),
        ("rand-k (k=p/8)".into(), Box::new(RandK::new(problem.dim() / 8))),
    ];

    println!(
        "compression study: Prox-LEAD, 8-node ring, λ1 = {lambda1}, target subopt {target:.0e}\n"
    );
    println!(
        "{:<18} {:>6} {:>8} {:>12} {:>12} {:>10}",
        "compressor", "C≈", "iters", "bits/round", "Mbit tot", "vs 32bit"
    );
    let mut dense_bits = None;
    for (label, comp) in compressors {
        // empirical noise-to-signal ratio C drives feasible (α, γ): the
        // paper's α = 0.5, γ = 1 works for low-C operators (eq. 21); the
        // high-variance comparators need Lemma 4's feasibility region
        let c = {
            let mut rng = proxlead::util::rng::Rng::new(99);
            proxlead::compress::empirical_nsr(comp.as_ref(), problem.dim(), 10, &mut rng)
        };
        let alpha = (0.8 / (1.0 + c)).min(0.5);
        let lmax_iw = 4.0 / 3.0; // ring, uniform 1/3 weights
        let gamma = if c < 0.3 {
            1.0
        } else {
            let delta = alpha - (1.0 + c) * alpha * alpha;
            (delta / (c.sqrt() * lmax_iw)).min(1.0)
        };
        let mut alg = ProxLead::new(
            &problem,
            &w,
            &x0,
            Hyper { eta, alpha, gamma },
            OracleKind::Full,
            comp,
            Box::new(L1::new(lambda1)),
            11,
        );
        let res = run(&mut alg, &problem, &x_star, &RunConfig::fixed(60_000).every(60_000).until(target));
        match res.rounds_to_target {
            Some(iters) => {
                let bits = res.history.last().unwrap().bits;
                let per_round = bits / iters as u64;
                if label == "dense 32bit" {
                    dense_bits = Some(bits);
                }
                let ratio = dense_bits
                    .map(|d| format!("{:>9.2}x", bits as f64 / d as f64))
                    .unwrap_or_else(|| "     (ref)".into());
                println!(
                    "{label:<18} {c:>6.2} {iters:>8} {per_round:>12} {:>12.2} {ratio}",
                    bits as f64 / 1e6
                );
            }
            None => println!("{label:<18} {c:>6.2} {:>8} — did not reach target in budget", "-"),
        }
    }
    println!(
        "\nnote: iterations barely change across 2/4/8-bit ∞-norm quantization while the\n\
         bit totals drop ~16x vs dense — 'compression almost for free' (paper §1, Fig 1b/2b).\n\
         The 2-norm (QSGD) scaling needs more precision at the same b, matching Appendix C\n\
         of the LEAD paper."
    );
}
