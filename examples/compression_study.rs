//! Compression ablation: how the bit budget, the scaling norm, and the
//! operator family affect Prox-LEAD (the paper's eq. 21 design choices).
//!
//! Sweeps b ∈ {2, 4, 8} for the ∞-norm quantizer (eq. 21), the QSGD-style
//! 2-norm quantizer, rand-k sparsification, and the 32-bit dense baseline,
//! reporting iterations and bits to reach 1e-10 suboptimality — the
//! "compression is almost free" claim, measured.
//!
//! The grid is a [`SweepSpec`] of one variant per operator on the
//! parallel sweep runtime (every cell resolves through the one
//! `Config → Experiment` pipeline); each variant's (α, γ) comes from its
//! measured noise-to-signal ratio C (Lemma 4's feasibility region for the
//! high-variance comparators, the paper's α = 0.5, γ = 1 otherwise).
//!
//! ```sh
//! cargo run --release --example compression_study
//! ```

use proxlead::config::Config;
use proxlead::sweep::{run_sweep_verbose, SweepSpec};
use proxlead::util::rng::Rng;

const LAMBDA1: f64 = 5e-3;
const TARGET: f64 = 1e-10;
const BUDGET: usize = 60_000;

fn base_cfg() -> Config {
    Config::parse(&format!(
        "nodes = 8\nsamples_per_node = 120\ndim = 32\nclasses = 10\nbatches = 15\n\
         separation = 1.0\nlambda1 = {LAMBDA1}\nlambda2 = 0.05\n\
         algorithm = prox-lead\nrounds = {BUDGET}\nrecord_every = {BUDGET}\n"
    ))
    .expect("compression_study base config")
}

/// The operator grid: (label, family, bits) — bits 32 ⇒ dense baseline.
const OPERATORS: &[(&str, &str, u32)] = &[
    ("dense 32bit", "inf", 32),
    ("inf-norm 2bit", "inf", 2),
    ("inf-norm 4bit", "inf", 4),
    ("inf-norm 8bit", "inf", 8),
    ("qsgd-2norm 2bit", "l2", 2),
    ("qsgd-2norm 4bit", "l2", 4),
    ("rand-k (k=p/8)", "randk", 2),
];

fn main() {
    let base = base_cfg();
    let dim = base.dim * base.classes; // flattened parameter dimension p

    // per-operator (α, γ) from the measured noise-to-signal ratio: the
    // paper's α = 0.5, γ = 1 works for low-C operators (eq. 21); the
    // high-variance comparators need Lemma 4's feasibility region
    let mut spec = SweepSpec::new(base.clone()).until(TARGET);
    let mut nsrs = Vec::new();
    for &(_, family, bits) in OPERATORS {
        let mut probe = base.clone();
        probe.compressor = family.into();
        probe.bits = bits;
        let comp = probe.compressor().expect("operator");
        let c = {
            let mut rng = Rng::new(99);
            proxlead::compress::empirical_nsr(comp.as_ref(), dim, 10, &mut rng)
        };
        nsrs.push(c);
        let alpha = (0.8 / (1.0 + c)).min(0.5);
        let lmax_iw = 4.0 / 3.0; // ring, uniform 1/3 weights
        let gamma = if c < 0.3 {
            1.0
        } else {
            let delta = alpha - (1.0 + c) * alpha * alpha;
            (delta / (c.sqrt() * lmax_iw)).min(1.0)
        };
        let (bits, alpha, gamma) = (format!("{bits}"), format!("{alpha}"), format!("{gamma}"));
        spec = spec.variant(&[
            ("compressor", family),
            ("bits", bits.as_str()),
            ("alpha", alpha.as_str()),
            ("gamma", gamma.as_str()),
        ]);
    }

    println!(
        "compression study: Prox-LEAD, 8-node ring, λ1 = {LAMBDA1}, target subopt {TARGET:.0e}\n\
         {} operators on {} threads\n",
        spec.num_cells(),
        spec.threads
    );
    let res = run_sweep_verbose(&spec).expect("compression sweep");

    println!(
        "\n{:<18} {:>6} {:>8} {:>12} {:>12} {:>10}",
        "compressor", "C≈", "iters", "bits/round", "Mbit tot", "vs 32bit"
    );
    let mut dense_bits = None;
    for ((&(label, _, _), cell), &c) in OPERATORS.iter().zip(&res.cells).zip(&nsrs) {
        match cell.result.rounds_to_target() {
            Some(iters) => {
                let bits = cell.result.history.last().unwrap().bits;
                let per_round = bits / iters as u64;
                if label == "dense 32bit" {
                    dense_bits = Some(bits);
                }
                let ratio = dense_bits
                    .map(|d| format!("{:>9.2}x", bits as f64 / d as f64))
                    .unwrap_or_else(|| "     (ref)".into());
                println!(
                    "{label:<18} {c:>6.2} {iters:>8} {per_round:>12} {:>12.2} {ratio}",
                    bits as f64 / 1e6
                );
            }
            None => println!("{label:<18} {c:>6.2} {:>8} — did not reach target in budget", "-"),
        }
    }
    println!(
        "\nnote: iterations barely change across 2/4/8-bit ∞-norm quantization while the\n\
         bit totals drop ~16x vs dense — 'compression almost for free' (paper §1, Fig 1b/2b).\n\
         The 2-norm (QSGD) scaling needs more precision at the same b, matching Appendix C\n\
         of the LEAD paper."
    );
}
