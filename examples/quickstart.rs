//! Quickstart: decentralized composite optimization in ~40 lines.
//!
//! Eight nodes on a ring minimize a shared ℓ1-regularized logistic loss
//! over heterogeneous (label-sorted) data, communicating 2-bit quantized
//! messages. Compare Prox-LEAD against DGD to see why the paper exists.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use proxlead::algorithm::{solve_reference, Algorithm, Dgd, Hyper, ProxLead};
use proxlead::compress::{Identity, InfNormQuantizer};
use proxlead::engine::{run, RunConfig};
use proxlead::graph::{Graph, MixingOp, MixingRule};
use proxlead::linalg::Mat;
use proxlead::oracle::OracleKind;
use proxlead::problem::data::BlobSpec;
use proxlead::problem::{LogReg, Problem};
use proxlead::prox::{Zero, L1};

fn main() {
    // 1. data: 8 label-sorted shards of an "MNIST-like" blob problem
    let spec = BlobSpec {
        nodes: 8,
        samples_per_node: 120,
        dim: 32,
        classes: 10,
        separation: 1.0,
        ..Default::default()
    };
    let problem = LogReg::from_blobs(&spec, 0.05, 15);

    // 2. network: ring with the paper's uniform 1/3 mixing
    let graph = Graph::ring(8);
    let w = MixingOp::build(&graph, MixingRule::UniformMaxDegree);

    // 3. ground truth for the suboptimality metric
    let lambda1 = 5e-3;
    let x_star = solve_reference(&problem, lambda1, 60_000, 1e-12);

    // 4. algorithms: Prox-LEAD @ 2 bits vs DGD @ 32 bits
    let eta = 0.5 / problem.smoothness();
    let x0 = Mat::zeros(8, problem.dim());
    let mut prox_lead = ProxLead::new(
        &problem,
        &w,
        &x0,
        Hyper::paper_default(eta),
        OracleKind::Full,
        Box::new(InfNormQuantizer::paper_default()),
        Box::new(L1::new(lambda1)),
        42,
    );
    let mut dgd = Dgd::new(
        &problem,
        &w,
        &x0,
        eta,
        OracleKind::Full,
        Box::new(Identity::f32()),
        Box::new(Zero),
        42,
    );

    let cfg = RunConfig::fixed(8000).every(800);
    println!("running {} …", prox_lead.name());
    let r1 = run(&mut prox_lead, &problem, &x_star, &cfg);
    println!("running {} …", dgd.name());
    let r2 = run(&mut dgd, &problem, &x_star, &cfg);

    println!("\n round | {:>26} | {:>26}", r1.name, r2.name);
    for (a, b) in r1.history.iter().zip(&r2.history) {
        println!("{:>6} | {:>26.6e} | {:>26.6e}", a.round, a.suboptimality, b.suboptimality);
    }
    let (b1, b2) = (r1.history.last().unwrap().bits, r2.history.last().unwrap().bits);
    println!(
        "\nProx-LEAD used {:.1}x fewer communication bits ({:.2} vs {:.2} Mbit)\n\
         and still converged to machine precision; DGD stalls at its bias ball.",
        b2 as f64 / b1 as f64,
        b1 as f64 / 1e6,
        b2 as f64 / 1e6
    );
    assert!(r1.final_subopt() < 1e-12, "Prox-LEAD should reach high accuracy");
    assert!(r2.final_subopt() > r1.final_subopt(), "DGD is biased");
    println!("quickstart OK");
}
