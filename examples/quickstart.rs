//! Quickstart: decentralized composite optimization in ~40 lines.
//!
//! Eight nodes on a ring minimize a shared ℓ1-regularized logistic loss
//! over heterogeneous (label-sorted) data, communicating 2-bit quantized
//! messages. Compare Prox-LEAD against DGD to see why the paper exists.
//!
//! Everything resolves through the one `Experiment` pipeline: the config
//! names the scenario, `ExperimentBuilder` builds it, and the typed
//! algorithm builders override exactly the knobs each arm changes.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use proxlead::algorithm::{Algorithm, Dgd, ProxLead};
use proxlead::compress::Identity;
use proxlead::exp::Experiment;
use proxlead::prox::Zero;
use proxlead::runner::{run_engine, RunSpec};

fn main() {
    // 1. the scenario: 8 label-sorted blob shards on a ring, λ1 = 5e-3,
    //    2-bit ∞-norm quantization, auto-η = 1/(2L) — resolved in ONE place
    let exp = Experiment::builder()
        .nodes(8)
        .set("samples_per_node", "120")
        .set("dim", "32")
        .set("classes", "10")
        .set("batches", "15")
        .set("separation", "1.0")
        .lambda1(5e-3)
        .lambda2(0.05)
        .bits(2)
        .seed(42)
        .build()
        .expect("quickstart experiment");

    // 2. ground truth for the suboptimality metric (cached on the experiment)
    let x_star = exp.reference();

    // 3. algorithms: Prox-LEAD @ 2 bits (all defaults from the experiment)
    //    vs DGD @ dense 32-bit with no prox (its classic biased form)
    let mut prox_lead = ProxLead::builder(&exp).build();
    let mut dgd = Dgd::builder(&exp)
        .compressor(Box::new(Identity::f32()))
        .prox(Box::new(Zero))
        .build();

    let spec = RunSpec::fixed(8000).every(800);
    println!("running {} …", prox_lead.name());
    let r1 = run_engine(&mut prox_lead, exp.problem.as_ref(), &x_star, &spec, &mut []);
    println!("running {} …", dgd.name());
    let r2 = run_engine(&mut dgd, exp.problem.as_ref(), &x_star, &spec, &mut []);

    println!("\n round | {:>26} | {:>26}", r1.name, r2.name);
    for (a, b) in r1.history.iter().zip(&r2.history) {
        println!("{:>6} | {:>26.6e} | {:>26.6e}", a.round, a.suboptimality, b.suboptimality);
    }
    let (b1, b2) = (r1.history.last().unwrap().bits, r2.history.last().unwrap().bits);
    println!(
        "\nProx-LEAD used {:.1}x fewer communication bits ({:.2} vs {:.2} Mbit)\n\
         and still converged to machine precision; DGD stalls at its bias ball.",
        b2 as f64 / b1 as f64,
        b1 as f64 / 1e6,
        b2 as f64 / 1e6
    );
    assert!(r1.final_subopt() < 1e-12, "Prox-LEAD should reach high accuracy");
    assert!(r2.final_subopt() > r1.final_subopt(), "DGD is biased");
    println!("quickstart OK");
}
