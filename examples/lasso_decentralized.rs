//! Decentralized lasso: sparse recovery over a network — the classic
//! composite problem the paper's intro motivates (regularized empirical
//! risk minimization with a non-smooth penalty shared by all nodes).
//!
//! Four nodes hold disjoint measurement sets of the same k-sparse signal;
//! Prox-LEAD (2 bit) recovers the support while communicating a fraction
//! of the bits the uncompressed proximal baselines need. The custom data
//! (a specific k-sparse ground truth) is injected into the Experiment
//! pipeline via `with_problem`; network, prox, compressor, and auto-η all
//! resolve through the one pipeline.
//!
//! ```sh
//! cargo run --release --example lasso_decentralized
//! ```

use proxlead::algorithm::{Algorithm, Nids, P2d2, ProxLead};
use proxlead::exp::Experiment;
use proxlead::problem::data::sparse_regression;
use proxlead::problem::{LeastSquares, Problem};
use proxlead::runner::{run_engine, RunSpec};
use std::sync::Arc;

fn support(x: &[f64], tol: f64) -> Vec<usize> {
    x.iter().enumerate().filter(|(_, v)| v.abs() > tol).map(|(i, _)| i).collect()
}

fn main() {
    // ground truth: 6-sparse signal in R^48, 4 nodes × 40 noisy measurements
    let (shards, x_true) = sparse_regression(4, 40, 48, 6, 0.02, 7);
    let problem: Arc<dyn Problem> = Arc::new(LeastSquares::new(shards, 1e-3, 8));
    let lambda1 = 0.02;

    let exp = Experiment::builder()
        .problem("lasso")
        .nodes(4)
        .lambda1(lambda1)
        .lambda2(1e-3)
        .bits(2)
        .seed(3)
        .with_problem(problem)
        .build()
        .expect("lasso experiment");
    // reference x* for the ℓ1-composite objective, cached on the experiment
    let x_star = exp.reference();

    let spec = RunSpec::fixed(6000).every(6000);
    let mut prox_lead = ProxLead::builder(&exp).build();
    let mut nids = Nids::builder(&exp).build();
    let mut p2d2 = P2d2::builder(&exp).build();

    println!("decentralized lasso: 4 nodes, p=48, 6-sparse truth, λ1={lambda1}\n");
    println!("{:<28} {:>14} {:>10} {:>12}", "algorithm", "suboptimality", "Mbit", "support");
    let mut rows = vec![];
    for alg in [&mut prox_lead as &mut dyn Algorithm, &mut nids, &mut p2d2] {
        let res = run_engine(alg, exp.problem.as_ref(), &x_star, &spec, &mut []);
        let xbar = res.final_x.row_mean();
        let sup = support(&xbar, 1e-3);
        let true_sup = support(&x_true, 1e-9);
        let exact = sup == true_sup;
        println!(
            "{:<28} {:>14.3e} {:>10.2} {:>8}/{} {}",
            res.name,
            res.final_subopt(),
            res.history.last().unwrap().bits as f64 / 1e6,
            sup.len(),
            true_sup.len(),
            if exact { "exact" } else { "" }
        );
        rows.push((res.name.clone(), res.final_subopt(), res.history.last().unwrap().bits, exact));
    }

    // signal recovery quality of the averaged Prox-LEAD solution
    let lead_bits = rows[0].2 as f64;
    let nids_bits = rows[1].2 as f64;
    println!(
        "\nProx-LEAD matched the uncompressed proximal baselines with {:.0}x fewer bits",
        nids_bits / lead_bits
    );
    assert!(rows.iter().all(|r| r.1 < 1e-10), "all three should solve the lasso: {rows:?}");
    assert!(rows.iter().all(|r| r.3), "all three should recover the exact support");
    assert!(lead_bits * 4.0 < nids_bits, "compression should save ≥4x bits");

    // the lasso estimate is close to the ground-truth signal
    let x_hat = &x_star;
    let err: f64 = x_hat.iter().zip(&x_true).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
    let scale: f64 = x_true.iter().map(|v| v * v).sum::<f64>().sqrt();
    println!("relative signal error ‖x̂ − x♯‖/‖x♯‖ = {:.3}", err / scale);
    assert!(err / scale < 0.2);
    println!("lasso_decentralized OK");
}
