//! Table 3 — the §4.3 cross-algorithm complexity comparison.
//!
//! Paper's predicted ordering (iterations to ε, hiding logs):
//!
//!   DualGD / LessBit-A     Õ(κ_f κ_g)         (slowest family)
//!   PDGM / LessBit-B       Õ(κ_f + κ_f κ_g)
//!   NIDS / LEAD / PUDA /   Õ(κ_f + κ_g)       (+ √C(1+C)κ_fκ_g with
//!   Prox-LEAD                                   compression)
//!
//! Measured as iterations (and, for DualGD, inner gradient steps) to hit
//! 1e-9 suboptimality on the common §5-analog problem — smooth panel for
//! the R = 0 rows, composite panel for the prox-capable rows. Each panel
//! is one [`SweepSpec`] of explicit variants on the parallel sweep
//! runtime with an early-stop target. The *shape* of the comparison (who
//! wins, roughly by what factor) is the reproduction target; constants
//! differ from the authors' testbed.
//!
//! Emits bench_out/table3.csv.

mod common;

use common::out_dir;
use proxlead::config::Config;
use proxlead::sweep::{run_sweep_verbose, SweepResult, SweepSpec};
use proxlead::util::bench::Table;

const TARGET: f64 = 1e-9;
const BUDGET: usize = 60_000;

/// Smaller than the figure workload: the DualGD family needs an inner
/// solve per round, so Table 3's common suite uses 8×60 samples, d=16.
fn base_cfg(lambda1: f64) -> Config {
    Config::parse(&format!(
        "nodes = 8\nsamples_per_node = 60\ndim = 16\nclasses = 5\nbatches = 15\n\
         separation = 1.0\nlambda1 = {lambda1}\nlambda2 = 0.05\n\
         rounds = {BUDGET}\nrecord_every = {BUDGET}\n"
    ))
    .expect("table3 base config")
}

/// Emit one panel: run the spec, then table + csv rows in variant order.
fn panel(
    title: &str,
    panel_tag: &str,
    labels: &[&str],
    spec: &SweepSpec,
    csv: &mut String,
) -> SweepResult {
    println!("table3 {panel_tag} panel: {} cells on {} threads", spec.num_cells(), spec.threads);
    let res = run_sweep_verbose(spec).expect("table3 sweep");
    let mut table = Table::new(title, &["algorithm", "compressed", "iters", "grad evals", "Mbit"]);
    for (label, cell) in labels.iter().zip(&res.cells) {
        let bits_override = cell
            .overrides
            .iter()
            .find(|(k, _)| k == "bits")
            .map(|(_, v)| v.as_str())
            .unwrap_or("2");
        let compressed = bits_override != "32" && bits_override != "64";
        let it_s = cell
            .result
            .rounds_to_target()
            .map(|i| i.to_string())
            .unwrap_or_else(|| format!(">{BUDGET}"));
        let last = cell.result.history.last().expect("history");
        table.row(vec![
            (*label).into(),
            if compressed { "2bit".into() } else { "—".into() },
            it_s.clone(),
            format!("{}", last.grad_evals),
            format!("{:.1}", last.bits as f64 / 1e6),
        ]);
        csv.push_str(&format!(
            "{panel_tag},{label},{compressed},{it_s},{},{}\n",
            last.grad_evals, last.bits
        ));
    }
    table.print();
    res
}

fn main() {
    let mut csv = String::from("panel,algorithm,compressed,iters,grad_evals,bits\n");

    // ---------------- smooth panel (R = 0, Table 3 upper rows) ----------
    // eta = 0 ⇒ 1/(2L) for the primal methods; the dual family derives its
    // dual stepsize (μ/2, or μ/4 when compressed) from the same config
    let spec = SweepSpec::new(base_cfg(0.0))
        .variant(&[("algorithm", "dualgd"), ("bits", "32"), ("alpha", "0.5")])
        .variant(&[("algorithm", "lessbit-a"), ("bits", "2"), ("alpha", "0.25")])
        .variant(&[("algorithm", "pdgm"), ("bits", "32"), ("gamma", "1.0")])
        .variant(&[("algorithm", "lessbit-b"), ("bits", "2"), ("gamma", "0.1"), ("alpha", "0.25")])
        .variant(&[("algorithm", "nids"), ("bits", "32")])
        .variant(&[("algorithm", "lead"), ("bits", "2")])
        .until(TARGET);
    panel(
        "Table 3 — smooth panel: iterations (grad evals) to 1e-9",
        "smooth",
        &["DualGD", "LessBit-A", "PDGM", "LessBit-B", "NIDS", "LEAD"],
        &spec,
        &mut csv,
    );

    // ---------------- composite panel (R = λ1‖·‖1, lower rows) ----------
    // PUDA = Prox-LEAD with C = 0 (Corollary 6) ⇒ the dense-64bit variant
    let spec = SweepSpec::new(base_cfg(5e-3))
        .variant(&[("algorithm", "prox-lead"), ("bits", "64")])
        .variant(&[("algorithm", "nids"), ("bits", "32")])
        .variant(&[("algorithm", "prox-lead"), ("bits", "2")])
        .until(TARGET);
    panel(
        "Table 3 — composite panel (λ1 = 5e-3): iterations to 1e-9",
        "composite",
        &["PUDA (C=0)", "NIDS (prox)", "Prox-LEAD"],
        &spec,
        &mut csv,
    );

    std::fs::write(out_dir().join("table3.csv"), csv).unwrap();
    println!("\nwrote bench_out/table3.csv");
    println!(
        "reading the shape: the DualGD family's 'iters' assume a (warm-started) exact\n\
         inner solve of ∇F* — its true cost is the grad-evals column, ~14x everyone\n\
         else's (the paper: dual methods 'require computing the non-trivial gradient\n\
         of the dual function'). Among single-gradient methods the paper's ordering\n\
         holds: PDGM/LessBit-B (Õ(κf+κfκg)) > NIDS ≈ LEAD ≈ PUDA ≈ Prox-LEAD\n\
         (Õ(κf+κg)), and the 2-bit rows cut bits ~13x at ≈ no iteration cost."
    );
}
