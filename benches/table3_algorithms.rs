//! Table 3 — the §4.3 cross-algorithm complexity comparison.
//!
//! Paper's predicted ordering (iterations to ε, hiding logs):
//!
//!   DualGD / LessBit-A     Õ(κ_f κ_g)         (slowest family)
//!   PDGM / LessBit-B       Õ(κ_f + κ_f κ_g)
//!   NIDS / LEAD / PUDA /   Õ(κ_f + κ_g)       (+ √C(1+C)κ_fκ_g with
//!   Prox-LEAD                                   compression)
//!
//! Measured as iterations (and, for DualGD, inner gradient steps) to hit
//! 1e-9 suboptimality on the common §5-analog problem — smooth panel for
//! the R = 0 rows, composite panel for the prox-capable rows. The *shape*
//! of the comparison (who wins, roughly by what factor) is the
//! reproduction target; constants differ from the authors' testbed.
//!
//! Emits bench_out/table3.csv.

mod common;

use common::{out_dir, Fixture};
use proxlead::algorithm::{Algorithm, DualGd, Hyper, Nids, Pdgm, ProxLead};
use proxlead::compress::{Compressor, Identity, InfNormQuantizer};
use proxlead::engine::rounds_to;
use proxlead::oracle::OracleKind;
use proxlead::prox::{Zero, L1};
use proxlead::util::bench::Table;

const TARGET: f64 = 1e-9;
const BUDGET: usize = 60_000;

fn q2() -> Box<dyn Compressor> {
    Box::new(InfNormQuantizer::new(2, 256))
}

fn main() {
    // smaller than the figure workload: the DualGD family needs an inner
    // solve per round, so Table 3's common suite uses 8×60 samples, d=16
    let fx = Fixture::table3();
    let (p, w, x0, eta) = (&fx.problem, &fx.w, &fx.x0, fx.eta);
    use proxlead::problem::Problem;
    let mu = p.strong_convexity();

    // ---------------- smooth panel (R = 0, Table 3 upper rows) ----------
    let x_star = fx.reference(0.0);
    let mut table = Table::new(
        "Table 3 — smooth panel: iterations (grad evals) to 1e-9",
        &["algorithm", "compressed", "iters", "grad evals", "Mbit"],
    );
    let mut csv = String::from("panel,algorithm,compressed,iters,grad_evals,bits\n");
    let mut row = |name: &str,
                   compressed: bool,
                   alg: &mut dyn Algorithm,
                   p: &dyn proxlead::problem::Problem,
                   x_star: &[f64],
                   table: &mut Table,
                   csv: &mut String,
                   panel: &str| {
        let iters = rounds_to(alg, p, x_star, TARGET, BUDGET);
        let it_s = iters.map(|i| i.to_string()).unwrap_or_else(|| format!(">{BUDGET}"));
        table.row(vec![
            name.into(),
            if compressed { "2bit".into() } else { "—".into() },
            it_s.clone(),
            format!("{}", alg.grad_evals()),
            format!("{:.1}", alg.bits() as f64 / 1e6),
        ]);
        csv.push_str(&format!(
            "{panel},{name},{compressed},{it_s},{},{}\n",
            alg.grad_evals(),
            alg.bits()
        ));
    };

    {
        let mut a = DualGd::new(p, w, x0, mu / 2.0, 40, Box::new(Identity::f32()), 0.5, 5);
        row("DualGD", false, &mut a, p, &x_star, &mut table, &mut csv, "smooth");
        let mut a = DualGd::new(p, w, x0, mu / 4.0, 40, q2(), 0.25, 5);
        row("LessBit-A", true, &mut a, p, &x_star, &mut table, &mut csv, "smooth");
        let mut a = Pdgm::plain(p, w, x0, eta, 1.0, 5);
        row("PDGM", false, &mut a, p, &x_star, &mut table, &mut csv, "smooth");
        let mut a = Pdgm::lessbit_b(p, w, x0, eta, 0.1, q2(), 0.25, 5);
        row("LessBit-B", true, &mut a, p, &x_star, &mut table, &mut csv, "smooth");
        let mut a = Nids::new(p, w, x0, eta, OracleKind::Full, Box::new(Zero), 5);
        row("NIDS", false, &mut a, p, &x_star, &mut table, &mut csv, "smooth");
        let mut a = ProxLead::new(
            p,
            w,
            x0,
            Hyper::paper_default(eta),
            OracleKind::Full,
            q2(),
            Box::new(Zero),
            5,
        );
        row("LEAD", true, &mut a, p, &x_star, &mut table, &mut csv, "smooth");
    }
    table.print();

    // ---------------- composite panel (R = λ1‖·‖1, lower rows) ----------
    let lam = 5e-3;
    let x_star = fx.reference(lam);
    let mut table = Table::new(
        "Table 3 — composite panel (λ1 = 5e-3): iterations to 1e-9",
        &["algorithm", "compressed", "iters", "grad evals", "Mbit"],
    );
    {
        // PUDA = Prox-LEAD with C = 0 (Corollary 6)
        let mut a = ProxLead::new(
            p,
            w,
            x0,
            Hyper::paper_default(eta),
            OracleKind::Full,
            Box::new(Identity::f64()),
            Box::new(L1::new(lam)),
            5,
        );
        row("PUDA (C=0)", false, &mut a, p, &x_star, &mut table, &mut csv, "composite");
        let mut a = Nids::new(p, w, x0, eta, OracleKind::Full, Box::new(L1::new(lam)), 5);
        row("NIDS (prox)", false, &mut a, p, &x_star, &mut table, &mut csv, "composite");
        let mut a = ProxLead::new(
            p,
            w,
            x0,
            Hyper::paper_default(eta),
            OracleKind::Full,
            q2(),
            Box::new(L1::new(lam)),
            5,
        );
        row("Prox-LEAD", true, &mut a, p, &x_star, &mut table, &mut csv, "composite");
    }
    table.print();

    std::fs::write(out_dir().join("table3.csv"), csv).unwrap();
    println!("\nwrote bench_out/table3.csv");
    println!(
        "reading the shape: the DualGD family's 'iters' assume a (warm-started) exact\n\
         inner solve of ∇F* — its true cost is the grad-evals column, ~14x everyone\n\
         else's (the paper: dual methods 'require computing the non-trivial gradient\n\
         of the dual function'). Among single-gradient methods the paper's ordering\n\
         holds: PDGM/LessBit-B (Õ(κf+κfκg)) > NIDS ≈ LEAD ≈ PUDA ≈ Prox-LEAD\n\
         (Õ(κf+κg)), and the 2-bit rows cut bits ~13x at ≈ no iteration cost."
    );
}
