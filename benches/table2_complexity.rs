//! Table 2 — convergence-complexity scaling of Prox-LEAD.
//!
//! The theorem rows predict iterations-to-ε growing as
//! Õ((1+C)(κ_f + κ_g) + √C(1+C)·κ_f·κ_g) (+ p⁻¹ for LSVRG, + m for SAGA).
//! We sweep each factor with the others fixed and report measured
//! iterations to 1e-9 suboptimality:
//!
//!   (i)   C: compression bits b ∈ {2, 3, 4, 8, 32};
//!   (ii)  κ_g: topology ∈ {complete, ring, chain} with chains up to n=32;
//!   (iii) κ_f: λ2 ∈ {0.2, 0.1, 0.05, 0.02};
//!   (iv)  oracle ∈ {full, LSVRG, SAGA} (the three fixed-stepsize rows).
//!
//! Each factor sweep is one [`SweepSpec`] axis on the parallel sweep
//! runtime with an early-stop target — the measured quantity *is*
//! `rounds_to_target`.
//!
//! Emits bench_out/table2.csv with one row per sweep point.

mod common;

use common::out_dir;
use proxlead::config::Config;
use proxlead::linalg::Spectrum;
use proxlead::problem::Problem;
use proxlead::sweep::{run_sweep_verbose, SweepResult, SweepSpec};
use proxlead::util::bench::Table;

const LAMBDA1: f64 = 5e-3;
const TARGET: f64 = 1e-9;
const BUDGET: usize = 60_000;

/// The §5-analog base: 8-node ring, Prox-LEAD, ℓ1 + the given λ2, with
/// the engine's budget/target configured for iterations-to-ε measurement.
fn base_cfg(lambda2: f64, eta: f64) -> Config {
    Config::parse(&format!(
        "nodes = 8\nsamples_per_node = 120\ndim = 32\nclasses = 10\nbatches = 15\n\
         separation = 1.0\nlambda1 = {LAMBDA1}\nlambda2 = {lambda2}\n\
         algorithm = prox-lead\nbits = 2\nrounds = {BUDGET}\nrecord_every = {BUDGET}\n\
         eta = {eta}\n"
    ))
    .expect("table2 base config")
}

fn iters(res: &SweepResult, i: usize) -> usize {
    res.cells[i].result.rounds_to_target().unwrap_or(BUDGET)
}

/// κ_f of a cell's problem (rebuilt through the problem registry).
fn kappa_f_of(cfg: &Config) -> f64 {
    proxlead::exp::build_problem(cfg).expect("table2 problem").kappa_f()
}

/// κ_g of a cell's network (recomputed from its config for the report).
fn kappa_g_of(cfg: &Config) -> f64 {
    let w = proxlead::graph::mixing_matrix(
        &cfg.topology().expect("topology"),
        cfg.mixing_rule().expect("mixing"),
    );
    Spectrum::of_mixing(&w).kappa_g()
}

fn main() {
    let mut csv = String::from("sweep,setting,kappa_f,kappa_g,oracle,bits,iters\n");

    // ------- (i) compression precision sweep ----------------------------
    let spec = SweepSpec::new(base_cfg(0.05, 0.0))
        .axis("bits", &["32", "8", "4", "3", "2"])
        .until(TARGET);
    println!("table2 (i): {} cells on {} threads", spec.num_cells(), spec.threads);
    let res = run_sweep_verbose(&spec).expect("table2(i) sweep");
    let kf = kappa_f_of(&res.spec.base);
    let kg = kappa_g_of(&res.spec.base);
    let mut t = Table::new(
        "Table 2(i) — iterations to 1e-9 vs compression bits (Thm 5 row)",
        &["bits", "iters", "vs 32bit"],
    );
    let base_iters = iters(&res, 0); // cell 0 is the 32-bit row
    for (i, cell) in res.cells.iter().enumerate() {
        let bits = cell.overrides.iter().find(|(k, _)| k == "bits").map(|(_, v)| v.clone());
        let bits = bits.unwrap_or_default();
        let it = iters(&res, i);
        t.row(vec![
            bits.clone(),
            format!("{it}"),
            format!("{:.2}x", it as f64 / base_iters as f64),
        ]);
        csv.push_str(&format!("bits,{bits},{kf:.1},{kg:.2},full,{bits},{it}\n"));
    }
    t.print();

    // ------- (ii) network condition number sweep ------------------------
    // κ_g only binds once the network term 1 − γλmin(I−W)/2 is slower than
    // the objective term 1 − ημ, so this sweep uses a *well-conditioned*
    // objective (λ2 = 0.2) and stretches chains until κ_g dominates.
    let mut net_base = base_cfg(0.2, 0.0);
    net_base.set("samples_per_node", "60").unwrap();
    net_base.set("dim", "16").unwrap();
    net_base.set("classes", "5").unwrap();
    net_base.set("mixing", "mh").unwrap();
    let spec = SweepSpec::new(net_base)
        .variant(&[("topology", "complete"), ("nodes", "8")])
        .variant(&[("topology", "ring"), ("nodes", "8")])
        .variant(&[("topology", "chain"), ("nodes", "8")])
        .variant(&[("topology", "chain"), ("nodes", "16")])
        .variant(&[("topology", "chain"), ("nodes", "32")])
        .until(TARGET);
    println!("\ntable2 (ii): {} cells on {} threads", spec.num_cells(), spec.threads);
    let res = run_sweep_verbose(&spec).expect("table2(ii) sweep");
    let mut t = Table::new(
        "Table 2(ii) — iterations to 1e-9 vs κ_g (chain length, 2bit, small κ_f)",
        &["network", "kappa_g", "iters"],
    );
    for (i, cell) in res.cells.iter().enumerate() {
        let cfg = res.spec.cell_config(cell.index).expect("cell config");
        let kg = kappa_g_of(&cfg);
        let kf = kappa_f_of(&cfg);
        let name = format!("{} n={}", cfg.topology, cfg.nodes);
        let it = iters(&res, i);
        t.row(vec![name.clone(), format!("{kg:.2}"), format!("{it}")]);
        csv.push_str(&format!("kappa_g,{name},{kf:.1},{kg:.2},full,2,{it}\n"));
    }
    t.print();

    // ------- (iii) objective condition number sweep ---------------------
    let spec = SweepSpec::new(base_cfg(0.05, 0.0))
        .axis("lambda2", &["0.2", "0.1", "0.05", "0.02"])
        .until(TARGET);
    println!("\ntable2 (iii): {} cells on {} threads", spec.num_cells(), spec.threads);
    let res = run_sweep_verbose(&spec).expect("table2(iii) sweep");
    let kg = kappa_g_of(&res.spec.base);
    let mut t = Table::new(
        "Table 2(iii) — iterations to 1e-9 vs κ_f (λ2, 2bit)",
        &["lambda2", "kappa_f", "iters"],
    );
    for (i, cell) in res.cells.iter().enumerate() {
        let cfg = res.spec.cell_config(cell.index).expect("cell config");
        let kf = kappa_f_of(&cfg);
        let it = iters(&res, i);
        t.row(vec![format!("{}", cfg.lambda2), format!("{kf:.1}"), format!("{it}")]);
        csv.push_str(&format!("kappa_f,{},{kf:.1},{kg:.2},full,2,{it}\n", cfg.lambda2));
    }
    t.print();

    // ------- (iv) oracle rows (Thm 5 vs Thm 8 vs Thm 9) ------------------
    let eta_s = {
        let problem = proxlead::exp::build_problem(&base_cfg(0.05, 0.0)).expect("table2 problem");
        1.0 / (6.0 * problem.smoothness())
    };
    let spec = SweepSpec::new(base_cfg(0.05, eta_s))
        .axis("oracle", &["full", "lsvrg", "saga"])
        .until(TARGET);
    println!("\ntable2 (iv): {} cells on {} threads", spec.num_cells(), spec.threads);
    let res = run_sweep_verbose(&spec).expect("table2(iv) sweep");
    let kf = kappa_f_of(&res.spec.base);
    let kg = kappa_g_of(&res.spec.base);
    let mut t = Table::new(
        "Table 2(iv) — fixed-stepsize oracles at 2bit (iterations + evals to 1e-9)",
        &["oracle", "iters", "grad evals"],
    );
    for (i, cell) in res.cells.iter().enumerate() {
        let oracle = cell
            .overrides
            .iter()
            .find(|(k, _)| k == "oracle")
            .map(|(_, v)| v.clone())
            .unwrap_or_default();
        let it = iters(&res, i);
        let evals =
            cell.result.history.last().map(|m| m.grad_evals).unwrap_or_default();
        t.row(vec![oracle.clone(), format!("{it}"), format!("{evals}")]);
        csv.push_str(&format!("oracle,{oracle},{kf:.1},{kg:.2},{oracle},2,{it}\n"));
    }
    t.print();

    std::fs::write(out_dir().join("table2.csv"), csv).unwrap();
    println!("\nwrote bench_out/table2.csv");
}
