//! Table 2 — convergence-complexity scaling of Prox-LEAD.
//!
//! The theorem rows predict iterations-to-ε growing as
//! Õ((1+C)(κ_f + κ_g) + √C(1+C)·κ_f·κ_g) (+ p⁻¹ for LSVRG, + m for SAGA).
//! We sweep each factor with the others fixed and report measured
//! iterations to 1e-9 suboptimality:
//!
//!   (i)   C: compression bits b ∈ {2, 3, 4, 8, 32};
//!   (ii)  κ_g: topology ∈ {complete, grid, ring, chain} at n = 8;
//!   (iii) κ_f: λ2 ∈ {0.2, 0.1, 0.05, 0.02};
//!   (iv)  oracle ∈ {full, LSVRG, SAGA} (the three fixed-stepsize rows).
//!
//! Emits bench_out/table2.csv with one row per sweep point.

mod common;

use common::{out_dir, Fixture};
use proxlead::algorithm::{Hyper, ProxLead};
use proxlead::compress::{Compressor, Identity, InfNormQuantizer};
use proxlead::engine::rounds_to;
use proxlead::graph::{mixing_matrix, Graph, MixingRule, Topology};
use proxlead::linalg::{Mat, Spectrum};
use proxlead::oracle::OracleKind;
use proxlead::problem::data::BlobSpec;
use proxlead::problem::{LogReg, Problem};
use proxlead::prox::L1;
use proxlead::util::bench::Table;
use proxlead::util::rng::Rng;

const LAMBDA1: f64 = 5e-3;
const TARGET: f64 = 1e-9;
const BUDGET: usize = 60_000;

fn comp_for_bits(bits: u32) -> Box<dyn Compressor> {
    if bits == 32 {
        Box::new(Identity::f32())
    } else {
        Box::new(InfNormQuantizer::new(bits, 256))
    }
}

fn main() {
    let mut csv = String::from("sweep,setting,kappa_f,kappa_g,oracle,bits,iters\n");

    // ------- (i) compression precision sweep ----------------------------
    let fx = Fixture::section5(0.05);
    let x_star = fx.reference(LAMBDA1);
    let mut t = Table::new(
        "Table 2(i) — iterations to 1e-9 vs compression bits (Thm 5 row)",
        &["bits", "iters", "vs 32bit"],
    );
    let mut base = 0usize;
    for bits in [32u32, 8, 4, 3, 2] {
        let mut alg = ProxLead::new(
            &fx.problem,
            &fx.w,
            &fx.x0,
            Hyper::paper_default(fx.eta),
            OracleKind::Full,
            comp_for_bits(bits),
            Box::new(L1::new(LAMBDA1)),
            5,
        );
        let iters = rounds_to(&mut alg, &fx.problem, &x_star, TARGET, BUDGET).unwrap_or(BUDGET);
        if bits == 32 {
            base = iters;
        }
        t.row(vec![
            format!("{bits}"),
            format!("{iters}"),
            format!("{:.2}x", iters as f64 / base as f64),
        ]);
        csv.push_str(&format!(
            "bits,{bits},{:.1},{:.2},full,{bits},{iters}\n",
            fx.problem.kappa_f(),
            Spectrum::of_mixing(&fx.w).kappa_g()
        ));
    }
    t.print();

    // ------- (ii) network condition number sweep ------------------------
    // κ_g only binds once the network term 1 − γλmin(I−W)/2 is slower than
    // the objective term 1 − ημ, so this sweep uses a *well-conditioned*
    // objective (λ2 = 0.2) and stretches chains until κ_g dominates.
    let mut t = Table::new(
        "Table 2(ii) — iterations to 1e-9 vs κ_g (chain length, 2bit, small κ_f)",
        &["network", "kappa_g", "iters"],
    );
    for (name, n, topo) in [
        ("complete n=8", 8usize, Topology::Complete),
        ("ring n=8", 8, Topology::Ring),
        ("chain n=8", 8, Topology::Chain),
        ("chain n=16", 16, Topology::Chain),
        ("chain n=32", 32, Topology::Chain),
    ] {
        let spec = BlobSpec {
            nodes: n,
            samples_per_node: 60,
            dim: 16,
            classes: 5,
            separation: 1.0,
            ..Default::default()
        };
        let p = LogReg::from_blobs(&spec, 0.2, 15);
        let x_star = proxlead::algorithm::solve_reference(&p, LAMBDA1, 80_000, 1e-12);
        let g = Graph::build(topo, n, &mut Rng::new(1));
        let w = mixing_matrix(&g, MixingRule::Metropolis);
        let kg = Spectrum::of_mixing(&w).kappa_g();
        let x0 = Mat::zeros(n, p.dim());
        let mut alg = ProxLead::new(
            &p,
            &w,
            &x0,
            Hyper::paper_default(0.5 / p.smoothness()),
            OracleKind::Full,
            comp_for_bits(2),
            Box::new(L1::new(LAMBDA1)),
            5,
        );
        let iters = rounds_to(&mut alg, &p, &x_star, TARGET, BUDGET).unwrap_or(BUDGET);
        t.row(vec![name.into(), format!("{kg:.2}"), format!("{iters}")]);
        csv.push_str(&format!("kappa_g,{name},{:.1},{kg:.2},full,2,{iters}\n", p.kappa_f()));
    }
    t.print();

    // ------- (iii) objective condition number sweep ---------------------
    let mut t = Table::new(
        "Table 2(iii) — iterations to 1e-9 vs κ_f (λ2, 2bit)",
        &["lambda2", "kappa_f", "iters"],
    );
    for lam2 in [0.2, 0.1, 0.05, 0.02] {
        let spec = BlobSpec {
            nodes: 8,
            samples_per_node: 120,
            dim: 32,
            classes: 10,
            separation: 1.0,
            ..Default::default()
        };
        let p = LogReg::from_blobs(&spec, lam2, 15);
        let x_star = proxlead::algorithm::solve_reference(&p, LAMBDA1, 80_000, 1e-12);
        let x0 = Mat::zeros(8, p.dim());
        let mut alg = ProxLead::new(
            &p,
            &fx.w,
            &x0,
            Hyper::paper_default(0.5 / p.smoothness()),
            OracleKind::Full,
            comp_for_bits(2),
            Box::new(L1::new(LAMBDA1)),
            5,
        );
        let iters = rounds_to(&mut alg, &p, &x_star, TARGET, BUDGET).unwrap_or(BUDGET);
        t.row(vec![format!("{lam2}"), format!("{:.1}", p.kappa_f()), format!("{iters}")]);
        csv.push_str(&format!(
            "kappa_f,{lam2},{:.1},{:.2},full,2,{iters}\n",
            p.kappa_f(),
            Spectrum::of_mixing(&fx.w).kappa_g()
        ));
    }
    t.print();

    // ------- (iv) oracle rows (Thm 5 vs Thm 8 vs Thm 9) ------------------
    let mut t = Table::new(
        "Table 2(iv) — fixed-stepsize oracles at 2bit (iterations + evals to 1e-9)",
        &["oracle", "iters", "grad evals"],
    );
    let eta_s = 1.0 / (6.0 * fx.problem.smoothness());
    for (name, kind) in [
        ("full (Thm 5)", OracleKind::Full),
        ("lsvrg (Thm 8)", OracleKind::Lsvrg { p: 1.0 / 15.0 }),
        ("saga (Thm 9)", OracleKind::Saga),
    ] {
        let mut alg = ProxLead::new(
            &fx.problem,
            &fx.w,
            &fx.x0,
            Hyper::paper_default(eta_s),
            kind,
            comp_for_bits(2),
            Box::new(L1::new(LAMBDA1)),
            5,
        );
        let iters = rounds_to(&mut alg, &fx.problem, &x_star, TARGET, BUDGET).unwrap_or(BUDGET);
        use proxlead::algorithm::Algorithm;
        t.row(vec![name.into(), format!("{iters}"), format!("{}", alg.grad_evals())]);
        csv.push_str(&format!(
            "oracle,{name},{:.1},{:.2},{name},2,{iters}\n",
            fx.problem.kappa_f(),
            Spectrum::of_mixing(&fx.w).kappa_g()
        ));
    }
    t.print();

    std::fs::write(out_dir().join("table2.csv"), csv).unwrap();
    println!("\nwrote bench_out/table2.csv");
}
