//! Figure 1 — smooth logistic regression (λ1 = 0).
//!
//! (a) full gradient: suboptimality vs epochs — DGD and Choco stall at
//!     their bias balls; NIDS / LEAD(32bit) / LEAD(2bit) / LessBit-B
//!     converge linearly, LEAD(2bit) ≈ LEAD(32bit) per iteration.
//! (b) same runs vs communicated bits — the 2-bit curves win by ~15×.
//! (c) stochastic: LEAD-{SGD, LSVRG, SAGA} ×{32, 2}bit + Choco-SGD +
//!     LessBit-{SGD, LSVRG} vs #gradient evaluations.
//! (d) same vs bits.
//!
//! Emits bench_out/fig1{a,b,c,d}.csv; prints the who-wins summary rows.

mod common;

use common::{out_dir, thin, Fixture};
use proxlead::algorithm::{Algorithm, Choco, Dgd, Hyper, Nids, Pdgm, ProxLead};
use proxlead::compress::{Identity, InfNormQuantizer};
use proxlead::engine::{run, RunConfig, XAxis};
use proxlead::oracle::OracleKind;
use proxlead::prox::Zero;
use proxlead::util::bench::{CsvSeries, Table};

fn q2() -> Box<InfNormQuantizer> {
    Box::new(InfNormQuantizer::new(2, 256))
}

fn main() {
    let fx = Fixture::section5(0.05);
    let x_star = fx.reference(0.0);
    let (p, w, x0, eta) = (&fx.problem, &fx.w, &fx.x0, fx.eta);
    let epoch = fx.evals_per_epoch();

    // ---------------- (a)/(b): full gradient ----------------------------
    let rounds = 12_000;
    let cfg = RunConfig::fixed(rounds).every(50);
    let mut algs: Vec<Box<dyn Algorithm>> = vec![
        Box::new(Dgd::new(
            p,
            w,
            x0,
            eta,
            OracleKind::Full,
            Box::new(Identity::f32()),
            Box::new(Zero),
            7,
        )),
        Box::new(Choco::new(p, w, x0, eta, 0.2, OracleKind::Full, q2(), Box::new(Zero), 7)),
        Box::new(Nids::new(p, w, x0, eta, OracleKind::Full, Box::new(Zero), 7)),
        Box::new(Pdgm::lessbit_b(p, w, x0, eta, 0.05, q2(), 0.2, 7)),
        Box::new(ProxLead::new(
            p,
            w,
            x0,
            Hyper::paper_default(eta),
            OracleKind::Full,
            Box::new(Identity::f32()),
            Box::new(Zero),
            7,
        )),
        Box::new(ProxLead::new(
            p,
            w,
            x0,
            Hyper::paper_default(eta),
            OracleKind::Full,
            q2(),
            Box::new(Zero),
            7,
        )),
    ];
    let mut csv_a = CsvSeries::new("epochs");
    let mut csv_b = CsvSeries::new("bits");
    let mut table = Table::new(
        "Fig 1a/1b — smooth, full gradient (12000 rounds)",
        &["algorithm", "final subopt", "Mbit", "linear?"],
    );
    for alg in algs.iter_mut() {
        let res = run(alg.as_mut(), p, &x_star, &cfg);
        csv_a.add(&res.name, thin(res.series(XAxis::Epochs(epoch)), 250));
        csv_b.add(&res.name, thin(res.series(XAxis::Bits), 250));
        let last = res.history.last().unwrap();
        // log-linear slope over the tail classifies linear vs stalled
        let n_hist = res.history.len();
        let tail: Vec<f64> = res
            .history
            .iter()
            .skip(n_hist.saturating_sub(60))
            .map(|m| m.suboptimality.max(1e-30))
            .collect();
        let slope = proxlead::util::stats::loglinear_slope(&tail);
        table.row(vec![
            res.name.clone(),
            format!("{:.3e}", last.suboptimality),
            format!("{:.1}", last.bits as f64 / 1e6),
            if last.suboptimality < 1e-12 || slope < -1e-3 {
                "linear".into()
            } else {
                "stalls".into()
            },
        ]);
    }
    table.print();
    csv_a.write(out_dir().join("fig1a.csv").to_str().unwrap()).unwrap();
    csv_b.write(out_dir().join("fig1b.csv").to_str().unwrap()).unwrap();

    // ---------------- (c)/(d): stochastic gradients ---------------------
    let rounds = 15_000;
    let cfg = RunConfig::fixed(rounds).every(60);
    let eta_s = 1.0 / (6.0 * proxlead::problem::Problem::smoothness(p));
    let lsvrg = OracleKind::Lsvrg { p: 1.0 / 15.0 };
    let mk_lead = |kind: OracleKind, comp: Box<dyn proxlead::compress::Compressor>| {
        Box::new(ProxLead::new(
            p,
            w,
            x0,
            Hyper::paper_default(eta_s),
            kind,
            comp,
            Box::new(Zero),
            9,
        ))
    };
    let mut algs: Vec<Box<dyn Algorithm>> = vec![
        mk_lead(OracleKind::Sgd, Box::new(Identity::f32())),
        mk_lead(OracleKind::Sgd, q2()),
        mk_lead(lsvrg, Box::new(Identity::f32())),
        mk_lead(lsvrg, q2()),
        mk_lead(OracleKind::Saga, Box::new(Identity::f32())),
        mk_lead(OracleKind::Saga, q2()),
        Box::new(Choco::new(p, w, x0, eta_s, 0.2, OracleKind::Sgd, q2(), Box::new(Zero), 9)),
        Box::new(Pdgm::new(p, w, x0, eta_s, 0.1 / (2.0 * eta_s), OracleKind::Sgd, q2(), 0.25, 9)),
        Box::new(Pdgm::new(p, w, x0, eta_s, 0.1 / (2.0 * eta_s), lsvrg, q2(), 0.25, 9)),
    ];
    let mut csv_c = CsvSeries::new("grad_evals");
    let mut csv_d = CsvSeries::new("bits");
    let mut table = Table::new(
        "Fig 1c/1d — smooth, stochastic (15000 rounds)",
        &["algorithm", "final subopt", "grad evals", "Mbit"],
    );
    for alg in algs.iter_mut() {
        let res = run(alg.as_mut(), p, &x_star, &cfg);
        csv_c.add(&res.name, thin(res.series(XAxis::GradEvals), 250));
        csv_d.add(&res.name, thin(res.series(XAxis::Bits), 250));
        let last = res.history.last().unwrap();
        table.row(vec![
            res.name.clone(),
            format!("{:.3e}", last.suboptimality),
            format!("{}", last.grad_evals),
            format!("{:.1}", last.bits as f64 / 1e6),
        ]);
    }
    table.print();
    csv_c.write(out_dir().join("fig1c.csv").to_str().unwrap()).unwrap();
    csv_d.write(out_dir().join("fig1d.csv").to_str().unwrap()).unwrap();
    println!("\nwrote bench_out/fig1{{a,b,c,d}}.csv");
}
