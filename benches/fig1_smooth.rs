//! Figure 1 — smooth logistic regression (λ1 = 0).
//!
//! (a) full gradient: suboptimality vs epochs — DGD and Choco stall at
//!     their bias balls; NIDS / LEAD(32bit) / LEAD(2bit) / LessBit-B
//!     converge linearly, LEAD(2bit) ≈ LEAD(32bit) per iteration.
//! (b) same runs vs communicated bits — the 2-bit curves win by ~15×.
//! (c) stochastic: LEAD-{SGD, LSVRG, SAGA} ×{32, 2}bit + Choco-SGD +
//!     LessBit-{SGD, LSVRG} vs #gradient evaluations.
//! (d) same vs bits.
//!
//! The grids are declared as [`SweepSpec`]s and executed by the parallel
//! sweep runtime — panel (a/b) is six explicit variants, panel (c/d) is a
//! LEAD oracle×codec cartesian product plus three comparator variants.
//!
//! Emits bench_out/fig1{a,b,c,d}.csv; prints the who-wins summary rows.

mod common;

use common::{out_dir, thin};
use proxlead::config::Config;
use proxlead::runner::XAxis;
use proxlead::problem::Problem;
use proxlead::sweep::{
    run_sweep_verbose, run_sweep_verbose_with_cache, CellOutcome, RefCache, SweepSpec,
};
use proxlead::util::bench::{CsvSeries, Table};
use proxlead::util::stats::loglinear_slope;

/// The §5 analog at bench scale (see DESIGN.md §4): 8-node ring, uniform
/// mixing, label-sorted 10-class blobs, 15 minibatches per node. 8 nodes ×
/// 15 batches = 120 batch-gradient evals per epoch (Fig 1's x-axis unit).
const EVALS_PER_EPOCH: u64 = 8 * 15;

fn base_cfg(rounds: usize, every: usize, eta: f64) -> Config {
    Config::parse(&format!(
        "nodes = 8\nsamples_per_node = 120\ndim = 32\nclasses = 10\nbatches = 15\n\
         separation = 1.0\nlambda1 = 0\nlambda2 = 0.05\n\
         rounds = {rounds}\nrecord_every = {every}\neta = {eta}\n"
    ))
    .expect("fig1 base config")
}

fn main() {
    // ---------------- (a)/(b): full gradient ----------------------------
    // eta = 0 ⇒ auto 1/(2L); each variant pairs an algorithm with its own
    // codec and family-specific constants, exactly as §5 configures them
    let spec = SweepSpec::new(base_cfg(12_000, 50, 0.0))
        .variant(&[("algorithm", "dgd"), ("bits", "32")])
        .variant(&[("algorithm", "choco"), ("bits", "2"), ("gamma", "0.2")])
        .variant(&[("algorithm", "nids"), ("bits", "32")])
        .variant(&[
            ("algorithm", "lessbit-b"),
            ("bits", "2"),
            ("gamma", "0.05"),
            ("alpha", "0.2"),
        ])
        .variant(&[("algorithm", "lead"), ("bits", "32")])
        .variant(&[("algorithm", "lead"), ("bits", "2")]);
    println!(
        "fig1 a/b: {} cells (full gradient, 12000 rounds) on {} threads",
        spec.num_cells(),
        spec.threads
    );
    let res = run_sweep_verbose(&spec).expect("fig1 a/b sweep");

    let mut csv_a = CsvSeries::new("epochs");
    let mut csv_b = CsvSeries::new("bits");
    let mut table = Table::new(
        "Fig 1a/1b — smooth, full gradient (12000 rounds)",
        &["algorithm", "final subopt", "Mbit", "linear?"],
    );
    for cell in &res.cells {
        let r = &cell.result;
        csv_a.add(&r.name, thin(r.series(XAxis::Epochs(EVALS_PER_EPOCH)), 250));
        csv_b.add(&r.name, thin(r.series(XAxis::Bits), 250));
        let last = r.history.last().unwrap();
        // log-linear slope over the tail classifies linear vs stalled
        let n_hist = r.history.len();
        let tail: Vec<f64> = r
            .history
            .iter()
            .skip(n_hist.saturating_sub(60))
            .map(|m| m.suboptimality.max(1e-30))
            .collect();
        let slope = loglinear_slope(&tail);
        table.row(vec![
            r.name.clone(),
            format!("{:.3e}", last.suboptimality),
            format!("{:.1}", last.bits as f64 / 1e6),
            if last.suboptimality < 1e-12 || slope < -1e-3 {
                "linear".into()
            } else {
                "stalls".into()
            },
        ]);
    }
    table.print();
    csv_a.write(out_dir().join("fig1a.csv").to_str().unwrap()).unwrap();
    csv_b.write(out_dir().join("fig1b.csv").to_str().unwrap()).unwrap();

    // ---------------- (c)/(d): stochastic gradients ---------------------
    // LEAD × {sgd, lsvrg, saga} × {32, 2}bit as a cartesian grid, plus the
    // Choco-SGD / LessBit comparators as explicit variants (their own
    // stepsize constants), all at η = 1/(6L)
    let eta_s = {
        let problem = proxlead::exp::build_problem(&base_cfg(1, 1, 0.0)).expect("fig1 problem");
        1.0 / (6.0 * problem.smoothness())
    };
    let base_s = base_cfg(15_000, 60, eta_s);
    let lead_spec = SweepSpec::new(base_s.clone())
        .variant(&[("algorithm", "lead")])
        .axis("oracle", &["sgd", "lsvrg", "saga"])
        .axis("bits", &["32", "2"]);
    let comparator_spec = SweepSpec::new(base_s)
        .variant(&[("algorithm", "choco"), ("bits", "2"), ("gamma", "0.2"), ("oracle", "sgd")])
        .variant(&[
            ("algorithm", "pdgm"),
            ("bits", "2"),
            ("gamma", "0.1"),
            ("alpha", "0.25"),
            ("oracle", "sgd"),
        ])
        .variant(&[
            ("algorithm", "pdgm"),
            ("bits", "2"),
            ("gamma", "0.1"),
            ("alpha", "0.25"),
            ("oracle", "lsvrg"),
        ]);
    println!(
        "\nfig1 c/d: {} + {} cells (stochastic, 15000 rounds) on {} threads",
        lead_spec.num_cells(),
        comparator_spec.num_cells(),
        lead_spec.threads
    );
    // both panels share one problem ⇒ share one reference solve
    let cache = RefCache::default();
    let mut cells: Vec<CellOutcome> =
        run_sweep_verbose_with_cache(&lead_spec, &cache).expect("fig1 c/d LEAD sweep").cells;
    cells.extend(
        run_sweep_verbose_with_cache(&comparator_spec, &cache)
            .expect("fig1 c/d comparator sweep")
            .cells,
    );

    let mut csv_c = CsvSeries::new("grad_evals");
    let mut csv_d = CsvSeries::new("bits");
    let mut table = Table::new(
        "Fig 1c/1d — smooth, stochastic (15000 rounds)",
        &["algorithm", "final subopt", "grad evals", "Mbit"],
    );
    for cell in &cells {
        let r = &cell.result;
        csv_c.add(&r.name, thin(r.series(XAxis::GradEvals), 250));
        csv_d.add(&r.name, thin(r.series(XAxis::Bits), 250));
        let last = r.history.last().unwrap();
        table.row(vec![
            r.name.clone(),
            format!("{:.3e}", last.suboptimality),
            format!("{}", last.grad_evals),
            format!("{:.1}", last.bits as f64 / 1e6),
        ]);
    }
    table.print();
    csv_c.write(out_dir().join("fig1c.csv").to_str().unwrap()).unwrap();
    csv_d.write(out_dir().join("fig1d.csv").to_str().unwrap()).unwrap();
    println!("\nwrote bench_out/fig1{{a,b,c,d}}.csv");
}
