//! Wire-bytes comparison — the paper's bits-axis figures (1b/2b) measured
//! on a *real* framed codec instead of the engine's accounting model.
//!
//! Every registry algorithm runs on the message-passing coordinator over
//! the same 8-node ring; the "communication cost" column is the total
//! serialized bytes that actually crossed the per-edge channels (frame
//! headers included), next to the entropy-coded payload bits the figures
//! plot. The reproduction target is the paper's headline shape: the
//! LEAD-family 2-bit rows land within a round-count whisker of their
//! uncompressed counterparts while moving an order of magnitude fewer
//! bytes; Choco moves as little but converges to a bias ball; the
//! uncompressed baselines (NIDS / PG-EXTRA / P2D2 / DGD on a 32-bit wire)
//! pay the full freight.
//!
//! Emits bench_out/wire_bytes.csv + bench_out/wire_bytes.json (CI artifact;
//! PERF_SMOKE=1 shrinks rounds so the whole harness finishes in seconds).

mod common;

use common::out_dir;
use proxlead::config::Config;
use proxlead::exp::Experiment;
use proxlead::util::bench::{smoke_mode, BenchReport, BenchSet, Table};
use std::sync::Arc;

fn base_cfg(rounds: usize) -> Config {
    // the Table-3 scale suite (DualGD pays an inner solve per round), smooth
    // panel so the dual family competes on the same objective
    Config::parse(&format!(
        "nodes = 8\nsamples_per_node = 60\ndim = 16\nclasses = 5\nbatches = 15\n\
         separation = 1.0\nlambda1 = 0\nlambda2 = 0.05\nrounds = {rounds}\n\
         record_every = {rounds}\n"
    ))
    .expect("wire_bytes base config")
}

fn main() {
    let rounds = if smoke_mode() { 60 } else { 600 };
    // (label, algorithm, overrides) — the Fig 1b cast plus every remaining
    // registry baseline on its conventional wire width
    let variants: &[(&str, &str, &[(&str, &str)])] = &[
        ("Prox-LEAD 2bit", "prox-lead", &[("bits", "2")]),
        ("PUDA (C=0, 64bit)", "prox-lead", &[("bits", "64")]),
        ("LEAD 2bit", "lead", &[("bits", "2")]),
        ("Choco 2bit", "choco", &[("bits", "2"), ("gamma", "0.2"), ("eta", "0.05")]),
        ("DGD 32bit", "dgd", &[("bits", "32")]),
        ("NIDS 32bit", "nids", &[("bits", "32")]),
        ("PG-EXTRA 32bit", "pg-extra", &[("bits", "32")]),
        ("P2D2 32bit", "p2d2", &[("bits", "32")]),
        ("LessBit-B 2bit", "pdgm", &[("bits", "2"), ("gamma", "0.1"), ("alpha", "0.25")]),
        ("LessBit-A 2bit", "dualgd", &[("bits", "2"), ("alpha", "0.25")]),
    ];

    let mut set =
        BenchSet::new(&format!("coordinator wire bytes — {rounds} rounds")).with_reps(0, 1);
    set.header();
    let mut table =
        Table::new("Algorithms on the same wire", &["algorithm", "wire KiB", "Mbit", "subopt"]);
    let mut csv = String::from("algorithm,codec,rounds,wire_bytes,payload_bits,subopt\n");
    let mut x_star: Option<Arc<Vec<f64>>> = None;

    for &(label, algorithm, overrides) in variants {
        let mut cfg = base_cfg(rounds);
        cfg.set("algorithm", algorithm).expect("algorithm");
        for &(k, v) in overrides {
            cfg.set(k, v).expect("override");
        }
        let exp = Experiment::from_config(&cfg).expect("experiment");
        // identical problem across variants ⇒ one reference solve total
        if let Some(r) = &x_star {
            exp.set_reference(Arc::clone(r));
        } else {
            x_star = Some(exp.reference());
        }

        // the unified run API: suboptimality is sampled by the leader, so
        // the final history row already carries every column we report
        let mut last = None;
        set.run(label, || last = Some(exp.run_coordinator(&exp.run_spec())));
        let res = last.expect("coordinator ran");
        let m = res.history.last().expect("final snapshot");
        let (bits, s) = (m.bits, m.suboptimality);
        table.row(vec![
            label.into(),
            format!("{:.1}", res.wire_bytes() as f64 / 1024.0),
            format!("{:.2}", bits as f64 / 1e6),
            format!("{s:.2e}"),
        ]);
        csv.push_str(&format!(
            "{label},{},{rounds},{},{bits},{s:.6e}\n",
            exp.codec().name(),
            res.wire_bytes(),
        ));
    }

    // the same frames over a real byte stream: one loopback-Tcp row pins
    // the socket transport's cost next to its in-process twin (identical
    // wire accounting — the transport moves frames, it doesn't re-price
    // them — so the delta this row shows is pure runtime overhead)
    {
        let mut cfg = base_cfg(rounds);
        cfg.set("algorithm", "prox-lead").expect("algorithm");
        cfg.set("bits", "2").expect("override");
        let exp = Experiment::from_config(&cfg).expect("experiment");
        if let Some(r) = &x_star {
            exp.set_reference(Arc::clone(r));
        }
        let label = "Prox-LEAD 2bit tcp-loopback";
        let mut last = None;
        set.run(label, || last = Some(exp.run_coordinator_loopback(&exp.run_spec(), "tcp")));
        let res = last.expect("loopback coordinator ran");
        let m = res.history.last().expect("final snapshot");
        table.row(vec![
            label.into(),
            format!("{:.1}", res.wire_bytes() as f64 / 1024.0),
            format!("{:.2}", m.bits as f64 / 1e6),
            format!("{:.2e}", m.suboptimality),
        ]);
        csv.push_str(&format!(
            "{label},{},{rounds},{},{},{:.6e}\n",
            exp.codec().name(),
            res.wire_bytes(),
            m.bits,
            m.suboptimality,
        ));
    }

    table.print();
    std::fs::write(out_dir().join("wire_bytes.csv"), csv).expect("write csv");
    let mut report = BenchReport::new("wire_bytes");
    report.add(&set);
    report.write(out_dir().join("wire_bytes.json").to_str().unwrap()).expect("write json");
    println!("\nwrote bench_out/wire_bytes.csv + wire_bytes.json");
    println!(
        "reading the shape: the 2-bit LEAD-family rows should reach comparable\n\
         suboptimality while moving ~10x fewer wire bytes than the 32/64-bit rows —\n\
         'reduces the communication cost almost for free', now measured on real frames."
    );
}
