#![allow(dead_code)] // each bench binary uses a subset of these helpers

//! Shared fixtures for the figure/table benches: the §5 workload at bench
//! scale resolved through the Experiment API, plus CSV output plumbing
//! (`bench_out/*.csv` holds the series the paper's figures plot).

use proxlead::algorithm::solve_reference;
use proxlead::exp::Experiment;
use proxlead::problem::Problem;

/// The §5 analog resolved once: 8-node ring, 1/3 mixing, label-sorted
/// 10-class blobs, 15 minibatches per node (see DESIGN.md §4 for the
/// MNIST substitution). Access the problem / mixing / x0 / auto-η through
/// `exp` — there is no second resolution path.
pub struct Fixture {
    pub exp: Experiment,
}

impl Fixture {
    pub fn section5(lambda2: f64) -> Fixture {
        let exp = Experiment::builder()
            .nodes(8)
            .set("samples_per_node", "120")
            .set("dim", "32")
            .set("classes", "10")
            .set("batches", "15")
            .set("separation", "1.0")
            .set("lambda1", "5e-3")
            .lambda2(lambda2)
            .bits(2)
            .build()
            .expect("section5 fixture");
        Fixture { exp }
    }

    /// Smaller suite for the Table 3 cross-algorithm comparison (the
    /// DualGD rows pay an inner solve per round).
    pub fn table3() -> Fixture {
        let exp = Experiment::builder()
            .nodes(8)
            .set("samples_per_node", "60")
            .set("dim", "16")
            .set("classes", "5")
            .set("batches", "15")
            .set("separation", "1.0")
            .lambda2(0.05)
            .bits(2)
            .build()
            .expect("table3 fixture");
        Fixture { exp }
    }

    pub fn reference(&self, lambda1: f64) -> Vec<f64> {
        solve_reference(self.exp.problem.as_ref(), lambda1, 80_000, 1e-12)
    }

    /// Batch-gradient evaluations per epoch (n·m) — Fig 1's x-axis unit.
    pub fn evals_per_epoch(&self) -> u64 {
        (self.exp.problem.num_nodes() * self.exp.problem.num_batches()) as u64
    }
}

pub fn out_dir() -> std::path::PathBuf {
    let d = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("bench_out");
    std::fs::create_dir_all(&d).expect("create bench_out");
    d
}

/// Thin every series to ≤ `max_pts` points so the CSVs stay plottable.
pub fn thin(pts: Vec<(f64, f64)>, max_pts: usize) -> Vec<(f64, f64)> {
    if pts.len() <= max_pts {
        return pts;
    }
    let step = pts.len().div_ceil(max_pts);
    pts.into_iter().step_by(step).collect()
}
