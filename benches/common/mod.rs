#![allow(dead_code)] // each bench binary uses a subset of these helpers

//! Shared fixtures for the figure/table benches: the §5 workload at bench
//! scale, plus CSV output plumbing (`bench_out/*.csv` holds the series the
//! paper's figures plot).

use proxlead::algorithm::solve_reference;
use proxlead::graph::{Graph, MixingOp, MixingRule};
use proxlead::linalg::Mat;
use proxlead::problem::data::BlobSpec;
use proxlead::problem::{LogReg, Problem};

/// The §5 analog: 8-node ring, 1/3 mixing, label-sorted 10-class blobs,
/// 15 minibatches per node (see DESIGN.md §4 for the MNIST substitution).
pub struct Fixture {
    pub problem: LogReg,
    pub w: MixingOp,
    pub x0: Mat,
    pub eta: f64,
}

impl Fixture {
    pub fn section5(lambda2: f64) -> Fixture {
        let spec = BlobSpec {
            nodes: 8,
            samples_per_node: 120,
            dim: 32,
            classes: 10,
            separation: 1.0,
            ..Default::default()
        };
        let problem = LogReg::from_blobs(&spec, lambda2, 15);
        let g = Graph::ring(8);
        let w = MixingOp::build(&g, MixingRule::UniformMaxDegree);
        let x0 = Mat::zeros(8, problem.dim());
        let eta = 0.5 / problem.smoothness();
        Fixture { problem, w, x0, eta }
    }

    /// Smaller suite for the Table 3 cross-algorithm comparison (the
    /// DualGD rows pay an inner solve per round).
    pub fn table3() -> Fixture {
        let spec = BlobSpec {
            nodes: 8,
            samples_per_node: 60,
            dim: 16,
            classes: 5,
            separation: 1.0,
            ..Default::default()
        };
        let problem = LogReg::from_blobs(&spec, 0.05, 15);
        let g = Graph::ring(8);
        let w = MixingOp::build(&g, MixingRule::UniformMaxDegree);
        let x0 = Mat::zeros(8, problem.dim());
        let eta = 0.5 / problem.smoothness();
        Fixture { problem, w, x0, eta }
    }

    pub fn reference(&self, lambda1: f64) -> Vec<f64> {
        solve_reference(&self.problem, lambda1, 80_000, 1e-12)
    }

    /// Batch-gradient evaluations per epoch (n·m) — Fig 1's x-axis unit.
    pub fn evals_per_epoch(&self) -> u64 {
        (self.problem.num_nodes() * self.problem.num_batches()) as u64
    }
}

pub fn out_dir() -> std::path::PathBuf {
    let d = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("bench_out");
    std::fs::create_dir_all(&d).expect("create bench_out");
    d
}

/// Thin every series to ≤ `max_pts` points so the CSVs stay plottable.
pub fn thin(pts: Vec<(f64, f64)>, max_pts: usize) -> Vec<(f64, f64)> {
    if pts.len() <= max_pts {
        return pts;
    }
    let step = pts.len().div_ceil(max_pts);
    pts.into_iter().step_by(step).collect()
}
