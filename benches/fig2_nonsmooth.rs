//! Figure 2 — non-smooth logistic regression (λ1 = 5e-3, ℓ1 prox).
//!
//! (a) full gradient: Prox-LEAD(2bit) vs Prox-LEAD(32bit), P2D2, NIDS,
//!     PG-EXTRA, Prox-DGD — linear convergence with the shared ℓ1 term,
//!     2-bit matching 32-bit per iteration.
//! (b) the same vs communicated bits.
//! (c) stochastic: Prox-LEAD-{SGD, LSVRG, SAGA} × {32, 2}bit.
//! (d) the same vs bits.
//!
//! Both grids are [`SweepSpec`]s on the parallel sweep runtime: panel
//! (a/b) is six explicit variants, panel (c/d) is a pure oracle × codec
//! cartesian product. λ1 > 0 in the base config routes every algorithm
//! through its proximal step automatically.
//!
//! Emits bench_out/fig2{a,b,c,d}.csv.

mod common;

use common::{out_dir, thin};
use proxlead::config::Config;
use proxlead::runner::XAxis;
use proxlead::problem::Problem;
use proxlead::sweep::{run_sweep_verbose, SweepSpec};
use proxlead::util::bench::{CsvSeries, Table};

const LAMBDA1: f64 = 5e-3;
const EVALS_PER_EPOCH: u64 = 8 * 15;

fn base_cfg(rounds: usize, every: usize, eta: f64) -> Config {
    Config::parse(&format!(
        "nodes = 8\nsamples_per_node = 120\ndim = 32\nclasses = 10\nbatches = 15\n\
         separation = 1.0\nlambda1 = {LAMBDA1}\nlambda2 = 0.05\n\
         rounds = {rounds}\nrecord_every = {every}\neta = {eta}\n"
    ))
    .expect("fig2 base config")
}

fn main() {
    // ---------------- (a)/(b): full gradient ----------------------------
    let spec = SweepSpec::new(base_cfg(6000, 25, 0.0))
        .variant(&[("algorithm", "prox-dgd"), ("bits", "32")])
        .variant(&[("algorithm", "nids"), ("bits", "32")])
        .variant(&[("algorithm", "p2d2"), ("bits", "32")])
        .variant(&[("algorithm", "pg-extra"), ("bits", "32")])
        .variant(&[("algorithm", "prox-lead"), ("bits", "32")])
        .variant(&[("algorithm", "prox-lead"), ("bits", "2")]);
    println!(
        "fig2 a/b: {} cells (composite, full gradient, 6000 rounds) on {} threads",
        spec.num_cells(),
        spec.threads
    );
    let res = run_sweep_verbose(&spec).expect("fig2 a/b sweep");

    let mut csv_a = CsvSeries::new("epochs");
    let mut csv_b = CsvSeries::new("bits");
    let mut table = Table::new(
        "Fig 2a/2b — non-smooth (λ1 = 5e-3), full gradient",
        &["algorithm", "final subopt", "Mbit", "linear?"],
    );
    for cell in &res.cells {
        let r = &cell.result;
        csv_a.add(&r.name, thin(r.series(XAxis::Epochs(EVALS_PER_EPOCH)), 250));
        csv_b.add(&r.name, thin(r.series(XAxis::Bits), 250));
        let last = r.history.last().unwrap();
        table.row(vec![
            r.name.clone(),
            format!("{:.3e}", last.suboptimality),
            format!("{:.1}", last.bits as f64 / 1e6),
            if last.suboptimality < 1e-12 { "yes".into() } else { "stalls".into() },
        ]);
    }
    table.print();
    csv_a.write(out_dir().join("fig2a.csv").to_str().unwrap()).unwrap();
    csv_b.write(out_dir().join("fig2b.csv").to_str().unwrap()).unwrap();

    // ---------------- (c)/(d): stochastic --------------------------------
    let eta_s = {
        let problem = proxlead::exp::build_problem(&base_cfg(1, 1, 0.0)).expect("fig2 problem");
        1.0 / (6.0 * problem.smoothness())
    };
    let spec = SweepSpec::new(base_cfg(15_000, 60, eta_s))
        .variant(&[("algorithm", "prox-lead")])
        .axis("oracle", &["sgd", "lsvrg", "saga"])
        .axis("bits", &["32", "2"]);
    println!(
        "\nfig2 c/d: {} cells (composite, stochastic, 15000 rounds) on {} threads",
        spec.num_cells(),
        spec.threads
    );
    let res = run_sweep_verbose(&spec).expect("fig2 c/d sweep");

    let mut csv_c = CsvSeries::new("grad_evals");
    let mut csv_d = CsvSeries::new("bits");
    let mut table = Table::new(
        "Fig 2c/2d — non-smooth, stochastic",
        &["algorithm", "final subopt", "grad evals", "Mbit"],
    );
    for cell in &res.cells {
        let r = &cell.result;
        csv_c.add(&r.name, thin(r.series(XAxis::GradEvals), 250));
        csv_d.add(&r.name, thin(r.series(XAxis::Bits), 250));
        let last = r.history.last().unwrap();
        table.row(vec![
            r.name.clone(),
            format!("{:.3e}", last.suboptimality),
            format!("{}", last.grad_evals),
            format!("{:.1}", last.bits as f64 / 1e6),
        ]);
    }
    table.print();
    csv_c.write(out_dir().join("fig2c.csv").to_str().unwrap()).unwrap();
    csv_d.write(out_dir().join("fig2d.csv").to_str().unwrap()).unwrap();
    println!("\nwrote bench_out/fig2{{a,b,c,d}}.csv");
}
