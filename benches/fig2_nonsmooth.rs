//! Figure 2 — non-smooth logistic regression (λ1 = 5e-3, ℓ1 prox).
//!
//! (a) full gradient: Prox-LEAD(2bit) vs Prox-LEAD(32bit), P2D2, NIDS,
//!     PG-EXTRA, Prox-DGD — linear convergence with the shared ℓ1 term,
//!     2-bit matching 32-bit per iteration.
//! (b) the same vs communicated bits.
//! (c) stochastic: Prox-LEAD-{SGD, LSVRG, SAGA} × {32, 2}bit.
//! (d) the same vs bits.
//!
//! Emits bench_out/fig2{a,b,c,d}.csv.

mod common;

use common::{out_dir, thin, Fixture};
use proxlead::algorithm::{Algorithm, Dgd, Hyper, Nids, P2d2, PgExtra, ProxLead};
use proxlead::compress::{Identity, InfNormQuantizer};
use proxlead::engine::{run, RunConfig, XAxis};
use proxlead::oracle::OracleKind;
use proxlead::prox::L1;
use proxlead::util::bench::{CsvSeries, Table};

const LAMBDA1: f64 = 5e-3;

fn q2() -> Box<InfNormQuantizer> {
    Box::new(InfNormQuantizer::new(2, 256))
}

fn l1() -> Box<L1> {
    Box::new(L1::new(LAMBDA1))
}

fn main() {
    let fx = Fixture::section5(0.05);
    let x_star = fx.reference(LAMBDA1);
    let (p, w, x0, eta) = (&fx.problem, &fx.w, &fx.x0, fx.eta);
    let epoch = fx.evals_per_epoch();

    // ---------------- (a)/(b): full gradient ----------------------------
    let cfg = RunConfig::fixed(6000).every(25);
    let mut algs: Vec<Box<dyn Algorithm>> = vec![
        Box::new(Dgd::new(p, w, x0, eta, OracleKind::Full, Box::new(Identity::f32()), l1(), 7)),
        Box::new(Nids::new(p, w, x0, eta, OracleKind::Full, l1(), 7)),
        Box::new(P2d2::new(p, w, x0, eta, OracleKind::Full, l1(), 7)),
        Box::new(PgExtra::new(p, w, x0, eta, OracleKind::Full, l1(), 7)),
        Box::new(ProxLead::new(
            p,
            w,
            x0,
            Hyper::paper_default(eta),
            OracleKind::Full,
            Box::new(Identity::f32()),
            l1(),
            7,
        )),
        Box::new(ProxLead::new(p, w, x0, Hyper::paper_default(eta), OracleKind::Full, q2(), l1(), 7)),
    ];
    let mut csv_a = CsvSeries::new("epochs");
    let mut csv_b = CsvSeries::new("bits");
    let mut table = Table::new(
        "Fig 2a/2b — non-smooth (λ1 = 5e-3), full gradient",
        &["algorithm", "final subopt", "Mbit", "linear?"],
    );
    for alg in algs.iter_mut() {
        let res = run(alg.as_mut(), p, &x_star, &cfg);
        csv_a.add(&res.name, thin(res.series(XAxis::Epochs(epoch)), 250));
        csv_b.add(&res.name, thin(res.series(XAxis::Bits), 250));
        let last = res.history.last().unwrap();
        table.row(vec![
            res.name.clone(),
            format!("{:.3e}", last.suboptimality),
            format!("{:.1}", last.bits as f64 / 1e6),
            if last.suboptimality < 1e-12 { "yes".into() } else { "stalls".into() },
        ]);
    }
    table.print();
    csv_a.write(out_dir().join("fig2a.csv").to_str().unwrap()).unwrap();
    csv_b.write(out_dir().join("fig2b.csv").to_str().unwrap()).unwrap();

    // ---------------- (c)/(d): stochastic --------------------------------
    let cfg = RunConfig::fixed(15_000).every(60);
    let eta_s = 1.0 / (6.0 * proxlead::problem::Problem::smoothness(p));
    let lsvrg = OracleKind::Lsvrg { p: 1.0 / 15.0 };
    let mk = |kind: OracleKind, comp: Box<dyn proxlead::compress::Compressor>| {
        Box::new(ProxLead::new(p, w, x0, Hyper::paper_default(eta_s), kind, comp, l1(), 9))
    };
    let mut algs: Vec<Box<dyn Algorithm>> = vec![
        mk(OracleKind::Sgd, Box::new(Identity::f32())),
        mk(OracleKind::Sgd, q2()),
        mk(lsvrg, Box::new(Identity::f32())),
        mk(lsvrg, q2()),
        mk(OracleKind::Saga, Box::new(Identity::f32())),
        mk(OracleKind::Saga, q2()),
    ];
    let mut csv_c = CsvSeries::new("grad_evals");
    let mut csv_d = CsvSeries::new("bits");
    let mut table = Table::new(
        "Fig 2c/2d — non-smooth, stochastic",
        &["algorithm", "final subopt", "grad evals", "Mbit"],
    );
    for alg in algs.iter_mut() {
        let res = run(alg.as_mut(), p, &x_star, &cfg);
        csv_c.add(&res.name, thin(res.series(XAxis::GradEvals), 250));
        csv_d.add(&res.name, thin(res.series(XAxis::Bits), 250));
        let last = res.history.last().unwrap();
        table.row(vec![
            res.name.clone(),
            format!("{:.3e}", last.suboptimality),
            format!("{}", last.grad_evals),
            format!("{:.1}", last.bits as f64 / 1e6),
        ]);
    }
    table.print();
    csv_c.write(out_dir().join("fig2c.csv").to_str().unwrap()).unwrap();
    csv_d.write(out_dir().join("fig2d.csv").to_str().unwrap()).unwrap();
    println!("\nwrote bench_out/fig2{{a,b,c,d}}.csv");
}
