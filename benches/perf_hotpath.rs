//! Hot-path microbenchmarks — the §Perf harness (EXPERIMENTS.md).
//!
//! Layer by layer:
//! - L3 primitives: blocked matmul (the engine's W·X mixing), the ∞-norm
//!   quantizer encode/decode, the wire codec, one COMM round;
//! - L3 end-to-end: one Prox-LEAD matrix step; one coordinator round
//!   (8 threads, serialized frames); a multi-cell sweep through the
//!   parallel sweep runtime;
//! - L2/L1: one PJRT gradient execution vs the native rust gradient at
//!   the shipped artifact shape (240×64×10) — requires `--features xla`.
//!
//! Run before/after every optimization and record deltas in
//! EXPERIMENTS.md §Perf. Every set is aggregated into
//! `bench_out/perf_hotpath.json` (the CI bench-trajectory artifact);
//! `PERF_SMOKE=1` shrinks reps/workloads to CI scale.

mod common;

use common::{out_dir, Fixture};
use proxlead::algorithm::{Algorithm, CommState, ProxLead};
use proxlead::compress::bits::{
    decode_inf_quantized, decode_inf_quantized_into, encode_inf_quantized,
    encode_inf_quantized_into,
};
use proxlead::compress::{Compressor, InfNormQuantizer};
use proxlead::coordinator::wire::{frame_begin, frame_end};
use proxlead::coordinator::{self, CoordConfig, FrameRef, NodeHyper, ProxLeadNode, WireCodec};
use proxlead::linalg::Mat;
use proxlead::oracle::OracleKind;
use proxlead::problem::data::{blobs, BlobSpec};
use proxlead::problem::{LogReg, Problem};
use proxlead::prox::Zero;
use proxlead::sweep::{run_sweep, SweepSpec};
use proxlead::util::bench::{smoke_mode, BenchReport, BenchSet};
use proxlead::util::rng::Rng;
use std::sync::Arc;

fn main() {
    let smoke = smoke_mode();
    if smoke {
        println!("PERF_SMOKE=1: minimal reps/workloads (CI trajectory mode)");
    }
    let reps = |warmup: usize, n: usize| if smoke { (0, 2) } else { (warmup, n) };
    let mut report = BenchReport::new("perf_hotpath");
    let mut rng = Rng::new(7);

    // ---------- L3 primitive: blocked matmul ----------------------------
    let (w0, n0) = reps(3, 15);
    let mut set = BenchSet::new("matmul (engine mixing W·X and gradients)").with_reps(w0, n0);
    set.header();
    for (n, k, m) in [(8, 8, 640), (64, 64, 640), (256, 256, 256), (240, 64, 10)] {
        let mut a = Mat::zeros(n, k);
        let mut b = Mat::zeros(k, m);
        rng.fill_normal(&mut a.data);
        rng.fill_normal(&mut b.data);
        let mut out = Mat::zeros(n, m);
        let flops = 2.0 * (n * k * m) as f64;
        set.run_throughput(&format!("matmul {n}x{k}x{m}"), flops, "flop", || {
            a.matmul_into(&b, &mut out)
        });
    }
    report.add(&set);

    // ---------- L3 primitive: quantizer + wire codec --------------------
    let (w0, n0) = reps(3, 30);
    let mut set = BenchSet::new("compression (2-bit ∞-norm, block 256)").with_reps(w0, n0);
    set.header();
    let x: Vec<f64> = (0..65_536).map(|_| rng.normal()).collect();
    let q = InfNormQuantizer::new(2, 256);
    set.run_throughput("quantize 64k doubles (analytic)", 65_536.0 * 8.0, "B", || {
        q.compress(&x, &mut rng)
    });
    set.run_throughput("encode 64k doubles (wire)", 65_536.0 * 8.0, "B", || {
        encode_inf_quantized(&x, 2, 256, &mut rng)
    });
    let (bytes, _, _) = encode_inf_quantized(&x, 2, 256, &mut Rng::new(1));
    set.run_throughput("decode 64k entries (wire)", 65_536.0 * 8.0, "B", || {
        decode_inf_quantized(&bytes, 65_536, 2, 256).expect("well-formed stream")
    });
    // the zero-alloc scratch paths the coordinator hot loop actually runs:
    // reused encode buffer + decoded slice, reused decode slice, and the
    // borrowing frame parse (before/after rows for the codec rework live
    // under these names in BENCH_perf_hotpath.json)
    {
        let mut out_buf: Vec<u8> = Vec::new();
        let mut decoded = vec![0.0; 65_536];
        set.run_throughput("encode_into 64k (reused scratch)", 65_536.0 * 8.0, "B", || {
            out_buf.clear();
            encode_inf_quantized_into(&x, 2, 256, &mut rng, &mut decoded, &mut out_buf)
        });
        set.run_throughput("decode_into 64k (reused scratch)", 65_536.0 * 8.0, "B", || {
            decode_inf_quantized_into(&bytes, 2, 256, &mut decoded).expect("well-formed")
        });
        let mut frame: Vec<u8> = Vec::new();
        frame_begin(&mut frame, WireCodec::Quant(2, 256).tag(), 7, 3);
        frame.extend_from_slice(&bytes);
        frame_end(&mut frame);
        set.run_throughput("FrameRef::parse (borrowing)", frame.len() as f64, "B", || {
            FrameRef::parse(&frame).expect("well-formed frame")
        });
    }
    report.add(&set);

    // ---------- L3: COMM round + Prox-LEAD step --------------------------
    // the §5 fixture resolved once through the Experiment pipeline
    let fx = Fixture::section5(0.05);
    let exp = &fx.exp;
    let (p, w, x0) = (exp.problem.as_ref(), &exp.mixing, &exp.x0);
    let dim = p.dim();
    let (w0, n0) = reps(5, 50);
    let mut set =
        BenchSet::new(&format!("Prox-LEAD round (8 nodes, p = {dim})")).with_reps(w0, n0);
    set.header();
    {
        let mut comm = CommState::new(x0.clone(), w, 0.5);
        let mut z = Mat::zeros(8, dim);
        rng.fill_normal(&mut z.data);
        let mut crng = Rng::new(3);
        set.run("COMM round (compress+mix, 8 rows)", || comm.comm(&z, w, &q, &mut crng));
    }
    {
        // compressor (2-bit, 256) and prox (ℓ1 5e-3) come from the config
        let mut alg = ProxLead::builder(exp).seed(5).build();
        set.run("matrix step, full grad + 2bit + prox", || alg.step(p));
        let mut alg = ProxLead::builder(exp).oracle(OracleKind::Saga).seed(5).build();
        set.run("matrix step, SAGA + 2bit + prox", || alg.step(p));
    }
    report.add(&set);

    // ---------- L3: coordinator round (threads + serialization) ---------
    let (w0, n0) = reps(1, 5);
    let mut set = BenchSet::new("coordinator (8 node threads, wire frames)").with_reps(w0, n0);
    set.header();
    let coord_rounds = if smoke { 10 } else { 100 };
    // the generic coordinator entry point with an explicit ProxLeadNode
    // factory (no reference solve — x_star is only a metric input here)
    let zeros = vec![0.0; dim];
    set.run_throughput(
        &format!("{coord_rounds} rounds end-to-end (spawn+run+join)"),
        coord_rounds as f64,
        "round",
        || {
            let wire = CoordConfig::new(WireCodec::Quant(2, 256));
            let hyper = NodeHyper::new(exp.hyper.eta);
            let spec = proxlead::runner::RunSpec::fixed(coord_rounds).every(coord_rounds);
            coordinator::run(w, x0, "prox-lead", &wire, &spec, &zeros, &mut [], |_, row| {
                Box::new(ProxLeadNode::new(
                    Arc::clone(&exp.problem),
                    Arc::new(Zero),
                    x0,
                    row,
                    &hyper,
                    &wire,
                ))
            })
        },
    );
    report.add(&set);

    // ---------- L3: the parallel sweep runtime ---------------------------
    // 4 cells (2 algorithms × 2 codecs) at smoke scale: measures the
    // fan-out overhead + reference-cache sharing, not convergence
    let (w0, n0) = reps(1, 3);
    let mut set = BenchSet::new("sweep runtime (4 cells, 8 workers)").with_reps(w0, n0);
    set.header();
    let sweep_rounds = if smoke { 20 } else { 200 };
    let base = proxlead::config::Config::parse(&format!(
        "nodes = 4\nsamples_per_node = 24\ndim = 5\nclasses = 3\nbatches = 4\n\
         lambda1 = 0\nlambda2 = 0.1\nrounds = {sweep_rounds}\nrecord_every = {sweep_rounds}\n"
    ))
    .expect("sweep base config");
    let spec = SweepSpec::new(base)
        .variant(&[("algorithm", "prox-lead"), ("bits", "2")])
        .variant(&[("algorithm", "dgd"), ("bits", "32")])
        .axis("seed", &["1", "2"])
        .threads(8);
    set.run_throughput("4-cell grid end-to-end", 4.0, "cell", || {
        run_sweep(&spec, |_| {}).expect("sweep")
    });
    report.add(&set);

    // ---------- L2/L1: PJRT gradient vs native gradient ------------------
    let dir = proxlead::runtime::default_artifact_dir();
    if dir.join("manifest.json").exists() && cfg!(feature = "xla") {
        let rt = Arc::new(proxlead::runtime::PjrtRuntime::load(&dir).expect("artifacts"));
        let spec = BlobSpec {
            nodes: 1,
            samples_per_node: 240,
            dim: 64,
            classes: 10,
            separation: 1.5,
            ..Default::default()
        };
        let native = LogReg::new(blobs(&spec), 10, 0.005, 15);
        let xla = proxlead::runtime::XlaLogReg::new(native, rt).expect("shape artifact");
        let (w0, n0) = reps(5, 40);
        let mut set = BenchSet::new("gradient backends (240×64×10)").with_reps(w0, n0);
        set.header();
        let xv: Vec<f64> = (0..xla.dim()).map(|_| 0.1 * rng.normal()).collect();
        let mut out = vec![0.0; xla.dim()];
        let flops = 2.0 * 2.0 * 240.0 * 64.0 * 10.0; // two matmuls
        set.run_throughput("native rust full gradient", flops, "flop", || {
            xla.native().grad(0, &xv, &mut out)
        });
        set.run_throughput("PJRT (jax/pallas AOT) full gradient", flops, "flop", || {
            xla.grad(0, &xv, &mut out)
        });
        set.run_throughput("PJRT batch gradient (16 rows)", flops / 15.0, "flop", || {
            xla.grad_batch(0, 3, &xv, &mut out)
        });
        report.add(&set);
    } else {
        println!("\n(skipping PJRT bench: needs `make artifacts` and --features xla)");
    }

    let json_path = out_dir().join("perf_hotpath.json");
    report.write(json_path.to_str().unwrap()).expect("write perf json");
    println!("\nwrote {}", json_path.display());
    println!("perf_hotpath done");
}
