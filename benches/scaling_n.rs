//! Gossip scaling in the network size n — the bench behind EXPERIMENTS.md
//! §"Scaling in n".
//!
//! For ring / torus-grid / Erdős–Rényi topologies at n from 32 to 2048,
//! times one gossip round W·X (p = 32 columns) through both mixing
//! representations:
//!
//! - **dense**: the blocked `Mat::matmul_into` kernel, O(n²p) per round;
//! - **sparse**: the CSR `MixingOp::apply_into` SpMM, O(nnz·p) per round —
//!   ~linear in n on these O(n)-edge graphs.
//!
//! Also times the power-iteration spectral-gap estimator (O(nnz) per step)
//! against the dense Jacobi eigensolve at small n, and one full sparse
//! Prox-LEAD matrix round at n = 512 to show gossip has left the hot path.
//!
//! The final set drives the event-driven **sim backend** end to end —
//! 2-bit Prox-LEAD over real wire frames on ring and Erdős–Rényi graphs,
//! n up to 10⁶ in full mode (ring n = 10⁵ in smoke mode, the acceptance
//! row) — reporting rounds/sec and wire bytes/round. Large ER graphs come
//! from the O(m + n) skip-sampler (`Graph::try_erdos_renyi_sparse`); the
//! exact O(n²) config-path sampler is intractable at n ≥ 10⁵.
//!
//! Every set lands in `bench_out/scaling_n.json` (schema proxlead-perf-v1);
//! CI uploads it next to perf_hotpath's as the second trajectory artifact.
//! `PERF_SMOKE=1` caps gossip n at 128 and sim n at 10⁵ with minimal reps.

mod common;

use common::out_dir;
use proxlead::algorithm::{Algorithm, ProxLead};
use proxlead::exp::Experiment;
use proxlead::graph::{Graph, MixingOp, MixingRule, Topology};
use proxlead::linalg::{Mat, Spectrum};
use proxlead::util::bench::{smoke_mode, BenchReport, BenchSet};
use proxlead::util::rng::Rng;

/// Iterate width p for the gossip timings (a mid-size model row).
const P_COLS: usize = 32;

/// Build the benchmark graph for a topology family at ~n nodes. Grid needs
/// a perfect square, so its sizes snap to the nearest square (reported in
/// the bench label via `g.n`).
fn build_graph(topo: Topology, n: usize, rng: &mut Rng) -> Graph {
    match topo {
        Topology::Grid => {
            let k = (n as f64).sqrt().round() as usize;
            Graph::grid(k * k)
        }
        Topology::ErdosRenyi => Graph::erdos_renyi(n, Graph::auto_er_prob(n), rng),
        _ => Graph::build(topo, n, rng),
    }
}

fn main() {
    let smoke = smoke_mode();
    if smoke {
        println!("PERF_SMOKE=1: n capped at 128, minimal reps (CI trajectory mode)");
    }
    let sizes: &[usize] = if smoke { &[32, 128] } else { &[32, 128, 512, 1024, 2048] };
    let mut report = BenchReport::new("scaling_n");
    let mut rng = Rng::new(7);

    // ---------- gossip round: dense vs sparse per topology ---------------
    for (name, topo) in [
        ("ring", Topology::Ring),
        ("grid", Topology::Grid),
        ("er", Topology::ErdosRenyi),
    ] {
        let (warm, reps) = if smoke { (0, 2) } else { (3, 10) };
        let mut set =
            BenchSet::new(&format!("gossip W·X — {name} (p = {P_COLS})")).with_reps(warm, reps);
        set.header();
        for &n in sizes {
            let g = build_graph(topo, n, &mut rng);
            let n = g.n;
            let dense = MixingOp::dense_from(&g, MixingRule::Metropolis);
            let sparse = MixingOp::sparse_from(&g, MixingRule::Metropolis);
            let mut x = Mat::zeros(n, P_COLS);
            rng.fill_normal(&mut x.data);
            let mut out_d = Mat::zeros(n, P_COLS);
            let mut out_s = Mat::zeros(n, P_COLS);
            // dense pays 2·n²·p flops; sparse only 2·nnz·p
            set.run_throughput(
                &format!("dense  n={n:<5} (n²p)"),
                2.0 * (n * n * P_COLS) as f64,
                "flop",
                || dense.apply_into(&x, &mut out_d),
            );
            set.run_throughput(
                &format!("sparse n={n:<5} (nnz={})", sparse.nnz()),
                2.0 * (sparse.nnz() * P_COLS) as f64,
                "flop",
                || sparse.apply_into(&x, &mut out_s),
            );
            // the two representations must agree bit for bit
            assert_eq!(out_d.data, out_s.data, "{name} n={n}: sparse ≠ dense");
        }
        report.add(&set);
    }

    // ---------- spectral gap: power iteration vs dense Jacobi ------------
    {
        let (warm, reps) = if smoke { (0, 2) } else { (1, 5) };
        let mut set = BenchSet::new("spectral gap λ₂/λ_n — ring").with_reps(warm, reps);
        set.header();
        for &n in sizes {
            let g = Graph::ring(n);
            let sparse = MixingOp::sparse_from(&g, MixingRule::Metropolis);
            set.run(&format!("power iteration n={n} (O(nnz)/step)"), || sparse.gap_estimate());
            // the O(n³) Jacobi solve is only tractable at small n
            if n <= 128 {
                let w = sparse.to_dense();
                set.run(&format!("jacobi eigensolve n={n} (O(n³))"), || Spectrum::of_mixing(&w));
            }
        }
        report.add(&set);
    }

    // ---------- end-to-end: one sparse Prox-LEAD round at n = 512 --------
    {
        let n = if smoke { 64 } else { 512 };
        let (warm, reps) = if smoke { (0, 2) } else { (3, 10) };
        let title = format!("Prox-LEAD round at n = {n} (ring, 2-bit)");
        let mut set = BenchSet::new(&title).with_reps(warm, reps);
        set.header();
        // resolved once through the Experiment pipeline (auto-η = 1/(2L),
        // 2-bit ∞-norm compressor, ℓ1 prox from the config)
        let base = Experiment::builder()
            .nodes(n)
            .set("samples_per_node", "8")
            .set("dim", "8")
            .set("classes", "4")
            .set("batches", "4")
            .set("separation", "1.0")
            .set("lambda1", "5e-3")
            .lambda2(0.05)
            .bits(2)
            .build()
            .expect("scaling_n experiment");
        for (label, w) in [
            ("dense gossip", MixingOp::dense_from(&base.graph, MixingRule::UniformMaxDegree)),
            ("sparse gossip", MixingOp::sparse_from(&base.graph, MixingRule::UniformMaxDegree)),
        ] {
            let exp = base.clone().with_mixing(w);
            let mut alg = ProxLead::builder(&exp).seed(5).build();
            set.run_throughput(&format!("matrix step, {label}"), 1.0, "round", || {
                alg.step(exp.problem.as_ref())
            });
        }
        report.add(&set);
    }

    // ---------- sim backend: massive-n end-to-end rounds ------------------
    {
        let (warm, reps) = if smoke { (0, 1) } else { (1, 3) };
        let rounds = if smoke { 3usize } else { 8 };
        let workers = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
        let title =
            format!("sim backend — 2-bit Prox-LEAD, {rounds} rounds, {workers} workers");
        let mut set = BenchSet::new(&title).with_reps(warm, reps);
        set.header();
        // (n, erdős–rényi?) rows; smoke keeps the n = 10⁵ acceptance row
        let rows: &[(usize, bool)] = if smoke {
            &[(1024, false), (1024, true), (100_000, false)]
        } else {
            &[(10_000, false), (100_000, false), (1_000_000, false), (10_000, true), (100_000, true)]
        };
        for &(n, er) in rows {
            // a tiny per-node problem: the bench measures the round loop
            // (encode → frame → decode → update), not the oracle
            let mut exp = Experiment::builder()
                .nodes(n)
                .set("problem", "least-squares")
                .set("samples_per_node", "2")
                .set("dim", "4")
                .set("batches", "1")
                .set("lambda1", "1e-3")
                .bits(2)
                .set("rounds", &rounds.to_string())
                .set("record_every", &rounds.to_string())
                .build()
                .expect("sim scaling experiment");
            let topo = if er {
                // O(m + n) skip-sampler — the config-path exact sampler is
                // O(n²) and intractable at these sizes
                let g = Graph::try_erdos_renyi_sparse(n, Graph::auto_er_prob(n), &mut rng, 100)
                    .expect("connected sparse ER draw");
                let w = MixingOp::sparse_from(&g, MixingRule::Metropolis);
                exp.graph = g;
                exp = exp.with_mixing(w);
                "er  "
            } else {
                "ring"
            };
            // pin x* = 0 so the reference FISTA solve stays out of the bench
            exp.set_reference(std::sync::Arc::new(vec![0.0; exp.x0.cols]));
            let spec = exp.run_spec();
            let nnz = exp.mixing.nnz();
            let mut last = None;
            set.run_throughput(
                &format!("{topo} n={n:<7} (nnz={nnz})"),
                rounds as f64,
                "round",
                || last = Some(exp.run_sim(&spec)),
            );
            let res = last.expect("at least one timed rep");
            let end = res.history.last().expect("sim history");
            assert!(end.suboptimality.is_finite(), "sim diverged at n={n}");
            println!(
                "    {topo} n={n}: {:.1} payload bits/round/node, {:.1} wire bytes/round/node",
                end.bits as f64 / (end.round.max(1) * n) as f64,
                end.wire_bytes as f64 / (end.round.max(1) * n) as f64,
            );
        }
        report.add(&set);
    }

    let json_path = out_dir().join("scaling_n.json");
    report.write(json_path.to_str().unwrap()).expect("write scaling json");
    println!("\nwrote {}", json_path.display());
    println!("scaling_n done");
}
