//! The synchronous matrix-form engine: drives any [`Algorithm`] for K
//! rounds on one thread with identical arithmetic to the message-passing
//! [`crate::coordinator`] (verified bit for bit by integration test).
//!
//! The run loop itself lives in [`crate::runner`] — the one run API both
//! backends share (composable [`crate::runner::StopSet`], streaming
//! [`crate::runner::Probe`]s, one [`RunResult`] shape). This module keeps
//! the deprecated [`RunConfig`]/[`run`] shims for sequence-pinning tests
//! and the [`rounds_to`] convenience.

use crate::algorithm::{Algorithm, Schedule};
use crate::problem::Problem;
use crate::runner::{self, RunSpec};

pub use crate::runner::{MetricPoint, RunResult, StopReason, XAxis};

/// Run controls of the pre-`runner` engine API.
#[deprecated(note = "use runner::RunSpec (composable StopSet + streaming probes) — this shim \
                     exists for sequence-pinning tests")]
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub rounds: usize,
    /// Sample the metrics every this many rounds (1 = every round).
    pub record_every: usize,
    /// Stop early once suboptimality falls below this.
    pub target_subopt: Option<f64>,
    /// Stepsize schedule applied before every round (Theorem 7 etc.).
    pub schedule: Option<Schedule>,
}

#[allow(deprecated)]
impl RunConfig {
    pub fn fixed(rounds: usize) -> RunConfig {
        RunConfig { rounds, record_every: 1, target_subopt: None, schedule: None }
    }

    pub fn every(mut self, k: usize) -> RunConfig {
        self.record_every = k.max(1);
        self
    }

    pub fn until(mut self, subopt: f64) -> RunConfig {
        self.target_subopt = Some(subopt);
        self
    }

    pub fn with_schedule(mut self, s: Schedule) -> RunConfig {
        self.schedule = Some(s);
        self
    }

    /// The equivalent [`RunSpec`] (what the shimmed [`run`] executes).
    pub fn to_spec(&self) -> RunSpec {
        let mut spec = RunSpec::fixed(self.rounds).every(self.record_every);
        if let Some(t) = self.target_subopt {
            spec = spec.until(t);
        }
        if let Some(s) = &self.schedule {
            spec = spec.with_schedule(s.clone());
        }
        spec
    }
}

/// Drive `alg` under `cfg`, measuring against `x_star` — the historical
/// entry point, now a thin shim over [`runner::run_engine`].
#[deprecated(note = "use Experiment::run(&RunSpec) or runner::run_engine — this shim exists \
                     for sequence-pinning tests")]
#[allow(deprecated)]
pub fn run(
    alg: &mut dyn Algorithm,
    problem: &dyn Problem,
    x_star: &[f64],
    cfg: &RunConfig,
) -> RunResult {
    runner::run_engine(alg, problem, x_star, &cfg.to_spec(), &mut [])
}

/// Convenience: rounds needed to hit `target`, or None within the budget.
pub fn rounds_to(
    alg: &mut dyn Algorithm,
    problem: &dyn Problem,
    x_star: &[f64],
    target: f64,
    budget: usize,
) -> Option<usize> {
    let spec = RunSpec::fixed(budget).every(budget.max(1)).until(target);
    runner::run_engine(alg, problem, x_star, &spec, &mut []).rounds_to_target()
}

#[cfg(test)]
mod tests {
    //! Theorem-level integration tests: the behaviors Theorems 5, 7, 8, 9
    //! promise, observed end-to-end through the engine driver. All
    //! algorithms are constructed through the Experiment builders (the
    //! ring_exp fixture resolves the same problem/network as the
    //! historical ring_logreg).
    use super::*;
    use crate::algorithm::testkit::ring_exp;
    use crate::algorithm::{solve_reference, ProxLead, Schedule};
    use crate::compress::Identity;
    use crate::linalg::Spectrum;
    use crate::oracle::OracleKind;
    use crate::runner::run_engine;
    use crate::util::stats::loglinear_slope;

    #[test]
    fn thm5_sgd_linear_to_noise_neighborhood() {
        // fixed stepsize + SGD: fast early progress, then a plateau whose
        // level scales with η² (Theorem 5's 2η²σ²/(1−ρ) ball)
        let exp = ring_exp();
        let p = exp.problem.as_ref();
        let x_star = solve_reference(p, 0.0, 40_000, 1e-13);
        let plateau = |eta: f64| {
            let mut alg =
                ProxLead::builder(&exp).eta(eta).oracle(OracleKind::Sgd).seed(5).build();
            let res = run_engine(&mut alg, p, &x_star, &RunSpec::fixed(4000).every(50), &mut []);
            // average the tail — the noise ball level
            let tail: Vec<f64> =
                res.history.iter().rev().take(20).map(|m| m.suboptimality).collect();
            crate::util::stats::mean(&tail)
        };
        let big = plateau(0.04);
        let small = plateau(0.01);
        assert!(big > small * 2.0, "noise ball should shrink with η: {big} vs {small}");
        assert!(big.is_finite() && small > 0.0);
    }

    #[test]
    fn thm7_diminishing_stepsize_beats_fixed_sgd() {
        let exp = ring_exp();
        let p = exp.problem.as_ref();
        let x_star = solve_reference(p, 0.0, 40_000, 1e-13);
        let spec = Spectrum::of_mixing(&exp.mixing.to_dense());
        let c = 0.2; // empirical 2-bit NSR on these dimensions
        // the fixture's auto-η is the Theorem 5 bound 1/(2L)
        let mk = || ProxLead::builder(&exp).oracle(OracleKind::Sgd).seed(5).build();
        let schedule = Schedule::Theorem7 {
            c,
            l: p.smoothness(),
            mu: p.strong_convexity(),
            kappa_g: spec.kappa_g(),
            lmax_iw: spec.lam_max,
        };
        let rounds = 20_000;
        let mut fixed = mk();
        let fixed_res =
            run_engine(&mut fixed, p, &x_star, &RunSpec::fixed(rounds).every(500), &mut []);
        let mut dim = mk();
        let dim_res = run_engine(
            &mut dim,
            p,
            &x_star,
            &RunSpec::fixed(rounds).every(500).with_schedule(schedule),
            &mut [],
        );
        let f_final = fixed_res.final_subopt();
        let d_final = dim_res.final_subopt();
        assert!(
            d_final < f_final * 0.5,
            "Theorem 7 schedule should beat the fixed-η noise ball: {d_final} vs {f_final}"
        );
    }

    #[test]
    fn thm8_9_variance_reduction_linear_rate() {
        // LSVRG and SAGA traces must decay log-linearly (linear convergence)
        let exp = ring_exp();
        let p = exp.problem.as_ref();
        let x_star = solve_reference(p, 5e-3, 40_000, 1e-13);
        for kind in [OracleKind::Lsvrg { p: 0.25 }, OracleKind::Saga] {
            let mut alg = ProxLead::builder(&exp)
                .eta(1.0 / (6.0 * p.smoothness()))
                .oracle(kind)
                .prox(Box::new(crate::prox::L1::new(5e-3)))
                .seed(5)
                .build();
            let res =
                run_engine(&mut alg, p, &x_star, &RunSpec::fixed(8000).every(200), &mut []);
            let ys: Vec<f64> =
                res.history.iter().map(|m| m.suboptimality).filter(|s| *s > 1e-20).collect();
            let slope = loglinear_slope(&ys);
            assert!(slope < -0.1, "{:?} trace should be log-linear, slope {slope}", kind);
            assert!(res.final_subopt() < 1e-10);
        }
    }

    #[test]
    fn early_stop_reports_rounds_to_target() {
        let exp = ring_exp();
        let p = exp.problem.as_ref();
        let x_star = solve_reference(p, 0.0, 40_000, 1e-13);
        let mut alg =
            ProxLead::builder(&exp).compressor(Box::new(Identity::f64())).seed(5).build();
        let res = run_engine(&mut alg, p, &x_star, &RunSpec::fixed(5000).until(1e-8), &mut []);
        let hit = res.rounds_to_target().expect("should reach 1e-8");
        assert!(hit < 2000, "took {hit} rounds");
        assert_eq!(res.stopped_by, StopReason::TargetSubopt);
        // monotone bookkeeping: bits and grad evals nondecreasing
        for w in res.history.windows(2) {
            assert!(w[1].bits >= w[0].bits);
            assert!(w[1].grad_evals >= w[0].grad_evals);
        }
    }

    #[test]
    fn record_every_thins_history() {
        let exp = ring_exp();
        let p = exp.problem.as_ref();
        let x_star = vec![0.0; p.dim()];
        let mut alg = ProxLead::builder(&exp)
            .eta(0.01)
            .compressor(Box::new(Identity::f64()))
            .seed(5)
            .build();
        let res = run_engine(&mut alg, p, &x_star, &RunSpec::fixed(100).every(10), &mut []);
        assert_eq!(res.history.len(), 11); // round 0 + 10 samples
        assert_eq!(res.history.last().unwrap().round, 100);
        // series x-axis extraction
        let pts = res.series(XAxis::Rounds);
        assert_eq!(pts[1].0, 10.0);
        let bits = res.series(XAxis::Bits);
        assert!(bits.last().unwrap().0 > 0.0);
    }

    #[test]
    fn divergence_between_record_points_reaches_history() {
        // regression: with a deliberately diverging η and a record interval
        // larger than the blow-up horizon, the loop used to break without
        // recording the diverged state — final_subopt() then reported the
        // stale round-0 sample (0.0 here) instead of the divergence
        use crate::algorithm::Dgd;
        let exp = ring_exp();
        let p = exp.problem.as_ref();
        let x_star = vec![0.0; p.dim()];
        // η·λ₂ ≫ 2 ⇒ the ridge term alone makes |1 − ηλ₂| > 1: exponential
        // blow-up to ±inf long before round 2000
        let mut alg = Dgd::builder(&exp).eta(1e3).build();
        let res = run_engine(&mut alg, p, &x_star, &RunSpec::fixed(2000).every(2000), &mut []);
        let last = res.history.last().expect("history never empty");
        assert!(last.round > 0 && last.round < 2000, "should diverge mid-run: {}", last.round);
        assert_eq!(res.stopped_by, StopReason::Diverged);
        assert!(
            !res.final_subopt().is_finite(),
            "final_subopt must report the divergence, got {}",
            res.final_subopt()
        );
        assert!(!res.final_x.is_finite());
        // bookkeeping on the flushed sample is still cumulative
        assert!(last.grad_evals > 0 && last.bits > 0);
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_shim_matches_run_spec_path_bit_for_bit() {
        // the sequence-pinning contract of the RunConfig shim: identical
        // MetricPoint sequence and final iterate through both entry points
        let exp = ring_exp();
        let p = exp.problem.as_ref();
        let x_star = solve_reference(p, 0.0, 40_000, 1e-13);
        let mk = || ProxLead::builder(&exp).seed(5).build();
        let legacy = {
            let mut alg = mk();
            run(&mut alg, p, &x_star, &RunConfig::fixed(120).every(30).until(1e-11))
        };
        let modern = {
            let mut alg = mk();
            run_engine(&mut alg, p, &x_star, &RunSpec::fixed(120).every(30).until(1e-11), &mut [])
        };
        assert_eq!(legacy.history.len(), modern.history.len());
        for (a, b) in legacy.history.iter().zip(&modern.history) {
            assert_eq!(a.round, b.round);
            assert_eq!(a.bits, b.bits);
            assert_eq!(a.grad_evals, b.grad_evals);
            assert_eq!(a.suboptimality.to_bits(), b.suboptimality.to_bits());
        }
        assert_eq!(legacy.stopped_by, modern.stopped_by);
        assert_eq!(legacy.final_x.data, modern.final_x.data);
        assert_eq!(legacy.rounds_to_target(), modern.rounds_to_target());
    }
}
