//! The synchronous matrix-form engine: drives any [`Algorithm`] for K
//! rounds, applies stepsize schedules, and records the metric history
//! behind every figure in §5 — suboptimality vs (rounds | epochs |
//! gradient evaluations | communicated bits).
//!
//! The message-passing [`crate::coordinator`] is the "real" distributed
//! runtime; this engine is the fast single-thread harness the benchmark
//! suite sweeps with (identical arithmetic, verified by integration test).

use crate::algorithm::{suboptimality, Algorithm, Schedule};
use crate::linalg::Mat;
use crate::problem::Problem;
use std::time::Instant;

/// One recorded metric sample.
#[derive(Clone, Copy, Debug)]
pub struct MetricPoint {
    /// Round index (1-based after the step executes).
    pub round: usize,
    /// Cumulative batch-gradient evaluations across all nodes.
    pub grad_evals: u64,
    /// Cumulative communicated bits across all nodes.
    pub bits: u64,
    /// ‖Xᵏ − 1(x*)ᵀ‖²/n vs the reference solution.
    pub suboptimality: f64,
    /// Σᵢ ‖xᵢ − x̄‖² consensus error.
    pub consensus: f64,
    /// Wall-clock since run start.
    pub wall_ns: u128,
}

/// Run controls.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub rounds: usize,
    /// Sample the metrics every this many rounds (1 = every round).
    pub record_every: usize,
    /// Stop early once suboptimality falls below this.
    pub target_subopt: Option<f64>,
    /// Stepsize schedule applied before every round (Theorem 7 etc.).
    pub schedule: Option<Schedule>,
}

impl RunConfig {
    pub fn fixed(rounds: usize) -> RunConfig {
        RunConfig { rounds, record_every: 1, target_subopt: None, schedule: None }
    }

    pub fn every(mut self, k: usize) -> RunConfig {
        self.record_every = k.max(1);
        self
    }

    pub fn until(mut self, subopt: f64) -> RunConfig {
        self.target_subopt = Some(subopt);
        self
    }

    pub fn with_schedule(mut self, s: Schedule) -> RunConfig {
        self.schedule = Some(s);
        self
    }
}

/// The full trace of one algorithm run.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub name: String,
    pub history: Vec<MetricPoint>,
    /// First round at which `target_subopt` was met (if requested and met).
    pub rounds_to_target: Option<usize>,
    pub final_x: Mat,
}

impl RunResult {
    pub fn final_subopt(&self) -> f64 {
        self.history.last().map_or(f64::NAN, |m| m.suboptimality)
    }

    /// Series (x_metric, suboptimality) for the figure CSVs.
    pub fn series(&self, x: XAxis) -> Vec<(f64, f64)> {
        self.history
            .iter()
            .map(|m| {
                let xv = match x {
                    XAxis::Rounds => m.round as f64,
                    XAxis::GradEvals => m.grad_evals as f64,
                    XAxis::Bits => m.bits as f64,
                    XAxis::Epochs(per_epoch) => m.grad_evals as f64 / per_epoch as f64,
                };
                (xv, m.suboptimality)
            })
            .collect()
    }
}

/// Which x-axis a figure uses.
#[derive(Clone, Copy, Debug)]
pub enum XAxis {
    Rounds,
    GradEvals,
    Bits,
    /// Epochs = grad_evals / (n·m batch evals per epoch).
    Epochs(u64),
}

/// Drive `alg` under `cfg`, measuring against `x_star`.
pub fn run(
    alg: &mut dyn Algorithm,
    problem: &dyn Problem,
    x_star: &[f64],
    cfg: &RunConfig,
) -> RunResult {
    let start = Instant::now();
    let mut history = Vec::with_capacity(cfg.rounds / cfg.record_every + 2);
    let mut rounds_to_target = None;

    // round-0 sample (post-initialization state)
    history.push(MetricPoint {
        round: 0,
        grad_evals: alg.grad_evals(),
        bits: alg.bits(),
        suboptimality: suboptimality(alg.x(), x_star),
        consensus: alg.x().consensus_error(),
        wall_ns: 0,
    });

    for k in 0..cfg.rounds {
        if let Some(s) = &cfg.schedule {
            alg.apply_hyper(s.hyper_at(k as u64));
        }
        alg.step(problem);
        let due = (k + 1) % cfg.record_every == 0 || k + 1 == cfg.rounds;
        let mut subopt = f64::NAN;
        if due || cfg.target_subopt.is_some() {
            subopt = suboptimality(alg.x(), x_star);
        }
        if due {
            history.push(MetricPoint {
                round: k + 1,
                grad_evals: alg.grad_evals(),
                bits: alg.bits(),
                suboptimality: subopt,
                consensus: alg.x().consensus_error(),
                wall_ns: start.elapsed().as_nanos(),
            });
        }
        if let Some(t) = cfg.target_subopt {
            if subopt < t {
                rounds_to_target = Some(k + 1);
                if !due {
                    // make sure the stopping state is in the history
                    history.push(MetricPoint {
                        round: k + 1,
                        grad_evals: alg.grad_evals(),
                        bits: alg.bits(),
                        suboptimality: subopt,
                        consensus: alg.x().consensus_error(),
                        wall_ns: start.elapsed().as_nanos(),
                    });
                }
                break;
            }
        }
        if !alg.x().is_finite() {
            // diverged — flush the diverged state before breaking
            // (mirroring the early-stop flush above), so `final_subopt()`
            // reports the divergence instead of a stale pre-divergence
            // sample when the break lands between record points
            if !due {
                history.push(MetricPoint {
                    round: k + 1,
                    grad_evals: alg.grad_evals(),
                    bits: alg.bits(),
                    suboptimality: suboptimality(alg.x(), x_star),
                    consensus: alg.x().consensus_error(),
                    wall_ns: start.elapsed().as_nanos(),
                });
            }
            break;
        }
    }

    RunResult { name: alg.name(), history, rounds_to_target, final_x: alg.x().clone() }
}

/// Convenience: rounds needed to hit `target`, or None within the budget.
pub fn rounds_to(
    alg: &mut dyn Algorithm,
    problem: &dyn Problem,
    x_star: &[f64],
    target: f64,
    budget: usize,
) -> Option<usize> {
    let cfg = RunConfig::fixed(budget).every(budget.max(1)).until(target);
    run(alg, problem, x_star, &cfg).rounds_to_target
}

#[cfg(test)]
mod tests {
    //! Theorem-level integration tests: the behaviors Theorems 5, 7, 8, 9
    //! promise, observed end-to-end through the engine. All algorithms are
    //! constructed through the Experiment builders (the ring_exp fixture
    //! resolves the same problem/network as the historical ring_logreg).
    use super::*;
    use crate::algorithm::testkit::ring_exp;
    use crate::algorithm::{solve_reference, ProxLead, Schedule};
    use crate::compress::Identity;
    use crate::linalg::Spectrum;
    use crate::oracle::OracleKind;
    use crate::util::stats::loglinear_slope;

    #[test]
    fn thm5_sgd_linear_to_noise_neighborhood() {
        // fixed stepsize + SGD: fast early progress, then a plateau whose
        // level scales with η² (Theorem 5's 2η²σ²/(1−ρ) ball)
        let exp = ring_exp();
        let p = exp.problem.as_ref();
        let x_star = solve_reference(p, 0.0, 40_000, 1e-13);
        let plateau = |eta: f64| {
            let mut alg =
                ProxLead::builder(&exp).eta(eta).oracle(OracleKind::Sgd).seed(5).build();
            let res = run(&mut alg, p, &x_star, &RunConfig::fixed(4000).every(50));
            // average the tail — the noise ball level
            let tail: Vec<f64> =
                res.history.iter().rev().take(20).map(|m| m.suboptimality).collect();
            crate::util::stats::mean(&tail)
        };
        let big = plateau(0.04);
        let small = plateau(0.01);
        assert!(big > small * 2.0, "noise ball should shrink with η: {big} vs {small}");
        assert!(big.is_finite() && small > 0.0);
    }

    #[test]
    fn thm7_diminishing_stepsize_beats_fixed_sgd() {
        let exp = ring_exp();
        let p = exp.problem.as_ref();
        let x_star = solve_reference(p, 0.0, 40_000, 1e-13);
        let spec = Spectrum::of_mixing(&exp.mixing.to_dense());
        let c = 0.2; // empirical 2-bit NSR on these dimensions
        // the fixture's auto-η is the Theorem 5 bound 1/(2L)
        let mk = || ProxLead::builder(&exp).oracle(OracleKind::Sgd).seed(5).build();
        let schedule = Schedule::Theorem7 {
            c,
            l: p.smoothness(),
            mu: p.strong_convexity(),
            kappa_g: spec.kappa_g(),
            lmax_iw: spec.lam_max,
        };
        let rounds = 20_000;
        let mut fixed = mk();
        let fixed_res = run(&mut fixed, p, &x_star, &RunConfig::fixed(rounds).every(500));
        let mut dim = mk();
        let dim_res =
            run(&mut dim, p, &x_star, &RunConfig::fixed(rounds).every(500).with_schedule(schedule));
        let f_final = fixed_res.final_subopt();
        let d_final = dim_res.final_subopt();
        assert!(
            d_final < f_final * 0.5,
            "Theorem 7 schedule should beat the fixed-η noise ball: {d_final} vs {f_final}"
        );
    }

    #[test]
    fn thm8_9_variance_reduction_linear_rate() {
        // LSVRG and SAGA traces must decay log-linearly (linear convergence)
        let exp = ring_exp();
        let p = exp.problem.as_ref();
        let x_star = solve_reference(p, 5e-3, 40_000, 1e-13);
        for kind in [OracleKind::Lsvrg { p: 0.25 }, OracleKind::Saga] {
            let mut alg = ProxLead::builder(&exp)
                .eta(1.0 / (6.0 * p.smoothness()))
                .oracle(kind)
                .prox(Box::new(crate::prox::L1::new(5e-3)))
                .seed(5)
                .build();
            let res = run(&mut alg, p, &x_star, &RunConfig::fixed(8000).every(200));
            let ys: Vec<f64> =
                res.history.iter().map(|m| m.suboptimality).filter(|s| *s > 1e-20).collect();
            let slope = loglinear_slope(&ys);
            assert!(slope < -0.1, "{:?} trace should be log-linear, slope {slope}", kind);
            assert!(res.final_subopt() < 1e-10);
        }
    }

    #[test]
    fn early_stop_reports_rounds_to_target() {
        let exp = ring_exp();
        let p = exp.problem.as_ref();
        let x_star = solve_reference(p, 0.0, 40_000, 1e-13);
        let mut alg =
            ProxLead::builder(&exp).compressor(Box::new(Identity::f64())).seed(5).build();
        let res = run(&mut alg, p, &x_star, &RunConfig::fixed(5000).until(1e-8));
        let hit = res.rounds_to_target.expect("should reach 1e-8");
        assert!(hit < 2000, "took {hit} rounds");
        // monotone bookkeeping: bits and grad evals nondecreasing
        for w in res.history.windows(2) {
            assert!(w[1].bits >= w[0].bits);
            assert!(w[1].grad_evals >= w[0].grad_evals);
        }
    }

    #[test]
    fn record_every_thins_history() {
        let exp = ring_exp();
        let p = exp.problem.as_ref();
        let x_star = vec![0.0; p.dim()];
        let mut alg = ProxLead::builder(&exp)
            .eta(0.01)
            .compressor(Box::new(Identity::f64()))
            .seed(5)
            .build();
        let res = run(&mut alg, p, &x_star, &RunConfig::fixed(100).every(10));
        assert_eq!(res.history.len(), 11); // round 0 + 10 samples
        assert_eq!(res.history.last().unwrap().round, 100);
        // series x-axis extraction
        let pts = res.series(XAxis::Rounds);
        assert_eq!(pts[1].0, 10.0);
        let bits = res.series(XAxis::Bits);
        assert!(bits.last().unwrap().0 > 0.0);
    }

    #[test]
    fn divergence_between_record_points_reaches_history() {
        // regression: with a deliberately diverging η and a record interval
        // larger than the blow-up horizon, the loop used to break without
        // recording the diverged state — final_subopt() then reported the
        // stale round-0 sample (0.0 here) instead of the divergence
        use crate::algorithm::Dgd;
        let exp = ring_exp();
        let p = exp.problem.as_ref();
        let x_star = vec![0.0; p.dim()];
        // η·λ₂ ≫ 2 ⇒ the ridge term alone makes |1 − ηλ₂| > 1: exponential
        // blow-up to ±inf long before round 2000
        let mut alg = Dgd::builder(&exp).eta(1e3).build();
        let res = run(&mut alg, p, &x_star, &RunConfig::fixed(2000).every(2000));
        let last = res.history.last().expect("history never empty");
        assert!(last.round > 0 && last.round < 2000, "should diverge mid-run: {}", last.round);
        assert!(
            !res.final_subopt().is_finite(),
            "final_subopt must report the divergence, got {}",
            res.final_subopt()
        );
        assert!(!res.final_x.is_finite());
        // bookkeeping on the flushed sample is still cumulative
        assert!(last.grad_evals > 0 && last.bits > 0);
    }

    #[test]
    fn final_subopt_is_nan_on_empty_history() {
        let res = RunResult {
            name: "empty".into(),
            history: Vec::new(),
            rounds_to_target: None,
            final_x: Mat::zeros(1, 1),
        };
        assert!(res.final_subopt().is_nan());
    }
}
