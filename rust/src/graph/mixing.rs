//! Mixing matrix construction (the W of Assumption 1).
//!
//! W must be symmetric, W1 = 1, supported on the graph's edges, and have
//! eigenvalues in (−1, 1] with λ₁ = 1 simple. The paper's experiments use a
//! ring with uniform weight 1/3 (self + two neighbors); we also provide
//! Metropolis–Hastings (valid for any graph) and its "lazy" damped variant.

use super::topology::Graph;
use crate::linalg::{Mat, Spectrum};

/// Weighting schemes for building W from a graph.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MixingRule {
    /// w_ij = 1/(deg_max + 1) for edges, diagonal absorbs the rest.
    /// Equals the paper's ring-1/3 on a ring (deg_max = 2).
    UniformMaxDegree,
    /// Metropolis–Hastings: w_ij = 1/(1 + max(deg_i, deg_j)).
    Metropolis,
    /// (I + W_mh)/2 — guarantees eigenvalues in [0, 1] (positive
    /// semidefinite), halving the spectral gap.
    LazyMetropolis,
}

impl std::str::FromStr for MixingRule {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "uniform" | "max-degree" => Ok(MixingRule::UniformMaxDegree),
            "metropolis" | "mh" => Ok(MixingRule::Metropolis),
            "lazy" | "lazy-metropolis" => Ok(MixingRule::LazyMetropolis),
            _ => Err(format!("unknown mixing rule '{s}'")),
        }
    }
}

/// Build the mixing matrix for `g` under `rule`.
pub fn mixing_matrix(g: &Graph, rule: MixingRule) -> Mat {
    let n = g.n;
    let mut w = Mat::zeros(n, n);
    match rule {
        MixingRule::UniformMaxDegree => {
            let weight = 1.0 / (g.max_degree() as f64 + 1.0);
            for i in 0..n {
                for &j in &g.adj[i] {
                    w[(i, j)] = weight;
                }
                w[(i, i)] = 1.0 - weight * g.degree(i) as f64;
            }
        }
        MixingRule::Metropolis | MixingRule::LazyMetropolis => {
            for i in 0..n {
                let mut row_sum = 0.0;
                for &j in &g.adj[i] {
                    let wij = 1.0 / (1.0 + g.degree(i).max(g.degree(j)) as f64);
                    w[(i, j)] = wij;
                    row_sum += wij;
                }
                w[(i, i)] = 1.0 - row_sum;
            }
            if rule == MixingRule::LazyMetropolis {
                for i in 0..n {
                    for j in 0..n {
                        w[(i, j)] *= 0.5;
                    }
                    w[(i, i)] += 0.5;
                }
            }
        }
    }
    w
}

/// Validate Assumption 1: symmetry, row-stochasticity, edge support,
/// eigenvalues in (−1, 1] with λ₁ = 1 simple. Returns the spectrum on
/// success so callers can reuse it.
pub fn validate_mixing(w: &Mat, g: &Graph) -> Result<Spectrum, String> {
    let n = g.n;
    if w.rows != n || w.cols != n {
        return Err(format!("W is {}x{}, graph has {n} nodes", w.rows, w.cols));
    }
    for i in 0..n {
        for j in 0..n {
            if (w[(i, j)] - w[(j, i)]).abs() > 1e-12 {
                return Err(format!("W not symmetric at ({i},{j})"));
            }
            if i != j && w[(i, j)].abs() > 1e-12 && !g.has_edge(i, j) {
                return Err(format!("W has weight on non-edge ({i},{j})"));
            }
        }
        let row_sum: f64 = w.row(i).iter().sum();
        if (row_sum - 1.0).abs() > 1e-10 {
            return Err(format!("row {i} sums to {row_sum}, not 1"));
        }
    }
    let spec = Spectrum::of_mixing(w);
    if (spec.w_eigs[0] - 1.0).abs() > 1e-8 {
        return Err(format!("largest eigenvalue {} != 1", spec.w_eigs[0]));
    }
    if n > 1 && spec.w_eigs[1] > 1.0 - 1e-10 {
        return Err("λ₂(W) = 1: graph disconnected or λ₁ not simple".into());
    }
    if spec.w_eigs[n - 1] <= -1.0 + 1e-12 {
        return Err(format!("smallest eigenvalue {} ≤ −1", spec.w_eigs[n - 1]));
    }
    Ok(spec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topology::Topology;
    use crate::util::rng::Rng;

    #[test]
    fn ring_uniform_is_one_third() {
        // the paper's setting: 8-node ring, mixing weight 1/3
        let g = Graph::ring(8);
        let w = mixing_matrix(&g, MixingRule::UniformMaxDegree);
        assert!((w[(0, 1)] - 1.0 / 3.0).abs() < 1e-15);
        assert!((w[(0, 7)] - 1.0 / 3.0).abs() < 1e-15);
        assert!((w[(0, 0)] - 1.0 / 3.0).abs() < 1e-15);
        assert_eq!(w[(0, 2)], 0.0);
        validate_mixing(&w, &g).expect("valid mixing");
    }

    #[test]
    fn all_rules_valid_on_all_topologies() {
        let mut rng = Rng::new(1);
        for kind in [
            Topology::Ring,
            Topology::Chain,
            Topology::Star,
            Topology::Complete,
            Topology::Grid,
            Topology::ErdosRenyi,
        ] {
            let n = if kind == Topology::Grid { 9 } else { 8 };
            let g = Graph::build(kind, n, &mut rng);
            for rule in [
                MixingRule::UniformMaxDegree,
                MixingRule::Metropolis,
                MixingRule::LazyMetropolis,
            ] {
                let w = mixing_matrix(&g, rule);
                validate_mixing(&w, &g)
                    .unwrap_or_else(|e| panic!("{kind:?}/{rule:?}: {e}"));
            }
        }
    }

    #[test]
    fn lazy_metropolis_psd() {
        let g = Graph::chain(6);
        let w = mixing_matrix(&g, MixingRule::LazyMetropolis);
        let spec = validate_mixing(&w, &g).unwrap();
        assert!(
            spec.w_eigs.iter().all(|&l| l >= -1e-12),
            "lazy MH must be PSD, got {:?}",
            spec.w_eigs
        );
    }

    #[test]
    fn kappa_g_ordering() {
        // complete graph mixes fastest; chain slowest
        let wc = mixing_matrix(&Graph::complete(8), MixingRule::Metropolis);
        let wr = mixing_matrix(&Graph::ring(8), MixingRule::Metropolis);
        let wh = mixing_matrix(&Graph::chain(8), MixingRule::Metropolis);
        let kc = Spectrum::of_mixing(&wc).kappa_g();
        let kr = Spectrum::of_mixing(&wr).kappa_g();
        let kh = Spectrum::of_mixing(&wh).kappa_g();
        assert!(kc < kr && kr < kh, "kappa_g: {kc} {kr} {kh}");
    }

    #[test]
    fn validate_rejects_asymmetric() {
        let g = Graph::ring(4);
        let mut w = mixing_matrix(&g, MixingRule::UniformMaxDegree);
        w[(0, 1)] += 0.01;
        assert!(validate_mixing(&w, &g).is_err());
    }

    #[test]
    fn validate_rejects_nonedge_weight() {
        let g = Graph::ring(6);
        let mut w = mixing_matrix(&g, MixingRule::UniformMaxDegree);
        // move weight onto a chord (0,3): symmetric + row sums preserved
        w[(0, 3)] = 0.1;
        w[(3, 0)] = 0.1;
        w[(0, 0)] -= 0.1;
        w[(3, 3)] -= 0.1;
        assert!(validate_mixing(&w, &g).is_err());
    }

    #[test]
    fn mixing_preserves_consensus() {
        // W applied to a consensual matrix must be a fixed point
        let g = Graph::ring(8);
        let w = mixing_matrix(&g, MixingRule::UniformMaxDegree);
        let x = Mat::broadcast_row(8, &[2.5, -1.0, 0.0]);
        let wx = w.matmul(&x);
        assert!(wx.dist_sq(&x) < 1e-24);
    }
}
