//! Mixing matrix construction (the W of Assumption 1).
//!
//! W must be symmetric, W1 = 1, supported on the graph's edges, and have
//! eigenvalues in (−1, 1] with λ₁ = 1 simple. The paper's experiments use a
//! ring with uniform weight 1/3 (self + two neighbors); we also provide
//! Metropolis–Hastings (valid for any graph) and its "lazy" damped variant.

use super::topology::Graph;
use crate::linalg::{power_gap_estimate, GapEstimate, Mat, SparseMat, Spectrum};

/// Weighting schemes for building W from a graph.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MixingRule {
    /// w_ij = 1/(deg_max + 1) for edges, diagonal absorbs the rest.
    /// Equals the paper's ring-1/3 on a ring (deg_max = 2).
    UniformMaxDegree,
    /// Metropolis–Hastings: w_ij = 1/(1 + max(deg_i, deg_j)).
    Metropolis,
    /// (I + W_mh)/2 — guarantees eigenvalues in [0, 1] (positive
    /// semidefinite), halving the spectral gap.
    LazyMetropolis,
}

impl std::str::FromStr for MixingRule {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "uniform" | "max-degree" => Ok(MixingRule::UniformMaxDegree),
            "metropolis" | "mh" => Ok(MixingRule::Metropolis),
            "lazy" | "lazy-metropolis" => Ok(MixingRule::LazyMetropolis),
            _ => Err(format!("unknown mixing rule '{s}'")),
        }
    }
}

/// Build the mixing matrix for `g` under `rule`.
pub fn mixing_matrix(g: &Graph, rule: MixingRule) -> Mat {
    let n = g.n;
    let mut w = Mat::zeros(n, n);
    match rule {
        MixingRule::UniformMaxDegree => {
            let weight = 1.0 / (g.max_degree() as f64 + 1.0);
            for i in 0..n {
                for &j in &g.adj[i] {
                    w[(i, j)] = weight;
                }
                w[(i, i)] = 1.0 - weight * g.degree(i) as f64;
            }
        }
        MixingRule::Metropolis | MixingRule::LazyMetropolis => {
            for i in 0..n {
                let mut row_sum = 0.0;
                for &j in &g.adj[i] {
                    let wij = 1.0 / (1.0 + g.degree(i).max(g.degree(j)) as f64);
                    w[(i, j)] = wij;
                    row_sum += wij;
                }
                w[(i, i)] = 1.0 - row_sum;
            }
            if rule == MixingRule::LazyMetropolis {
                for i in 0..n {
                    for j in 0..n {
                        w[(i, j)] *= 0.5;
                    }
                    w[(i, i)] += 0.5;
                }
            }
        }
    }
    w
}

/// Build the mixing matrix for `g` under `rule` directly in CSR form —
/// O(nnz) storage, never materializing the n×n dense matrix. The per-entry
/// arithmetic mirrors [`mixing_matrix`] operation for operation, so the
/// stored values are **bit-identical** to the dense construction (asserted
/// by the `sparse_equals_dense_*` property test below).
pub fn mixing_csr(g: &Graph, rule: MixingRule) -> SparseMat {
    let n = g.n;
    let mut rows: Vec<Vec<(usize, f64)>> = Vec::with_capacity(n);
    match rule {
        MixingRule::UniformMaxDegree => {
            let weight = 1.0 / (g.max_degree() as f64 + 1.0);
            for i in 0..n {
                let diag = 1.0 - weight * g.degree(i) as f64;
                rows.push(row_with_diag(&g.adj[i], i, diag, |_| weight));
            }
        }
        MixingRule::Metropolis | MixingRule::LazyMetropolis => {
            for i in 0..n {
                // accumulate row_sum in adjacency order, as the dense path does
                let mut row_sum = 0.0;
                for &j in &g.adj[i] {
                    row_sum += 1.0 / (1.0 + g.degree(i).max(g.degree(j)) as f64);
                }
                let diag = 1.0 - row_sum;
                rows.push(row_with_diag(&g.adj[i], i, diag, |j| {
                    1.0 / (1.0 + g.degree(i).max(g.degree(j)) as f64)
                }));
            }
        }
    }
    let mut w = SparseMat::from_rows(n, n, &rows);
    if rule == MixingRule::LazyMetropolis {
        // (I + W_mh)/2, with the same f64 ops as the dense construction
        w.scale(0.5);
        w.add_to_diag(0.5);
    }
    w
}

/// One CSR row: the sorted neighbor entries with the diagonal spliced in.
fn row_with_diag(
    adj: &[usize],
    i: usize,
    diag: f64,
    weight_of: impl Fn(usize) -> f64,
) -> Vec<(usize, f64)> {
    let mut row = Vec::with_capacity(adj.len() + 1);
    let mut placed = false;
    for &j in adj {
        if !placed && j > i {
            row.push((i, diag));
            placed = true;
        }
        row.push((j, weight_of(j)));
    }
    if !placed {
        row.push((i, diag));
    }
    row
}

/// Stored-entry density below which the CSR representation wins: W rows
/// touch deg+1 entries out of n, so sparse gossip pays off as soon as the
/// graph is meaningfully sparser than complete. The 25% threshold keeps the
/// paper's 8-node ring (3/8 = 37.5% dense rows) on the historical dense
/// path while every larger ring/grid/ER graph goes sparse.
const SPARSE_DENSITY_THRESHOLD: f64 = 0.25;

/// The mixing operator every algorithm gossips through: a dense matrix for
/// small/dense graphs, CSR for sparse ones, auto-selected by stored-entry
/// density. Both variants produce **bit-identical** products (see
/// [`crate::linalg::sparse`]'s exactness contract), so the choice is purely
/// a performance decision: dense gossip is O(n²p) per round, sparse is
/// O(nnz·p).
#[derive(Clone, Debug)]
pub enum MixingOp {
    Dense(Mat),
    Sparse(SparseMat),
}

impl MixingOp {
    /// Build from a graph + rule, auto-selecting the representation.
    pub fn build(g: &Graph, rule: MixingRule) -> MixingOp {
        let nnz = 2 * g.num_edges() + g.n; // off-diagonals + stored diagonal
        let density = nnz as f64 / (g.n * g.n).max(1) as f64;
        if density < SPARSE_DENSITY_THRESHOLD {
            MixingOp::sparse_from(g, rule)
        } else {
            MixingOp::dense_from(g, rule)
        }
    }

    /// Force the dense representation.
    pub fn dense_from(g: &Graph, rule: MixingRule) -> MixingOp {
        MixingOp::Dense(mixing_matrix(g, rule))
    }

    /// Force the CSR representation.
    pub fn sparse_from(g: &Graph, rule: MixingRule) -> MixingOp {
        MixingOp::Sparse(mixing_csr(g, rule))
    }

    pub fn is_sparse(&self) -> bool {
        matches!(self, MixingOp::Sparse(_))
    }

    /// Number of nodes (W is n×n).
    pub fn n(&self) -> usize {
        match self {
            MixingOp::Dense(w) => w.rows,
            MixingOp::Sparse(s) => s.rows,
        }
    }

    /// Stored nonzeros (dense counts actual nonzero entries).
    pub fn nnz(&self) -> usize {
        match self {
            MixingOp::Dense(w) => w.data.iter().filter(|v| **v != 0.0).count(),
            MixingOp::Sparse(s) => s.nnz(),
        }
    }

    /// Entry w_ij.
    pub fn get(&self, i: usize, j: usize) -> f64 {
        match self {
            MixingOp::Dense(w) => w[(i, j)],
            MixingOp::Sparse(s) => s.get(i, j),
        }
    }

    /// w_ii — the node's own gossip weight.
    pub fn self_weight(&self, i: usize) -> f64 {
        self.get(i, i)
    }

    /// Node i's gossip neighbors as (j, w_ij), ascending j, excluding self
    /// and zero weights — the coordinator derives its per-edge channels
    /// from exactly this structure.
    pub fn neighbors(&self, i: usize) -> Vec<(usize, f64)> {
        match self {
            MixingOp::Dense(w) => (0..w.cols)
                .filter(|&j| j != i && w[(i, j)] != 0.0)
                .map(|j| (j, w[(i, j)]))
                .collect(),
            MixingOp::Sparse(s) => {
                s.row_iter(i).filter(|&(j, v)| j != i && v != 0.0).collect()
            }
        }
    }

    /// out = W · X into a preallocated buffer — the gossip hot path.
    pub fn apply_into(&self, x: &Mat, out: &mut Mat) {
        match self {
            MixingOp::Dense(w) => w.matmul_into(x, out),
            MixingOp::Sparse(s) => s.apply_into(x, out),
        }
    }

    /// Allocating convenience wrapper (init paths only; rounds use
    /// [`MixingOp::apply_into`] with scratch).
    pub fn apply(&self, x: &Mat) -> Mat {
        let mut out = Mat::zeros(self.n(), x.cols);
        self.apply_into(x, &mut out);
        out
    }

    /// y = W · x for a single vector (power iteration, per-node checks).
    pub fn apply_vec(&self, x: &[f64], y: &mut [f64]) {
        match self {
            MixingOp::Dense(w) => {
                for (i, yi) in y.iter_mut().enumerate() {
                    *yi = crate::linalg::vdot(w.row(i), x);
                }
            }
            MixingOp::Sparse(s) => s.apply_vec(x, y),
        }
    }

    /// W̃ = (I + W)/2, in the same representation (the NIDS / PG-EXTRA /
    /// P2D2 double-mixing operator). Same f64 ops as the historical dense
    /// in-algorithm construction, so iterates are unchanged bit for bit.
    pub fn half_lazy(&self) -> MixingOp {
        match self {
            MixingOp::Dense(w) => {
                let mut t = w.clone();
                t.scale(0.5);
                for i in 0..t.rows {
                    t[(i, i)] += 0.5;
                }
                MixingOp::Dense(t)
            }
            MixingOp::Sparse(s) => {
                let mut t = s.clone();
                t.scale(0.5);
                t.add_to_diag(0.5);
                MixingOp::Sparse(t)
            }
        }
    }

    /// W − I, in the same representation (Choco's consensus correction).
    pub fn minus_identity(&self) -> MixingOp {
        match self {
            MixingOp::Dense(w) => {
                let mut t = w.clone();
                for i in 0..t.rows {
                    t[(i, i)] -= 1.0;
                }
                MixingOp::Dense(t)
            }
            MixingOp::Sparse(s) => {
                let mut t = s.clone();
                t.add_to_diag(-1.0);
                MixingOp::Sparse(t)
            }
        }
    }

    /// Materialize as dense (validation, eigensolves, tests).
    pub fn to_dense(&self) -> Mat {
        match self {
            MixingOp::Dense(w) => w.clone(),
            MixingOp::Sparse(s) => s.to_dense(),
        }
    }

    /// Spectral-edge estimate by matrix-free power iteration — O(nnz) per
    /// step, replacing the dense O(n³) eigendecomposition for λ₂/λ_n.
    /// Deterministic (fixed internal seed).
    pub fn gap_estimate(&self) -> GapEstimate {
        power_gap_estimate(self.n(), |x, y| self.apply_vec(x, y), 100_000, 1e-14, 0x5EED)
    }
}

impl From<Mat> for MixingOp {
    fn from(w: Mat) -> MixingOp {
        MixingOp::Dense(w)
    }
}

/// Validate Assumption 1: symmetry, row-stochasticity, edge support,
/// eigenvalues in (−1, 1] with λ₁ = 1 simple. Returns the spectrum on
/// success so callers can reuse it.
pub fn validate_mixing(w: &Mat, g: &Graph) -> Result<Spectrum, String> {
    let n = g.n;
    if w.rows != n || w.cols != n {
        return Err(format!("W is {}x{}, graph has {n} nodes", w.rows, w.cols));
    }
    for i in 0..n {
        for j in 0..n {
            if (w[(i, j)] - w[(j, i)]).abs() > 1e-12 {
                return Err(format!("W not symmetric at ({i},{j})"));
            }
            if i != j && w[(i, j)].abs() > 1e-12 && !g.has_edge(i, j) {
                return Err(format!("W has weight on non-edge ({i},{j})"));
            }
        }
        let row_sum = crate::linalg::vsum(w.row(i));
        if (row_sum - 1.0).abs() > 1e-10 {
            return Err(format!("row {i} sums to {row_sum}, not 1"));
        }
    }
    let spec = Spectrum::of_mixing(w);
    if (spec.w_eigs[0] - 1.0).abs() > 1e-8 {
        return Err(format!("largest eigenvalue {} != 1", spec.w_eigs[0]));
    }
    if n > 1 && spec.w_eigs[1] > 1.0 - 1e-10 {
        return Err("λ₂(W) = 1: graph disconnected or λ₁ not simple".into());
    }
    if spec.w_eigs[n - 1] <= -1.0 + 1e-12 {
        return Err(format!("smallest eigenvalue {} ≤ −1", spec.w_eigs[n - 1]));
    }
    Ok(spec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topology::Topology;
    use crate::util::rng::Rng;

    #[test]
    fn ring_uniform_is_one_third() {
        // the paper's setting: 8-node ring, mixing weight 1/3
        let g = Graph::ring(8);
        let w = mixing_matrix(&g, MixingRule::UniformMaxDegree);
        assert!((w[(0, 1)] - 1.0 / 3.0).abs() < 1e-15);
        assert!((w[(0, 7)] - 1.0 / 3.0).abs() < 1e-15);
        assert!((w[(0, 0)] - 1.0 / 3.0).abs() < 1e-15);
        assert_eq!(w[(0, 2)], 0.0);
        validate_mixing(&w, &g).expect("valid mixing");
    }

    #[test]
    fn all_rules_valid_on_all_topologies() {
        let mut rng = Rng::new(1);
        for kind in [
            Topology::Ring,
            Topology::Chain,
            Topology::Star,
            Topology::Complete,
            Topology::Grid,
            Topology::ErdosRenyi,
        ] {
            let n = if kind == Topology::Grid { 9 } else { 8 };
            let g = Graph::build(kind, n, &mut rng);
            for rule in [
                MixingRule::UniformMaxDegree,
                MixingRule::Metropolis,
                MixingRule::LazyMetropolis,
            ] {
                let w = mixing_matrix(&g, rule);
                validate_mixing(&w, &g)
                    .unwrap_or_else(|e| panic!("{kind:?}/{rule:?}: {e}"));
            }
        }
    }

    #[test]
    fn lazy_metropolis_psd() {
        let g = Graph::chain(6);
        let w = mixing_matrix(&g, MixingRule::LazyMetropolis);
        let spec = validate_mixing(&w, &g).unwrap();
        assert!(
            spec.w_eigs.iter().all(|&l| l >= -1e-12),
            "lazy MH must be PSD, got {:?}",
            spec.w_eigs
        );
    }

    #[test]
    fn kappa_g_ordering() {
        // complete graph mixes fastest; chain slowest
        let wc = mixing_matrix(&Graph::complete(8), MixingRule::Metropolis);
        let wr = mixing_matrix(&Graph::ring(8), MixingRule::Metropolis);
        let wh = mixing_matrix(&Graph::chain(8), MixingRule::Metropolis);
        let kc = Spectrum::of_mixing(&wc).kappa_g();
        let kr = Spectrum::of_mixing(&wr).kappa_g();
        let kh = Spectrum::of_mixing(&wh).kappa_g();
        assert!(kc < kr && kr < kh, "kappa_g: {kc} {kr} {kh}");
    }

    #[test]
    fn validate_rejects_asymmetric() {
        let g = Graph::ring(4);
        let mut w = mixing_matrix(&g, MixingRule::UniformMaxDegree);
        w[(0, 1)] += 0.01;
        assert!(validate_mixing(&w, &g).is_err());
    }

    #[test]
    fn validate_rejects_nonedge_weight() {
        let g = Graph::ring(6);
        let mut w = mixing_matrix(&g, MixingRule::UniformMaxDegree);
        // move weight onto a chord (0,3): symmetric + row sums preserved
        w[(0, 3)] = 0.1;
        w[(3, 0)] = 0.1;
        w[(0, 0)] -= 0.1;
        w[(3, 3)] -= 0.1;
        assert!(validate_mixing(&w, &g).is_err());
    }

    #[test]
    fn sparse_equals_dense_across_topologies_and_rules() {
        // The tentpole contract: the CSR construction stores bit-identical
        // values, its products are bit-identical to the dense kernel, and
        // both representations stay symmetric and row-stochastic.
        use crate::util::qc::assert_prop;
        let rules =
            [MixingRule::UniformMaxDegree, MixingRule::Metropolis, MixingRule::LazyMetropolis];
        let topos = [
            Topology::Ring,
            Topology::Chain,
            Topology::Star,
            Topology::Complete,
            Topology::Grid,
            Topology::ErdosRenyi,
        ];
        assert_prop("MixingOp sparse == dense (bitwise)", 40, |g| {
            let kind = *g.choose(&topos);
            let rule = *g.choose(&rules);
            let n = match kind {
                // grid needs a perfect square; others just need n ≥ 3
                Topology::Grid => [4usize, 9, 16, 25][g.rng.below(4)],
                _ => g.usize_in(3, 24),
            };
            let mut rng = Rng::new(g.rng.next_u64());
            let graph = Graph::build(kind, n, &mut rng);
            let dense = mixing_matrix(&graph, rule);
            let csr = mixing_csr(&graph, rule);
            // (1) stored values are bit-identical to the dense construction
            let lifted = csr.to_dense();
            for (i, (a, b)) in dense.data.iter().zip(&lifted.data).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("{kind:?}/{rule:?} n={n}: entry {i} {a:?} vs {b:?}"));
                }
            }
            // (2) products are bit-identical (same summation order)
            let p = g.usize_in(1, 8);
            let mut x = Mat::zeros(n, p);
            rng.fill_normal(&mut x.data);
            let mut out_d = Mat::zeros(n, p);
            let mut out_s = Mat::zeros(n, p);
            MixingOp::Dense(dense.clone()).apply_into(&x, &mut out_d);
            MixingOp::Sparse(csr.clone()).apply_into(&x, &mut out_s);
            for (i, (a, b)) in out_d.data.iter().zip(&out_s.data).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!(
                        "{kind:?}/{rule:?} n={n}: apply entry {i} {a:?} vs {b:?}"
                    ));
                }
            }
            // (3) symmetry and (4) row sums = 1 on the sparse operator
            let op = MixingOp::Sparse(csr);
            for i in 0..n {
                let mut row_sum = op.self_weight(i);
                for (j, wij) in op.neighbors(i) {
                    if (wij - op.get(j, i)).abs() > 1e-15 {
                        return Err(format!("asymmetry at ({i},{j}): {wij} vs {}", op.get(j, i)));
                    }
                    row_sum += wij;
                }
                if (row_sum - 1.0).abs() > 1e-12 {
                    return Err(format!("row {i} sums to {row_sum}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn mixing_op_auto_selects_by_density() {
        // the paper's 8-ring stays dense; larger rings go sparse
        let small = MixingOp::build(&Graph::ring(8), MixingRule::UniformMaxDegree);
        assert!(!small.is_sparse());
        let big = MixingOp::build(&Graph::ring(32), MixingRule::UniformMaxDegree);
        assert!(big.is_sparse());
        assert_eq!(big.nnz(), 3 * 32); // self + two neighbors per node
        let complete = MixingOp::build(&Graph::complete(32), MixingRule::Metropolis);
        assert!(!complete.is_sparse());
    }

    #[test]
    fn mixing_op_neighbors_match_matrix_row() {
        let g = Graph::grid(16);
        for op in [
            MixingOp::dense_from(&g, MixingRule::Metropolis),
            MixingOp::sparse_from(&g, MixingRule::Metropolis),
        ] {
            let w = op.to_dense();
            for i in 0..g.n {
                let nbrs = op.neighbors(i);
                assert_eq!(nbrs.len(), g.degree(i));
                for (j, wij) in nbrs {
                    assert!(g.has_edge(i, j));
                    assert_eq!(wij, w[(i, j)]);
                }
                assert_eq!(op.self_weight(i), w[(i, i)]);
            }
        }
    }

    #[test]
    fn half_lazy_and_minus_identity_match_dense_ops() {
        let g = Graph::ring(12);
        let dense = MixingOp::dense_from(&g, MixingRule::Metropolis);
        let sparse = MixingOp::sparse_from(&g, MixingRule::Metropolis);
        for (a, b) in [
            (dense.half_lazy().to_dense(), sparse.half_lazy().to_dense()),
            (dense.minus_identity().to_dense(), sparse.minus_identity().to_dense()),
        ] {
            for (x, y) in a.data.iter().zip(&b.data) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        // and half_lazy really is (I+W)/2
        let w = dense.to_dense();
        let ht = dense.half_lazy().to_dense();
        for i in 0..12 {
            for j in 0..12 {
                let expect = 0.5 * w[(i, j)] + if i == j { 0.5 } else { 0.0 };
                assert!((ht[(i, j)] - expect).abs() < 1e-15);
            }
        }
    }

    #[test]
    fn gap_estimate_matches_dense_spectrum() {
        let g = Graph::ring(20);
        let op = MixingOp::sparse_from(&g, MixingRule::UniformMaxDegree);
        let est = op.gap_estimate();
        let spec = Spectrum::of_mixing(&op.to_dense());
        assert!((est.lam_min_pos() - spec.lam_min_pos).abs() < 1e-6);
        assert!((est.lam_max() - spec.lam_max).abs() < 1e-6);
        assert!((est.kappa_g() - spec.kappa_g()).abs() < 1e-4 * spec.kappa_g());
        assert!((est.spectral_gap() - spec.spectral_gap()).abs() < 1e-6);
    }

    #[test]
    fn mixing_preserves_consensus() {
        // W applied to a consensual matrix must be a fixed point
        let g = Graph::ring(8);
        let w = mixing_matrix(&g, MixingRule::UniformMaxDegree);
        let x = Mat::broadcast_row(8, &[2.5, -1.0, 0.0]);
        let wx = w.matmul(&x);
        assert!(wx.dist_sq(&x) < 1e-24);
    }
}
