//! Network graphs and mixing matrices (Assumption 1 of the paper).

pub mod mixing;
pub mod topology;

pub use mixing::{mixing_csr, mixing_matrix, validate_mixing, MixingOp, MixingRule};
pub use topology::{Graph, Topology};
