//! Communication network topologies (the graph G of Assumption 1).
//!
//! A [`Graph`] is an undirected simple graph over nodes 0..n. The paper's
//! experiments use an 8-node ring; we provide the standard families used in
//! the decentralized-optimization literature so κ_g can be swept in the
//! complexity benchmarks (Table 2 / Table 3).

use crate::util::rng::Rng;
use std::collections::BTreeSet;

/// Undirected graph with adjacency sets.
#[derive(Clone, Debug)]
pub struct Graph {
    pub n: usize,
    /// adj[i] = sorted neighbor ids of node i (no self-loops).
    pub adj: Vec<Vec<usize>>,
}

/// Named topology families for configs / CLI.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topology {
    Ring,
    Chain,
    Star,
    Complete,
    /// 2-D torus grid (n must be a perfect square).
    Grid,
    /// Erdős–Rényi G(n, prob), re-sampled until connected.
    ErdosRenyi,
}

impl std::str::FromStr for Topology {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "ring" => Ok(Topology::Ring),
            "chain" | "path" => Ok(Topology::Chain),
            "star" => Ok(Topology::Star),
            "complete" | "full" => Ok(Topology::Complete),
            "grid" | "torus" => Ok(Topology::Grid),
            "er" | "erdos-renyi" => Ok(Topology::ErdosRenyi),
            _ => Err(format!("unknown topology '{s}'")),
        }
    }
}

impl Graph {
    fn from_edges(n: usize, edges: impl IntoIterator<Item = (usize, usize)>) -> Graph {
        let mut sets: Vec<BTreeSet<usize>> = vec![BTreeSet::new(); n];
        for (a, b) in edges {
            assert!(a < n && b < n && a != b, "bad edge ({a},{b}) for n={n}");
            sets[a].insert(b);
            sets[b].insert(a);
        }
        Graph {
            n,
            adj: sets.into_iter().map(|s| s.into_iter().collect()).collect(),
        }
    }

    /// Build a named topology. `rng` is only used by Erdős–Rényi.
    pub fn build(kind: Topology, n: usize, rng: &mut Rng) -> Graph {
        match kind {
            Topology::Ring => Graph::ring(n),
            Topology::Chain => Graph::chain(n),
            Topology::Star => Graph::star(n),
            Topology::Complete => Graph::complete(n),
            Topology::Grid => Graph::grid(n),
            Topology::ErdosRenyi => Graph::erdos_renyi(n, Graph::auto_er_prob(n), rng),
        }
    }

    pub fn ring(n: usize) -> Graph {
        assert!(n >= 3, "ring needs n >= 3");
        Graph::from_edges(n, (0..n).map(|i| (i, (i + 1) % n)))
    }

    pub fn chain(n: usize) -> Graph {
        assert!(n >= 2);
        Graph::from_edges(n, (0..n - 1).map(|i| (i, i + 1)))
    }

    pub fn star(n: usize) -> Graph {
        assert!(n >= 2);
        Graph::from_edges(n, (1..n).map(|i| (0, i)))
    }

    pub fn complete(n: usize) -> Graph {
        assert!(n >= 2);
        Graph::from_edges(n, (0..n).flat_map(|i| ((i + 1)..n).map(move |j| (i, j))))
    }

    /// 2-D torus: n must be a perfect square k×k (k ≥ 2); wraps both
    /// dimensions. Non-square n **panics** here — there is no silent
    /// rounding/fallback that would mis-shape the torus. Config-driven
    /// paths never reach the panic: `Config::topology()` rejects
    /// non-square node counts with a `ConfigError` naming the nearest
    /// squares (see `config.rs`), which is also what sweeps surface.
    pub fn grid(n: usize) -> Graph {
        let k = (n as f64).sqrt().round() as usize;
        assert_eq!(k * k, n, "grid needs a perfect square n");
        assert!(k >= 2);
        let id = |r: usize, c: usize| r * k + c;
        let mut edges = Vec::new();
        for r in 0..k {
            for c in 0..k {
                edges.push((id(r, c), id(r, (c + 1) % k)));
                edges.push((id(r, c), id((r + 1) % k, c)));
            }
        }
        // k = 2 wraps create duplicate edges; from_edges dedups via sets
        Graph::from_edges(n, edges.into_iter().filter(|(a, b)| a != b))
    }

    /// The connectivity-safe default Erdős–Rényi edge probability,
    /// (2·ln n / n) capped at 0.8 — twice the ln(n)/n connectivity
    /// threshold, so resampling-until-connected takes O(1) tries. The one
    /// definition shared by [`Graph::build`], `Config::topology()`, and
    /// the scaling benches.
    pub fn auto_er_prob(n: usize) -> f64 {
        (2.0 * (n as f64).ln() / n as f64).min(0.8)
    }

    /// Erdős–Rényi, re-sampled until connected (expected O(1) tries above
    /// the connectivity threshold). Panics if 1000 draws all come up
    /// disconnected; use [`Graph::try_erdos_renyi`] to handle that case.
    pub fn erdos_renyi(n: usize, prob: f64, rng: &mut Rng) -> Graph {
        Graph::try_erdos_renyi(n, prob, rng, 1000)
            .unwrap_or_else(|| panic!("could not sample a connected G({n},{prob}) in 1000 tries"))
    }

    /// [`Graph::erdos_renyi`] with a caller-chosen retry budget, returning
    /// None instead of panicking when no draw comes up connected (config
    /// paths turn that into a clean error).
    pub fn try_erdos_renyi(n: usize, prob: f64, rng: &mut Rng, attempts: usize) -> Option<Graph> {
        assert!(n >= 2);
        for _attempt in 0..attempts {
            let mut edges = Vec::new();
            for i in 0..n {
                for j in (i + 1)..n {
                    if rng.bernoulli(prob) {
                        edges.push((i, j));
                    }
                }
            }
            let g = Graph::from_edges(n, edges);
            if g.is_connected() {
                return Some(g);
            }
        }
        None
    }

    /// Sparse Erdős–Rényi sampler for massive n: geometric skip-sampling
    /// over the (i,j) pair index draws each present edge directly, so the
    /// cost is O(m + n) instead of the O(n²) Bernoulli-per-pair loop of
    /// [`Graph::try_erdos_renyi`] — at n = 10⁶ with p = 2·ln n/n that is
    /// ~1.4·10⁷ draws instead of 5·10¹¹. Statistically the same G(n, p)
    /// (each pair is present independently with probability `prob`), but a
    /// *different* stream-consumption pattern, so seeded draws do not
    /// reproduce `try_erdos_renyi`'s graphs — seeded experiments keep the
    /// exact sampler; the scaling benches use this one. Re-samples until
    /// connected like the exact sampler; returns None after `attempts`
    /// disconnected draws.
    pub fn try_erdos_renyi_sparse(
        n: usize,
        prob: f64,
        rng: &mut Rng,
        attempts: usize,
    ) -> Option<Graph> {
        assert!(n >= 2);
        assert!((0.0..=1.0).contains(&prob));
        if prob >= 1.0 {
            return Some(Graph::complete(n));
        }
        let total = n * (n - 1) / 2; // pairs (i,j), i<j, in row-major order
        let log1m = (1.0 - prob).ln(); // < 0; prob > 0 or nothing connects
        for _attempt in 0..attempts {
            if prob <= 0.0 {
                return None; // empty graph can't be connected (n ≥ 2)
            }
            let mut edges = Vec::with_capacity((prob * total as f64 * 1.1) as usize + 16);
            // skip-sampling: the gap to the next present pair is geometric
            // with success prob `prob`; ⌊ln(u)/ln(1−p)⌋ inverts its CDF.
            // Pair indices enumerate the upper triangle row-major: row i
            // holds the n−1−i pairs (i, i+1..n). `idx` is monotone, so the
            // (row, row_start) cursor below advances O(n) total.
            let mut idx = 0usize;
            let mut row = 0usize; // current row i
            let mut row_start = 0usize; // pair index of (row, row+1)
            loop {
                let u = rng.f64().max(f64::MIN_POSITIVE); // avoid ln(0)
                let skip = (u.ln() / log1m).floor() as usize;
                idx = match idx.checked_add(skip) {
                    Some(v) if v < total => v,
                    _ => break,
                };
                while idx - row_start >= n - 1 - row {
                    row_start += n - 1 - row;
                    row += 1;
                }
                edges.push((row, row + 1 + (idx - row_start)));
                idx += 1;
            }
            let g = Graph::from_edges(n, edges);
            if g.is_connected() {
                return Some(g);
            }
        }
        None
    }

    pub fn degree(&self, i: usize) -> usize {
        self.adj[i].len()
    }

    pub fn max_degree(&self) -> usize {
        self.adj.iter().map(|a| a.len()).max().unwrap_or(0)
    }

    pub fn num_edges(&self) -> usize {
        self.adj.iter().map(|a| a.len()).sum::<usize>() / 2
    }

    pub fn edges(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::with_capacity(self.num_edges());
        for i in 0..self.n {
            for &j in &self.adj[i] {
                if j > i {
                    out.push((i, j));
                }
            }
        }
        out
    }

    pub fn has_edge(&self, a: usize, b: usize) -> bool {
        self.adj[a].binary_search(&b).is_ok()
    }

    /// BFS connectivity check (Assumption 1 requires a connected graph).
    pub fn is_connected(&self) -> bool {
        if self.n == 0 {
            return true;
        }
        let mut seen = vec![false; self.n];
        let mut queue = vec![0usize];
        seen[0] = true;
        let mut count = 1;
        while let Some(u) = queue.pop() {
            for &v in &self.adj[u] {
                if !seen[v] {
                    seen[v] = true;
                    count += 1;
                    queue.push(v);
                }
            }
        }
        count == self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_structure() {
        let g = Graph::ring(8);
        assert_eq!(g.n, 8);
        assert_eq!(g.num_edges(), 8);
        assert!(g.adj.iter().all(|a| a.len() == 2));
        assert!(g.has_edge(0, 7));
        assert!(g.has_edge(0, 1));
        assert!(!g.has_edge(0, 2));
        assert!(g.is_connected());
    }

    #[test]
    fn chain_and_star() {
        let c = Graph::chain(5);
        assert_eq!(c.num_edges(), 4);
        assert!(c.is_connected());
        let s = Graph::star(6);
        assert_eq!(s.degree(0), 5);
        assert!(s.adj[1..].iter().all(|a| a == &vec![0]));
    }

    #[test]
    fn complete_graph() {
        let g = Graph::complete(6);
        assert_eq!(g.num_edges(), 15);
        assert_eq!(g.max_degree(), 5);
    }

    #[test]
    fn grid_torus() {
        let g = Graph::grid(9);
        assert!(g.is_connected());
        assert!(g.adj.iter().all(|a| a.len() == 4), "3x3 torus is 4-regular");
        let g2 = Graph::grid(4); // 2x2 torus: wraps dedup to 4 edges
        assert!(g2.is_connected());
    }

    #[test]
    #[should_panic(expected = "perfect square")]
    fn grid_requires_square() {
        let _ = Graph::grid(7);
    }

    #[test]
    fn erdos_renyi_connected() {
        let mut rng = Rng::new(3);
        for _ in 0..5 {
            let g = Graph::erdos_renyi(20, 0.25, &mut rng);
            assert!(g.is_connected());
            assert_eq!(g.n, 20);
        }
    }

    #[test]
    fn erdos_renyi_sparse_matches_family() {
        // the skip-sampler draws the same G(n, p) family: connected,
        // simple, i<j edges only, edge count near p·n(n−1)/2
        let mut rng = Rng::new(7);
        let n = 400;
        let p = Graph::auto_er_prob(n);
        let g = Graph::try_erdos_renyi_sparse(n, p, &mut rng, 1000).unwrap();
        assert_eq!(g.n, n);
        assert!(g.is_connected());
        for i in 0..n {
            for &j in &g.adj[i] {
                assert!(j < n && j != i);
            }
        }
        let expect = p * (n * (n - 1) / 2) as f64;
        let m = g.num_edges() as f64;
        assert!((m - expect).abs() < 6.0 * expect.sqrt(), "m={m} expect≈{expect}");
        // degenerate probabilities: p=1 is the complete graph, p=0 can
        // never connect and must return None instead of spinning
        let full = Graph::try_erdos_renyi_sparse(5, 1.0, &mut rng, 1).unwrap();
        assert_eq!(full.num_edges(), 10);
        assert!(Graph::try_erdos_renyi_sparse(5, 0.0, &mut rng, 3).is_none());
    }

    #[test]
    fn edges_listing_consistent() {
        let g = Graph::ring(5);
        let es = g.edges();
        assert_eq!(es.len(), 5);
        for (a, b) in es {
            assert!(g.has_edge(a, b) && g.has_edge(b, a));
            assert!(a < b);
        }
    }

    #[test]
    fn disconnected_detected() {
        let g = Graph::from_edges(4, [(0, 1), (2, 3)]);
        assert!(!g.is_connected());
    }

    #[test]
    fn topology_parse() {
        assert_eq!("ring".parse::<Topology>().unwrap(), Topology::Ring);
        assert_eq!("full".parse::<Topology>().unwrap(), Topology::Complete);
        assert!("moebius".parse::<Topology>().is_err());
    }
}
