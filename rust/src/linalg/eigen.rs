//! Symmetric eigensolver (cyclic Jacobi) and derived spectral utilities.
//!
//! Needed for Assumption 1 checks and the theory-driven parameter choices:
//! λ_max(I−W), λ_min⁺(I−W) (smallest *nonzero* eigenvalue), the network
//! condition number κ_g, and (I−W)† norms used by the potential function
//! Φᵏ in the convergence tests.

use super::matrix::Mat;

/// Full symmetric eigendecomposition via cyclic Jacobi rotations.
/// Returns eigenvalues sorted descending and the matching eigenvectors as
/// *columns* of the returned matrix. Suitable for the small (n ≤ a few
/// hundred) mixing matrices we work with.
pub fn sym_eigen(a: &Mat) -> (Vec<f64>, Mat) {
    assert_eq!(a.rows, a.cols, "sym_eigen needs a square matrix");
    let n = a.rows;
    let mut m = a.clone();
    let mut v = Mat::eye(n);
    let max_sweeps = 100;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() < 1e-14 * (1.0 + m.norm()) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                let t = if theta >= 0.0 {
                    1.0 / (theta + (1.0 + theta * theta).sqrt())
                } else {
                    -1.0 / (-theta + (1.0 + theta * theta).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                // rotate rows/cols p,q of m
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                // accumulate eigenvectors
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }
    let mut eig: Vec<(f64, usize)> = (0..n).map(|i| (m[(i, i)], i)).collect();
    eig.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let vals: Vec<f64> = eig.iter().map(|e| e.0).collect();
    let mut vecs = Mat::zeros(n, n);
    for (new_col, &(_, old_col)) in eig.iter().enumerate() {
        for k in 0..n {
            vecs[(k, new_col)] = v[(k, old_col)];
        }
    }
    (vals, vecs)
}

/// Spectral data of a mixing matrix W needed by the algorithms' theory.
#[derive(Clone, Debug)]
pub struct Spectrum {
    /// Eigenvalues of W, descending (λ₁ = 1 for a valid mixing matrix).
    pub w_eigs: Vec<f64>,
    /// λ_max(I − W) = 1 − λ_n(W).
    pub lam_max: f64,
    /// λ_min⁺(I − W): smallest nonzero eigenvalue = 1 − λ₂(W).
    pub lam_min_pos: f64,
}

impl Spectrum {
    pub fn of_mixing(w: &Mat) -> Spectrum {
        let (eigs, _) = sym_eigen(w);
        let n = eigs.len();
        let lam_max = 1.0 - eigs[n - 1];
        let lam_min_pos = 1.0 - eigs[1.min(n - 1)];
        Spectrum {
            w_eigs: eigs,
            lam_max,
            lam_min_pos,
        }
    }

    /// Network condition number κ_g = λ_max(I−W) / λ_min⁺(I−W).
    pub fn kappa_g(&self) -> f64 {
        self.lam_max / self.lam_min_pos
    }

    /// Spectral gap 1 − |λ₂| used by gossip-style analyses (Choco).
    pub fn spectral_gap(&self) -> f64 {
        let n = self.w_eigs.len();
        let rho = self.w_eigs[1.min(n - 1)]
            .abs()
            .max(self.w_eigs[n - 1].abs());
        1.0 - rho
    }
}

/// ‖M‖²_{(I−W)†} = ⟨M, (I−W)† M⟩: the weighted norm of the dual variable in
/// the potential function Φᵏ. Computed via the eigendecomposition of W.
pub struct PinvNorm {
    vecs: Mat,          // eigenvectors of W (columns)
    inv_vals: Vec<f64>, // 1/λᵢ(I−W) for nonzero λ, else 0
}

impl PinvNorm {
    pub fn new(w: &Mat) -> PinvNorm {
        let (vals, vecs) = sym_eigen(w);
        let inv_vals: Vec<f64> = vals
            .iter()
            .map(|&lw| {
                let l = 1.0 - lw;
                if l.abs() < 1e-10 {
                    0.0
                } else {
                    1.0 / l
                }
            })
            .collect();
        PinvNorm { vecs, inv_vals }
    }

    /// ⟨M, (I−W)† M⟩ for an n×p matrix M.
    pub fn norm_sq(&self, m: &Mat) -> f64 {
        // project each column of M onto the eigenbasis: Y = Vᵀ M
        let y = self.vecs.t_matmul(m);
        let mut total = 0.0;
        for i in 0..y.rows {
            let wgt = self.inv_vals[i];
            if wgt == 0.0 {
                continue;
            }
            let row = y.row(i);
            total += wgt * row.iter().map(|x| x * x).sum::<f64>();
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_sym(rng: &mut Rng, n: usize) -> Mat {
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let v = rng.normal();
                a[(i, j)] = v;
                a[(j, i)] = v;
            }
        }
        a
    }

    #[test]
    fn diagonal_matrix_eigs() {
        let mut a = Mat::zeros(3, 3);
        a[(0, 0)] = 3.0;
        a[(1, 1)] = -1.0;
        a[(2, 2)] = 2.0;
        let (vals, _) = sym_eigen(&a);
        assert!((vals[0] - 3.0).abs() < 1e-10);
        assert!((vals[1] - 2.0).abs() < 1e-10);
        assert!((vals[2] + 1.0).abs() < 1e-10);
    }

    #[test]
    fn eigen_reconstruction() {
        let mut rng = Rng::new(4);
        for n in [2, 5, 10, 20] {
            let a = random_sym(&mut rng, n);
            let (vals, vecs) = sym_eigen(&a);
            // A V = V Λ
            let av = a.matmul(&vecs);
            let mut vl = vecs.clone();
            for i in 0..n {
                for j in 0..n {
                    vl[(i, j)] *= vals[j];
                }
            }
            assert!(av.dist_sq(&vl) < 1e-16 * (1.0 + a.norm_sq()) * n as f64, "n={n}");
            // V orthonormal
            let vtv = vecs.t_matmul(&vecs);
            assert!(vtv.dist_sq(&Mat::eye(n)) < 1e-18 * n as f64 * n as f64);
        }
    }

    #[test]
    fn eigenvalue_trace_invariant() {
        let mut rng = Rng::new(5);
        let a = random_sym(&mut rng, 8);
        let (vals, _) = sym_eigen(&a);
        let trace: f64 = (0..8).map(|i| a[(i, i)]).sum();
        assert!((vals.iter().sum::<f64>() - trace).abs() < 1e-9);
    }

    #[test]
    fn pinv_norm_on_known_matrix() {
        // W for a 2-node graph with weight 1/2: I−W = [[.5,-.5],[-.5,.5]],
        // eigenvalues {0, 1}; pinv has eigenvalue 1 on span{(1,-1)/√2}.
        let w = Mat::from_vec(2, 2, vec![0.5, 0.5, 0.5, 0.5]);
        let pn = PinvNorm::new(&w);
        // m = (1,-1)ᵀ lies in the nonzero eigenspace with λ(I−W)=1
        let m = Mat::from_vec(2, 1, vec![1.0, -1.0]);
        assert!((pn.norm_sq(&m) - 2.0).abs() < 1e-10);
        // consensual component is annihilated
        let ones = Mat::from_vec(2, 1, vec![1.0, 1.0]);
        assert!(pn.norm_sq(&ones).abs() < 1e-12);
    }

    #[test]
    fn spectrum_of_two_node_mixing() {
        let w = Mat::from_vec(2, 2, vec![0.5, 0.5, 0.5, 0.5]);
        let s = Spectrum::of_mixing(&w);
        assert!((s.w_eigs[0] - 1.0).abs() < 1e-12);
        assert!((s.lam_max - 1.0).abs() < 1e-12);
        assert!((s.lam_min_pos - 1.0).abs() < 1e-12);
        assert!((s.kappa_g() - 1.0).abs() < 1e-12);
    }
}
