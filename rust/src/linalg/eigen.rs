//! Symmetric eigensolver (cyclic Jacobi) and derived spectral utilities.
//!
//! Needed for Assumption 1 checks and the theory-driven parameter choices:
//! λ_max(I−W), λ_min⁺(I−W) (smallest *nonzero* eigenvalue), the network
//! condition number κ_g, and (I−W)† norms used by the potential function
//! Φᵏ in the convergence tests.

use super::matrix::Mat;

/// Full symmetric eigendecomposition via cyclic Jacobi rotations.
/// Returns eigenvalues sorted descending and the matching eigenvectors as
/// *columns* of the returned matrix. Suitable for the small (n ≤ a few
/// hundred) mixing matrices we work with.
pub fn sym_eigen(a: &Mat) -> (Vec<f64>, Mat) {
    assert_eq!(a.rows, a.cols, "sym_eigen needs a square matrix");
    let n = a.rows;
    let mut m = a.clone();
    let mut v = Mat::eye(n);
    let max_sweeps = 100;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() < 1e-14 * (1.0 + m.norm()) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                let t = if theta >= 0.0 {
                    1.0 / (theta + (1.0 + theta * theta).sqrt())
                } else {
                    -1.0 / (-theta + (1.0 + theta * theta).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                // rotate rows/cols p,q of m
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                // accumulate eigenvectors
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }
    let mut eig: Vec<(f64, usize)> = (0..n).map(|i| (m[(i, i)], i)).collect();
    eig.sort_by(|a, b| b.0.total_cmp(&a.0));
    let vals: Vec<f64> = eig.iter().map(|e| e.0).collect();
    let mut vecs = Mat::zeros(n, n);
    for (new_col, &(_, old_col)) in eig.iter().enumerate() {
        for k in 0..n {
            vecs[(k, new_col)] = v[(k, old_col)];
        }
    }
    (vals, vecs)
}

/// Spectral data of a mixing matrix W needed by the algorithms' theory.
#[derive(Clone, Debug)]
pub struct Spectrum {
    /// Eigenvalues of W, descending (λ₁ = 1 for a valid mixing matrix).
    pub w_eigs: Vec<f64>,
    /// λ_max(I − W) = 1 − λ_n(W).
    pub lam_max: f64,
    /// λ_min⁺(I − W): smallest nonzero eigenvalue = 1 − λ₂(W).
    pub lam_min_pos: f64,
}

impl Spectrum {
    pub fn of_mixing(w: &Mat) -> Spectrum {
        let (eigs, _) = sym_eigen(w);
        let n = eigs.len();
        let lam_max = 1.0 - eigs[n - 1];
        let lam_min_pos = 1.0 - eigs[1.min(n - 1)];
        Spectrum {
            w_eigs: eigs,
            lam_max,
            lam_min_pos,
        }
    }

    /// Network condition number κ_g = λ_max(I−W) / λ_min⁺(I−W).
    pub fn kappa_g(&self) -> f64 {
        self.lam_max / self.lam_min_pos
    }

    /// Spectral gap 1 − |λ₂| used by gossip-style analyses (Choco).
    pub fn spectral_gap(&self) -> f64 {
        let n = self.w_eigs.len();
        let rho = self.w_eigs[1.min(n - 1)]
            .abs()
            .max(self.w_eigs[n - 1].abs());
        1.0 - rho
    }
}

/// Spectral-edge estimates of a mixing operator, from power iteration.
///
/// The Jacobi solve behind [`Spectrum`] is O(n³) on a dense matrix; for the
/// sparse mixing operators of large-n sweeps we only ever need the two
/// spectral edges — λ₂(W) (the largest eigenvalue on 1⊥, giving
/// λ_min⁺(I−W)) and λ_n(W) (the smallest, giving λ_max(I−W)) — and both
/// fall out of matrix-free power iteration at O(nnz) per step.
#[derive(Clone, Copy, Debug)]
pub struct GapEstimate {
    /// λ₂(W): largest eigenvalue of W restricted to 1⊥.
    pub lambda2: f64,
    /// λ_n(W): smallest eigenvalue of W.
    pub lambda_min: f64,
    /// Power-iteration steps spent (both passes combined).
    pub iters: usize,
    /// Whether both passes hit their Rayleigh-quotient tolerance before
    /// exhausting the iteration budget. On near-degenerate edges (e.g. a
    /// ring's λ₂ − λ₃ ≈ 4π²/n² at large n) power iteration converges
    /// slowly; when false, treat λ₂ (and the derived κ_g) as approximate
    /// — callers that print these quantities should say so.
    pub converged: bool,
}

impl GapEstimate {
    /// λ_max(I − W) = 1 − λ_n(W).
    pub fn lam_max(&self) -> f64 {
        1.0 - self.lambda_min
    }

    /// λ_min⁺(I − W) = 1 − λ₂(W).
    pub fn lam_min_pos(&self) -> f64 {
        1.0 - self.lambda2
    }

    /// Network condition number κ_g = λ_max(I−W) / λ_min⁺(I−W).
    pub fn kappa_g(&self) -> f64 {
        self.lam_max() / self.lam_min_pos()
    }

    /// Spectral gap 1 − ρ with ρ = max(|λ₂|, |λ_n|).
    pub fn spectral_gap(&self) -> f64 {
        1.0 - self.lambda2.abs().max(self.lambda_min.abs())
    }
}

/// Power iteration for the dominant eigenvalue of a symmetric operator
/// `apply_b`, optionally deflating the all-ones direction each step.
/// Returns (Rayleigh-quotient estimate, iterations used, converged).
fn power_dominant(
    n: usize,
    mut apply_b: impl FnMut(&[f64], &mut [f64]),
    deflate_ones: bool,
    max_iters: usize,
    tol: f64,
    seed: u64,
) -> (f64, usize, bool) {
    use super::matrix::{vdot, vnorm, vsum};
    let mut rng = crate::util::rng::Rng::new(seed);
    let mut v = vec![0.0; n];
    rng.fill_normal(&mut v);
    let project = |v: &mut [f64]| {
        let mean = vsum(v) / v.len() as f64;
        v.iter_mut().for_each(|x| *x -= mean);
    };
    if deflate_ones {
        project(&mut v);
    }
    let norm = vnorm(&v).max(1e-300);
    v.iter_mut().for_each(|x| *x /= norm);
    let mut bv = vec![0.0; n];
    let mut lam = 0.0;
    let mut prev = f64::INFINITY;
    for it in 1..=max_iters {
        apply_b(&v, &mut bv);
        if deflate_ones {
            project(&mut bv);
        }
        lam = vdot(&v, &bv); // Rayleigh quotient (‖v‖ = 1)
        let norm = vnorm(&bv);
        if norm < 1e-300 {
            return (lam, it, true); // operator annihilated v: eigenvalue 0
        }
        for (vi, &b) in v.iter_mut().zip(&bv) {
            *vi = b / norm;
        }
        if (lam - prev).abs() <= tol * (1.0 + lam.abs()) {
            return (lam, it, true);
        }
        prev = lam;
    }
    (lam, max_iters, false)
}

/// Estimate both spectral edges of a symmetric mixing operator W (given as
/// `apply`: y = W·x) without a dense eigendecomposition:
///
/// - λ₂ from power iteration on (I+W)/2 with the 1-direction deflated —
///   all eigenvalues of (I+W)/2 lie in (0, 1], so the dominant remaining
///   mode is (1+λ₂)/2;
/// - λ_n from power iteration on (I−W)/2 — its spectrum is [0, 1) with the
///   consensus mode at 0, so the dominant mode is (1−λ_n)/2.
pub fn power_gap_estimate(
    n: usize,
    mut apply: impl FnMut(&[f64], &mut [f64]),
    max_iters: usize,
    tol: f64,
    seed: u64,
) -> GapEstimate {
    assert!(n >= 2, "gap estimate needs n >= 2");
    let (mu2, it2, conv2) = power_dominant(
        n,
        |x, y| {
            apply(x, y);
            for (yi, &xi) in y.iter_mut().zip(x) {
                *yi = 0.5 * (xi + *yi);
            }
        },
        true,
        max_iters,
        tol,
        seed,
    );
    let (mu_n, it_n, conv_n) = power_dominant(
        n,
        |x, y| {
            apply(x, y);
            for (yi, &xi) in y.iter_mut().zip(x) {
                *yi = 0.5 * (xi - *yi);
            }
        },
        true,
        max_iters,
        tol,
        seed ^ 0xA5A5_A5A5,
    );
    GapEstimate {
        lambda2: 2.0 * mu2 - 1.0,
        lambda_min: 1.0 - 2.0 * mu_n,
        iters: it2 + it_n,
        converged: conv2 && conv_n,
    }
}

/// ‖M‖²_{(I−W)†} = ⟨M, (I−W)† M⟩: the weighted norm of the dual variable in
/// the potential function Φᵏ. Computed via the eigendecomposition of W.
pub struct PinvNorm {
    vecs: Mat,          // eigenvectors of W (columns)
    inv_vals: Vec<f64>, // 1/λᵢ(I−W) for nonzero λ, else 0
}

impl PinvNorm {
    pub fn new(w: &Mat) -> PinvNorm {
        let (vals, vecs) = sym_eigen(w);
        let inv_vals: Vec<f64> = vals
            .iter()
            .map(|&lw| {
                let l = 1.0 - lw;
                if l.abs() < 1e-10 {
                    0.0
                } else {
                    1.0 / l
                }
            })
            .collect();
        PinvNorm { vecs, inv_vals }
    }

    /// ⟨M, (I−W)† M⟩ for an n×p matrix M.
    pub fn norm_sq(&self, m: &Mat) -> f64 {
        // project each column of M onto the eigenbasis: Y = Vᵀ M
        let y = self.vecs.t_matmul(m);
        let mut total = 0.0;
        for i in 0..y.rows {
            let wgt = self.inv_vals[i];
            if wgt == 0.0 {
                continue;
            }
            let row = y.row(i);
            total += wgt * super::matrix::vnorm_sq(row);
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_sym(rng: &mut Rng, n: usize) -> Mat {
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let v = rng.normal();
                a[(i, j)] = v;
                a[(j, i)] = v;
            }
        }
        a
    }

    #[test]
    fn diagonal_matrix_eigs() {
        let mut a = Mat::zeros(3, 3);
        a[(0, 0)] = 3.0;
        a[(1, 1)] = -1.0;
        a[(2, 2)] = 2.0;
        let (vals, _) = sym_eigen(&a);
        assert!((vals[0] - 3.0).abs() < 1e-10);
        assert!((vals[1] - 2.0).abs() < 1e-10);
        assert!((vals[2] + 1.0).abs() < 1e-10);
    }

    #[test]
    fn eigen_reconstruction() {
        let mut rng = Rng::new(4);
        for n in [2, 5, 10, 20] {
            let a = random_sym(&mut rng, n);
            let (vals, vecs) = sym_eigen(&a);
            // A V = V Λ
            let av = a.matmul(&vecs);
            let mut vl = vecs.clone();
            for i in 0..n {
                for j in 0..n {
                    vl[(i, j)] *= vals[j];
                }
            }
            assert!(av.dist_sq(&vl) < 1e-16 * (1.0 + a.norm_sq()) * n as f64, "n={n}");
            // V orthonormal
            let vtv = vecs.t_matmul(&vecs);
            assert!(vtv.dist_sq(&Mat::eye(n)) < 1e-18 * n as f64 * n as f64);
        }
    }

    #[test]
    fn eigenvalue_trace_invariant() {
        let mut rng = Rng::new(5);
        let a = random_sym(&mut rng, 8);
        let (vals, _) = sym_eigen(&a);
        let trace: f64 = (0..8).map(|i| a[(i, i)]).sum();
        assert!((vals.iter().sum::<f64>() - trace).abs() < 1e-9);
    }

    #[test]
    fn pinv_norm_on_known_matrix() {
        // W for a 2-node graph with weight 1/2: I−W = [[.5,-.5],[-.5,.5]],
        // eigenvalues {0, 1}; pinv has eigenvalue 1 on span{(1,-1)/√2}.
        let w = Mat::from_vec(2, 2, vec![0.5, 0.5, 0.5, 0.5]);
        let pn = PinvNorm::new(&w);
        // m = (1,-1)ᵀ lies in the nonzero eigenspace with λ(I−W)=1
        let m = Mat::from_vec(2, 1, vec![1.0, -1.0]);
        assert!((pn.norm_sq(&m) - 2.0).abs() < 1e-10);
        // consensual component is annihilated
        let ones = Mat::from_vec(2, 1, vec![1.0, 1.0]);
        assert!(pn.norm_sq(&ones).abs() < 1e-12);
    }

    #[test]
    fn power_gap_matches_jacobi_on_mixing_matrices() {
        use crate::graph::{mixing_matrix, Graph, MixingRule};
        let mut rng = Rng::new(9);
        let graphs = [
            Graph::ring(8),
            Graph::ring(9),
            Graph::chain(7),
            Graph::star(6),
            Graph::complete(5),
            Graph::grid(9),
            Graph::erdos_renyi(12, 0.4, &mut rng),
        ];
        for g in &graphs {
            for rule in
                [MixingRule::UniformMaxDegree, MixingRule::Metropolis, MixingRule::LazyMetropolis]
            {
                let w = mixing_matrix(g, rule);
                let spec = Spectrum::of_mixing(&w);
                let est = power_gap_estimate(
                    g.n,
                    |x, y| {
                        for (i, yi) in y.iter_mut().enumerate() {
                            *yi = crate::linalg::matrix::vdot(w.row(i), x);
                        }
                    },
                    50_000,
                    1e-14,
                    11,
                );
                let lam2 = spec.w_eigs[1];
                let lam_n = *spec.w_eigs.last().unwrap();
                assert!(
                    (est.lambda2 - lam2).abs() < 1e-6,
                    "λ₂ n={} {rule:?}: {} vs {lam2}",
                    g.n,
                    est.lambda2
                );
                assert!(
                    (est.lambda_min - lam_n).abs() < 1e-6,
                    "λ_n n={} {rule:?}: {} vs {lam_n}",
                    g.n,
                    est.lambda_min
                );
                assert!((est.kappa_g() - spec.kappa_g()).abs() < 1e-4 * spec.kappa_g());
            }
        }
    }

    #[test]
    fn power_gap_on_ring_is_analytic() {
        // ring-1/3: eigenvalues (1 + 2cos(2πk/n))/3
        use crate::graph::{mixing_matrix, Graph, MixingRule};
        let n = 16;
        let w = mixing_matrix(&Graph::ring(n), MixingRule::UniformMaxDegree);
        let est = power_gap_estimate(
            n,
            |x, y| {
                for (i, yi) in y.iter_mut().enumerate() {
                    *yi = crate::linalg::matrix::vdot(w.row(i), x);
                }
            },
            50_000,
            1e-14,
            3,
        );
        let lam2 = (1.0 + 2.0 * (2.0 * std::f64::consts::PI / n as f64).cos()) / 3.0;
        assert!((est.lambda2 - lam2).abs() < 1e-7, "{} vs {lam2}", est.lambda2);
        assert!((est.lambda_min - (-1.0 / 3.0)).abs() < 1e-7, "{}", est.lambda_min);
    }

    #[test]
    fn spectrum_of_two_node_mixing() {
        let w = Mat::from_vec(2, 2, vec![0.5, 0.5, 0.5, 0.5]);
        let s = Spectrum::of_mixing(&w);
        assert!((s.w_eigs[0] - 1.0).abs() < 1e-12);
        assert!((s.lam_max - 1.0).abs() < 1e-12);
        assert!((s.lam_min_pos - 1.0).abs() < 1e-12);
        assert!((s.kappa_g() - 1.0).abs() < 1e-12);
    }
}
