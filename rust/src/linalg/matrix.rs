//! Dense row-major matrix type and the vector/matrix operations used by the
//! decentralized algorithms. Algorithm state is an n×p matrix `X` whose row
//! i is node i's local iterate (the paper's compact notation).
//!
//! The hot operation is the blocked matmul in [`Mat::matmul`], tuned in the
//! performance pass (see EXPERIMENTS.md §Perf): i-k-j loop order with a
//! cache-blocked k dimension vectorizes well under LLVM's auto-vectorizer.
//! Its inner accumulation — and the inner loop of every other
//! order-sensitive kernel in the crate (transposed matmul, CSR SpMM, the
//! coordinator's node-side mixes) — is the one fixed-width chunked
//! [`vaxpy`], so bit-exactness between all those paths is enforced
//! structurally rather than by parallel-maintained loops.

use std::fmt;
use std::ops::{Add, AddAssign, Mul, Sub, SubAssign};

/// Dense row-major f64 matrix.
#[derive(Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(6) {
            let row: Vec<String> = (0..self.cols.min(8))
                .map(|j| format!("{:9.4}", self[(i, j)]))
                .collect();
            writeln!(f, "  {}{}", row.join(" "), if self.cols > 8 { " …" } else { "" })?;
        }
        if self.rows > 6 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Mat {
        assert_eq!(data.len(), rows * cols, "data length mismatch");
        Mat { rows, cols, data }
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Mat {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Matrix with every entry `v`.
    pub fn full(rows: usize, cols: usize, v: f64) -> Mat {
        Mat {
            rows,
            cols,
            data: vec![v; rows * cols],
        }
    }

    /// n×p matrix whose every row is `row` (the consensual matrix 1 xᵀ).
    pub fn broadcast_row(n: usize, row: &[f64]) -> Mat {
        let mut m = Mat::zeros(n, row.len());
        for i in 0..n {
            m.row_mut(i).copy_from_slice(row);
        }
        m
    }

    #[inline(always)]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline(always)]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// C = A · B, cache-blocked ikj kernel. Hot path of the matrix engine.
    pub fn matmul(&self, other: &Mat) -> Mat {
        let mut out = Mat::zeros(self.rows, other.cols);
        self.matmul_into(other, &mut out);
        out
    }

    /// C = A · B writing into a preallocated output (hot loop avoids alloc).
    pub fn matmul_into(&self, other: &Mat, out: &mut Mat) {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        assert_eq!(out.rows, self.rows);
        assert_eq!(out.cols, other.cols);
        out.data.iter_mut().for_each(|x| *x = 0.0);
        let (n, k_dim, m) = (self.rows, self.cols, other.cols);
        const KB: usize = 64; // k-blocking: keeps B panel rows in L1
        for kb in (0..k_dim).step_by(KB) {
            let kend = (kb + KB).min(k_dim);
            for i in 0..n {
                let a_row = &self.data[i * k_dim..(i + 1) * k_dim];
                let out_row = &mut out.data[i * m..(i + 1) * m];
                for k in kb..kend {
                    let a = a_row[k];
                    if a == 0.0 {
                        continue;
                    }
                    let b_row = &other.data[k * m..(k + 1) * m];
                    vaxpy(out_row, a, b_row);
                }
            }
        }
    }

    /// C = Aᵀ · B without materializing Aᵀ (gradient hot path AᵀΔ).
    pub fn t_matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows, "t_matmul shape mismatch");
        let (k_dim, n, m) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(n, m);
        for k in 0..k_dim {
            let a_row = &self.data[k * n..(k + 1) * n];
            let b_row = &other.data[k * m..(k + 1) * m];
            for i in 0..n {
                let a = a_row[i];
                if a == 0.0 {
                    continue;
                }
                let out_row = &mut out.data[i * m..(i + 1) * m];
                vaxpy(out_row, a, b_row);
            }
        }
        out
    }

    /// Frobenius norm squared ‖A‖²_F, via the pinned [`vnorm_sq`] kernel.
    pub fn norm_sq(&self) -> f64 {
        vnorm_sq(&self.data)
    }

    pub fn norm(&self) -> f64 {
        self.norm_sq().sqrt()
    }

    /// ⟨A, B⟩ Frobenius inner product, via the pinned [`vdot`] kernel.
    pub fn dot(&self, other: &Mat) -> f64 {
        assert_eq!(self.data.len(), other.data.len());
        vdot(&self.data, &other.data)
    }

    /// ‖A − B‖²_F without allocating the difference, via [`vdist_sq`].
    pub fn dist_sq(&self, other: &Mat) -> f64 {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.cols, other.cols);
        vdist_sq(&self.data, &other.data)
    }

    /// self += alpha * other  (axpy), via the shared chunked [`vaxpy`].
    pub fn axpy(&mut self, alpha: f64, other: &Mat) {
        assert_eq!(self.data.len(), other.data.len());
        vaxpy(&mut self.data, alpha, &other.data);
    }

    /// self = alpha*self + beta*other.
    pub fn scale_add(&mut self, alpha: f64, beta: f64, other: &Mat) {
        assert_eq!(self.data.len(), other.data.len());
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a = alpha * *a + beta * b;
        }
    }

    pub fn scale(&mut self, alpha: f64) {
        self.data.iter_mut().for_each(|x| *x *= alpha);
    }

    pub fn map(&self, f: impl Fn(f64) -> f64) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Mean of the rows (the network-average iterate x̄).
    pub fn row_mean(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            for (o, &v) in out.iter_mut().zip(self.row(i)) {
                *o += v;
            }
        }
        let inv = 1.0 / self.rows as f64;
        out.iter_mut().for_each(|x| *x *= inv);
        out
    }

    /// Consensus error: Σᵢ ‖xᵢ − x̄‖².
    pub fn consensus_error(&self) -> f64 {
        let mean = self.row_mean();
        let mut err = 0.0;
        for i in 0..self.rows {
            for (j, &v) in self.row(i).iter().enumerate() {
                err += (v - mean[j]) * (v - mean[j]);
            }
        }
        err
    }

    pub fn max_abs(&self) -> f64 {
        vinf_norm(&self.data)
    }

    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline(always)]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline(always)]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl Add for &Mat {
    type Output = Mat;
    fn add(self, other: &Mat) -> Mat {
        let mut out = self.clone();
        out.axpy(1.0, other);
        out
    }
}

impl Sub for &Mat {
    type Output = Mat;
    fn sub(self, other: &Mat) -> Mat {
        let mut out = self.clone();
        out.axpy(-1.0, other);
        out
    }
}

impl AddAssign<&Mat> for Mat {
    fn add_assign(&mut self, other: &Mat) {
        self.axpy(1.0, other);
    }
}

impl SubAssign<&Mat> for Mat {
    fn sub_assign(&mut self, other: &Mat) {
        self.axpy(-1.0, other);
    }
}

impl Mul<f64> for &Mat {
    type Output = Mat;
    fn mul(self, s: f64) -> Mat {
        let mut out = self.clone();
        out.scale(s);
        out
    }
}

// --- vector helpers (free functions over &[f64]) ---------------------------

/// Σ aᵢ·bᵢ in ascending index order — the pinned dot-product reduction.
pub fn vdot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // lint:allow(parity-order): kernel definition — the one pinned-order dot
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Σ aᵢ² in ascending index order — the pinned squared-norm reduction.
pub fn vnorm_sq(a: &[f64]) -> f64 {
    // lint:allow(parity-order): kernel definition — the one pinned-order ‖·‖²
    a.iter().map(|x| x * x).sum()
}

/// Σ aᵢ in ascending index order — the pinned plain-sum reduction. Row-sum
/// and mean computations (mixing-matrix checks, spectral utilities) route
/// through here so every float reduction in the crate has one summation
/// order.
pub fn vsum(a: &[f64]) -> f64 {
    // lint:allow(parity-order): kernel definition — the one pinned-order Σ
    a.iter().sum()
}

pub fn vnorm(a: &[f64]) -> f64 {
    vnorm_sq(a).sqrt()
}

/// y += alpha·x — THE shared accumulation kernel. Every order-sensitive
/// hot loop in the crate (dense blocked ikj matmul, transposed matmul,
/// CSR SpMM, the coordinator's `WeightRow` mixes) funnels through this one
/// function, so the engine≡coordinator bit-exactness contract has a single
/// point of truth.
///
/// Fixed-width 8-lane chunks with a scalar remainder: a branch-free body
/// LLVM's auto-vectorizer maps onto packed mul/add. Each element still
/// performs exactly one `y[i] += alpha * x[i]` in ascending index order —
/// element operations are independent, so the chunking changes codegen,
/// never results: output stays bit-identical to the scalar loop.
#[inline]
pub fn vaxpy(y: &mut [f64], alpha: f64, x: &[f64]) {
    debug_assert_eq!(y.len(), x.len());
    const W: usize = 8;
    let mut yc = y.chunks_exact_mut(W);
    let mut xc = x.chunks_exact(W);
    for (ys, xs) in (&mut yc).zip(&mut xc) {
        for i in 0..W {
            ys[i] += alpha * xs[i];
        }
    }
    for (yi, &xi) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
        *yi += alpha * xi;
    }
}

pub fn vsub(a: &[f64], b: &[f64]) -> Vec<f64> {
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

/// Σ (aᵢ−bᵢ)² in ascending index order — the pinned distance reduction.
pub fn vdist_sq(a: &[f64], b: &[f64]) -> f64 {
    // lint:allow(parity-order): kernel definition — the one pinned-order dist²
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// max |aᵢ| scanned in ascending index order (order-insensitive, but pinned
/// anyway so ∞-norms share one code path).
pub fn vinf_norm(a: &[f64]) -> f64 {
    // lint:allow(parity-order): kernel definition — the one pinned-order max|·|
    a.iter().fold(0.0f64, |m, &x| m.max(x.abs()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::qc::{assert_prop, close_slices};
    use crate::util::rng::Rng;

    fn random_mat(rng: &mut Rng, r: usize, c: usize) -> Mat {
        let mut m = Mat::zeros(r, c);
        rng.fill_normal(&mut m.data);
        m
    }

    /// Naive triple-loop reference matmul for checking the blocked kernel.
    fn matmul_naive(a: &Mat, b: &Mat) -> Mat {
        let mut out = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a[(i, k)] * b[(k, j)];
                }
                out[(i, j)] = s;
            }
        }
        out
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(1);
        let a = random_mat(&mut rng, 5, 5);
        let i = Mat::eye(5);
        assert!(a.matmul(&i).dist_sq(&a) < 1e-24);
        assert!(i.matmul(&a).dist_sq(&a) < 1e-24);
    }

    #[test]
    fn matmul_matches_naive() {
        assert_prop("blocked-matmul == naive", 30, |g| {
            let mut rng = Rng::new(g.rng.next_u64());
            let (n, k, m) = (g.usize_in(1, 20), g.usize_in(1, 70), g.usize_in(1, 20));
            let a = random_mat(&mut rng, n, k);
            let b = random_mat(&mut rng, k, m);
            close_slices(&a.matmul(&b).data, &matmul_naive(&a, &b).data, 1e-10)
        });
    }

    #[test]
    fn t_matmul_matches_transpose() {
        assert_prop("t_matmul == transpose().matmul", 30, |g| {
            let mut rng = Rng::new(g.rng.next_u64());
            let (n, k, m) = (g.usize_in(1, 15), g.usize_in(1, 15), g.usize_in(1, 15));
            let a = random_mat(&mut rng, k, n);
            let b = random_mat(&mut rng, k, m);
            close_slices(&a.t_matmul(&b).data, &a.transpose().matmul(&b).data, 1e-10)
        });
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(2);
        let a = random_mat(&mut rng, 7, 3);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn norms_and_dot() {
        let a = Mat::from_vec(2, 2, vec![3.0, 0.0, 0.0, 4.0]);
        assert!((a.norm() - 5.0).abs() < 1e-12);
        let b = Mat::eye(2);
        assert!((a.dot(&b) - 7.0).abs() < 1e-12);
        assert!((a.dist_sq(&b) - (4.0 + 9.0)).abs() < 1e-12);
    }

    #[test]
    fn row_mean_and_consensus() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(a.row_mean(), vec![2.0, 3.0]);
        // consensus error = sum of squared deviations from mean
        assert!((a.consensus_error() - 4.0).abs() < 1e-12);
        let consensual = Mat::broadcast_row(4, &[1.0, -1.0]);
        assert!(consensual.consensus_error() < 1e-24);
    }

    #[test]
    fn axpy_and_scale_add() {
        let mut a = Mat::full(2, 2, 1.0);
        let b = Mat::full(2, 2, 2.0);
        a.axpy(0.5, &b);
        assert_eq!(a, Mat::full(2, 2, 2.0));
        a.scale_add(0.5, 1.0, &b);
        assert_eq!(a, Mat::full(2, 2, 3.0));
    }

    #[test]
    fn operators() {
        let a = Mat::full(2, 3, 2.0);
        let b = Mat::full(2, 3, 1.0);
        assert_eq!(&a + &b, Mat::full(2, 3, 3.0));
        assert_eq!(&a - &b, Mat::full(2, 3, 1.0));
        assert_eq!(&a * 2.0, Mat::full(2, 3, 4.0));
    }

    #[test]
    fn matmul_into_no_stale_data() {
        let a = Mat::eye(3);
        let b = Mat::full(3, 3, 2.0);
        let mut out = Mat::full(3, 3, 99.0);
        a.matmul_into(&b, &mut out);
        assert_eq!(out, b);
    }

    #[test]
    #[should_panic(expected = "matmul shape mismatch")]
    fn matmul_shape_check() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(2, 3);
        let _ = a.matmul(&b);
    }

    #[test]
    fn vector_helpers() {
        assert_eq!(vdot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        assert_eq!(vnorm(&[3.0, 4.0]), 5.0);
        assert_eq!(vinf_norm(&[-7.0, 2.0]), 7.0);
        assert_eq!(vdist_sq(&[1.0, 1.0], &[0.0, 0.0]), 2.0);
        let mut y = vec![1.0, 1.0];
        vaxpy(&mut y, 2.0, &[1.0, 2.0]);
        assert_eq!(y, vec![3.0, 5.0]);
    }

    #[test]
    fn broadcast_and_mean_roundtrip() {
        let row = vec![1.0, -2.0, 0.5];
        let m = Mat::broadcast_row(5, &row);
        assert_eq!(m.row_mean(), row);
    }
}
