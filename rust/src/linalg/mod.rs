//! Linear algebra substrate: the dense `Mat` type every algorithm's state
//! lives in, the CSR `SparseMat` behind O(nnz) gossip, a symmetric
//! eigensolver, and power-iteration spectral-edge estimation for mixing
//! operators too large to eigendecompose densely.

pub mod eigen;
pub mod matrix;
pub mod sparse;

pub use eigen::{power_gap_estimate, sym_eigen, GapEstimate, PinvNorm, Spectrum};
pub use matrix::{vaxpy, vdist_sq, vdot, vinf_norm, vnorm, vnorm_sq, vsub, vsum, Mat};
pub use sparse::SparseMat;
