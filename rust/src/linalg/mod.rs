//! Dense linear algebra substrate: the `Mat` type every algorithm's state
//! lives in, plus a symmetric eigensolver for spectral quantities of the
//! mixing matrix.

pub mod eigen;
pub mod matrix;

pub use eigen::{sym_eigen, PinvNorm, Spectrum};
pub use matrix::{vaxpy, vdist_sq, vdot, vinf_norm, vnorm, vnorm_sq, vsub, Mat};
