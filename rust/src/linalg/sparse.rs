//! Compressed-sparse-row matrix for O(nnz) gossip.
//!
//! The mixing matrices of every topology the paper sweeps (ring-1/3,
//! chains, grids, Metropolis-weighted Erdős–Rényi) have O(n) nonzeros, so
//! storing W densely makes every gossip round O(n²p). [`SparseMat`] is the
//! CSR substrate behind [`crate::graph::mixing::MixingOp`]: `apply_into`
//! is a row-major SpMM over a preallocated output, O(nnz·p) per round.
//!
//! **Exactness contract:** with column indices sorted ascending (guaranteed
//! by every constructor here), `apply_into` accumulates each output entry
//! in the *same order* as [`Mat::matmul_into`]'s blocked ikj kernel — for a
//! fixed output row the dense kernel also walks k ascending and skips
//! zeros — so sparse and dense products are **bit-identical**, not merely
//! close. The algorithms rely on this to keep sparse/dense iterate
//! sequences interchangeable (see `rust/tests/sparse_dense_equiv.rs`).

use super::matrix::{vaxpy, Mat};

/// Row-major CSR sparse f64 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseMat {
    pub rows: usize,
    pub cols: usize,
    /// `row_ptr[i]..row_ptr[i+1]` indexes row i's entries (len rows + 1).
    pub row_ptr: Vec<usize>,
    /// Column index per entry, ascending within each row.
    pub col_idx: Vec<usize>,
    pub vals: Vec<f64>,
}

impl SparseMat {
    /// Build from a dense matrix, keeping every nonzero entry plus the
    /// diagonal (stored even when 0.0, so in-place diagonal shifts like
    /// (I+W)/2 never need structural inserts).
    pub fn from_dense(m: &Mat) -> SparseMat {
        let mut row_ptr = Vec::with_capacity(m.rows + 1);
        let mut col_idx = Vec::new();
        let mut vals = Vec::new();
        row_ptr.push(0);
        for i in 0..m.rows {
            for (j, &v) in m.row(i).iter().enumerate() {
                if v != 0.0 || (j == i && i < m.cols) {
                    col_idx.push(j);
                    vals.push(v);
                }
            }
            row_ptr.push(col_idx.len());
        }
        SparseMat { rows: m.rows, cols: m.cols, row_ptr, col_idx, vals }
    }

    /// Build from per-row (column, value) lists. Each row must be sorted by
    /// column, in-range, and duplicate-free.
    pub fn from_rows(rows: usize, cols: usize, entries: &[Vec<(usize, f64)>]) -> SparseMat {
        assert_eq!(entries.len(), rows, "row count mismatch");
        let mut row_ptr = Vec::with_capacity(rows + 1);
        let mut col_idx = Vec::new();
        let mut vals = Vec::new();
        row_ptr.push(0);
        for row in entries {
            let mut last: Option<usize> = None;
            for &(j, v) in row {
                assert!(j < cols, "column {j} out of range ({cols})");
                if let Some(l) = last {
                    assert!(l < j, "columns not strictly ascending");
                }
                last = Some(j);
                col_idx.push(j);
                vals.push(v);
            }
            row_ptr.push(col_idx.len());
        }
        SparseMat { rows, cols, row_ptr, col_idx, vals }
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Fraction of entries stored: nnz / (rows·cols).
    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.rows * self.cols).max(1) as f64
    }

    /// Entry (i, j), 0.0 when not stored. O(log nnz_row).
    pub fn get(&self, i: usize, j: usize) -> f64 {
        let (lo, hi) = (self.row_ptr[i], self.row_ptr[i + 1]);
        match self.col_idx[lo..hi].binary_search(&j) {
            Ok(k) => self.vals[lo + k],
            Err(_) => 0.0,
        }
    }

    /// Iterate row i's stored (column, value) pairs, ascending column.
    pub fn row_iter(&self, i: usize) -> impl Iterator<Item = (usize, f64)> + '_ {
        let (lo, hi) = (self.row_ptr[i], self.row_ptr[i + 1]);
        self.col_idx[lo..hi].iter().copied().zip(self.vals[lo..hi].iter().copied())
    }

    /// out = S · X, row-major SpMM with buffer reuse (no allocation).
    /// Accumulation order per output entry matches [`Mat::matmul_into`]
    /// exactly — see the module docs' exactness contract.
    pub fn apply_into(&self, x: &Mat, out: &mut Mat) {
        assert_eq!(self.cols, x.rows, "spmm shape mismatch");
        assert_eq!(out.rows, self.rows);
        assert_eq!(out.cols, x.cols);
        let m = x.cols;
        out.data.iter_mut().for_each(|v| *v = 0.0);
        for i in 0..self.rows {
            let out_row = &mut out.data[i * m..(i + 1) * m];
            for idx in self.row_ptr[i]..self.row_ptr[i + 1] {
                let a = self.vals[idx];
                if a == 0.0 {
                    continue; // mirror the dense kernel's zero skip
                }
                let k = self.col_idx[idx];
                let x_row = &x.data[k * m..(k + 1) * m];
                // the shared chunked kernel: same per-element order as the
                // dense ikj matmul, so the bitwise contract holds
                vaxpy(out_row, a, x_row);
            }
        }
    }

    /// Allocating convenience wrapper over [`SparseMat::apply_into`].
    pub fn apply(&self, x: &Mat) -> Mat {
        let mut out = Mat::zeros(self.rows, x.cols);
        self.apply_into(x, &mut out);
        out
    }

    /// y = S · x for a single vector (the power-iteration hot loop).
    pub fn apply_vec(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for (i, yi) in y.iter_mut().enumerate() {
            let mut acc = 0.0;
            for idx in self.row_ptr[i]..self.row_ptr[i + 1] {
                let a = self.vals[idx];
                if a == 0.0 {
                    continue;
                }
                acc += a * x[self.col_idx[idx]];
            }
            *yi = acc;
        }
    }

    /// Scale every stored value in place.
    pub fn scale(&mut self, s: f64) {
        self.vals.iter_mut().for_each(|v| *v *= s);
    }

    /// Add `c` to every diagonal entry. The diagonal must be stored (all
    /// constructors in this crate guarantee it for square matrices).
    pub fn add_to_diag(&mut self, c: f64) {
        assert_eq!(self.rows, self.cols, "add_to_diag needs a square matrix");
        for i in 0..self.rows {
            let (lo, hi) = (self.row_ptr[i], self.row_ptr[i + 1]);
            match self.col_idx[lo..hi].binary_search(&i) {
                Ok(k) => self.vals[lo + k] += c,
                Err(_) => panic!("diagonal entry ({i},{i}) not stored"),
            }
        }
    }

    /// Materialize back to dense (tests, validation, eigensolves).
    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            for (j, v) in self.row_iter(i) {
                m[(i, j)] = v;
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::qc::assert_prop;
    use crate::util::rng::Rng;

    /// Random sparse square matrix with ~`fill` density plus full diagonal.
    fn random_sparse(rng: &mut Rng, n: usize, fill: f64) -> SparseMat {
        let mut rows = Vec::with_capacity(n);
        for i in 0..n {
            let mut row = Vec::new();
            for j in 0..n {
                if j == i || rng.bernoulli(fill) {
                    row.push((j, rng.normal()));
                }
            }
            rows.push(row);
        }
        SparseMat::from_rows(n, n, &rows)
    }

    #[test]
    fn from_dense_roundtrips() {
        let mut rng = Rng::new(1);
        let mut d = Mat::zeros(6, 6);
        for _ in 0..10 {
            d[(rng.below(6), rng.below(6))] = rng.normal();
        }
        let s = SparseMat::from_dense(&d);
        assert_eq!(s.to_dense(), d);
        // diagonal is always stored, even when zero
        assert!(s.nnz() >= 6);
        for i in 0..6 {
            assert!(s.col_idx[s.row_ptr[i]..s.row_ptr[i + 1]].contains(&i));
        }
    }

    #[test]
    fn apply_into_bitwise_matches_dense_matmul() {
        assert_prop("spmm == blocked matmul (bitwise)", 30, |g| {
            let mut rng = Rng::new(g.rng.next_u64());
            let n = g.usize_in(1, 90); // spans the dense kernel's KB=64 block
            let p = g.usize_in(1, 12);
            let s = random_sparse(&mut rng, n, 0.15);
            let d = s.to_dense();
            let mut x = Mat::zeros(n, p);
            rng.fill_normal(&mut x.data);
            let mut dense_out = Mat::zeros(n, p);
            d.matmul_into(&x, &mut dense_out);
            let mut sparse_out = Mat::full(n, p, f64::NAN); // must be fully overwritten
            s.apply_into(&x, &mut sparse_out);
            for (i, (a, b)) in dense_out.data.iter().zip(&sparse_out.data).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("entry {i}: {a:?} vs {b:?} differ in bits"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn apply_vec_matches_apply() {
        let mut rng = Rng::new(3);
        let s = random_sparse(&mut rng, 20, 0.2);
        let x: Vec<f64> = (0..20).map(|_| rng.normal()).collect();
        let mut y = vec![0.0; 20];
        s.apply_vec(&x, &mut y);
        let xm = Mat::from_vec(20, 1, x);
        let ym = s.apply(&xm);
        assert_eq!(y, ym.data);
    }

    #[test]
    fn get_and_row_iter_agree() {
        let mut rng = Rng::new(5);
        let s = random_sparse(&mut rng, 12, 0.3);
        for i in 0..12 {
            for (j, v) in s.row_iter(i) {
                assert_eq!(s.get(i, j), v);
            }
            assert_eq!(s.get(i, (i + 1) % 12), s.to_dense()[(i, (i + 1) % 12)]);
        }
    }

    #[test]
    fn scale_and_diag_shift() {
        let mut rng = Rng::new(7);
        let mut s = random_sparse(&mut rng, 8, 0.2);
        let mut d = s.to_dense();
        s.scale(0.5);
        s.add_to_diag(0.5);
        d.scale(0.5);
        for i in 0..8 {
            d[(i, i)] += 0.5;
        }
        assert_eq!(s.to_dense(), d);
    }

    #[test]
    #[should_panic(expected = "columns not strictly ascending")]
    fn rejects_unsorted_rows() {
        let _ = SparseMat::from_rows(1, 3, &[vec![(2, 1.0), (0, 1.0)]]);
    }

    #[test]
    fn density_counts_stored_entries() {
        let s = SparseMat::from_rows(2, 2, &[vec![(0, 1.0)], vec![(1, 1.0)]]);
        assert_eq!(s.nnz(), 2);
        assert!((s.density() - 0.5).abs() < 1e-15);
    }
}
