//! Configuration system for the launcher: `key = value` files (INI-like,
//! `#` comments) merged with `--key value` command-line overrides, so a
//! training run is reproducible from one small text file.

use crate::compress::{Compressor, Identity, InfNormQuantizer, L2NormQuantizer, RandK, TopK};
use crate::coordinator::WireCodec;
use crate::graph::{Graph, MixingRule, Topology};
use crate::oracle::OracleKind;
use crate::prox::{ElasticNet, Prox, Zero, L1};
use crate::util::rng::Rng;
use std::collections::BTreeMap;
use std::fmt;

/// All knobs of a training/experiment run, with §5-faithful defaults.
#[derive(Clone, Debug, PartialEq)]
pub struct Config {
    // problem
    /// Problem family (`problem` key): `logreg` (§5 workload),
    /// `least-squares` (Table 3 quadratic suite), `lasso` (k-sparse
    /// regression). Resolved by [`Config::problem_kind`]; the single
    /// construction path is `exp::build_problem`.
    pub problem: String,
    pub nodes: usize,
    pub samples_per_node: usize,
    pub dim: usize,
    pub classes: usize,
    pub batches: usize,
    pub lambda1: f64,
    pub lambda2: f64,
    pub separation: f64,
    pub shuffled: bool,
    // network
    pub topology: String,
    pub mixing: String,
    /// Erdős–Rényi edge probability (config keys `connectivity` /
    /// `er_prob`); 0 ⇒ auto 2·ln(n)/n, just above the connectivity
    /// threshold, so a `nodes` axis can sweep ER graphs without retuning.
    pub er_prob: f64,
    // algorithm
    pub algorithm: String,
    pub oracle: String,
    pub lsvrg_p: f64,
    /// Compression operator family: `inf` (eq. 21 ∞-norm quantizer),
    /// `l2` (QSGD-style 2-norm), `randk` / `topk` (sparsifiers keeping
    /// `sparsify_k` entries; `topk` is the biased ablation operator).
    pub compressor: String,
    pub bits: u32,
    pub block: usize,
    /// Entries kept by the `randk` / `topk` sparsifiers (0 ⇒ dim/8).
    pub sparsify_k: usize,
    pub eta: f64,
    pub alpha: f64,
    pub gamma: f64,
    // run
    pub rounds: usize,
    pub record_every: usize,
    pub seed: u64,
    /// Run backend: `engine` (synchronous matrix form), `coordinator`
    /// (one thread per node, real framed wire bytes), or `sim` (the
    /// event-driven massive-n simulator). A sweepable grid axis.
    pub backend: String,
    /// Compute kernel provider for the engine's matrix arithmetic:
    /// `native` (portable Rust kernels) or `xla` (PJRT-compiled gradient
    /// kernels; logreg only).
    pub compute: String,
    pub out: String,
    pub straggler_prob: f64,
    pub straggler_us: u64,
    // transport
    /// Coordinator byte-stream transport: `inproc` (node threads over
    /// checker-visible channels, the default), `tcp`, or `unix` (node
    /// *processes* over sockets — see DESIGN.md §4e). Non-inproc values
    /// require `backend = coordinator`. A sweepable grid axis.
    pub transport: String,
    /// Leader listen address, and what `proxlead node` dials: `host:port`
    /// for tcp, a filesystem path for unix. Ignored under inproc.
    pub bind: String,
    /// Total dial budget for `proxlead node` (bounded exponential backoff
    /// while the leader is still binding), milliseconds.
    pub connect_timeout_ms: u64,
}

impl Default for Config {
    fn default() -> Config {
        Config {
            problem: "logreg".into(),
            nodes: 8,
            samples_per_node: 240,
            dim: 64,
            classes: 10,
            batches: 15,
            lambda1: 5e-3,
            lambda2: 5e-3,
            separation: 1.0,
            shuffled: false,
            topology: "ring".into(),
            mixing: "uniform".into(),
            er_prob: 0.4,
            algorithm: "prox-lead".into(),
            oracle: "full".into(),
            lsvrg_p: 1.0 / 15.0,
            compressor: "inf".into(),
            bits: 2,
            block: 256,
            sparsify_k: 0,
            eta: 0.0, // 0 ⇒ auto: 1/(2L)
            alpha: 0.5,
            gamma: 1.0,
            rounds: 500,
            record_every: 10,
            seed: 42,
            backend: "engine".into(),
            compute: "native".into(),
            out: String::new(),
            straggler_prob: 0.0,
            straggler_us: 0,
            transport: "inproc".into(),
            bind: String::new(),
            connect_timeout_ms: 5000,
        }
    }
}

#[derive(Debug)]
pub struct ConfigError(pub String);

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config error: {}", self.0)
    }
}

impl std::error::Error for ConfigError {}

impl Config {
    /// Parse `key = value` lines (`#`/`;` comments, blank lines ok).
    pub fn parse(text: &str) -> Result<Config, ConfigError> {
        let mut cfg = Config::default();
        let mut map = BTreeMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.split(['#', ';']).next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| ConfigError(format!("line {}: expected key = value", lineno + 1)))?;
            map.insert(k.trim().to_string(), v.trim().to_string());
        }
        for (k, v) in map {
            cfg.set(&k, &v)?;
        }
        Ok(cfg)
    }

    pub fn from_file(path: &str) -> Result<Config, ConfigError> {
        let text =
            std::fs::read_to_string(path).map_err(|e| ConfigError(format!("{path}: {e}")))?;
        Config::parse(&text)
    }

    /// Apply one override (both file keys and CLI `--key value` route here).
    pub fn set(&mut self, key: &str, val: &str) -> Result<(), ConfigError> {
        fn p<T: std::str::FromStr>(key: &str, val: &str) -> Result<T, ConfigError> {
            val.parse()
                .map_err(|_| ConfigError(format!("bad value '{val}' for {key}")))
        }
        match key {
            "problem" => self.problem = val.into(),
            "nodes" => self.nodes = p(key, val)?,
            "samples_per_node" | "samples" => self.samples_per_node = p(key, val)?,
            "dim" => self.dim = p(key, val)?,
            "classes" => self.classes = p(key, val)?,
            "batches" => self.batches = p(key, val)?,
            "lambda1" | "l1" => self.lambda1 = p(key, val)?,
            "lambda2" | "l2" => self.lambda2 = p(key, val)?,
            "separation" => self.separation = p(key, val)?,
            "shuffled" => self.shuffled = p(key, val)?,
            "topology" => self.topology = val.into(),
            "mixing" => self.mixing = val.into(),
            "er_prob" | "connectivity" => self.er_prob = p(key, val)?,
            "algorithm" => self.algorithm = val.into(),
            "oracle" => self.oracle = val.into(),
            "lsvrg_p" => self.lsvrg_p = p(key, val)?,
            "compressor" => self.compressor = val.into(),
            "bits" => self.bits = p(key, val)?,
            "block" => self.block = p(key, val)?,
            "sparsify_k" => self.sparsify_k = p(key, val)?,
            "eta" => self.eta = p(key, val)?,
            "alpha" => self.alpha = p(key, val)?,
            "gamma" => self.gamma = p(key, val)?,
            "rounds" => self.rounds = p(key, val)?,
            "record_every" => self.record_every = p(key, val)?,
            "seed" => self.seed = p(key, val)?,
            "backend" => self.backend = val.into(),
            "compute" => self.compute = val.into(),
            "out" => self.out = val.into(),
            "straggler_prob" => self.straggler_prob = p(key, val)?,
            "straggler_us" => self.straggler_us = p(key, val)?,
            "transport" => self.transport = val.into(),
            "bind" => self.bind = val.into(),
            "connect_timeout_ms" => self.connect_timeout_ms = p(key, val)?,
            _ => return Err(ConfigError(format!("unknown key '{key}'"))),
        }
        Ok(())
    }

    // --- factories -------------------------------------------------------

    pub fn topology(&self) -> Result<Graph, ConfigError> {
        let mut rng = Rng::new(self.seed ^ 0x70_70);
        let kind: Topology = self.topology.parse().map_err(ConfigError)?;
        let n = self.nodes;
        match kind {
            Topology::Ring if n < 3 => {
                Err(ConfigError(format!("ring topology needs nodes >= 3 (got {n})")))
            }
            _ if n < 2 => Err(ConfigError(format!("topology needs nodes >= 2 (got {n})"))),
            Topology::ErdosRenyi => {
                // honor an explicit connectivity; 0 ⇒ the connectivity-safe
                // default 2·ln(n)/n, capped at 0.8
                if !(0.0..=1.0).contains(&self.er_prob) {
                    return Err(ConfigError(format!(
                        "connectivity must be in [0, 1] (0 = auto), got {}",
                        self.er_prob
                    )));
                }
                let prob =
                    if self.er_prob > 0.0 { self.er_prob } else { Graph::auto_er_prob(n) };
                // a clean error instead of the sampler's panic when every
                // draw comes up disconnected (prob far below ln(n)/n)
                Graph::try_erdos_renyi(n, prob, &mut rng, 1000).ok_or_else(|| {
                    ConfigError(format!(
                        "could not sample a connected er graph at connectivity {prob} \
                         (n = {n}; the threshold is ln(n)/n ≈ {:.4} — raise connectivity \
                         or use 0 for auto)",
                        (n as f64).ln() / n as f64
                    ))
                })
            }
            Topology::Grid => {
                // reject non-square n with a clear config error instead of
                // the library-level panic (Graph::grid asserts)
                let k = (n as f64).sqrt().floor() as usize;
                if k * k != n || k < 2 {
                    let hint = if k < 2 {
                        "smallest valid is 4".to_string()
                    } else {
                        format!("nearest squares are {} and {}", k * k, (k + 1) * (k + 1))
                    };
                    return Err(ConfigError(format!(
                        "grid topology needs a perfect square nodes >= 4 (got {n}; {hint})"
                    )));
                }
                Ok(Graph::build(kind, n, &mut rng))
            }
            kind => Ok(Graph::build(kind, n, &mut rng)),
        }
    }

    pub fn mixing_rule(&self) -> Result<MixingRule, ConfigError> {
        self.mixing.parse().map_err(ConfigError)
    }

    /// The problem family the `problem` key names.
    pub fn problem_kind(&self) -> Result<crate::problem::ProblemKind, ConfigError> {
        self.problem.parse().map_err(ConfigError)
    }

    pub fn oracle_kind(&self) -> Result<OracleKind, ConfigError> {
        Ok(match self.oracle.as_str() {
            "full" => OracleKind::Full,
            "sgd" => OracleKind::Sgd,
            "lsvrg" => OracleKind::Lsvrg { p: self.lsvrg_p },
            "saga" => OracleKind::Saga,
            o => return Err(ConfigError(format!("unknown oracle '{o}'"))),
        })
    }

    /// Compressor for the matrix engine. bits = 32/64 ⇒ dense identity
    /// (whatever the family); otherwise `compressor` picks the operator
    /// family at the given bit budget. The default sparsifier budget is
    /// derived from the logreg parameter dimension p = dim·classes; when
    /// the actual flattened dimension is known (an `exp::Experiment`
    /// resolves it from the built problem), use
    /// [`Config::compressor_for_dim`].
    pub fn compressor(&self) -> Result<Box<dyn Compressor>, ConfigError> {
        self.compressor_for_dim(self.dim * self.classes.max(1))
    }

    /// [`Config::compressor`] with the flattened parameter dimension `p`
    /// supplied by the caller (drives the `randk`/`topk` default budget
    /// k = p/8 when `sparsify_k` = 0).
    pub fn compressor_for_dim(&self, p: usize) -> Result<Box<dyn Compressor>, ConfigError> {
        match self.bits {
            64 => return Ok(Box::new(Identity::f64())),
            32 => return Ok(Box::new(Identity::f32())),
            b if (2..=16).contains(&b) => {}
            b => return Err(ConfigError(format!("bits must be 2..=16, 32 or 64 (got {b})"))),
        }
        // default sparsifier budget: an eighth of the parameter dimension
        let k = if self.sparsify_k > 0 { self.sparsify_k } else { (p / 8).max(1) };
        Ok(match self.compressor.as_str() {
            "inf" => Box::new(InfNormQuantizer::new(self.bits, self.block)),
            "l2" | "qsgd" => Box::new(L2NormQuantizer::new(self.bits, self.block)),
            "randk" | "rand-k" => Box::new(RandK::new(k)),
            "topk" | "top-k" => Box::new(TopK::new(k)),
            c => return Err(ConfigError(format!("unknown compressor family '{c}'"))),
        })
    }

    /// QSGD-style comparator at the same bit budget (ablations).
    pub fn l2_compressor(&self) -> Result<Box<dyn Compressor>, ConfigError> {
        match self.bits {
            b if (2..=16).contains(&b) => Ok(Box::new(L2NormQuantizer::new(b, self.block))),
            b => Err(ConfigError(format!("qsgd bits must be 2..=16 (got {b})"))),
        }
    }

    /// Wire codec for the message-passing coordinator.
    pub fn codec(&self) -> Result<WireCodec, ConfigError> {
        Ok(match self.bits {
            64 => WireCodec::Dense64,
            32 => WireCodec::Dense32,
            b if (2..=16).contains(&b) => WireCodec::Quant(b, self.block),
            b => return Err(ConfigError(format!("bits must be 2..=16, 32 or 64 (got {b})"))),
        })
    }

    /// The shared non-smooth term r(x).
    pub fn prox(&self) -> Box<dyn Prox> {
        if self.lambda1 > 0.0 {
            Box::new(L1::new(self.lambda1))
        } else {
            Box::new(Zero)
        }
    }

    /// Elastic-net variant (λ₂ handled proximally instead of smoothly).
    pub fn prox_elastic(&self) -> Box<dyn Prox> {
        Box::new(ElasticNet::new(self.lambda1, self.lambda2))
    }

    /// Spec for the regression generator behind the `least-squares` /
    /// `lasso` problem kinds. `sparsity` is the ground-truth support size
    /// (0 ⇒ dense x♯); the noise scale is fixed at the suite's 0.05.
    pub fn reg_spec(&self, sparsity: usize) -> crate::problem::data::RegSpec {
        crate::problem::data::RegSpec {
            nodes: self.nodes,
            samples_per_node: self.samples_per_node,
            dim: self.dim,
            sparsity,
            noise: 0.05,
            seed: self.seed,
        }
    }

    pub fn blob_spec(&self) -> crate::problem::data::BlobSpec {
        crate::problem::data::BlobSpec {
            nodes: self.nodes,
            samples_per_node: self.samples_per_node,
            dim: self.dim,
            classes: self.classes,
            separation: self.separation,
            noise: 1.0,
            partition: if self.shuffled {
                crate::problem::data::Partition::Shuffled
            } else {
                crate::problem::data::Partition::LabelSorted
            },
            seed: self.seed,
        }
    }

    /// Render back to the file format (round-trips through `parse`).
    pub fn to_text(&self) -> String {
        format!(
            "# prox-lead run configuration\n\
             problem = {}\n\
             nodes = {}\nsamples_per_node = {}\ndim = {}\nclasses = {}\nbatches = {}\n\
             lambda1 = {}\nlambda2 = {}\nseparation = {}\nshuffled = {}\n\
             topology = {}\nmixing = {}\ner_prob = {}\n\
             algorithm = {}\noracle = {}\nlsvrg_p = {}\n\
             compressor = {}\nbits = {}\nblock = {}\nsparsify_k = {}\n\
             eta = {}\nalpha = {}\ngamma = {}\n\
             rounds = {}\nrecord_every = {}\nseed = {}\nbackend = {}\ncompute = {}\nout = {}\n\
             straggler_prob = {}\nstraggler_us = {}\n\
             transport = {}\nbind = {}\nconnect_timeout_ms = {}\n",
            self.problem,
            self.nodes,
            self.samples_per_node,
            self.dim,
            self.classes,
            self.batches,
            self.lambda1,
            self.lambda2,
            self.separation,
            self.shuffled,
            self.topology,
            self.mixing,
            self.er_prob,
            self.algorithm,
            self.oracle,
            self.lsvrg_p,
            self.compressor,
            self.bits,
            self.block,
            self.sparsify_k,
            self.eta,
            self.alpha,
            self.gamma,
            self.rounds,
            self.record_every,
            self.seed,
            self.backend,
            self.compute,
            self.out,
            self.straggler_prob,
            self.straggler_us,
            self.transport,
            self.bind,
            self.connect_timeout_ms,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_paper_section5() {
        let c = Config::default();
        assert_eq!(c.nodes, 8);
        assert_eq!(c.batches, 15);
        assert_eq!(c.bits, 2);
        assert_eq!(c.block, 256);
        assert_eq!(c.alpha, 0.5);
        assert_eq!(c.gamma, 1.0);
        assert_eq!(c.topology, "ring");
    }

    #[test]
    fn parse_and_roundtrip() {
        let text = "nodes = 4\n# comment\nbits=8\noracle = saga ; trailing\n";
        let c = Config::parse(text).unwrap();
        assert_eq!(c.nodes, 4);
        assert_eq!(c.bits, 8);
        assert_eq!(c.oracle, "saga");
        let again = Config::parse(&c.to_text()).unwrap();
        assert_eq!(again, c);

        // every key non-default, so a key missing from to_text would show
        // up as a full-struct diff after the round-trip
        let mut all = Config::default();
        for (k, v) in [
            ("problem", "least-squares"),
            ("nodes", "6"),
            ("samples_per_node", "48"),
            ("dim", "12"),
            ("classes", "4"),
            ("batches", "6"),
            ("lambda1", "0.01"),
            ("lambda2", "0.02"),
            ("separation", "1.5"),
            ("shuffled", "true"),
            ("topology", "chain"),
            ("mixing", "mh"),
            ("connectivity", "0.6"),
            ("algorithm", "nids"),
            ("oracle", "saga"),
            ("lsvrg_p", "0.25"),
            ("compressor", "l2"),
            ("bits", "4"),
            ("block", "128"),
            ("sparsify_k", "9"),
            ("eta", "0.05"),
            ("alpha", "0.4"),
            ("gamma", "0.9"),
            ("rounds", "123"),
            ("record_every", "7"),
            ("seed", "99"),
            ("backend", "sim"),
            ("compute", "xla"),
            ("out", "run.json"),
            ("straggler_prob", "0.1"),
            ("straggler_us", "500"),
            ("transport", "tcp"),
            ("bind", "127.0.0.1:7070"),
            ("connect_timeout_ms", "250"),
        ] {
            all.set(k, v).unwrap();
        }
        let rendered = all.to_text();
        let reparsed = Config::parse(&rendered).unwrap();
        assert_eq!(reparsed, all, "Config::to_text must emit every key:\n{rendered}");
        assert_eq!(reparsed.to_text(), rendered);
    }

    #[test]
    fn rejects_unknown_keys_and_bad_values() {
        assert!(Config::parse("warp_drive = on").is_err());
        assert!(Config::parse("nodes = many").is_err());
        assert!(Config::parse("just a line").is_err());
    }

    #[test]
    fn problem_key_resolves_and_rejects_unknown() {
        use crate::problem::ProblemKind;
        let mut c = Config::default();
        assert_eq!(c.problem_kind().unwrap(), ProblemKind::LogReg);
        c.set("problem", "least-squares").unwrap();
        assert_eq!(c.problem_kind().unwrap(), ProblemKind::LeastSquares);
        c.set("problem", "lasso").unwrap();
        assert_eq!(c.problem_kind().unwrap(), ProblemKind::Lasso);
        c.set("problem", "sudoku").unwrap();
        assert!(c.problem_kind().is_err());
    }

    #[test]
    fn factories_resolve() {
        let mut c = Config::default();
        c.nodes = 6;
        let g = c.topology().unwrap();
        assert_eq!(g.n, 6);
        assert!(c.mixing_rule().is_ok());
        assert!(matches!(c.oracle_kind().unwrap(), OracleKind::Full));
        assert_eq!(c.compressor().unwrap().name(), "2bit");
        assert_eq!(c.codec().unwrap().name(), "2bit");
        c.bits = 32;
        assert_eq!(c.codec().unwrap(), WireCodec::Dense32);
        c.bits = 7;
        assert!(c.codec().is_ok());
        c.bits = 1;
        assert!(c.codec().is_err());
        // prox selection
        assert_eq!(c.prox().name(), "l1(0.005)");
        c.lambda1 = 0.0;
        assert!(c.prox().is_zero());
    }

    #[test]
    fn topology_factory_covers_chain_er_and_aliases() {
        let mut c = Config::default();
        c.nodes = 10;
        for (name, edges) in [("chain", 9), ("path", 9), ("ring", 10)] {
            c.topology = name.into();
            let g = c.topology().unwrap();
            assert_eq!(g.num_edges(), edges, "{name}");
            assert!(g.is_connected());
        }
        // er honors an explicit connectivity and resolves the `connectivity`
        // config key as an alias of er_prob
        c.set("connectivity", "0.5").unwrap();
        assert_eq!(c.er_prob, 0.5);
        for name in ["er", "erdos-renyi"] {
            c.topology = name.into();
            assert!(c.topology().unwrap().is_connected());
        }
        // connectivity = 0 ⇒ auto threshold 2·ln(n)/n
        c.er_prob = 0.0;
        assert!(c.topology().unwrap().is_connected());
        // same seed ⇒ same sampled graph
        assert_eq!(c.topology().unwrap().adj, c.topology().unwrap().adj);
        // out-of-range and hopelessly low connectivity are config errors,
        // not sampler panics
        c.er_prob = -0.3;
        assert!(c.topology().unwrap_err().0.contains("must be in [0, 1]"));
        c.er_prob = 5.0;
        assert!(c.topology().unwrap_err().0.contains("must be in [0, 1]"));
        c.er_prob = 0.01; // far below ln(10)/10 ≈ 0.23: every draw disconnected
        assert!(c.topology().unwrap_err().0.contains("could not sample"));
        // slightly sub-threshold values that still sample fine keep working
        c.er_prob = 0.2;
        assert!(c.topology().unwrap().is_connected());
    }

    #[test]
    fn grid_topology_requires_perfect_square() {
        let mut c = Config::default();
        c.topology = "grid".into();
        c.nodes = 9;
        assert!(c.topology().is_ok());
        c.nodes = 8;
        let err = c.topology().unwrap_err();
        assert!(err.0.contains("perfect square"), "{}", err.0);
        assert!(err.0.contains("4 and 9"), "should name nearest squares: {}", err.0);
        c.nodes = 3; // k = 1: the hint must not be a bogus "4 and 4"
        assert!(c.topology().unwrap_err().0.contains("smallest valid is 4"));
        c.nodes = 2; // 2 < 4: too small for a torus even though not square
        let err = c.topology().unwrap_err();
        assert!(err.0.contains("smallest valid is 4"), "{}", err.0);
        // tiny node counts error cleanly instead of panicking
        c.topology = "ring".into();
        assert!(c.topology().is_err());
        c.nodes = 1;
        c.topology = "chain".into();
        assert!(c.topology().is_err());
    }

    #[test]
    fn compressor_families_resolve() {
        let mut c = Config::default();
        c.bits = 4;
        c.compressor = "l2".into();
        assert!(c.compressor().unwrap().name().contains("4bit"));
        c.compressor = "randk".into();
        c.sparsify_k = 6;
        assert_eq!(c.compressor().unwrap().name(), "rand6");
        c.compressor = "topk".into();
        assert_eq!(c.compressor().unwrap().name(), "top6");
        // default sparsifier budget: p/8 = dim·classes/8
        c.sparsify_k = 0;
        assert_eq!(c.compressor().unwrap().name(), format!("top{}", 64 * 10 / 8));
        // dense bit-widths ignore the family; unknown families error
        c.bits = 32;
        assert_eq!(c.compressor().unwrap().name(), "32bit");
        c.bits = 2;
        c.compressor = "zip".into();
        assert!(c.compressor().is_err());
    }
}
