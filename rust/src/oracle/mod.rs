//! Stochastic gradient oracles — the paper's Table 1 (procedure SGO).
//!
//! Four estimators of ∇f_i(x):
//!
//! - [`OracleKind::Full`] — the deterministic gradient (σ² = 0);
//! - [`OracleKind::Sgd`] — one uniformly sampled batch gradient ∇f_il(x)
//!   (the general stochastic setting);
//! - [`OracleKind::Lsvrg`] — Loopless SVRG: per-node reference point x̃_i
//!   whose full gradient is cached; refreshed with Bernoulli(p) coin flips;
//! - [`OracleKind::Saga`] — per-node table of m batch gradients at the m
//!   reference points x̃_ij, with an incrementally maintained table mean.
//!
//! Every sample draw reports its cost in *batch-gradient evaluations* so
//! the figures' "number of gradient evaluations" axes are exact: full = m,
//! SGD = 1, LSVRG = 2 (+m on refresh), SAGA = 1 (+m·n once at init).

use crate::linalg::Mat;
use crate::problem::Problem;
use crate::util::rng::Rng;

/// Which estimator the SGO uses.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum OracleKind {
    Full,
    Sgd,
    /// Loopless SVRG with reference-refresh probability p (paper suggests
    /// p = 1/m to balance computation).
    Lsvrg { p: f64 },
    Saga,
}

impl OracleKind {
    pub fn name(&self) -> String {
        match self {
            OracleKind::Full => "full".into(),
            OracleKind::Sgd => "sgd".into(),
            OracleKind::Lsvrg { p } => format!("lsvrg(p={p})"),
            OracleKind::Saga => "saga".into(),
        }
    }
}

/// Per-node Loopless-SVRG state.
struct LsvrgState {
    ref_point: Vec<f64>,
    ref_grad: Vec<f64>, // ∇f_i(x̃_i), cached
}

/// Per-node SAGA state: gradient table (m × dim) and its running mean.
struct SagaState {
    table: Mat,
    mean: Vec<f64>,
}

enum NodeState {
    Stateless,
    Lsvrg(LsvrgState),
    Saga(SagaState),
}

/// The stochastic gradient oracle over all n nodes. Owns per-node
/// variance-reduction state and the sampling RNG; counts every
/// batch-gradient evaluation it performs.
pub struct Sgo {
    pub kind: OracleKind,
    states: Vec<NodeState>,
    rngs: Vec<Rng>,
    grad_evals: u64,
    scratch: Vec<f64>,
    /// When Some(i), this oracle serves only node i (a coordinator node
    /// thread); state vectors have length 1 and are indexed at 0.
    only: Option<usize>,
}

impl Sgo {
    /// Build the oracle, initializing VR state at `x0` (row i = node i's
    /// start point). LSVRG caches ∇f_i(x0); SAGA fills its table with the
    /// m batch gradients at x0. Both initializations are counted.
    pub fn new(kind: OracleKind, problem: &dyn Problem, x0: &Mat, seed: u64) -> Sgo {
        assert_eq!(x0.rows, problem.num_nodes());
        Sgo::build(kind, problem, x0, seed, None)
    }

    /// Single-node oracle for a coordinator node thread: VR state (and
    /// gradient-eval accounting) cover only `node`; `x0` is that node's
    /// start row. Seeded with the same `seed`, the stream equals the one
    /// [`Sgo::new`] hands node `node` — so a coordinator node thread and
    /// the matrix engine draw identical gradient samples.
    pub fn for_node(
        kind: OracleKind,
        problem: &dyn Problem,
        node: usize,
        x0: &[f64],
        seed: u64,
    ) -> Sgo {
        let x0m = Mat::from_rows(&[x0.to_vec()]);
        Sgo::build(kind, problem, &x0m, seed, Some(node))
    }

    fn build(
        kind: OracleKind,
        problem: &dyn Problem,
        x0: &Mat,
        seed: u64,
        only: Option<usize>,
    ) -> Sgo {
        let m = problem.num_batches();
        let dim = problem.dim();
        assert_eq!(x0.cols, dim);
        if let OracleKind::Lsvrg { p } = kind {
            assert!(p > 0.0 && p <= 1.0, "LSVRG refresh probability must be in (0,1]");
        }
        let node_ids: Vec<usize> = match only {
            Some(i) => vec![i],
            None => (0..problem.num_nodes()).collect(),
        };
        let mut root = Rng::new(seed);
        let rngs: Vec<Rng> = match only {
            // fork() advances the root once per call, so a single-node
            // oracle must skip the draws nodes 0..i would have consumed —
            // its stream then matches slot i of the all-nodes constructor
            // (the engine ≡ coordinator oracle-parity contract)
            Some(i) => {
                for _ in 0..i {
                    root.next_u64();
                }
                vec![root.fork(i as u64)]
            }
            None => node_ids.iter().map(|&i| root.fork(i as u64)).collect(),
        };
        let mut grad_evals = 0u64;
        let states: Vec<NodeState> = node_ids
            .iter()
            .enumerate()
            .map(|(_slot, &i)| {
                match kind {
                OracleKind::Full | OracleKind::Sgd => NodeState::Stateless,
                OracleKind::Lsvrg { .. } => {
                    let x0_row = if only.is_some() { 0 } else { i };
                    let ref_point = x0.row(x0_row).to_vec();
                    let mut ref_grad = vec![0.0; dim];
                    problem.grad(i, &ref_point, &mut ref_grad);
                    grad_evals += m as u64;
                    NodeState::Lsvrg(LsvrgState { ref_point, ref_grad })
                }
                OracleKind::Saga => {
                    let x0_row = if only.is_some() { 0 } else { i };
                    let mut table = Mat::zeros(m, dim);
                    let xi = x0.row(x0_row).to_vec();
                    for b in 0..m {
                        problem.grad_batch(i, b, &xi, table.row_mut(b));
                    }
                    grad_evals += m as u64;
                    let mean = table.row_mean();
                    NodeState::Saga(SagaState { table, mean })
                }
            }})
            .collect();
        Sgo {
            kind,
            states,
            rngs,
            grad_evals,
            scratch: vec![0.0; dim],
            only,
        }
    }

    /// Map a global node id to the local state slot.
    #[inline]
    fn slot(&self, node: usize) -> usize {
        match self.only {
            Some(i) => {
                assert_eq!(node, i, "single-node oracle asked for node {node}, owns {i}");
                0
            }
            None => node,
        }
    }

    /// Draw g_i ≈ ∇f_i(x) for node `node` into `out` (Table 1).
    pub fn sample(&mut self, problem: &dyn Problem, node: usize, x: &[f64], out: &mut [f64]) {
        let m = problem.num_batches();
        let slot = self.slot(node);
        match self.kind {
            OracleKind::Full => {
                problem.grad(node, x, out);
                self.grad_evals += m as u64;
            }
            OracleKind::Sgd => {
                let l = self.rngs[slot].below(m);
                problem.grad_batch(node, l, x, out);
                self.grad_evals += 1;
            }
            OracleKind::Lsvrg { p } => {
                let l = self.rngs[slot].below(m);
                let refresh = self.rngs[slot].bernoulli(p);
                let st = match &mut self.states[slot] {
                    NodeState::Lsvrg(s) => s,
                    _ => unreachable!(),
                };
                // g = ∇f_il(x) − ∇f_il(x̃) + ∇f_i(x̃)   (uniform: 1/(m·p_il) = 1)
                problem.grad_batch(node, l, x, out);
                problem.grad_batch(node, l, &st.ref_point, &mut self.scratch);
                self.grad_evals += 2;
                for ((o, &s), &r) in out.iter_mut().zip(&self.scratch).zip(&st.ref_grad) {
                    *o = *o - s + r;
                }
                if refresh {
                    st.ref_point.copy_from_slice(x);
                    problem.grad(node, &st.ref_point, &mut st.ref_grad);
                    self.grad_evals += m as u64;
                }
            }
            OracleKind::Saga => {
                let l = self.rngs[slot].below(m);
                let st = match &mut self.states[slot] {
                    NodeState::Saga(s) => s,
                    _ => unreachable!(),
                };
                // g = ∇f_il(x) − table[l] + mean(table)
                problem.grad_batch(node, l, x, &mut self.scratch);
                self.grad_evals += 1;
                let old = st.table.row(l);
                for (((o, &gnew), &gold), &mean) in out
                    .iter_mut()
                    .zip(&self.scratch)
                    .zip(old.iter())
                    .zip(&st.mean)
                {
                    *o = gnew - gold + mean;
                }
                // table[l] ← ∇f_il(x); mean updated incrementally
                let inv_m = 1.0 / m as f64;
                let row = st.table.row_mut(l);
                for ((mean, r), &gnew) in st.mean.iter_mut().zip(row.iter_mut()).zip(&self.scratch)
                {
                    *mean += (gnew - *r) * inv_m;
                    *r = gnew;
                }
            }
        }
    }

    /// Draw the whole stacked G (row i = g_i) into `out`.
    pub fn sample_all(&mut self, problem: &dyn Problem, x: &Mat, out: &mut Mat) {
        for i in 0..problem.num_nodes() {
            let xi = x.row(i).to_vec();
            self.sample(problem, i, &xi, out.row_mut(i));
        }
    }

    /// Total batch-gradient evaluations so far (including VR init).
    pub fn grad_evals(&self) -> u64 {
        self.grad_evals
    }

    pub fn name(&self) -> String {
        self.kind.name()
    }

    /// True when samples are the exact full gradient.
    pub fn is_exact(&self) -> bool {
        self.kind == OracleKind::Full
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problem::data::{blobs, BlobSpec};
    use crate::problem::LogReg;

    fn problem() -> LogReg {
        let spec = BlobSpec {
            nodes: 2,
            samples_per_node: 20,
            dim: 5,
            classes: 3,
            seed: 21,
            ..Default::default()
        };
        LogReg::new(blobs(&spec), 3, 1e-2, 4)
    }

    fn mean_sample(
        kind: OracleKind,
        problem: &LogReg,
        x: &Mat,
        node: usize,
        trials: usize,
    ) -> Vec<f64> {
        use crate::problem::Problem;
        let dim = problem.dim();
        let mut acc = vec![0.0; dim];
        for t in 0..trials {
            let mut o = Sgo::new(kind, problem, x, 1000 + t as u64);
            let mut g = vec![0.0; dim];
            let xi = x.row(node).to_vec();
            o.sample(problem, node, &xi, &mut g);
            for (a, &v) in acc.iter_mut().zip(&g) {
                *a += v;
            }
        }
        acc.iter_mut().for_each(|v| *v /= trials as f64);
        acc
    }

    #[test]
    fn all_oracles_unbiased() {
        use crate::problem::Problem;
        let p = problem();
        let mut x = Mat::zeros(2, p.dim());
        let mut rng = Rng::new(3);
        rng.fill_normal(&mut x.data);
        x.scale(0.3);
        let mut full = vec![0.0; p.dim()];
        let xi = x.row(0).to_vec();
        p.grad(0, &xi, &mut full);
        let fn_ = crate::linalg::matrix::vnorm(&full).max(1e-12);
        for kind in [
            OracleKind::Full,
            OracleKind::Sgd,
            OracleKind::Lsvrg { p: 0.25 },
            OracleKind::Saga,
        ] {
            let mean = mean_sample(kind, &p, &x, 0, 600);
            let err = crate::linalg::matrix::vdist_sq(&mean, &full).sqrt() / fn_;
            assert!(err < 0.12, "{} bias too large: {err}", kind.name());
        }
    }

    #[test]
    fn full_oracle_is_exact_every_draw() {
        use crate::problem::Problem;
        let p = problem();
        let x = Mat::zeros(2, p.dim());
        let mut o = Sgo::new(OracleKind::Full, &p, &x, 1);
        let mut g = vec![0.0; p.dim()];
        let mut full = vec![0.0; p.dim()];
        let xi = vec![0.0; p.dim()];
        o.sample(&p, 1, &xi, &mut g);
        p.grad(1, &xi, &mut full);
        assert_eq!(g, full);
        assert!(o.is_exact());
    }

    #[test]
    fn variance_reduction_shrinks_at_reference() {
        use crate::problem::Problem;
        // at x = x̃ (the init point), LSVRG/SAGA variance is exactly zero:
        // g = ∇f_il(x) − ∇f_il(x̃) + ∇f_i(x̃) = ∇f_i(x̃); SGD's is not.
        let p = problem();
        let mut x = Mat::zeros(2, p.dim());
        let mut rng = Rng::new(9);
        rng.fill_normal(&mut x.data);
        let xi = x.row(0).to_vec();
        let mut full = vec![0.0; p.dim()];
        p.grad(0, &xi, &mut full);

        let var_of = |kind: OracleKind| {
            let mut acc = 0.0;
            let trials = 100;
            for t in 0..trials {
                // p=0 refresh would be invalid; use tiny p and a fresh oracle
                let mut o = Sgo::new(kind, &p, &x, 50 + t);
                let mut g = vec![0.0; p.dim()];
                o.sample(&p, 0, &xi, &mut g);
                acc += crate::linalg::matrix::vdist_sq(&g, &full);
            }
            acc / trials as f64
        };

        assert!(var_of(OracleKind::Lsvrg { p: 0.01 }) < 1e-20);
        assert!(var_of(OracleKind::Saga) < 1e-20);
        assert!(var_of(OracleKind::Sgd) > 1e-6);
    }

    #[test]
    fn grad_eval_accounting() {
        use crate::problem::Problem;
        let p = problem(); // m = 4 batches, n = 2 nodes
        let x = Mat::zeros(2, p.dim());
        let mut g = vec![0.0; p.dim()];
        let xi = vec![0.0; p.dim()];

        let mut full = Sgo::new(OracleKind::Full, &p, &x, 1);
        full.sample(&p, 0, &xi, &mut g);
        assert_eq!(full.grad_evals(), 4); // one full = m

        let mut sgd = Sgo::new(OracleKind::Sgd, &p, &x, 1);
        sgd.sample(&p, 0, &xi, &mut g);
        assert_eq!(sgd.grad_evals(), 1);

        let saga = Sgo::new(OracleKind::Saga, &p, &x, 1);
        assert_eq!(saga.grad_evals(), 8); // init: m per node × 2 nodes

        let mut saga = saga;
        saga.sample(&p, 0, &xi, &mut g);
        assert_eq!(saga.grad_evals(), 9); // +1 per draw

        let lsvrg = Sgo::new(OracleKind::Lsvrg { p: 1e-12 }, &p, &x, 1);
        assert_eq!(lsvrg.grad_evals(), 8); // init full grad per node
        let mut lsvrg = lsvrg;
        lsvrg.sample(&p, 0, &xi, &mut g);
        assert_eq!(lsvrg.grad_evals(), 10); // +2 per draw (no refresh)
    }

    #[test]
    fn for_node_stream_matches_all_nodes_slot() {
        // the engine ≡ coordinator oracle-parity contract: a single-node
        // oracle seeded like the engine's draws the exact same samples the
        // all-nodes oracle hands that node — for every node slot
        use crate::problem::Problem;
        let p = problem(); // 2 nodes, m = 4
        let mut x = Mat::zeros(2, p.dim());
        Rng::new(4).fill_normal(&mut x.data);
        for kind in [OracleKind::Sgd, OracleKind::Saga, OracleKind::Lsvrg { p: 0.3 }] {
            for node in 0..2 {
                let mut all = Sgo::new(kind, &p, &x, 99);
                let mut solo = Sgo::for_node(kind, &p, node, x.row(node), 99);
                let xi = x.row(node).to_vec();
                let (mut ga, mut gs) = (vec![0.0; p.dim()], vec![0.0; p.dim()]);
                for draw in 0..20 {
                    all.sample(&p, node, &xi, &mut ga);
                    solo.sample(&p, node, &xi, &mut gs);
                    assert_eq!(ga, gs, "{} node {node} draw {draw}", kind.name());
                }
            }
        }
    }

    #[test]
    fn saga_table_mean_stays_consistent() {
        use crate::problem::Problem;
        let p = problem();
        let mut x = Mat::zeros(2, p.dim());
        let mut rng = Rng::new(31);
        rng.fill_normal(&mut x.data);
        let mut o = Sgo::new(OracleKind::Saga, &p, &x, 77);
        let mut g = vec![0.0; p.dim()];
        for step in 0..30 {
            let xi: Vec<f64> = x.row(0).iter().map(|&v| v * (1.0 - step as f64 * 0.01)).collect();
            o.sample(&p, 0, &xi, &mut g);
        }
        // invariant: stored mean equals the recomputed row mean of the table
        if let NodeState::Saga(st) = &o.states[0] {
            let m = st.table.rows as f64;
            for (j, &mean_j) in st.mean.iter().enumerate() {
                let col: f64 = (0..st.table.rows).map(|b| st.table[(b, j)]).sum::<f64>() / m;
                assert!((col - mean_j).abs() < 1e-10, "drift at {j}: {col} vs {mean_j}");
            }
        } else {
            panic!("expected saga state");
        }
    }
}
