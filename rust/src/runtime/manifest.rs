//! Artifact manifest — the contract between `python/compile/aot.py` and
//! the rust registry. One entry per lowered (fn, m, d, C, λ₂) artifact.

use super::{Result, RtError};
use crate::util::json::Json;
use std::path::Path;

#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    /// Which L2 function: `"logreg_grad"` or `"logreg_loss"`.
    pub fn_name: String,
    pub m: usize,
    pub d: usize,
    pub c: usize,
    pub lam2: f64,
}

#[derive(Clone, Debug, PartialEq)]
pub struct Manifest {
    pub format: String,
    pub dtype: String,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let root = Json::parse(text).map_err(|e| RtError(format!("manifest json: {e}")))?;
        let format = root
            .get("format")
            .and_then(|j| j.as_str())
            .ok_or_else(|| RtError("manifest missing 'format'".to_string()))?
            .to_string();
        if format != "hlo-text" {
            return Err(RtError(format!("unsupported artifact format '{format}'")));
        }
        let dtype = root
            .get("dtype")
            .and_then(|j| j.as_str())
            .unwrap_or("f32")
            .to_string();
        let arts = root
            .get("artifacts")
            .and_then(|j| j.as_arr())
            .ok_or_else(|| RtError("manifest missing 'artifacts'".to_string()))?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for a in arts {
            let str_field = |k: &str| -> Result<String> {
                a.get(k)
                    .and_then(|j| j.as_str())
                    .map(str::to_string)
                    .ok_or_else(|| RtError(format!("artifact missing '{k}'")))
            };
            let num_field = |k: &str| -> Result<usize> {
                a.get(k)
                    .and_then(|j| j.as_usize())
                    .ok_or_else(|| RtError(format!("artifact missing '{k}'")))
            };
            artifacts.push(ArtifactMeta {
                name: str_field("name")?,
                file: str_field("file")?,
                fn_name: str_field("fn")?,
                m: num_field("m")?,
                d: num_field("d")?,
                c: num_field("c")?,
                lam2: a.get("lam2").and_then(|j| j.as_f64()).unwrap_or(0.0),
            });
        }
        Ok(Manifest { format, dtype, artifacts })
    }

    pub fn read(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)?;
        Manifest::parse(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text", "dtype": "f32",
      "artifacts": [
        {"name": "logreg_grad_8x4x3_l0.01", "file": "g.hlo.txt",
         "fn": "logreg_grad", "m": 8, "d": 4, "c": 3, "lam2": 0.01}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = &m.artifacts[0];
        assert_eq!(a.fn_name, "logreg_grad");
        assert_eq!((a.m, a.d, a.c), (8, 4, 3));
        assert_eq!(a.lam2, 0.01);
    }

    #[test]
    fn rejects_unknown_format() {
        let bad = SAMPLE.replace("hlo-text", "proto");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn missing_fields_error() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"format":"hlo-text","artifacts":[{}]}"#).is_err());
    }
}
