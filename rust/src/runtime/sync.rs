//! The synchronization shim layer — every atomic, barrier, channel, and
//! thread spawn the sim backend and the coordinator use goes through this
//! module (DESIGN.md §6b, enforced by the `atomic-ordering` lint rule).
//!
//! In production the wrappers are transparent: one thread-local lookup per
//! operation (no allocation, no locking — the sim's zero-alloc round
//! contract holds), then the underlying `std` primitive. When the calling
//! thread is registered with an active [`crate::check`] scheduler — which
//! only scenario code sets up — every operation first announces itself as
//! a yield point, lets the scheduler pick the interleaving, and only then
//! performs the real operation while still holding the schedule token.
//! That serialization is what makes the model checker's happens-before
//! bookkeeping exact: real effects occur in exactly the modeled order.
//!
//! Design note (deviation from a `cfg`-gated shim): dispatch is by
//! thread-local registration at *runtime*, not compile-time `cfg`, so the
//! scenario suite runs under a plain `cargo test` / `cargo run --bin
//! check` with no custom `RUSTFLAGS` plumbing, and production binaries pay
//! only the thread-local check. See DESIGN.md §6b.
//!
//! The `Ordering` parameters are live in both modes: production code
//! states its real ordering (and the lint rule demands a justification
//! comment at every `Relaxed`/`SeqCst` call site outside this module),
//! while the checker uses the stated ordering to maintain release clocks,
//! so an unjustified downgrade shows up as a race finding in scenarios.
//!
//! **Scope note (DESIGN.md §4e):** only the *in-process* transport runs
//! through this shim. A socket-transport coordinator's uplink reader
//! threads (`transport::socket::run_uplink`) deliberately use plain
//! `std::thread` + `std::sync::mpsc`: their nondeterminism comes from
//! the kernel's socket scheduling, which the checker cannot enumerate —
//! that path is covered by the transport parity/kill tests and the CI
//! multi-process smoke job instead.

use std::collections::VecDeque;
use std::fmt;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

use crate::check::{self, AtomicKind, Op, YieldOutcome};

/// Does `ord` carry acquire semantics on a load/RMW?
fn acquires(ord: Ordering) -> bool {
    matches!(ord, Ordering::Acquire | Ordering::AcqRel | Ordering::SeqCst)
}

/// Does `ord` carry release semantics on a store/RMW?
fn releases(ord: Ordering) -> bool {
    matches!(ord, Ordering::Release | Ordering::AcqRel | Ordering::SeqCst)
}

fn hook_atomic(var: usize, site: &'static str, kind: AtomicKind, ord: Ordering) {
    if let Some(h) = check::active() {
        h.ck.yield_op(
            h.tid,
            Op::Atomic { var, site, kind, acquire: acquires(ord), release: releases(ord) },
        );
    }
}

// ---------------------------------------------------------------------------
// atomics

/// Shimmed [`std::sync::atomic::AtomicUsize`] with a site label for checker
/// diagnostics and lint accounting.
pub struct AtomicUsize {
    inner: std::sync::atomic::AtomicUsize,
    site: &'static str,
}

impl AtomicUsize {
    pub fn new(v: usize, site: &'static str) -> AtomicUsize {
        AtomicUsize { inner: std::sync::atomic::AtomicUsize::new(v), site }
    }

    fn var(&self) -> usize {
        &self.inner as *const std::sync::atomic::AtomicUsize as usize
    }

    pub fn load(&self, ord: Ordering) -> usize {
        hook_atomic(self.var(), self.site, AtomicKind::Load, ord);
        self.inner.load(ord)
    }

    pub fn store(&self, v: usize, ord: Ordering) {
        hook_atomic(self.var(), self.site, AtomicKind::Store, ord);
        self.inner.store(v, ord);
    }

    pub fn fetch_add(&self, v: usize, ord: Ordering) -> usize {
        hook_atomic(self.var(), self.site, AtomicKind::Rmw, ord);
        self.inner.fetch_add(v, ord)
    }
}

/// Shimmed [`std::sync::atomic::AtomicBool`]; `raise` is the idempotent
/// monotone flag-set (an RMW, so concurrent raises are atomicity-only and
/// not race-flagged by the checker).
pub struct AtomicBool {
    inner: std::sync::atomic::AtomicBool,
    site: &'static str,
}

impl AtomicBool {
    pub fn new(v: bool, site: &'static str) -> AtomicBool {
        AtomicBool { inner: std::sync::atomic::AtomicBool::new(v), site }
    }

    fn var(&self) -> usize {
        &self.inner as *const std::sync::atomic::AtomicBool as usize
    }

    pub fn load(&self, ord: Ordering) -> bool {
        hook_atomic(self.var(), self.site, AtomicKind::Load, ord);
        self.inner.load(ord)
    }

    pub fn store(&self, v: bool, ord: Ordering) {
        hook_atomic(self.var(), self.site, AtomicKind::Store, ord);
        self.inner.store(v, ord);
    }

    /// Set the flag to `true` via `fetch_or` — use for flags that several
    /// threads may raise concurrently (idempotent; RMW-vs-RMW pairs are
    /// exempt from the checker's race rule by design).
    pub fn raise(&self, ord: Ordering) {
        hook_atomic(self.var(), self.site, AtomicKind::Rmw, ord);
        self.inner.fetch_or(true, ord);
    }
}

// ---------------------------------------------------------------------------
// barrier

enum BarrierInner {
    Std(std::sync::Barrier),
    Chk { ck: Arc<check::Checker>, id: usize },
}

/// Shimmed [`std::sync::Barrier`]. `wait` returns `()` — the leader flag
/// is unused by every caller in this repo.
pub struct Barrier {
    inner: BarrierInner,
}

impl Barrier {
    pub fn new(arity: usize, site: &'static str) -> Barrier {
        match check::active() {
            Some(h) => {
                let id = h.ck.register_barrier(arity, site);
                Barrier { inner: BarrierInner::Chk { ck: h.ck, id } }
            }
            None => Barrier { inner: BarrierInner::Std(std::sync::Barrier::new(arity)) },
        }
    }

    pub fn wait(&self) {
        match &self.inner {
            BarrierInner::Std(b) => {
                b.wait();
            }
            BarrierInner::Chk { ck, id } => {
                let h = check::active()
                    .expect("checked barrier reached from a thread the checker never registered");
                assert!(
                    Arc::ptr_eq(&h.ck, ck),
                    "checked barrier crossed into a different checker's execution"
                );
                ck.yield_op(h.tid, Op::BarrierArrive { bar: *id });
            }
        }
    }
}

// ---------------------------------------------------------------------------
// channels

/// Error returned by [`Sender::send`] when the receiver is gone; carries
/// the unsent value like [`std::sync::mpsc::SendError`].
#[derive(Debug)]
pub struct SendError<T>(pub T);

impl<T> fmt::Display for SendError<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "sending on a closed channel")
    }
}

/// Error returned by [`Receiver::recv`] once every sender is dropped and
/// the queue is drained.
#[derive(Debug, PartialEq, Eq)]
pub struct RecvError;

impl fmt::Display for RecvError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "receiving on a closed channel")
    }
}

struct ChkCore<T> {
    /// Typed FIFO in lockstep with the scheduler's clock queue: both are
    /// only touched while holding the schedule token.
    q: Mutex<VecDeque<T>>,
    ck: Arc<check::Checker>,
    id: usize,
}

struct ChkSender<T> {
    core: Arc<ChkCore<T>>,
}

impl<T> Drop for ChkSender<T> {
    fn drop(&mut self) {
        // teardown is a visible event: a dropped sender may enable a
        // peer's disconnect-recv, so it yields (poison-tolerantly)
        match check::active() {
            Some(h) if Arc::ptr_eq(&h.ck, &self.core.ck) => {
                self.core.ck.yield_op_noexcept(h.tid, Op::ChanDropSender { ch: self.core.id });
            }
            _ => self.core.ck.detach_drop_sender(self.core.id),
        }
    }
}

struct ChkReceiver<T> {
    core: Arc<ChkCore<T>>,
}

impl<T> Drop for ChkReceiver<T> {
    fn drop(&mut self) {
        match check::active() {
            Some(h) if Arc::ptr_eq(&h.ck, &self.core.ck) => {
                self.core.ck.yield_op_noexcept(h.tid, Op::ChanDropReceiver { ch: self.core.id });
            }
            _ => self.core.ck.detach_drop_receiver(self.core.id),
        }
    }
}

enum SenderInner<T> {
    Std(mpsc::Sender<T>),
    Chk(ChkSender<T>),
}

/// Shimmed [`std::sync::mpsc::Sender`].
pub struct Sender<T>(SenderInner<T>);

impl<T> Sender<T> {
    pub fn send(&self, t: T) -> Result<(), SendError<T>> {
        match &self.0 {
            SenderInner::Std(tx) => tx.send(t).map_err(|e| SendError(e.0)),
            SenderInner::Chk(s) => {
                let h = check::active()
                    .expect("checked sender used from a thread the checker never registered");
                match s.core.ck.yield_op(h.tid, Op::ChanSend { ch: s.core.id }) {
                    YieldOutcome::Closed => Err(SendError(t)),
                    YieldOutcome::Proceed => {
                        s.core.q.lock().unwrap_or_else(|e| e.into_inner()).push_back(t);
                        Ok(())
                    }
                }
            }
        }
    }
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Sender<T> {
        match &self.0 {
            SenderInner::Std(tx) => Sender(SenderInner::Std(tx.clone())),
            SenderInner::Chk(s) => {
                s.core.ck.sender_cloned(s.core.id);
                Sender(SenderInner::Chk(ChkSender { core: s.core.clone() }))
            }
        }
    }
}

enum ReceiverInner<T> {
    Std(mpsc::Receiver<T>),
    Chk(ChkReceiver<T>),
}

/// Shimmed [`std::sync::mpsc::Receiver`] (blocking `recv` only — that is
/// the complete coordinator surface).
pub struct Receiver<T>(ReceiverInner<T>);

impl<T> Receiver<T> {
    pub fn recv(&self) -> Result<T, RecvError> {
        match &self.0 {
            ReceiverInner::Std(rx) => rx.recv().map_err(|_| RecvError),
            ReceiverInner::Chk(r) => {
                let h = check::active()
                    .expect("checked receiver used from a thread the checker never registered");
                match r.core.ck.yield_op(h.tid, Op::ChanRecv { ch: r.core.id }) {
                    YieldOutcome::Closed => Err(RecvError),
                    YieldOutcome::Proceed => Ok(r
                        .core
                        .q
                        .lock()
                        .unwrap_or_else(|e| e.into_inner())
                        .pop_front()
                        .expect("checker channel queue desynced from the schedule")),
                }
            }
        }
    }
}

/// Shimmed [`std::sync::mpsc::channel`]; `site` labels the channel in
/// checker diagnostics. The mode (std vs checked) is fixed at creation by
/// whether the creating thread is registered with an active checker.
pub fn channel<T: Send>(site: &'static str) -> (Sender<T>, Receiver<T>) {
    match check::active() {
        Some(h) => {
            let id = h.ck.register_channel(site);
            let core =
                Arc::new(ChkCore { q: Mutex::new(VecDeque::new()), ck: h.ck.clone(), id });
            (
                Sender(SenderInner::Chk(ChkSender { core: core.clone() })),
                Receiver(ReceiverInner::Chk(ChkReceiver { core })),
            )
        }
        None => {
            let (tx, rx) = mpsc::channel();
            (Sender(SenderInner::Std(tx)), Receiver(ReceiverInner::Std(rx)))
        }
    }
}

// ---------------------------------------------------------------------------
// threads

/// Shimmed [`std::thread::Builder::spawn_scoped`] with a thread name. When
/// the spawning thread is registered with a checker, the child is
/// registered too and the pair performs a deterministic handshake: the
/// parent's spawn op only becomes schedulable once the child has announced
/// itself, so registration order never depends on OS timing.
pub fn spawn_scoped<'scope, 'env, T, F>(
    scope: &'scope thread::Scope<'scope, 'env>,
    name: &str,
    f: F,
) -> thread::ScopedJoinHandle<'scope, T>
where
    F: FnOnce() -> T + Send + 'scope,
    T: Send + 'scope,
{
    let builder = thread::Builder::new().name(name.to_string());
    match check::active() {
        Some(h) => {
            let child = h.ck.register_child(h.tid, name);
            let ck = h.ck.clone();
            let handle = builder
                .spawn_scoped(scope, move || {
                    let _reg = check::ThreadGuard::enter(ck, child);
                    f()
                })
                .expect("spawn checked worker thread");
            h.ck.yield_op(h.tid, Op::SpawnWait { child });
            handle
        }
        None => builder.spawn_scoped(scope, f).expect("spawn worker thread"),
    }
}

/// The pre-join gate: call immediately before joining worker threads (or
/// before a `thread::scope`'s implicit join). Under a checker this blocks
/// the schedule until every other logical thread has exited, so the real
/// join below can never block the token holder; in production it is free.
pub fn pre_join() {
    if let Some(h) = check::active() {
        h.ck.yield_op(h.tid, Op::Join);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Production-path (no active checker) behavior of every wrapper.

    #[test]
    fn atomics_pass_through() {
        let a = AtomicUsize::new(3, "t.a");
        assert_eq!(a.fetch_add(4, Ordering::Relaxed), 3);
        assert_eq!(a.load(Ordering::Acquire), 7);
        a.store(1, Ordering::Release);
        assert_eq!(a.load(Ordering::Relaxed), 1);
        let b = AtomicBool::new(false, "t.b");
        b.raise(Ordering::Relaxed);
        assert!(b.load(Ordering::Relaxed));
        b.store(false, Ordering::Relaxed);
        assert!(!b.load(Ordering::Relaxed));
    }

    #[test]
    fn channel_passes_through_and_reports_disconnects() {
        let (tx, rx) = channel::<u32>("t.ch");
        let tx2 = tx.clone();
        tx.send(1).unwrap();
        tx2.send(2).unwrap();
        assert_eq!(rx.recv(), Ok(1));
        assert_eq!(rx.recv(), Ok(2));
        drop(tx);
        drop(tx2);
        assert_eq!(rx.recv(), Err(RecvError));
        let (tx, rx) = channel::<u32>("t.ch2");
        drop(rx);
        assert!(tx.send(9).is_err());
    }

    #[test]
    fn barrier_and_spawn_pass_through() {
        let bar = Barrier::new(2, "t.bar");
        let hits = AtomicUsize::new(0, "t.hits");
        std::thread::scope(|s| {
            spawn_scoped(s, "t-worker", || {
                hits.fetch_add(1, Ordering::SeqCst);
                bar.wait();
            });
            bar.wait();
            pre_join();
        });
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }
}
