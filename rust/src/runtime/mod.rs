//! PJRT runtime — loads the AOT artifacts `python/compile/aot.py` emitted
//! (HLO text + manifest.json) and executes them on the request path.
//!
//! Python runs once at build time (`make artifacts`); after that the rust
//! binary is self-contained: `HloModuleProto::from_text_file` →
//! `XlaComputation` → `PjRtClient::compile` → `execute`. HLO *text* is the
//! interchange format because jax ≥ 0.5 serializes protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects (see
//! /opt/xla-example/README.md).
//!
//! **Feature gating:** the `xla` crate (PJRT bindings) is not available in
//! the offline build image, so the real executor only compiles under
//! `--features xla` (after vendoring that crate). With the feature off —
//! the default — [`PjrtRuntime`] is a stub whose `load` always errors;
//! everything that can fall back to the native kernels does, and callers
//! that *require* PJRT fail with a pointer at the feature flag.
//!
//! Thread safety (real impl): the `xla` crate's handles hold `Rc`
//! refcounts and raw PJRT pointers, so they are `!Send`. `PjrtRuntime`
//! owns them inside a `Mutex` and never lets a handle escape — every PJRT
//! call (including the `Rc` clones `execute` performs internally) happens
//! under the lock, so promoting the wrapper to `Send + Sync` is sound.
//! The PJRT CPU client itself is thread-safe; the lock is about the
//! wrapper's `Rc`s.

pub mod manifest;
pub mod sync;
pub mod xla_problem;

pub use manifest::{ArtifactMeta, Manifest};
pub use xla_problem::XlaLogReg;

use std::fmt;
use std::path::PathBuf;

/// Minimal runtime error (`anyhow` is unavailable offline).
#[derive(Debug)]
pub struct RtError(pub String);

impl fmt::Display for RtError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "runtime error: {}", self.0)
    }
}

impl std::error::Error for RtError {}

impl From<std::io::Error> for RtError {
    fn from(e: std::io::Error) -> RtError {
        RtError(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, RtError>;

pub use pjrt::PjrtRuntime;

#[cfg(feature = "xla")]
mod pjrt {
    //! The real PJRT executor — requires the vendored `xla` crate.
    use super::{ArtifactMeta, Manifest, Result, RtError};
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::sync::Mutex;

    struct Inner {
        /// Kept alive for the lifetime of the executables (PJRT requires
        /// the client to outlive everything it compiled).
        #[allow(dead_code)]
        client: xla::PjRtClient,
        execs: HashMap<String, xla::PjRtLoadedExecutable>,
        dir: PathBuf,
        manifest: Manifest,
    }

    // SAFETY: `Inner` is only ever touched through `PjrtRuntime`'s Mutex,
    // so no two threads manipulate the Rc refcounts or PJRT handles
    // concurrently, and no handle is exposed outside the lock. See module
    // docs.
    unsafe impl Send for Inner {}

    /// A compiled-artifact registry + executor over the PJRT CPU client.
    pub struct PjrtRuntime {
        inner: Mutex<Inner>,
    }

    impl PjrtRuntime {
        /// Open `dir` (normally `artifacts/`), parse `manifest.json`, and
        /// compile every artifact eagerly. Fails with a pointer at
        /// `make artifacts` when the directory is missing.
        pub fn load(dir: &Path) -> Result<PjrtRuntime> {
            let manifest = Manifest::read(&dir.join("manifest.json")).map_err(|e| {
                RtError(format!(
                    "cannot read {}/manifest.json — run `make artifacts` first: {e}",
                    dir.display()
                ))
            })?;
            let client =
                xla::PjRtClient::cpu().map_err(|e| RtError(format!("PJRT cpu client: {e}")))?;
            let mut execs = HashMap::new();
            for art in &manifest.artifacts {
                let path = dir.join(&art.file);
                let proto = xla::HloModuleProto::from_text_file(&path)
                    .map_err(|e| RtError(format!("parse {}: {e}", path.display())))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .map_err(|e| RtError(format!("compile {}: {e}", art.name)))?;
                execs.insert(art.name.clone(), exe);
            }
            Ok(PjrtRuntime {
                inner: Mutex::new(Inner { client, execs, dir: dir.to_path_buf(), manifest }),
            })
        }

        /// Artifact metadata (immutable snapshot of the manifest).
        pub fn manifest(&self) -> Manifest {
            self.inner.lock().unwrap().manifest.clone()
        }

        /// Find the gradient artifact for a given shape, if compiled.
        pub fn find(&self, fn_name: &str, m: usize, d: usize, c: usize) -> Option<ArtifactMeta> {
            let inner = self.inner.lock().unwrap();
            inner
                .manifest
                .artifacts
                .iter()
                .find(|a| a.fn_name == fn_name && a.m == m && a.d == d && a.c == c)
                .cloned()
        }

        /// Execute artifact `name` with f32 row-major inputs
        /// `(data, dims)…`, returning the flattened f32 output of the
        /// 1-tuple root.
        pub fn exec(&self, name: &str, args: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
            let inner = self.inner.lock().unwrap();
            let exe = inner
                .execs
                .get(name)
                .ok_or_else(|| {
                    RtError(format!("no artifact '{name}' in {}", inner.dir.display()))
                })?;
            let literals: Vec<xla::Literal> = args
                .iter()
                .map(|(data, dims)| {
                    let expected: i64 = dims.iter().product();
                    assert_eq!(data.len() as i64, expected, "input size/dims mismatch");
                    xla::Literal::vec1(data)
                        .reshape(dims)
                        .map_err(|e| RtError(format!("reshape {dims:?}: {e}")))
                })
                .collect::<Result<_>>()?;
            let result = exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| RtError(format!("execute {name}: {e}")))?[0][0]
                .to_literal_sync()
                .map_err(|e| RtError(format!("fetch {name}: {e}")))?;
            // aot.py lowers with return_tuple=True ⇒ unwrap the 1-tuple
            let out = result.to_tuple1().map_err(|e| RtError(format!("untuple {name}: {e}")))?;
            out.to_vec::<f32>().map_err(|e| RtError(format!("to_vec {name}: {e}")))
        }

        /// Number of compiled executables.
        pub fn len(&self) -> usize {
            self.inner.lock().unwrap().execs.len()
        }

        pub fn is_empty(&self) -> bool {
            self.len() == 0
        }
    }
}

#[cfg(not(feature = "xla"))]
mod pjrt {
    //! Stub executor used when the `xla` feature is off (the default in
    //! the offline build): `load` validates the manifest, then reports
    //! that PJRT execution is not compiled in. No instance can exist, so
    //! the accessor methods are unreachable by construction.
    use super::{ArtifactMeta, Manifest, Result, RtError};
    use std::path::Path;

    /// A compiled-artifact registry + executor over the PJRT CPU client
    /// (stubbed out — build with `--features xla` for the real one).
    pub struct PjrtRuntime {
        manifest: Manifest,
    }

    impl PjrtRuntime {
        pub fn load(dir: &Path) -> Result<PjrtRuntime> {
            // Still parse the manifest so configuration errors surface
            // even without the backend.
            let _ = Manifest::read(&dir.join("manifest.json"))?;
            Err(RtError(format!(
                "PJRT/XLA execution is not compiled in (rebuild with `--features xla` after \
                 vendoring the xla crate); artifacts in {} cannot be executed",
                dir.display()
            )))
        }

        pub fn manifest(&self) -> Manifest {
            self.manifest.clone()
        }

        pub fn find(
            &self,
            _fn_name: &str,
            _m: usize,
            _d: usize,
            _c: usize,
        ) -> Option<ArtifactMeta> {
            None
        }

        pub fn exec(&self, name: &str, _args: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
            Err(RtError(format!("xla feature disabled: cannot execute '{name}'")))
        }

        pub fn len(&self) -> usize {
            0
        }

        pub fn is_empty(&self) -> bool {
            true
        }
    }
}

/// Default artifact directory: `$CARGO_MANIFEST_DIR/artifacts` when built
/// from the workspace, else `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    let ws = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if ws.exists() {
        ws
    } else {
        PathBuf::from("artifacts")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Skip (with a loud note) when `make artifacts` hasn't run or the
    /// PJRT backend isn't compiled in — the Makefile test target always
    /// builds artifacts first.
    fn runtime_or_skip() -> Option<PjrtRuntime> {
        let dir = default_artifact_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("SKIP runtime tests: {} missing (run `make artifacts`)", dir.display());
            return None;
        }
        if cfg!(not(feature = "xla")) {
            eprintln!("SKIP runtime tests: built without the `xla` feature");
            return None;
        }
        Some(PjrtRuntime::load(&dir).expect("artifacts present but failed to load"))
    }

    #[test]
    fn loads_all_manifest_artifacts() {
        let Some(rt) = runtime_or_skip() else { return };
        let manifest = rt.manifest();
        assert_eq!(rt.len(), manifest.artifacts.len());
        assert!(rt.find("logreg_grad", 24, 8, 4).is_some());
        assert!(rt.find("logreg_grad", 1, 1, 1).is_none());
    }

    #[test]
    fn grad_artifact_matches_native_gradient() {
        use crate::problem::data::{blobs, BlobSpec};
        use crate::problem::{LogReg, Problem};
        let Some(rt) = runtime_or_skip() else { return };
        // shape (24, 8, 4), λ2 = 0.005 — the shipped test artifact
        let spec = BlobSpec {
            nodes: 1,
            samples_per_node: 24,
            dim: 8,
            classes: 4,
            seed: 3,
            ..Default::default()
        };
        let p = LogReg::new(blobs(&spec), 4, 0.005, 4);
        let art = rt.find("logreg_grad", 24, 8, 4).expect("test artifact");

        let mut rng = crate::util::rng::Rng::new(5);
        let w: Vec<f64> = (0..p.dim()).map(|_| 0.3 * rng.normal()).collect();
        let mut native = vec![0.0; p.dim()];
        p.grad(0, &w, &mut native);

        // assemble f32 inputs: A (m,d), W (d,C), Y one-hot (m,C)
        let shard = &p.shards()[0];
        let a32: Vec<f32> = shard.features.data.iter().map(|&v| v as f32).collect();
        let w32: Vec<f32> = w.iter().map(|&v| v as f32).collect();
        let mut y32 = vec![0.0f32; 24 * 4];
        for (r, &lbl) in shard.labels.iter().enumerate() {
            y32[r * 4 + lbl] = 1.0;
        }
        let out = rt
            .exec(&art.name, &[(&a32, &[24, 8]), (&w32, &[8, 4]), (&y32, &[24, 4])])
            .expect("execute");
        assert_eq!(out.len(), p.dim());
        for (i, (&x, &n)) in out.iter().zip(&native).enumerate() {
            assert!(
                (x as f64 - n).abs() < 1e-5 * (1.0 + n.abs()),
                "grad[{i}]: xla {x} vs native {n}"
            );
        }
    }

    #[test]
    fn loss_artifact_evaluates() {
        let Some(rt) = runtime_or_skip() else { return };
        let art = rt.find("logreg_loss", 24, 8, 4).expect("loss artifact");
        let a = vec![0.0f32; 24 * 8];
        let w = vec![0.0f32; 8 * 4];
        let mut y = vec![0.0f32; 24 * 4];
        for r in 0..24 {
            y[r * 4] = 1.0;
        }
        let out = rt.exec(&art.name, &[(&a, &[24, 8]), (&w, &[8, 4]), (&y, &[24, 4])]).unwrap();
        // zero weights ⇒ CE = ln(C)
        assert_eq!(out.len(), 1);
        assert!((out[0] as f64 - (4.0f64).ln()).abs() < 1e-5, "{}", out[0]);
    }

    #[test]
    fn exec_unknown_artifact_errors() {
        let Some(rt) = runtime_or_skip() else { return };
        assert!(rt.exec("nope", &[]).is_err());
    }

    #[test]
    fn runtime_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<PjrtRuntime>();
    }

    #[test]
    fn stub_load_reports_missing_manifest() {
        // whatever the backend, loading a nonexistent dir must error
        let dir = std::env::temp_dir().join("proxlead_no_such_artifacts");
        assert!(PjrtRuntime::load(&dir).is_err());
    }
}
