//! [`XlaLogReg`] — the logistic-regression problem with its gradient
//! hot-spot executed by the PJRT runtime (the JAX/Pallas AOT artifact)
//! instead of the native rust kernel.
//!
//! This is the L3→L2/L1 seam: any [`crate::algorithm::Algorithm`] runs
//! unchanged over either backend, and `grad_backends_agree` in the
//! integration suite pins the two to ≤ f32 tolerance of each other.
//! Loss evaluation stays native (f64, off the hot path, used only for
//! metric logging).

use super::{PjrtRuntime, Result, RtError};
use crate::problem::{LogReg, Problem};
use std::sync::Arc;

/// Per-node f32 input caches (A and one-hot Y), sliced per batch.
struct NodeCache {
    a32: Vec<f32>,
    y32: Vec<f32>,
}

pub struct XlaLogReg {
    native: LogReg,
    rt: Arc<PjrtRuntime>,
    grad_full: String,
    grad_batch: Option<String>,
    caches: Vec<NodeCache>,
    batch_rows: usize,
}

impl XlaLogReg {
    /// Wrap `native`, resolving the full-gradient artifact (required) and
    /// the batch-gradient artifact (optional — without it, batch draws
    /// fall back to the native kernel and a warning is worth logging).
    pub fn new(native: LogReg, rt: Arc<PjrtRuntime>) -> Result<XlaLogReg> {
        let m = native.samples_per_node();
        let d = native.features;
        let c = native.classes;
        let grad_full = rt
            .find("logreg_grad", m, d, c)
            .ok_or_else(|| {
                RtError(format!(
                    "no logreg_grad artifact for shape ({m},{d},{c}) — \
                     add a --spec to `make artifacts`"
                ))
            })?
            .name;
        let batch_rows = m / native.num_batches();
        let grad_batch = rt.find("logreg_grad", batch_rows, d, c).map(|a| a.name);

        let caches = native
            .shards()
            .iter()
            .map(|s| {
                let a32: Vec<f32> = s.features.data.iter().map(|&v| v as f32).collect();
                let mut y32 = vec![0.0f32; s.labels.len() * c];
                for (r, &lbl) in s.labels.iter().enumerate() {
                    y32[r * c + lbl] = 1.0;
                }
                NodeCache { a32, y32 }
            })
            .collect();

        Ok(XlaLogReg { native, rt, grad_full, grad_batch, caches, batch_rows })
    }

    /// True when stochastic draws also run on PJRT (batch artifact found).
    pub fn batch_on_xla(&self) -> bool {
        self.grad_batch.is_some()
    }

    pub fn native(&self) -> &LogReg {
        &self.native
    }

    fn exec_grad(&self, name: &str, a: &[f32], y: &[f32], rows: usize, x: &[f64], out: &mut [f64]) {
        let d = self.native.features as i64;
        let c = self.native.classes as i64;
        let w32: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        let res = self
            .rt
            .exec(
                name,
                &[(a, &[rows as i64, d]), (&w32, &[d, c]), (y, &[rows as i64, c])],
            )
            .expect("PJRT gradient execution failed");
        for (o, &v) in out.iter_mut().zip(&res) {
            *o = v as f64;
        }
    }
}

impl Problem for XlaLogReg {
    fn dim(&self) -> usize {
        self.native.dim()
    }
    fn as_logreg(&self) -> Option<&crate::problem::LogReg> {
        Some(&self.native)
    }
    fn num_nodes(&self) -> usize {
        self.native.num_nodes()
    }
    fn num_batches(&self) -> usize {
        self.native.num_batches()
    }

    fn loss(&self, node: usize, x: &[f64]) -> f64 {
        self.native.loss(node, x)
    }

    fn grad(&self, node: usize, x: &[f64], out: &mut [f64]) {
        let cache = &self.caches[node];
        let rows = self.native.samples_per_node();
        let name = self.grad_full.clone();
        self.exec_grad(&name, &cache.a32, &cache.y32, rows, x, out);
    }

    fn grad_batch(&self, node: usize, batch: usize, x: &[f64], out: &mut [f64]) {
        match &self.grad_batch {
            Some(name) => {
                let cache = &self.caches[node];
                let d = self.native.features;
                let c = self.native.classes;
                let (lo, hi) = (batch * self.batch_rows, (batch + 1) * self.batch_rows);
                let a = &cache.a32[lo * d..hi * d];
                let y = &cache.y32[lo * c..hi * c];
                let name = name.clone();
                self.exec_grad(&name, a, y, self.batch_rows, x, out);
            }
            None => self.native.grad_batch(node, batch, x, out),
        }
    }

    fn smoothness(&self) -> f64 {
        self.native.smoothness()
    }
    fn strong_convexity(&self) -> f64 {
        self.native.strong_convexity()
    }
    fn name(&self) -> String {
        format!("xla[{}]", self.native.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problem::data::{blobs, BlobSpec};
    use crate::runtime::default_artifact_dir;
    use crate::util::rng::Rng;

    fn setup() -> Option<XlaLogReg> {
        let dir = default_artifact_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("SKIP xla_problem tests: run `make artifacts`");
            return None;
        }
        if cfg!(not(feature = "xla")) {
            eprintln!("SKIP xla_problem tests: built without the `xla` feature");
            return None;
        }
        let rt = Arc::new(PjrtRuntime::load(&dir).unwrap());
        let spec = BlobSpec {
            nodes: 3,
            samples_per_node: 24,
            dim: 8,
            classes: 4,
            seed: 3,
            ..Default::default()
        };
        let native = LogReg::new(blobs(&spec), 4, 0.005, 4);
        Some(XlaLogReg::new(native, rt).unwrap())
    }

    #[test]
    fn grad_backends_agree() {
        let Some(p) = setup() else { return };
        let mut rng = Rng::new(9);
        let x: Vec<f64> = (0..p.dim()).map(|_| 0.3 * rng.normal()).collect();
        let mut xg = vec![0.0; p.dim()];
        let mut ng = vec![0.0; p.dim()];
        for node in 0..p.num_nodes() {
            p.grad(node, &x, &mut xg);
            p.native().grad(node, &x, &mut ng);
            for (i, (&a, &b)) in xg.iter().zip(&ng).enumerate() {
                let tol = 1e-5 * (1.0 + b.abs());
                assert!((a - b).abs() < tol, "node {node} grad[{i}]: {a} vs {b}");
            }
        }
    }

    #[test]
    fn batch_grad_falls_back_when_no_artifact() {
        // shape (24,8,4) with 4 batches ⇒ batch rows 6: no shipped artifact,
        // so the native fallback must kick in and still be correct
        let Some(p) = setup() else { return };
        assert!(!p.batch_on_xla());
        let x = vec![0.1; p.dim()];
        let mut got = vec![0.0; p.dim()];
        let mut want = vec![0.0; p.dim()];
        p.grad_batch(0, 2, &x, &mut got);
        p.native().grad_batch(0, 2, &x, &mut want);
        assert_eq!(got, want);
    }

    #[test]
    fn prox_lead_runs_on_xla_backend() {
        use crate::algorithm::{Algorithm, ProxLead};
        use crate::exp::Experiment;
        let Some(p) = setup() else { return };
        let p = Arc::new(p);
        let exp = Experiment::builder()
            .nodes(3)
            .set("mixing", "mh")
            .set("lambda1", "5e-3")
            .set("bits", "2")
            .seed(1)
            .with_problem(Arc::clone(&p) as Arc<dyn Problem>)
            .build()
            .expect("xla experiment");
        let mut alg = ProxLead::builder(&exp).build();
        for _ in 0..50 {
            alg.step(p.as_ref());
        }
        let zeros = vec![0.0; p.dim()];
        let loss_now: f64 = (0..3).map(|i| p.loss(i, alg.x().row(0))).sum();
        let loss_0: f64 = (0..3).map(|i| p.loss(i, &zeros)).sum();
        assert!(loss_now < loss_0, "training on XLA backend must reduce loss");
        assert!(alg.x().is_finite());
    }
}
