//! `proxlead-check` — deterministic schedule exploration for the repo's two
//! hand-rolled synchronization protocols (DESIGN.md §6b).
//!
//! The sim backend's barrier-phased shard protocol (`crate::sim`) and the
//! coordinator's channel teardown (`crate::coordinator`) are exercised by
//! the parity suite only under whatever interleavings the OS happens to
//! produce. This module is a zero-dependency "loom-lite": the shim layer in
//! [`crate::runtime::sync`] routes every atomic access, barrier arrival,
//! channel operation, and thread spawn through a cooperative scheduler that
//! serializes the run (one logical thread holds the token at a time) and
//! *chooses* the interleaving — bounded-preemption DFS from replayed
//! prefixes for systematic coverage at tiny n, plus seed-recorded random
//! schedules for breadth.
//!
//! What one explored execution checks:
//!
//! - **Races on `Relaxed` pairs.** A vector clock per logical thread tracks
//!   happens-before: barrier releases join all arrivals' clocks, channel
//!   messages carry the sender's clock, acquire loads join the variable's
//!   release clock. An access that observes a cross-thread write with no
//!   happens-before edge is reported — except RMW-against-RMW pairs (the
//!   shard-claim counters and fault-flag raises are atomicity-only by
//!   design). Executions themselves are sequentially consistent; the
//!   checker does not simulate weak memory, it proves which `Relaxed` sites
//!   are ordered by *other* edges (see DESIGN.md §6b for the tsan
//!   comparison).
//! - **Deadlocks.** Every live logical thread blocked on a disabled
//!   operation (barrier arity mismatch, `recv` with live senders and an
//!   empty queue after teardown, a join gate with live peers) is reported
//!   with the full blocked-op listing.
//! - **Schedule invariance.** The scenario returns an [`Outcome`]
//!   fingerprint (slot matrix bits, history, stop reason); all explored
//!   schedules must produce the same fingerprint.
//!
//! Scenario definitions live in [`scenarios`]; `cargo run --release --bin
//! check` drives them and emits the `proxlead-check-v1` JSON report.

pub mod scenarios;

use std::collections::{HashMap, HashSet, VecDeque};
use std::fmt;
use std::panic::{self, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::Duration;

use crate::util::json::Json;
use crate::util::rng::Rng;

/// Hard wall against a true hang (an unshimmed blocking call, or a thread
/// crunching uncontrolled for this long): after this much scheduler
/// silence, the execution is poisoned and reported as stuck.
const WATCHDOG: Duration = Duration::from_secs(10);

/// Panic message prefix used when the scheduler unwinds an execution on
/// purpose (deadlock/stuck poisoning); the explorer filters these out of
/// the stray-panic findings.
const POISON_MSG: &str = "proxlead-check: execution poisoned";

// ---------------------------------------------------------------------------
// vector clocks

/// A grow-on-demand vector clock over logical thread ids.
#[derive(Clone, Debug, Default)]
struct VClock(Vec<u64>);

impl VClock {
    fn ensure(&mut self, len: usize) {
        if self.0.len() < len {
            self.0.resize(len, 0);
        }
    }

    fn tick(&mut self, tid: usize) {
        self.ensure(tid + 1);
        self.0[tid] += 1;
    }

    fn get(&self, tid: usize) -> u64 {
        self.0.get(tid).copied().unwrap_or(0)
    }

    fn join(&mut self, other: &VClock) {
        self.ensure(other.0.len());
        for (i, &v) in other.0.iter().enumerate() {
            if v > self.0[i] {
                self.0[i] = v;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// operations and findings

/// The kind of shimmed atomic access (see [`crate::runtime::sync`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum AtomicKind {
    Load,
    Store,
    /// Read-modify-write (`fetch_add`, flag raise via `fetch_or`): pairs of
    /// RMWs on one variable are atomicity-only and never flagged as races.
    Rmw,
}

/// One announced shim operation — every variant is a yield point.
#[derive(Clone, Debug)]
pub(crate) enum Op {
    /// First announcement of a freshly spawned logical thread; the parent's
    /// matching [`Op::SpawnWait`] is enabled once this is announced, which
    /// makes thread registration order deterministic for replay.
    Begin,
    /// Parent-side half of the spawn handshake.
    SpawnWait { child: usize },
    /// Atomic access; `acquire`/`release` carry the ordering strength (both
    /// false = relaxed) so the scheduler can maintain release clocks.
    Atomic { var: usize, site: &'static str, kind: AtomicKind, acquire: bool, release: bool },
    BarrierArrive { bar: usize },
    ChanSend { ch: usize },
    ChanRecv { ch: usize },
    ChanDropSender { ch: usize },
    ChanDropReceiver { ch: usize },
    /// Pre-join gate (`sync::pre_join`): enabled once every other logical
    /// thread is dead, so the real (uncontrolled) `join` that follows can
    /// never block the token holder.
    Join,
}

/// What a granted operation tells the shim layer to do.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum YieldOutcome {
    /// Perform the real operation (the thread still holds the token).
    Proceed,
    /// Channel endpoint is closed: `send` must return the value, `recv`
    /// must return a disconnect error.
    Closed,
}

/// Classification of one checker finding.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum FindingKind {
    /// Unordered cross-thread access pair on one atomic variable.
    Race,
    /// All live logical threads blocked on disabled operations.
    Deadlock,
    /// Watchdog or step-limit poisoning (livelock / unshimmed blocking).
    Stuck,
    /// A scenario panicked outside the scheduler's own poisoning.
    Panic,
    /// Explored schedules disagree on the scenario outcome fingerprint.
    Invariance,
    /// Fewer distinct schedules than the scenario demands.
    Coverage,
    /// A replayed prefix stopped matching the enabled set (scenario is
    /// itself schedule-dependent in its communication structure).
    Divergence,
}

impl FindingKind {
    pub fn name(&self) -> &'static str {
        match self {
            FindingKind::Race => "race",
            FindingKind::Deadlock => "deadlock",
            FindingKind::Stuck => "stuck",
            FindingKind::Panic => "panic",
            FindingKind::Invariance => "invariance",
            FindingKind::Coverage => "coverage",
            FindingKind::Divergence => "divergence",
        }
    }
}

/// One deduplicated checker finding.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Finding {
    pub kind: FindingKind,
    pub detail: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.kind.name(), self.detail)
    }
}

/// What one scenario execution returns: a fingerprint that must be
/// bit-identical across every explored schedule, plus a human label.
#[derive(Clone, Debug)]
pub struct Outcome {
    pub fingerprint: u64,
    pub label: String,
}

// ---------------------------------------------------------------------------
// FNV-1a — schedule and outcome fingerprints

/// Tiny FNV-1a hasher for schedule and outcome fingerprints (zero-dep, and
/// deterministic across runs unlike `DefaultHasher`).
pub struct Fnv(u64);

impl Fnv {
    pub fn new() -> Fnv {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    pub fn write_u64(&mut self, v: u64) {
        for b in v.to_le_bytes() {
            self.0 ^= u64::from(b);
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    pub fn write_bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= u64::from(b);
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv {
    fn default() -> Fnv {
        Fnv::new()
    }
}

// ---------------------------------------------------------------------------
// scheduler state

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Ts {
    /// OS-running before its `Begin` announcement.
    Startup,
    /// Holds the token.
    Running,
    /// Announced a pending op, waiting for a grant.
    Parked,
    /// Arrived at a barrier, waiting for the release.
    BarrierWait,
    /// Barrier released (or equivalent): schedulable with no pending op.
    Released,
    Dead,
}

struct Th {
    name: String,
    clock: VClock,
    state: Ts,
    pending: Option<Op>,
    /// Set when `Begin` is announced (spawn handshake).
    begun: bool,
}

#[derive(Clone)]
struct WriteRec {
    tid: usize,
    /// Writer's own clock component at the write — the happens-before test
    /// for a later access by `t` is `t.clock[tid] >= stamp`.
    stamp: u64,
    rmw: bool,
    site: &'static str,
}

#[derive(Default)]
struct VarMeta {
    /// Clock transferred to acquire loads; maintained by release stores
    /// (overwrite), release RMWs (join), and cleared by relaxed stores.
    release_clock: VClock,
    last_write: Option<WriteRec>,
}

struct BarMeta {
    site: &'static str,
    arity: usize,
    waiting: Vec<usize>,
    clock: VClock,
}

struct ChanMeta {
    site: &'static str,
    senders: usize,
    receiver_open: bool,
    /// Sender clocks, in lockstep with the typed queue in the shim layer
    /// (both are only touched by the token holder).
    msgs: VecDeque<VClock>,
    /// Joined at every sender drop; transferred to a disconnect `recv`.
    close_clock: VClock,
}

/// One schedule choice point, recorded for DFS child generation and replay.
#[derive(Clone, Debug)]
pub(crate) struct ChoicePoint {
    enabled: Vec<usize>,
    chosen: usize,
    running_before: Option<usize>,
    /// Preemptions accumulated strictly before this step.
    preempts_before: usize,
}

enum Policy {
    /// Replay `prefix`, then run-to-completion (continue the last running
    /// thread when enabled, else lowest tid). An empty prefix is the
    /// deterministic baseline schedule.
    Replay(Vec<usize>),
    Random(Rng),
}

struct SchedInner {
    threads: Vec<Th>,
    current: Option<usize>,
    last_running: Option<usize>,
    vars: HashMap<usize, VarMeta>,
    bars: Vec<BarMeta>,
    chans: Vec<ChanMeta>,
    findings: Vec<Finding>,
    poisoned: bool,
    log: Vec<ChoicePoint>,
    preempts: usize,
    policy: Policy,
    step_limit: usize,
    /// Joined at every thread exit; transferred at the pre-join gate.
    exit_clock: VClock,
}

pub(crate) struct Checker {
    inner: Mutex<SchedInner>,
    cv: Condvar,
}

enum ApplyResult {
    Proceed,
    Disconnected,
    BarrierBlocked,
}

impl Checker {
    fn fresh(policy: Policy, step_limit: usize) -> Arc<Checker> {
        let mut main = Th {
            name: "main".to_string(),
            clock: VClock::default(),
            state: Ts::Running,
            pending: None,
            begun: true,
        };
        main.clock.tick(0);
        Arc::new(Checker {
            inner: Mutex::new(SchedInner {
                threads: vec![main],
                current: Some(0),
                last_running: Some(0),
                vars: HashMap::new(),
                bars: Vec::new(),
                chans: Vec::new(),
                findings: Vec::new(),
                poisoned: false,
                log: Vec::new(),
                preempts: 0,
                policy,
                step_limit,
                exit_clock: VClock::default(),
            }),
            cv: Condvar::new(),
        })
    }

    fn lock(&self) -> MutexGuard<'_, SchedInner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    pub(crate) fn register_child(&self, parent: usize, name: &str) -> usize {
        let mut g = self.lock();
        let tid = g.threads.len();
        g.threads[parent].clock.tick(parent);
        let clock = g.threads[parent].clock.clone();
        g.threads.push(Th {
            name: name.to_string(),
            clock,
            state: Ts::Startup,
            pending: None,
            begun: false,
        });
        tid
    }

    pub(crate) fn register_barrier(&self, arity: usize, site: &'static str) -> usize {
        let mut g = self.lock();
        g.bars.push(BarMeta { site, arity, waiting: Vec::new(), clock: VClock::default() });
        g.bars.len() - 1
    }

    pub(crate) fn register_channel(&self, site: &'static str) -> usize {
        let mut g = self.lock();
        g.chans.push(ChanMeta {
            site,
            senders: 1,
            receiver_open: true,
            msgs: VecDeque::new(),
            close_clock: VClock::default(),
        });
        g.chans.len() - 1
    }

    /// `Sender::clone` bookkeeping — a pure refcount bump, not a yield
    /// point (cloning is thread-local and communicates nothing).
    pub(crate) fn sender_cloned(&self, ch: usize) {
        let mut g = self.lock();
        g.chans[ch].senders += 1;
    }

    /// Announce `op`, hand the token to the scheduler's choice, and apply
    /// the op's bookkeeping once granted. Panics if the execution is
    /// poisoned (deadlock/stuck) so the scenario unwinds.
    pub(crate) fn yield_op(&self, tid: usize, op: Op) -> YieldOutcome {
        let mut g = self.lock();
        g = self.announce(g, tid, op);
        g = self.wait_granted(g, tid);
        let op = g.threads[tid].pending.take().expect("granted thread lost its pending op");
        match Self::apply(&mut g, tid, &op) {
            ApplyResult::Proceed => YieldOutcome::Proceed,
            ApplyResult::Disconnected => YieldOutcome::Closed,
            ApplyResult::BarrierBlocked => {
                // parked again (state = BarrierWait, set by apply); hand the
                // token off and wait for the release grant
                Self::pick_next(&mut g, &self.cv);
                drop(self.wait_granted(g, tid));
                YieldOutcome::Proceed
            }
        }
    }

    /// [`Checker::yield_op`] for teardown paths (`Drop` impls): never
    /// panics — on a poisoned execution it falls back to detached
    /// bookkeeping so unwinding threads don't double-panic.
    pub(crate) fn yield_op_noexcept(&self, tid: usize, op: Op) {
        if std::thread::panicking() {
            self.apply_detached(&op);
            return;
        }
        let mut g = self.lock();
        if g.poisoned {
            drop(g);
            self.apply_detached(&op);
            return;
        }
        g = self.announce(g, tid, op);
        loop {
            if g.poisoned {
                if let Some(op) = g.threads[tid].pending.take() {
                    g.threads[tid].state = Ts::Running;
                    drop(g);
                    self.apply_detached(&op);
                }
                return;
            }
            if g.current == Some(tid) && g.threads[tid].state == Ts::Running {
                break;
            }
            let (g2, _) = self
                .cv
                .wait_timeout(g, WATCHDOG)
                .unwrap_or_else(|e| e.into_inner());
            g = g2;
        }
        let op = g.threads[tid].pending.take().expect("granted thread lost its pending op");
        let _ = Self::apply(&mut g, tid, &op);
    }

    /// Sender dropped from a thread this checker never registered (e.g.
    /// after the execution already finished): bookkeeping only, no yield.
    pub(crate) fn detach_drop_sender(&self, ch: usize) {
        self.apply_detached(&Op::ChanDropSender { ch });
    }

    /// Receiver counterpart of [`Checker::detach_drop_sender`].
    pub(crate) fn detach_drop_receiver(&self, ch: usize) {
        self.apply_detached(&Op::ChanDropReceiver { ch });
    }

    /// Minimal fallback bookkeeping when the scheduler is poisoned: keep
    /// channel refcounts sane without scheduling.
    fn apply_detached(&self, op: &Op) {
        let mut g = self.lock();
        match op {
            Op::ChanDropSender { ch } => {
                g.chans[*ch].senders = g.chans[*ch].senders.saturating_sub(1);
            }
            Op::ChanDropReceiver { ch } => g.chans[*ch].receiver_open = false,
            _ => {}
        }
    }

    fn announce<'a>(
        &'a self,
        mut g: MutexGuard<'a, SchedInner>,
        tid: usize,
        op: Op,
    ) -> MutexGuard<'a, SchedInner> {
        g.threads[tid].clock.tick(tid);
        if matches!(op, Op::Begin) {
            g.threads[tid].begun = true;
        }
        g.threads[tid].state = Ts::Parked;
        g.threads[tid].pending = Some(op);
        if g.current == Some(tid) {
            g.current = None;
        }
        if g.current.is_none() {
            Self::pick_next(&mut g, &self.cv);
        }
        g
    }

    fn wait_granted<'a>(
        &'a self,
        mut g: MutexGuard<'a, SchedInner>,
        tid: usize,
    ) -> MutexGuard<'a, SchedInner> {
        loop {
            if g.poisoned {
                drop(g);
                panic!("{POISON_MSG} — unwinding logical thread {tid}");
            }
            if g.current == Some(tid) && g.threads[tid].state == Ts::Running {
                return g;
            }
            let (g2, to) = self
                .cv
                .wait_timeout(g, WATCHDOG)
                .unwrap_or_else(|e| e.into_inner());
            g = g2;
            if to.timed_out() && g.current != Some(tid) && !g.poisoned {
                let name = g.threads[tid].name.clone();
                g.findings.push(Finding {
                    kind: FindingKind::Stuck,
                    detail: format!(
                        "watchdog: no scheduler progress for {}s while `{name}` waited \
                         (unshimmed blocking call?)",
                        WATCHDOG.as_secs()
                    ),
                });
                g.poisoned = true;
                self.cv.notify_all();
            }
        }
    }

    /// Thread teardown: mark dead, fold the exit clock, hand the token on.
    /// Never panics (runs from `Drop` during unwinds too).
    pub(crate) fn thread_exit(&self, tid: usize) {
        let mut g = self.lock();
        let clock = g.threads[tid].clock.clone();
        g.exit_clock.join(&clock);
        g.threads[tid].state = Ts::Dead;
        g.threads[tid].pending = None;
        if g.current == Some(tid) {
            g.current = None;
        }
        if !g.poisoned && g.current.is_none() {
            Self::pick_next(&mut g, &self.cv);
        }
        self.cv.notify_all();
    }

    fn enabled_tids(g: &SchedInner) -> Vec<usize> {
        g.threads
            .iter()
            .enumerate()
            .filter(|(i, t)| match t.state {
                Ts::Released => true,
                Ts::Parked => Self::op_enabled(g, *i),
                _ => false,
            })
            .map(|(i, _)| i)
            .collect()
    }

    fn op_enabled(g: &SchedInner, tid: usize) -> bool {
        match g.threads[tid].pending.as_ref() {
            None => false,
            Some(op) => match op {
                Op::Begin
                | Op::Atomic { .. }
                | Op::BarrierArrive { .. }
                | Op::ChanSend { .. }
                | Op::ChanDropSender { .. }
                | Op::ChanDropReceiver { .. } => true,
                Op::SpawnWait { child } => g.threads[*child].begun,
                Op::ChanRecv { ch } => {
                    let c = &g.chans[*ch];
                    !c.msgs.is_empty() || c.senders == 0
                }
                Op::Join => g
                    .threads
                    .iter()
                    .enumerate()
                    .all(|(i, t)| i == tid || t.state == Ts::Dead),
            },
        }
    }

    /// Choose and grant the next thread; on an empty enabled set with no
    /// startup stragglers, diagnose and poison.
    fn pick_next(g: &mut SchedInner, cv: &Condvar) {
        if g.poisoned {
            return;
        }
        if g.log.len() >= g.step_limit {
            g.findings.push(Finding {
                kind: FindingKind::Stuck,
                detail: format!("step limit {} exceeded (livelock?)", g.step_limit),
            });
            g.poisoned = true;
            cv.notify_all();
            return;
        }
        let enabled = Self::enabled_tids(g);
        if enabled.is_empty() {
            if g.threads.iter().any(|t| matches!(t.state, Ts::Startup | Ts::Running)) {
                // an uncontrolled thread will announce shortly; token stays
                // free until it does
                g.current = None;
                return;
            }
            if g.threads.iter().all(|t| t.state == Ts::Dead) {
                g.current = None;
                return;
            }
            let blocked: Vec<String> = g
                .threads
                .iter()
                .enumerate()
                .filter(|(_, t)| !matches!(t.state, Ts::Dead))
                .map(|(i, t)| format!("`{}`(t{i}) blocked on {}", t.name, Self::op_desc(g, i)))
                .collect();
            g.findings.push(Finding {
                kind: FindingKind::Deadlock,
                detail: format!("no enabled thread: {}", blocked.join("; ")),
            });
            g.poisoned = true;
            cv.notify_all();
            return;
        }
        let chosen = match &mut g.policy {
            Policy::Replay(prefix) => {
                let idx = g.log.len();
                match prefix.get(idx) {
                    Some(&want) if enabled.contains(&want) => want,
                    Some(&want) => {
                        g.findings.push(Finding {
                            kind: FindingKind::Divergence,
                            detail: format!(
                                "replay divergence at step {idx}: wanted t{want}, enabled {:?}",
                                enabled
                            ),
                        });
                        Self::default_pick(&enabled, g.last_running)
                    }
                    None => Self::default_pick(&enabled, g.last_running),
                }
            }
            Policy::Random(rng) => enabled[rng.below(enabled.len())],
        };
        let preempt = matches!(g.last_running, Some(rb) if enabled.contains(&rb) && chosen != rb);
        g.log.push(ChoicePoint {
            enabled,
            chosen,
            running_before: g.last_running,
            preempts_before: g.preempts,
        });
        if preempt {
            g.preempts += 1;
        }
        g.current = Some(chosen);
        g.last_running = Some(chosen);
        g.threads[chosen].state = Ts::Running;
        cv.notify_all();
    }

    fn default_pick(enabled: &[usize], last: Option<usize>) -> usize {
        match last {
            Some(rb) if enabled.contains(&rb) => rb,
            _ => *enabled.iter().min().expect("non-empty enabled set"),
        }
    }

    fn op_desc(g: &SchedInner, tid: usize) -> String {
        let t = &g.threads[tid];
        match (&t.state, t.pending.as_ref()) {
            (Ts::BarrierWait, _) => {
                let at =
                    g.bars.iter().find(|b| b.waiting.contains(&tid)).map_or("?", |b| b.site);
                format!("barrier `{at}` (release pending)")
            }
            (_, Some(Op::Begin)) => "spawn handshake".to_string(),
            (_, Some(Op::SpawnWait { child })) => format!("spawn of t{child}"),
            (_, Some(Op::Atomic { site, .. })) => format!("atomic `{site}`"),
            (_, Some(Op::BarrierArrive { bar })) => format!("barrier `{}`", g.bars[*bar].site),
            (_, Some(Op::ChanSend { ch })) => format!("send on `{}`", g.chans[*ch].site),
            (_, Some(Op::ChanRecv { ch })) => format!(
                "recv on `{}` ({} live sender(s), empty queue)",
                g.chans[*ch].site, g.chans[*ch].senders
            ),
            (_, Some(Op::ChanDropSender { ch })) => {
                format!("sender drop on `{}`", g.chans[*ch].site)
            }
            (_, Some(Op::ChanDropReceiver { ch })) => {
                format!("receiver drop on `{}`", g.chans[*ch].site)
            }
            (_, Some(Op::Join)) => "pre-join gate (live peers remain)".to_string(),
            (_, None) => "nothing (inconsistent state)".to_string(),
        }
    }

    fn apply(g: &mut SchedInner, tid: usize, op: &Op) -> ApplyResult {
        match op {
            Op::Begin | Op::SpawnWait { .. } => ApplyResult::Proceed,
            Op::Join => {
                let ec = g.exit_clock.clone();
                g.threads[tid].clock.join(&ec);
                ApplyResult::Proceed
            }
            Op::Atomic { var, site, kind, acquire, release } => {
                Self::apply_atomic(g, tid, *var, site, *kind, *acquire, *release);
                ApplyResult::Proceed
            }
            Op::BarrierArrive { bar } => {
                let clk = g.threads[tid].clock.clone();
                let b = &mut g.bars[*bar];
                b.clock.join(&clk);
                b.waiting.push(tid);
                if b.waiting.len() < b.arity {
                    g.threads[tid].state = Ts::BarrierWait;
                    return ApplyResult::BarrierBlocked;
                }
                let release_clock = std::mem::take(&mut b.clock);
                let waiters = std::mem::take(&mut b.waiting);
                for w in waiters {
                    g.threads[w].clock.join(&release_clock);
                    if w != tid {
                        g.threads[w].state = Ts::Released;
                        g.threads[w].pending = None;
                    }
                }
                ApplyResult::Proceed
            }
            Op::ChanSend { ch } => {
                if !g.chans[*ch].receiver_open {
                    return ApplyResult::Disconnected;
                }
                let clk = g.threads[tid].clock.clone();
                g.chans[*ch].msgs.push_back(clk);
                ApplyResult::Proceed
            }
            Op::ChanRecv { ch } => match g.chans[*ch].msgs.pop_front() {
                Some(mc) => {
                    g.threads[tid].clock.join(&mc);
                    ApplyResult::Proceed
                }
                None => {
                    // enabled with an empty queue means senders == 0
                    let cc = g.chans[*ch].close_clock.clone();
                    g.threads[tid].clock.join(&cc);
                    ApplyResult::Disconnected
                }
            },
            Op::ChanDropSender { ch } => {
                let clk = g.threads[tid].clock.clone();
                let c = &mut g.chans[*ch];
                c.senders = c.senders.saturating_sub(1);
                c.close_clock.join(&clk);
                ApplyResult::Proceed
            }
            Op::ChanDropReceiver { ch } => {
                g.chans[*ch].receiver_open = false;
                ApplyResult::Proceed
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn apply_atomic(
        g: &mut SchedInner,
        tid: usize,
        var: usize,
        site: &'static str,
        kind: AtomicKind,
        acquire: bool,
        release: bool,
    ) {
        if acquire && matches!(kind, AtomicKind::Load | AtomicKind::Rmw) {
            let rc = g.vars.get(&var).map(|m| m.release_clock.clone()).unwrap_or_default();
            g.threads[tid].clock.join(&rc);
        }
        let my_clock = g.threads[tid].clock.clone();
        let stamp = my_clock.get(tid);
        let prior = g.vars.get(&var).and_then(|m| m.last_write.clone());
        if let Some(w) = prior {
            if w.tid != tid && my_clock.get(w.tid) < w.stamp {
                let benign = w.rmw && kind == AtomicKind::Rmw;
                if !benign {
                    let access = match kind {
                        AtomicKind::Load => "load",
                        AtomicKind::Store => "store",
                        AtomicKind::Rmw => "rmw",
                    };
                    let writer = g.threads[w.tid].name.clone();
                    let f = Finding {
                        kind: FindingKind::Race,
                        detail: format!(
                            "{access} at `{site}` is unordered against the write at `{}` by \
                             `{writer}` (no happens-before edge; schedule-dependent value)",
                            w.site
                        ),
                    };
                    if !g.findings.contains(&f) {
                        g.findings.push(f);
                    }
                }
            }
        }
        let meta = g.vars.entry(var).or_default();
        match kind {
            AtomicKind::Load => {}
            AtomicKind::Store => {
                if release {
                    meta.release_clock = my_clock;
                } else {
                    // a relaxed store breaks the release sequence: a later
                    // acquire load must not inherit stale ordering
                    meta.release_clock = VClock::default();
                }
                meta.last_write = Some(WriteRec { tid, stamp, rmw: false, site });
            }
            AtomicKind::Rmw => {
                if release {
                    meta.release_clock.join(&my_clock);
                }
                // relaxed RMWs leave the release sequence intact
                meta.last_write = Some(WriteRec { tid, stamp, rmw: true, site });
            }
        }
    }
}

// ---------------------------------------------------------------------------
// thread-local registration (consumed by crate::runtime::sync)

thread_local! {
    static ACTIVE: std::cell::RefCell<Option<Handle>> =
        const { std::cell::RefCell::new(None) };
}

/// This thread's registration with an active checker, if any. The shim
/// layer consults this on every operation; `None` means pass-through.
#[derive(Clone)]
pub(crate) struct Handle {
    pub(crate) ck: Arc<Checker>,
    pub(crate) tid: usize,
}

pub(crate) fn active() -> Option<Handle> {
    ACTIVE.with(|a| a.borrow().clone())
}

fn set_active(h: Option<Handle>) {
    ACTIVE.with(|a| *a.borrow_mut() = h);
}

/// RAII registration for a spawned worker thread: announces `Begin` on
/// entry, announces thread death on drop (including during unwinds).
pub(crate) struct ThreadGuard {
    h: Handle,
}

impl ThreadGuard {
    pub(crate) fn enter(ck: Arc<Checker>, tid: usize) -> ThreadGuard {
        set_active(Some(Handle { ck: ck.clone(), tid }));
        ck.yield_op(tid, Op::Begin);
        ThreadGuard { h: Handle { ck, tid } }
    }
}

impl Drop for ThreadGuard {
    fn drop(&mut self) {
        self.h.ck.thread_exit(self.h.tid);
        set_active(None);
    }
}

// ---------------------------------------------------------------------------
// one controlled execution

struct ExecRun {
    log: Vec<ChoicePoint>,
    findings: Vec<Finding>,
    outcome: Option<Outcome>,
    panic_msg: Option<String>,
    schedule_fp: u64,
}

fn panic_payload(e: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

fn run_once(policy: Policy, step_limit: usize, f: &dyn Fn() -> Outcome) -> ExecRun {
    let ck = Checker::fresh(policy, step_limit);
    set_active(Some(Handle { ck: ck.clone(), tid: 0 }));
    let res = panic::catch_unwind(AssertUnwindSafe(f));
    set_active(None);
    ck.thread_exit(0);
    let g = ck.lock();
    let mut h = Fnv::new();
    for cp in &g.log {
        h.write_u64(cp.chosen as u64);
    }
    ExecRun {
        log: g.log.clone(),
        findings: g.findings.clone(),
        panic_msg: res.as_ref().err().map(|e| panic_payload(e.as_ref())),
        outcome: res.ok(),
        schedule_fp: h.finish(),
    }
}

// ---------------------------------------------------------------------------
// the explorer

/// Exploration budget and identity for one scenario.
#[derive(Clone, Debug)]
pub struct ExploreSpec {
    pub name: &'static str,
    /// Executions spent on bounded-preemption DFS from replayed prefixes.
    pub dfs_budget: usize,
    /// Executions spent on seed-recorded uniformly random schedules.
    pub random_budget: usize,
    /// Preemption bound for DFS child prefixes (the classic small-bound
    /// heuristic: most protocol bugs need very few forced switches).
    pub max_preemptions: usize,
    pub seed: u64,
    /// Poison an execution past this many scheduler choice points.
    pub step_limit: usize,
    /// Minimum distinct schedule fingerprints the exploration must reach.
    pub min_distinct: usize,
}

/// Aggregated result of exploring one scenario.
#[derive(Clone, Debug)]
pub struct ScenarioReport {
    pub name: String,
    pub executions: usize,
    pub distinct: usize,
    pub dfs_executions: usize,
    pub random_executions: usize,
    pub max_steps: usize,
    /// Distinct outcome labels with fingerprints (length 1 iff invariant).
    pub outcomes: Vec<String>,
    pub findings: Vec<Finding>,
    pub schedule_invariant: bool,
    pub pass: bool,
}

impl ScenarioReport {
    pub fn summary_line(&self) -> String {
        format!(
            "{}: {} — {} executions ({} dfs, {} random), {} distinct schedules, \
             {} finding(s), outcome {}",
            self.name,
            if self.pass { "PASS" } else { "FAIL" },
            self.executions,
            self.dfs_executions,
            self.random_executions,
            self.distinct,
            self.findings.len(),
            if self.schedule_invariant { "invariant" } else { "SCHEDULE-DEPENDENT" },
        )
    }
}

struct Collect {
    executions: usize,
    seen: HashSet<u64>,
    findings: Vec<Finding>,
    outcomes: HashMap<u64, String>,
    max_steps: usize,
}

impl Collect {
    fn add(&mut self, run: &ExecRun) {
        self.executions += 1;
        self.seen.insert(run.schedule_fp);
        self.max_steps = self.max_steps.max(run.log.len());
        for f in &run.findings {
            if !self.findings.contains(f) {
                self.findings.push(f.clone());
            }
        }
        if let Some(o) = &run.outcome {
            self.outcomes.entry(o.fingerprint).or_insert_with(|| o.label.clone());
        }
        if let Some(msg) = &run.panic_msg {
            if !msg.contains("proxlead-check") {
                let f = Finding {
                    kind: FindingKind::Panic,
                    detail: format!("scenario panicked: {msg}"),
                };
                if !self.findings.contains(&f) {
                    self.findings.push(f);
                }
            }
        }
    }
}

/// Explore `f` under `spec`: DFS over bounded-preemption prefix
/// alternatives first, then random schedules (topped up until
/// `min_distinct` or the attempt cap). `f` runs once per execution on this
/// thread with its spawned workers routed through the active checker.
pub fn explore(spec: &ExploreSpec, f: impl Fn() -> Outcome) -> ScenarioReport {
    let f: &dyn Fn() -> Outcome = &f;
    let mut c = Collect {
        executions: 0,
        seen: HashSet::new(),
        findings: Vec::new(),
        outcomes: HashMap::new(),
        max_steps: 0,
    };

    // phase 1: bounded-preemption DFS from replayed prefixes
    let mut stack: Vec<Vec<usize>> = vec![Vec::new()];
    let mut dfs_executions = 0;
    while let Some(prefix) = stack.pop() {
        if dfs_executions >= spec.dfs_budget {
            break;
        }
        let run = run_once(Policy::Replay(prefix.clone()), spec.step_limit, f);
        dfs_executions += 1;
        for (s, cp) in run.log.iter().enumerate().skip(prefix.len()) {
            if cp.enabled.len() < 2 {
                continue;
            }
            for &alt in &cp.enabled {
                if alt == cp.chosen {
                    continue;
                }
                let delta = match cp.running_before {
                    Some(rb) if cp.enabled.contains(&rb) && alt != rb => 1,
                    _ => 0,
                };
                if cp.preempts_before + delta > spec.max_preemptions {
                    continue;
                }
                let mut child: Vec<usize> = run.log[..s].iter().map(|p| p.chosen).collect();
                child.push(alt);
                stack.push(child);
            }
        }
        c.add(&run);
    }

    // phase 2: seed-recorded random schedules, topped up to min_distinct
    let mut random_executions = 0;
    let cap = spec.random_budget + 3 * spec.min_distinct;
    while random_executions < spec.random_budget
        || (c.seen.len() < spec.min_distinct && random_executions < cap)
    {
        let seed = spec.seed.wrapping_add(random_executions as u64);
        let run = run_once(Policy::Random(Rng::new(seed)), spec.step_limit, f);
        random_executions += 1;
        c.add(&run);
    }

    let mut findings = c.findings;
    let schedule_invariant = c.outcomes.len() <= 1;
    if !schedule_invariant {
        let mut labels: Vec<String> = c
            .outcomes
            .iter()
            .map(|(fp, label)| format!("{label}#{fp:016x}"))
            .collect();
        labels.sort();
        findings.push(Finding {
            kind: FindingKind::Invariance,
            detail: format!("outcome differs across schedules: {}", labels.join(" vs ")),
        });
    }
    if c.seen.len() < spec.min_distinct {
        findings.push(Finding {
            kind: FindingKind::Coverage,
            detail: format!(
                "only {} distinct schedules explored (need {})",
                c.seen.len(),
                spec.min_distinct
            ),
        });
    }
    findings.sort();
    let mut outcomes: Vec<String> = c
        .outcomes
        .iter()
        .map(|(fp, label)| format!("{label}#{fp:016x}"))
        .collect();
    outcomes.sort();
    let pass = findings.is_empty();
    ScenarioReport {
        name: spec.name.to_string(),
        executions: c.executions,
        distinct: c.seen.len(),
        dfs_executions,
        random_executions,
        max_steps: c.max_steps,
        outcomes,
        findings,
        schedule_invariant,
        pass,
    }
}

// ---------------------------------------------------------------------------
// JSON report

/// Render the `proxlead-check-v1` report consumed by CI and validated by
/// `scripts/test_check_report.py`.
pub fn report_json(reports: &[ScenarioReport]) -> Json {
    let scenarios: Vec<Json> = reports
        .iter()
        .map(|r| {
            let findings: Vec<Json> = r
                .findings
                .iter()
                .map(|f| {
                    Json::obj(vec![
                        ("kind", f.kind.name().into()),
                        ("detail", f.detail.as_str().into()),
                    ])
                })
                .collect();
            Json::obj(vec![
                ("name", r.name.as_str().into()),
                ("pass", r.pass.into()),
                ("executions", r.executions.into()),
                ("distinct_schedules", r.distinct.into()),
                ("dfs_executions", r.dfs_executions.into()),
                ("random_executions", r.random_executions.into()),
                ("max_steps", r.max_steps.into()),
                ("schedule_invariant", r.schedule_invariant.into()),
                ("outcomes", Json::Arr(r.outcomes.iter().map(|o| o.as_str().into()).collect())),
                ("findings", Json::Arr(findings)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("schema", "proxlead-check-v1".into()),
        ("pass", reports.iter().all(|r| r.pass).into()),
        ("scenarios", Json::Arr(scenarios)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::sync;
    use std::sync::atomic::Ordering;

    fn spec(name: &'static str) -> ExploreSpec {
        ExploreSpec {
            name,
            dfs_budget: 40,
            random_budget: 40,
            max_preemptions: 2,
            seed: 7,
            step_limit: 10_000,
            min_distinct: 2,
        }
    }

    #[test]
    fn relaxed_store_load_without_barrier_is_a_race_and_schedule_dependent() {
        let report = explore(&spec("unit-racy-flag"), || {
            let flag = sync::AtomicUsize::new(0, "unit.flag");
            let mut v = 0;
            std::thread::scope(|s| {
                sync::spawn_scoped(s, "writer", || {
                    flag.store(1, Ordering::Relaxed);
                });
                v = flag.load(Ordering::Relaxed);
                sync::pre_join();
            });
            Outcome { fingerprint: v as u64, label: format!("v={v}") }
        });
        assert!(
            report.findings.iter().any(|f| f.kind == FindingKind::Race),
            "expected a race finding: {:?}",
            report.findings
        );
        assert!(!report.schedule_invariant, "v must depend on the schedule");
        assert!(!report.pass);
    }

    #[test]
    fn barrier_separated_relaxed_pair_is_clean_and_invariant() {
        let report = explore(&spec("unit-barrier-hb"), || {
            let flag = sync::AtomicUsize::new(0, "unit.flag");
            let bar = sync::Barrier::new(2, "unit.bar");
            let mut v = 0;
            std::thread::scope(|s| {
                sync::spawn_scoped(s, "writer", || {
                    flag.store(1, Ordering::Relaxed);
                    bar.wait();
                });
                bar.wait();
                v = flag.load(Ordering::Relaxed);
                sync::pre_join();
            });
            Outcome { fingerprint: v as u64, label: format!("v={v}") }
        });
        assert!(report.pass, "barrier-ordered relaxed pair must be clean: {:?}", report.findings);
        assert_eq!(report.outcomes.len(), 1);
        assert!(report.distinct >= 2, "only {} distinct schedules", report.distinct);
    }

    #[test]
    fn rmw_rmw_contention_is_exempt_and_deterministic() {
        let report = explore(&spec("unit-rmw-claim"), || {
            let next = sync::AtomicUsize::new(0, "unit.next");
            let bar = sync::Barrier::new(2, "unit.bar");
            std::thread::scope(|s| {
                sync::spawn_scoped(s, "claimer", || {
                    while next.fetch_add(1, Ordering::Relaxed) < 4 {}
                    bar.wait();
                });
                while next.fetch_add(1, Ordering::Relaxed) < 4 {}
                bar.wait();
                sync::pre_join();
            });
            let total = next.load(Ordering::Relaxed);
            Outcome { fingerprint: total as u64, label: format!("total={total}") }
        });
        assert!(
            !report.findings.iter().any(|f| f.kind == FindingKind::Race),
            "rmw-vs-rmw claims must not be flagged: {:?}",
            report.findings
        );
        assert!(report.schedule_invariant, "claim totals are schedule-invariant");
    }

    #[test]
    fn barrier_arity_mismatch_deadlocks() {
        let report = explore(&spec("unit-arity-deadlock"), || {
            let bar = sync::Barrier::new(3, "unit.bar3");
            std::thread::scope(|s| {
                sync::spawn_scoped(s, "worker", || {
                    bar.wait();
                });
                bar.wait();
                sync::pre_join();
            });
            Outcome { fingerprint: 0, label: "unreachable".to_string() }
        });
        assert!(
            report.findings.iter().any(|f| f.kind == FindingKind::Deadlock),
            "2 arrivals at an arity-3 barrier must deadlock: {:?}",
            report.findings
        );
        assert!(!report.pass);
    }

    #[test]
    fn blocked_recv_with_live_sender_deadlocks() {
        let report = explore(&spec("unit-recv-deadlock"), || {
            let (tx, rx) = sync::channel::<u8>("unit.ch");
            std::thread::scope(|s| {
                sync::spawn_scoped(s, "idle", || {});
                // tx is alive on this thread, so recv can never be enabled
                let _ = rx.recv();
                drop(tx);
                sync::pre_join();
            });
            Outcome { fingerprint: 0, label: "unreachable".to_string() }
        });
        assert!(
            report.findings.iter().any(|f| f.kind == FindingKind::Deadlock),
            "recv with a live local sender must deadlock: {:?}",
            report.findings
        );
    }

    #[test]
    fn channel_disconnect_after_drain_is_clean() {
        let report = explore(&spec("unit-chan-drain"), || {
            let (tx, rx) = sync::channel::<u64>("unit.ch");
            let mut got = Vec::new();
            std::thread::scope(|s| {
                sync::spawn_scoped(s, "sender", move || {
                    let _ = tx.send(10);
                    let _ = tx.send(20);
                });
                while let Ok(v) = rx.recv() {
                    got.push(v);
                }
                sync::pre_join();
            });
            let mut h = Fnv::new();
            for v in &got {
                h.write_u64(*v);
            }
            Outcome { fingerprint: h.finish(), label: format!("got={got:?}") }
        });
        assert!(report.pass, "drain-then-disconnect must be clean: {:?}", report.findings);
        assert_eq!(report.outcomes.len(), 1, "fifo order is schedule-invariant");
    }

    #[test]
    fn release_acquire_pair_is_not_a_race_but_value_still_schedule_dependent() {
        let report = explore(&spec("unit-acq-rel"), || {
            let flag = sync::AtomicUsize::new(0, "unit.flag");
            let mut v = 0;
            std::thread::scope(|s| {
                sync::spawn_scoped(s, "writer", || {
                    flag.store(1, Ordering::Release);
                });
                v = flag.load(Ordering::Acquire);
                sync::pre_join();
            });
            Outcome { fingerprint: v as u64, label: format!("v={v}") }
        });
        assert!(
            !report.findings.iter().any(|f| f.kind == FindingKind::Race),
            "release/acquire pair is ordered when it hits: {:?}",
            report.findings
        );
        assert!(
            report.findings.iter().any(|f| f.kind == FindingKind::Invariance),
            "unsynchronized timing still makes the value schedule-dependent"
        );
    }

    #[test]
    fn coverage_shortfall_is_reported() {
        let mut s = spec("unit-coverage");
        s.dfs_budget = 2;
        s.random_budget = 1;
        s.min_distinct = 50;
        let report = explore(&s, || Outcome { fingerprint: 1, label: "one".to_string() });
        assert!(report.findings.iter().any(|f| f.kind == FindingKind::Coverage));
        assert!(!report.pass);
    }

    #[test]
    fn report_json_round_trips() {
        let report = explore(&spec("unit-json"), || Outcome {
            fingerprint: 7,
            label: "seven".to_string(),
        });
        let rendered = report_json(&[report]).to_string();
        let parsed = Json::parse(&rendered).expect("check report must re-parse");
        assert_eq!(
            parsed.get("schema").and_then(|s| s.as_str()),
            Some("proxlead-check-v1")
        );
        let scen = parsed.get("scenarios").and_then(|s| s.as_arr()).expect("scenarios array");
        assert_eq!(scen.len(), 1);
        assert_eq!(scen[0].get("name").and_then(|s| s.as_str()), Some("unit-json"));
    }

    #[test]
    fn dfs_replay_is_deterministic() {
        let run = || {
            explore(&spec("unit-replay"), || {
                let flag = sync::AtomicUsize::new(0, "unit.flag");
                let bar = sync::Barrier::new(2, "unit.bar");
                std::thread::scope(|s| {
                    sync::spawn_scoped(s, "w", || {
                        flag.fetch_add(3, Ordering::Relaxed);
                        bar.wait();
                    });
                    flag.fetch_add(4, Ordering::Relaxed);
                    bar.wait();
                    sync::pre_join();
                });
                let v = flag.load(Ordering::Relaxed);
                Outcome { fingerprint: v as u64, label: format!("v={v}") }
            })
        };
        let (a, b) = (run(), run());
        assert_eq!(a.distinct, b.distinct, "exploration must be reproducible");
        assert_eq!(a.outcomes, b.outcomes);
        assert_eq!(a.findings, b.findings);
    }
}
