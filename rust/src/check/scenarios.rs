//! Named model-checking scenarios over the real sim/coordinator protocols.
//!
//! Each scenario builds one tiny [`Experiment`] up front (data generation
//! and the FISTA reference are warmed *outside* [`explore`], so executions
//! spend their scheduler steps on the protocol under test, not on setup),
//! then runs a full backend under the controlled scheduler once per
//! explored schedule. The outcome fingerprint hashes exactly what the
//! repo's determinism contract pins — final-iterate bits, the counted
//! history columns, and the stop label — and deliberately excludes the
//! wall-clock fields, which legitimately vary per schedule.
//!
//! Expected outcomes are *pinned*, not just invariant: a scenario that
//! lands on a stable-but-wrong stop reason under every schedule fails with
//! a divergence finding rather than passing the invariance check.

use crate::check::{explore, ExploreSpec, Finding, FindingKind, Fnv, Outcome, ScenarioReport};
use crate::config::Config;
use crate::coordinator::{self, FrameTamper, TamperKind};
use crate::exp::{registry, Experiment};
use crate::runner::{RunResult, StopReason};
use crate::sim;

/// Scenario names, in the order `--bin check` runs them.
pub const NAMES: &[&str] = &[
    "sim-ring-phases",
    "sim-tamper-teardown",
    "coord-fault-teardown",
    "coord-bits-budget-stop",
];

/// Exploration depth: [`Budget::Full`] is the CI hard gate (≥ 1000
/// distinct schedules per scenario); [`Budget::Quick`] keeps the
/// `cargo test` scenario suite fast.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Budget {
    Quick,
    Full,
}

impl Budget {
    /// Distinct-schedule floor enforced per scenario (a shortfall is a
    /// coverage finding, failing the run).
    pub fn min_distinct(self) -> usize {
        match self {
            Budget::Quick => 16,
            Budget::Full => 1000,
        }
    }
}

fn spec(name: &'static str, budget: Budget) -> ExploreSpec {
    let (dfs_budget, random_budget) = match budget {
        Budget::Quick => (12, 24),
        Budget::Full => (300, 1100),
    };
    ExploreSpec {
        name,
        dfs_budget,
        random_budget,
        max_preemptions: 2,
        seed: 0x70726f78_6c656164, // "proxlead"
        step_limit: 50_000,
        min_distinct: budget.min_distinct(),
    }
}

/// Tiny ring experiment shared by every scenario: generated logistic
/// regression, dense 64-bit codec (decode errors come from tamper hooks,
/// never from quantization), one metric row per round.
fn ring_exp(nodes: usize, rounds: usize) -> Experiment {
    let text = format!(
        "algorithm = prox-lead\n\
         topology = ring\n\
         nodes = {nodes}\n\
         samples_per_node = 6\n\
         dim = 2\n\
         classes = 2\n\
         batches = 2\n\
         seed = 11\n\
         lambda1 = 0.005\n\
         lambda2 = 0.1\n\
         bits = 64\n\
         rounds = {rounds}\n\
         record_every = 1\n"
    );
    let cfg = Config::parse(&text).expect("scenario config parses");
    Experiment::from_config(&cfg).expect("scenario experiment resolves")
}

/// Fingerprint of everything the determinism contract pins, and nothing
/// it doesn't: `wall_ns`/`elapsed` stay out.
fn outcome_of(res: &RunResult) -> Outcome {
    let mut h = Fnv::new();
    h.write_u64(res.final_x.rows as u64);
    h.write_u64(res.final_x.cols as u64);
    for v in &res.final_x.data {
        h.write_u64(v.to_bits());
    }
    for m in &res.history {
        h.write_u64(m.round as u64);
        h.write_u64(m.grad_evals);
        h.write_u64(m.bits);
        h.write_u64(m.wire_bytes);
        h.write_u64(m.suboptimality.to_bits());
        h.write_u64(m.consensus.to_bits());
    }
    let label = match &res.stopped_by {
        StopReason::WireFault(f) => format!("wire-fault@r{}n{}", f.round, f.node),
        other => other.name().to_string(),
    };
    h.write_bytes(label.as_bytes());
    Outcome { fingerprint: h.finish(), label }
}

/// Pin the semantic outcome over and above schedule invariance.
fn expect_outcome(mut r: ScenarioReport, want: &str) -> ScenarioReport {
    let ok = !r.outcomes.is_empty()
        && r.outcomes.iter().all(|o| o.split('#').next() == Some(want));
    if !ok {
        r.findings.push(Finding {
            kind: FindingKind::Divergence,
            detail: format!("expected outcome '{want}', observed [{}]", r.outcomes.join(", ")),
        });
        r.pass = false;
    }
    r
}

/// The sim's phase A/B chunk-claim protocol on a clean ring: 4 nodes,
/// 3 participants (so claiming genuinely interleaves), 2 rounds to the
/// natural end. Exercises every Relaxed site in `sim::run_with_workers`.
fn sim_ring_phases(budget: Budget) -> ScenarioReport {
    let exp = ring_exp(4, 2);
    let wire = exp.coord_config();
    let run = exp.run_spec();
    let x_star = exp.reference();
    let r = explore(&spec("sim-ring-phases", budget), || {
        let res = sim::run_with_workers(
            &exp.mixing,
            &exp.x0,
            &exp.config.algorithm,
            &wire,
            &run,
            &x_star,
            &mut [],
            |i, row| registry::build_node_algorithm(&exp, &wire, i, row),
            3,
        );
        outcome_of(&res)
    });
    expect_outcome(r, "max-rounds")
}

/// A corrupt frame raised mid-run: whichever participant claims node 2's
/// shard in round 1 records the fault and raises `fault_flag`; the run
/// must stop at the same truncated history under every schedule. The sim
/// reports the *sender's* id.
fn sim_tamper_teardown(budget: Budget) -> ScenarioReport {
    let exp = ring_exp(4, 2);
    let wire = exp
        .coord_config()
        .tamper(FrameTamper { node: 2, round: 1, kind: TamperKind::TrailingGarbage });
    let run = exp.run_spec();
    let x_star = exp.reference();
    let r = explore(&spec("sim-tamper-teardown", budget), || {
        let res = sim::run_with_workers(
            &exp.mixing,
            &exp.x0,
            &exp.config.algorithm,
            &wire,
            &run,
            &x_star,
            &mut [],
            |i, row| registry::build_node_algorithm(&exp, &wire, i, row),
            3,
        );
        outcome_of(&res)
    });
    expect_outcome(r, "wire-fault@r1n2")
}

/// The coordinator's ABORT teardown: node 1 corrupts its round-1
/// broadcast in a 3-ring. Node 1 floods ascending by neighbor id, so
/// node 0 always dequeues the corrupt frame before any ABORT can reach it
/// (mpsc FIFO + program order) and always reports; min-(round, node)
/// resolution must land on the *detector* (round 1, node 0) under every
/// schedule, whether or not node 2 also detects.
fn coord_fault_teardown(budget: Budget) -> ScenarioReport {
    let exp = ring_exp(3, 2);
    let wire = exp
        .coord_config()
        .tamper(FrameTamper { node: 1, round: 1, kind: TamperKind::UnknownTag });
    let run = exp.run_spec();
    let x_star = exp.reference();
    let r = explore(&spec("coord-fault-teardown", budget), || {
        let res = coordinator::run(
            &exp.mixing,
            &exp.x0,
            &exp.config.algorithm,
            &wire,
            &run,
            &x_star,
            &mut [],
            |i, row| registry::build_node_algorithm(&exp, &wire, i, row),
        );
        outcome_of(&res)
    });
    expect_outcome(r, "wire-fault@r1n0")
}

/// The gated control path: a 1-bit budget trips at the round-1 flush, the
/// leader's checkpoint verdict turns `false`, and every node must stop
/// after step 1 — same truncated history under every schedule.
fn coord_bits_budget_stop(budget: Budget) -> ScenarioReport {
    let exp = ring_exp(3, 3);
    let wire = exp.coord_config();
    let run = exp.run_spec().bits_budget(1);
    let x_star = exp.reference();
    let r = explore(&spec("coord-bits-budget-stop", budget), || {
        let res = coordinator::run(
            &exp.mixing,
            &exp.x0,
            &exp.config.algorithm,
            &wire,
            &run,
            &x_star,
            &mut [],
            |i, row| registry::build_node_algorithm(&exp, &wire, i, row),
        );
        outcome_of(&res)
    });
    expect_outcome(r, "bits-budget")
}

fn lookup(name: &str) -> Option<fn(Budget) -> ScenarioReport> {
    match name {
        "sim-ring-phases" => Some(sim_ring_phases),
        "sim-tamper-teardown" => Some(sim_tamper_teardown),
        "coord-fault-teardown" => Some(coord_fault_teardown),
        "coord-bits-budget-stop" => Some(coord_bits_budget_stop),
        _ => None,
    }
}

/// Run one scenario by name (`None` for an unknown name).
pub fn run_by_name(name: &str, budget: Budget) -> Option<ScenarioReport> {
    lookup(name).map(|f| f(budget))
}

/// Run every named scenario in [`NAMES`] order.
pub fn run_all(budget: Budget) -> Vec<ScenarioReport> {
    NAMES
        .iter()
        .map(|n| run_by_name(n, budget).expect("NAMES entries are exhaustively matched"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_listed_name_resolves_and_unknown_names_do_not() {
        for n in NAMES {
            // resolution only — running is rust/tests/check_scenarios.rs
            assert!(lookup(n).is_some(), "unmatched scenario name {n}");
        }
        assert!(run_by_name("no-such-scenario", Budget::Quick).is_none());
    }

    #[test]
    fn budget_floors_match_the_acceptance_bar() {
        assert_eq!(Budget::Full.min_distinct(), 1000);
        assert!(Budget::Quick.min_distinct() >= 8);
    }
}
