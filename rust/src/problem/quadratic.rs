//! Least-squares / ridge problems — the quadratic suite used by the
//! Table 3 cross-algorithm comparison and the decentralized-lasso example.
//!
//! ```text
//! f_i(x) = ‖A_i x − b_i‖² / (2 mᵢ) + λ₂‖x‖²
//! ∇f_i(x) = A_iᵀ(A_i x − b_i) / mᵢ + 2λ₂ x
//! ```
//!
//! With λ₂ = 0 and an L1 prox this is the decentralized lasso; with λ₂ > 0
//! it is ridge regression with closed-form optimum (handy for exactness
//! tests). Quadratics have *known* L and μ from the spectrum of the
//! empirical covariance, so theory-driven stepsizes are exact here.

use super::data::RegShard;
use super::{spectral_norm_sq, Problem};
use crate::linalg::matrix::{vaxpy, vdot};
use crate::linalg::Mat;

/// Decentralized least squares (ridge for λ₂ > 0).
pub struct LeastSquares {
    shards: Vec<RegShard>,
    pub lambda2: f64,
    batches: usize,
    dim: usize,
    l_smooth: f64,
    mu: f64,
}

impl LeastSquares {
    pub fn new(shards: Vec<RegShard>, lambda2: f64, batches: usize) -> LeastSquares {
        assert!(!shards.is_empty());
        let dim = shards[0].features.cols;
        for s in &shards {
            assert_eq!(s.features.cols, dim);
            assert_eq!(s.features.rows, s.targets.len());
            assert_eq!(s.features.rows % batches, 0);
        }
        // batchwise smoothness: L_ij = σ_max(A_b)²/|b| + 2λ₂
        let mut l_data: f64 = 0.0;
        for (i, s) in shards.iter().enumerate() {
            let bs = s.features.rows / batches;
            for b in 0..batches {
                let rows: Vec<Vec<f64>> =
                    (b * bs..(b + 1) * bs).map(|r| s.features.row(r).to_vec()).collect();
                let ab = Mat::from_rows(&rows);
                let sn = spectral_norm_sq(&ab, 60, 77 + (i * batches + b) as u64);
                l_data = l_data.max(sn / bs as f64);
            }
        }
        // μ: strong convexity from the regularizer alone (a valid lower
        // bound whether or not the designs are full-rank).
        LeastSquares {
            shards,
            lambda2,
            batches,
            dim,
            l_smooth: l_data + 2.0 * lambda2,
            mu: 2.0 * lambda2,
        }
    }

    /// Override μ when the aggregate design is known full-rank (tightens
    /// theory-driven stepsizes).
    pub fn with_mu(mut self, mu: f64) -> LeastSquares {
        assert!(mu > 0.0);
        self.mu = mu;
        self
    }

    /// μ from the smallest eigenvalue of the *global* averaged Hessian
    /// (1/n)Σᵢ A_iᵀA_i/mᵢ + 2λ₂I — exact strong convexity of the average
    /// objective. O(p³) via the Jacobi eigensolver; fine at setup time.
    pub fn exact_global_mu(&self) -> f64 {
        let n = self.shards.len();
        let mut h = Mat::zeros(self.dim, self.dim);
        for s in &self.shards {
            let ata = s.features.t_matmul(&s.features);
            h.axpy(1.0 / (n as f64 * s.targets.len() as f64), &ata);
        }
        let (evals, _) = crate::linalg::eigen::sym_eigen(&h);
        let lmin = evals.iter().cloned().fold(f64::MAX, f64::min).max(0.0);
        lmin + 2.0 * self.lambda2
    }

    fn grad_slice(&self, node: usize, lo: usize, hi: usize, x: &[f64], out: &mut [f64]) {
        let s = &self.shards[node];
        out.iter_mut().for_each(|v| *v = 0.0);
        let inv_m = 1.0 / (hi - lo) as f64;
        for r in lo..hi {
            let resid = vdot(s.features.row(r), x) - s.targets[r];
            vaxpy(out, resid * inv_m, s.features.row(r));
        }
        let reg = 2.0 * self.lambda2;
        for (o, &xi) in out.iter_mut().zip(x) {
            *o += reg * xi;
        }
    }

    pub fn shards(&self) -> &[RegShard] {
        &self.shards
    }
}

impl Problem for LeastSquares {
    fn dim(&self) -> usize {
        self.dim
    }
    fn num_nodes(&self) -> usize {
        self.shards.len()
    }
    fn num_batches(&self) -> usize {
        self.batches
    }

    fn loss(&self, node: usize, x: &[f64]) -> f64 {
        let s = &self.shards[node];
        let m = s.targets.len();
        let mut acc = 0.0;
        for r in 0..m {
            let resid = vdot(s.features.row(r), x) - s.targets[r];
            acc += resid * resid;
        }
        acc / (2.0 * m as f64) + self.lambda2 * x.iter().map(|v| v * v).sum::<f64>()
    }

    fn grad(&self, node: usize, x: &[f64], out: &mut [f64]) {
        self.grad_slice(node, 0, self.shards[node].targets.len(), x, out);
    }

    fn grad_batch(&self, node: usize, batch: usize, x: &[f64], out: &mut [f64]) {
        let m = self.shards[node].targets.len();
        let bs = m / self.batches;
        self.grad_slice(node, batch * bs, (batch + 1) * bs, x, out);
    }

    fn smoothness(&self) -> f64 {
        self.l_smooth
    }
    fn strong_convexity(&self) -> f64 {
        self.mu
    }
    fn name(&self) -> String {
        format!("lsq(n={},p={},λ2={})", self.shards.len(), self.dim, self.lambda2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problem::data::sparse_regression;
    use crate::problem::testutil::{check_batch_consistency, check_gradient};
    use crate::util::rng::Rng;

    fn small() -> LeastSquares {
        let (shards, _) = sparse_regression(3, 24, 10, 4, 0.05, 13);
        LeastSquares::new(shards, 1e-2, 4)
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let p = small();
        let mut rng = Rng::new(5);
        let x: Vec<f64> = (0..p.dim()).map(|_| rng.normal()).collect();
        for node in 0..p.num_nodes() {
            check_gradient(&p, node, &x, 1e-5);
        }
    }

    #[test]
    fn batch_average_is_full_gradient() {
        let p = small();
        let mut rng = Rng::new(6);
        let x: Vec<f64> = (0..p.dim()).map(|_| rng.normal()).collect();
        for node in 0..p.num_nodes() {
            check_batch_consistency(&p, node, &x, 1e-10);
        }
    }

    #[test]
    fn ridge_closed_form_is_stationary() {
        // global optimum solves (H + 2λ₂I)x = c; the averaged gradient there is 0
        let p = small();
        let n = p.num_nodes();
        let dim = p.dim();
        let mut h = Mat::zeros(dim, dim);
        let mut c = vec![0.0; dim];
        for s in p.shards() {
            let m = s.targets.len() as f64;
            h.axpy(1.0 / (n as f64 * m), &s.features.t_matmul(&s.features));
            for (r, &t) in s.targets.iter().enumerate() {
                vaxpy(&mut c, t / (n as f64 * m), s.features.row(r));
            }
        }
        for i in 0..dim {
            h[(i, i)] += 2.0 * p.lambda2;
        }
        // solve via eigen decomposition (symmetric PD)
        let (evals, vecs) = crate::linalg::eigen::sym_eigen(&h);
        let mut x = vec![0.0; dim];
        for (j, &lam) in evals.iter().enumerate() {
            let vj = vecs.col(j);
            let coef = vdot(&vj, &c) / lam;
            vaxpy(&mut x, coef, &vj);
        }
        let mut g = vec![0.0; dim];
        p.global_grad(&x, &mut g);
        assert!(crate::linalg::matrix::vnorm(&g) < 1e-8);
    }

    #[test]
    fn exact_mu_at_least_regularizer() {
        let p = small();
        let mu = p.exact_global_mu();
        assert!(mu >= 2.0 * p.lambda2 - 1e-12);
        assert!(mu <= p.smoothness());
    }
}
