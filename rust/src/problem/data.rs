//! Synthetic data generators — the MNIST substitution (DESIGN.md §4).
//!
//! The paper's experiment distributes MNIST across 8 nodes *sorted by
//! label*, so each node sees an extremely skewed class distribution (the
//! heterogeneous-data regime the theory is proud of handling without
//! bounded-heterogeneity assumptions). What the algorithms are sensitive to
//! is (a) strong convexity from λ₂, (b) smoothness L of the design, and
//! (c) cross-node heterogeneity — all three are reproduced by Gaussian
//! class blobs partitioned label-sorted.

use crate::linalg::Mat;
use crate::util::rng::Rng;

/// One node's classification shard: feature matrix (samples × d) and labels.
#[derive(Clone, Debug)]
pub struct ClassShard {
    pub features: Mat,
    pub labels: Vec<usize>,
}

/// How samples are assigned to nodes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Partition {
    /// Sort by label, then split contiguously — each node sees ~(C/n)
    /// classes. The paper's "non-iid" setting.
    LabelSorted,
    /// Global shuffle — every node sees every class. The easy iid baseline
    /// used in heterogeneity ablations.
    Shuffled,
}

/// Configuration for the Gaussian-blob classification generator.
#[derive(Clone, Debug)]
pub struct BlobSpec {
    pub nodes: usize,
    pub samples_per_node: usize,
    pub dim: usize,
    pub classes: usize,
    /// Distance scale between class means (bigger = more separable).
    pub separation: f64,
    /// Within-class noise std.
    pub noise: f64,
    pub partition: Partition,
    pub seed: u64,
}

impl Default for BlobSpec {
    fn default() -> Self {
        // mirrors §5 at laptop scale: 8 nodes, 10 classes, label-sorted
        BlobSpec {
            nodes: 8,
            samples_per_node: 120,
            dim: 32,
            classes: 10,
            separation: 2.0,
            noise: 1.0,
            partition: Partition::LabelSorted,
            seed: 42,
        }
    }
}

/// Generate "MNIST-like" Gaussian-blob classification data, partitioned
/// across nodes. Total samples = nodes × samples_per_node.
pub fn blobs(spec: &BlobSpec) -> Vec<ClassShard> {
    assert!(spec.nodes > 0 && spec.classes > 0 && spec.dim > 0);
    let mut rng = Rng::new(spec.seed);
    let total = spec.nodes * spec.samples_per_node;

    // class means on a scaled Gaussian cloud
    let mut means = Mat::zeros(spec.classes, spec.dim);
    rng.fill_normal(&mut means.data);
    means.scale(spec.separation);

    // draw (feature, label) pairs with balanced class counts
    let mut samples: Vec<(Vec<f64>, usize)> = Vec::with_capacity(total);
    for s in 0..total {
        let c = s % spec.classes; // balanced
        let mut x: Vec<f64> = means.row(c).to_vec();
        for v in x.iter_mut() {
            *v += spec.noise * rng.normal();
        }
        samples.push((x, c));
    }

    match spec.partition {
        Partition::LabelSorted => samples.sort_by_key(|(_, c)| *c),
        Partition::Shuffled => {
            // Fisher–Yates
            for i in (1..samples.len()).rev() {
                let j = rng.below(i + 1);
                samples.swap(i, j);
            }
        }
    }

    // contiguous split into node shards
    (0..spec.nodes)
        .map(|i| {
            let start = i * spec.samples_per_node;
            let chunk = &samples[start..start + spec.samples_per_node];
            let rows: Vec<Vec<f64>> = chunk.iter().map(|(x, _)| x.clone()).collect();
            ClassShard {
                features: Mat::from_rows(&rows),
                labels: chunk.iter().map(|(_, c)| *c).collect(),
            }
        })
        .collect()
}

/// One node's regression shard: (A_i, b_i).
#[derive(Clone, Debug)]
pub struct RegShard {
    pub features: Mat,
    pub targets: Vec<f64>,
}

/// Configuration for the synthetic regression generator behind the
/// least-squares / lasso problem kinds: Gaussian designs A_i and targets
/// b_i = A_i x♯ + ε distributed over `nodes` shards.
#[derive(Clone, Debug)]
pub struct RegSpec {
    pub nodes: usize,
    pub samples_per_node: usize,
    pub dim: usize,
    /// Non-zeros in the ground truth x♯: 0 ⇒ dense Gaussian x♯ (the ridge
    /// suite), k > 0 ⇒ k-sparse ±[0.5, 1.5] entries (the lasso suite).
    pub sparsity: usize,
    /// Target noise std ε.
    pub noise: f64,
    pub seed: u64,
}

/// Generate regression data per [`RegSpec`]. Returns (shards, x♯).
/// Deterministic in the seed; the sparse path draws the exact sequence
/// [`sparse_regression`] historically drew, so existing fixtures are
/// unchanged.
pub fn regression(spec: &RegSpec) -> (Vec<RegShard>, Vec<f64>) {
    assert!(spec.nodes > 0 && spec.dim > 0);
    let mut rng = Rng::new(spec.seed);
    let dim = spec.dim;
    let mut x_true = vec![0.0; dim];
    if spec.sparsity == 0 || spec.sparsity >= dim {
        // dense ground truth (ridge / generic least squares)
        for v in x_true.iter_mut() {
            *v = rng.normal();
        }
    } else {
        // k-sparse ground truth with ±1-ish entries
        let mut idx: Vec<usize> = (0..dim).collect();
        for i in (1..dim).rev() {
            let j = rng.below(i + 1);
            idx.swap(i, j);
        }
        for &j in idx.iter().take(spec.sparsity) {
            x_true[j] = if rng.bernoulli(0.5) { 1.0 } else { -1.0 } * rng.range(0.5, 1.5);
        }
    }

    let shards = (0..spec.nodes)
        .map(|_| {
            let mut a = Mat::zeros(spec.samples_per_node, dim);
            rng.fill_normal(&mut a.data);
            let targets: Vec<f64> = (0..spec.samples_per_node)
                .map(|s| {
                    crate::linalg::matrix::vdot(a.row(s), &x_true) + spec.noise * rng.normal()
                })
                .collect();
            RegShard { features: a, targets }
        })
        .collect();
    (shards, x_true)
}

/// Sparse linear-regression data b = A x♯ + ε with a k-sparse ground truth,
/// for the decentralized lasso example. Returns (shards, x♯). Thin wrapper
/// over [`regression`] (`sparsity >= dim` or 0 falls back to a dense x♯).
pub fn sparse_regression(
    nodes: usize,
    samples_per_node: usize,
    dim: usize,
    sparsity: usize,
    noise: f64,
    seed: u64,
) -> (Vec<RegShard>, Vec<f64>) {
    regression(&RegSpec { nodes, samples_per_node, dim, sparsity, noise, seed })
}

/// Heterogeneity index of a label partition: mean over nodes of the
/// total-variation distance between the node's class histogram and the
/// global histogram. 0 = perfectly iid, →1 as nodes become single-class.
pub fn heterogeneity_index(shards: &[ClassShard], classes: usize) -> f64 {
    let total: usize = shards.iter().map(|s| s.labels.len()).sum();
    let mut global = vec![0.0; classes];
    for s in shards {
        for &c in &s.labels {
            global[c] += 1.0;
        }
    }
    global.iter_mut().for_each(|g| *g /= total as f64);
    let mut acc = 0.0;
    for s in shards {
        let mut local = vec![0.0; classes];
        for &c in &s.labels {
            local[c] += 1.0;
        }
        local.iter_mut().for_each(|l| *l /= s.labels.len() as f64);
        let tv: f64 = local
            .iter()
            .zip(&global)
            .map(|(l, g)| (l - g).abs())
            .sum::<f64>()
            / 2.0;
        acc += tv;
    }
    acc / shards.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blobs_shapes_and_balance() {
        let spec = BlobSpec {
            nodes: 4,
            samples_per_node: 50,
            dim: 8,
            classes: 5,
            ..Default::default()
        };
        let shards = blobs(&spec);
        assert_eq!(shards.len(), 4);
        for s in &shards {
            assert_eq!(s.features.rows, 50);
            assert_eq!(s.features.cols, 8);
            assert_eq!(s.labels.len(), 50);
            assert!(s.labels.iter().all(|&c| c < 5));
        }
        // balanced classes overall
        let mut counts = vec![0usize; 5];
        for s in &shards {
            for &c in &s.labels {
                counts[c] += 1;
            }
        }
        assert!(counts.iter().all(|&c| c == 40));
    }

    #[test]
    fn label_sorted_is_heterogeneous_shuffled_is_not() {
        let base = BlobSpec {
            nodes: 8,
            samples_per_node: 100,
            dim: 4,
            classes: 8,
            ..Default::default()
        };
        let sorted = blobs(&BlobSpec { partition: Partition::LabelSorted, ..base.clone() });
        let shuffled = blobs(&BlobSpec { partition: Partition::Shuffled, ..base });
        let h_sorted = heterogeneity_index(&sorted, 8);
        let h_shuffled = heterogeneity_index(&shuffled, 8);
        assert!(h_sorted > 0.8, "label-sorted should be extreme: {h_sorted}");
        assert!(h_shuffled < 0.25, "shuffled should be near-iid: {h_shuffled}");
    }

    #[test]
    fn blobs_deterministic_in_seed() {
        let spec = BlobSpec::default();
        let a = blobs(&spec);
        let b = blobs(&spec);
        assert_eq!(a[0].features.data, b[0].features.data);
        assert_eq!(a[3].labels, b[3].labels);
    }

    #[test]
    fn sparse_regression_ground_truth() {
        let (shards, x_true) = sparse_regression(3, 40, 20, 5, 0.0, 9);
        assert_eq!(x_true.iter().filter(|&&v| v != 0.0).count(), 5);
        // zero noise ⇒ targets reproduce exactly
        for s in &shards {
            for (i, &b) in s.targets.iter().enumerate() {
                let pred = crate::linalg::matrix::vdot(s.features.row(i), &x_true);
                assert!((pred - b).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn dense_regression_ground_truth() {
        let spec = RegSpec {
            nodes: 3,
            samples_per_node: 20,
            dim: 10,
            sparsity: 0,
            noise: 0.0,
            seed: 11,
        };
        let (shards, x_true) = regression(&spec);
        assert_eq!(shards.len(), 3);
        // dense truth: every coordinate drawn (almost surely non-zero)
        assert!(x_true.iter().filter(|&&v| v != 0.0).count() > 7);
        for s in &shards {
            assert_eq!(s.features.rows, 20);
            assert_eq!(s.features.cols, 10);
            for (i, &b) in s.targets.iter().enumerate() {
                let pred = crate::linalg::matrix::vdot(s.features.row(i), &x_true);
                assert!((pred - b).abs() < 1e-12);
            }
        }
        // deterministic in the seed
        let (again, xt) = regression(&spec);
        assert_eq!(again[0].features.data, shards[0].features.data);
        assert_eq!(xt, x_true);
    }

    #[test]
    fn separation_controls_class_distance() {
        let tight = blobs(&BlobSpec { separation: 0.1, seed: 5, ..Default::default() });
        let wide = blobs(&BlobSpec { separation: 10.0, seed: 5, ..Default::default() });
        // feature energy grows with separation
        let e = |s: &[ClassShard]| s.iter().map(|x| x.features.norm_sq()).sum::<f64>();
        assert!(e(&wide) > 10.0 * e(&tight));
    }
}
