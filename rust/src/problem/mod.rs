//! Decentralized problem instances: the smooth components {f_i} of (1).
//!
//! A [`Problem`] owns the data of all n nodes and exposes local losses,
//! full local gradients, and per-batch gradients (the finite-sum setting,
//! m batches per node). Concrete problems:
//!
//! - [`logreg::LogReg`] — multinomial logistic regression + λ₂‖x‖², the
//!   paper's §5 workload;
//! - [`quadratic::LeastSquares`] — ridge / lasso-ready least squares, used
//!   by Table 3's quadratic suite and the lasso example.
//!
//! Synthetic data generators (the MNIST substitution — see DESIGN.md §4)
//! live in [`data`].

pub mod data;
pub mod logreg;
pub mod quadratic;

pub use logreg::LogReg;
pub use quadratic::LeastSquares;

use crate::linalg::Mat;
use std::fmt;

/// Which problem family a configuration names — the key of the problem
/// registry (`problem = logreg | least-squares | lasso` in config files).
/// Resolution from a [`crate::config::Config`] to a built [`Problem`]
/// happens in exactly one place: [`crate::exp::build_problem`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProblemKind {
    /// Multinomial logistic regression + λ₂‖x‖² on label-sorted Gaussian
    /// blobs — the paper's §5 workload ([`LogReg`]).
    LogReg,
    /// Ridge-regularized least squares on dense-ground-truth regression
    /// data — Table 3's quadratic suite ([`LeastSquares`]).
    LeastSquares,
    /// Least squares on k-sparse-ground-truth data with λ₁‖x‖₁ handled by
    /// the prox — the decentralized lasso (also [`LeastSquares`]; the
    /// generator and the intended prox differ).
    Lasso,
}

impl ProblemKind {
    /// Canonical config-file spelling.
    pub fn name(&self) -> &'static str {
        match self {
            ProblemKind::LogReg => "logreg",
            ProblemKind::LeastSquares => "least-squares",
            ProblemKind::Lasso => "lasso",
        }
    }
}

impl fmt::Display for ProblemKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for ProblemKind {
    type Err = String;

    fn from_str(s: &str) -> Result<ProblemKind, String> {
        Ok(match s {
            "logreg" | "logistic" | "softmax" => ProblemKind::LogReg,
            "least-squares" | "leastsquares" | "lsq" | "ridge" => ProblemKind::LeastSquares,
            "lasso" | "sparse-regression" => ProblemKind::Lasso,
            other => {
                return Err(format!(
                    "unknown problem '{other}' (expected logreg | least-squares | lasso)"
                ))
            }
        })
    }
}

/// The smooth part of a decentralized composite problem: n nodes, each with
/// a local f_i that is an average of m batch losses f_ij (finite-sum form).
pub trait Problem: Send + Sync {
    /// Flattened parameter dimension p (for multinomial logreg, p = d·C).
    fn dim(&self) -> usize;

    /// Number of nodes n.
    fn num_nodes(&self) -> usize;

    /// Number of finite-sum batches m per node.
    fn num_batches(&self) -> usize;

    /// Local loss f_i(x) (including any smooth regularizer folded into f).
    fn loss(&self, node: usize, x: &[f64]) -> f64;

    /// Full local gradient ∇f_i(x), written into `out`.
    fn grad(&self, node: usize, x: &[f64], out: &mut [f64]);

    /// Gradient of the j-th batch loss ∇f_ij(x), written into `out`.
    fn grad_batch(&self, node: usize, batch: usize, x: &[f64], out: &mut [f64]);

    /// Smoothness constant L (Assumption 4); an upper estimate is fine.
    fn smoothness(&self) -> f64;

    /// Strong-convexity constant μ > 0 (Assumption 4).
    fn strong_convexity(&self) -> f64;

    /// Short tag for logs/tables.
    fn name(&self) -> String;

    /// Global objective F(X)/n = (1/n) Σᵢ f_i(xᵢ) evaluated at a consensual x.
    fn global_loss(&self, x: &[f64]) -> f64 {
        (0..self.num_nodes()).map(|i| self.loss(i, x)).sum::<f64>() / self.num_nodes() as f64
    }

    /// Average gradient (1/n) Σᵢ ∇f_i(x) at a consensual x, into `out`.
    fn global_grad(&self, x: &[f64], out: &mut [f64]) {
        let n = self.num_nodes();
        let mut tmp = vec![0.0; self.dim()];
        out.iter_mut().for_each(|v| *v = 0.0);
        for i in 0..n {
            self.grad(i, x, &mut tmp);
            for (o, &t) in out.iter_mut().zip(&tmp) {
                *o += t;
            }
        }
        let inv = 1.0 / n as f64;
        out.iter_mut().for_each(|v| *v *= inv);
    }

    /// Stacked gradient ∇F(X): row i is ∇f_i(xᵢ). `x` and `out` are n×p.
    fn grad_all(&self, x: &Mat, out: &mut Mat) {
        assert_eq!(x.rows, self.num_nodes());
        assert_eq!(x.cols, self.dim());
        for i in 0..self.num_nodes() {
            // split borrow: rows of out are disjoint
            let xi = x.row(i).to_vec();
            self.grad(i, &xi, out.row_mut(i));
        }
    }

    /// Condition number κ_f = L/μ.
    fn kappa_f(&self) -> f64 {
        self.smoothness() / self.strong_convexity()
    }

    /// Downcast hook for logreg-specific diagnostics (e.g. the
    /// heterogeneity index over class shards). Wrappers that delegate to a
    /// native [`LogReg`] override this to expose it.
    fn as_logreg(&self) -> Option<&LogReg> {
        None
    }
}

/// Estimate the largest singular value squared σ_max(A)² via power iteration
/// on AᵀA (forty iterations is plenty for the L estimates we need).
pub fn spectral_norm_sq(a: &Mat, iters: usize, seed: u64) -> f64 {
    use crate::linalg::matrix::{vnorm, vnorm_sq};
    use crate::util::rng::Rng;
    let mut rng = Rng::new(seed);
    let p = a.cols;
    if p == 0 || a.rows == 0 {
        return 0.0;
    }
    let mut v: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
    let nv = vnorm(&v).max(1e-300);
    v.iter_mut().for_each(|x| *x /= nv);
    let mut lam = 0.0;
    for _ in 0..iters {
        // w = Aᵀ(Av)
        let mut av = vec![0.0; a.rows];
        for (i, avi) in av.iter_mut().enumerate() {
            *avi = crate::linalg::matrix::vdot(a.row(i), &v);
        }
        let mut w = vec![0.0; p];
        for (i, &avi) in av.iter().enumerate() {
            if avi != 0.0 {
                crate::linalg::matrix::vaxpy(&mut w, avi, a.row(i));
            }
        }
        lam = vnorm_sq(&w).sqrt(); // ‖AᵀAv‖ ≈ λ_max since ‖v‖=1
        let nw = vnorm(&w).max(1e-300);
        v = w;
        v.iter_mut().for_each(|x| *x /= nw);
    }
    lam
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::Problem;
    use crate::util::rng::Rng;

    /// Central finite-difference check of ∇f_i against the loss.
    pub fn check_gradient(p: &dyn Problem, node: usize, x: &[f64], tol: f64) {
        let dim = p.dim();
        let mut g = vec![0.0; dim];
        p.grad(node, x, &mut g);
        let mut rng = Rng::new(7 + node as u64);
        // probe a handful of random coordinates (full FD is O(p²))
        for _ in 0..dim.min(12) {
            let j = rng.below(dim);
            let h = 1e-6 * (1.0 + x[j].abs());
            let mut xp = x.to_vec();
            let mut xm = x.to_vec();
            xp[j] += h;
            xm[j] -= h;
            let fd = (p.loss(node, &xp) - p.loss(node, &xm)) / (2.0 * h);
            assert!(
                (fd - g[j]).abs() <= tol * (1.0 + fd.abs()),
                "grad mismatch at coord {j}: fd={fd} analytic={}",
                g[j]
            );
        }
    }

    /// The batch average must reproduce the full local gradient:
    /// f_i = (1/m) Σ_j f_ij  ⇒  ∇f_i = (1/m) Σ_j ∇f_ij.
    pub fn check_batch_consistency(p: &dyn Problem, node: usize, x: &[f64], tol: f64) {
        let dim = p.dim();
        let m = p.num_batches();
        let mut acc = vec![0.0; dim];
        let mut tmp = vec![0.0; dim];
        for b in 0..m {
            p.grad_batch(node, b, x, &mut tmp);
            for (a, &t) in acc.iter_mut().zip(&tmp) {
                *a += t;
            }
        }
        acc.iter_mut().for_each(|v| *v /= m as f64);
        let mut full = vec![0.0; dim];
        p.grad(node, x, &mut full);
        for (j, (&a, &f)) in acc.iter().zip(&full).enumerate() {
            assert!(
                (a - f).abs() <= tol * (1.0 + f.abs()),
                "batch-average grad mismatch at {j}: {a} vs {f}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn problem_kind_parses_aliases_and_rejects_unknown() {
        assert_eq!("logreg".parse::<ProblemKind>().unwrap(), ProblemKind::LogReg);
        assert_eq!("logistic".parse::<ProblemKind>().unwrap(), ProblemKind::LogReg);
        assert_eq!("least-squares".parse::<ProblemKind>().unwrap(), ProblemKind::LeastSquares);
        assert_eq!("ridge".parse::<ProblemKind>().unwrap(), ProblemKind::LeastSquares);
        assert_eq!("lasso".parse::<ProblemKind>().unwrap(), ProblemKind::Lasso);
        assert!("warp".parse::<ProblemKind>().is_err());
        // canonical names round-trip through FromStr
        for kind in [ProblemKind::LogReg, ProblemKind::LeastSquares, ProblemKind::Lasso] {
            assert_eq!(kind.name().parse::<ProblemKind>().unwrap(), kind);
        }
    }

    #[test]
    fn spectral_norm_of_diagonal() {
        // A = diag(3, 1) (as 2x2): σ_max² = 9
        let a = Mat::from_rows(&[vec![3.0, 0.0], vec![0.0, 1.0]]);
        let s = spectral_norm_sq(&a, 60, 1);
        assert!((s - 9.0).abs() < 1e-6, "{s}");
    }

    #[test]
    fn spectral_norm_random_vs_eigen() {
        let mut rng = Rng::new(3);
        let mut a = Mat::zeros(12, 6);
        rng.fill_normal(&mut a.data);
        let s = spectral_norm_sq(&a, 200, 1);
        // reference: largest eigenvalue of AᵀA via the Jacobi eigensolver
        let ata = a.t_matmul(&a);
        let (evals, _) = crate::linalg::eigen::sym_eigen(&ata);
        let lmax = evals.iter().cloned().fold(f64::MIN, f64::max);
        assert!((s - lmax).abs() < 1e-6 * lmax.max(1.0), "{s} vs {lmax}");
    }
}
