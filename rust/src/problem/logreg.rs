//! Multinomial (softmax) logistic regression with an ℓ₂² smooth regularizer
//! — the paper's §5 experimental workload.
//!
//! Parameters are a d×C weight matrix flattened row-major into x ∈ ℝ^{dC}.
//! Node i holds (A_i, y_i) and
//!
//! ```text
//! f_i(x) = −(1/mᵢ) Σ_s log softmax(a_s W)[y_s] + λ₂‖x‖²,
//! ∇f_i(x) = (1/mᵢ) A_iᵀ (softmax(A_i W) − Y_i) + 2λ₂ W.
//! ```
//!
//! The non-smooth λ₁‖x‖₁ term of the paper's non-smooth experiments is NOT
//! part of this struct — it is handled by the algorithms' prox operator
//! ([`crate::prox::L1`]).
//!
//! The gradient hot-spot `A_iᵀ(softmax(A_i W) − Y_i)` is exactly the
//! computation the L1 Pallas kernel implements; the PJRT-backed variant
//! lives in `crate::runtime` and is tested against this native code.

use super::data::ClassShard;
use super::{spectral_norm_sq, Problem};
use crate::linalg::Mat;

/// Row-wise numerically-stable softmax, in place over an m×C matrix.
pub fn softmax_rows(logits: &mut Mat) {
    for i in 0..logits.rows {
        let row = logits.row_mut(i);
        let mx = row.iter().fold(f64::NEG_INFINITY, |m, &v| m.max(v));
        let mut z = 0.0;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            z += *v;
        }
        let inv = 1.0 / z;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// The multinomial logistic-regression problem over n nodes.
pub struct LogReg {
    shards: Vec<ClassShard>,
    pub classes: usize,
    pub features: usize,
    /// Smooth ℓ₂² coefficient λ₂ (paper: 5e-3).
    pub lambda2: f64,
    batches: usize,
    l_smooth: f64,
}

impl LogReg {
    /// Build from per-node shards. `batches` is the paper's m (15 in §5);
    /// sample counts must be divisible by `batches`.
    pub fn new(shards: Vec<ClassShard>, classes: usize, lambda2: f64, batches: usize) -> LogReg {
        assert!(!shards.is_empty());
        let features = shards[0].features.cols;
        for s in &shards {
            assert_eq!(s.features.cols, features, "feature dim mismatch across nodes");
            assert_eq!(
                s.features.rows % batches,
                0,
                "samples per node must divide into batches"
            );
            assert!(s.labels.iter().all(|&c| c < classes));
        }
        // Smoothness of each *batch* loss (Assumption 4 finite-sum form):
        // Hessian of softmax-CE w.r.t. W is ≼ (1/2)·(A_bᵀA_b/|b|) ⊗ I_C, so
        // L_ij ≤ σ_max(A_b)²/(2|b|) + 2λ₂. Take the max over (i, j); it also
        // bounds the full-gradient L since f_i is the batch average.
        let mut l_data: f64 = 0.0;
        for (i, s) in shards.iter().enumerate() {
            let bs = s.features.rows / batches;
            for b in 0..batches {
                let rows: Vec<Vec<f64>> =
                    (b * bs..(b + 1) * bs).map(|r| s.features.row(r).to_vec()).collect();
                let ab = Mat::from_rows(&rows);
                let sn = spectral_norm_sq(&ab, 60, 1000 + (i * batches + b) as u64);
                l_data = l_data.max(sn / (2.0 * bs as f64));
            }
        }
        LogReg {
            shards,
            classes,
            features,
            lambda2,
            batches,
            l_smooth: l_data + 2.0 * lambda2,
        }
    }

    /// Convenience constructor from a [`super::data::BlobSpec`].
    pub fn from_blobs(spec: &super::data::BlobSpec, lambda2: f64, batches: usize) -> LogReg {
        LogReg::new(super::data::blobs(spec), spec.classes, lambda2, batches)
    }

    #[inline]
    fn weights(&self, x: &[f64]) -> Mat {
        debug_assert_eq!(x.len(), self.features * self.classes);
        Mat::from_vec(self.features, self.classes, x.to_vec())
    }

    /// softmax(A_slice · W) − Y_slice and the mean CE loss over the slice.
    fn residual(&self, node: usize, lo: usize, hi: usize, w: &Mat) -> (Mat, f64) {
        let s = &self.shards[node];
        let rows: Vec<Vec<f64>> = (lo..hi).map(|r| s.features.row(r).to_vec()).collect();
        let a = Mat::from_rows(&rows);
        let mut probs = a.matmul(w);
        // loss needs log-softmax at the true label BEFORE overwriting
        let mut loss = 0.0;
        for (ri, r) in (lo..hi).enumerate() {
            let row = probs.row(ri);
            let mx = row.iter().fold(f64::NEG_INFINITY, |m, &v| m.max(v));
            let lse = mx + row.iter().map(|&v| (v - mx).exp()).sum::<f64>().ln();
            loss += lse - row[s.labels[r]];
        }
        loss /= (hi - lo) as f64;
        softmax_rows(&mut probs);
        for (ri, r) in (lo..hi).enumerate() {
            probs[(ri, s.labels[r])] -= 1.0;
        }
        (a.t_matmul(&probs), loss) // (AᵀΔ: d×C, mean CE)
    }

    /// Fused gradient over the contiguous sample slice [lo, hi) — the hot
    /// path. Operates directly on the stored row-major feature buffer (no
    /// Mat construction, one logits scratch allocation), mirroring the L1
    /// Pallas kernel's fused softmax-residual structure. See EXPERIMENTS.md
    /// §Perf for the before/after.
    fn grad_slice(&self, node: usize, lo: usize, hi: usize, x: &[f64], out: &mut [f64]) {
        let s = &self.shards[node];
        let d = self.features;
        let c = self.classes;
        let mb = hi - lo;
        let a = &s.features.data[lo * d..hi * d];

        // logits = A_b · W — ikj over the flattened weight rows (the
        // zero-skip branch measured faster than branchless; kept)
        let mut logits = vec![0.0f64; mb * c];
        for r in 0..mb {
            let arow = &a[r * d..(r + 1) * d];
            let lrow = &mut logits[r * c..(r + 1) * c];
            for (k, &av) in arow.iter().enumerate() {
                if av != 0.0 {
                    crate::linalg::matrix::vaxpy(lrow, av, &x[k * c..(k + 1) * c]);
                }
            }
        }

        // delta = softmax(logits) − onehot(y), in place
        for (r, lbl) in s.labels[lo..hi].iter().enumerate() {
            let row = &mut logits[r * c..(r + 1) * c];
            let mx = row.iter().fold(f64::NEG_INFINITY, |m, &v| m.max(v));
            let mut z = 0.0;
            for v in row.iter_mut() {
                *v = (*v - mx).exp();
                z += *v;
            }
            let inv = 1.0 / z;
            for v in row.iter_mut() {
                *v *= inv;
            }
            row[*lbl] -= 1.0;
        }

        // out = Aᵀ·delta / mb + 2λ2·x
        out.iter_mut().for_each(|o| *o = 0.0);
        let inv_m = 1.0 / mb as f64;
        for r in 0..mb {
            let arow = &a[r * d..(r + 1) * d];
            let drow = &logits[r * c..(r + 1) * c];
            for (k, &av) in arow.iter().enumerate() {
                if av != 0.0 {
                    crate::linalg::matrix::vaxpy(&mut out[k * c..(k + 1) * c], av * inv_m, drow);
                }
            }
        }
        let reg = 2.0 * self.lambda2;
        for (o, &xi) in out.iter_mut().zip(x) {
            *o += reg * xi;
        }
    }

    /// Classification accuracy of the flattened weights on a shard set.
    pub fn accuracy(&self, x: &[f64], shards: &[ClassShard]) -> f64 {
        let w = self.weights(x);
        let (mut hit, mut tot) = (0usize, 0usize);
        for s in shards {
            let scores = s.features.matmul(&w);
            for (r, &label) in s.labels.iter().enumerate() {
                let row = scores.row(r);
                let argmax = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map_or(0, |best| best.0);
                hit += (argmax == label) as usize;
                tot += 1;
            }
        }
        hit as f64 / tot as f64
    }

    pub fn shards(&self) -> &[ClassShard] {
        &self.shards
    }

    /// Per-node sample count (uniform by construction).
    pub fn samples_per_node(&self) -> usize {
        self.shards[0].features.rows
    }
}

impl Problem for LogReg {
    fn dim(&self) -> usize {
        self.features * self.classes
    }
    fn as_logreg(&self) -> Option<&LogReg> {
        Some(self)
    }
    fn num_nodes(&self) -> usize {
        self.shards.len()
    }
    fn num_batches(&self) -> usize {
        self.batches
    }

    fn loss(&self, node: usize, x: &[f64]) -> f64 {
        let w = self.weights(x);
        let m = self.shards[node].features.rows;
        let (_, ce) = self.residual(node, 0, m, &w);
        ce + self.lambda2 * x.iter().map(|v| v * v).sum::<f64>()
    }

    fn grad(&self, node: usize, x: &[f64], out: &mut [f64]) {
        let m = self.shards[node].features.rows;
        self.grad_slice(node, 0, m, x, out);
    }

    fn grad_batch(&self, node: usize, batch: usize, x: &[f64], out: &mut [f64]) {
        let m = self.shards[node].features.rows;
        let bs = m / self.batches;
        self.grad_slice(node, batch * bs, (batch + 1) * bs, x, out);
    }

    fn smoothness(&self) -> f64 {
        self.l_smooth
    }
    fn strong_convexity(&self) -> f64 {
        2.0 * self.lambda2
    }
    fn name(&self) -> String {
        format!(
            "logreg(n={},d={},C={},λ2={})",
            self.shards.len(),
            self.features,
            self.classes,
            self.lambda2
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problem::data::{blobs, BlobSpec};
    use crate::problem::testutil::{check_batch_consistency, check_gradient};
    use crate::util::rng::Rng;

    fn small_problem() -> LogReg {
        let spec = BlobSpec {
            nodes: 3,
            samples_per_node: 30,
            dim: 6,
            classes: 4,
            seed: 11,
            ..Default::default()
        };
        LogReg::new(blobs(&spec), 4, 5e-3, 5)
    }

    #[test]
    fn softmax_rows_is_distribution() {
        let mut m = Mat::from_rows(&[vec![1.0, 2.0, 3.0], vec![-100.0, 0.0, 100.0]]);
        softmax_rows(&mut m);
        for i in 0..2 {
            let s: f64 = m.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-12);
            assert!(m.row(i).iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
        assert!(m[(1, 2)] > 0.999); // extreme logit dominates
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let p = small_problem();
        let mut rng = Rng::new(1);
        let x: Vec<f64> = (0..p.dim()).map(|_| 0.1 * rng.normal()).collect();
        for node in 0..p.num_nodes() {
            check_gradient(&p, node, &x, 1e-4);
        }
    }

    #[test]
    fn batch_gradients_average_to_full() {
        let p = small_problem();
        let mut rng = Rng::new(2);
        let x: Vec<f64> = (0..p.dim()).map(|_| 0.2 * rng.normal()).collect();
        for node in 0..p.num_nodes() {
            check_batch_consistency(&p, node, &x, 1e-10);
        }
    }

    #[test]
    fn loss_decreases_along_negative_gradient() {
        let p = small_problem();
        let x = vec![0.0; p.dim()];
        let mut g = vec![0.0; p.dim()];
        p.grad(0, &x, &mut g);
        let step: Vec<f64> = x.iter().zip(&g).map(|(xi, gi)| xi - 1e-3 * gi).collect();
        assert!(p.loss(0, &step) < p.loss(0, &x));
    }

    #[test]
    fn smoothness_bounds_gradient_lipschitz() {
        // ‖∇f(x)−∇f(y)‖ ≤ L‖x−y‖ sampled at random pairs
        let p = small_problem();
        let l = p.smoothness();
        let mut rng = Rng::new(3);
        for _ in 0..10 {
            let x: Vec<f64> = (0..p.dim()).map(|_| rng.normal()).collect();
            let y: Vec<f64> = (0..p.dim()).map(|_| rng.normal()).collect();
            let mut gx = vec![0.0; p.dim()];
            let mut gy = vec![0.0; p.dim()];
            p.grad(0, &x, &mut gx);
            p.grad(0, &y, &mut gy);
            let gd: f64 = gx.iter().zip(&gy).map(|(a, b)| (a - b) * (a - b)).sum();
            let xd: f64 = x.iter().zip(&y).map(|(a, b)| (a - b) * (a - b)).sum();
            assert!(gd.sqrt() <= l * xd.sqrt() * (1.0 + 1e-9), "{} > {}", gd.sqrt(), l * xd.sqrt());
        }
    }

    #[test]
    fn strong_convexity_from_regularizer() {
        let p = small_problem();
        assert_eq!(p.strong_convexity(), 0.01);
        assert!(p.kappa_f() >= 1.0);
    }

    #[test]
    fn accuracy_improves_with_training() {
        // a few centralized GD steps must beat random guessing
        let p = small_problem();
        let mut x = vec![0.0; p.dim()];
        let mut g = vec![0.0; p.dim()];
        let eta = 1.0 / p.smoothness();
        for _ in 0..200 {
            p.global_grad(&x, &mut g);
            for (xi, &gi) in x.iter_mut().zip(&g) {
                *xi -= eta * gi;
            }
        }
        let acc = p.accuracy(&x, p.shards());
        assert!(acc > 0.5, "trained accuracy {acc} should beat 1/4 guessing");
    }
}
