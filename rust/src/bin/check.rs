//! `proxlead-check` — schedule-exploring model checker for the sim and
//! coordinator sync protocols.
//!
//! Usage: `cargo run --release --bin check [-- [SCENARIO...] [--quick] [--json PATH]]`
//!
//! Runs the named scenarios (default: all of
//! [`proxlead::check::scenarios::NAMES`]) under the controlled scheduler:
//! bounded-preemption DFS plus seed-recorded random schedules, with
//! happens-before race tracking, deadlock detection, and outcome
//! invariance checks. Exit status: 0 every scenario passed, 1 findings,
//! 2 usage error. `--json PATH` additionally writes the
//! `proxlead-check-v1` report CI archives.

use std::path::PathBuf;
use std::process::ExitCode;

use proxlead::check::scenarios::{self, Budget};
use proxlead::check::{report_json, ScenarioReport};

fn main() -> ExitCode {
    let mut names: Vec<String> = Vec::new();
    let mut budget = Budget::Full;
    let mut json_out: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--json" => match args.next() {
                Some(p) => json_out = Some(PathBuf::from(p)),
                None => {
                    eprintln!("check: --json requires a path");
                    return ExitCode::from(2);
                }
            },
            "--quick" => budget = Budget::Quick,
            "--help" | "-h" => {
                println!("usage: check [SCENARIO...] [--quick] [--json PATH]");
                println!("scenarios: {}", scenarios::NAMES.join(", "));
                return ExitCode::SUCCESS;
            }
            flag if flag.starts_with('-') => {
                eprintln!("check: unknown flag `{flag}` (try --help)");
                return ExitCode::from(2);
            }
            name => names.push(name.to_string()),
        }
    }

    let mut reports: Vec<ScenarioReport> = Vec::new();
    if names.is_empty() {
        reports = scenarios::run_all(budget);
        for r in &reports {
            println!("{}", r.summary_line());
        }
    } else {
        for name in &names {
            match scenarios::run_by_name(name, budget) {
                Some(r) => {
                    println!("{}", r.summary_line());
                    reports.push(r);
                }
                None => {
                    eprintln!(
                        "check: unknown scenario `{name}` (known: {})",
                        scenarios::NAMES.join(", ")
                    );
                    return ExitCode::from(2);
                }
            }
        }
    }

    if let Some(path) = json_out {
        let report = report_json(&reports).to_string();
        if let Err(e) = std::fs::write(&path, report) {
            eprintln!("check: cannot write {}: {e}", path.display());
            return ExitCode::from(2);
        }
    }

    let failed: Vec<&str> = reports.iter().filter(|r| !r.pass).map(|r| r.name.as_str()).collect();
    for r in reports.iter().filter(|r| !r.pass) {
        for f in &r.findings {
            eprintln!("check: [{}] {}: {}", r.name, f.kind.name(), f.detail);
        }
    }
    if failed.is_empty() {
        println!("check: {} scenario(s) clean", reports.len());
        ExitCode::SUCCESS
    } else {
        eprintln!("check: {} scenario(s) failed: {}", failed.len(), failed.join(", "));
        ExitCode::FAILURE
    }
}
