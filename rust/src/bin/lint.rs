//! `proxlead-lint` — check the repo's standing source contracts.
//!
//! Usage: `cargo run --release --bin lint [-- [ROOT] [--json PATH]]`
//!
//! Walks `ROOT` (default: this crate's `src/`) and applies the rule table
//! in [`proxlead::lint`]. Exit status: 0 clean, 1 diagnostics found,
//! 2 usage or I/O error. `--json PATH` additionally writes the CI report.

use std::path::PathBuf;
use std::process::ExitCode;

use proxlead::lint;

fn main() -> ExitCode {
    let mut root = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/src"));
    let mut json_out: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--json" => match args.next() {
                Some(p) => json_out = Some(PathBuf::from(p)),
                None => {
                    eprintln!("lint: --json requires a path");
                    return ExitCode::from(2);
                }
            },
            "--help" | "-h" => {
                println!("usage: lint [ROOT] [--json PATH]");
                println!("rules: {}", lint::rule_ids().join(", "));
                return ExitCode::SUCCESS;
            }
            flag if flag.starts_with('-') => {
                eprintln!("lint: unknown flag `{flag}` (try --help)");
                return ExitCode::from(2);
            }
            path => root = PathBuf::from(path),
        }
    }

    let (files_scanned, diags) = match lint::lint_tree(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("lint: cannot scan {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };
    for d in &diags {
        println!("{d}");
    }
    if let Some(path) = json_out {
        let report = lint::report_json(files_scanned, &diags).to_string();
        if let Err(e) = std::fs::write(&path, report) {
            eprintln!("lint: cannot write {}: {e}", path.display());
            return ExitCode::from(2);
        }
    }
    if diags.is_empty() {
        println!("lint: {files_scanned} files clean ({} rules)", lint::rule_ids().len());
        ExitCode::SUCCESS
    } else {
        eprintln!("lint: {} diagnostic(s) across {files_scanned} files", diags.len());
        ExitCode::FAILURE
    }
}
