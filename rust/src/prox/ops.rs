//! Concrete proximal operators.
//!
//! Each has a closed form; the experiments use [`L1`] (the paper's λ₁‖X‖₁
//! regularizer, prox = soft-thresholding) and [`Zero`] (the smooth case).

use super::Prox;
use crate::linalg::matrix::vnorm;

/// r ≡ 0 — the smooth case. Prox-LEAD with `Zero` *is* LEAD (Algorithm 3).
#[derive(Clone, Copy, Debug, Default)]
pub struct Zero;

impl Prox for Zero {
    fn prox(&self, _v: &mut [f64], _eta: f64) {}
    fn eval(&self, _x: &[f64]) -> f64 {
        0.0
    }
    fn name(&self) -> String {
        "none".into()
    }
    fn is_zero(&self) -> bool {
        true
    }
}

/// r(x) = λ‖x‖₁; prox is elementwise soft-thresholding
/// `S_{ηλ}(v) = sign(v)·max(|v| − ηλ, 0)`.
#[derive(Clone, Copy, Debug)]
pub struct L1 {
    pub lambda: f64,
}

impl L1 {
    pub fn new(lambda: f64) -> L1 {
        assert!(lambda >= 0.0);
        L1 { lambda }
    }
}

/// Elementwise soft-threshold helper shared by [`L1`] and [`ElasticNet`].
#[inline(always)]
pub fn soft_threshold(v: f64, t: f64) -> f64 {
    if v > t {
        v - t
    } else if v < -t {
        v + t
    } else {
        0.0
    }
}

impl Prox for L1 {
    fn prox(&self, v: &mut [f64], eta: f64) {
        let t = eta * self.lambda;
        for x in v.iter_mut() {
            *x = soft_threshold(*x, t);
        }
    }
    fn eval(&self, x: &[f64]) -> f64 {
        self.lambda * x.iter().map(|v| v.abs()).sum::<f64>()
    }
    fn name(&self) -> String {
        format!("l1({})", self.lambda)
    }
}

/// r(x) = λ‖x‖²; prox is the shrinkage `v / (1 + 2ηλ)`.
///
/// (The paper folds its λ₂‖X‖₂² term into the *smooth* part f; this operator
/// exists so the same term can instead be handled proximally — an ablation.)
#[derive(Clone, Copy, Debug)]
pub struct SquaredL2 {
    pub lambda: f64,
}

impl SquaredL2 {
    pub fn new(lambda: f64) -> SquaredL2 {
        assert!(lambda >= 0.0);
        SquaredL2 { lambda }
    }
}

impl Prox for SquaredL2 {
    fn prox(&self, v: &mut [f64], eta: f64) {
        let s = 1.0 / (1.0 + 2.0 * eta * self.lambda);
        for x in v.iter_mut() {
            *x *= s;
        }
    }
    fn eval(&self, x: &[f64]) -> f64 {
        self.lambda * x.iter().map(|v| v * v).sum::<f64>()
    }
    fn name(&self) -> String {
        format!("l2sq({})", self.lambda)
    }
}

/// r(x) = λ₁‖x‖₁ + λ₂‖x‖² — the elastic net. Prox composes shrinkage after
/// soft-thresholding: `prox(v) = S_{ηλ₁}(v) / (1 + 2ηλ₂)`.
#[derive(Clone, Copy, Debug)]
pub struct ElasticNet {
    pub l1: f64,
    pub l2: f64,
}

impl ElasticNet {
    pub fn new(l1: f64, l2: f64) -> ElasticNet {
        assert!(l1 >= 0.0 && l2 >= 0.0);
        ElasticNet { l1, l2 }
    }
}

impl Prox for ElasticNet {
    fn prox(&self, v: &mut [f64], eta: f64) {
        let t = eta * self.l1;
        let s = 1.0 / (1.0 + 2.0 * eta * self.l2);
        for x in v.iter_mut() {
            *x = soft_threshold(*x, t) * s;
        }
    }
    fn eval(&self, x: &[f64]) -> f64 {
        self.l1 * x.iter().map(|v| v.abs()).sum::<f64>()
            + self.l2 * x.iter().map(|v| v * v).sum::<f64>()
    }
    fn name(&self) -> String {
        format!("elastic({},{})", self.l1, self.l2)
    }
}

/// Indicator of the non-negative orthant; prox is projection max(v, 0).
#[derive(Clone, Copy, Debug, Default)]
pub struct NonNegative;

impl Prox for NonNegative {
    fn prox(&self, v: &mut [f64], _eta: f64) {
        for x in v.iter_mut() {
            if *x < 0.0 {
                *x = 0.0;
            }
        }
    }
    fn eval(&self, x: &[f64]) -> f64 {
        if x.iter().all(|&v| v >= -1e-12) {
            0.0
        } else {
            f64::INFINITY
        }
    }
    fn name(&self) -> String {
        "nonneg".into()
    }
}

/// Indicator of the box [lo, hi]^p; prox is the clamp projection.
#[derive(Clone, Copy, Debug)]
pub struct BoxConstraint {
    pub lo: f64,
    pub hi: f64,
}

impl BoxConstraint {
    pub fn new(lo: f64, hi: f64) -> BoxConstraint {
        assert!(lo <= hi);
        BoxConstraint { lo, hi }
    }
}

impl Prox for BoxConstraint {
    fn prox(&self, v: &mut [f64], _eta: f64) {
        for x in v.iter_mut() {
            *x = x.clamp(self.lo, self.hi);
        }
    }
    fn eval(&self, x: &[f64]) -> f64 {
        let tol = 1e-12;
        if x.iter().all(|&v| v >= self.lo - tol && v <= self.hi + tol) {
            0.0
        } else {
            f64::INFINITY
        }
    }
    fn name(&self) -> String {
        format!("box[{},{}]", self.lo, self.hi)
    }
}

/// r(x) = λ Σ_g ‖x_g‖₂ over contiguous groups of size `group`; prox is
/// blockwise soft-thresholding of the group norm (the last group may be
/// short when p is not a multiple of `group`).
#[derive(Clone, Copy, Debug)]
pub struct GroupLasso {
    pub lambda: f64,
    pub group: usize,
}

impl GroupLasso {
    pub fn new(lambda: f64, group: usize) -> GroupLasso {
        assert!(lambda >= 0.0 && group > 0);
        GroupLasso { lambda, group }
    }
}

impl Prox for GroupLasso {
    fn prox(&self, v: &mut [f64], eta: f64) {
        let t = eta * self.lambda;
        for chunk in v.chunks_mut(self.group) {
            let n = vnorm(chunk);
            let scale = if n <= t { 0.0 } else { 1.0 - t / n };
            for x in chunk.iter_mut() {
                *x *= scale;
            }
        }
    }
    fn eval(&self, x: &[f64]) -> f64 {
        self.lambda * x.chunks(self.group).map(vnorm).sum::<f64>()
    }
    fn name(&self) -> String {
        format!("group_lasso({},{})", self.lambda, self.group)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn soft_threshold_known_values() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(-0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(1.0, 1.0), 0.0);
    }

    #[test]
    fn l1_prox_and_eval() {
        let r = L1::new(0.5);
        let mut v = vec![2.0, -0.3, 0.0, -2.0];
        r.prox(&mut v, 1.0); // threshold 0.5
        assert_eq!(v, vec![1.5, 0.0, 0.0, -1.5]);
        assert!((r.eval(&v) - 0.5 * 3.0).abs() < 1e-15);
    }

    #[test]
    fn l2sq_prox_shrinks() {
        let r = SquaredL2::new(0.5);
        let mut v = vec![2.0, -4.0];
        r.prox(&mut v, 1.0); // divide by (1 + 2*1*0.5) = 2
        assert_eq!(v, vec![1.0, -2.0]);
        assert_eq!(r.eval(&[1.0, -2.0]), 0.5 * 5.0);
    }

    #[test]
    fn elastic_net_composes() {
        let r = ElasticNet::new(0.5, 0.5);
        let l1 = L1::new(0.5);
        let l2 = SquaredL2::new(0.5);
        let mut a = vec![2.0, -0.3, 1.0];
        let mut b = a.clone();
        r.prox(&mut a, 1.0);
        l1.prox(&mut b, 1.0);
        l2.prox(&mut b, 1.0);
        assert_eq!(a, b);
    }

    #[test]
    fn projections() {
        let nn = NonNegative;
        let mut v = vec![-1.0, 2.0];
        nn.prox(&mut v, 10.0);
        assert_eq!(v, vec![0.0, 2.0]);
        assert_eq!(nn.eval(&v), 0.0);
        assert_eq!(nn.eval(&[-1.0]), f64::INFINITY);

        let bx = BoxConstraint::new(-1.0, 1.0);
        let mut v = vec![-3.0, 0.5, 7.0];
        bx.prox(&mut v, 1.0);
        assert_eq!(v, vec![-1.0, 0.5, 1.0]);
        assert_eq!(bx.eval(&v), 0.0);
        assert_eq!(bx.eval(&[2.0]), f64::INFINITY);
    }

    #[test]
    fn group_lasso_zeroes_small_groups() {
        let r = GroupLasso::new(1.0, 2);
        // group 1: norm 5 > 1 → scaled by (1 - 1/5); group 2: norm 0.5 ≤ 1 → 0
        let mut v = vec![3.0, 4.0, 0.3, 0.4];
        r.prox(&mut v, 1.0);
        assert!((v[0] - 3.0 * 0.8).abs() < 1e-12);
        assert!((v[1] - 4.0 * 0.8).abs() < 1e-12);
        assert_eq!(&v[2..], &[0.0, 0.0]);
    }

    #[test]
    fn group_lasso_ragged_tail() {
        let r = GroupLasso::new(0.1, 4);
        let mut v = vec![1.0; 6]; // groups: 4 + 2
        r.prox(&mut v, 1.0);
        assert!(v.iter().all(|&x| x > 0.0 && x < 1.0));
    }
}
