//! Proximal operators for the composite (non-smooth) term r(x).
//!
//! The paper requires r to be proper, convex, and *shared across nodes*
//! (Section 2.2: consensus of X̄ implies consensus of X only when every node
//! applies the same prox). The operator with parameter η > 0 is
//!
//! ```text
//! prox_{ηr}(v) = argmin_z  r(z) + ‖z − v‖² / (2η)
//! ```
//!
//! Algorithm 1 line 10 applies it to each row of the stacked matrix V; see
//! [`prox_rows`] / [`prox_rows_into`].

pub mod ops;

pub use ops::{BoxConstraint, ElasticNet, GroupLasso, NonNegative, SquaredL2, Zero, L1};

use crate::linalg::Mat;

/// A proximable convex function r : ℝ^p → ℝ ∪ {+∞}.
pub trait Prox: Send + Sync {
    /// In-place evaluation of prox_{ηr} on one vector.
    fn prox(&self, v: &mut [f64], eta: f64);

    /// The value r(x) (used for objective tracking; +∞ is encoded as
    /// `f64::INFINITY` for constraint indicators evaluated off-set).
    fn eval(&self, x: &[f64]) -> f64;

    /// Human-readable tag for tables/configs, e.g. `"l1(0.005)"`.
    fn name(&self) -> String;

    /// True when r ≡ 0 — lets algorithms skip the prox entirely (LEAD is
    /// Prox-LEAD with this flag true).
    fn is_zero(&self) -> bool {
        false
    }
}

/// Apply prox_{ηr} to each row of V (Algorithm 1 line 10), out of place.
pub fn prox_rows(r: &dyn Prox, v: &Mat, eta: f64) -> Mat {
    let mut out = v.clone();
    prox_rows_into(r, &mut out, eta);
    out
}

/// Apply prox_{ηr} to each row of V in place (hot loop avoids the clone).
pub fn prox_rows_into(r: &dyn Prox, v: &mut Mat, eta: f64) {
    if r.is_zero() {
        return;
    }
    for i in 0..v.rows {
        r.prox(v.row_mut(i), eta);
    }
}

/// Σᵢ r(vᵢ) over the rows of V — the stacked R(X) of problem (2).
pub fn eval_rows(r: &dyn Prox, v: &Mat) -> f64 {
    (0..v.rows).map(|i| r.eval(v.row(i))).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::qc::assert_prop;
    use crate::util::rng::Rng;

    /// prox of the zero function is the identity.
    #[test]
    fn zero_prox_is_identity() {
        let z = Zero;
        let mut v = vec![1.0, -2.0, 3.5];
        let orig = v.clone();
        z.prox(&mut v, 0.7);
        assert_eq!(v, orig);
        assert!(z.is_zero());
        assert_eq!(z.eval(&v), 0.0);
    }

    /// Non-expansiveness ‖prox(u) − prox(v)‖ ≤ ‖u − v‖ for every operator —
    /// the property the proof of Lemma 3(iii) rests on.
    #[test]
    fn prox_nonexpansive() {
        let ops: Vec<Box<dyn Prox>> = vec![
            Box::new(L1::new(0.3)),
            Box::new(SquaredL2::new(0.5)),
            Box::new(ElasticNet::new(0.2, 0.4)),
            Box::new(NonNegative),
            Box::new(BoxConstraint::new(-1.0, 2.0)),
            Box::new(GroupLasso::new(0.3, 4)),
        ];
        for op in &ops {
            assert_prop(&format!("nonexpansive {}", op.name()), 40, |g| {
                let p = g.usize_in(1, 24);
                let eta = g.f64_in(0.01, 5.0);
                let mut rng = Rng::new(g.rng.next_u64());
                let u: Vec<f64> = (0..p).map(|_| rng.normal() * 3.0).collect();
                let v: Vec<f64> = (0..p).map(|_| rng.normal() * 3.0).collect();
                let d0: f64 = u.iter().zip(&v).map(|(a, b)| (a - b) * (a - b)).sum();
                let (mut pu, mut pv) = (u.clone(), v.clone());
                op.prox(&mut pu, eta);
                op.prox(&mut pv, eta);
                let d1: f64 = pu.iter().zip(&pv).map(|(a, b)| (a - b) * (a - b)).sum();
                if d1 <= d0 + 1e-12 {
                    Ok(())
                } else {
                    Err(format!("expanded: {d1} > {d0}"))
                }
            });
        }
    }

    /// prox minimizes r(z) + ‖z−v‖²/(2η): check first-order optimality by
    /// comparing the prox objective at the prox point vs random perturbations.
    #[test]
    fn prox_is_minimizer() {
        let ops: Vec<Box<dyn Prox>> = vec![
            Box::new(L1::new(0.3)),
            Box::new(SquaredL2::new(0.5)),
            Box::new(ElasticNet::new(0.2, 0.4)),
            Box::new(GroupLasso::new(0.5, 3)),
        ];
        for op in &ops {
            assert_prop(&format!("minimizer {}", op.name()), 25, |g| {
                let p = g.usize_in(1, 12);
                let eta = g.f64_in(0.05, 2.0);
                let mut rng = Rng::new(g.rng.next_u64());
                let v: Vec<f64> = (0..p).map(|_| rng.normal() * 2.0).collect();
                let mut z = v.clone();
                op.prox(&mut z, eta);
                let obj = |x: &[f64]| {
                    op.eval(x)
                        + x.iter()
                            .zip(&v)
                            .map(|(a, b)| (a - b) * (a - b))
                            .sum::<f64>()
                            / (2.0 * eta)
                };
                let base = obj(&z);
                for _ in 0..20 {
                    let pert: Vec<f64> =
                        z.iter().map(|&x| x + 0.1 * rng.normal()).collect();
                    if obj(&pert) < base - 1e-9 {
                        return Err(format!("perturbation beats prox: {} < {base}", obj(&pert)));
                    }
                }
                Ok(())
            });
        }
    }

    #[test]
    fn prox_rows_matches_per_row() {
        let r = L1::new(0.25);
        let v = Mat::from_rows(&[vec![1.0, -0.1], vec![-2.0, 0.05]]);
        let out = prox_rows(&r, &v, 1.0);
        let mut r0 = v.row(0).to_vec();
        let mut r1 = v.row(1).to_vec();
        r.prox(&mut r0, 1.0);
        r.prox(&mut r1, 1.0);
        assert_eq!(out.row(0), &r0[..]);
        assert_eq!(out.row(1), &r1[..]);
        assert!((eval_rows(&r, &out) - (r.eval(&r0) + r.eval(&r1))).abs() < 1e-15);
    }
}
