//! The one run API — every backend, every consumer.
//!
//! The paper's central claim is that Prox-LEAD "reduces the communication
//! cost almost for free"; measuring that requires runs that stop on
//! *communication* budgets, not just round counts. This module owns the
//! whole run vocabulary, shared by the synchronous matrix engine and the
//! message-passing coordinator:
//!
//! ```text
//! RunSpec {
//!    stop: StopSet            — max rounds, target suboptimality,
//!                               cumulative-bits budget, grad-evals budget,
//!                               wall-clock deadline; ANY combination,
//!                               first hit wins
//!    record_every, schedule, seed
//! }
//!    │
//!    ├── Experiment::run(&spec)              → engine  (matrix form)
//!    ├── Experiment::run_coordinator(&spec)  → node threads + wire frames
//!    └── Experiment::run_sim(&spec)          → sharded event-driven sim
//!                                              (100k–1M nodes, wire frames)
//!              │
//!              ▼   streaming, while the run is in flight
//!        Probe::on_sample(&MetricPoint)      — live CSV, progress lines, …
//!        Probe::on_iterate(round, &Mat)      — the stacked iterate Xᵏ
//!        Probe::on_finish(&RunOutcome)
//!              │
//!              ▼
//! RunResult { backend, history: Vec<MetricPoint>, stopped_by: StopReason,
//!             elapsed, final_x }             — ONE shape for both backends
//! ```
//!
//! **Stop granularity.** The engine evaluates the [`StopSet`] after every
//! round (all counters are local). The coordinator's leader only observes
//! the network at recorded snapshots, so budget/target/deadline stops fire
//! at `record_every` granularity there — set `record_every = 1` for
//! round-exact budget stops (and for bit-identical engine ↔ coordinator
//! stop rounds, which `rust/tests/run_api.rs` pins under `Dense64`). The
//! sim backend samples on the same snapshot grid as the coordinator, so
//! the three backends stop on the same round at the same cumulative bit
//! count (`rust/tests/sim_parity.rs`).
//!
//! The deprecated shims ([`crate::engine::RunConfig`],
//! [`crate::coordinator::run_prox_lead`]) forward here and exist only for
//! sequence-pinning tests.

pub mod probe;

pub use probe::{CsvProbe, Probe, ProgressProbe};

use crate::algorithm::{suboptimality, Algorithm, Schedule};
use crate::linalg::Mat;
use crate::problem::Problem;
use crate::util::json::Json;
use std::time::{Duration, Instant};

/// One recorded metric sample — the row behind every figure in §5
/// (suboptimality vs rounds | epochs | gradient evaluations | bits).
#[derive(Clone, Copy, Debug)]
pub struct MetricPoint {
    /// Round index (1-based after the step executes; 0 = post-init state).
    pub round: usize,
    /// Cumulative batch-gradient evaluations across all nodes.
    pub grad_evals: u64,
    /// Cumulative communicated payload bits across all nodes (the
    /// entropy-coded accounting the figures plot).
    pub bits: u64,
    /// Cumulative framed wire bytes across all nodes (headers included).
    /// Real serialized bytes on the coordinator; 0 on the matrix engine,
    /// whose communication is an accounting model, not a wire.
    pub wire_bytes: u64,
    /// ‖Xᵏ − 1(x*)ᵀ‖²/n vs the reference solution.
    pub suboptimality: f64,
    /// Σᵢ ‖xᵢ − x̄‖² consensus error.
    pub consensus: f64,
    /// Wall-clock since run start.
    pub wall_ns: u128,
}

/// Which criterion ended a run (recorded in [`RunResult::stopped_by`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    /// The round budget ran out (the default end of a run).
    MaxRounds,
    /// Suboptimality fell below [`StopSet::target_subopt`].
    TargetSubopt,
    /// Cumulative payload bits reached [`StopSet::max_bits`].
    BitsBudget,
    /// Cumulative gradient evaluations reached [`StopSet::max_grad_evals`].
    GradEvalsBudget,
    /// Wall-clock passed [`StopSet::deadline`].
    Deadline,
    /// The iterate went non-finite (the run is flushed, then abandoned).
    Diverged,
    /// A node received a malformed or protocol-violating frame and the run
    /// was torn down (coordinator backend only). Carries the earliest fault
    /// by (round, node) — see [`crate::coordinator::wire::WireError`].
    WireFault(crate::coordinator::wire::WireFault),
}

impl StopReason {
    pub fn name(&self) -> &'static str {
        match self {
            StopReason::MaxRounds => "max-rounds",
            StopReason::TargetSubopt => "target-subopt",
            StopReason::BitsBudget => "bits-budget",
            StopReason::GradEvalsBudget => "grad-evals-budget",
            StopReason::Deadline => "deadline",
            StopReason::Diverged => "diverged",
            StopReason::WireFault(_) => "wire-fault",
        }
    }
}

/// Composable stop criteria: any combination, first hit wins. Ties within
/// one evaluation are broken in the fixed order target-subopt → bits →
/// grad-evals → deadline → max-rounds (divergence is detected separately
/// and beats them all).
#[derive(Clone, Copy, Debug)]
pub struct StopSet {
    /// Hard round cap — always present; the other criteria are optional.
    pub max_rounds: usize,
    /// Stop once suboptimality falls below this.
    pub target_subopt: Option<f64>,
    /// Stop once cumulative payload bits (all nodes) reach this budget.
    pub max_bits: Option<u64>,
    /// Stop once cumulative gradient evaluations reach this budget.
    pub max_grad_evals: Option<u64>,
    /// Stop once this much wall-clock has elapsed.
    pub deadline: Option<Duration>,
}

impl StopSet {
    /// A pure round cap — combinators add the optional criteria.
    pub fn rounds(max_rounds: usize) -> StopSet {
        StopSet {
            max_rounds,
            target_subopt: None,
            max_bits: None,
            max_grad_evals: None,
            deadline: None,
        }
    }

    /// First criterion hit by the given counters, if any (see the ordering
    /// contract on [`StopSet`]). `subopt` may be NaN when the caller did
    /// not measure it — NaN never triggers the target.
    pub fn check(
        &self,
        round: usize,
        bits: u64,
        grad_evals: u64,
        subopt: f64,
        elapsed: Duration,
    ) -> Option<StopReason> {
        if let Some(t) = self.target_subopt {
            if subopt < t {
                return Some(StopReason::TargetSubopt);
            }
        }
        if let Some(b) = self.max_bits {
            if bits >= b {
                return Some(StopReason::BitsBudget);
            }
        }
        if let Some(g) = self.max_grad_evals {
            if grad_evals >= g {
                return Some(StopReason::GradEvalsBudget);
            }
        }
        if let Some(d) = self.deadline {
            if elapsed >= d {
                return Some(StopReason::Deadline);
            }
        }
        if round >= self.max_rounds {
            return Some(StopReason::MaxRounds);
        }
        None
    }

    /// True when suboptimality must be measured every evaluation (an early
    /// target is set).
    pub fn needs_subopt(&self) -> bool {
        self.target_subopt.is_some()
    }

    /// True when the coordinator's leader must gate node threads at
    /// checkpoints (any criterion beyond the round cap — those need
    /// leader-side observation plus an early-stop broadcast).
    pub fn leader_gated(&self) -> bool {
        self.target_subopt.is_some()
            || self.max_bits.is_some()
            || self.max_grad_evals.is_some()
            || self.deadline.is_some()
    }
}

/// Run controls shared by both backends.
#[derive(Clone, Debug)]
pub struct RunSpec {
    pub stop: StopSet,
    /// Sample the metrics every this many rounds (1 = every round; the
    /// final round is always sampled). Must be ≥ 1.
    pub record_every: usize,
    /// Stepsize schedule applied before every round (Theorem 7 etc.).
    /// Engine-only: the coordinator's node halves run fixed
    /// hyperparameters, and `run_coordinator` rejects a schedule.
    pub schedule: Option<Schedule>,
    /// Algorithm RNG seed override (None ⇒ the experiment's config seed).
    /// Sweep cells derive theirs from the cell index.
    pub seed: Option<u64>,
}

impl RunSpec {
    /// Run for exactly `rounds` rounds, sampling every round.
    pub fn fixed(rounds: usize) -> RunSpec {
        RunSpec { stop: StopSet::rounds(rounds), record_every: 1, schedule: None, seed: None }
    }

    pub fn every(mut self, k: usize) -> RunSpec {
        self.record_every = k.max(1);
        self
    }

    /// Stop early once suboptimality falls below `subopt`.
    pub fn until(mut self, subopt: f64) -> RunSpec {
        self.stop.target_subopt = Some(subopt);
        self
    }

    /// Stop once cumulative payload bits reach `bits`.
    pub fn bits_budget(mut self, bits: u64) -> RunSpec {
        self.stop.max_bits = Some(bits);
        self
    }

    /// Stop once cumulative gradient evaluations reach `evals`.
    pub fn grad_evals_budget(mut self, evals: u64) -> RunSpec {
        self.stop.max_grad_evals = Some(evals);
        self
    }

    /// Stop once `d` of wall-clock has elapsed.
    pub fn deadline(mut self, d: Duration) -> RunSpec {
        self.stop.deadline = Some(d);
        self
    }

    pub fn with_schedule(mut self, s: Schedule) -> RunSpec {
        self.schedule = Some(s);
        self
    }

    pub fn with_seed(mut self, seed: u64) -> RunSpec {
        self.seed = Some(seed);
        self
    }
}

/// Which runtime produced a [`RunResult`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// The synchronous matrix engine (single thread, accounting model).
    Engine,
    /// The message-passing coordinator (node threads, real framed bytes).
    Coordinator,
    /// The event-driven massive-n simulator (sharded worker pool driving
    /// the per-node halves over real wire frames — no per-node threads).
    Sim,
}

impl Backend {
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Engine => "engine",
            Backend::Coordinator => "coordinator",
            Backend::Sim => "sim",
        }
    }
}

/// The full trace of one run — the ONE shape both backends return.
#[derive(Clone, Debug)]
pub struct RunResult {
    /// Display name: the algorithm's `name()` on the engine, the config's
    /// `algorithm` key on the coordinator.
    pub name: String,
    pub backend: Backend,
    pub history: Vec<MetricPoint>,
    /// The criterion that ended the run (first hit wins).
    pub stopped_by: StopReason,
    /// Total wall-clock.
    pub elapsed: Duration,
    /// The final stacked iterate (n × p).
    pub final_x: Mat,
}

impl RunResult {
    pub fn final_subopt(&self) -> f64 {
        self.history.last().map_or(f64::NAN, |m| m.suboptimality)
    }

    /// First round at which the suboptimality target was met, if the run
    /// stopped on it (the target beats every other criterion at the same
    /// evaluation, so the last recorded round *is* the hit round).
    pub fn rounds_to_target(&self) -> Option<usize> {
        match self.stopped_by {
            StopReason::TargetSubopt => self.history.last().map(|m| m.round),
            _ => None,
        }
    }

    /// Total framed wire bytes (0 for engine runs).
    pub fn wire_bytes(&self) -> u64 {
        self.history.last().map_or(0, |m| m.wire_bytes)
    }

    /// Series (x_metric, suboptimality) for the figure CSVs.
    pub fn series(&self, x: XAxis) -> Vec<(f64, f64)> {
        if let XAxis::Epochs(per_epoch) = x {
            // a 0 divisor would silently produce inf/NaN x-coordinates in
            // every figure CSV downstream — fail loudly instead
            assert!(per_epoch > 0, "XAxis::Epochs needs per_epoch >= 1 (n·m batch evals)");
        }
        self.history
            .iter()
            .map(|m| {
                let xv = match x {
                    XAxis::Rounds => m.round as f64,
                    XAxis::GradEvals => m.grad_evals as f64,
                    XAxis::Bits => m.bits as f64,
                    XAxis::Epochs(per_epoch) => m.grad_evals as f64 / per_epoch as f64,
                };
                (xv, m.suboptimality)
            })
            .collect()
    }

    /// Serialize the full result — every history row, the stop reason, and
    /// the final stacked iterate — as one JSON object. `proxlead train
    /// --json FILE` writes this, and the multi-process CI smoke job uploads
    /// it as the run artifact.
    pub fn to_json(&self) -> String {
        let history = Json::Arr(
            self.history
                .iter()
                .map(|m| {
                    Json::obj(vec![
                        ("round", Json::Num(m.round as f64)),
                        ("grad_evals", Json::Num(m.grad_evals as f64)),
                        ("bits", Json::Num(m.bits as f64)),
                        ("wire_bytes", Json::Num(m.wire_bytes as f64)),
                        ("suboptimality", Json::Num(m.suboptimality)),
                        ("consensus", Json::Num(m.consensus)),
                        ("wall_ns", Json::Num(m.wall_ns as f64)),
                    ])
                })
                .collect(),
        );
        let final_x = Json::obj(vec![
            ("rows", Json::Num(self.final_x.rows as f64)),
            ("cols", Json::Num(self.final_x.cols as f64)),
            ("data", Json::arr_f64(&self.final_x.data)),
        ]);
        Json::obj(vec![
            ("schema", Json::Str("proxlead-run-v1".into())),
            ("name", Json::Str(self.name.clone())),
            ("backend", Json::Str(self.backend.name().into())),
            ("stopped_by", Json::Str(self.stopped_by.name().into())),
            ("elapsed_ns", Json::Num(self.elapsed.as_nanos() as f64)),
            ("history", history),
            ("final_x", final_x),
        ])
        .to_string()
    }

    /// The flat end-of-run summary handed to [`Probe::on_finish`].
    pub fn outcome(&self) -> RunOutcome {
        let last = self.history.last();
        RunOutcome {
            name: self.name.clone(),
            backend: self.backend,
            stopped_by: self.stopped_by,
            rounds: last.map_or(0, |m| m.round),
            final_subopt: self.final_subopt(),
            grad_evals: last.map_or(0, |m| m.grad_evals),
            bits: last.map_or(0, |m| m.bits),
            wire_bytes: last.map_or(0, |m| m.wire_bytes),
            elapsed: self.elapsed,
        }
    }
}

/// Which x-axis a figure uses.
#[derive(Clone, Copy, Debug)]
pub enum XAxis {
    Rounds,
    GradEvals,
    Bits,
    /// Epochs = grad_evals / (n·m batch evals per epoch). The divisor must
    /// be ≥ 1 — [`RunResult::series`] panics on 0.
    Epochs(u64),
}

/// End-of-run summary, streamed to [`Probe::on_finish`] and printed by the
/// built-in progress probe and the sweep runtime's per-cell lines.
#[derive(Clone, Debug)]
pub struct RunOutcome {
    pub name: String,
    pub backend: Backend,
    pub stopped_by: StopReason,
    /// Last recorded round.
    pub rounds: usize,
    pub final_subopt: f64,
    pub grad_evals: u64,
    pub bits: u64,
    pub wire_bytes: u64,
    pub elapsed: Duration,
}

impl RunOutcome {
    /// One human-readable line: name, backend, final state, stop reason.
    pub fn summary_line(&self) -> String {
        let wire = if self.wire_bytes > 0 {
            format!(" | wire {} KiB", self.wire_bytes / 1024)
        } else {
            String::new()
        };
        format!(
            "{} [{}] subopt {:.3e} @ round {} | {:.2} Mbit{wire} | stopped by {} | {:.2?}",
            self.name,
            self.backend.name(),
            self.final_subopt,
            self.rounds,
            self.bits as f64 / 1e6,
            self.stopped_by.name(),
            self.elapsed,
        )
    }
}

/// Push one sample into the history and stream it to every probe — the
/// one emit path both backends use (the coordinator's leader calls this
/// per flushed snapshot).
pub(crate) fn emit(
    m: MetricPoint,
    x: &Mat,
    history: &mut Vec<MetricPoint>,
    probes: &mut [&mut dyn Probe],
) {
    history.push(m);
    for p in probes.iter_mut() {
        p.on_sample(&m);
        p.on_iterate(m.round, x);
    }
}

/// Deliver the end-of-run summary to every probe (both backends' shared
/// epilogue).
pub(crate) fn finish(result: &RunResult, probes: &mut [&mut dyn Probe]) {
    let outcome = result.outcome();
    for p in probes.iter_mut() {
        p.on_finish(&outcome);
    }
}

/// Drive `alg` through the synchronous matrix engine under `spec`,
/// measuring against `x_star` and streaming samples to `probes`. The
/// [`StopSet`] is evaluated after every round. `spec.seed` is resolved by
/// the caller (the algorithm arrives constructed); see
/// [`crate::exp::Experiment::run`] for the seed-resolving entry point.
pub fn run_engine(
    alg: &mut dyn Algorithm,
    problem: &dyn Problem,
    x_star: &[f64],
    spec: &RunSpec,
    probes: &mut [&mut dyn Probe],
) -> RunResult {
    assert!(
        spec.record_every >= 1,
        "record_every must be >= 1 (0 would divide by zero sizing the history)"
    );
    #[allow(clippy::disallowed_methods)] // wall-clock run timing (see clippy.toml)
    let start = Instant::now();
    let rounds = spec.stop.max_rounds;
    let mut history: Vec<MetricPoint> = Vec::with_capacity(rounds / spec.record_every + 2);
    let mut stopped_by = StopReason::MaxRounds;

    // round-0 sample (post-initialization state)
    emit(
        MetricPoint {
            round: 0,
            grad_evals: alg.grad_evals(),
            bits: alg.bits(),
            wire_bytes: 0,
            suboptimality: suboptimality(alg.x(), x_star),
            consensus: alg.x().consensus_error(),
            wall_ns: 0,
        },
        alg.x(),
        &mut history,
        probes,
    );

    for k in 0..rounds {
        if let Some(s) = &spec.schedule {
            alg.apply_hyper(s.hyper_at(k as u64));
        }
        alg.step(problem);
        let round = k + 1;
        let due = round % spec.record_every == 0 || round == rounds;
        let mut subopt = f64::NAN;
        if due || spec.stop.needs_subopt() {
            subopt = suboptimality(alg.x(), x_star);
        }
        let elapsed = start.elapsed();
        let sample = |subopt: f64, alg: &dyn Algorithm| MetricPoint {
            round,
            grad_evals: alg.grad_evals(),
            bits: alg.bits(),
            wire_bytes: 0,
            suboptimality: subopt,
            consensus: alg.x().consensus_error(),
            wall_ns: elapsed.as_nanos(),
        };
        if due {
            emit(sample(subopt, &*alg), alg.x(), &mut history, probes);
        }
        // divergence beats every stop criterion (the documented contract,
        // matching the coordinator's leader), and the diverged state is
        // flushed before breaking so final_subopt() reports it instead of
        // a stale pre-divergence sample between record points
        let hit = if !alg.x().is_finite() {
            Some(StopReason::Diverged)
        } else {
            spec.stop.check(round, alg.bits(), alg.grad_evals(), subopt, elapsed)
        };
        if let Some(reason) = hit {
            stopped_by = reason;
            if !due {
                // make sure the stopping state is in the history, with a
                // measured suboptimality even when only a budget criterion
                // demanded the stop
                let s = if subopt.is_nan() { suboptimality(alg.x(), x_star) } else { subopt };
                emit(sample(s, &*alg), alg.x(), &mut history, probes);
            }
            break;
        }
    }

    let result = RunResult {
        name: alg.name(),
        backend: Backend::Engine,
        history,
        stopped_by,
        elapsed: start.elapsed(),
        final_x: alg.x().clone(),
    };
    finish(&result, probes);
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithm::testkit::ring_exp;
    use crate::algorithm::{solve_reference, ProxLead};
    use crate::compress::Identity;

    fn exact_prox_lead(exp: &crate::exp::Experiment) -> Box<dyn Algorithm> {
        Box::new(ProxLead::builder(exp).compressor(Box::new(Identity::f64())).seed(5).build())
    }

    #[test]
    fn stop_set_order_is_target_bits_evals_deadline_rounds() {
        let s = StopSet {
            max_rounds: 10,
            target_subopt: Some(1e-3),
            max_bits: Some(100),
            max_grad_evals: Some(100),
            deadline: Some(Duration::from_secs(1)),
        };
        let hit = |sub: f64, bits, evals, el| s.check(10, bits, evals, sub, el);
        assert_eq!(hit(1e-4, 100, 100, Duration::from_secs(2)), Some(StopReason::TargetSubopt));
        assert_eq!(hit(1.0, 100, 100, Duration::from_secs(2)), Some(StopReason::BitsBudget));
        assert_eq!(hit(1.0, 0, 100, Duration::from_secs(2)), Some(StopReason::GradEvalsBudget));
        assert_eq!(hit(1.0, 0, 0, Duration::from_secs(2)), Some(StopReason::Deadline));
        assert_eq!(hit(1.0, 0, 0, Duration::ZERO), Some(StopReason::Deadline));
        assert_eq!(
            StopSet::rounds(10).check(10, 0, 0, f64::NAN, Duration::ZERO),
            Some(StopReason::MaxRounds)
        );
        assert_eq!(StopSet::rounds(10).check(9, 0, 0, f64::NAN, Duration::ZERO), None);
        // NaN suboptimality never triggers the target
        assert_eq!(
            s.check(1, 0, 0, f64::NAN, Duration::ZERO),
            None,
            "NaN must not satisfy the target"
        );
    }

    #[test]
    fn bits_budget_stops_the_engine_early() {
        let exp = ring_exp();
        let x_star = vec![0.0; exp.problem.dim()];
        let mut alg = exact_prox_lead(&exp);
        // one round moves n·p·64 bits exactly (Dense64-equivalent)
        let per_round = (exp.config.nodes * exp.problem.dim() * 64) as u64;
        let spec = RunSpec::fixed(100).bits_budget(3 * per_round);
        let res = run_engine(alg.as_mut(), exp.problem.as_ref(), &x_star, &spec, &mut []);
        assert_eq!(res.stopped_by, StopReason::BitsBudget);
        assert_eq!(res.history.last().unwrap().round, 3);
        assert_eq!(res.history.last().unwrap().bits, 3 * per_round);
        assert!(res.rounds_to_target().is_none());
    }

    #[test]
    fn grad_evals_budget_stops_the_engine_early() {
        let exp = ring_exp();
        let x_star = vec![0.0; exp.problem.dim()];
        let mut alg = exact_prox_lead(&exp);
        let init = alg.grad_evals(); // construction cost (full grad at X⁰)
        let spec = RunSpec::fixed(500).grad_evals_budget(init * 4);
        let res = run_engine(alg.as_mut(), exp.problem.as_ref(), &x_star, &spec, &mut []);
        assert_eq!(res.stopped_by, StopReason::GradEvalsBudget);
        let last = res.history.last().unwrap();
        assert!(last.round < 500, "budget must bite early, ran to {}", last.round);
        assert!(last.grad_evals >= init * 4);
    }

    #[test]
    fn deadline_stops_the_engine() {
        let exp = ring_exp();
        let x_star = vec![0.0; exp.problem.dim()];
        let mut alg = exact_prox_lead(&exp);
        let spec = RunSpec::fixed(1_000_000).deadline(Duration::ZERO);
        let res = run_engine(alg.as_mut(), exp.problem.as_ref(), &x_star, &spec, &mut []);
        assert_eq!(res.stopped_by, StopReason::Deadline);
        assert_eq!(res.history.last().unwrap().round, 1);
    }

    #[test]
    fn target_stop_records_reason_and_round() {
        let exp = ring_exp();
        let p = exp.problem.as_ref();
        let x_star = solve_reference(p, 0.0, 40_000, 1e-13);
        let mut alg = exact_prox_lead(&exp);
        let res = run_engine(alg.as_mut(), p, &x_star, &RunSpec::fixed(5000).until(1e-8), &mut []);
        assert_eq!(res.stopped_by, StopReason::TargetSubopt);
        let hit = res.rounds_to_target().expect("target reached");
        assert!(hit < 2000, "took {hit} rounds");
        assert_eq!(hit, res.history.last().unwrap().round);
        assert!(res.final_subopt() < 1e-8);
    }

    #[test]
    fn completed_runs_report_max_rounds() {
        let exp = ring_exp();
        let x_star = vec![0.0; exp.problem.dim()];
        let mut alg = exact_prox_lead(&exp);
        let res =
            run_engine(alg.as_mut(), exp.problem.as_ref(), &x_star, &RunSpec::fixed(10), &mut []);
        assert_eq!(res.stopped_by, StopReason::MaxRounds);
        assert_eq!(res.backend, Backend::Engine);
        assert_eq!(res.history.last().unwrap().round, 10);
        assert_eq!(res.wire_bytes(), 0, "the engine models bits, not framed bytes");
    }

    #[test]
    #[should_panic(expected = "record_every must be >= 1")]
    fn record_every_zero_is_a_clear_error() {
        // regression: a literal-constructed spec with record_every = 0 used
        // to divide by zero at the history-capacity computation
        let exp = ring_exp();
        let x_star = vec![0.0; exp.problem.dim()];
        let mut alg = exact_prox_lead(&exp);
        let spec = RunSpec { record_every: 0, ..RunSpec::fixed(10) };
        let _ = run_engine(alg.as_mut(), exp.problem.as_ref(), &x_star, &spec, &mut []);
    }

    #[test]
    #[should_panic(expected = "per_epoch >= 1")]
    fn epochs_axis_rejects_zero_divisor() {
        // regression: XAxis::Epochs(0) divided by zero, writing inf/NaN
        // x-coordinates into the figure CSVs
        let res = RunResult {
            name: "x".into(),
            backend: Backend::Engine,
            history: vec![MetricPoint {
                round: 1,
                grad_evals: 10,
                bits: 1,
                wire_bytes: 0,
                suboptimality: 0.5,
                consensus: 0.0,
                wall_ns: 0,
            }],
            stopped_by: StopReason::MaxRounds,
            elapsed: Duration::ZERO,
            final_x: Mat::zeros(1, 1),
        };
        let _ = res.series(XAxis::Epochs(0));
    }

    #[test]
    fn run_result_serializes_to_parseable_json() {
        let exp = ring_exp();
        let x_star = vec![0.0; exp.problem.dim()];
        let mut alg = exact_prox_lead(&exp);
        let res =
            run_engine(alg.as_mut(), exp.problem.as_ref(), &x_star, &RunSpec::fixed(4), &mut []);
        let v = Json::parse(&res.to_json()).unwrap();
        assert_eq!(v.get("schema").unwrap().as_str(), Some("proxlead-run-v1"));
        assert_eq!(v.get("backend").unwrap().as_str(), Some("engine"));
        assert_eq!(v.get("stopped_by").unwrap().as_str(), Some("max-rounds"));
        assert_eq!(v.get("history").unwrap().as_arr().unwrap().len(), res.history.len());
        let fx = v.get("final_x").unwrap();
        assert_eq!(fx.get("rows").unwrap().as_usize(), Some(res.final_x.rows));
        assert_eq!(fx.get("data").unwrap().as_arr().unwrap().len(), res.final_x.data.len());
    }

    #[test]
    fn probes_stream_samples_and_finish() {
        #[derive(Default)]
        struct Counter {
            samples: usize,
            iterates: usize,
            finished: Option<StopReason>,
        }
        impl Probe for Counter {
            fn on_sample(&mut self, _m: &MetricPoint) {
                self.samples += 1;
            }
            fn on_iterate(&mut self, _round: usize, _x: &Mat) {
                self.iterates += 1;
            }
            fn on_finish(&mut self, o: &RunOutcome) {
                self.finished = Some(o.stopped_by);
            }
        }
        let exp = ring_exp();
        let x_star = vec![0.0; exp.problem.dim()];
        let mut alg = exact_prox_lead(&exp);
        let mut c = Counter::default();
        let res = run_engine(
            alg.as_mut(),
            exp.problem.as_ref(),
            &x_star,
            &RunSpec::fixed(40).every(10),
            &mut [&mut c],
        );
        assert_eq!(res.history.len(), 5); // round 0 + 4 samples
        assert_eq!(c.samples, 5);
        assert_eq!(c.iterates, 5);
        assert_eq!(c.finished, Some(StopReason::MaxRounds));
        let line = res.outcome().summary_line();
        assert!(line.contains("max-rounds") && line.contains("engine"), "{line}");
    }
}
