//! Streaming run probes: metrics observed *during* a run instead of only
//! materializing after it.
//!
//! Both backends call every probe at each recorded sample (the engine on
//! its thread, the coordinator on the leader thread as node reports
//! complete a round) and once at the end. Built-ins cover the two outputs
//! the CLI and sweep runtime used to assemble by hand: live CSV emission
//! ([`CsvProbe`]) and progress lines ([`ProgressProbe`]).

use super::{MetricPoint, RunOutcome};
use crate::linalg::Mat;
use std::fs::File;
use std::io::{self, BufWriter, Write};

/// Observer of a run in flight. All methods default to no-ops so a probe
/// implements only what it needs.
pub trait Probe {
    /// A recorded metric sample (round 0 = post-init state).
    fn on_sample(&mut self, _m: &MetricPoint) {}

    /// The stacked iterate Xᵏ (n × p) at a recorded sample, delivered
    /// right after [`Probe::on_sample`] for the same round — for
    /// checkpointing, per-round loss/accuracy, or custom diagnostics.
    fn on_iterate(&mut self, _round: usize, _x: &Mat) {}

    /// The run finished (any stop reason); flush buffers here.
    fn on_finish(&mut self, _outcome: &RunOutcome) {}
}

/// Streams one CSV row per sample:
/// `round,suboptimality,consensus,bits,wire_bytes,grad_evals`.
///
/// Rows hit the writer as the run progresses (a killed run keeps every
/// sample already emitted); the writer is flushed at `on_finish`.
pub struct CsvProbe<W: Write> {
    out: W,
    header_written: bool,
}

impl CsvProbe<BufWriter<File>> {
    /// Stream to a file at `path` (created/truncated, buffered).
    pub fn to_path(path: &str) -> io::Result<CsvProbe<BufWriter<File>>> {
        Ok(CsvProbe::new(BufWriter::new(File::create(path)?)))
    }
}

impl<W: Write> CsvProbe<W> {
    pub fn new(out: W) -> CsvProbe<W> {
        CsvProbe { out, header_written: false }
    }

    /// Recover the writer (e.g. a `Vec<u8>` buffer in tests).
    pub fn into_writer(self) -> W {
        self.out
    }
}

impl<W: Write> Probe for CsvProbe<W> {
    fn on_sample(&mut self, m: &MetricPoint) {
        if !self.header_written {
            writeln!(self.out, "round,suboptimality,consensus,bits,wire_bytes,grad_evals")
                .expect("csv probe write");
            self.header_written = true;
        }
        writeln!(
            self.out,
            "{},{:.6e},{:.6e},{},{},{}",
            m.round, m.suboptimality, m.consensus, m.bits, m.wire_bytes, m.grad_evals
        )
        .expect("csv probe write");
        // flush per row so the durability promise holds: a killed run
        // keeps every sample already emitted (row rate is bounded by
        // record_every, so this is cheap)
        self.out.flush().expect("csv probe flush");
    }

    fn on_finish(&mut self, _outcome: &RunOutcome) {
        self.out.flush().expect("csv probe flush");
    }
}

/// Prints one aligned progress line per sample and a summary line at the
/// end — the formatting `proxlead train` used to hand-roll.
#[derive(Default)]
pub struct ProgressProbe {
    header_written: bool,
}

impl ProgressProbe {
    pub fn new() -> ProgressProbe {
        ProgressProbe::default()
    }
}

impl Probe for ProgressProbe {
    fn on_sample(&mut self, m: &MetricPoint) {
        if !self.header_written {
            println!("round      subopt        consensus     Mbits    grad-evals");
            self.header_written = true;
        }
        println!(
            "{:>6} {:>13.4e} {:>13.4e} {:>8.2} {:>10}",
            m.round,
            m.suboptimality,
            m.consensus,
            m.bits as f64 / 1e6,
            m.grad_evals
        );
    }

    fn on_finish(&mut self, outcome: &RunOutcome) {
        println!("{}", outcome.summary_line());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runner::{Backend, StopReason};
    use std::time::Duration;

    fn point(round: usize) -> MetricPoint {
        MetricPoint {
            round,
            grad_evals: 4 * round as u64,
            bits: 100 * round as u64,
            wire_bytes: 120 * round as u64,
            suboptimality: 1.0 / (round + 1) as f64,
            consensus: 0.5,
            wall_ns: 1,
        }
    }

    #[test]
    fn csv_probe_streams_header_and_rows() {
        let mut probe = CsvProbe::new(Vec::new());
        probe.on_sample(&point(0));
        probe.on_sample(&point(10));
        probe.on_finish(&RunOutcome {
            name: "x".into(),
            backend: Backend::Coordinator,
            stopped_by: StopReason::BitsBudget,
            rounds: 10,
            final_subopt: 0.09,
            grad_evals: 40,
            bits: 1000,
            wire_bytes: 1200,
            elapsed: Duration::from_millis(5),
        });
        let text = String::from_utf8(probe.into_writer()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[0], "round,suboptimality,consensus,bits,wire_bytes,grad_evals");
        assert!(lines[1].starts_with("0,"), "{}", lines[1]);
        assert!(lines[2].starts_with("10,") && lines[2].contains(",1000,1200,40"));
    }
}
