//! Zero-dependency fixed thread pool for the sweep runtime.
//!
//! Deliberately *not* work-stealing: a single `std::sync::mpsc` job queue
//! (preloaded with every cell index, then closed) is shared by all
//! workers, each popping the next index under a mutex and sending
//! `(index, result)` back over a results channel. The main thread drains
//! results as they complete (for live progress) and re-orders them by
//! index, so the output is a plain `Vec<T>` in job order **regardless of
//! thread count or scheduling** — the determinism the sweep runtime's
//! byte-identical-JSON guarantee rests on (each job must itself be a pure
//! function of its index).

use std::sync::mpsc;
use std::sync::Mutex;
use std::thread;

/// Run `f(0..jobs)` on `threads` worker threads, returning results in job
/// order. `f` must be a pure function of its index for deterministic
/// output (the pool guarantees ordering, not purity).
pub fn parallel_map<T, F>(jobs: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    parallel_map_progress(jobs, threads, f, |_, _| {})
}

/// [`parallel_map`] with a completion callback: `progress(index, &result)`
/// runs on the calling thread, in *completion* order (the returned Vec is
/// still in job order).
pub fn parallel_map_progress<T, F, P>(jobs: usize, threads: usize, f: F, mut progress: P) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
    P: FnMut(usize, &T),
{
    let threads = threads.max(1).min(jobs.max(1));
    // preload the queue with every job index, then close it: workers stop
    // on the first empty pop, so no shutdown signalling is needed
    let (job_tx, job_rx) = mpsc::channel::<usize>();
    for i in 0..jobs {
        job_tx.send(i).expect("queue job");
    }
    drop(job_tx);
    let job_rx = Mutex::new(job_rx);
    let (res_tx, res_rx) = mpsc::channel::<(usize, T)>();

    let mut out: Vec<Option<T>> = Vec::with_capacity(jobs);
    out.resize_with(jobs, || None);
    thread::scope(|s| {
        for _ in 0..threads {
            let res_tx = res_tx.clone();
            let job_rx = &job_rx;
            let f = &f;
            s.spawn(move || {
                loop {
                    // the queue is preloaded and closed, so an empty pop
                    // (or a disconnect) means all work is handed out
                    let job = job_rx.lock().unwrap().try_recv();
                    match job {
                        Ok(i) => {
                            if res_tx.send((i, f(i))).is_err() {
                                break;
                            }
                        }
                        Err(_) => break,
                    }
                }
            });
        }
        drop(res_tx);
        // drain completions live; ends when every worker dropped its sender
        for (i, r) in res_rx.iter() {
            progress(i, &r);
            out[i] = Some(r);
        }
    });
    out.into_iter().map(|o| o.expect("every queued job completes")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn results_are_in_job_order() {
        let out = parallel_map(64, 8, |i| i * i);
        let want: Vec<usize> = (0..64).map(|i| i * i).collect();
        assert_eq!(out, want);
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let f = |i: usize| (i as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let serial = parallel_map(33, 1, f);
        let wide = parallel_map(33, 8, f);
        assert_eq!(serial, wide);
    }

    #[test]
    fn more_threads_than_jobs_is_fine() {
        assert_eq!(parallel_map(2, 16, |i| i + 1), vec![1, 2]);
        assert_eq!(parallel_map(0, 4, |i| i), Vec::<usize>::new());
    }

    #[test]
    fn every_job_runs_exactly_once() {
        let count = AtomicUsize::new(0);
        let out = parallel_map(100, 7, |i| {
            count.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(out.len(), 100);
        assert_eq!(count.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn progress_sees_every_completion() {
        let mut seen = Vec::new();
        let out = parallel_map_progress(20, 4, |i| i * 3, |i, &r| seen.push((i, r)));
        assert_eq!(out, (0..20).map(|i| i * 3).collect::<Vec<_>>());
        seen.sort_unstable();
        assert_eq!(seen, (0..20).map(|i| (i, i * 3)).collect::<Vec<_>>());
    }
}
