//! Declarative sweep grids over the [`Config`] schema.
//!
//! A [`SweepSpec`] is a base [`Config`] plus two kinds of structure:
//!
//! - **variants** — explicit override-sets, one per experimental arm
//!   (e.g. the per-figure algorithm lists, where each algorithm pairs
//!   with its own codec: `[("algorithm","dgd"), ("bits","32")]`);
//! - **axes** — cartesian dimensions multiplied onto *every* variant
//!   (e.g. `oracle ∈ {sgd, saga}` × `seed ∈ {1, 2, 3}`).
//!
//! Cells are indexed `0..num_cells()` in a fixed order (variant-major,
//! then axes left-to-right with the first axis slowest), so a cell index
//! alone identifies a full configuration — the sweep runtime derives each
//! cell's RNG seed from it. Every override routes through
//! [`Config::set`], so the sweep surface automatically tracks the config
//! schema, exactly like the CLI.

use crate::config::{Config, ConfigError};

/// One cartesian sweep dimension: a config key and its values.
#[derive(Clone, Debug)]
pub struct Axis {
    pub key: String,
    pub values: Vec<String>,
}

/// A declarative experiment grid (see module docs for the cell order).
#[derive(Clone, Debug)]
pub struct SweepSpec {
    pub base: Config,
    /// Explicit override-sets; empty means the single empty variant.
    pub variants: Vec<Vec<(String, String)>>,
    /// Cartesian axes applied on top of every variant.
    pub axes: Vec<Axis>,
    /// Worker threads (does not affect results, only wall-clock).
    pub threads: usize,
    /// Optional early-stop target passed to the engine.
    pub target_subopt: Option<f64>,
}

/// One fully resolved grid cell.
#[derive(Clone, Debug)]
pub struct Cell {
    pub index: usize,
    /// The overrides that produced this cell (variant first, then axes).
    pub overrides: Vec<(String, String)>,
    pub config: Config,
}

impl SweepSpec {
    pub fn new(base: Config) -> SweepSpec {
        let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        SweepSpec { base, variants: Vec::new(), axes: Vec::new(), threads, target_subopt: None }
    }

    /// Add a cartesian axis from string literals.
    pub fn axis(mut self, key: &str, values: &[&str]) -> SweepSpec {
        self.axes.push(Axis {
            key: key.to_string(),
            values: values.iter().map(|v| v.to_string()).collect(),
        });
        self
    }

    /// Add a cartesian axis from owned values (e.g. formatted floats —
    /// `format!("{v}")` round-trips f64 exactly).
    pub fn axis_values(mut self, key: &str, values: Vec<String>) -> SweepSpec {
        self.axes.push(Axis { key: key.to_string(), values });
        self
    }

    /// Add one explicit variant (an override-set applied before the axes).
    pub fn variant(mut self, overrides: &[(&str, &str)]) -> SweepSpec {
        self.variants
            .push(overrides.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect());
        self
    }

    pub fn threads(mut self, threads: usize) -> SweepSpec {
        self.threads = threads.max(1);
        self
    }

    /// Stop each cell early once suboptimality falls below `target`.
    pub fn until(mut self, target: f64) -> SweepSpec {
        self.target_subopt = Some(target);
        self
    }

    /// Parse a CLI grid string: `"bits=2,32;seed=1,2,3"` (`;`-separated
    /// axes, `,`-separated values).
    pub fn with_grid(mut self, grid: &str) -> Result<SweepSpec, ConfigError> {
        for part in grid.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (key, vals) = part
                .split_once('=')
                .ok_or_else(|| ConfigError(format!("grid axis '{part}': expected key=v1,v2,…")))?;
            let values: Vec<String> =
                vals.split(',').map(|v| v.trim().to_string()).filter(|v| !v.is_empty()).collect();
            if values.is_empty() {
                return Err(ConfigError(format!("grid axis '{key}' has no values")));
            }
            self.axes.push(Axis { key: key.trim().to_string(), values });
        }
        Ok(self)
    }

    /// Number of cells in the grid (product of variants × all axes).
    pub fn num_cells(&self) -> usize {
        let v = self.variants.len().max(1);
        self.axes.iter().fold(v, |acc, a| acc * a.values.len().max(1))
    }

    /// The overrides for cell `index` (variant-major; first axis slowest).
    pub fn cell_overrides(&self, index: usize) -> Vec<(String, String)> {
        debug_assert!(index < self.num_cells());
        let axes_cells: usize = self.axes.iter().map(|a| a.values.len().max(1)).product();
        let (v_idx, mut a_idx) = (index / axes_cells.max(1), index % axes_cells.max(1));
        let mut overrides: Vec<(String, String)> = match self.variants.get(v_idx) {
            Some(v) => v.clone(),
            None => Vec::new(),
        };
        // mixed-radix decode, first axis slowest
        let mut radix = axes_cells.max(1);
        for axis in &self.axes {
            let len = axis.values.len().max(1);
            radix /= len;
            let i = a_idx / radix.max(1);
            a_idx %= radix.max(1);
            if let Some(val) = axis.values.get(i) {
                overrides.push((axis.key.clone(), val.clone()));
            }
        }
        overrides
    }

    /// Resolve cell `index` into a full [`Config`].
    pub fn cell_config(&self, index: usize) -> Result<Config, ConfigError> {
        let mut cfg = self.base.clone();
        for (k, v) in self.cell_overrides(index) {
            cfg.set(&k, &v)?;
        }
        Ok(cfg)
    }

    /// Resolve and validate every cell up front (serial, so configuration
    /// errors surface deterministically before any work is fanned out).
    pub fn cells(&self) -> Result<Vec<Cell>, ConfigError> {
        (0..self.num_cells())
            .map(|index| {
                let config = self.cell_config(index)?;
                super::validate_cell(&config)?;
                Ok(Cell { index, overrides: self.cell_overrides(index), config })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cartesian_order_is_first_axis_slowest() {
        let spec =
            SweepSpec::new(Config::default()).axis("bits", &["2", "32"]).axis("seed", &["1", "2"]);
        assert_eq!(spec.num_cells(), 4);
        let flat: Vec<Vec<(String, String)>> =
            (0..4).map(|i| spec.cell_overrides(i)).collect();
        assert_eq!(flat[0], vec![("bits".into(), "2".into()), ("seed".into(), "1".into())]);
        assert_eq!(flat[1], vec![("bits".into(), "2".into()), ("seed".into(), "2".into())]);
        assert_eq!(flat[2], vec![("bits".into(), "32".into()), ("seed".into(), "1".into())]);
        assert_eq!(flat[3], vec![("bits".into(), "32".into()), ("seed".into(), "2".into())]);
    }

    #[test]
    fn variants_multiply_with_axes() {
        let spec = SweepSpec::new(Config::default())
            .variant(&[("algorithm", "dgd"), ("bits", "32")])
            .variant(&[("algorithm", "prox-lead"), ("bits", "2")])
            .axis("seed", &["1", "2", "3"]);
        assert_eq!(spec.num_cells(), 6);
        // cells 0..3 are the dgd variant, 3..6 prox-lead
        let c0 = spec.cell_config(0).unwrap();
        assert_eq!(c0.algorithm, "dgd");
        assert_eq!(c0.bits, 32);
        assert_eq!(c0.seed, 1);
        let c5 = spec.cell_config(5).unwrap();
        assert_eq!(c5.algorithm, "prox-lead");
        assert_eq!(c5.bits, 2);
        assert_eq!(c5.seed, 3);
    }

    #[test]
    fn grid_string_parses() {
        let spec =
            SweepSpec::new(Config::default()).with_grid("bits=2, 32; oracle=sgd,saga").unwrap();
        assert_eq!(spec.axes.len(), 2);
        assert_eq!(spec.axes[0].key, "bits");
        assert_eq!(spec.axes[0].values, vec!["2", "32"]);
        assert_eq!(spec.axes[1].values, vec!["sgd", "saga"]);
        assert_eq!(spec.num_cells(), 4);
    }

    #[test]
    fn bad_grid_strings_error() {
        assert!(SweepSpec::new(Config::default()).with_grid("bits").is_err());
        assert!(SweepSpec::new(Config::default()).with_grid("bits=").is_err());
        // unknown keys surface when cells are resolved
        let spec = SweepSpec::new(Config::default()).with_grid("warp=1,2").unwrap();
        assert!(spec.cells().is_err());
    }

    #[test]
    fn empty_spec_is_one_base_cell() {
        let spec = SweepSpec::new(Config::default());
        assert_eq!(spec.num_cells(), 1);
        assert!(spec.cell_overrides(0).is_empty());
        let cfg = spec.cell_config(0).unwrap();
        assert_eq!(cfg.nodes, Config::default().nodes);
    }

    #[test]
    fn cell_config_applies_overrides_in_order() {
        // an axis can override a variant key; last write wins
        let spec = SweepSpec::new(Config::default())
            .variant(&[("bits", "8")])
            .axis("bits", &["2", "4"]);
        assert_eq!(spec.cell_config(0).unwrap().bits, 2);
        assert_eq!(spec.cell_config(1).unwrap().bits, 4);
    }
}
