//! The parallel experiment-sweep runtime.
//!
//! Every figure and table in §5 is a grid over
//! {algorithm × compressor × topology × oracle × stepsize × seed}. This
//! module turns those grids into data: a declarative [`SweepSpec`]
//! (see [`spec`]) expands into indexed cells, a zero-dependency
//! `std::sync::mpsc` thread pool (see [`pool`]) fans the cells out to
//! worker threads, each cell runs through the unified [`crate::runner`]
//! API on the matrix engine, and the results aggregate into the
//! deterministic JSON trajectory format built on [`crate::util::json`].
//!
//! **Determinism contract:** a cell is a pure function of its index — the
//! data seed comes from the cell's `Config`, the algorithm seed from
//! [`cell_seed`]`(config.seed, index)`, and the pool re-orders results by
//! index — so the aggregated output (including [`SweepResult::to_json`],
//! which deliberately excludes wall-clock and thread count) is
//! **byte-identical regardless of thread count or scheduling**. The
//! integration suite asserts this, and pins a sweep cell to a hand-rolled
//! serial [`crate::runner::run_engine`] of the same configuration.

pub mod pool;
pub mod spec;

pub use pool::{parallel_map, parallel_map_progress};
pub use spec::{Axis, Cell, SweepSpec};

// Reference-solution budget shared by every cell (now owned by the
// Experiment API; re-exported so sweep callers keep compiling).
pub use crate::exp::{REF_MAX_ITER, REF_TOL};

use crate::algorithm::solve_reference;
use crate::config::{Config, ConfigError};
use crate::exp::Experiment;
use crate::problem::Problem;
use crate::runner::RunResult;
use crate::util::bench::Table;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// The result of one sweep cell.
#[derive(Clone, Debug)]
pub struct CellOutcome {
    pub index: usize,
    /// The overrides that produced this cell (variant first, then axes).
    pub overrides: Vec<(String, String)>,
    /// The algorithm's display name, e.g. `"Prox-LEAD (2bit, saga)"`.
    pub name: String,
    /// The derived per-cell algorithm seed (see [`cell_seed`]).
    pub seed: u64,
    /// The resolved stepsize (auto = 1/(2L) when the config says 0).
    pub eta: f64,
    /// The engine trace.
    pub result: RunResult,
    /// Cell wall-clock including the (cached) reference solve. Excluded
    /// from the JSON aggregate — it is scheduling-dependent.
    pub wall_ns: u128,
}

/// An executed sweep: the spec plus every cell outcome, in cell order.
#[derive(Clone, Debug)]
pub struct SweepResult {
    pub spec: SweepSpec,
    pub cells: Vec<CellOutcome>,
}

/// Derive the algorithm RNG seed for one cell: a splitmix64-style
/// finalizer over (base seed, cell index). Identical regardless of thread
/// count or scheduling; decorrelated across neighboring cells.
pub fn cell_seed(base_seed: u64, index: usize) -> u64 {
    let mut z = base_seed ^ (index as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Check that a cell's config resolves to a runnable experiment — every
/// factory the runner will call, without constructing the problem (grids
/// validate serially up front; data generation stays on the workers).
/// Delegates to the Experiment API's [`crate::exp::validate_config`].
pub fn validate_cell(cfg: &Config) -> Result<(), ConfigError> {
    crate::exp::validate_config(cfg)
}

/// Shared reference-solution cache: cells whose configs describe the same
/// problem (and λ1) reuse one x*. `solve_reference` is deterministic, so
/// a racing duplicate solve returns the identical vector — the cache only
/// saves time, never changes results.
#[derive(Default)]
pub struct RefCache {
    inner: Mutex<BTreeMap<String, Arc<Vec<f64>>>>,
}

impl RefCache {
    fn key(cfg: &Config) -> String {
        format!(
            "{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}",
            cfg.problem,
            cfg.nodes,
            cfg.samples_per_node,
            cfg.dim,
            cfg.classes,
            cfg.batches,
            cfg.lambda1,
            cfg.lambda2,
            cfg.separation,
            cfg.shuffled,
            cfg.seed
        )
    }

    pub fn get_or_solve(&self, cfg: &Config, problem: &dyn Problem) -> Arc<Vec<f64>> {
        let key = RefCache::key(cfg);
        if let Some(hit) = self.inner.lock().unwrap().get(&key).cloned() {
            return hit;
        }
        // solve outside the lock so unrelated references proceed in
        // parallel; a duplicate compute yields the same deterministic x*
        let x = Arc::new(solve_reference(problem, cfg.lambda1, REF_MAX_ITER, REF_TOL));
        self.inner.lock().unwrap().entry(key).or_insert(x).clone()
    }
}

/// Run one cell serially, solving its own reference. This is the exact
/// function the pool fans out (modulo the shared [`RefCache`]), exposed so
/// tests can pin a sweep cell to the serial [`crate::runner::run_engine`]
/// path.
pub fn run_cell(cell: &Cell, target_subopt: Option<f64>) -> CellOutcome {
    run_cell_cached(cell, target_subopt, &RefCache::default())
}

fn run_cell_cached(cell: &Cell, target_subopt: Option<f64>, cache: &RefCache) -> CellOutcome {
    #[allow(clippy::disallowed_methods)] // wall-clock run timing (see clippy.toml)
    let t0 = Instant::now();
    // sweeps always run the native kernels — the PJRT compute path is
    // per-run, not per-grid (use `proxlead train --compute xla` for that).
    // `cfg.backend` (engine | coordinator | sim) is left alone so a grid
    // can sweep over the run backend itself.
    let mut cfg = cell.config.clone();
    cfg.compute = "native".into();
    let cfg = &cfg;
    // the single Config → Experiment resolution pipeline (problem registry,
    // CSR-auto mixing, auto-η); the shared cache injects the reference x*
    let exp = Experiment::from_config(cfg).expect("validated experiment");
    exp.set_reference(cache.get_or_solve(cfg, exp.problem.as_ref()));
    let seed = cell_seed(cfg.seed, cell.index);
    // the unified run API: per-cell seed + optional early-stop target on
    // the experiment's own rounds/record_every
    let mut spec = exp.run_spec().with_seed(seed);
    if let Some(t) = target_subopt {
        spec = spec.until(t);
    }
    let result = exp.run_backend(&spec);
    CellOutcome {
        index: cell.index,
        overrides: cell.overrides.clone(),
        name: result.name.clone(),
        seed,
        eta: exp.hyper.eta,
        result,
        wall_ns: t0.elapsed().as_nanos(),
    }
}

/// Execute the whole grid on the spec's thread count. `progress` runs on
/// the calling thread as cells complete (completion order); the returned
/// cells are in index order.
pub fn run_sweep(
    spec: &SweepSpec,
    progress: impl FnMut(&CellOutcome),
) -> Result<SweepResult, ConfigError> {
    run_sweep_with_cache(spec, &RefCache::default(), progress)
}

/// [`run_sweep`] against a caller-owned [`RefCache`] — lets several specs
/// over the same problem (e.g. a figure's panels) share one reference
/// solve. Results are unchanged; only wall-clock differs.
pub fn run_sweep_with_cache(
    spec: &SweepSpec,
    cache: &RefCache,
    mut progress: impl FnMut(&CellOutcome),
) -> Result<SweepResult, ConfigError> {
    let cells = spec.cells()?;
    let outcomes = pool::parallel_map_progress(
        cells.len(),
        spec.threads,
        |i| run_cell_cached(&cells[i], spec.target_subopt, cache),
        |_, out| progress(out),
    );
    Ok(SweepResult { spec: spec.clone(), cells: outcomes })
}

/// [`run_sweep`] with a per-cell progress line (name, suboptimality,
/// Mbits, wall-clock) on stdout — the default for benches and the CLI.
pub fn run_sweep_verbose(spec: &SweepSpec) -> Result<SweepResult, ConfigError> {
    run_sweep_verbose_with_cache(spec, &RefCache::default())
}

/// [`run_sweep_verbose`] sharing a caller-owned reference cache across
/// several specs (see [`run_sweep_with_cache`]).
pub fn run_sweep_verbose_with_cache(
    spec: &SweepSpec,
    cache: &RefCache,
) -> Result<SweepResult, ConfigError> {
    let total = spec.num_cells();
    let mut done = 0usize;
    run_sweep_with_cache(spec, cache, |out| {
        done += 1;
        let (subopt, mbits) = match out.result.history.last() {
            Some(m) => (m.suboptimality, m.bits as f64 / 1e6),
            None => (f64::NAN, 0.0),
        };
        println!(
            "  [{done}/{total}] cell {:<3} {:<34} subopt {subopt:>10.3e}  {mbits:>8.2} Mbit  {:.2?}",
            out.index,
            out.name,
            Duration::from_nanos(out.wall_ns as u64),
        );
    })
}

fn jnum(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else {
        Json::Null
    }
}

impl CellOutcome {
    /// Final recorded suboptimality (NaN when the history is empty).
    pub fn final_subopt(&self) -> f64 {
        self.result.final_subopt()
    }

    fn to_json(&self) -> Json {
        let overrides = Json::Obj(
            self.overrides
                .iter()
                .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                .collect::<BTreeMap<String, Json>>(),
        );
        let history = Json::Arr(
            self.result
                .history
                .iter()
                .map(|m| {
                    Json::Arr(vec![
                        Json::Num(m.round as f64),
                        Json::Num(m.grad_evals as f64),
                        Json::Num(m.bits as f64),
                        jnum(m.suboptimality),
                        jnum(m.consensus),
                    ])
                })
                .collect(),
        );
        let last = self.result.history.last();
        Json::obj(vec![
            ("index", self.index.into()),
            ("name", self.name.as_str().into()),
            ("overrides", overrides),
            // the full 64-bit seed as a string (f64 would lose precision)
            ("seed", Json::Str(format!("{}", self.seed))),
            ("eta", jnum(self.eta)),
            ("rounds", last.map(|m| Json::Num(m.round as f64)).unwrap_or(Json::Null)),
            ("final_subopt", last.map(|m| jnum(m.suboptimality)).unwrap_or(Json::Null)),
            (
                "rounds_to_target",
                self.result
                    .rounds_to_target()
                    .map(|r| Json::Num(r as f64))
                    .unwrap_or(Json::Null),
            ),
            // which criterion ended the cell (deterministic: sweeps carry
            // no wall-clock deadline)
            ("stopped_by", self.result.stopped_by.name().into()),
            ("grad_evals", last.map(|m| Json::Num(m.grad_evals as f64)).unwrap_or(Json::Null)),
            ("bits", last.map(|m| Json::Num(m.bits as f64)).unwrap_or(Json::Null)),
            ("history", history),
        ])
    }
}

impl SweepResult {
    /// The deterministic JSON aggregate: the spec (minus thread count) and
    /// every cell trajectory. Deliberately excludes anything
    /// scheduling-dependent (wall-clock, threads), so the same grid at
    /// `threads = 1` and `threads = 8` serializes to identical bytes.
    pub fn to_json(&self) -> Json {
        let variants = Json::Arr(
            self.spec
                .variants
                .iter()
                .map(|v| {
                    Json::Obj(
                        v.iter()
                            .map(|(k, val)| (k.clone(), Json::Str(val.clone())))
                            .collect::<BTreeMap<String, Json>>(),
                    )
                })
                .collect(),
        );
        let axes = Json::Arr(
            self.spec
                .axes
                .iter()
                .map(|a| {
                    Json::obj(vec![
                        ("key", a.key.as_str().into()),
                        (
                            "values",
                            Json::Arr(a.values.iter().map(|v| Json::Str(v.clone())).collect()),
                        ),
                    ])
                })
                .collect(),
        );
        Json::obj(vec![
            ("schema", "proxlead-sweep-v1".into()),
            ("base", Json::Str(self.spec.base.to_text())),
            (
                "target_subopt",
                self.spec.target_subopt.map(Json::Num).unwrap_or(Json::Null),
            ),
            ("variants", variants),
            ("axes", axes),
            ("cells", Json::Arr(self.cells.iter().map(|c| c.to_json()).collect())),
        ])
    }

    /// Serialize [`SweepResult::to_json`] to `path`.
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_json().to_string())
    }

    /// Wall-clock / bits / convergence summary table for stdout.
    pub fn summary_table(&self, title: &str) -> Table {
        let mut t = Table::new(
            title,
            &["cell", "algorithm", "overrides", "subopt", "rounds", "grad evals", "Mbit", "wall"],
        );
        for c in &self.cells {
            let last = c.result.history.last();
            let ov: Vec<String> =
                c.overrides.iter().map(|(k, v)| format!("{k}={v}")).collect();
            t.row(vec![
                format!("{}", c.index),
                c.name.clone(),
                ov.join(" "),
                last.map(|m| format!("{:.3e}", m.suboptimality)).unwrap_or_default(),
                c.result
                    .rounds_to_target()
                    .map(|r| format!("{r}"))
                    .or_else(|| last.map(|m| format!("{}", m.round)))
                    .unwrap_or_default(),
                last.map(|m| format!("{}", m.grad_evals)).unwrap_or_default(),
                last.map(|m| format!("{:.2}", m.bits as f64 / 1e6)).unwrap_or_default(),
                format!("{:.2?}", Duration::from_nanos(c.wall_ns as u64)),
            ]);
        }
        t
    }

    /// Total communicated bits across all cells.
    pub fn total_bits(&self) -> u64 {
        self.cells
            .iter()
            .filter_map(|c| c.result.history.last().map(|m| m.bits))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_base() -> Config {
        Config::parse(
            "nodes = 4\nsamples_per_node = 24\ndim = 5\nclasses = 3\nbatches = 4\n\
             lambda1 = 0\nlambda2 = 0.1\nrounds = 60\nrecord_every = 20\n",
        )
        .unwrap()
    }

    #[test]
    fn cell_seed_is_stable_and_decorrelated() {
        assert_eq!(cell_seed(42, 0), cell_seed(42, 0));
        assert_ne!(cell_seed(42, 0), cell_seed(42, 1));
        assert_ne!(cell_seed(42, 0), cell_seed(43, 0));
        // neighboring cells should differ in many bits, not one
        let a = cell_seed(7, 10);
        let b = cell_seed(7, 11);
        assert!((a ^ b).count_ones() > 8, "{a:x} vs {b:x}");
    }

    #[test]
    fn validate_rejects_unknown_algorithm() {
        let mut cfg = tiny_base();
        cfg.algorithm = "gradient-descent-but-wrong".into();
        assert!(validate_cell(&cfg).is_err());
        cfg.algorithm = "nids".into();
        assert!(validate_cell(&cfg).is_ok());
    }

    #[test]
    fn every_registered_algorithm_constructs_and_steps() {
        let cfg = tiny_base();
        for name in crate::exp::ALGORITHM_NAMES {
            let mut c = cfg.clone();
            c.algorithm = (*name).into();
            if *name == "choco" {
                c.gamma = 0.2; // gossip stepsize convention
            }
            let exp = Experiment::from_config(&c).unwrap();
            let mut alg = exp.algorithm_with_seed(3);
            alg.step(exp.problem.as_ref());
            assert!(alg.x().is_finite(), "{name} produced non-finite iterates");
        }
    }

    #[test]
    fn problem_key_is_a_sweep_axis() {
        // the acceptance scenario: a `problem` axis fans the same grid
        // across problem families, least-squares running end to end
        let mut base = tiny_base();
        base.rounds = 30;
        base.record_every = 30;
        let spec =
            SweepSpec::new(base).axis("problem", &["logreg", "least-squares"]).threads(2);
        let res = run_sweep(&spec, |_| {}).unwrap();
        assert_eq!(res.cells.len(), 2);
        for (c, dim) in res.cells.iter().zip([5 * 3, 5]) {
            assert!(c.final_subopt().is_finite());
            assert_eq!(c.result.final_x.cols, dim, "problem axis must rebuild the problem");
        }
        // unknown problems are rejected at validation, before fan-out
        let spec = SweepSpec::new(tiny_base()).axis("problem", &["sudoku"]);
        assert!(spec.cells().is_err());
    }

    #[test]
    fn sweep_forces_native_compute() {
        // the PJRT compute path is per-run, not per-grid: a compute=xla
        // config sweeps on the native kernels instead of panicking in the
        // pool when artifacts are unavailable (the stub default)
        let mut base = tiny_base();
        base.rounds = 10;
        base.record_every = 10;
        base.compute = "xla".into();
        let res = run_sweep(&SweepSpec::new(base), |_| {}).unwrap();
        assert_eq!(res.cells.len(), 1);
        assert!(res.cells[0].final_subopt().is_finite());
    }

    #[test]
    fn backend_is_a_sweep_axis() {
        // the run backend (engine | coordinator | sim) is gridable: the
        // same cell dispatches to all three and every backend reports
        // itself in the result. Per-cell seeds differ, so this asserts
        // dispatch, not bit-parity (rust/tests/sim_parity.rs pins that).
        let mut base = tiny_base();
        base.rounds = 10;
        base.record_every = 10;
        let spec = SweepSpec::new(base)
            .axis("backend", &["engine", "coordinator", "sim"])
            .threads(2);
        let res = run_sweep(&spec, |_| {}).unwrap();
        assert_eq!(res.cells.len(), 3);
        use crate::runner::Backend;
        for (c, b) in
            res.cells.iter().zip([Backend::Engine, Backend::Coordinator, Backend::Sim])
        {
            assert_eq!(c.result.backend, b, "backend axis must reach {}", b.name());
            assert!(c.final_subopt().is_finite());
        }
        // unknown backends are rejected at validation, before fan-out
        let spec = SweepSpec::new(tiny_base()).axis("backend", &["tpu"]);
        assert!(spec.cells().is_err());
    }

    #[test]
    fn small_sweep_runs_and_serializes() {
        let spec = SweepSpec::new(tiny_base())
            .variant(&[("algorithm", "prox-lead"), ("bits", "2")])
            .variant(&[("algorithm", "dgd"), ("bits", "32")])
            .axis("seed", &["1", "2"])
            .threads(2);
        assert_eq!(spec.num_cells(), 4);
        let res = run_sweep(&spec, |_| {}).unwrap();
        assert_eq!(res.cells.len(), 4);
        for (i, c) in res.cells.iter().enumerate() {
            assert_eq!(c.index, i);
            assert!(c.final_subopt().is_finite());
        }
        // serialized form parses back and has the right shape
        let text = res.to_json().to_string();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.get("schema").unwrap().as_str(), Some("proxlead-sweep-v1"));
        assert_eq!(parsed.get("cells").unwrap().as_arr().unwrap().len(), 4);
        // wall-clock and thread count must NOT leak into the aggregate
        assert!(!text.contains("wall"));
        assert!(!text.contains("threads"));
    }

    #[test]
    fn nodes_axis_sweeps_topology_scale() {
        // the `nodes` axis resolves per cell: graph, problem, and x0 all
        // track the cell's node count (ring 4 stays dense, ring 32 CSR)
        let mut base = tiny_base();
        base.rounds = 10;
        base.record_every = 10;
        let spec = SweepSpec::new(base).axis("nodes", &["4", "32"]).threads(2);
        let res = run_sweep(&spec, |_| {}).unwrap();
        assert_eq!(res.cells.len(), 2);
        for (c, nodes) in res.cells.iter().zip([4usize, 32]) {
            assert!(c.final_subopt().is_finite(), "nodes={nodes}");
            assert_eq!(c.result.final_x.rows, nodes);
        }
    }

    #[test]
    fn grid_topology_rejects_non_square_nodes_as_config_error() {
        let mut cfg = tiny_base();
        cfg.topology = "grid".into();
        cfg.nodes = 8;
        let err = validate_cell(&cfg).unwrap_err();
        assert!(err.0.contains("perfect square"), "{}", err.0);
        cfg.nodes = 9;
        assert!(validate_cell(&cfg).is_ok());
    }

    #[test]
    fn reference_cache_shares_identical_problems() {
        let cfg = tiny_base();
        let problem = crate::exp::build_problem(&cfg).unwrap();
        let cache = RefCache::default();
        let a = cache.get_or_solve(&cfg, problem.as_ref());
        let b = cache.get_or_solve(&cfg, problem.as_ref());
        assert!(Arc::ptr_eq(&a, &b));
        let mut cfg2 = cfg.clone();
        cfg2.lambda1 = 5e-3;
        let c = cache.get_or_solve(&cfg2, problem.as_ref());
        assert!(!Arc::ptr_eq(&a, &c));
        // a different problem family must never share an x*
        let mut cfg3 = cfg.clone();
        cfg3.problem = "least-squares".into();
        let p3 = crate::exp::build_problem(&cfg3).unwrap();
        let d = cache.get_or_solve(&cfg3, p3.as_ref());
        assert!(!Arc::ptr_eq(&a, &d));
        assert_ne!(a.len(), d.len());
    }
}
