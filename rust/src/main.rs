//! `proxlead` — the launcher binary.
//!
//! Subcommands (see `proxlead help`):
//! - `train`: any registry algorithm on the configured run backend —
//!   `--backend engine` (matrix engine, the default), `--backend
//!   coordinator` (message-passing node threads, real wire bytes), or
//!   `--backend sim` (sharded massive-n simulator) — optionally with the
//!   PJRT/XLA gradient compute path (`--compute xla`). Under
//!   `--transport tcp|unix` the coordinator leader listens on `bind` for
//!   `proxlead node` worker processes instead of spawning threads;
//! - `node`: one worker process of a socket-transport coordinator run
//!   (dials the leader, handshakes as `--node-id N`);
//! - `sweep`: a parallel experiment grid through the matrix engine (the
//!   sweep runtime — deterministic regardless of `--threads`);
//! - `solve-ref`: high-precision centralized reference x*;
//! - `info`: condition numbers, spectra, artifact registry;
//! - `config`: print the effective configuration.
//!
//! Every subcommand resolves its configuration through the one
//! [`Experiment`] pipeline — no per-command factory wiring.

use proxlead::algorithm::solve_reference;
use proxlead::cli::{self, Invocation, USAGE};
use proxlead::exp::Experiment;
use proxlead::problem::Problem;
use proxlead::runner::{CsvProbe, Probe, ProgressProbe, RunSpec};
use proxlead::runtime::{default_artifact_dir, PjrtRuntime};
use std::time::Duration;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let inv = match cli::parse(&args) {
        Ok(inv) => inv,
        Err(e) => {
            eprintln!("{e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    let code = match inv.subcommand.as_str() {
        "train" => cmd_train(&inv),
        "node" => cmd_node(&inv),
        "sweep" => cmd_sweep(&inv),
        "solve-ref" => cmd_solve_ref(&inv),
        "info" => cmd_info(&inv),
        "config" => {
            print!("{}", inv.config.to_text());
            0
        }
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            0
        }
        other => {
            eprintln!("unknown subcommand '{other}'\n\n{USAGE}");
            2
        }
    };
    std::process::exit(code);
}

/// Resolve the invocation's config, or print the error and exit code 2.
fn resolve(inv: &Invocation) -> Result<Experiment, i32> {
    Experiment::from_config(&inv.config).map_err(|e| {
        eprintln!("{e}");
        2
    })
}

/// Parse the train stop flags into the run spec (composable; any subset).
fn train_spec(inv: &Invocation, exp: &Experiment) -> Result<RunSpec, String> {
    let mut spec = exp.run_spec();
    for (key, val) in &inv.extra {
        spec = match key.as_str() {
            "target" => match val.parse::<f64>() {
                Ok(t) if t > 0.0 => spec.until(t),
                _ => return Err(format!("--target needs a positive float (got '{val}')")),
            },
            "max-bits" => match val.parse::<u64>() {
                Ok(b) if b > 0 => spec.bits_budget(b),
                _ => return Err(format!("--max-bits needs a positive integer (got '{val}')")),
            },
            "max-grad-evals" => match val.parse::<u64>() {
                Ok(g) if g > 0 => spec.grad_evals_budget(g),
                _ => {
                    return Err(format!("--max-grad-evals needs a positive integer (got '{val}')"))
                }
            },
            "deadline-ms" => match val.parse::<u64>() {
                Ok(ms) => spec.deadline(Duration::from_millis(ms)),
                _ => return Err(format!("--deadline-ms needs an integer (got '{val}')")),
            },
            // consumed by cmd_train after the run (not a stop criterion)
            "json" => spec,
            _ => return Err(format!("unrecognized or invalid flag --{key} {val}\n\n{USAGE}")),
        };
    }
    Ok(spec)
}

fn cmd_train(inv: &Invocation) -> i32 {
    let cfg = &inv.config;
    let exp = match resolve(inv) {
        Ok(e) => e,
        Err(code) => return code,
    };
    let spec = match train_spec(inv, &exp) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    // power iteration: O(nnz) per step, fine at any n (no dense eigensolve)
    let gap = exp.mixing.gap_estimate();
    println!(
        "proxlead train: {} on {} [{} backend] | {} nodes ({}, {}, {}) | {} | η={:.4} α={} γ={}",
        cfg.algorithm,
        exp.problem.name(),
        cfg.backend,
        cfg.nodes,
        cfg.topology,
        cfg.mixing,
        if exp.mixing.is_sparse() { "csr" } else { "dense" },
        exp.codec().name(),
        exp.hyper.eta,
        cfg.alpha,
        cfg.gamma,
    );
    println!(
        "κ_f = {:.1}, κ_g {} {:.2}, data = label-{}",
        exp.problem.kappa_f(),
        // ≈ when power iteration exhausted its budget (near-degenerate
        // spectral edge, e.g. very large rings) — estimate, not exact
        if gap.converged { "=" } else { "≈" },
        gap.kappa_g(),
        if cfg.shuffled { "shuffled (iid)" } else { "sorted (non-iid)" }
    );

    // reference for the suboptimality metric (cached on the experiment)
    eprint!("solving reference x*… ");
    let _ = exp.reference();
    eprintln!("done");

    // metrics stream while the run is in flight: progress lines always,
    // live CSV when --out is set (a killed run keeps its rows)
    let mut progress = ProgressProbe::new();
    let res = if cfg.out.is_empty() {
        exp.run_backend_probed(&spec, &mut [&mut progress])
    } else {
        let mut csv = match CsvProbe::to_path(&cfg.out) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("open {}: {e}", cfg.out);
                return 1;
            }
        };
        let probes: &mut [&mut dyn Probe] = &mut [&mut progress, &mut csv];
        let res = exp.run_backend_probed(&spec, probes);
        println!("wrote {}", cfg.out);
        res
    };
    if let Some(path) = inv.flag("json") {
        if let Err(e) = std::fs::write(path, res.to_json()) {
            eprintln!("write {path}: {e}");
            return 1;
        }
        println!("wrote {path}");
    }
    0
}

/// `proxlead node`: one worker process of a socket-transport coordinator
/// run. Dials the leader at the config's `bind` address, handshakes as
/// `--node-id N`, drives the configured algorithm's node half over the
/// socket, and exits when the leader tears the run down (BYE/ABORT). The
/// stop flags must match the leader's invocation — they shape the
/// handshake (rounds, record_every, gating), and a mismatch is a typed
/// reject at dial time.
fn cmd_node(inv: &Invocation) -> i32 {
    let Some(id) = inv.flag("node-id") else {
        eprintln!("node: --node-id N is required (0-based, one worker per node)");
        return 2;
    };
    let node: usize = match id.parse() {
        Ok(v) => v,
        Err(_) => {
            eprintln!("--node-id needs a non-negative integer (got '{id}')");
            return 2;
        }
    };
    let exp = match resolve(inv) {
        Ok(e) => e,
        Err(code) => return code,
    };
    // the remaining extras are the train stop flags, shared with the leader
    let rest = Invocation {
        subcommand: inv.subcommand.clone(),
        config: inv.config.clone(),
        extra: inv.extra.iter().filter(|(k, _)| k != "node-id").cloned().collect(),
    };
    let spec = match train_spec(&rest, &exp) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    eprintln!(
        "proxlead node {node}: dialing {} over {} ({} on {})",
        inv.config.bind,
        inv.config.transport,
        inv.config.algorithm,
        exp.problem.name()
    );
    match exp.run_node_worker(&spec, node) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("{e}");
            1
        }
    }
}

fn cmd_sweep(inv: &Invocation) -> i32 {
    use proxlead::sweep::{run_sweep_verbose, SweepSpec};
    // `extra` holds both sweep-specific flags and config overrides whose
    // values failed to parse — reject anything we don't recognize instead
    // of silently sweeping a default configuration
    for (key, val) in &inv.extra {
        if !matches!(key.as_str(), "grid" | "threads" | "target") {
            eprintln!("unrecognized or invalid flag --{key} {val}\n\n{USAGE}");
            return 2;
        }
    }
    let mut spec = SweepSpec::new(inv.config.clone());
    if let Some(grid) = inv.flag("grid") {
        spec = match spec.with_grid(grid) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
    }
    if let Some(t) = inv.flag("threads") {
        match t.parse::<usize>() {
            Ok(n) if n > 0 => spec = spec.threads(n),
            _ => {
                eprintln!("--threads needs a positive integer (got '{t}')");
                return 2;
            }
        }
    }
    if let Some(t) = inv.flag("target") {
        match t.parse::<f64>() {
            Ok(x) if x > 0.0 => spec = spec.until(x),
            _ => {
                eprintln!("--target needs a positive float (got '{t}')");
                return 2;
            }
        }
    }
    println!(
        "prox-lead sweep: {} cells ({} variants × axes {:?}) on {} threads",
        spec.num_cells(),
        spec.variants.len().max(1),
        spec.axes.iter().map(|a| format!("{}×{}", a.key, a.values.len())).collect::<Vec<_>>(),
        spec.threads,
    );
    let res = match run_sweep_verbose(&spec) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    res.summary_table("sweep summary").print();
    println!("total wire payload across cells: {:.2} Mbit", res.total_bits() as f64 / 1e6);
    if !inv.config.out.is_empty() {
        match res.write_json(&inv.config.out) {
            Ok(()) => println!("wrote {}", inv.config.out),
            Err(e) => {
                eprintln!("write {}: {e}", inv.config.out);
                return 1;
            }
        }
    }
    0
}

fn cmd_solve_ref(inv: &Invocation) -> i32 {
    let cfg = &inv.config;
    let tol: f64 = inv.flag("tol").map(|t| t.parse().expect("tol")).unwrap_or(1e-12);
    let exp = match resolve(inv) {
        Ok(e) => e,
        Err(code) => return code,
    };
    let x = solve_reference(exp.problem.as_ref(), cfg.lambda1, 100_000, tol);
    let loss = exp.problem.global_loss(&x);
    let nnz = x.iter().filter(|v| v.abs() > 1e-9).count();
    println!(
        "x*: dim {} | smooth loss {loss:.6} | nnz {nnz}/{} (λ1 = {})",
        x.len(),
        x.len(),
        cfg.lambda1
    );
    if !cfg.out.is_empty() {
        let text: String = x.iter().map(|v| format!("{v:.17e}\n")).collect();
        std::fs::write(&cfg.out, text).expect("write x*");
        println!("wrote {}", cfg.out);
    }
    0
}

fn cmd_info(inv: &Invocation) -> i32 {
    let cfg = &inv.config;
    // info diagnoses the native problem/network; PJRT availability is
    // reported separately below (no hard dependency on artifacts, and no
    // double runtime load when they exist)
    let mut native_cfg = inv.config.clone();
    native_cfg.compute = "native".into();
    let exp = match Experiment::from_config(&native_cfg) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let spec = exp.mixing.gap_estimate();
    println!("prox-lead {}", proxlead::version());
    println!(
        "network: {} n={} edges={} nnz={} ({}) | λ2(W){eq}{:.4} λn(W){eq}{:.4} \
         κ_g{eq}{:.3} gap{eq}{:.4}",
        cfg.topology,
        cfg.nodes,
        exp.graph.num_edges(),
        exp.mixing.nnz(),
        if exp.mixing.is_sparse() { "csr" } else { "dense" },
        spec.lambda2,
        spec.lambda_min,
        spec.kappa_g(),
        spec.spectral_gap(),
        // ≈ when the power iteration exhausted its budget (see GapEstimate)
        eq = if spec.converged { "=" } else { "≈" },
    );
    print!(
        "problem: {} | L={:.3} μ={:.3} κ_f={:.1}",
        exp.problem.name(),
        exp.problem.smoothness(),
        exp.problem.strong_convexity(),
        exp.problem.kappa_f(),
    );
    // the built problem exposes its own shards — no second data generation
    if let Some(lr) = exp.problem.as_logreg() {
        println!(
            " | heterogeneity index {:.3}",
            proxlead::problem::data::heterogeneity_index(lr.shards(), cfg.classes),
        );
    } else {
        println!();
    }
    match PjrtRuntime::load(&default_artifact_dir()) {
        Ok(rt) => {
            let m = rt.manifest();
            println!("artifacts: {} compiled ({})", rt.len(), m.format);
            for a in &m.artifacts {
                println!(
                    "  {} ({}, m={}, d={}, C={}, λ2={})",
                    a.name, a.fn_name, a.m, a.d, a.c, a.lam2
                );
            }
        }
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    0
}
