//! The byte-stream transport layer: how a node's framed wire bytes reach
//! its gossip neighbors, abstracted behind [`NodeLink`] so the coordinator
//! node loop ([`crate::coordinator::node::run_node`]) is transport-generic.
//!
//! Three implementations:
//!
//! - **InProc** ([`InProcLink`]): the original per-edge
//!   [`crate::runtime::sync`] channels — byte-identical to the historical
//!   coordinator, still fully visible to the `proxlead-check` scheduler
//!   and the lint rules, and the parity baseline the socket transports are
//!   pinned against (`rust/tests/transport_parity.rs`).
//! - **Tcp** / **Unix** ([`socket::SocketLink`]): real OS byte streams.
//!   Each node process dials the leader ([`socket::dial`]) with bounded
//!   exponential backoff, performs a [`Hello`] handshake (node id +
//!   config fingerprint + run-shape fields; mismatch → typed
//!   [`Reject`]), and then exchanges length-delimited frames
//!   ([`framing`]) — the leader relays data frames along the mixing
//!   graph's edges, so the per-edge channel abstraction survives the
//!   hub-and-spoke socket topology.
//!
//! **Fault taxonomy.** Every socket failure mode — EOF, connection
//! refused, timeout, short read, oversize frame, handshake rejection —
//! is a typed [`TransportError`], folded into
//! [`crate::coordinator::WireError::Transport`] so a dead peer surfaces
//! through the existing ABORT/BYE teardown as a
//! [`crate::runner::StopReason::WireFault`] — never a hang, never a
//! panic. The socket read path reuses a scratch buffer
//! ([`framing::read_frame_into`]) so the PR-6 zero-alloc decode path
//! ([`crate::coordinator::FrameRef::parse`] + `decode_into`) is
//! preserved end to end; the one allocation per received frame is the
//! `Arc<[u8]>` handoff the in-process transport also pays per broadcast.
//!
//! See DESIGN.md §4e for the wire-level contract.

pub mod framing;
pub mod socket;

pub use framing::Hello;
pub use socket::{dial, DialAddr, SocketLink};

use crate::coordinator::NodeEvent;
use crate::runtime::sync;
use std::fmt;
use std::sync::Arc;
use std::time::Duration;

/// Control-plane frame tags, disjoint from the codec tags (0–2) and the
/// teardown tags (`BYE` 0xFE, `ABORT` 0xFF). Control frames reuse the
/// 11-byte inner header so one parser serves both planes.
pub const VERDICT_TAG: u8 = 0xF8;
/// Handshake rejection (leader → node); payload is one [`Reject`] code.
pub const REJECT_TAG: u8 = 0xF9;
/// Handshake acceptance (leader → node); empty payload.
pub const WELCOME_TAG: u8 = 0xFA;
/// Handshake opener (node → leader); payload is a [`Hello`].
pub const HELLO_TAG: u8 = 0xFB;
/// A node-detected [`crate::coordinator::WireFault`] (node → leader).
pub const FAULT_TAG: u8 = 0xFC;
/// A [`crate::coordinator::NodeReport`] snapshot (node → leader).
pub const REPORT_TAG: u8 = 0xFD;

/// Why the leader refused a dialing node's handshake.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Reject {
    /// Node id outside `0..n`.
    NodeIdRange,
    /// A node with this id already completed the handshake.
    DuplicateNode,
    /// The node's config fingerprint differs from the leader's — the two
    /// processes parsed different configs.
    ConfigFingerprint,
    /// Fingerprints agree but a run-shape field (n, dim, rounds,
    /// record_every, gating) differs — CLI-flag drift outside the config.
    SpecShape,
}

impl Reject {
    pub(crate) fn code(self) -> u8 {
        match self {
            Reject::NodeIdRange => 0,
            Reject::DuplicateNode => 1,
            Reject::ConfigFingerprint => 2,
            Reject::SpecShape => 3,
        }
    }

    pub(crate) fn from_code(c: u8) -> Option<Reject> {
        match c {
            0 => Some(Reject::NodeIdRange),
            1 => Some(Reject::DuplicateNode),
            2 => Some(Reject::ConfigFingerprint),
            3 => Some(Reject::SpecShape),
            _ => None,
        }
    }
}

impl fmt::Display for Reject {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Reject::NodeIdRange => write!(f, "node id outside 0..n"),
            Reject::DuplicateNode => write!(f, "duplicate node id"),
            Reject::ConfigFingerprint => write!(f, "config fingerprint mismatch"),
            Reject::SpecShape => write!(f, "run-shape mismatch (n/dim/rounds/record_every/gating)"),
        }
    }
}

/// Everything that can go wrong moving framed bytes over a link. `Copy +
/// Eq` so it can ride inside [`crate::coordinator::WireError`] (and thus
/// [`crate::runner::StopReason`]) without touching those enums' derives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportError {
    /// Clean close at a message boundary — the peer's socket is gone.
    Eof,
    /// The stream ended mid-message: `got` of `need` bytes.
    ShortRead { need: u32, got: u32 },
    /// A per-op read/write deadline expired.
    TimedOut,
    /// Connection refused past the dial retry budget.
    Refused,
    /// An outer length prefix beyond [`framing::MAX_FRAME_LEN`].
    Oversize { len: u32 },
    /// The leader refused this node's handshake.
    Rejected(Reject),
    /// Bytes that violate the control-plane framing (bad handshake reply,
    /// undecodable control payload).
    Protocol,
    /// The in-process channel peer is gone (the socket `Eof` analogue).
    Closed,
    /// Fewer than n nodes completed the handshake within the accept
    /// deadline; `missing` is the lowest absent node id.
    HandshakeTimeout { missing: u16 },
}

impl fmt::Display for TransportError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            TransportError::Eof => write!(f, "connection closed by peer"),
            TransportError::ShortRead { need, got } => {
                write!(f, "short read: {got} of {need} bytes before the stream ended")
            }
            TransportError::TimedOut => write!(f, "socket operation timed out"),
            TransportError::Refused => write!(f, "connection refused past the retry budget"),
            TransportError::Oversize { len } => {
                write!(f, "frame length {len} exceeds the {} byte cap", framing::MAX_FRAME_LEN)
            }
            TransportError::Rejected(r) => write!(f, "handshake rejected: {r}"),
            TransportError::Protocol => write!(f, "control-plane protocol violation"),
            TransportError::Closed => write!(f, "channel closed by peer"),
            TransportError::HandshakeTimeout { missing } => {
                write!(f, "handshake deadline expired; lowest missing node: {missing}")
            }
        }
    }
}

impl std::error::Error for TransportError {}

/// Map an io error onto the transport taxonomy (refused/timeout/EOF; the
/// long tail degrades to `Closed`, which still tears the run down typed).
pub(crate) fn map_io(e: &std::io::Error) -> TransportError {
    use std::io::ErrorKind;
    match e.kind() {
        ErrorKind::ConnectionRefused => TransportError::Refused,
        ErrorKind::WouldBlock | ErrorKind::TimedOut => TransportError::TimedOut,
        ErrorKind::UnexpectedEof
        | ErrorKind::ConnectionReset
        | ErrorKind::ConnectionAborted
        | ErrorKind::BrokenPipe => TransportError::Eof,
        _ => TransportError::Closed,
    }
}

/// FNV-1a over a config's canonical text form ([`crate::config::Config::
/// to_text`]) — the handshake fingerprint that catches two processes
/// running different configs before any wire round starts.
pub fn fingerprint(text: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in text.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// One node's view of the network: broadcast a framed buffer to every
/// gossip neighbor, receive the next inbound frame, and talk to the
/// leader (metric reports up, continue/stop verdicts down). The node
/// round loop is written against this trait only; the implementations
/// decide whether the bytes cross a channel or a socket.
pub trait NodeLink: Send {
    /// Send `frame` to every gossip neighbor. Must *attempt* all
    /// neighbors even after one fails (the ABORT teardown wave relies on
    /// reaching the still-alive ones); returns `Err` if any send failed.
    fn broadcast(&mut self, frame: &Arc<[u8]>) -> Result<(), TransportError>;

    /// Block for the next inbound neighbor frame (data, BYE, or ABORT —
    /// verbatim bytes; the caller's `absorb` does the judging).
    fn recv(&mut self) -> Result<Arc<[u8]>, TransportError>;

    /// Report a snapshot or a detected fault to the leader.
    fn report(&mut self, ev: NodeEvent) -> Result<(), TransportError>;

    /// Block for the leader's checkpoint verdict: `true` = continue.
    fn verdict(&mut self) -> Result<bool, TransportError>;

    /// Is this run leader-gated (checkpoint verdicts flow at all)?
    fn gated(&self) -> bool;
}

/// The in-process transport: per-edge [`sync`] channels, exactly as the
/// coordinator has always wired them — every operation still goes through
/// the shim layer, so `proxlead-check` schedules it and the teardown
/// scenarios keep their coverage.
pub struct InProcLink {
    /// Senders into each gossip neighbor's inbox, ascending neighbor id.
    neighbors: Vec<sync::Sender<Arc<[u8]>>>,
    inbox: sync::Receiver<Arc<[u8]>>,
    reports: sync::Sender<NodeEvent>,
    /// `Some` iff the run is leader-gated.
    control: Option<sync::Receiver<bool>>,
}

impl InProcLink {
    pub fn new(
        neighbors: Vec<sync::Sender<Arc<[u8]>>>,
        inbox: sync::Receiver<Arc<[u8]>>,
        reports: sync::Sender<NodeEvent>,
        control: Option<sync::Receiver<bool>>,
    ) -> InProcLink {
        InProcLink { neighbors, inbox, reports, control }
    }
}

impl NodeLink for InProcLink {
    fn broadcast(&mut self, frame: &Arc<[u8]>) -> Result<(), TransportError> {
        // attempt every neighbor: a dead peer (dropped receiver) must not
        // stop the teardown wave from reaching the live ones
        let mut ok = true;
        for tx in &self.neighbors {
            ok &= tx.send(Arc::clone(frame)).is_ok();
        }
        if ok {
            Ok(())
        } else {
            Err(TransportError::Closed)
        }
    }

    fn recv(&mut self) -> Result<Arc<[u8]>, TransportError> {
        self.inbox.recv().map_err(|_| TransportError::Closed)
    }

    fn report(&mut self, ev: NodeEvent) -> Result<(), TransportError> {
        self.reports.send(ev).map_err(|_| TransportError::Closed)
    }

    fn verdict(&mut self) -> Result<bool, TransportError> {
        match &self.control {
            Some(rx) => rx.recv().map_err(|_| TransportError::Closed),
            None => Ok(true),
        }
    }

    fn gated(&self) -> bool {
        self.control.is_some()
    }
}

/// The leader-side transport selector [`crate::coordinator::
/// run_with_transport`] is generic over: in-process node threads, or a
/// pre-bound socket listener the node *processes* dial. The listener is
/// bound by the caller (so tests can bind port 0 and learn the address)
/// and carries the handshake fingerprint plus the accept deadline.
pub enum Transport {
    /// Node threads over [`sync`] channels — today's behavior, verbatim.
    InProc,
    /// Node processes over a byte-stream socket (TCP or Unix).
    Socket {
        listener: socket::Listener,
        /// The [`fingerprint`] dialing nodes must present.
        fingerprint: u64,
        /// Handshake deadline: all n nodes must connect within this.
        accept_timeout: Duration,
    },
}

impl Transport {
    pub fn tcp(l: std::net::TcpListener, fingerprint: u64, accept_timeout: Duration) -> Transport {
        Transport::Socket { listener: socket::Listener::Tcp(l), fingerprint, accept_timeout }
    }

    pub fn unix(
        l: std::os::unix::net::UnixListener,
        fingerprint: u64,
        accept_timeout: Duration,
    ) -> Transport {
        Transport::Socket { listener: socket::Listener::Unix(l), fingerprint, accept_timeout }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::NodeReport;

    #[test]
    fn fingerprint_is_stable_and_content_sensitive() {
        let a = fingerprint("nodes = 8\nbits = 2\n");
        assert_eq!(a, fingerprint("nodes = 8\nbits = 2\n"), "must be deterministic");
        assert_ne!(a, fingerprint("nodes = 8\nbits = 32\n"));
        assert_ne!(fingerprint(""), fingerprint(" "));
    }

    #[test]
    fn reject_codes_round_trip() {
        for r in [
            Reject::NodeIdRange,
            Reject::DuplicateNode,
            Reject::ConfigFingerprint,
            Reject::SpecShape,
        ] {
            assert_eq!(Reject::from_code(r.code()), Some(r));
        }
        assert_eq!(Reject::from_code(9), None);
    }

    #[test]
    fn inproc_link_matches_channel_semantics() {
        let (tx_a, rx_a) = sync::channel::<Arc<[u8]>>("t.inbox");
        let (tx_rep, rx_rep) = sync::channel::<NodeEvent>("t.reports");
        let (tx_ctrl, rx_ctrl) = sync::channel::<bool>("t.ctrl");
        let mut link =
            InProcLink::new(vec![tx_a], rx_a, tx_rep, Some(rx_ctrl));
        assert!(link.gated());

        let frame: Arc<[u8]> = Arc::from([1u8, 2, 3].as_slice());
        link.broadcast(&frame).unwrap();
        assert_eq!(&link.recv().unwrap()[..], &[1, 2, 3]);

        link.report(NodeEvent::Report(NodeReport {
            node: 0,
            round: 0,
            x: vec![0.0],
            bytes_sent: 3,
            payload_bits: 0,
            grad_evals: 0,
        }))
        .unwrap();
        assert!(matches!(rx_rep.recv().unwrap(), NodeEvent::Report(r) if r.bytes_sent == 3));

        tx_ctrl.send(true).unwrap();
        assert_eq!(link.verdict(), Ok(true));
        drop(tx_ctrl);
        assert_eq!(link.verdict(), Err(TransportError::Closed));
    }

    #[test]
    fn inproc_broadcast_attempts_all_neighbors_past_a_dead_one() {
        let (tx_dead, rx_dead) = sync::channel::<Arc<[u8]>>("t.dead");
        let (tx_live, rx_live) = sync::channel::<Arc<[u8]>>("t.live");
        let (tx_rep, _rx_rep) = sync::channel::<NodeEvent>("t.reports2");
        let (_tx_self, rx_self) = sync::channel::<Arc<[u8]>>("t.self");
        drop(rx_dead); // neighbor 0 already exited
        let mut link = InProcLink::new(vec![tx_dead, tx_live], rx_self, tx_rep, None);
        assert!(!link.gated());
        assert_eq!(link.verdict(), Ok(true), "ungated links always answer continue");

        let frame: Arc<[u8]> = Arc::from([0xFFu8].as_slice());
        // the dead edge makes the broadcast an error — but the live
        // neighbor must still have received the teardown frame
        assert_eq!(link.broadcast(&frame), Err(TransportError::Closed));
        assert_eq!(&rx_live.recv().unwrap()[..], &[0xFF]);
    }

    #[test]
    fn transport_error_display_is_informative() {
        let s = format!("{}", TransportError::ShortRead { need: 11, got: 4 });
        assert!(s.contains("4") && s.contains("11"), "{s}");
        let s = format!("{}", TransportError::Rejected(Reject::ConfigFingerprint));
        assert!(s.contains("fingerprint"), "{s}");
        let s = format!("{}", TransportError::HandshakeTimeout { missing: 3 });
        assert!(s.contains('3'), "{s}");
    }
}
