//! The socket transports: TCP and Unix-domain byte streams carrying the
//! length-delimited frames of [`super::framing`].
//!
//! # Topology
//!
//! Node processes dial the **leader** only (hub and spoke). The leader
//! accepts one stream per node, runs the [`Hello`] handshake, and then
//! relays each node's data frames to its gossip neighbors along the
//! mixing graph's edges — so the per-edge channel abstraction the node
//! loop is written against survives even though only `n` sockets exist.
//! Per node the leader runs one uplink reader thread ([`run_uplink`]);
//! writes to a node's socket are serialized through a per-node mutex
//! (reader threads relay into their peers' write halves).
//!
//! # Liveness under failure
//!
//! Every socket read/write carries a per-op timeout, dials retry with
//! bounded exponential backoff up to a deadline, and a peer that dies
//! mid-run surfaces as a synthesized
//! [`WireError::Transport`] fault plus an ABORT wave to its neighbors —
//! the same teardown protocol a corrupt frame triggers, so a dead
//! process yields a typed [`crate::runner::StopReason`] rather than a
//! hang. An EOF *after* the node announced completion (BYE), aborted
//! (ABORT), or reported a fault is a clean close and synthesizes
//! nothing — otherwise every normal teardown would race a spurious
//! fault into the leader's resolution.

use super::framing::{
    decode_fault, decode_hello, decode_reject, decode_report, decode_verdict, encode_fault,
    encode_hello, encode_reject, encode_report, encode_verdict, encode_welcome, read_frame_into,
    write_frame, Hello,
};
use super::{map_io, NodeLink, Reject, TransportError, REJECT_TAG, VERDICT_TAG, WELCOME_TAG};
use super::{FAULT_TAG, REPORT_TAG};
use crate::coordinator::wire::{frame_begin, frame_end, ABORT_TAG, BYE_TAG};
use crate::coordinator::{FrameRef, NodeEvent, WireError, WireFault};
use std::collections::VecDeque;
use std::io::{self, ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Steady-state per-op socket deadline. Generous — rounds are
/// millisecond-scale even with stragglers — but finite, so a wedged peer
/// becomes a typed `TimedOut` instead of an unbounded block. Also bounds
/// the theoretical relay-vs-node write deadlock when both directions'
/// kernel buffers fill (see DESIGN.md §4e).
const IO_TIMEOUT: Duration = Duration::from_secs(60);

/// Dial retry backoff: start, cap.
const BACKOFF_START: Duration = Duration::from_millis(10);
const BACKOFF_CAP: Duration = Duration::from_millis(200);

/// Accept-poll interval while waiting for node processes to dial in.
const ACCEPT_POLL: Duration = Duration::from_millis(5);

/// One connected byte stream, TCP or Unix — the rest of the module is
/// written against this enum so both transports share every code path.
pub enum Stream {
    Tcp(TcpStream),
    Unix(UnixStream),
}

impl Stream {
    fn try_clone(&self) -> io::Result<Stream> {
        match self {
            Stream::Tcp(s) => s.try_clone().map(Stream::Tcp),
            Stream::Unix(s) => s.try_clone().map(Stream::Unix),
        }
    }

    fn set_read_timeout(&self, t: Option<Duration>) -> io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_read_timeout(t),
            Stream::Unix(s) => s.set_read_timeout(t),
        }
    }

    fn set_write_timeout(&self, t: Option<Duration>) -> io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_write_timeout(t),
            Stream::Unix(s) => s.set_write_timeout(t),
        }
    }

    fn set_nonblocking(&self, nb: bool) -> io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_nonblocking(nb),
            Stream::Unix(s) => s.set_nonblocking(nb),
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            Stream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            Stream::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            Stream::Unix(s) => s.flush(),
        }
    }
}

/// The leader's pre-bound listening socket (bound by the caller, so
/// tests can bind port 0 / a temp path and learn the address).
pub enum Listener {
    Tcp(TcpListener),
    Unix(UnixListener),
}

impl Listener {
    fn accept(&self) -> io::Result<Stream> {
        match self {
            Listener::Tcp(l) => l.accept().map(|(s, _)| Stream::Tcp(s)),
            Listener::Unix(l) => l.accept().map(|(s, _)| Stream::Unix(s)),
        }
    }

    fn set_nonblocking(&self, nb: bool) -> io::Result<()> {
        match self {
            Listener::Tcp(l) => l.set_nonblocking(nb),
            Listener::Unix(l) => l.set_nonblocking(nb),
        }
    }
}

/// Where a node process finds its leader.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DialAddr {
    /// `host:port`, e.g. `127.0.0.1:7911`.
    Tcp(String),
    /// Filesystem path of the leader's Unix-domain socket.
    Unix(std::path::PathBuf),
}

fn connect(addr: &DialAddr) -> io::Result<Stream> {
    match addr {
        DialAddr::Tcp(a) => TcpStream::connect(a.as_str()).map(Stream::Tcp),
        DialAddr::Unix(p) => UnixStream::connect(p).map(Stream::Unix),
    }
}

/// Dial the leader as node `node`, presenting `hello`. Retries refused /
/// not-yet-bound addresses with bounded exponential backoff until
/// `timeout` expires (so worker processes may start before the leader),
/// then runs the handshake: HELLO out, WELCOME or a typed REJECT back.
pub fn dial(
    addr: &DialAddr,
    node: u16,
    hello: &Hello,
    timeout: Duration,
) -> Result<SocketLink, TransportError> {
    #[allow(clippy::disallowed_methods)] // wall-clock dial deadline (see clippy.toml)
    let deadline = Instant::now() + timeout;
    let mut backoff = BACKOFF_START;
    let stream = loop {
        match connect(addr) {
            Ok(s) => break s,
            Err(e) => {
                // refused / path-not-bound-yet are the "leader not up yet"
                // cases worth retrying; anything else is terminal
                let retryable =
                    matches!(e.kind(), ErrorKind::ConnectionRefused | ErrorKind::NotFound);
                if !retryable {
                    return Err(map_io(&e));
                }
                #[allow(clippy::disallowed_methods)] // wall-clock dial deadline
                let now = Instant::now();
                if now + backoff >= deadline {
                    return Err(TransportError::Refused);
                }
                thread::sleep(backoff);
                backoff = (backoff * 2).min(BACKOFF_CAP);
            }
        }
    };
    if let Stream::Tcp(s) = &stream {
        let _ = s.set_nodelay(true);
    }
    // handshake under the remaining dial budget; steady state after
    #[allow(clippy::disallowed_methods)] // wall-clock dial deadline
    let remain = deadline.saturating_duration_since(Instant::now()).max(Duration::from_millis(10));
    stream.set_read_timeout(Some(remain)).map_err(|e| map_io(&e))?;
    stream.set_write_timeout(Some(remain)).map_err(|e| map_io(&e))?;

    let mut link = SocketLink::new(stream, hello.gated);
    encode_hello(&mut link.out, node, hello);
    write_frame(&mut link.stream, &link.out)?;
    read_frame_into(&mut link.stream, &mut link.scratch)?;
    let f = FrameRef::parse(&link.scratch).map_err(|_| TransportError::Protocol)?;
    match f.tag {
        WELCOME_TAG => {}
        REJECT_TAG => {
            let r = decode_reject(&f)?;
            return Err(TransportError::Rejected(r));
        }
        _ => return Err(TransportError::Protocol),
    }
    link.stream.set_read_timeout(Some(IO_TIMEOUT)).map_err(|e| map_io(&e))?;
    link.stream.set_write_timeout(Some(IO_TIMEOUT)).map_err(|e| map_io(&e))?;
    Ok(link)
}

/// A node's connection to the leader: one socket carrying both planes.
/// Data frames go out once — the leader fans them out per edge — and the
/// inbound stream interleaves relayed neighbor frames with VERDICT
/// control frames, which are de-multiplexed into per-plane queues here.
pub struct SocketLink {
    stream: Stream,
    /// Reused receive scratch ([`read_frame_into`]) — the zero-alloc
    /// receive path; the `Arc<[u8]>` handed to the caller is the same
    /// one-allocation-per-frame cost the in-process transport pays.
    scratch: Vec<u8>,
    /// Reused encode buffer for reports/faults.
    out: Vec<u8>,
    /// Neighbor frames that arrived while waiting for a verdict.
    frames: VecDeque<Arc<[u8]>>,
    /// Verdicts that arrived while waiting for a neighbor frame.
    verdicts: VecDeque<bool>,
    gated: bool,
}

impl SocketLink {
    fn new(stream: Stream, gated: bool) -> SocketLink {
        SocketLink {
            stream,
            scratch: Vec::new(),
            out: Vec::new(),
            frames: VecDeque::new(),
            verdicts: VecDeque::new(),
            gated,
        }
    }
}

impl NodeLink for SocketLink {
    fn broadcast(&mut self, frame: &Arc<[u8]>) -> Result<(), TransportError> {
        // one write — the leader relays a copy along each gossip edge
        write_frame(&mut self.stream, frame)
    }

    fn recv(&mut self) -> Result<Arc<[u8]>, TransportError> {
        if let Some(f) = self.frames.pop_front() {
            return Ok(f);
        }
        loop {
            read_frame_into(&mut self.stream, &mut self.scratch)?;
            if self.scratch.first() == Some(&VERDICT_TAG) {
                let f = FrameRef::parse(&self.scratch).map_err(|_| TransportError::Protocol)?;
                self.verdicts.push_back(decode_verdict(&f)?);
                continue;
            }
            // data / BYE / ABORT / corrupt bytes: hand over verbatim — the
            // caller's absorb does the judging, exactly like in-process
            return Ok(Arc::from(self.scratch.as_slice()));
        }
    }

    fn report(&mut self, ev: NodeEvent) -> Result<(), TransportError> {
        match ev {
            NodeEvent::Report(r) => encode_report(&mut self.out, &r),
            NodeEvent::Fault(f) => encode_fault(&mut self.out, &f),
        }
        write_frame(&mut self.stream, &self.out)
    }

    fn verdict(&mut self) -> Result<bool, TransportError> {
        if !self.gated {
            return Ok(true);
        }
        if let Some(v) = self.verdicts.pop_front() {
            return Ok(v);
        }
        loop {
            read_frame_into(&mut self.stream, &mut self.scratch)?;
            if self.scratch.first() == Some(&VERDICT_TAG) {
                let f = FrameRef::parse(&self.scratch).map_err(|_| TransportError::Protocol)?;
                return decode_verdict(&f);
            }
            self.frames.push_back(Arc::from(self.scratch.as_slice()));
        }
    }

    fn gated(&self) -> bool {
        self.gated
    }
}

/// Accept and handshake all `expect.n` node processes. Returns the
/// streams indexed by node id. A connection presenting a bad id, a
/// duplicate id, a foreign config fingerprint, or drifted run-shape
/// fields gets a typed REJECT and is dropped — its slot stays open for a
/// correct dialer until the deadline, after which the lowest missing id
/// is reported in [`TransportError::HandshakeTimeout`].
pub fn accept_nodes(
    listener: &Listener,
    expect: &Hello,
    timeout: Duration,
) -> Result<Vec<Stream>, TransportError> {
    listener.set_nonblocking(true).map_err(|e| map_io(&e))?;
    #[allow(clippy::disallowed_methods)] // wall-clock accept deadline (see clippy.toml)
    let deadline = Instant::now() + timeout;
    let n = expect.n as usize;
    let mut slots: Vec<Option<Stream>> = Vec::with_capacity(n);
    slots.resize_with(n, || None);
    let mut filled = 0usize;
    let mut scratch = Vec::new();
    let mut out = Vec::new();
    while filled < n {
        match listener.accept() {
            Ok(mut s) => {
                let _ = s.set_nonblocking(false);
                if let Stream::Tcp(t) = &s {
                    let _ = t.set_nodelay(true);
                }
                #[allow(clippy::disallowed_methods)] // wall-clock accept deadline
                let remain = deadline
                    .saturating_duration_since(Instant::now())
                    .max(Duration::from_millis(10));
                let _ = s.set_read_timeout(Some(remain));
                let _ = s.set_write_timeout(Some(remain));
                // a failed handshake drops the stream; the slot stays open
                if let Ok(id) = handshake(&mut s, expect, &slots, &mut scratch, &mut out) {
                    let _ = s.set_read_timeout(Some(IO_TIMEOUT));
                    let _ = s.set_write_timeout(Some(IO_TIMEOUT));
                    slots[id] = Some(s);
                    filled += 1;
                }
            }
            Err(ref e) if e.kind() == ErrorKind::WouldBlock => {
                #[allow(clippy::disallowed_methods)] // wall-clock accept deadline
                let now = Instant::now();
                if now >= deadline {
                    let missing = slots.iter().position(|s| s.is_none()).unwrap_or(0) as u16;
                    return Err(TransportError::HandshakeTimeout { missing });
                }
                thread::sleep(ACCEPT_POLL);
            }
            Err(ref e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(map_io(&e)),
        }
    }
    Ok(slots.into_iter().flatten().collect())
}

/// One connection's handshake: read HELLO, judge it, answer WELCOME or a
/// typed REJECT. Returns the validated node id.
fn handshake(
    s: &mut Stream,
    expect: &Hello,
    slots: &[Option<Stream>],
    scratch: &mut Vec<u8>,
    out: &mut Vec<u8>,
) -> Result<usize, TransportError> {
    read_frame_into(s, scratch)?;
    let f = FrameRef::parse(scratch).map_err(|_| TransportError::Protocol)?;
    let (id, h) = decode_hello(&f)?;
    let shape_ok = h.n == expect.n
        && h.dim == expect.dim
        && h.rounds == expect.rounds
        && h.record_every == expect.record_every
        && h.gated == expect.gated;
    let verdict = if (id as usize) >= slots.len() {
        Some(Reject::NodeIdRange)
    } else if slots[id as usize].is_some() {
        Some(Reject::DuplicateNode)
    } else if h.fingerprint != expect.fingerprint {
        Some(Reject::ConfigFingerprint)
    } else if !shape_ok {
        Some(Reject::SpecShape)
    } else {
        None
    };
    match verdict {
        Some(r) => {
            encode_reject(out, r);
            let _ = write_frame(s, out);
            Err(TransportError::Rejected(r))
        }
        None => {
            encode_welcome(out);
            write_frame(s, out)?;
            Ok(id as usize)
        }
    }
}

/// A node's mutex-serialized write half: shared by every uplink thread
/// that relays toward this node and by the leader's verdict fan-out.
pub type WriteHalf = Arc<Mutex<Stream>>;

/// Split each accepted stream into a read half (moved into that node's
/// uplink thread) and a [`WriteHalf`].
pub fn split(streams: Vec<Stream>) -> Result<(Vec<Stream>, Vec<WriteHalf>), TransportError> {
    let mut readers = Vec::with_capacity(streams.len());
    let mut writers = Vec::with_capacity(streams.len());
    for s in streams {
        let w = s.try_clone().map_err(|e| map_io(&e))?;
        readers.push(s);
        writers.push(Arc::new(Mutex::new(w)));
    }
    Ok((readers, writers))
}

fn locked(w: &WriteHalf) -> std::sync::MutexGuard<'_, Stream> {
    match w.lock() {
        Ok(g) => g,
        // a poisoned write half just means some relay thread panicked
        // mid-write; the stream is still the best teardown channel we have
        Err(p) => p.into_inner(),
    }
}

/// Relay one frame to each of `neighbors`' write halves. Write failures
/// are ignored — a dead neighbor's own uplink handles its teardown.
fn relay(frame: &[u8], neighbors: &[usize], writers: &[WriteHalf]) {
    for &j in neighbors {
        if let Some(w) = writers.get(j) {
            let _ = write_frame(&mut *locked(w), frame);
        }
    }
}

/// The leader's per-node uplink reader: routes REPORT/FAULT control
/// frames to the leader loop and relays everything else (data, BYE,
/// ABORT, tampered bytes — verbatim) along the node's gossip edges.
///
/// If the stream dies *without* the node having announced completion
/// (BYE), aborted (ABORT), or reported a fault, the death is the event:
/// a [`WireError::Transport`] fault is synthesized at the node's last
/// observed round and an ABORT wave is written to its neighbors, so the
/// survivors tear down through the ordinary protocol.
pub fn run_uplink(
    node: u16,
    mut reader: Stream,
    neighbors: &[usize],
    writers: &[WriteHalf],
    events: &mpsc::Sender<NodeEvent>,
) {
    let mut scratch = Vec::new();
    let mut last_seen: u32 = 0;
    let mut closing = false;
    loop {
        match read_frame_into(&mut reader, &mut scratch) {
            Ok(()) => match scratch.first() {
                Some(&REPORT_TAG) => {
                    if let Ok(f) = FrameRef::parse(&scratch) {
                        if let Ok(r) = decode_report(&f) {
                            let _ = events.send(NodeEvent::Report(r));
                        }
                    }
                }
                Some(&FAULT_TAG) => {
                    if let Ok(f) = FrameRef::parse(&scratch) {
                        if let Ok(w) = decode_fault(&f) {
                            let _ = events.send(NodeEvent::Fault(w));
                        }
                    }
                    closing = true;
                }
                _ => {
                    if let Ok(f) = FrameRef::parse(&scratch) {
                        if f.tag == BYE_TAG || f.tag == ABORT_TAG {
                            closing = true;
                        }
                        last_seen = last_seen.max(f.round);
                    }
                    relay(&scratch, neighbors, writers);
                }
            },
            Err(TransportError::Eof) if closing => return,
            Err(e) => {
                let fault = WireFault { node, round: last_seen, error: WireError::Transport(e) };
                let _ = events.send(NodeEvent::Fault(fault));
                let mut out = Vec::new();
                frame_begin(&mut out, ABORT_TAG, last_seen, node);
                frame_end(&mut out);
                relay(&out, neighbors, writers);
                return;
            }
        }
    }
}

/// Fan a checkpoint verdict out to every node's write half (errors
/// ignored: a node that died mid-checkpoint is its uplink's problem).
pub fn send_verdicts(writers: &[WriteHalf], go: bool, buf: &mut Vec<u8>) {
    encode_verdict(buf, go);
    for w in writers {
        let _ = write_frame(&mut *locked(w), buf);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hello(fp: u64) -> Hello {
        Hello { fingerprint: fp, n: 2, dim: 3, rounds: 10, record_every: 5, gated: false }
    }

    #[test]
    fn tcp_handshake_accepts_matching_nodes() {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = DialAddr::Tcp(l.local_addr().unwrap().to_string());
        let listener = Listener::Tcp(l);
        let h = hello(7);
        let dialers: Vec<_> = (0..2u16)
            .map(|i| {
                let addr = addr.clone();
                thread::spawn(move || dial(&addr, i, &hello(7), Duration::from_secs(5)))
            })
            .collect();
        let streams = accept_nodes(&listener, &h, Duration::from_secs(5)).unwrap();
        assert_eq!(streams.len(), 2);
        for d in dialers {
            assert!(d.join().unwrap().is_ok());
        }
    }

    #[test]
    fn mismatched_fingerprint_is_rejected_then_correct_dialer_fills_the_slot() {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = DialAddr::Tcp(l.local_addr().unwrap().to_string());
        let listener = Listener::Tcp(l);
        let h = hello(7);
        let bad = {
            let addr = addr.clone();
            thread::spawn(move || dial(&addr, 0, &hello(8), Duration::from_secs(5)))
        };
        let good: Vec<_> = (0..2u16)
            .map(|i| {
                let addr = addr.clone();
                thread::spawn(move || {
                    // give the bad dialer a head start at the listener
                    thread::sleep(Duration::from_millis(50));
                    dial(&addr, i, &hello(7), Duration::from_secs(5))
                })
            })
            .collect();
        let streams = accept_nodes(&listener, &h, Duration::from_secs(5)).unwrap();
        assert_eq!(streams.len(), 2);
        match bad.join().unwrap() {
            Err(TransportError::Rejected(Reject::ConfigFingerprint)) => {}
            other => panic!("expected fingerprint reject, got {:?}", other.err()),
        }
        for d in good {
            assert!(d.join().unwrap().is_ok());
        }
    }

    #[test]
    fn out_of_range_and_duplicate_ids_are_typed_rejects() {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = DialAddr::Tcp(l.local_addr().unwrap().to_string());
        let listener = Listener::Tcp(l);
        let h = hello(7);
        let acceptor = thread::spawn(move || accept_nodes(&listener, &h, Duration::from_secs(5)));
        let first = dial(&addr, 0, &hello(7), Duration::from_secs(5));
        assert!(first.is_ok());
        match dial(&addr, 9, &hello(7), Duration::from_secs(5)) {
            Err(TransportError::Rejected(Reject::NodeIdRange)) => {}
            other => panic!("expected NodeIdRange reject, got {:?}", other.err()),
        }
        match dial(&addr, 0, &hello(7), Duration::from_secs(5)) {
            Err(TransportError::Rejected(Reject::DuplicateNode)) => {}
            other => panic!("expected DuplicateNode reject, got {:?}", other.err()),
        }
        let second = dial(&addr, 1, &hello(7), Duration::from_secs(5));
        assert!(second.is_ok());
        assert_eq!(acceptor.join().unwrap().unwrap().len(), 2);
    }

    #[test]
    fn accept_deadline_reports_lowest_missing_node() {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = DialAddr::Tcp(l.local_addr().unwrap().to_string());
        let listener = Listener::Tcp(l);
        let h = hello(7);
        // only node 1 dials; node 0 never shows up
        let d = thread::spawn(move || dial(&addr, 1, &hello(7), Duration::from_secs(5)));
        let got = accept_nodes(&listener, &h, Duration::from_millis(400));
        assert_eq!(got.err(), Some(TransportError::HandshakeTimeout { missing: 0 }));
        let _ = d.join();
    }

    #[test]
    fn dial_gives_up_refused_past_the_deadline() {
        // bind-then-drop yields a port nothing listens on
        let port = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().port()
        };
        let addr = DialAddr::Tcp(format!("127.0.0.1:{port}"));
        let got = dial(&addr, 0, &hello(1), Duration::from_millis(300));
        assert_eq!(got.err(), Some(TransportError::Refused));
    }

    #[test]
    fn unix_socket_round_trips_a_relayed_frame() {
        let path = std::env::temp_dir()
            .join(format!("proxlead-test-relay-{}.sock", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let l = UnixListener::bind(&path).unwrap();
        let addr = DialAddr::Unix(path.clone());
        let listener = Listener::Unix(l);
        let h = hello(3);
        let worker: Vec<_> = (0..2u16)
            .map(|i| {
                let addr = addr.clone();
                thread::spawn(move || dial(&addr, i, &hello(3), Duration::from_secs(5)))
            })
            .collect();
        let streams = accept_nodes(&listener, &h, Duration::from_secs(5)).unwrap();
        let (mut readers, writers) = split(streams).unwrap();
        let mut links: Vec<SocketLink> =
            worker.into_iter().map(|w| w.join().unwrap().unwrap()).collect();

        // node 0 broadcasts one inner frame; leader relays it to node 1
        let mut inner = Vec::new();
        frame_begin(&mut inner, 0, 4, 0);
        inner.extend_from_slice(&[1, 2, 3, 4, 5, 6, 7, 8]);
        frame_end(&mut inner);
        let frame: Arc<[u8]> = Arc::from(inner.as_slice());
        links[0].broadcast(&frame).unwrap();

        let mut scratch = Vec::new();
        read_frame_into(&mut readers[0], &mut scratch).unwrap();
        relay(&scratch, &[1], &writers);
        let got = links[1].recv().unwrap();
        assert_eq!(&got[..], &frame[..]);
        let _ = std::fs::remove_file(&path);
    }
}
