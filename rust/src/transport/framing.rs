//! Length-delimited socket framing + the control-plane frame codecs.
//!
//! A socket message is `[u32 outer_len LE][outer_len bytes]`, where the
//! body is one *inner* frame in the exact [`crate::coordinator::wire`]
//! format (`[tag][round][from][payload_len][payload…]`). The outer prefix
//! is deliberately redundant for well-formed frames: the tamper matrix
//! ([`crate::coordinator::TamperKind`]) produces inner frames whose own
//! length field lies (truncated header, short payload, trailing garbage),
//! and without an independent delimiter one corrupt frame would desync
//! the byte stream forever. With it, corrupt frames transit the relay
//! intact and the *receiving node's* decode path detects them — the same
//! typed [`crate::coordinator::WireError`] as in-process transport.
//!
//! **Contract (lint-enforced):** [`read_frame_into`] and [`write_frame`]
//! are on the `zero-alloc` + `panic-freedom` scope lists — reads land in
//! a caller-owned scratch buffer (amortized like the PR-6 decode
//! scratch), and every malformed input or socket failure returns a typed
//! [`TransportError`], never a panic. The `decode_*` control codecs are
//! `panic-freedom`-scoped: total over arbitrary bytes.

use super::{
    map_io, Reject, TransportError, FAULT_TAG, HELLO_TAG, REJECT_TAG, REPORT_TAG, VERDICT_TAG,
    WELCOME_TAG,
};
use crate::coordinator::wire::{frame_begin, frame_end};
use crate::coordinator::{FrameRef, NodeReport, WireError, WireFault};
use std::io::{ErrorKind, Read, Write};

/// Outer-frame size cap: an adversarial or desynced length prefix must
/// not make the receiver allocate unbounded scratch.
pub const MAX_FRAME_LEN: u32 = 64 * 1024 * 1024;

/// Write one outer-framed message: length prefix, then the inner frame
/// bytes, then flush. Allocation-free; all failures are typed.
pub fn write_frame<W: Write>(w: &mut W, frame: &[u8]) -> Result<(), TransportError> {
    let len = frame.len() as u64;
    if len > MAX_FRAME_LEN as u64 {
        return Err(TransportError::Oversize { len: len.min(u32::MAX as u64) as u32 });
    }
    let hdr = (len as u32).to_le_bytes();
    w.write_all(&hdr).map_err(|e| map_io(&e))?;
    w.write_all(frame).map_err(|e| map_io(&e))?;
    w.flush().map_err(|e| map_io(&e))
}

/// Read one outer-framed message into `scratch` (resized to the exact
/// frame length; its warmed-up capacity is reused across frames, so the
/// steady state allocates nothing). Distinguishes a clean close at a
/// message boundary ([`TransportError::Eof`]) from a stream that died
/// mid-message ([`TransportError::ShortRead`]).
pub fn read_frame_into<R: Read>(r: &mut R, scratch: &mut Vec<u8>) -> Result<(), TransportError> {
    let mut hdr = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        let Some(rest) = hdr.get_mut(got..) else {
            return Err(TransportError::Protocol);
        };
        match r.read(rest) {
            Ok(0) => {
                return Err(if got == 0 {
                    TransportError::Eof
                } else {
                    TransportError::ShortRead { need: 4, got: got as u32 }
                });
            }
            Ok(k) => got += k,
            Err(ref e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(map_io(&e)),
        }
    }
    let len = u32::from_le_bytes(hdr);
    if len > MAX_FRAME_LEN {
        return Err(TransportError::Oversize { len });
    }
    scratch.resize(len as usize, 0);
    let mut off = 0usize;
    while off < len as usize {
        let Some(rest) = scratch.get_mut(off..) else {
            return Err(TransportError::Protocol);
        };
        match r.read(rest) {
            Ok(0) => return Err(TransportError::ShortRead { need: len, got: off as u32 }),
            Ok(k) => off += k,
            Err(ref e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(map_io(&e)),
        }
    }
    Ok(())
}

/// The handshake payload a dialing node presents: the config fingerprint
/// ([`super::fingerprint`] over the canonical config text) plus the
/// run-shape fields that live *outside* the config (CLI-resolved), so
/// flag drift between leader and worker invocations is caught before any
/// wire round starts. The node id rides in the inner header's `from`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Hello {
    pub fingerprint: u64,
    pub n: u32,
    pub dim: u32,
    pub rounds: u32,
    pub record_every: u32,
    pub gated: bool,
}

/// Total little-endian u64 read at `off`.
fn rd8(p: &[u8], off: usize) -> Option<u64> {
    let s = p.get(off..off.checked_add(8)?)?;
    let a = <[u8; 8]>::try_from(s).ok()?;
    Some(u64::from_le_bytes(a))
}

/// Total little-endian u32 read at `off`.
fn rd4(p: &[u8], off: usize) -> Option<u32> {
    let s = p.get(off..off.checked_add(4)?)?;
    let a = <[u8; 4]>::try_from(s).ok()?;
    Some(u32::from_le_bytes(a))
}

/// Build a HELLO frame for node `node` into `out` (reused buffer).
pub fn encode_hello(out: &mut Vec<u8>, node: u16, h: &Hello) {
    frame_begin(out, HELLO_TAG, 0, node);
    out.extend_from_slice(&h.fingerprint.to_le_bytes());
    out.extend_from_slice(&h.n.to_le_bytes());
    out.extend_from_slice(&h.dim.to_le_bytes());
    out.extend_from_slice(&h.rounds.to_le_bytes());
    out.extend_from_slice(&h.record_every.to_le_bytes());
    out.push(h.gated as u8);
    frame_end(out);
}

/// Total decode of a HELLO frame: `(node id, Hello)`.
pub fn decode_hello(f: &FrameRef<'_>) -> Result<(u16, Hello), TransportError> {
    if f.tag != HELLO_TAG || f.payload.len() != 25 {
        return Err(TransportError::Protocol);
    }
    let p = f.payload;
    let (Some(fingerprint), Some(n), Some(dim), Some(rounds), Some(record_every)) =
        (rd8(p, 0), rd4(p, 8), rd4(p, 12), rd4(p, 16), rd4(p, 20))
    else {
        return Err(TransportError::Protocol);
    };
    let gated = match p.get(24) {
        Some(0) => false,
        Some(1) => true,
        _ => return Err(TransportError::Protocol),
    };
    Ok((f.from, Hello { fingerprint, n, dim, rounds, record_every, gated }))
}

/// Build a WELCOME frame (empty payload) into `out`.
pub fn encode_welcome(out: &mut Vec<u8>) {
    frame_begin(out, WELCOME_TAG, 0, 0);
    frame_end(out);
}

/// Build a REJECT frame carrying the typed reason into `out`.
pub fn encode_reject(out: &mut Vec<u8>, r: Reject) {
    frame_begin(out, REJECT_TAG, 0, 0);
    out.push(r.code());
    frame_end(out);
}

/// Total decode of a REJECT frame.
pub fn decode_reject(f: &FrameRef<'_>) -> Result<Reject, TransportError> {
    if f.tag != REJECT_TAG {
        return Err(TransportError::Protocol);
    }
    match f.payload {
        &[c] => Reject::from_code(c).ok_or(TransportError::Protocol),
        _ => Err(TransportError::Protocol),
    }
}

/// Build a VERDICT frame (`true` = continue past the checkpoint).
pub fn encode_verdict(out: &mut Vec<u8>, go: bool) {
    frame_begin(out, VERDICT_TAG, 0, 0);
    out.push(go as u8);
    frame_end(out);
}

/// Total decode of a VERDICT frame.
pub fn decode_verdict(f: &FrameRef<'_>) -> Result<bool, TransportError> {
    if f.tag != VERDICT_TAG {
        return Err(TransportError::Protocol);
    }
    match f.payload {
        &[0] => Ok(false),
        &[1] => Ok(true),
        _ => Err(TransportError::Protocol),
    }
}

/// Build a REPORT frame from a node snapshot: counters, then the iterate
/// as little-endian f64s. Round and node id ride in the inner header.
pub fn encode_report(out: &mut Vec<u8>, r: &NodeReport) {
    frame_begin(out, REPORT_TAG, r.round as u32, r.node as u16);
    out.extend_from_slice(&r.bytes_sent.to_le_bytes());
    out.extend_from_slice(&r.payload_bits.to_le_bytes());
    out.extend_from_slice(&r.grad_evals.to_le_bytes());
    for v in &r.x {
        out.extend_from_slice(&v.to_le_bytes());
    }
    frame_end(out);
}

/// Total decode of a REPORT frame (the iterate length is implied by the
/// payload size; the leader checks it against the run's dimension).
pub fn decode_report(f: &FrameRef<'_>) -> Result<NodeReport, TransportError> {
    if f.tag != REPORT_TAG {
        return Err(TransportError::Protocol);
    }
    let p = f.payload;
    let (Some(bytes_sent), Some(payload_bits), Some(grad_evals)) =
        (rd8(p, 0), rd8(p, 8), rd8(p, 16))
    else {
        return Err(TransportError::Protocol);
    };
    let Some(body) = p.get(24..) else {
        return Err(TransportError::Protocol);
    };
    if body.len() % 8 != 0 {
        return Err(TransportError::Protocol);
    }
    let mut x = Vec::with_capacity(body.len() / 8);
    for c in body.chunks_exact(8) {
        let Ok(a) = <[u8; 8]>::try_from(c) else {
            return Err(TransportError::Protocol);
        };
        x.push(f64::from_le_bytes(a));
    }
    Ok(NodeReport {
        node: f.from as usize,
        round: f.round as usize,
        x,
        bytes_sent,
        payload_bits,
        grad_evals,
    })
}

/// Fixed 26-byte encoding of a [`WireError`]:
/// `[code u8][subcode u8][a u64][b u64][c u64]`.
fn wire_error_fields(e: WireError) -> (u8, u8, u64, u64, u64) {
    match e {
        WireError::TruncatedHeader { len } => (0, 0, len as u64, 0, 0),
        WireError::TruncatedPayload { need, got } => (1, 0, need as u64, got as u64, 0),
        WireError::TrailingBytes { expected, got } => (2, 0, expected as u64, got as u64, 0),
        WireError::UnknownTag { tag } => (3, 0, tag as u64, 0, 0),
        WireError::TagMismatch { expected, got } => (4, 0, expected as u64, got as u64, 0),
        WireError::PayloadSize { expected, got } => (5, 0, expected as u64, got as u64, 0),
        WireError::TruncatedBitstream { need_bits, got_bits } => {
            (6, 0, need_bits as u64, got_bits as u64, 0)
        }
        WireError::BadBlockNorm { block } => (7, 0, block as u64, 0, 0),
        WireError::NonNeighbor { from } => (8, 0, from as u64, 0, 0),
        WireError::DuplicateFrame { from, round } => (9, 0, from as u64, round as u64, 0),
        WireError::RoundSkew { from, frame_round, expect } => {
            (10, 0, from as u64, frame_round as u64, expect as u64)
        }
        WireError::Transport(t) => {
            let (sub, a, b) = match t {
                TransportError::Eof => (0, 0, 0),
                TransportError::ShortRead { need, got } => (1, need as u64, got as u64),
                TransportError::TimedOut => (2, 0, 0),
                TransportError::Refused => (3, 0, 0),
                TransportError::Oversize { len } => (4, len as u64, 0),
                TransportError::Rejected(r) => (5, r.code() as u64, 0),
                TransportError::Protocol => (6, 0, 0),
                TransportError::Closed => (7, 0, 0),
                TransportError::HandshakeTimeout { missing } => (8, missing as u64, 0),
            };
            (11, sub, a, b, 0)
        }
    }
}

/// Total inverse of [`wire_error_fields`].
fn wire_error_from_fields(code: u8, sub: u8, a: u64, b: u64, c: u64) -> Option<WireError> {
    Some(match code {
        0 => WireError::TruncatedHeader { len: a as usize },
        1 => WireError::TruncatedPayload { need: a as usize, got: b as usize },
        2 => WireError::TrailingBytes { expected: a as usize, got: b as usize },
        3 => WireError::UnknownTag { tag: a as u8 },
        4 => WireError::TagMismatch { expected: a as u8, got: b as u8 },
        5 => WireError::PayloadSize { expected: a as usize, got: b as usize },
        6 => WireError::TruncatedBitstream { need_bits: a as usize, got_bits: b as usize },
        7 => WireError::BadBlockNorm { block: a as usize },
        8 => WireError::NonNeighbor { from: a as u16 },
        9 => WireError::DuplicateFrame { from: a as u16, round: b as u32 },
        10 => WireError::RoundSkew { from: a as u16, frame_round: b as u32, expect: c as u32 },
        11 => WireError::Transport(match sub {
            0 => TransportError::Eof,
            1 => TransportError::ShortRead { need: a as u32, got: b as u32 },
            2 => TransportError::TimedOut,
            3 => TransportError::Refused,
            4 => TransportError::Oversize { len: a as u32 },
            5 => TransportError::Rejected(Reject::from_code(a as u8)?),
            6 => TransportError::Protocol,
            7 => TransportError::Closed,
            8 => TransportError::HandshakeTimeout { missing: a as u16 },
            _ => return None,
        }),
        _ => return None,
    })
}

/// Build a FAULT frame from a node-detected wire fault. The detecting
/// node and round ride in the inner header.
pub fn encode_fault(out: &mut Vec<u8>, f: &WireFault) {
    frame_begin(out, FAULT_TAG, f.round, f.node);
    let (code, sub, a, b, c) = wire_error_fields(f.error);
    out.push(code);
    out.push(sub);
    out.extend_from_slice(&a.to_le_bytes());
    out.extend_from_slice(&b.to_le_bytes());
    out.extend_from_slice(&c.to_le_bytes());
    frame_end(out);
}

/// Total decode of a FAULT frame.
pub fn decode_fault(f: &FrameRef<'_>) -> Result<WireFault, TransportError> {
    if f.tag != FAULT_TAG || f.payload.len() != 26 {
        return Err(TransportError::Protocol);
    }
    let p = f.payload;
    let (Some(&code), Some(&sub), Some(a), Some(b), Some(c)) =
        (p.first(), p.get(1), rd8(p, 2), rd8(p, 10), rd8(p, 18))
    else {
        return Err(TransportError::Protocol);
    };
    let error = wire_error_from_fields(code, sub, a, b, c).ok_or(TransportError::Protocol)?;
    Ok(WireFault { node: f.from, round: f.round, error })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn outer_framing_round_trips_and_reuses_scratch() {
        let mut wire = Vec::new();
        write_frame(&mut wire, &[1, 2, 3, 4, 5]).unwrap();
        write_frame(&mut wire, &[9]).unwrap();
        write_frame(&mut wire, &[]).unwrap();
        let mut r = Cursor::new(wire);
        let mut scratch = Vec::new();
        read_frame_into(&mut r, &mut scratch).unwrap();
        assert_eq!(scratch, vec![1, 2, 3, 4, 5]);
        read_frame_into(&mut r, &mut scratch).unwrap();
        assert_eq!(scratch, vec![9], "scratch must shrink to the frame length");
        read_frame_into(&mut r, &mut scratch).unwrap();
        assert!(scratch.is_empty());
        assert_eq!(read_frame_into(&mut r, &mut scratch), Err(TransportError::Eof));
    }

    #[test]
    fn short_reads_are_typed_not_eof() {
        // stream dies inside the length prefix
        let mut r = Cursor::new(vec![5u8, 0]);
        let mut scratch = Vec::new();
        assert_eq!(
            read_frame_into(&mut r, &mut scratch),
            Err(TransportError::ShortRead { need: 4, got: 2 })
        );
        // stream dies inside the body
        let mut wire = Vec::new();
        write_frame(&mut wire, &[7u8; 10]).unwrap();
        wire.truncate(4 + 6);
        let mut r = Cursor::new(wire);
        assert_eq!(
            read_frame_into(&mut r, &mut scratch),
            Err(TransportError::ShortRead { need: 10, got: 6 })
        );
    }

    #[test]
    fn oversize_length_prefix_is_rejected_before_allocating() {
        let mut wire = Vec::new();
        wire.extend_from_slice(&(MAX_FRAME_LEN + 1).to_le_bytes());
        let mut scratch = Vec::new();
        assert_eq!(
            read_frame_into(&mut Cursor::new(wire), &mut scratch),
            Err(TransportError::Oversize { len: MAX_FRAME_LEN + 1 })
        );
        assert!(scratch.is_empty(), "the lying prefix must not size the scratch");
    }

    #[test]
    fn hello_round_trips() {
        let h = Hello {
            fingerprint: 0xDEAD_BEEF_CAFE_F00D,
            n: 8,
            dim: 40,
            rounds: 300,
            record_every: 50,
            gated: true,
        };
        let mut buf = Vec::new();
        encode_hello(&mut buf, 5, &h);
        let f = FrameRef::parse(&buf).unwrap();
        assert_eq!(decode_hello(&f).unwrap(), (5, h));
        // truncated payload is a typed protocol error
        let mut short = buf.clone();
        short.pop();
        crate::coordinator::wire::frame_end(&mut short);
        let f = FrameRef::parse(&short).unwrap();
        assert_eq!(decode_hello(&f), Err(TransportError::Protocol));
    }

    #[test]
    fn reject_welcome_verdict_round_trip() {
        let mut buf = Vec::new();
        for r in [Reject::NodeIdRange, Reject::SpecShape] {
            encode_reject(&mut buf, r);
            let f = FrameRef::parse(&buf).unwrap();
            assert_eq!(decode_reject(&f).unwrap(), r);
        }
        for go in [true, false] {
            encode_verdict(&mut buf, go);
            let f = FrameRef::parse(&buf).unwrap();
            assert_eq!(decode_verdict(&f).unwrap(), go);
        }
        encode_welcome(&mut buf);
        let f = FrameRef::parse(&buf).unwrap();
        assert_eq!(f.tag, WELCOME_TAG);
        assert!(f.payload.is_empty());
        assert_eq!(decode_verdict(&f), Err(TransportError::Protocol), "wrong tag is typed");
    }

    #[test]
    fn report_round_trips_bit_exactly() {
        let r = NodeReport {
            node: 3,
            round: 120,
            x: vec![1.5, -2.25e-300, f64::MAX, 0.0],
            bytes_sent: 123_456,
            payload_bits: 789,
            grad_evals: 42,
        };
        let mut buf = Vec::new();
        encode_report(&mut buf, &r);
        let f = FrameRef::parse(&buf).unwrap();
        let d = decode_report(&f).unwrap();
        assert_eq!((d.node, d.round), (3, 120));
        assert_eq!((d.bytes_sent, d.payload_bits, d.grad_evals), (123_456, 789, 42));
        assert_eq!(d.x.len(), 4);
        for (a, b) in d.x.iter().zip(&r.x) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // a payload that is not a whole number of f64s is typed
        let mut odd = buf.clone();
        odd.push(0);
        crate::coordinator::wire::frame_end(&mut odd);
        let f = FrameRef::parse(&odd).unwrap();
        assert!(matches!(decode_report(&f), Err(TransportError::Protocol)));
    }

    #[test]
    fn fault_round_trips_every_error_arm() {
        let errors = [
            WireError::TruncatedHeader { len: 6 },
            WireError::TruncatedPayload { need: 100, got: 50 },
            WireError::TrailingBytes { expected: 10, got: 12 },
            WireError::UnknownTag { tag: 0x7E },
            WireError::TagMismatch { expected: 0, got: 1 },
            WireError::PayloadSize { expected: 64, got: 63 },
            WireError::TruncatedBitstream { need_bits: 12, got_bits: 8 },
            WireError::BadBlockNorm { block: 2 },
            WireError::NonNeighbor { from: 9 },
            WireError::DuplicateFrame { from: 1, round: 7 },
            WireError::RoundSkew { from: 2, frame_round: 9, expect: 4 },
            WireError::Transport(TransportError::Eof),
            WireError::Transport(TransportError::ShortRead { need: 11, got: 3 }),
            WireError::Transport(TransportError::TimedOut),
            WireError::Transport(TransportError::Refused),
            WireError::Transport(TransportError::Oversize { len: 1 << 30 }),
            WireError::Transport(TransportError::Rejected(Reject::ConfigFingerprint)),
            WireError::Transport(TransportError::Protocol),
            WireError::Transport(TransportError::Closed),
            WireError::Transport(TransportError::HandshakeTimeout { missing: 3 }),
        ];
        let mut buf = Vec::new();
        for e in errors {
            let fault = WireFault { node: 7, round: 31, error: e };
            encode_fault(&mut buf, &fault);
            let f = FrameRef::parse(&buf).unwrap();
            assert_eq!(decode_fault(&f).unwrap(), fault, "{e:?}");
        }
        // unknown code byte is typed, not a panic
        encode_fault(&mut buf, &WireFault { node: 0, round: 0, error: WireError::Transport(TransportError::Eof) });
        let hdr = crate::coordinator::Frame::HEADER_LEN;
        buf[hdr] = 0xEE;
        let f = FrameRef::parse(&buf).unwrap();
        assert_eq!(decode_fault(&f), Err(TransportError::Protocol));
    }
}
