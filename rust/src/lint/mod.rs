//! `proxlead-lint`: a source-level checker for the repo's standing contracts.
//!
//! The crate's correctness story is a handful of *source properties* —
//! panic-free wire decoding, zero-alloc hot loops, deterministic parity
//! modules, one pinned float-summation order, no resurrecting deprecated
//! entry points — that tests can only sample, never prove. This module
//! enforces them at the text level with a small lexical scanner (strings,
//! comments, and char literals stripped; `#[cfg(test)]` regions skipped;
//! function bodies tracked), driven by the declarative [`RULES`] table.
//! Zero dependencies by design: no `syn`, no `proc-macro2` — the offline
//! build environment has no registry, and a lexical pass is all these
//! rules need.
//!
//! Diagnostics print as `file:line: rule-id: message` (and as a JSON
//! report for CI via [`report_json`]). A finding can be suppressed only by
//! an inline justification comment on the same or the preceding line:
//!
//! ```text
//! lint:allow(rule-id): why this site is exempt
//! ```
//!
//! written as a `//` line comment. An allow with an unknown rule-id or an
//! empty justification is itself a diagnostic (`bad-allow`) and suppresses
//! nothing.
//!
//! Run with `cargo run --release --bin lint` (see `src/bin/lint.rs`); the
//! rule-by-rule contract map lives in DESIGN.md §6.

use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Marker introducing a suppression comment. Built from two halves so the
/// scanner never reads its own definition as an (unjustified) suppression.
const ALLOW_MARKER: &str = concat!("// lint:", "allow(");

/// Rule-id of the meta-diagnostic for malformed suppression comments.
pub const BAD_ALLOW: &str = "bad-allow";

/// One entry of the declarative rule table.
pub struct Rule {
    /// Stable diagnostic id (`panic-freedom`, `zero-alloc`, …).
    pub id: &'static str,
    /// One-line statement of the contract the rule enforces.
    pub summary: &'static str,
    /// Forbidden token spellings, matched on stripped source with ident
    /// boundaries respected on both ends.
    pub patterns: &'static [&'static str],
    /// Additionally flag bare `[...]` indexing / slicing expressions.
    pub bare_index: bool,
    /// Path scope, relative to `src/` with `/` separators. Entries ending
    /// in `/` are directory prefixes, others exact files. Empty = whole
    /// tree.
    pub files: &'static [&'static str],
    /// Path anti-scope (same syntax), applied after `files`.
    pub exclude: &'static [&'static str],
    /// When `Some`, only these function bodies (by exact name) are in
    /// scope; `None` scopes the whole file.
    pub fns: Option<&'static [&'static str]>,
}

/// The repo-contract rule table. Order is presentation order in reports.
pub const RULES: &[Rule] = &[
    Rule {
        id: "panic-freedom",
        summary: "wire-path code must be total: decode returns typed errors, never panics",
        patterns: &[
            ".unwrap()",
            ".expect(",
            "panic!(",
            "unreachable!(",
            "todo!(",
            "unimplemented!(",
            "assert!(",
            "assert_eq!(",
            "assert_ne!(",
        ],
        bare_index: true,
        files: &[
            "coordinator/wire.rs",
            "coordinator/node.rs",
            "compress/bits.rs",
            "transport/framing.rs",
        ],
        exclude: &[],
        fns: Some(&[
            // node.rs: the decode half (everything a hostile frame reaches)
            "absorb",
            // bits.rs: the reader side of the quantizer codec
            "try_read_bits",
            "try_read_f32",
            "byte_at",
            "decode_inf_quantized",
            "decode_inf_quantized_into",
            // wire.rs: whole-file intent, spelled per function so the rule
            // composes with the fn tracker (encode side included — frames
            // are built in the same hot loop that decodes)
            "encode_into",
            "decode_into",
            "frame_begin",
            "frame_end",
            "parse",
            "payload_len",
            "known_tag",
            // transport/framing.rs: everything bytes off a socket reach —
            // the outer length-delimited framing and the control-frame
            // decoders (a hostile peer drives all of these)
            "read_frame_into",
            "write_frame",
            "decode_hello",
            "decode_report",
            "decode_fault",
            "decode_verdict",
            "decode_reject",
        ]),
    },
    Rule {
        id: "zero-alloc",
        summary: "hot-path function allocates: warmed-up rounds must be allocation-free",
        patterns: &[
            "Vec::new(",
            "Vec::with_capacity(",
            "vec!",
            ".to_vec(",
            ".clone()",
            "Box::new(",
            "format!(",
            ".collect(",
            ".to_string(",
            "String::new(",
        ],
        bare_index: false,
        files: &[
            "linalg/matrix.rs",
            "linalg/sparse.rs",
            "compress/bits.rs",
            "coordinator/wire.rs",
            "coordinator/node.rs",
            "sim/mod.rs",
            "transport/framing.rs",
        ],
        exclude: &[],
        fns: Some(&[
            // linalg: the shared accumulation kernels
            "vaxpy",
            "vsum",
            "vdot",
            "vnorm_sq",
            "vdist_sq",
            "vinf_norm",
            "matmul_into",
            "axpy",
            "apply_into",
            // codec: the _into pairs the coordinator round loop drives
            "write_bits",
            "write_f32",
            "try_read_bits",
            "try_read_f32",
            "encode_inf_quantized_into",
            "decode_inf_quantized_into",
            "encode_into",
            "decode_into",
            "frame_begin",
            "frame_end",
            "parse",
            // node hot loop: mixing + gather
            "mix_into",
            "mix_rows_into",
            "mix_with",
            "acc",
            "absorb",
            // sim backend: the per-round phase bodies
            "phase_a",
            "phase_b",
            "parse_decode",
            "drain",
            // transport framing: the per-round socket read/write path
            // reuses one scratch buffer (resize, not reallocate) — the
            // PR-6 zero-alloc decode contract extended to the socket
            "read_frame_into",
            "write_frame",
        ]),
    },
    Rule {
        id: "determinism",
        summary: "parity-critical module reads iteration order or wall-clock state",
        patterns: &["HashMap", "HashSet", "Instant::now(", "SystemTime"],
        bare_index: false,
        files: &[
            "algorithm/",
            "compress/",
            "engine/",
            "exp/",
            "graph/",
            "linalg/",
            "oracle/",
            "problem/",
            "prox/",
            "coordinator/algorithms.rs",
            "coordinator/node.rs",
            "coordinator/wire.rs",
            "util/rng.rs",
        ],
        // timing allowlist: runner/sweep/bench layers and the leader loops
        // (coordinator/mod.rs, sim/mod.rs) are *not* listed above; they own
        // wall-clock reads and carry clippy::disallowed_methods allows.
        exclude: &[],
        fns: None,
    },
    Rule {
        id: "parity-order",
        summary: "float reduction outside the pinned kernels: route through vsum/vdot/vnorm_sq \
                  (linalg::matrix) so engine, coordinator, and sim sum in one order",
        patterns: &[".sum(", ".fold(", ".product(", ".rfold("],
        bare_index: false,
        files: &[
            "linalg/",
            "graph/mixing.rs",
            "coordinator/node.rs",
            "coordinator/algorithms.rs",
        ],
        exclude: &[],
        fns: None,
    },
    Rule {
        id: "deprecated-api",
        summary: "deprecated entry point: use AlgorithmBuilder / Experiment::run instead of the \
                  positional constructors and engine shims",
        patterns: &[
            "ProxLead::new(",
            "Dgd::new(",
            "Choco::new(",
            "Nids::new(",
            "PgExtra::new(",
            "P2d2::new(",
            "DualGd::new(",
            "Pdgm::new(",
            "Pdgm::plain(",
            "Pdgm::lessbit_b(",
            "engine::RunConfig",
            "engine::run(",
            "run_prox_lead(",
        ],
        bare_index: false,
        files: &[],
        exclude: &[
            // the shims live (and are pin-tested) here; everything else
            // must go through the builder/experiment layers
            "algorithm/",
            "engine/",
            "coordinator/mod.rs",
        ],
        fns: None,
    },
    Rule {
        id: "total-cmp",
        summary: "float comparison via partial_cmp can panic/misorder on NaN: use f64::total_cmp",
        patterns: &[".partial_cmp("],
        bare_index: false,
        files: &[],
        exclude: &[],
        fns: None,
    },
    Rule {
        id: "atomic-ordering",
        summary: "explicit atomic Ordering outside the runtime/sync shim layer: route the \
                  access through crate::runtime::sync so proxlead-check can schedule it, and \
                  justify the memory-order choice in a suppression",
        patterns: &["Ordering::Relaxed", "Ordering::SeqCst"],
        bare_index: false,
        files: &[],
        // the shim layer itself converts Ordering into checker acquire/
        // release flags — it is the one place the tokens may appear bare
        exclude: &["runtime/sync.rs"],
        fns: None,
    },
];

/// All known rule ids, including the synthetic [`BAD_ALLOW`].
pub fn rule_ids() -> Vec<&'static str> {
    let mut ids: Vec<&'static str> = RULES.iter().map(|r| r.id).collect();
    ids.push(BAD_ALLOW);
    ids
}

impl Rule {
    /// Path scope test for a `src/`-relative, `/`-separated path.
    pub fn applies_to(&self, rel: &str) -> bool {
        let hit = |list: &[&str]| {
            list.iter().any(|e| {
                if let Some(dir) = e.strip_suffix('/') {
                    rel.starts_with(dir) && rel[dir.len()..].starts_with('/')
                } else {
                    rel == *e
                }
            })
        };
        (self.files.is_empty() || hit(self.files)) && !hit(self.exclude)
    }
}

/// One finding, printable as `file:line: rule-id: message`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Diagnostic {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: {}: {}", self.file, self.line, self.rule, self.message)
    }
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// A parsed suppression comment.
struct Allow {
    line: usize,
    id: String,
}

/// Lexed view of one source file: comments/strings/chars blanked out,
/// `#[cfg(test)]` and function-body spans resolved, suppressions parsed.
struct Lexed {
    /// Source with every comment, string, and char literal replaced by
    /// spaces — byte-for-byte the same length as the input.
    stripped: Vec<u8>,
    /// Byte offset of the start of each line (line numbers are 1-based).
    line_starts: Vec<usize>,
    /// Byte spans covered by `#[cfg(test)]` items.
    test_spans: Vec<(usize, usize)>,
    /// Function-body spans `(start, end, name)`, innermost = latest start.
    fn_spans: Vec<(usize, usize, String)>,
    /// Valid suppressions (each covers its own line and the next).
    allows: Vec<Allow>,
    /// Malformed suppressions, pre-packaged as diagnostics (file unset).
    bad_allows: Vec<(usize, String)>,
}

impl Lexed {
    fn new(src: &str) -> Lexed {
        let bytes = src.as_bytes();
        let stripped = strip(bytes);
        let mut line_starts = vec![0usize];
        for (i, &b) in bytes.iter().enumerate() {
            if b == b'\n' {
                line_starts.push(i + 1);
            }
        }
        let (test_spans, fn_spans) = structure(&stripped);
        let mut lx = Lexed {
            stripped,
            line_starts,
            test_spans,
            fn_spans,
            allows: Vec::new(),
            bad_allows: Vec::new(),
        };
        lx.parse_allows(src);
        lx
    }

    fn line_of(&self, pos: usize) -> usize {
        match self.line_starts.binary_search(&pos) {
            Ok(i) => i + 1,
            Err(i) => i,
        }
    }

    fn in_test(&self, pos: usize) -> bool {
        self.test_spans.iter().any(|&(s, e)| s <= pos && pos < e)
    }

    fn fn_at(&self, pos: usize) -> Option<&str> {
        self.fn_spans
            .iter()
            .filter(|&&(s, e, _)| s <= pos && pos < e)
            .max_by_key(|&&(s, _, _)| s)
            .map(|(_, _, name)| name.as_str())
    }

    fn allowed(&self, rule: &str, line: usize) -> bool {
        self.allows.iter().any(|a| a.id == rule && (a.line == line || a.line + 1 == line))
    }

    /// Scan ORIGINAL lines for suppression comments (they live in comments,
    /// which the stripped view blanks out).
    fn parse_allows(&mut self, src: &str) {
        for (i, text) in src.lines().enumerate() {
            let line = i + 1;
            let Some(at) = text.find(ALLOW_MARKER) else { continue };
            let rest = &text[at + ALLOW_MARKER.len()..];
            let parsed = rest.split_once(')').and_then(|(id, tail)| {
                let just = tail.strip_prefix(':')?.trim();
                Some((id.trim().to_string(), !just.is_empty()))
            });
            match parsed {
                Some((id, true)) if rule_ids().contains(&id.as_str()) => {
                    self.allows.push(Allow { line, id });
                }
                Some((id, justified)) => {
                    let why = if !rule_ids().contains(&id.as_str()) {
                        format!("unknown rule-id `{id}` in suppression")
                    } else if !justified {
                        format!("suppression of `{id}` lacks a justification text")
                    } else {
                        "malformed suppression".to_string()
                    };
                    self.bad_allows.push((line, why));
                }
                None => {
                    self.bad_allows.push((
                        line,
                        "malformed suppression: expected `(rule-id): justification`".to_string(),
                    ));
                }
            }
        }
    }
}

/// Blank out comments (line + nested block), string literals (plain, byte,
/// raw), and char literals. Lifetimes (`'a`) are left intact. Output has
/// the same length as the input; newlines survive so line numbers hold.
fn strip(bytes: &[u8]) -> Vec<u8> {
    let mut out = bytes.to_vec();
    let n = bytes.len();
    let mut i = 0;
    let blank = |out: &mut [u8], from: usize, to: usize| {
        for b in &mut out[from..to.min(n)] {
            if *b != b'\n' {
                *b = b' ';
            }
        }
    };
    while i < n {
        let b = bytes[i];
        // line comment
        if b == b'/' && bytes.get(i + 1) == Some(&b'/') {
            let end = bytes[i..].iter().position(|&c| c == b'\n').map_or(n, |p| i + p);
            blank(&mut out, i, end);
            i = end;
            continue;
        }
        // block comment (nested)
        if b == b'/' && bytes.get(i + 1) == Some(&b'*') {
            let mut depth = 1;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if bytes[j] == b'/' && bytes.get(j + 1) == Some(&b'*') {
                    depth += 1;
                    j += 2;
                } else if bytes[j] == b'*' && bytes.get(j + 1) == Some(&b'/') {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            blank(&mut out, i, j);
            i = j;
            continue;
        }
        // raw string (optionally byte): r"..." / r#"..."# / br#"..."#
        if (b == b'r' || b == b'b') && (i == 0 || !is_ident(bytes[i - 1])) {
            let mut j = i;
            if bytes[j] == b'b' && bytes.get(j + 1) == Some(&b'r') {
                j += 1;
            }
            if bytes[j] == b'r' {
                let mut hashes = 0;
                let mut k = j + 1;
                while bytes.get(k) == Some(&b'#') {
                    hashes += 1;
                    k += 1;
                }
                if bytes.get(k) == Some(&b'"') {
                    // scan for closing quote + matching hashes
                    let mut e = k + 1;
                    'raw: while e < n {
                        if bytes[e] == b'"' {
                            let mut h = 0;
                            while h < hashes && bytes.get(e + 1 + h) == Some(&b'#') {
                                h += 1;
                            }
                            if h == hashes {
                                e += 1 + hashes;
                                break 'raw;
                            }
                        }
                        e += 1;
                    }
                    blank(&mut out, i, e);
                    i = e;
                    continue;
                }
            }
        }
        // plain / byte string
        if b == b'"' {
            let mut j = i + 1;
            while j < n {
                match bytes[j] {
                    b'\\' => j += 2,
                    b'"' => {
                        j += 1;
                        break;
                    }
                    _ => j += 1,
                }
            }
            blank(&mut out, i, j);
            i = j;
            continue;
        }
        // char literal vs lifetime: a closing quote within a short window
        // (escape-aware) means char literal; otherwise leave it (lifetime)
        if b == b'\'' {
            let mut j = i + 1;
            let window = (i + 8).min(n);
            let mut closed = None;
            while j < window {
                match bytes[j] {
                    b'\\' => j += 2,
                    b'\'' if j > i + 1 => {
                        closed = Some(j + 1);
                        break;
                    }
                    _ => j += 1,
                }
            }
            if let Some(end) = closed {
                blank(&mut out, i, end);
                i = end;
                continue;
            }
        }
        i += 1;
    }
    out
}

/// One structural walk over stripped bytes: `#[cfg(test)]` item spans and
/// function-body spans (by header name).
fn structure(stripped: &[u8]) -> (Vec<(usize, usize)>, Vec<(usize, usize, String)>) {
    const CFG_TEST: &[u8] = b"#[cfg(test)]";
    let n = stripped.len();
    let mut test_spans = Vec::new();
    let mut fn_spans = Vec::new();
    let mut fn_stack: Vec<(usize, usize, String)> = Vec::new(); // (start, open_depth, name)
    let mut pending_fn: Option<String> = None;
    let mut pending_test: Option<(usize, usize)> = None; // (attr_pos, attr_depth)
    let mut open_tests: Vec<(usize, usize)> = Vec::new(); // (start, open_depth)
    let mut depth = 0usize;
    let mut i = 0;
    while i < n {
        let b = stripped[i];
        if b == b'#' && stripped[i..].starts_with(CFG_TEST) {
            pending_test = Some((i, depth));
            i += CFG_TEST.len();
            continue;
        }
        if is_ident(b) {
            let start = i;
            while i < n && is_ident(stripped[i]) {
                i += 1;
            }
            let word = &stripped[start..i];
            if word == b"fn" {
                // capture the following identifier as the function name
                let mut j = i;
                while j < n && (stripped[j] as char).is_whitespace() {
                    j += 1;
                }
                let name_start = j;
                while j < n && is_ident(stripped[j]) {
                    j += 1;
                }
                if j > name_start {
                    pending_fn =
                        Some(String::from_utf8_lossy(&stripped[name_start..j]).into_owned());
                    i = j;
                }
            }
            continue;
        }
        match b {
            b'{' => {
                if let Some(name) = pending_fn.take() {
                    fn_stack.push((i, depth, name));
                }
                if let Some((attr_pos, _)) = pending_test.take() {
                    open_tests.push((attr_pos, depth));
                }
                depth += 1;
            }
            b'}' => {
                depth = depth.saturating_sub(1);
                if fn_stack.last().is_some_and(|&(_, d, _)| d == depth) {
                    let (start, _, name) = fn_stack.pop().unwrap_or_default();
                    fn_spans.push((start, i + 1, name));
                }
                if open_tests.last().is_some_and(|&(_, d)| d == depth) {
                    let (start, _) = open_tests.pop().unwrap_or_default();
                    test_spans.push((start, i + 1));
                }
            }
            b';' => {
                // `fn f(...);` (trait method) or `#[cfg(test)] use x;`
                pending_fn = None;
                if let Some((attr_pos, d)) = pending_test {
                    if d == depth {
                        test_spans.push((attr_pos, i + 1));
                        pending_test = None;
                    }
                }
            }
            _ => {}
        }
        i += 1;
    }
    // unterminated spans (truncated input): close at EOF
    for (start, _, name) in fn_stack {
        fn_spans.push((start, n, name));
    }
    for (start, _) in open_tests {
        test_spans.push((start, n));
    }
    if let Some((start, _)) = pending_test {
        test_spans.push((start, n));
    }
    (test_spans, fn_spans)
}

/// Occurrences of `pat` in `hay` with ident boundaries respected on both
/// ends (so `assert!(` never matches inside `debug_assert!(`).
fn find_guarded(hay: &[u8], pat: &str, out: &mut Vec<usize>) {
    let p = pat.as_bytes();
    let guard_front = is_ident(p[0]);
    let guard_back = is_ident(p[p.len() - 1]);
    let mut from = 0;
    while from + p.len() <= hay.len() {
        let Some(off) = hay[from..].windows(p.len()).position(|w| w == p) else { break };
        let at = from + off;
        let front_ok = !guard_front || at == 0 || !is_ident(hay[at - 1]);
        let back_ok = !guard_back
            || at + p.len() >= hay.len()
            || !is_ident(hay[at + p.len()]);
        if front_ok && back_ok {
            out.push(at);
        }
        from = at + 1;
    }
}

/// Positions of bare `[...]` indexing: a `[` directly preceded by an
/// identifier character, `)`, or `]`. Attribute (`#[`), slice-type (`&[`),
/// macro (`vec![`), and pattern positions all fail the predecessor test.
fn find_bare_index(hay: &[u8], out: &mut Vec<usize>) {
    for i in 1..hay.len() {
        if hay[i] == b'[' && (is_ident(hay[i - 1]) || hay[i - 1] == b')' || hay[i - 1] == b']') {
            out.push(i);
        }
    }
}

/// Lint one file's source. `rel` is the `src/`-relative path with `/`
/// separators (used for scoping and in diagnostics).
pub fn lint_source(rel: &str, src: &str) -> Vec<Diagnostic> {
    let lx = Lexed::new(src);
    let mut diags: Vec<Diagnostic> = Vec::new();
    for (line, why) in &lx.bad_allows {
        let pos = lx.line_starts.get(line - 1).copied().unwrap_or(0);
        if !lx.in_test(pos) {
            diags.push(Diagnostic {
                file: rel.to_string(),
                line: *line,
                rule: BAD_ALLOW,
                message: why.clone(),
            });
        }
    }
    let mut hits: Vec<usize> = Vec::new();
    for rule in RULES {
        if !rule.applies_to(rel) {
            continue;
        }
        let mut found: Vec<(usize, String)> = Vec::new();
        for pat in rule.patterns {
            hits.clear();
            find_guarded(&lx.stripped, pat, &mut hits);
            for &pos in &hits {
                found.push((pos, format!("{} (forbidden: `{}`)", rule.summary, pat)));
            }
        }
        if rule.bare_index {
            hits.clear();
            find_bare_index(&lx.stripped, &mut hits);
            for &pos in &hits {
                found.push((pos, format!("{} (forbidden: bare `[...]` indexing)", rule.summary)));
            }
        }
        for (pos, message) in found {
            if lx.in_test(pos) {
                continue;
            }
            if let Some(fns) = rule.fns {
                match lx.fn_at(pos) {
                    Some(name) if fns.contains(&name) => {}
                    _ => continue,
                }
            }
            let line = lx.line_of(pos);
            if lx.allowed(rule.id, line) {
                continue;
            }
            if diags.iter().any(|d| d.rule == rule.id && d.line == line) {
                continue; // one report per rule per line
            }
            diags.push(Diagnostic { file: rel.to_string(), line, rule: rule.id, message });
        }
    }
    diags.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    diags
}

/// Recursively collect `.rs` files under `root`, as sorted relative paths.
fn collect_rs(root: &Path) -> io::Result<Vec<PathBuf>> {
    fn walk(dir: &Path, acc: &mut Vec<PathBuf>) -> io::Result<()> {
        let mut entries: Vec<PathBuf> = fs::read_dir(dir)?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .collect();
        entries.sort();
        for p in entries {
            if p.is_dir() {
                walk(&p, acc)?;
            } else if p.extension().is_some_and(|x| x == "rs") {
                acc.push(p);
            }
        }
        Ok(())
    }
    let mut acc = Vec::new();
    walk(root, &mut acc)?;
    Ok(acc)
}

/// Lint every `.rs` file under `root` (normally `rust/src`). Returns the
/// number of files scanned and all diagnostics, sorted by path.
pub fn lint_tree(root: &Path) -> io::Result<(usize, Vec<Diagnostic>)> {
    let files = collect_rs(root)?;
    let mut diags = Vec::new();
    for path in &files {
        let rel: String = path
            .strip_prefix(root)
            .unwrap_or(path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let src = fs::read_to_string(path)?;
        diags.extend(lint_source(&rel, &src));
    }
    Ok((files.len(), diags))
}

/// CI-facing JSON report.
pub fn report_json(files_scanned: usize, diags: &[Diagnostic]) -> Json {
    Json::obj(vec![
        ("schema", "proxlead-lint-v1".into()),
        ("files_scanned", files_scanned.into()),
        ("clean", diags.is_empty().into()),
        (
            "diagnostics",
            Json::Arr(
                diags
                    .iter()
                    .map(|d| {
                        Json::obj(vec![
                            ("file", d.file.as_str().into()),
                            ("line", d.line.into()),
                            ("rule", d.rule.into()),
                            ("message", d.message.as_str().into()),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ids(diags: &[Diagnostic]) -> Vec<&'static str> {
        diags.iter().map(|d| d.rule).collect()
    }

    #[test]
    fn stripping_blanks_comments_strings_chars() {
        let src = "let a = \"x.unwrap()\"; // .unwrap()\nlet c = '\\''; /* .unwrap() */\n";
        let s = strip(src.as_bytes());
        let text = String::from_utf8_lossy(&s);
        assert!(!text.contains(".unwrap()"), "stripped: {text}");
        assert_eq!(s.len(), src.len(), "stripping must preserve length");
        assert_eq!(text.matches('\n').count(), 2, "newlines must survive");
    }

    #[test]
    fn stripping_handles_raw_strings_and_lifetimes() {
        let src = "fn f<'a>(x: &'a str) { let r = r#\"panic!(\"#; }";
        let text = String::from_utf8_lossy(&strip(src.as_bytes())).into_owned();
        assert!(!text.contains("panic!("), "raw string not stripped: {text}");
        assert!(text.contains("<'a>"), "lifetime must survive: {text}");
    }

    #[test]
    fn guarded_match_respects_ident_boundaries() {
        let mut out = Vec::new();
        find_guarded(b"debug_assert!(x); assert!(y);", "assert!(", &mut out);
        assert_eq!(out.len(), 1, "debug_assert must not match");
        out.clear();
        find_guarded(b"let m: HashMapLike = x; let h: HashMap<u8, u8>;", "HashMap", &mut out);
        assert_eq!(out.len(), 1, "HashMapLike must not match");
    }

    #[test]
    fn bare_index_detector_skips_non_index_brackets() {
        let mut out = Vec::new();
        find_bare_index(b"#[cfg(test)] let a: &[u8] = x; vec![0; n]; b[i]; f()[0];", &mut out);
        assert_eq!(out.len(), 2, "expected b[i] and f()[0] only, got {out:?}");
    }

    #[test]
    fn cfg_test_region_is_skipped() {
        let src = "fn absorb() { let x = 1; }\n#[cfg(test)]\nmod tests {\n    fn absorb() { \
                   x.unwrap(); }\n}\n";
        let diags = lint_source("coordinator/node.rs", src);
        assert!(diags.is_empty(), "test region must be exempt: {diags:?}");
    }

    #[test]
    fn fn_scope_limits_rule_to_listed_bodies() {
        let src = "fn absorb() { x.unwrap(); }\nfn helper() { y.unwrap(); }\n";
        let diags = lint_source("coordinator/node.rs", src);
        assert_eq!(ids(&diags), vec!["panic-freedom"]);
        assert_eq!(diags.first().map(|d| d.line), Some(1), "only absorb is scoped");
    }

    #[test]
    fn justified_allow_suppresses_next_line() {
        let allow = format!("{}parity-order): kernel definition", super::ALLOW_MARKER);
        let src = format!("fn vsum(a: &[f64]) -> f64 {{\n    {allow}: pinned\n    \
                           a.iter().sum()\n}}\n");
        let diags = lint_source("linalg/matrix.rs", &src);
        assert!(diags.is_empty(), "justified allow must suppress: {diags:?}");
    }

    #[test]
    fn unjustified_allow_is_rejected_and_suppresses_nothing() {
        let allow = format!("{}parity-order):", super::ALLOW_MARKER);
        let src = format!("fn f(a: &[f64]) -> f64 {{\n    {allow}\n    a.iter().sum()\n}}\n");
        let diags = lint_source("linalg/matrix.rs", &src);
        let got = ids(&diags);
        assert!(got.contains(&BAD_ALLOW), "missing bad-allow: {diags:?}");
        assert!(got.contains(&"parity-order"), "must not suppress: {diags:?}");
    }

    #[test]
    fn unknown_rule_id_in_allow_is_rejected() {
        let allow = format!("{}no-such-rule): because reasons", super::ALLOW_MARKER);
        let src = format!("fn f() {{\n    {allow}\n    let x = 1;\n}}\n");
        let diags = lint_source("linalg/matrix.rs", &src);
        assert_eq!(ids(&diags), vec![BAD_ALLOW]);
    }

    #[test]
    fn diagnostics_carry_file_line_and_display_format() {
        let src = "fn parse() {\n    let x = buf[0];\n}\n";
        let diags = lint_source("coordinator/wire.rs", src);
        assert_eq!(diags.len(), 1, "{diags:?}");
        let d = &diags[0];
        assert_eq!((d.file.as_str(), d.line, d.rule), ("coordinator/wire.rs", 2, "panic-freedom"));
        let shown = d.to_string();
        assert!(shown.starts_with("coordinator/wire.rs:2: panic-freedom: "), "{shown}");
    }

    #[test]
    fn path_scoping_matches_dirs_and_files() {
        let r = &RULES[2]; // determinism
        assert!(r.applies_to("linalg/matrix.rs"));
        assert!(r.applies_to("coordinator/wire.rs"));
        assert!(!r.applies_to("runner/mod.rs"), "runner is on the timing allowlist");
        assert!(!r.applies_to("util/bench.rs"), "bench is on the timing allowlist");
    }

    #[test]
    fn deprecated_rule_exempts_definition_sites() {
        let src = "fn f() { let a = ProxLead::new(1); }\n";
        assert_eq!(ids(&lint_source("exp/mod.rs", src)), vec!["deprecated-api"]);
        assert!(lint_source("algorithm/prox_lead.rs", src).is_empty());
    }
}
