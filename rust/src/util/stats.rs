//! Small statistics helpers shared by the bench harness and metrics code.

/// Arithmetic mean. Returns 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n − 1 denominator). 0.0 for n < 2.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    var.sqrt()
}

/// Percentile with linear interpolation, q in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let pos = (q / 100.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Least-squares slope of log10(y) against x — used to estimate linear
/// convergence rates from suboptimality curves (slope < 0 ⇒ linear rate
/// 10^slope per iteration). Ignores non-finite / non-positive y.
pub fn loglinear_slope(ys: &[f64]) -> f64 {
    let pts: Vec<(f64, f64)> = ys
        .iter()
        .enumerate()
        .filter(|(_, &y)| y.is_finite() && y > 0.0)
        .map(|(i, &y)| (i as f64, y.log10()))
        .collect();
    if pts.len() < 2 {
        return 0.0;
    }
    let n = pts.len() as f64;
    let sx: f64 = pts.iter().map(|p| p.0).sum();
    let sy: f64 = pts.iter().map(|p| p.1).sum();
    let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
    let denom = n * sxx - sx * sx;
    if denom.abs() < 1e-300 {
        0.0
    } else {
        (n * sxy - sx * sy) / denom
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((stddev(&xs) - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn empty_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs = [3.0, 1.0, 2.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn slope_of_geometric_decay() {
        // y_k = 10^{-k} has log-slope exactly -1
        let ys: Vec<f64> = (0..20).map(|k| 10f64.powi(-k)).collect();
        assert!((loglinear_slope(&ys) + 1.0).abs() < 1e-9);
    }

    #[test]
    fn slope_ignores_zeros() {
        let ys = [1.0, 0.1, 0.0, 0.01, f64::NAN, 0.001];
        let s = loglinear_slope(&ys);
        assert!(s < -0.3);
    }
}
