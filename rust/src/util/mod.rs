//! Infrastructure substrates built in-repo (the offline environment has no
//! rand / serde_json / criterion / proptest): PRNG, JSON, stats, bench
//! harness, and a mini property-testing framework.

pub mod bench;
pub mod json;
pub mod qc;
pub mod rng;
pub mod stats;
