//! Mini property-testing framework (proptest is unavailable offline).
//!
//! A property is a closure over a [`Gen`] draw; [`check`] runs it for many
//! seeded cases and, on failure, retries with progressively "smaller" draws
//! (smaller sizes, magnitudes) to report a simple shrunken counterexample.

use super::rng::Rng;

/// Draw source handed to properties. Wraps the PRNG and a "size" budget that
/// shrinks on failure so counterexamples are reported at small sizes.
pub struct Gen {
    pub rng: Rng,
    pub size: usize,
}

impl Gen {
    /// usize in [lo, hi], scaled down by the current shrink size.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        let hi_eff = lo + ((hi - lo) * self.size) / 100;
        lo + self.rng.below(hi_eff - lo + 1)
    }

    /// f64 in [lo, hi].
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range(lo, hi)
    }

    /// A vector of f64s with entries in [-mag, mag], magnitude shrinking.
    pub fn vec_f64(&mut self, len: usize, mag: f64) -> Vec<f64> {
        let m = mag * self.size as f64 / 100.0;
        (0..len).map(|_| self.rng.range(-m, m)).collect()
    }

    /// A vector of standard normals.
    pub fn vec_normal(&mut self, len: usize) -> Vec<f64> {
        (0..len).map(|_| self.rng.normal()).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bernoulli(0.5)
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }
}

/// Outcome of a property check.
#[derive(Debug)]
pub enum QcResult {
    Pass { cases: usize },
    Fail { seed: u64, size: usize, msg: String },
}

/// Run `prop` on `cases` seeded draws. `prop` returns Err(msg) to fail.
/// On failure, re-run the failing seed at smaller sizes to shrink.
pub fn check(
    name: &str,
    cases: usize,
    mut prop: impl FnMut(&mut Gen) -> Result<(), String>,
) -> QcResult {
    for case in 0..cases {
        let seed = 0xDEC0DE + case as u64;
        let mut g = Gen {
            rng: Rng::new(seed),
            size: 100,
        };
        if let Err(first_msg) = prop(&mut g) {
            // shrink: try the same seed at smaller size budgets
            let mut best = (100usize, first_msg);
            for size in [50, 25, 10, 5, 2, 1] {
                let mut g = Gen {
                    rng: Rng::new(seed),
                    size,
                };
                if let Err(msg) = prop(&mut g) {
                    best = (size, msg);
                }
            }
            return QcResult::Fail {
                seed,
                size: best.0,
                msg: format!("property '{name}' failed (seed {seed}, size {}): {}", best.0, best.1),
            };
        }
    }
    QcResult::Pass { cases }
}

/// Panic-on-fail wrapper for use inside #[test] functions.
pub fn assert_prop(name: &str, cases: usize, prop: impl FnMut(&mut Gen) -> Result<(), String>) {
    if let QcResult::Fail { msg, .. } = check(name, cases, prop) {
        panic!("{msg}");
    }
}

/// Helper: assert two f64 slices are elementwise close.
pub fn close_slices(a: &[f64], b: &[f64], tol: f64) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let scale = 1.0f64.max(x.abs()).max(y.abs());
        if (x - y).abs() > tol * scale {
            return Err(format!("index {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        let r = check("add-commutes", 50, |g| {
            let a = g.f64_in(-10.0, 10.0);
            let b = g.f64_in(-10.0, 10.0);
            if (a + b - (b + a)).abs() < 1e-15 {
                Ok(())
            } else {
                Err("not commutative".into())
            }
        });
        assert!(matches!(r, QcResult::Pass { cases: 50 }));
    }

    #[test]
    fn failing_property_shrinks() {
        let r = check("always-small", 50, |g| {
            let v = g.vec_f64(4, 100.0);
            if v.iter().all(|x| x.abs() < 0.5) {
                Ok(())
            } else {
                Err(format!("big value {v:?}"))
            }
        });
        match r {
            QcResult::Fail { size, .. } => assert!(size <= 100),
            _ => panic!("expected failure"),
        }
    }

    #[test]
    fn close_slices_detects_mismatch() {
        assert!(close_slices(&[1.0, 2.0], &[1.0, 2.0 + 1e-12], 1e-9).is_ok());
        assert!(close_slices(&[1.0], &[1.1], 1e-3).is_err());
        assert!(close_slices(&[1.0], &[1.0, 2.0], 1e-3).is_err());
    }

    #[test]
    fn deterministic_cases() {
        // the same property must see the same draws across runs
        let collect = |_: ()| {
            let mut seen = Vec::new();
            let _ = check("collect", 3, |g| {
                seen.push(g.f64_in(0.0, 1.0));
                Ok(())
            });
            seen
        };
        assert_eq!(collect(()), collect(()));
    }
}
