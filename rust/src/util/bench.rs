//! Tiny benchmark harness (criterion is unavailable offline).
//!
//! Each `cargo bench` target is a `harness = false` binary that uses
//! [`BenchSet`] for timed micro-sections and [`Table`]/CSV emission for the
//! paper-figure harnesses. Timing methodology: warmup runs, then `reps`
//! timed runs; report mean ± std and p50.
//!
//! For CI trend tracking, [`BenchReport`] aggregates every set into one
//! JSON document (`bench_out/perf_hotpath.json` in the perf harness) and
//! [`smoke_mode`] (env `PERF_SMOKE=1`) shrinks rep counts/workloads so the
//! whole harness finishes in seconds on a shared runner.

use super::json::Json;
use super::stats;
use std::fmt::Write as _;
use std::time::Instant;

/// True when `PERF_SMOKE` is set (and not `0`): bench binaries should run
/// minimal reps/workloads — CI wants the JSON shape and rough magnitudes,
/// not publication-grade timings.
pub fn smoke_mode() -> bool {
    std::env::var("PERF_SMOKE").map(|v| v != "0" && !v.is_empty()).unwrap_or(false)
}

/// One timed measurement series.
pub struct BenchResult {
    pub name: String,
    pub samples_ns: Vec<f64>,
    /// Optional work-unit count per run (e.g. flops, bytes) for throughput.
    pub work_per_run: Option<f64>,
    pub work_unit: &'static str,
}

impl BenchResult {
    pub fn mean_ns(&self) -> f64 {
        stats::mean(&self.samples_ns)
    }
    pub fn p50_ns(&self) -> f64 {
        stats::percentile(&self.samples_ns, 50.0)
    }
    pub fn std_ns(&self) -> f64 {
        stats::stddev(&self.samples_ns)
    }
    /// Work units per second at the median run time.
    pub fn throughput(&self) -> Option<f64> {
        self.work_per_run.map(|w| w / (self.p50_ns() * 1e-9))
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

fn fmt_rate(x: f64) -> String {
    if x >= 1e9 {
        format!("{:.2} G", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2} M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.2} K", x / 1e3)
    } else {
        format!("{x:.2} ")
    }
}

/// A named collection of benchmarks that prints a summary on drop.
pub struct BenchSet {
    title: String,
    results: Vec<BenchResult>,
    warmup: usize,
    reps: usize,
}

impl BenchSet {
    pub fn new(title: &str) -> Self {
        BenchSet {
            title: title.to_string(),
            results: Vec::new(),
            warmup: 3,
            reps: 10,
        }
    }

    pub fn with_reps(mut self, warmup: usize, reps: usize) -> Self {
        self.warmup = warmup;
        self.reps = reps;
        self
    }

    /// Time `f` (called once per rep). Use a closure returning a value to
    /// defeat dead-code elimination; we black-box via `std::hint`.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) {
        self.run_with_work(name, None, "", &mut f)
    }

    /// Time `f` with a throughput annotation (`work` units per run).
    pub fn run_throughput<T>(
        &mut self,
        name: &str,
        work: f64,
        unit: &'static str,
        mut f: impl FnMut() -> T,
    ) {
        self.run_with_work(name, Some(work), unit, &mut f)
    }

    fn run_with_work<T>(
        &mut self,
        name: &str,
        work: Option<f64>,
        unit: &'static str,
        f: &mut dyn FnMut() -> T,
    ) {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.reps);
        for _ in 0..self.reps {
            #[allow(clippy::disallowed_methods)] // wall-clock run timing (see clippy.toml)
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        let r = BenchResult {
            name: name.to_string(),
            samples_ns: samples,
            work_per_run: work,
            work_unit: unit,
        };
        println!(
            "  {:<44} {:>12} ± {:>10}  p50 {:>12}{}",
            r.name,
            fmt_ns(r.mean_ns()),
            fmt_ns(r.std_ns()),
            fmt_ns(r.p50_ns()),
            r.throughput()
                .map(|t| format!("   {}{}/s", fmt_rate(t), r.work_unit))
                .unwrap_or_default()
        );
        self.results.push(r);
    }

    pub fn header(&self) {
        println!("\n== {} ==", self.title);
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    pub fn title(&self) -> &str {
        &self.title
    }

    /// Serialize this set's measurements (ns statistics + throughput).
    pub fn to_json(&self) -> Json {
        let results = Json::Arr(
            self.results
                .iter()
                .map(|r| {
                    Json::obj(vec![
                        ("name", r.name.as_str().into()),
                        ("mean_ns", Json::Num(r.mean_ns())),
                        ("std_ns", Json::Num(r.std_ns())),
                        ("p50_ns", Json::Num(r.p50_ns())),
                        ("reps", r.samples_ns.len().into()),
                        (
                            "throughput_per_s",
                            r.throughput().map(Json::Num).unwrap_or(Json::Null),
                        ),
                        ("work_unit", r.work_unit.into()),
                    ])
                })
                .collect(),
        );
        Json::obj(vec![("title", self.title.as_str().into()), ("results", results)])
    }
}

/// Aggregates [`BenchSet`]s into one JSON document for the CI bench
/// trajectory (uploaded as an artifact by the perf job).
pub struct BenchReport {
    name: String,
    sets: Vec<Json>,
}

impl BenchReport {
    pub fn new(name: &str) -> BenchReport {
        BenchReport { name: name.to_string(), sets: Vec::new() }
    }

    pub fn add(&mut self, set: &BenchSet) {
        self.sets.push(set.to_json());
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", "proxlead-perf-v1".into()),
            ("name", self.name.as_str().into()),
            ("smoke", smoke_mode().into()),
            ("sets", Json::Arr(self.sets.clone())),
        ])
    }

    pub fn write(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_json().to_string())
    }
}

/// An aligned text table for paper-style outputs.
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "\n### {}", self.title);
        let hdr: Vec<String> = self
            .columns
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
            .collect();
        let _ = writeln!(out, "| {} |", hdr.join(" | "));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        let _ = writeln!(out, "|-{}-|", sep.join("-|-"));
        for row in &self.rows {
            let cells: Vec<String> = row
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect();
            let _ = writeln!(out, "| {} |", cells.join(" | "));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Write series as CSV: first column is the x value, then one column per
/// named series (missing points are blank). Used to dump figure data.
pub struct CsvSeries {
    pub xlabel: String,
    pub names: Vec<String>,
    /// Per-series (x, y) points.
    pub series: Vec<Vec<(f64, f64)>>,
}

impl CsvSeries {
    pub fn new(xlabel: &str) -> Self {
        CsvSeries {
            xlabel: xlabel.to_string(),
            names: Vec::new(),
            series: Vec::new(),
        }
    }

    pub fn add(&mut self, name: &str, pts: Vec<(f64, f64)>) {
        self.names.push(name.to_string());
        self.series.push(pts);
    }

    pub fn to_csv(&self) -> String {
        // union of x values, sorted
        let mut xs: Vec<f64> = self.series.iter().flatten().map(|p| p.0).collect();
        xs.sort_by(|a, b| a.total_cmp(b));
        xs.dedup();
        let mut out = String::new();
        let _ = writeln!(out, "{},{}", self.xlabel, self.names.join(","));
        for &x in &xs {
            let mut line = format!("{x}");
            for s in &self.series {
                match s.iter().find(|p| p.0 == x) {
                    Some(&(_, y)) => {
                        let _ = write!(line, ",{y:e}");
                    }
                    None => line.push(','),
                }
            }
            let _ = writeln!(out, "{line}");
        }
        out
    }

    pub fn write(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_records() {
        let mut b = BenchSet::new("t").with_reps(1, 3);
        b.run("noop", || 1 + 1);
        assert_eq!(b.results().len(), 1);
        assert_eq!(b.results()[0].samples_ns.len(), 3);
    }

    #[test]
    fn table_render_aligned() {
        let mut t = Table::new("demo", &["alg", "iters"]);
        t.row(vec!["prox-lead".into(), "120".into()]);
        let s = t.render();
        assert!(s.contains("prox-lead"));
        assert!(s.contains("| alg"));
    }

    #[test]
    fn csv_union_of_x() {
        let mut c = CsvSeries::new("epoch");
        c.add("a", vec![(0.0, 1.0), (1.0, 0.5)]);
        c.add("b", vec![(1.0, 0.4), (2.0, 0.2)]);
        let csv = c.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 4); // header + 3 x values
        assert!(lines[0].starts_with("epoch,a,b"));
        assert!(lines[1].starts_with("0,1e0,"));
    }

    #[test]
    fn throughput_annotation() {
        let mut b = BenchSet::new("t").with_reps(0, 2);
        b.run_throughput("copy", 1e6, "B", || vec![0u8; 16]);
        assert!(b.results()[0].throughput().unwrap() > 0.0);
    }

    #[test]
    fn bench_json_roundtrips() {
        let mut b = BenchSet::new("json set").with_reps(0, 3);
        b.run("noop", || 1 + 1);
        b.run_throughput("copy", 64.0, "B", || vec![0u8; 8]);
        let mut report = BenchReport::new("unit");
        report.add(&b);
        let text = report.to_json().to_string();
        let v = Json::parse(&text).unwrap();
        assert_eq!(v.get("schema").unwrap().as_str(), Some("proxlead-perf-v1"));
        let sets = v.get("sets").unwrap().as_arr().unwrap();
        assert_eq!(sets.len(), 1);
        let results = sets[0].get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 2);
        assert!(results[0].get("mean_ns").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(results[0].get("throughput_per_s").unwrap(), &Json::Null);
        assert!(results[1].get("throughput_per_s").unwrap().as_f64().unwrap() > 0.0);
    }
}
