//! Deterministic pseudo-random number generation.
//!
//! The offline build has no `rand` crate, so we implement xoshiro256++
//! (Blackman & Vigna, 2019) directly. All stochastic components of the
//! library (compression dithering, SGO sampling, synthetic data) draw from
//! this generator so that every experiment is reproducible from a seed.

/// xoshiro256++ generator. 256 bits of state, period 2^256 − 1.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline(always)]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

/// SplitMix64, used to seed xoshiro from a single u64 (recommended by the
/// xoshiro authors to avoid correlated low-entropy states).
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream for a sub-component (e.g. node id).
    /// Uses the jump-free "seed with hash of (state, tag)" construction.
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mixed = self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15);
        Rng::new(mixed)
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform f64 in [0, 1). 53 bits of mantissa entropy.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Lemire's unbiased rejection method.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n {
                return (m >> 64) as usize;
            }
            // rejection zone: only when lo < n do we need the threshold test
            let t = n.wrapping_neg() % n;
            if lo >= t {
                return (m >> 64) as usize;
            }
        }
    }

    /// Bernoulli(p) draw.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Marsaglia polar method.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// N(mu, sigma^2) draw.
    #[inline]
    pub fn normal_scaled(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Fill a slice with standard normals.
    pub fn fill_normal(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.normal();
        }
    }

    /// Sample an index from a discrete distribution given by `weights`
    /// (need not be normalized). Linear scan — fine for m ≤ a few hundred.
    pub fn discrete(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// k distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_uniformish() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sumsq += x * x;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Rng::new(5);
        let hits = (0..100_000).filter(|_| r.bernoulli(0.3)).count();
        assert!((28_000..32_000).contains(&hits));
    }

    #[test]
    fn discrete_respects_weights() {
        let mut r = Rng::new(9);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.discrete(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > 2 * counts[0]);
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(13);
        let idx = r.sample_indices(50, 20);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
        assert!(sorted.iter().all(|&i| i < 50));
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(17);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
