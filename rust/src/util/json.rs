//! Minimal JSON value model, parser, and writer.
//!
//! The offline environment has no `serde_json`, so we implement the small
//! subset the library needs: the artifact manifest written by
//! `python/compile/aot.py`, experiment configs, and metric dumps. Supports
//! the full JSON grammar except `\uXXXX` surrogate pairs outside the BMP.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) for deterministic output.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // --- typed accessors -------------------------------------------------
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    // --- builders ---------------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
    pub fn arr_str(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.to_string())).collect())
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }
    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }
    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }
    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // multi-byte UTF-8: copy raw bytes
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    let s = std::str::from_utf8(&self.b[start..self.pos])
                        .map_err(|_| self.err("invalid utf8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_json(self, &mut s);
        f.write_str(&s)
    }
}

fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(x) => {
            if x.fract() == 0.0 && x.abs() < 1e15 {
                out.push_str(&format!("{}", *x as i64));
            } else {
                out.push_str(&format!("{x}"));
            }
        }
        Json::Str(s) => escape(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(item, out);
            }
            out.push(']');
        }
        Json::Obj(o) => {
            out.push('{');
            for (i, (k, val)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape(k, out);
                out.push(':');
                write_json(val, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let src = r#"{"a": 1, "b": [true, null, "x\n"], "c": {"d": -2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2500.0));
        let printed = v.to_string();
        let v2 = Json::parse(&printed).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn parse_numbers() {
        for (s, want) in [
            ("0", 0.0),
            ("-0.5", -0.5),
            ("1e3", 1000.0),
            ("2.5E-2", 0.025),
            ("123456789", 123456789.0),
        ] {
            assert_eq!(Json::parse(s).unwrap().as_f64(), Some(want), "{s}");
        }
    }

    #[test]
    fn rejects_garbage() {
        for s in ["{", "[1,", "\"abc", "tru", "1.2.3", "{\"a\" 1}", "[1] x"] {
            assert!(Json::parse(s).is_err(), "{s} should fail");
        }
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""é""#).unwrap();
        assert_eq!(v.as_str(), Some("é"));
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo → world\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → world"));
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
    }

    #[test]
    fn builders() {
        let v = Json::obj(vec![("xs", Json::arr_f64(&[1.0, 2.0])), ("n", 3usize.into())]);
        assert_eq!(v.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("xs").unwrap().as_arr().unwrap().len(), 2);
    }
}
