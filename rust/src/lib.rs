//! # Prox-LEAD: Decentralized Composite Optimization with Compression
//!
//! A full-system reproduction of *"Decentralized Composite Optimization
//! with Compression"* (Li, Liu, Tang, Yan, Yuan, 2021): the Prox-LEAD
//! algorithm (Algorithm 1) with SGD / Loopless-SVRG / SAGA gradient oracles,
//! every baseline the paper compares against, exact communication-bit
//! accounting, an algorithm-generic message-passing multi-node coordinator
//! (every registry algorithm runs on real serialized frames, bit-identical
//! to the matrix engine under an exact codec), and a PJRT runtime that
//! executes JAX/Pallas-AOT-compiled gradient kernels on the hot path.
//!
//! See `DESIGN.md` for the architecture and the per-experiment index, and
//! `EXPERIMENTS.md` for reproduced figures/tables.

// Several builders intentionally take the full hyperparameter surface as
// arguments, and tests mutate default-constructed configs field by field.
#![allow(clippy::too_many_arguments)]
#![allow(clippy::field_reassign_with_default)]

pub mod algorithm;
pub mod check;
pub mod cli;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod exp;
pub mod graph;
pub mod linalg;
pub mod lint;
pub mod oracle;
pub mod problem;
pub mod prox;
pub mod runner;
pub mod runtime;
pub mod sim;
pub mod sweep;
pub mod transport;
pub mod util;

pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
