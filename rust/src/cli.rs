//! Minimal argument parser for the launcher (clap is unavailable offline).
//!
//! Grammar: `proxlead <subcommand> [--config FILE] [--key value | --key=value]…`
//! Every `--key` after `--config` handling is routed into
//! [`crate::config::Config::set`], so the CLI surface automatically tracks
//! the config schema.

use crate::config::{Config, ConfigError};

/// A parsed invocation.
#[derive(Debug)]
pub struct Invocation {
    pub subcommand: String,
    pub config: Config,
    /// Raw flags not consumed by the config (subcommand-specific).
    pub extra: Vec<(String, String)>,
}

pub const USAGE: &str = "\
prox-lead: decentralized composite optimization with compression
  (Li, Liu, Tang, Yan, Yuan 2021 — full-system reproduction)

USAGE:
  proxlead <SUBCOMMAND> [--config FILE] [--key value]...

SUBCOMMANDS:
  train       run any `algorithm` on the configured `backend`: the matrix
              engine (default), the message-passing coordinator (node
              threads, real serialized frames), or the sharded massive-n
              simulator (`--backend sim`, 100k+ nodes). With
              `--transport tcp|unix` the coordinator listens on `bind`
              and waits for `proxlead node` worker processes instead of
              spawning threads
  node        run ONE node of a socket-transport coordinator run in this
              process: dials the leader's `bind` address (bounded retry),
              handshakes as `--node-id N`, exits on BYE/ABORT. Launch n
              workers against one `train --transport tcp|unix` leader
  sweep       run a parallel experiment grid through the matrix engine
  solve-ref   compute the high-precision reference solution x*
  info        print problem/network condition numbers and artifacts
  config      print the effective configuration (after overrides)
  help        this message

CONFIG KEYS (also usable as --key value):
  problem(logreg|least-squares|lasso)
  nodes samples_per_node dim classes batches lambda1 lambda2 separation
  shuffled topology(ring|chain|star|complete|grid|er) mixing(uniform|mh|lazy)
  connectivity|er_prob (ER edge prob; 0 = auto 2·ln(n)/n)
  algorithm(prox-lead|lead|dgd|choco|nids|p2d2|pg-extra|pdgm|dualgd)
  oracle(full|sgd|lsvrg|saga) lsvrg_p compressor(inf|l2|randk|topk)
  bits(2..16|32|64) block sparsify_k eta(0=auto 1/2L) alpha gamma
  rounds record_every seed backend(engine|coordinator|sim)
  compute(native|xla) out
  straggler_prob straggler_us
  transport(inproc|tcp|unix) bind(host:port | socket path)
  connect_timeout_ms (worker dial budget; leader accepts for 2x)

TRAIN STOP FLAGS (composable; first criterion hit ends the run and is
reported as `stopped by …` — `rounds` is always the hard cap):
  --target 1e-9                   stop at this suboptimality
  --max-bits N                    stop at a cumulative payload-bit budget
  --max-grad-evals N              stop at a gradient-evaluation budget
  --deadline-ms N                 stop at a wall-clock deadline
  (stops are observed at `record_every` granularity — use
   --record_every 1 for round-exact budget stops)
  --json result.json              write the full RunResult (history,
                                  stop reason, final iterate) as JSON

NODE FLAGS (node subcommand only; stop flags must match the leader's):
  --node-id N                     which node this worker is (0-based)

SWEEP FLAGS (sweep subcommand only):
  --grid \"key=v1,v2;key2=v1,v2\"   cartesian axes over any config key
  --threads N                     worker threads (default: all cores);
                                  never changes results, only wall-clock
  --target 1e-9                   per-cell early-stop suboptimality
  --out sweep.json                deterministic JSON trajectory aggregate

EXAMPLES:
  proxlead train --rounds 300 --bits 2 --oracle saga --out run.csv
  proxlead train --rounds 5000 --record_every 1 --max-bits 2000000
  proxlead train --config experiment.cfg --compute xla
  proxlead train --backend sim --nodes 100000 --problem least-squares
  proxlead sweep --grid \"algorithm=prox-lead,dgd;bits=2,32;seed=1,2\" \\
                 --rounds 2000 --threads 8 --out sweep.json
  proxlead sweep --grid \"problem=logreg,least-squares;bits=2,32\" --rounds 500
  proxlead info --nodes 16 --topology grid
  proxlead train --backend coordinator --transport unix --bind /tmp/pl.sock \\
                 --nodes 4 --json result.json   # leader; plus 4 workers:
  proxlead node --node-id 0 --backend coordinator --transport unix \\
                --bind /tmp/pl.sock --nodes 4   # …and ids 1, 2, 3
";

/// Parse `args` (without argv[0]).
pub fn parse(args: &[String]) -> Result<Invocation, ConfigError> {
    let mut it = args.iter().peekable();
    let subcommand = it
        .next()
        .cloned()
        .unwrap_or_else(|| "help".to_string());
    let mut config = Config::default();
    let mut extra = Vec::new();
    let mut overrides: Vec<(String, String)> = Vec::new();

    while let Some(arg) = it.next() {
        let Some(flag) = arg.strip_prefix("--") else {
            return Err(ConfigError(format!("unexpected positional argument '{arg}'")));
        };
        let (key, val) = match flag.split_once('=') {
            Some((k, v)) => (k.to_string(), v.to_string()),
            None => {
                let v = it
                    .next()
                    .ok_or_else(|| ConfigError(format!("--{flag} needs a value")))?;
                (flag.to_string(), v.clone())
            }
        };
        if key == "config" {
            // file first, CLI overrides later (collected separately)
            config = Config::from_file(&val)?;
        } else {
            overrides.push((key, val));
        }
    }
    for (k, v) in overrides {
        match config.set(&k, &v) {
            Ok(()) => {}
            Err(_) => extra.push((k, v)), // subcommand-specific flag
        }
    }
    Ok(Invocation { subcommand, config, extra })
}

impl Invocation {
    /// Look up a subcommand-specific flag.
    pub fn flag(&self, key: &str) -> Option<&str> {
        self.extra.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(args: &[&str]) -> Vec<String> {
        args.iter().map(|a| a.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_overrides() {
        let inv = parse(&s(&["train", "--rounds", "77", "--bits=8", "--oracle", "saga"])).unwrap();
        assert_eq!(inv.subcommand, "train");
        assert_eq!(inv.config.rounds, 77);
        assert_eq!(inv.config.bits, 8);
        assert_eq!(inv.config.oracle, "saga");
    }

    #[test]
    fn config_file_then_cli_override() {
        let dir = std::env::temp_dir().join("proxlead_cli_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("a.cfg");
        std::fs::write(&path, "rounds = 5\nbits = 4\n").unwrap();
        let inv = parse(&s(&[
            "train",
            "--config",
            path.to_str().unwrap(),
            "--bits",
            "2",
        ]))
        .unwrap();
        assert_eq!(inv.config.rounds, 5); // from file
        assert_eq!(inv.config.bits, 2); // CLI wins
    }

    #[test]
    fn unknown_keys_become_extra_flags() {
        let inv = parse(&s(&["solve-ref", "--tol", "1e-9"])).unwrap();
        assert_eq!(inv.flag("tol"), Some("1e-9"));
        assert_eq!(inv.flag("nope"), None);
    }

    #[test]
    fn missing_value_errors() {
        assert!(parse(&s(&["train", "--rounds"])).is_err());
        assert!(parse(&s(&["train", "stray"])).is_err());
    }

    #[test]
    fn no_args_is_help() {
        let inv = parse(&[]).unwrap();
        assert_eq!(inv.subcommand, "help");
    }
}
