//! Per-node halves of every registry algorithm — the [`NodeAlgorithm`]
//! implementations the generic driver runs on node threads.
//!
//! Each struct is the row-i arithmetic of its matrix-engine counterpart,
//! re-expressed over local vectors, with the *same operation order per
//! entry* (see each `outgoing`/`update` body's correspondence comments).
//! Under an exact codec (`Dense64`) a coordinator run is therefore
//! bit-identical to the engine — `rust/tests/coordinator_parity.rs` pins
//! all 9 registry names.
//!
//! Two communication styles cover all of them:
//!
//! - **Difference compression against a running state H** ([`NodeComm`],
//!   the per-node mirror of the engine's `CommState`): Prox-LEAD/LEAD
//!   broadcast Q(Z − H); the compressed dual methods (LessBit-A/B =
//!   DualGD/PDGM under a lossy codec) broadcast Q(X − H). Both endpoints
//!   blend H ← H + αQ, so the compression error vanishes as Z stabilizes.
//! - **Raw-vector broadcast**: DGD sends its iterate, Choco the difference
//!   against its public replica, NIDS/PG-EXTRA/P2D2 their mixing operand.
//!   The wire codec still applies — running e.g. NIDS over a 2-bit wire is
//!   a new scenario the matrix engine never modeled (it charges these
//!   baselines 32 bits/entry and mixes exact values).
//!
//! Oracle streams are shared with the engine: `Sgo::for_node` aligns the
//! per-node RNG fork with the slot the all-nodes constructor would
//! produce, so even SGD/LSVRG/SAGA runs match the engine bit for bit on an
//! exact codec.

// Several updates deliberately spell `+ -1.0 * v` / `+ -η * g`: each line
// mirrors one engine `axpy(alpha, ·)` call so the per-entry f64 operation
// sequence — and therefore the iterate bits — match exactly.
#![allow(clippy::neg_multiply)]

use super::node::{NodeAlgorithm, WeightRow};
use super::{CoordConfig, NodeHyper};
use crate::linalg::Mat;
use crate::oracle::Sgo;
use crate::problem::Problem;
use crate::prox::Prox;
use crate::util::rng::Rng;
use std::sync::Arc;

/// The engine seeds its oracle with `Rng::new(seed).next_u64()`; drawing
/// the same value here puts every node thread on the engine's per-node
/// oracle stream (see [`Sgo::for_node`]).
fn oracle_for(
    hyper: &NodeHyper,
    wire: &CoordConfig,
    problem: &dyn Problem,
    me: usize,
    x0: &[f64],
) -> Sgo {
    Sgo::for_node(hyper.oracle, problem, me, x0, Rng::new(wire.seed).next_u64())
}

/// The COMM procedure of Algorithm 1, one node's share — the per-node
/// mirror of the engine's `CommState`. Both wire endpoints decode the same
/// Qᵏ, so H and H_w = (WH)ᵢ stay consistent across the network without
/// ever exchanging H itself.
pub struct NodeComm {
    h: Vec<f64>,
    h_w: Vec<f64>,
    alpha: f64,
    wq: Vec<f64>, // scratch: (W·Qᵏ) row
}

impl NodeComm {
    /// H¹ = X⁰ and H_w¹ = (W X⁰)ᵢ — X⁰ is common knowledge, so the init
    /// product is local (no startup exchange), exactly like the engine's
    /// `CommState::new`.
    pub fn new(row: &WeightRow, x0_all: &Mat, alpha: f64) -> NodeComm {
        let h = x0_all.row(row.node).to_vec();
        let mut h_w = vec![0.0; x0_all.cols];
        row.mix_rows_into(&mut h_w, x0_all);
        NodeComm { h, h_w, alpha, wq: vec![0.0; x0_all.cols] }
    }

    /// The broadcast operand Z − H (what the wire codec compresses).
    pub fn diff_into(&self, z: &[f64], out: &mut [f64]) {
        for ((o, &zi), &hi) in out.iter_mut().zip(z).zip(&self.h) {
            *o = zi - hi;
        }
    }

    /// Absorb one decoded round: writes the gossip residual Ẑ − Ẑ_w into
    /// `resid` (Ẑ = H + Qᵢ, Ẑ_w = H_w + (WQ)ᵢ) and blends H ← H + αQᵢ,
    /// H_w ← H_w + α(WQ)ᵢ — the engine's `CommState::comm` per row.
    pub fn absorb(
        &mut self,
        row: &WeightRow,
        q_own: &[f64],
        peers: &[(usize, Vec<f64>)],
        resid: &mut [f64],
    ) {
        row.mix_into(&mut self.wq, q_own, peers);
        let a = self.alpha;
        for ((((r, h), hw), &q), &wq) in resid
            .iter_mut()
            .zip(self.h.iter_mut())
            .zip(self.h_w.iter_mut())
            .zip(q_own)
            .zip(&self.wq)
        {
            let z_hat = *h + q;
            let zw_hat = *hw + wq;
            *r = z_hat - zw_hat;
            *h += a * q;
            *hw += a * wq;
        }
    }
}

// ---------------------------------------------------------------------------
// Prox-LEAD (Algorithm 1; LEAD when the prox is Zero)
// ---------------------------------------------------------------------------

/// Node half of [`crate::algorithm::ProxLead`].
pub struct ProxLeadNode {
    problem: Arc<dyn Problem>,
    prox: Arc<dyn Prox>,
    row: WeightRow,
    me: usize,
    eta: f64,
    gamma: f64,
    oracle: Sgo,
    comm: NodeComm,
    x: Vec<f64>,
    d: Vec<f64>,
    z: Vec<f64>,
    g: Vec<f64>,
    resid: Vec<f64>,
}

impl ProxLeadNode {
    pub fn new(
        problem: Arc<dyn Problem>,
        prox: Arc<dyn Prox>,
        x0_all: &Mat,
        row: WeightRow,
        hyper: &NodeHyper,
        wire: &CoordConfig,
    ) -> ProxLeadNode {
        let me = row.node;
        let p = problem.dim();
        let mut oracle = oracle_for(hyper, wire, problem.as_ref(), me, x0_all.row(me));
        // lines 1–3: Z¹ = X⁰ − η·SGO(X⁰), X¹ = prox_ηR(Z¹), D¹ = 0
        let mut g = vec![0.0; p];
        oracle.sample(problem.as_ref(), me, x0_all.row(me), &mut g);
        let mut x = x0_all.row(me).to_vec();
        for (xi, &gi) in x.iter_mut().zip(&g) {
            *xi += -hyper.eta * gi;
        }
        prox.prox(&mut x, hyper.eta);
        let comm = NodeComm::new(&row, x0_all, hyper.alpha);
        ProxLeadNode {
            problem,
            prox,
            row,
            me,
            eta: hyper.eta,
            gamma: hyper.gamma,
            oracle,
            comm,
            x,
            d: vec![0.0; p],
            z: vec![0.0; p],
            g,
            resid: vec![0.0; p],
        }
    }
}

impl NodeAlgorithm for ProxLeadNode {
    fn outgoing(&mut self, out: &mut [f64]) {
        // lines 5–6: Z = X − ηG − ηD (engine: z.axpy(-η, G); z.axpy(-η, D))
        self.oracle.sample(self.problem.as_ref(), self.me, &self.x, &mut self.g);
        for (((z, &xi), &gi), &di) in self.z.iter_mut().zip(&self.x).zip(&self.g).zip(&self.d) {
            *z = xi + -self.eta * gi + -self.eta * di;
        }
        // COMM broadcast operand: Z − H
        self.comm.diff_into(&self.z, out);
    }

    fn update(&mut self, q_own: &[f64], peers: &[(usize, Vec<f64>)]) {
        self.comm.absorb(&self.row, q_own, peers, &mut self.resid);
        // lines 8–10: D += γ/(2η)·resid; V = Z − γ/2·resid; X = prox_ηR(V)
        let coef = self.gamma / (2.0 * self.eta);
        for ((d, z), &r) in self.d.iter_mut().zip(self.z.iter_mut()).zip(&self.resid) {
            *d += coef * r;
            *z += -self.gamma / 2.0 * r;
        }
        self.prox.prox(&mut self.z, self.eta);
        self.x.copy_from_slice(&self.z);
    }

    fn x(&self) -> &[f64] {
        &self.x
    }

    fn grad_evals(&self) -> u64 {
        self.oracle.grad_evals()
    }
}

// ---------------------------------------------------------------------------
// DGD / D-PSGD / Prox-DGD
// ---------------------------------------------------------------------------

/// Node half of [`crate::algorithm::Dgd`]: broadcast the (codec-compressed)
/// iterate, mix, gradient step, prox.
pub struct DgdNode {
    problem: Arc<dyn Problem>,
    prox: Arc<dyn Prox>,
    row: WeightRow,
    me: usize,
    eta: f64,
    oracle: Sgo,
    x: Vec<f64>,
    g: Vec<f64>,
    mixed: Vec<f64>,
}

impl DgdNode {
    pub fn new(
        problem: Arc<dyn Problem>,
        prox: Arc<dyn Prox>,
        x0_all: &Mat,
        row: WeightRow,
        hyper: &NodeHyper,
        wire: &CoordConfig,
    ) -> DgdNode {
        let me = row.node;
        let p = problem.dim();
        let oracle = oracle_for(hyper, wire, problem.as_ref(), me, x0_all.row(me));
        DgdNode {
            problem,
            prox,
            row,
            me,
            eta: hyper.eta,
            oracle,
            x: x0_all.row(me).to_vec(),
            g: vec![0.0; p],
            mixed: vec![0.0; p],
        }
    }
}

impl NodeAlgorithm for DgdNode {
    fn outgoing(&mut self, out: &mut [f64]) {
        self.oracle.sample(self.problem.as_ref(), self.me, &self.x, &mut self.g);
        out.copy_from_slice(&self.x);
    }

    fn update(&mut self, q_own: &[f64], peers: &[(usize, Vec<f64>)]) {
        // X ← prox_ηr(W X̂ − η G)  (engine: apply_into; axpy(-η, G); prox)
        self.row.mix_into(&mut self.mixed, q_own, peers);
        for (m, &gi) in self.mixed.iter_mut().zip(&self.g) {
            *m += -self.eta * gi;
        }
        self.prox.prox(&mut self.mixed, self.eta);
        self.x.copy_from_slice(&self.mixed);
    }

    fn x(&self) -> &[f64] {
        &self.x
    }

    fn grad_evals(&self) -> u64 {
        self.oracle.grad_evals()
    }
}

// ---------------------------------------------------------------------------
// Choco-SGD / Choco-Gossip
// ---------------------------------------------------------------------------

/// Node half of [`crate::algorithm::Choco`]: every node keeps public
/// replicas x̂ⱼ of itself and its gossip neighbors, updated by the
/// compressed differences everyone broadcasts.
pub struct ChocoNode {
    problem: Arc<dyn Problem>,
    prox: Arc<dyn Prox>,
    row_minus_i: WeightRow,
    me: usize,
    eta: f64,
    gamma_c: f64,
    oracle: Sgo,
    x: Vec<f64>,
    x_half: Vec<f64>,
    g: Vec<f64>,
    corr: Vec<f64>,
    replica_own: Vec<f64>,
    /// Neighbor replicas, aligned with the gossip row (ascending id).
    replicas: Vec<(usize, Vec<f64>)>,
}

impl ChocoNode {
    pub fn new(
        problem: Arc<dyn Problem>,
        prox: Arc<dyn Prox>,
        x0_all: &Mat,
        row: WeightRow,
        hyper: &NodeHyper,
        wire: &CoordConfig,
    ) -> ChocoNode {
        let me = row.node;
        let p = problem.dim();
        let oracle = oracle_for(hyper, wire, problem.as_ref(), me, x0_all.row(me));
        let replicas = row.neighbors.iter().map(|&(j, _)| (j, vec![0.0; p])).collect();
        ChocoNode {
            problem,
            prox,
            row_minus_i: row.minus_identity(),
            me,
            eta: hyper.eta,
            // the experiment γ doubles as Choco's gossip stepsize γ_c (the
            // registry convention)
            gamma_c: hyper.gamma,
            oracle,
            x: x0_all.row(me).to_vec(),
            x_half: vec![0.0; p],
            g: vec![0.0; p],
            corr: vec![0.0; p],
            replica_own: vec![0.0; p],
            replicas,
        }
    }
}

impl NodeAlgorithm for ChocoNode {
    fn outgoing(&mut self, out: &mut [f64]) {
        // X½ = X − ηG; broadcast Q(X½ − X̂ᵢ)
        self.oracle.sample(self.problem.as_ref(), self.me, &self.x, &mut self.g);
        for ((h, &xi), &gi) in self.x_half.iter_mut().zip(&self.x).zip(&self.g) {
            *h = xi + -self.eta * gi;
        }
        for ((o, &hi), &ri) in out.iter_mut().zip(&self.x_half).zip(&self.replica_own) {
            *o = hi - ri;
        }
    }

    fn update(&mut self, q_own: &[f64], peers: &[(usize, Vec<f64>)]) {
        // all replicas advance by the decoded differences: X̂ ← X̂ + Q
        for (r, &q) in self.replica_own.iter_mut().zip(q_own) {
            *r += q;
        }
        for ((_, rep), (_, q)) in self.replicas.iter_mut().zip(peers) {
            for (r, &qi) in rep.iter_mut().zip(q) {
                *r += qi;
            }
        }
        // X ← prox_ηr( X½ + γ_c (W − I) X̂ )
        self.row_minus_i.mix_into(&mut self.corr, &self.replica_own, &self.replicas);
        for (h, &c) in self.x_half.iter_mut().zip(&self.corr) {
            *h += self.gamma_c * c;
        }
        self.prox.prox(&mut self.x_half, self.eta);
        self.x.copy_from_slice(&self.x_half);
    }

    fn x(&self) -> &[f64] {
        &self.x
    }

    fn grad_evals(&self) -> u64 {
        self.oracle.grad_evals()
    }
}

// ---------------------------------------------------------------------------
// NIDS
// ---------------------------------------------------------------------------

/// Node half of [`crate::algorithm::Nids`]: broadcast the W̃ operand
/// 2Xᵏ − Xᵏ⁻¹ − η(Gᵏ − Gᵏ⁻¹), mix with W̃ = (I+W)/2.
pub struct NidsNode {
    problem: Arc<dyn Problem>,
    prox: Arc<dyn Prox>,
    row_tilde: WeightRow,
    me: usize,
    eta: f64,
    oracle: Sgo,
    x: Vec<f64>,
    x_prev: Vec<f64>,
    z: Vec<f64>,
    g: Vec<f64>,
    g_prev: Vec<f64>,
    mixed: Vec<f64>,
}

impl NidsNode {
    pub fn new(
        problem: Arc<dyn Problem>,
        prox: Arc<dyn Prox>,
        x0_all: &Mat,
        row: WeightRow,
        hyper: &NodeHyper,
        wire: &CoordConfig,
    ) -> NidsNode {
        let me = row.node;
        let p = problem.dim();
        let mut oracle = oracle_for(hyper, wire, problem.as_ref(), me, x0_all.row(me));
        // init: Z¹ = X⁰ − η∇F(X⁰); X¹ = prox(Z¹)
        let mut g0 = vec![0.0; p];
        oracle.sample(problem.as_ref(), me, x0_all.row(me), &mut g0);
        let mut z = x0_all.row(me).to_vec();
        for (zi, &gi) in z.iter_mut().zip(&g0) {
            *zi += -hyper.eta * gi;
        }
        let mut x = z.clone();
        prox.prox(&mut x, hyper.eta);
        NidsNode {
            problem,
            prox,
            row_tilde: row.half_lazy(),
            me,
            eta: hyper.eta,
            oracle,
            x,
            x_prev: x0_all.row(me).to_vec(),
            z,
            g: vec![0.0; p],
            g_prev: g0,
            mixed: vec![0.0; p],
        }
    }
}

impl NodeAlgorithm for NidsNode {
    fn outgoing(&mut self, out: &mut [f64]) {
        // inner = 2Xᵏ − Xᵏ⁻¹ − η(Gᵏ − Gᵏ⁻¹), engine's exact axpy sequence
        self.oracle.sample(self.problem.as_ref(), self.me, &self.x, &mut self.g);
        for ((((o, &xi), &xp), &gi), &gp) in
            out.iter_mut().zip(&self.x).zip(&self.x_prev).zip(&self.g).zip(&self.g_prev)
        {
            let mut t = xi * 2.0;
            t += -1.0 * xp;
            t += -self.eta * gi;
            t += self.eta * gp;
            *o = t;
        }
    }

    fn update(&mut self, q_own: &[f64], peers: &[(usize, Vec<f64>)]) {
        // Zᵏ⁺¹ = Zᵏ − Xᵏ + W̃·inner; Xᵏ⁺¹ = prox(Zᵏ⁺¹)
        self.row_tilde.mix_into(&mut self.mixed, q_own, peers);
        for ((z, &xi), &m) in self.z.iter_mut().zip(&self.x).zip(&self.mixed) {
            *z += -1.0 * xi;
            *z += 1.0 * m;
        }
        self.x_prev.copy_from_slice(&self.x);
        self.g_prev.copy_from_slice(&self.g);
        self.x.copy_from_slice(&self.z);
        self.prox.prox(&mut self.x, self.eta);
    }

    fn x(&self) -> &[f64] {
        &self.x
    }

    fn grad_evals(&self) -> u64 {
        self.oracle.grad_evals()
    }
}

// ---------------------------------------------------------------------------
// PG-EXTRA
// ---------------------------------------------------------------------------

/// Node half of [`crate::algorithm::PgExtra`]: broadcasts Xᵏ and mixes it
/// with W *and* (cached from the previous round) with W̃ — the only
/// algorithm whose update needs two weight rows.
pub struct PgExtraNode {
    problem: Arc<dyn Problem>,
    prox: Arc<dyn Prox>,
    row: WeightRow,
    row_tilde: WeightRow,
    me: usize,
    eta: f64,
    oracle: Sgo,
    x: Vec<f64>,
    x_prev: Vec<f64>,
    z: Vec<f64>,
    g: Vec<f64>,
    g_prev: Vec<f64>,
    wx: Vec<f64>,
    wtx_prev: Vec<f64>,
    /// Previous round's decoded broadcasts (own + peers) — the W̃Xᵏ⁻¹
    /// operands. Initialized from the common X⁰.
    prev_own: Vec<f64>,
    prev_peers: Vec<(usize, Vec<f64>)>,
}

impl PgExtraNode {
    pub fn new(
        problem: Arc<dyn Problem>,
        prox: Arc<dyn Prox>,
        x0_all: &Mat,
        row: WeightRow,
        hyper: &NodeHyper,
        wire: &CoordConfig,
    ) -> PgExtraNode {
        let me = row.node;
        let p = problem.dim();
        let mut oracle = oracle_for(hyper, wire, problem.as_ref(), me, x0_all.row(me));
        // init: Z¹ = (W X⁰)ᵢ − η∇F(X⁰)ᵢ; X¹ = prox(Z¹); X⁰ is common
        // knowledge, so the W·X⁰ product is local
        let mut g0 = vec![0.0; p];
        oracle.sample(problem.as_ref(), me, x0_all.row(me), &mut g0);
        let mut z = vec![0.0; p];
        row.mix_rows_into(&mut z, x0_all);
        for (zi, &gi) in z.iter_mut().zip(&g0) {
            *zi += -hyper.eta * gi;
        }
        let mut x = z.clone();
        prox.prox(&mut x, hyper.eta);
        let prev_peers = row.neighbors.iter().map(|&(j, _)| (j, x0_all.row(j).to_vec())).collect();
        PgExtraNode {
            problem,
            prox,
            row_tilde: row.half_lazy(),
            row,
            me,
            eta: hyper.eta,
            oracle,
            x,
            x_prev: x0_all.row(me).to_vec(),
            z,
            g: vec![0.0; p],
            g_prev: g0,
            wx: vec![0.0; p],
            wtx_prev: vec![0.0; p],
            prev_own: x0_all.row(me).to_vec(),
            prev_peers,
        }
    }
}

impl NodeAlgorithm for PgExtraNode {
    fn outgoing(&mut self, out: &mut [f64]) {
        self.oracle.sample(self.problem.as_ref(), self.me, &self.x, &mut self.g);
        out.copy_from_slice(&self.x);
    }

    fn update(&mut self, q_own: &[f64], peers: &[(usize, Vec<f64>)]) {
        // Zᵏ⁺¹ = Zᵏ + WXᵏ − W̃Xᵏ⁻¹ − η(Gᵏ − Gᵏ⁻¹)
        self.row.mix_into(&mut self.wx, q_own, peers);
        self.row_tilde.mix_into(&mut self.wtx_prev, &self.prev_own, &self.prev_peers);
        for ((((z, &wx), &wt), &gi), &gp) in
            self.z.iter_mut().zip(&self.wx).zip(&self.wtx_prev).zip(&self.g).zip(&self.g_prev)
        {
            *z += 1.0 * wx;
            *z += -1.0 * wt;
            *z += -self.eta * gi;
            *z += self.eta * gp;
        }
        self.x_prev.copy_from_slice(&self.x);
        self.g_prev.copy_from_slice(&self.g);
        self.x.copy_from_slice(&self.z);
        self.prox.prox(&mut self.x, self.eta);
        // next round's W̃ operands are this round's decoded broadcasts
        self.prev_own.copy_from_slice(q_own);
        for ((_, prev), (_, cur)) in self.prev_peers.iter_mut().zip(peers) {
            prev.copy_from_slice(cur);
        }
    }

    fn x(&self) -> &[f64] {
        &self.x
    }

    fn grad_evals(&self) -> u64 {
        self.oracle.grad_evals()
    }
}

// ---------------------------------------------------------------------------
// P2D2
// ---------------------------------------------------------------------------

/// Node half of [`crate::algorithm::P2d2`]. The engine performs a W̃
/// product at *construction* (Z¹ = W̃(X⁰ − η∇F(X⁰))); on the wire that
/// product needs the neighbors' gradients, so the node declares one setup
/// round — the driver exchanges frames once before step counting starts.
pub struct P2d2Node {
    problem: Arc<dyn Problem>,
    prox: Arc<dyn Prox>,
    row_tilde: WeightRow,
    me: usize,
    eta: f64,
    oracle: Sgo,
    x: Vec<f64>,
    x_prev: Vec<f64>,
    z: Vec<f64>,
    g: Vec<f64>,
    g_prev: Vec<f64>,
    pending_setup: bool,
}

impl P2d2Node {
    pub fn new(
        problem: Arc<dyn Problem>,
        prox: Arc<dyn Prox>,
        x0_all: &Mat,
        row: WeightRow,
        hyper: &NodeHyper,
        wire: &CoordConfig,
    ) -> P2d2Node {
        let me = row.node;
        let p = problem.dim();
        let mut oracle = oracle_for(hyper, wire, problem.as_ref(), me, x0_all.row(me));
        let mut g0 = vec![0.0; p];
        oracle.sample(problem.as_ref(), me, x0_all.row(me), &mut g0);
        P2d2Node {
            problem,
            prox,
            row_tilde: row.half_lazy(),
            me,
            eta: hyper.eta,
            oracle,
            x: x0_all.row(me).to_vec(),
            x_prev: x0_all.row(me).to_vec(),
            z: vec![0.0; p],
            g: vec![0.0; p],
            g_prev: g0,
            pending_setup: true,
        }
    }
}

impl NodeAlgorithm for P2d2Node {
    fn setup_rounds(&self) -> usize {
        1
    }

    fn outgoing(&mut self, out: &mut [f64]) {
        if self.pending_setup {
            // init broadcast: X⁰ − η∇F(X⁰) (g_prev holds G⁰)
            for ((o, &xi), &gi) in out.iter_mut().zip(&self.x).zip(&self.g_prev) {
                *o = xi + -self.eta * gi;
            }
            return;
        }
        // inner = Zᵏ + Xᵏ − Xᵏ⁻¹ − η(Gᵏ − Gᵏ⁻¹), engine's axpy sequence
        self.oracle.sample(self.problem.as_ref(), self.me, &self.x, &mut self.g);
        for (((((o, &zi), &xi), &xp), &gi), &gp) in out
            .iter_mut()
            .zip(&self.z)
            .zip(&self.x)
            .zip(&self.x_prev)
            .zip(&self.g)
            .zip(&self.g_prev)
        {
            let mut t = zi;
            t += 1.0 * xi;
            t += -1.0 * xp;
            t += -self.eta * gi;
            t += self.eta * gp;
            *o = t;
        }
    }

    fn update(&mut self, q_own: &[f64], peers: &[(usize, Vec<f64>)]) {
        // Z is overwritten by the W̃ mix, exactly like the engine's
        // apply_into; then Xᵏ⁺¹ = prox(Zᵏ⁺¹)
        self.row_tilde.mix_into(&mut self.z, q_own, peers);
        if self.pending_setup {
            // x_prev/g_prev already hold X⁰/G⁰ (the engine's init state)
            self.pending_setup = false;
        } else {
            self.x_prev.copy_from_slice(&self.x);
            self.g_prev.copy_from_slice(&self.g);
        }
        self.x.copy_from_slice(&self.z);
        self.prox.prox(&mut self.x, self.eta);
    }

    fn x(&self) -> &[f64] {
        &self.x
    }

    fn grad_evals(&self) -> u64 {
        self.oracle.grad_evals()
    }
}

/// The dual-ascent consume step DualGD and PDGM share (one copy of the
/// engine correspondence): on a lossy wire, D += θ(X̂ − X̂_w) through the
/// COMM state (LessBit); on an exact wire, D += θ(I − W)X — the engine's
/// fused uncompressed loop.
#[allow(clippy::too_many_arguments)]
fn dual_ascend(
    comm: &mut Option<NodeComm>,
    row: &WeightRow,
    theta: f64,
    x: &[f64],
    d: &mut [f64],
    mixed: &mut [f64],
    resid: &mut [f64],
    q_own: &[f64],
    peers: &[(usize, Vec<f64>)],
) {
    match comm {
        Some(c) => {
            c.absorb(row, q_own, peers, resid);
            for (di, &r) in d.iter_mut().zip(resid.iter()) {
                *di += theta * r;
            }
        }
        None => {
            row.mix_into(mixed, q_own, peers);
            for ((di, &xi), &wx) in d.iter_mut().zip(x).zip(mixed.iter()) {
                *di += theta * (xi - wx);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// DualGD / LessBit-A
// ---------------------------------------------------------------------------

/// Node half of [`crate::algorithm::DualGd`]: a warm-started inner solve of
/// ∇F*(−Dᵢ) per round, then one X broadcast. A lossy codec switches on the
/// [`NodeComm`] half (LessBit Option A); exact codecs ascend on the raw
/// mix, matching the engine's uncompressed path.
pub struct DualGdNode {
    problem: Arc<dyn Problem>,
    row: WeightRow,
    me: usize,
    theta: f64,
    inner_eta: f64,
    inner_iters: usize,
    inner_tol: f64,
    inner_grad_evals: u64,
    x: Vec<f64>,
    d: Vec<f64>,
    g: Vec<f64>,
    comm: Option<NodeComm>,
    mixed: Vec<f64>,
    resid: Vec<f64>,
}

impl DualGdNode {
    pub fn new(
        problem: Arc<dyn Problem>,
        x0_all: &Mat,
        row: WeightRow,
        theta: f64,
        inner_iters: usize,
        hyper: &NodeHyper,
        wire: &CoordConfig,
    ) -> DualGdNode {
        let me = row.node;
        let p = problem.dim();
        let comm = wire.codec.is_lossy().then(|| NodeComm::new(&row, x0_all, hyper.alpha));
        let inner_eta = 1.0 / problem.smoothness();
        DualGdNode {
            problem,
            row,
            me,
            theta,
            inner_eta,
            inner_iters,
            inner_tol: crate::algorithm::DUALGD_INNER_TOL,
            inner_grad_evals: 0,
            x: x0_all.row(me).to_vec(),
            d: vec![0.0; p],
            g: vec![0.0; p],
            comm,
            mixed: vec![0.0; p],
            resid: vec![0.0; p],
        }
    }
}

impl NodeAlgorithm for DualGdNode {
    fn outgoing(&mut self, out: &mut [f64]) {
        // inner solve: x = argmin f_i(x) + ⟨d, x⟩ — the engine's per-row
        // warm-started gradient loop, verbatim
        let m = self.problem.num_batches() as u64;
        for _ in 0..self.inner_iters {
            self.problem.grad(self.me, &self.x, &mut self.g);
            self.inner_grad_evals += m;
            let mut sq = 0.0;
            for (gj, &dj) in self.g.iter_mut().zip(&self.d) {
                *gj += dj;
                sq += *gj * *gj;
            }
            if sq.sqrt() < self.inner_tol {
                break;
            }
            for (xj, &gj) in self.x.iter_mut().zip(&self.g) {
                *xj -= self.inner_eta * gj;
            }
        }
        match &self.comm {
            Some(c) => c.diff_into(&self.x, out),
            None => out.copy_from_slice(&self.x),
        }
    }

    fn update(&mut self, q_own: &[f64], peers: &[(usize, Vec<f64>)]) {
        dual_ascend(
            &mut self.comm,
            &self.row,
            self.theta,
            &self.x,
            &mut self.d,
            &mut self.mixed,
            &mut self.resid,
            q_own,
            peers,
        );
    }

    fn x(&self) -> &[f64] {
        &self.x
    }

    fn grad_evals(&self) -> u64 {
        self.inner_grad_evals
    }
}

// ---------------------------------------------------------------------------
// PDGM / LessBit-B/C/D
// ---------------------------------------------------------------------------

/// Node half of [`crate::algorithm::Pdgm`]: one primal step per dual
/// ascent. A lossy codec switches on the [`NodeComm`] half (LessBit
/// Options B/C/D depending on the oracle).
pub struct PdgmNode {
    problem: Arc<dyn Problem>,
    row: WeightRow,
    me: usize,
    eta: f64,
    theta: f64,
    oracle: Sgo,
    x: Vec<f64>,
    d: Vec<f64>,
    g: Vec<f64>,
    comm: Option<NodeComm>,
    mixed: Vec<f64>,
    resid: Vec<f64>,
}

impl PdgmNode {
    pub fn new(
        problem: Arc<dyn Problem>,
        x0_all: &Mat,
        row: WeightRow,
        theta: f64,
        hyper: &NodeHyper,
        wire: &CoordConfig,
    ) -> PdgmNode {
        let me = row.node;
        let p = problem.dim();
        let oracle = oracle_for(hyper, wire, problem.as_ref(), me, x0_all.row(me));
        let comm = wire.codec.is_lossy().then(|| NodeComm::new(&row, x0_all, hyper.alpha));
        PdgmNode {
            problem,
            row,
            me,
            eta: hyper.eta,
            theta,
            oracle,
            x: x0_all.row(me).to_vec(),
            d: vec![0.0; p],
            g: vec![0.0; p],
            comm,
            mixed: vec![0.0; p],
            resid: vec![0.0; p],
        }
    }
}

impl NodeAlgorithm for PdgmNode {
    fn outgoing(&mut self, out: &mut [f64]) {
        // primal: X ← X − ηG − ηD (engine: axpy(-η, G); X -= η·D)
        self.oracle.sample(self.problem.as_ref(), self.me, &self.x, &mut self.g);
        for ((x, &gi), &di) in self.x.iter_mut().zip(&self.g).zip(&self.d) {
            *x += -self.eta * gi;
            *x += -1.0 * (di * self.eta);
        }
        match &self.comm {
            Some(c) => c.diff_into(&self.x, out),
            None => out.copy_from_slice(&self.x),
        }
    }

    fn update(&mut self, q_own: &[f64], peers: &[(usize, Vec<f64>)]) {
        dual_ascend(
            &mut self.comm,
            &self.row,
            self.theta,
            &self.x,
            &mut self.d,
            &mut self.mixed,
            &mut self.resid,
            q_own,
            peers,
        );
    }

    fn x(&self) -> &[f64] {
        &self.x
    }

    fn grad_evals(&self) -> u64 {
        self.oracle.grad_evals()
    }
}
