//! Wire format for coordinator messages — real serialized bytes, so the
//! communication-bit numbers come off an actual codec rather than a model.
//!
//! A round message carries one node's compressed COMM payload Qᵢ:
//!
//! ```text
//! [u8 tag][u32 round][u16 from][u32 payload_len][payload…]
//! ```
//!
//! Payload encodings:
//! - tag 0 `DENSE64`: p×8 bytes little-endian f64 (identity compressor);
//! - tag 1 `DENSE32`: p×4 bytes f32 (the "32bit" baselines);
//! - tag 2 `QUANT`: the bit-packed ∞-norm quantizer stream of
//!   [`crate::compress::bits::encode_inf_quantized_into`];
//! - tag 0xFF `ABORT`: empty payload, floods a fatal fault through the
//!   network so neighbors unblock instead of deadlocking on a dead peer.
//!
//! Decoding is deterministic, so the sender-side decoded Qᵢ (needed for
//! its own H update) and every receiver's decode agree bit-exactly — the
//! property the COMM error compensation relies on.
//!
//! # Panic-free pull parsing and caller-provided scratch
//!
//! The receive path is *total*: [`FrameRef::parse`] borrows the raw bytes
//! (no payload copy) and every malformed input — truncated header, short
//! or overlong payload, trailing garbage, unknown tag, corrupt quantizer
//! block — comes back as a typed [`WireError`], never a panic. The send
//! path is allocation-free per round: [`frame_begin`]/[`frame_end`]
//! build the header in a reused buffer, [`WireCodec::encode_into`]
//! appends the payload to it, and [`WireCodec::decode_into`] writes into
//! a reused `&mut [f64]`. The allocating [`WireCodec::encode`] wrapper
//! remains for one-shot call sites (tests, benches).

use crate::compress::bits::{
    decode_inf_quantized_into, encode_inf_quantized, encode_inf_quantized_into, QuantError,
};
use crate::transport::TransportError;
use crate::util::rng::Rng;
use std::fmt;

/// Frame tag announcing a fatal fault; the payload is empty. Nodes that
/// receive it re-flood and exit, so one corrupt frame tears the run down
/// deterministically instead of deadlocking the synchronous barrier.
pub const ABORT_TAG: u8 = 0xFF;

/// Frame tag for a clean goodbye ("no more frames from me"); the payload
/// is empty. Harmless to peers that already hold this sender's frames;
/// fatal to a peer still owed one — which only happens downstream of a
/// fault, where it unblocks the synchronous barrier (see
/// [`super::node`]'s teardown protocol).
pub const BYE_TAG: u8 = 0xFE;

/// A wire fault as reported to the leader: which node detected what, and
/// in which round. Rides inside [`crate::runner::StopReason::WireFault`]
/// so a corrupt frame surfaces as a reported run outcome.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WireFault {
    /// The node that *detected* the fault (not the sender of the bad frame).
    pub node: u16,
    /// The detecting node's wire round (setup rounds included).
    pub round: u32,
    pub error: WireError,
}

impl fmt::Display for WireFault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "node {} at round {}: {}", self.node, self.round, self.error)
    }
}

/// Everything that can go wrong turning received bytes back into a
/// payload vector. `Copy + Eq` so it can ride inside
/// [`crate::runner::StopReason`] without touching that enum's derives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireError {
    /// Fewer than [`Frame::HEADER_LEN`] bytes.
    TruncatedHeader { len: usize },
    /// The header's payload_len promises more bytes than were received.
    TruncatedPayload { need: usize, got: usize },
    /// Bytes beyond the framed length (or spare whole bytes after a
    /// quantizer stream).
    TrailingBytes { expected: usize, got: usize },
    /// A tag no codec in this build understands.
    UnknownTag { tag: u8 },
    /// A valid codec tag, but not the codec this run negotiated.
    TagMismatch { expected: u8, got: u8 },
    /// Dense payload whose byte length does not match the vector length.
    PayloadSize { expected: usize, got: usize },
    /// Quantizer bitstream ran dry mid-block.
    TruncatedBitstream { need_bits: usize, got_bits: usize },
    /// Quantizer block header norm is NaN or negative.
    BadBlockNorm { block: usize },
    /// Frame from a node that is not a neighbor on this edge set.
    NonNeighbor { from: u16 },
    /// Second frame from the same neighbor in one round.
    DuplicateFrame { from: u16, round: u32 },
    /// Frame round outside the one-round skew the synchronous barrier
    /// allows (stale, or more than one round ahead).
    RoundSkew { from: u16, frame_round: u32, expect: u32 },
    /// The byte stream under the frames failed (socket transports only):
    /// EOF mid-run, short read, refused dial, timeout. In-process
    /// channels never produce this variant.
    Transport(TransportError),
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            WireError::TruncatedHeader { len } => {
                write!(f, "truncated header: {len} of {} bytes", Frame::HEADER_LEN)
            }
            WireError::TruncatedPayload { need, got } => {
                write!(f, "truncated payload: header promises {need} bytes, got {got}")
            }
            WireError::TrailingBytes { expected, got } => {
                write!(f, "trailing bytes: expected {expected}, got {got}")
            }
            WireError::UnknownTag { tag } => write!(f, "unknown frame tag {tag:#04x}"),
            WireError::TagMismatch { expected, got } => {
                write!(f, "codec tag mismatch: negotiated {expected}, frame carries {got}")
            }
            WireError::PayloadSize { expected, got } => {
                write!(f, "dense payload size mismatch: expected {expected} bytes, got {got}")
            }
            WireError::TruncatedBitstream { need_bits, got_bits } => {
                write!(f, "quant stream truncated: need {need_bits} bits, have {got_bits}")
            }
            WireError::BadBlockNorm { block } => {
                write!(f, "quant block {block} has a NaN or negative norm")
            }
            WireError::NonNeighbor { from } => write!(f, "frame from non-neighbor node {from}"),
            WireError::DuplicateFrame { from, round } => {
                write!(f, "duplicate frame from node {from} in round {round}")
            }
            WireError::RoundSkew { from, frame_round, expect } => {
                write!(
                    f,
                    "round skew from node {from}: frame round {frame_round}, expected {expect} \
                     (±1 ahead allowed)"
                )
            }
            WireError::Transport(e) => write!(f, "transport: {e}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<QuantError> for WireError {
    fn from(e: QuantError) -> WireError {
        match e {
            QuantError::Truncated { need_bits, have_bits } => {
                WireError::TruncatedBitstream { need_bits, got_bits: have_bits }
            }
            QuantError::BadBlockNorm { block } => WireError::BadBlockNorm { block },
            QuantError::TrailingBytes { used_bytes, got_bytes } => {
                WireError::TrailingBytes { expected: used_bytes, got: got_bytes }
            }
        }
    }
}

/// How a node's payload is put on the wire.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireCodec {
    Dense64,
    Dense32,
    /// ∞-norm quantizer: (bits, block).
    Quant(u32, usize),
}

impl WireCodec {
    /// Encode `x`, appending wire bytes to `out` and writing the decoded
    /// values both sides agree on into `decoded`. Returns the accounted
    /// payload bits. Allocation-free once `out`'s capacity has warmed up.
    pub fn encode_into(
        &self,
        x: &[f64],
        rng: &mut Rng,
        decoded: &mut [f64],
        out: &mut Vec<u8>,
    ) -> u64 {
        debug_assert_eq!(decoded.len(), x.len(), "decoded scratch length mismatch");
        match *self {
            WireCodec::Dense64 => {
                for (&v, d) in x.iter().zip(decoded.iter_mut()) {
                    out.extend_from_slice(&v.to_le_bytes());
                    *d = v;
                }
                64 * x.len() as u64
            }
            WireCodec::Dense32 => {
                for (&v, d) in x.iter().zip(decoded.iter_mut()) {
                    let f = v as f32;
                    out.extend_from_slice(&f.to_le_bytes());
                    *d = f as f64;
                }
                32 * x.len() as u64
            }
            WireCodec::Quant(bits, block) => {
                encode_inf_quantized_into(x, bits, block, rng, decoded, out)
            }
        }
    }

    /// Decode a received payload into `out` (whose length fixes the
    /// expected vector length). Total over arbitrary bytes: malformed
    /// payloads return a [`WireError`]; nothing panics, nothing allocates.
    pub fn decode_into(&self, payload: &[u8], out: &mut [f64]) -> Result<(), WireError> {
        match *self {
            WireCodec::Dense64 => {
                if payload.len() != out.len() * 8 {
                    return Err(WireError::PayloadSize {
                        expected: out.len() * 8,
                        got: payload.len(),
                    });
                }
                for (chunk, slot) in payload.chunks_exact(8).zip(out.iter_mut()) {
                    let mut b = [0u8; 8];
                    b.copy_from_slice(chunk);
                    *slot = f64::from_le_bytes(b);
                }
                Ok(())
            }
            WireCodec::Dense32 => {
                if payload.len() != out.len() * 4 {
                    return Err(WireError::PayloadSize {
                        expected: out.len() * 4,
                        got: payload.len(),
                    });
                }
                for (chunk, slot) in payload.chunks_exact(4).zip(out.iter_mut()) {
                    let mut b = [0u8; 4];
                    b.copy_from_slice(chunk);
                    *slot = f32::from_le_bytes(b) as f64;
                }
                Ok(())
            }
            WireCodec::Quant(bits, block) => {
                decode_inf_quantized_into(payload, bits, block, out).map_err(WireError::from)
            }
        }
    }

    /// Allocating one-shot encode; returns (wire bytes, decoded values
    /// both sides agree on, accounted payload bits).
    pub fn encode(&self, x: &[f64], rng: &mut Rng) -> (Vec<u8>, Vec<f64>, u64) {
        match *self {
            WireCodec::Quant(bits, block) => encode_inf_quantized(x, bits, block, rng),
            _ => {
                let mut bytes = Vec::with_capacity(x.len() * 8);
                let mut decoded = vec![0.0; x.len()];
                let bits = self.encode_into(x, rng, &mut decoded, &mut bytes);
                (bytes, decoded, bits)
            }
        }
    }

    /// Checked one-shot decode (allocating convenience over
    /// [`WireCodec::decode_into`]).
    pub fn decode(&self, payload: &[u8], n: usize) -> Result<Vec<f64>, WireError> {
        let mut out = vec![0.0; n];
        self.decode_into(payload, &mut out)?;
        Ok(out)
    }

    pub fn tag(&self) -> u8 {
        match self {
            WireCodec::Dense64 => 0,
            WireCodec::Dense32 => 1,
            WireCodec::Quant(..) => 2,
        }
    }

    /// Is `tag` any codec this build understands (ABORT excluded)?
    pub fn known_tag(tag: u8) -> bool {
        tag <= 2
    }

    /// Assumption-2 style noise bound (0 for the dense codecs).
    pub fn is_lossy(&self) -> bool {
        matches!(self, WireCodec::Quant(..))
    }

    pub fn name(&self) -> String {
        match self {
            WireCodec::Dense64 => "64bit".into(),
            WireCodec::Dense32 => "32bit".into(),
            WireCodec::Quant(b, _) => format!("{b}bit"),
        }
    }
}

/// Start a frame in a reused buffer: clears it and writes the header with
/// a zero payload_len placeholder. Append the payload (e.g. via
/// [`WireCodec::encode_into`]), then call [`frame_end`] to patch the
/// length. Allocation-free once the buffer's capacity has warmed up.
pub fn frame_begin(out: &mut Vec<u8>, tag: u8, round: u32, from: u16) {
    out.clear();
    out.push(tag);
    out.extend_from_slice(&round.to_le_bytes());
    out.extend_from_slice(&from.to_le_bytes());
    out.extend_from_slice(&0u32.to_le_bytes());
}

/// Patch the payload_len field once the payload has been appended. Total:
/// calling it on a buffer shorter than a header (a misuse `frame_begin`
/// makes impossible) is a debug assertion, and a no-op in release rather
/// than a panic.
pub fn frame_end(out: &mut Vec<u8>) {
    debug_assert!(out.len() >= Frame::HEADER_LEN, "frame_end before frame_begin");
    let len = out.len().saturating_sub(Frame::HEADER_LEN) as u32;
    if let Some(field) = out.get_mut(7..11) {
        field.copy_from_slice(&len.to_le_bytes());
    }
}

/// A parsed frame borrowing the receive buffer — the pull-style view the
/// node hot loop uses; no payload copy, no allocation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FrameRef<'a> {
    pub tag: u8,
    pub round: u32,
    pub from: u16,
    pub payload: &'a [u8],
}

impl<'a> FrameRef<'a> {
    /// Total parse of a received buffer. The buffer must contain exactly
    /// one frame: short buffers, payloads shorter than the header's
    /// payload_len, and trailing garbage are all typed errors.
    pub fn parse(buf: &'a [u8]) -> Result<FrameRef<'a>, WireError> {
        // index-free by construction (lint rule `panic-freedom`): the header
        // is destructured through a refutable slice pattern, the payload
        // through checked `get` — no arithmetic here can panic.
        let Some(header) = buf.get(..Frame::HEADER_LEN) else {
            return Err(WireError::TruncatedHeader { len: buf.len() });
        };
        let &[tag, r0, r1, r2, r3, f0, f1, l0, l1, l2, l3] = header else {
            // `get(..HEADER_LEN)` yielded exactly HEADER_LEN (= 11) bytes
            return Err(WireError::TruncatedHeader { len: buf.len() });
        };
        let round = u32::from_le_bytes([r0, r1, r2, r3]);
        let from = u16::from_le_bytes([f0, f1]);
        let len = u32::from_le_bytes([l0, l1, l2, l3]) as usize;
        let framed = Frame::HEADER_LEN + len;
        if buf.len() > framed {
            return Err(WireError::TrailingBytes { expected: framed, got: buf.len() });
        }
        let Some(payload) = buf.get(Frame::HEADER_LEN..framed) else {
            return Err(WireError::TruncatedPayload { need: framed, got: buf.len() });
        };
        Ok(FrameRef { tag, round, from, payload })
    }
}

/// One framed round message, owned form (tests and frame construction;
/// the hot loop parses with [`FrameRef`] instead).
#[derive(Clone, Debug)]
pub struct Frame {
    pub round: u32,
    pub from: u16,
    pub payload: Vec<u8>,
}

impl Frame {
    /// Framing overhead per message: tag + round + from + payload_len.
    /// The wire-bytes bench charges this against every unicast, which is
    /// why tiny-dimension runs are header-dominated.
    pub const HEADER_LEN: usize = 11;

    /// Serialize header + payload into one buffer (what the socket of a
    /// real deployment would carry).
    pub fn to_bytes(&self, codec: &WireCodec) -> Vec<u8> {
        let mut out = Vec::with_capacity(Frame::HEADER_LEN + self.payload.len());
        frame_begin(&mut out, codec.tag(), self.round, self.from);
        out.extend_from_slice(&self.payload);
        frame_end(&mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_roundtrips_exact() {
        let x = vec![1.5, -2.25, 1e-17, 3e8];
        let mut rng = Rng::new(1);
        let (bytes, decoded, bits) = WireCodec::Dense64.encode(&x, &mut rng);
        assert_eq!(decoded, x);
        assert_eq!(bits, 256);
        assert_eq!(WireCodec::Dense64.decode(&bytes, 4).unwrap(), x);

        let (bytes32, dec32, bits32) = WireCodec::Dense32.encode(&x, &mut rng);
        assert_eq!(bits32, 128);
        assert_eq!(WireCodec::Dense32.decode(&bytes32, 4).unwrap(), dec32);
        assert!((dec32[1] - x[1]).abs() < 1e-6);
    }

    #[test]
    fn quant_sender_receiver_agree() {
        let mut rng = Rng::new(2);
        let x: Vec<f64> = (0..300).map(|_| rng.normal()).collect();
        let codec = WireCodec::Quant(2, 256);
        let (bytes, decoded, _) = codec.encode(&x, &mut rng);
        let recv = codec.decode(&bytes, 300).unwrap();
        assert_eq!(decoded, recv, "sender/receiver decode divergence");
    }

    #[test]
    fn encode_into_matches_one_shot_encode() {
        let mut rng = Rng::new(3);
        let x: Vec<f64> = (0..300).map(|_| rng.normal()).collect();
        for codec in [WireCodec::Dense64, WireCodec::Dense32, WireCodec::Quant(4, 128)] {
            let (bytes_a, dec_a, bits_a) = codec.encode(&x, &mut Rng::new(77));
            let mut bytes_b = Vec::new();
            let mut dec_b = vec![0.0; 300];
            let bits_b = codec.encode_into(&x, &mut Rng::new(77), &mut dec_b, &mut bytes_b);
            assert_eq!(bytes_a, bytes_b, "{codec:?} byte stream");
            assert_eq!(dec_a, dec_b, "{codec:?} decoded");
            assert_eq!(bits_a, bits_b, "{codec:?} accounted bits");
        }
    }

    #[test]
    fn dense_decode_rejects_size_mismatch() {
        let x = vec![1.0; 8];
        let (bytes, _, _) = WireCodec::Dense64.encode(&x, &mut Rng::new(4));
        let mut out = vec![0.0; 8];
        assert!(WireCodec::Dense64.decode_into(&bytes, &mut out).is_ok());
        assert_eq!(
            WireCodec::Dense64.decode_into(&bytes[..63], &mut out),
            Err(WireError::PayloadSize { expected: 64, got: 63 })
        );
        let mut long = bytes.clone();
        long.push(0);
        assert_eq!(
            WireCodec::Dense64.decode_into(&long, &mut out),
            Err(WireError::PayloadSize { expected: 64, got: 65 })
        );
    }

    #[test]
    fn frame_roundtrip() {
        let codec = WireCodec::Quant(2, 256);
        let f = Frame { round: 77, from: 3, payload: vec![1, 2, 3, 4, 5] };
        let bytes = f.to_bytes(&codec);
        let g = FrameRef::parse(&bytes).unwrap();
        assert_eq!(g.tag, 2);
        assert_eq!(g.round, 77);
        assert_eq!(g.from, 3);
        assert_eq!(g.payload, &f.payload[..]);
    }

    #[test]
    fn frame_begin_end_reuses_buffer() {
        let mut buf = Vec::new();
        for round in 0..3u32 {
            frame_begin(&mut buf, 1, round, 9);
            buf.extend_from_slice(&[0xAA; 12]);
            frame_end(&mut buf);
            let f = FrameRef::parse(&buf).unwrap();
            assert_eq!((f.tag, f.round, f.from), (1, round, 9));
            assert_eq!(f.payload, &[0xAA; 12]);
        }
    }

    #[test]
    fn parse_rejects_malformed_buffers() {
        let f = Frame { round: 1, from: 0, payload: vec![9; 100] };
        let bytes = f.to_bytes(&WireCodec::Dense64);
        assert_eq!(
            FrameRef::parse(&bytes[..10]),
            Err(WireError::TruncatedHeader { len: 10 })
        );
        assert_eq!(
            FrameRef::parse(&bytes[..50]),
            Err(WireError::TruncatedPayload { need: 111, got: 50 })
        );
        let mut garbage = bytes.clone();
        garbage.extend_from_slice(&[1, 2, 3]);
        assert_eq!(
            FrameRef::parse(&garbage),
            Err(WireError::TrailingBytes { expected: 111, got: 114 })
        );
        assert_eq!(FrameRef::parse(&[]), Err(WireError::TruncatedHeader { len: 0 }));
    }

    #[test]
    fn wire_error_display_is_informative() {
        let e = WireError::RoundSkew { from: 3, frame_round: 9, expect: 4 };
        let s = format!("{e}");
        assert!(s.contains("node 3") && s.contains('9') && s.contains('4'), "{s}");
    }
}
