//! Wire format for coordinator messages — real serialized bytes, so the
//! communication-bit numbers come off an actual codec rather than a model.
//!
//! A round message carries one node's compressed COMM payload Qᵢ:
//!
//! ```text
//! [u8 tag][u32 round][u16 from][u32 payload_len][payload…]
//! ```
//!
//! Payload encodings:
//! - tag 0 `DENSE64`: p×8 bytes little-endian f64 (identity compressor);
//! - tag 1 `DENSE32`: p×4 bytes f32 (the "32bit" baselines);
//! - tag 2 `QUANT`: the bit-packed ∞-norm quantizer stream of
//!   [`crate::compress::bits::encode_inf_quantized`].
//!
//! Decoding is deterministic, so the sender-side decoded Qᵢ (needed for
//! its own H update) and every receiver's decode agree bit-exactly — the
//! property the COMM error compensation relies on.

use crate::compress::bits::{decode_inf_quantized, encode_inf_quantized};
use crate::util::rng::Rng;

/// How a node's payload is put on the wire.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireCodec {
    Dense64,
    Dense32,
    /// ∞-norm quantizer: (bits, block).
    Quant(u32, usize),
}

impl WireCodec {
    /// Encode `x`; returns (wire bytes, decoded values both sides agree
    /// on, accounted payload bits).
    pub fn encode(&self, x: &[f64], rng: &mut Rng) -> (Vec<u8>, Vec<f64>, u64) {
        match *self {
            WireCodec::Dense64 => {
                let mut bytes = Vec::with_capacity(x.len() * 8);
                for &v in x {
                    bytes.extend_from_slice(&v.to_le_bytes());
                }
                (bytes, x.to_vec(), 64 * x.len() as u64)
            }
            WireCodec::Dense32 => {
                let mut bytes = Vec::with_capacity(x.len() * 4);
                let mut decoded = Vec::with_capacity(x.len());
                for &v in x {
                    let f = v as f32;
                    bytes.extend_from_slice(&f.to_le_bytes());
                    decoded.push(f as f64);
                }
                (bytes, decoded, 32 * x.len() as u64)
            }
            WireCodec::Quant(bits, block) => encode_inf_quantized(x, bits, block, rng),
        }
    }

    pub fn decode(&self, bytes: &[u8], n: usize) -> Vec<f64> {
        match *self {
            WireCodec::Dense64 => bytes
                .chunks_exact(8)
                .take(n)
                .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                .collect(),
            WireCodec::Dense32 => bytes
                .chunks_exact(4)
                .take(n)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()) as f64)
                .collect(),
            WireCodec::Quant(bits, block) => decode_inf_quantized(bytes, n, bits, block),
        }
    }

    fn tag(&self) -> u8 {
        match self {
            WireCodec::Dense64 => 0,
            WireCodec::Dense32 => 1,
            WireCodec::Quant(..) => 2,
        }
    }

    /// Assumption-2 style noise bound (0 for the dense codecs).
    pub fn is_lossy(&self) -> bool {
        matches!(self, WireCodec::Quant(..))
    }

    pub fn name(&self) -> String {
        match self {
            WireCodec::Dense64 => "64bit".into(),
            WireCodec::Dense32 => "32bit".into(),
            WireCodec::Quant(b, _) => format!("{b}bit"),
        }
    }
}

/// One framed round message.
#[derive(Clone, Debug)]
pub struct Frame {
    pub round: u32,
    pub from: u16,
    pub payload: Vec<u8>,
}

impl Frame {
    /// Framing overhead per message: tag + round + from + payload_len.
    /// The wire-bytes bench charges this against every unicast, which is
    /// why tiny-dimension runs are header-dominated.
    pub const HEADER_LEN: usize = 11;

    /// Serialize header + payload into one buffer (what the socket of a
    /// real deployment would carry).
    pub fn to_bytes(&self, codec: &WireCodec) -> Vec<u8> {
        let mut out = Vec::with_capacity(Frame::HEADER_LEN + self.payload.len());
        out.push(codec.tag());
        out.extend_from_slice(&self.round.to_le_bytes());
        out.extend_from_slice(&self.from.to_le_bytes());
        out.extend_from_slice(&(self.payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.payload);
        out
    }

    pub fn from_bytes(buf: &[u8]) -> Option<(u8, Frame)> {
        if buf.len() < Frame::HEADER_LEN {
            return None;
        }
        let tag = buf[0];
        let round = u32::from_le_bytes(buf[1..5].try_into().ok()?);
        let from = u16::from_le_bytes(buf[5..7].try_into().ok()?);
        let len = u32::from_le_bytes(buf[7..11].try_into().ok()?) as usize;
        if buf.len() < Frame::HEADER_LEN + len {
            return None;
        }
        let payload = buf[Frame::HEADER_LEN..Frame::HEADER_LEN + len].to_vec();
        Some((tag, Frame { round, from, payload }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_roundtrips_exact() {
        let x = vec![1.5, -2.25, 1e-17, 3e8];
        let mut rng = Rng::new(1);
        let (bytes, decoded, bits) = WireCodec::Dense64.encode(&x, &mut rng);
        assert_eq!(decoded, x);
        assert_eq!(bits, 256);
        assert_eq!(WireCodec::Dense64.decode(&bytes, 4), x);

        let (bytes32, dec32, bits32) = WireCodec::Dense32.encode(&x, &mut rng);
        assert_eq!(bits32, 128);
        assert_eq!(WireCodec::Dense32.decode(&bytes32, 4), dec32);
        assert!((dec32[1] - x[1]).abs() < 1e-6);
    }

    #[test]
    fn quant_sender_receiver_agree() {
        let mut rng = Rng::new(2);
        let x: Vec<f64> = (0..300).map(|_| rng.normal()).collect();
        let codec = WireCodec::Quant(2, 256);
        let (bytes, decoded, _) = codec.encode(&x, &mut rng);
        let recv = codec.decode(&bytes, 300);
        assert_eq!(decoded, recv, "sender/receiver decode divergence");
    }

    #[test]
    fn frame_roundtrip() {
        let codec = WireCodec::Quant(2, 256);
        let f = Frame { round: 77, from: 3, payload: vec![1, 2, 3, 4, 5] };
        let bytes = f.to_bytes(&codec);
        let (tag, g) = Frame::from_bytes(&bytes).unwrap();
        assert_eq!(tag, 2);
        assert_eq!(g.round, 77);
        assert_eq!(g.from, 3);
        assert_eq!(g.payload, f.payload);
    }

    #[test]
    fn frame_rejects_truncation() {
        let f = Frame { round: 1, from: 0, payload: vec![9; 100] };
        let bytes = f.to_bytes(&WireCodec::Dense64);
        assert!(Frame::from_bytes(&bytes[..10]).is_none());
        assert!(Frame::from_bytes(&bytes[..50]).is_none());
    }
}
