//! The algorithm-generic node runtime: one thread per node, synchronous
//! rounds over serialized frames.
//!
//! A [`NodeAlgorithm`] is one node's half of a decentralized algorithm —
//! the per-row arithmetic the matrix engine performs on row i, re-expressed
//! over local state. The driver [`run_node`] owns everything that is *not*
//! algorithm arithmetic: wire encoding/decoding, frame transport, the
//! synchronous-round barrier, straggler injection, metric reporting, and
//! the leader's early-stop protocol. Per round it
//!
//! 1. asks the algorithm for its broadcast vector ([`NodeAlgorithm::outgoing`]),
//! 2. encodes it with the wire codec and unicasts the frame to every
//!    gossip neighbor,
//! 3. gathers exactly one frame per neighbor for the current round
//!    (buffering ahead-of-round frames from fast neighbors), decodes them,
//!    and
//! 4. hands the decoded round back ([`NodeAlgorithm::update`]).
//!
//! **Bit-exactness.** The engine's gossip is a W·X product whose kernels
//! (dense blocked ikj and CSR SpMM) accumulate each output entry over
//! ascending column index, skipping zero weights. [`WeightRow::mix_into`]
//! reproduces exactly that order — neighbors ascending with the diagonal
//! spliced at j = node — so a node-thread round is bit-identical to the
//! engine's row arithmetic whenever the codec round-trips exactly
//! (`Dense64`). Frames are therefore collected into per-neighbor slots
//! *before* mixing; arrival order never touches the arithmetic.
//!
//! **Zero-alloc hot path.** All per-round buffers — the outgoing payload,
//! the frame build buffer, the decoded own-payload, the per-neighbor
//! decode slots, the ahead-of-round stash — are allocated once before the
//! round loop and reused; encode/decode run through the scratch APIs
//! ([`super::wire::WireCodec::encode_into`]/`decode_into`,
//! [`super::wire::FrameRef::parse`]). The only per-round allocation is the
//! single refcounted transport buffer (`Arc<[u8]>`) the channel handoff
//! requires — one per broadcast, not one per neighbor.
//!
//! **Panic-free receive path + teardown protocol.** A malformed frame is
//! detected as a typed [`WireError`] (never a panic), reported to the
//! leader as a [`WireFault`], and followed by an `ABORT` flood so every
//! neighbor blocked on the synchronous barrier unblocks instead of
//! deadlocking on a dead peer; receivers of `ABORT` re-flood and exit, so
//! the teardown wave covers any connected graph. Clean exits (round budget
//! done, leader stop verdict) flood `BYE` — "no more frames from me" —
//! which is harmless to a peer that already holds this node's frames but
//! fatal (teardown, no fault report) to one that still *needs* a frame
//! this sender can no longer send; that situation only arises downstream
//! of a fault, where the leader releases checkpoint-blocked nodes early.
//!
//! The synchronous-round barrier bounds skew to exactly one round: a
//! neighbor can only start round k+1 after receiving our round-k frame,
//! and can therefore send us nothing beyond round k+1 while we still
//! gather round k. A single reused one-round-ahead stash replaces any
//! general future-frame map; a frame two or more rounds ahead (or stale)
//! is a protocol violation reported as [`WireError::RoundSkew`].
//!
//! **Early stop (leader gating).** When the run's
//! [`crate::runner::StopSet`] carries a criterion the leader must observe
//! (target suboptimality, bits/grad-evals budget, deadline), every node
//! blocks after each `record_every`-checkpoint report until the leader
//! broadcasts continue-or-stop over the per-node control channel. All
//! nodes checkpoint at the same steps, so they all receive the same
//! decision and a stopped run ends on the same round network-wide — which
//! is what makes budget stops deterministic and bit-comparable to the
//! engine. Between checkpoints nodes free-run exactly as in the ungated
//! case.

use super::wire::{self, Frame, FrameRef, WireCodec, WireError, WireFault, ABORT_TAG, BYE_TAG};
use super::{CoordConfig, NodeEvent, NodeReport, TamperKind};
use crate::graph::MixingOp;
use crate::linalg::{vaxpy, Mat};
use crate::transport::NodeLink;
use crate::util::rng::Rng;
use std::sync::Arc;

/// One node's half of a decentralized algorithm (see the module docs).
/// Implementations live in [`super::algorithms`]; the name-dispatching
/// factory is `exp::registry::build_node_algorithm`.
pub trait NodeAlgorithm: Send {
    /// Wire exchanges that happen *before* step counting starts (P2D2's
    /// init round mixes W̃(X⁰ − η∇F(X⁰)), which the matrix engine performs
    /// at construction). Default: none.
    fn setup_rounds(&self) -> usize {
        0
    }

    /// Compute this round's broadcast vector into `out` (length p). Local
    /// gradient work happens here.
    fn outgoing(&mut self, out: &mut [f64]);

    /// Consume the round: `q_own` is the node's own payload as both wire
    /// endpoints decode it, `peers` the decoded neighbor payloads aligned
    /// with the gossip row (ascending neighbor id).
    fn update(&mut self, q_own: &[f64], peers: &[(usize, Vec<f64>)]);

    /// The node's current iterate xᵢ.
    fn x(&self) -> &[f64];

    /// Cumulative batch-gradient evaluations (including any VR/init cost).
    fn grad_evals(&self) -> u64;
}

/// Node i's row of a mixing operator: the self weight plus the (j, w_ij)
/// gossip neighbors, ascending j — the structure the per-edge channels and
/// every node-side mix are derived from.
#[derive(Clone, Debug)]
pub struct WeightRow {
    pub node: usize,
    pub self_weight: f64,
    /// (neighbor id, w_ij), ascending id, zero weights excluded.
    pub neighbors: Vec<(usize, f64)>,
}

impl WeightRow {
    /// Extract row `node` from the mixing operator (one CSR row walk on
    /// sparse graphs).
    pub fn from_op(w: &MixingOp, node: usize) -> WeightRow {
        WeightRow { node, self_weight: w.self_weight(node), neighbors: w.neighbors(node) }
    }

    /// The W̃ = (I + W)/2 row, with the same f64 operations as
    /// [`MixingOp::half_lazy`] (scale by 0.5, then +0.5 on the diagonal) so
    /// NIDS / PG-EXTRA / P2D2 node mixes stay bit-identical to the engine.
    pub fn half_lazy(&self) -> WeightRow {
        WeightRow {
            node: self.node,
            self_weight: self.self_weight * 0.5 + 0.5,
            neighbors: self.neighbors.iter().map(|&(j, w)| (j, w * 0.5)).collect(),
        }
    }

    /// The W − I row (Choco's consensus correction), mirroring
    /// [`MixingOp::minus_identity`].
    pub fn minus_identity(&self) -> WeightRow {
        WeightRow {
            node: self.node,
            self_weight: self.self_weight - 1.0,
            neighbors: self.neighbors.clone(),
        }
    }

    /// out ← Σⱼ w_ij·vⱼ with `own` at j = node and `peers[k]` at the k-th
    /// gossip neighbor. Accumulates in ascending-j order and skips zero
    /// weights — the exact summation order of the engine's matmul/SpMM
    /// kernels, which makes node mixes bit-identical to W·X rows.
    pub fn mix_into(&self, out: &mut [f64], own: &[f64], peers: &[(usize, Vec<f64>)]) {
        debug_assert_eq!(peers.len(), self.neighbors.len());
        debug_assert!(
            peers.iter().zip(&self.neighbors).all(|((pj, _), &(j, _))| *pj == j),
            "peer slots misaligned with the gossip row"
        );
        self.mix_with(out, own, |k| peers[k].1.as_slice());
    }

    /// [`WeightRow::mix_into`] over the rows of a shared matrix (init-time
    /// products every node can compute locally, e.g. W·X⁰ from the common
    /// start iterate).
    pub fn mix_rows_into(&self, out: &mut [f64], x: &Mat) {
        self.mix_with(out, x.row(self.node), |k| x.row(self.neighbors[k].0));
    }

    /// The one copy of the order-sensitive accumulation loop both mixes
    /// share: diagonal spliced before the first neighbor with j > node,
    /// ascending j throughout, zero weights skipped. The axpy itself is
    /// the shared chunked kernel ([`crate::linalg::vaxpy`]) the engine's
    /// matmul/SpMM inner loops also run — same per-element order, so the
    /// bit-exactness contract survives the vectorization-friendly shape.
    fn mix_with<'a>(&self, out: &mut [f64], own: &[f64], peer: impl Fn(usize) -> &'a [f64]) {
        out.iter_mut().for_each(|o| *o = 0.0);
        let mut placed = false;
        for (k, &(j, wij)) in self.neighbors.iter().enumerate() {
            if !placed && self.node < j {
                acc(out, self.self_weight, own);
                placed = true;
            }
            acc(out, wij, peer(k));
        }
        if !placed {
            acc(out, self.self_weight, own);
        }
    }
}

/// out += w·v, skipping zero weights exactly like the engine kernels do.
#[inline]
fn acc(out: &mut [f64], w: f64, v: &[f64]) {
    if w == 0.0 {
        return;
    }
    vaxpy(out, w, v);
}

/// Everything a node thread needs besides its algorithm half.
pub struct NodeConfig {
    pub id: usize,
    /// Gossip neighbor ids, ascending — aligned with the algorithm's
    /// [`WeightRow`].
    pub neighbors: Vec<usize>,
    /// The node's view of the network: in-process channels or a socket to
    /// the leader ([`crate::transport`]). Carries broadcast, receive, the
    /// report uplink, and the leader's checkpoint verdicts.
    pub link: Box<dyn NodeLink>,
    /// Wire-level knobs: codec, straggler model, RNG seed, tamper.
    pub wire: CoordConfig,
    /// Counted algorithm rounds (setup rounds excluded).
    pub rounds: usize,
    /// Report (and, when gated, checkpoint) every this many rounds.
    pub record_every: usize,
    /// Parameter dimension p (frame payloads decode to this length).
    pub dim: usize,
}

/// Outcome of absorbing one received buffer into the current round.
enum Gather {
    /// Decoded into its neighbor slot for round k.
    Consumed,
    /// A round-(k+1) frame from a fast neighbor, stashed for next round.
    Ahead,
    /// Fault-teardown flood: re-flood and exit.
    Abort,
    /// Clean goodbye from `slot`: fatal only if that neighbor's frame is
    /// still owed this round (or any later round).
    Bye(usize),
}

/// Parse + validate + decode one received buffer. Total: every malformed
/// or protocol-violating input comes back as `Err(WireError)`.
fn absorb(
    raw: Arc<[u8]>,
    k: u32,
    expected_tag: u8,
    codec: &WireCodec,
    peers: &mut [(usize, Vec<f64>)],
    filled: &mut [bool],
    ahead_next: &mut Vec<Arc<[u8]>>,
) -> Result<Gather, WireError> {
    let f = FrameRef::parse(&raw)?;
    let (tag, round, from) = (f.tag, f.round, f.from);
    if tag == ABORT_TAG {
        return Ok(Gather::Abort);
    }
    let slot = match peers.binary_search_by_key(&(from as usize), |&(j, _)| j) {
        Ok(s) => s,
        Err(_) => return Err(WireError::NonNeighbor { from }),
    };
    if tag == BYE_TAG {
        return Ok(Gather::Bye(slot));
    }
    if tag != expected_tag {
        return Err(if WireCodec::known_tag(tag) {
            WireError::TagMismatch { expected: expected_tag, got: tag }
        } else {
            WireError::UnknownTag { tag }
        });
    }
    if round != k {
        // the synchronous barrier bounds honest skew to exactly +1 (a
        // neighbor needs OUR round-k frame to get past round k)
        if round == k + 1 {
            ahead_next.push(raw);
            return Ok(Gather::Ahead);
        }
        return Err(WireError::RoundSkew { from, frame_round: round, expect: k });
    }
    // `slot` came from binary_search over these same slices, so the lookups
    // cannot miss; decode-path code still never bare-indexes (lint rule
    // `panic-freedom`), so a miss degrades to a typed error, not a panic.
    let (Some(was_filled), Some((_, slot_buf))) = (filled.get_mut(slot), peers.get_mut(slot))
    else {
        return Err(WireError::NonNeighbor { from });
    };
    if *was_filled {
        return Err(WireError::DuplicateFrame { from, round: k });
    }
    codec.decode_into(f.payload, slot_buf)?;
    *was_filled = true;
    Ok(Gather::Consumed)
}

/// Flood a payload-less control frame (ABORT or BYE) to every neighbor.
/// Send failures mean the peer already exited — ignored by design (the
/// link's broadcast still *attempts* every neighbor past a dead one).
fn flood(link: &mut dyn NodeLink, tag: u8, round: u32, me: u16) {
    let mut buf = Vec::with_capacity(Frame::HEADER_LEN);
    wire::frame_begin(&mut buf, tag, round, me);
    wire::frame_end(&mut buf);
    let buf: Arc<[u8]> = Arc::from(buf.as_slice());
    let _ = link.broadcast(&buf);
}

/// Fault teardown: flood ABORT, report the typed fault to the leader.
fn fault(link: &mut dyn NodeLink, e: WireError, k: usize, me: u16) {
    flood(link, ABORT_TAG, k as u32, me);
    let _ = link.report(NodeEvent::Fault(WireFault { node: me, round: k as u32, error: e }));
}

/// Corrupt an outgoing frame buffer in a prescribed way (test/chaos hook;
/// see [`super::FrameTamper`]). Shared with the sim backend, which applies
/// the tamper at the broadcast site (`crate::sim`).
pub(crate) fn apply_tamper(buf: &mut Vec<u8>, kind: TamperKind) {
    match kind {
        TamperKind::TruncateHeader => buf.truncate(6),
        TamperKind::ShortPayload => {
            buf.pop();
        }
        TamperKind::OverlongPayload => {
            buf.extend_from_slice(&[0u8; 8]);
            wire::frame_end(buf); // re-patch: header now claims the extra bytes
        }
        TamperKind::TrailingGarbage => buf.extend_from_slice(&[0xDE, 0xAD]),
        TamperKind::UnknownTag => buf[0] = 0x7E,
        TamperKind::WrongCodecTag => buf[0] = if buf[0] == 0 { 1 } else { 0 },
        TamperKind::BadQuantNorm => {
            buf[Frame::HEADER_LEN..Frame::HEADER_LEN + 4]
                .copy_from_slice(&f32::NAN.to_bits().to_be_bytes());
        }
    }
}

/// Drive one node's algorithm through `setup + rounds` wire exchanges.
///
/// Reporting follows the engine's record rule: a report at round 0 (the
/// post-init state, after any setup exchanges — mirroring the engine's
/// round-0 sample), at every `record_every`-th step, AND always at step
/// `rounds`, so leader totals (wire bytes, payload bits, grad evals)
/// cover the whole run even when `rounds % record_every != 0`.
pub fn run_node(mut alg: Box<dyn NodeAlgorithm>, nc: NodeConfig) {
    let me = nc.id;
    let p = nc.dim;
    let wire_cfg = &nc.wire;
    let mut link = nc.link;
    // deterministic per-node streams: compression dither + straggler coin
    let mut comp_rng = Rng::new(wire_cfg.seed).fork(me as u64);
    let mut fault_rng = Rng::new(wire_cfg.seed ^ 0x5747_4C52).fork(me as u64);

    let setup = alg.setup_rounds();
    let total = setup + nc.rounds;
    let deg = nc.neighbors.len();
    let expected_tag = wire_cfg.codec.tag();

    // round-persistent scratch — allocated once, reused every round
    let mut payload = vec![0.0; p];
    let mut q_own = vec![0.0; p];
    let mut frame_buf: Vec<u8> = Vec::with_capacity(Frame::HEADER_LEN + p * 8 + 8);
    let mut peers: Vec<(usize, Vec<f64>)> =
        nc.neighbors.iter().map(|&j| (j, vec![0.0; p])).collect();
    let mut filled = vec![false; deg];
    let mut departed = vec![false; deg];
    // raw round-(k+1) buffers from fast neighbors; swapped each round
    let mut ahead: Vec<Arc<[u8]>> = Vec::with_capacity(deg);
    let mut ahead_next: Vec<Arc<[u8]>> = Vec::with_capacity(deg);
    let (mut bytes_sent, mut payload_bits) = (0u64, 0u64);

    for k in 0..total {
        if k == setup {
            // round-0 report: the post-initialization state (engine: the
            // sample taken before the first step). Setup-round wire costs
            // (P2D2's init exchange) are already in the counters.
            let sent = link.report(NodeEvent::Report(NodeReport {
                node: me,
                round: 0,
                x: alg.x().to_vec(),
                bytes_sent,
                payload_bits,
                grad_evals: alg.grad_evals(),
            }));
            if sent.is_err() {
                return;
            }
        }
        alg.outgoing(&mut payload);
        wire::frame_begin(&mut frame_buf, expected_tag, k as u32, me as u16);
        let bits = wire_cfg.codec.encode_into(&payload, &mut comp_rng, &mut q_own, &mut frame_buf);
        wire::frame_end(&mut frame_buf);
        payload_bits += bits;
        if let Some(t) = wire_cfg.tamper {
            if t.node == me && t.round == k {
                apply_tamper(&mut frame_buf, t.kind);
            }
        }
        // straggler coins: one per gossip edge, drawn in ascending-neighbor
        // order — the same fault_rng consumption as the historical per-edge
        // send loop, so seeded runs stay comparable across transports
        if let Some(s) = wire_cfg.straggler {
            for _ in 0..deg {
                if fault_rng.bernoulli(s.prob) {
                    std::thread::sleep(s.delay);
                }
            }
        }
        // one refcounted buffer for the whole broadcast — the round's only
        // allocation (the transport handoff needs ownership). Wire bytes
        // count per gossip edge regardless of how the transport moves them
        // (the socket hub relays one upstream copy along each edge).
        let buf: Arc<[u8]> = Arc::from(frame_buf.as_slice());
        bytes_sent += (buf.len() * deg) as u64;
        if link.broadcast(&buf).is_err() {
            // peer gone mid-run: only happens downstream of a fault or an
            // early leader release — join the teardown wave
            flood(&mut *link, ABORT_TAG, k as u32, me as u16);
            return;
        }

        // barrier: exactly one frame per neighbor, slotted by sender id so
        // arrival order never reaches the arithmetic
        filled.iter_mut().for_each(|f| *f = false);
        let mut got = 0usize;
        std::mem::swap(&mut ahead, &mut ahead_next);
        for raw in ahead.drain(..) {
            match absorb(raw, k as u32, expected_tag, &wire_cfg.codec, &mut peers, &mut filled, &mut ahead_next)
            {
                Ok(Gather::Consumed) => got += 1,
                Ok(Gather::Ahead) => {}
                Ok(Gather::Bye(slot)) => departed[slot] = true,
                Ok(Gather::Abort) => {
                    flood(&mut *link, ABORT_TAG, k as u32, me as u16);
                    return;
                }
                Err(e) => {
                    fault(&mut *link, e, k, me as u16);
                    return;
                }
            }
        }
        while got < deg {
            // a departed neighbor can never fill its owed slot — tear down
            // instead of blocking forever
            if filled.iter().zip(&departed).any(|(&f, &d)| d && !f) {
                flood(&mut *link, ABORT_TAG, k as u32, me as u16);
                return;
            }
            let raw = match link.recv() {
                Ok(r) => r,
                // link gone without a goodbye (every in-process sender
                // dropped, or the socket died): fault teardown already in
                // flight elsewhere
                Err(_) => return,
            };
            match absorb(raw, k as u32, expected_tag, &wire_cfg.codec, &mut peers, &mut filled, &mut ahead_next)
            {
                Ok(Gather::Consumed) => got += 1,
                Ok(Gather::Ahead) => {}
                Ok(Gather::Bye(slot)) => departed[slot] = true,
                Ok(Gather::Abort) => {
                    flood(&mut *link, ABORT_TAG, k as u32, me as u16);
                    return;
                }
                Err(e) => {
                    fault(&mut *link, e, k, me as u16);
                    return;
                }
            }
        }

        alg.update(&q_own, &peers);

        if k >= setup {
            let step = k - setup + 1;
            if step % nc.record_every == 0 || step == nc.rounds {
                let sent = link.report(NodeEvent::Report(NodeReport {
                    node: me,
                    round: step,
                    x: alg.x().to_vec(),
                    bytes_sent,
                    payload_bits,
                    grad_evals: alg.grad_evals(),
                }));
                if sent.is_err() {
                    return;
                }
            }
            // checkpoint gate: wait for the leader's continue/stop verdict
            // (sent for every flushed multiple of record_every before the
            // final round — the same set of steps on every node, so a stop
            // lands network-wide on one round). Ungated links answer an
            // immediate `continue`, matching the historical no-channel case.
            if step % nc.record_every == 0 && step < nc.rounds {
                if !link.verdict().unwrap_or(false) {
                    break;
                }
            }
        }
    }
    // clean exit: tell the neighborhood no more frames are coming (harmless
    // when everyone stops at the same round; unblocks stragglers when the
    // leader released this node early after a fault)
    flood(&mut *link, BYE_TAG, total as u32, me as u16);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Graph, MixingRule};

    #[test]
    fn weight_row_mix_matches_matmul_bitwise() {
        // the contract everything rests on: a WeightRow mix reproduces the
        // engine's W·X row bit for bit, through both representations and
        // all three derived operators
        let g = Graph::grid(16);
        let mut rng = Rng::new(5);
        let mut x = Mat::zeros(16, 7);
        rng.fill_normal(&mut x.data);
        for op in [
            MixingOp::dense_from(&g, MixingRule::Metropolis),
            MixingOp::sparse_from(&g, MixingRule::Metropolis),
        ] {
            for derived in ["w", "half_lazy", "minus_identity"] {
                let full_op = match derived {
                    "w" => op.clone(),
                    "half_lazy" => op.half_lazy(),
                    _ => op.minus_identity(),
                };
                let expect = full_op.apply(&x);
                for i in 0..16 {
                    let base = WeightRow::from_op(&op, i);
                    let row = match derived {
                        "w" => base.clone(),
                        "half_lazy" => base.half_lazy(),
                        _ => base.minus_identity(),
                    };
                    let peers: Vec<(usize, Vec<f64>)> =
                        row.neighbors.iter().map(|&(j, _)| (j, x.row(j).to_vec())).collect();
                    let mut out = vec![0.0; 7];
                    row.mix_into(&mut out, x.row(i), &peers);
                    for (a, b) in out.iter().zip(expect.row(i)) {
                        assert_eq!(a.to_bits(), b.to_bits(), "{derived}: node {i}");
                    }
                    let mut out2 = vec![0.0; 7];
                    row.mix_rows_into(&mut out2, &x);
                    assert_eq!(out, out2, "{derived}: matrix-form mix differs at node {i}");
                }
            }
        }
    }

    #[test]
    fn weight_row_derivations_match_operator_entries() {
        let g = Graph::ring(8);
        let op = MixingOp::dense_from(&g, MixingRule::UniformMaxDegree);
        let row = WeightRow::from_op(&op, 3);
        assert_eq!(row.self_weight, op.self_weight(3));
        assert_eq!(row.neighbors, op.neighbors(3));
        let lazy = row.half_lazy();
        let wl = op.half_lazy();
        assert_eq!(lazy.self_weight.to_bits(), wl.self_weight(3).to_bits());
        assert_eq!(lazy.neighbors, wl.neighbors(3));
        let mi = row.minus_identity();
        assert_eq!(mi.self_weight.to_bits(), op.minus_identity().self_weight(3).to_bits());
    }

    #[test]
    fn absorb_rejects_protocol_violations() {
        let codec = WireCodec::Dense64;
        let mk = |round: u32, from: u16, payload: Vec<u8>| -> Arc<[u8]> {
            let f = Frame { round, from, payload };
            Arc::from(f.to_bytes(&codec).as_slice())
        };
        let p = 3usize;
        let good = vec![0u8; p * 8];
        let mut peers = vec![(1usize, vec![0.0; p]), (4usize, vec![0.0; p])];
        let mut filled = vec![false; 2];
        let mut ahead = Vec::new();
        let k = 5u32;
        macro_rules! run {
            ($raw:expr) => {
                absorb($raw, k, codec.tag(), &codec, &mut peers, &mut filled, &mut ahead)
                    .map(|_| ())
            };
        }
        // non-neighbor sender
        assert_eq!(run!(mk(k, 2, good.clone())), Err(WireError::NonNeighbor { from: 2 }));
        // stale and too-far-ahead rounds
        assert_eq!(
            run!(mk(k - 1, 1, good.clone())),
            Err(WireError::RoundSkew { from: 1, frame_round: k - 1, expect: k })
        );
        assert_eq!(
            run!(mk(k + 2, 1, good.clone())),
            Err(WireError::RoundSkew { from: 1, frame_round: k + 2, expect: k })
        );
        // duplicate after a good frame
        assert!(run!(mk(k, 1, good.clone())).is_ok());
        assert_eq!(
            run!(mk(k, 1, good.clone())),
            Err(WireError::DuplicateFrame { from: 1, round: k })
        );
        // one round ahead is buffered, not an error
        assert!(run!(mk(k + 1, 4, good.clone())).is_ok());
        assert_eq!(ahead.len(), 1);
        // short dense payload surfaces the codec error
        assert_eq!(
            run!(mk(k, 4, vec![0u8; p * 8 - 1])),
            Err(WireError::PayloadSize { expected: p * 8, got: p * 8 - 1 })
        );
    }
}
