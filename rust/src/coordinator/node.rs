//! Per-node Prox-LEAD state machine, run on its own thread.
//!
//! Vector form of Algorithm 1: the node holds (x, d, h, h_w), draws from
//! its own single-node SGO, compresses z − h with the wire codec,
//! broadcasts the frame to its neighbors, and combines their frames into
//! the mixed estimate ẑ_w = h_w + Σⱼ w_ij q_j. The synchronous-round
//! barrier: the node blocks until it holds one frame from every neighbor
//! for the current round. A fast neighbor may already have sent its
//! round-(k+1) frame while this node still collects round k (it only
//! needed OUR round-k frame to advance, not our slow neighbor's), so
//! ahead-of-round frames are buffered; behind-round frames indicate a
//! protocol violation and panic.

use super::wire::Frame;
use super::{CoordConfig, NodeReport};
use crate::linalg::matrix::vaxpy;
use crate::linalg::Mat;
use crate::oracle::Sgo;
use crate::problem::Problem;
use crate::prox::Prox;
use crate::util::rng::Rng;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

pub struct NodeConfig {
    pub id: usize,
    pub self_weight: f64,
    /// (neighbor id, w_ij, sender into the neighbor's inbox).
    pub neighbors: Vec<(usize, f64, Sender<Vec<u8>>)>,
    pub inbox: Receiver<Vec<u8>>,
    pub reports: Sender<NodeReport>,
    pub cfg: CoordConfig,
}

pub fn run_node(
    problem: Arc<dyn Problem>,
    prox: Arc<dyn Prox>,
    x0_all: &Mat,
    nc: NodeConfig,
) {
    let me = nc.id;
    let p = problem.dim();
    let cfg = &nc.cfg;
    let (eta, alpha, gamma) = (cfg.eta, cfg.alpha, cfg.gamma);
    // deterministic per-node streams: compression dither + straggler coin
    let mut comp_rng = Rng::new(cfg.seed).fork(me as u64);
    let mut fault_rng = Rng::new(cfg.seed ^ 0x5747_4C52).fork(me as u64);
    let seed = cfg.seed.wrapping_add(me as u64);
    let mut oracle = Sgo::for_node(cfg.oracle, problem.as_ref(), me, x0_all.row(me), seed);

    // Algorithm 1 lines 1–3 (H¹ = X⁰; every node knows the common X⁰, so
    // h_w = Σⱼ w_ij x⁰_j is computed locally without a startup exchange)
    let mut x: Vec<f64> = x0_all.row(me).to_vec();
    let mut h = x.clone();
    let mut h_w = vec![0.0; p];
    vaxpy(&mut h_w, nc.self_weight, x0_all.row(me));
    for &(j, wij, _) in &nc.neighbors {
        vaxpy(&mut h_w, wij, x0_all.row(j));
    }
    let mut g = vec![0.0; p];
    oracle.sample(problem.as_ref(), me, &x.clone(), &mut g);
    let mut z: Vec<f64> = x.iter().zip(&g).map(|(xi, gi)| xi - eta * gi).collect();
    prox.prox(&mut z, eta);
    x = z;
    let mut d = vec![0.0; p];

    let mut bytes_sent = 0u64;
    let mut payload_bits = 0u64;
    let mut diff = vec![0.0; p];
    let mut z_buf = vec![0.0; p];
    // frames from neighbors that are a round ahead of us
    let mut future: std::collections::HashMap<u32, Vec<Frame>> = std::collections::HashMap::new();

    for k in 0..cfg.rounds {
        // line 5–6: z = x − η(g + d)
        oracle.sample(problem.as_ref(), me, &x, &mut g);
        for (((zb, &xi), &gi), &di) in z_buf.iter_mut().zip(&x).zip(&g).zip(&d) {
            *zb = xi - eta * gi - eta * di;
        }

        // COMM: q = Q(z − h), broadcast the frame
        for ((df, &zi), &hi) in diff.iter_mut().zip(&z_buf).zip(&h) {
            *df = zi - hi;
        }
        let (payload, q_own, bits) = cfg.codec.encode(&diff, &mut comp_rng);
        payload_bits += bits;
        let frame = Frame { round: k as u32, from: me as u16, payload };
        let buf = frame.to_bytes(&cfg.codec);
        for &(_, _, ref tx) in &nc.neighbors {
            if let Some(s) = cfg.straggler {
                if fault_rng.bernoulli(s.prob) {
                    std::thread::sleep(s.delay);
                }
            }
            bytes_sent += buf.len() as u64;
            tx.send(buf.clone()).expect("peer inbox closed");
        }

        // ẑ_w accumulation starts from own contribution
        let mut wq = vec![0.0; p];
        vaxpy(&mut wq, nc.self_weight, &q_own);
        let mut got = 0usize;
        let apply = |f: Frame, wq: &mut Vec<f64>| {
            let q_j = cfg.codec.decode(&f.payload, p);
            let wij = nc
                .neighbors
                .iter()
                .find(|(j, _, _)| *j == f.from as usize)
                .map(|(_, w, _)| *w)
                .expect("frame from non-neighbor");
            vaxpy(wq, wij, &q_j);
        };
        for f in future.remove(&(k as u32)).unwrap_or_default() {
            apply(f, &mut wq);
            got += 1;
        }
        while got < nc.neighbors.len() {
            let raw = nc.inbox.recv().expect("inbox closed mid-round");
            let (_, f) = Frame::from_bytes(&raw).expect("malformed frame");
            if (f.round as usize) > k {
                future.entry(f.round).or_default().push(f);
            } else {
                assert_eq!(f.round as usize, k, "stale frame from node {}", f.from);
                apply(f, &mut wq);
                got += 1;
            }
        }

        // ẑ = h + q, ẑ_w = h_w + wq; update h, h_w; D/V/X updates
        let coef = gamma / (2.0 * eta);
        let mut v = vec![0.0; p];
        for i in 0..p {
            let z_hat = h[i] + q_own[i];
            let zw_hat = h_w[i] + wq[i];
            let resid = z_hat - zw_hat;
            d[i] += coef * resid;
            v[i] = z_buf[i] - 0.5 * gamma * resid;
            h[i] += alpha * q_own[i];
            h_w[i] += alpha * wq[i];
        }
        prox.prox(&mut v, eta);
        x = v;

        if (k + 1) % cfg.record_every == 0 || k + 1 == cfg.rounds {
            nc.reports
                .send(NodeReport {
                    node: me,
                    round: k + 1,
                    x: x.clone(),
                    bytes_sent,
                    payload_bits,
                    grad_evals: oracle.grad_evals(),
                })
                .expect("leader gone");
        }
    }
}
