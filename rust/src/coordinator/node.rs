//! The algorithm-generic node runtime: one thread per node, synchronous
//! rounds over serialized frames.
//!
//! A [`NodeAlgorithm`] is one node's half of a decentralized algorithm —
//! the per-row arithmetic the matrix engine performs on row i, re-expressed
//! over local state. The driver [`run_node`] owns everything that is *not*
//! algorithm arithmetic: wire encoding/decoding, frame transport, the
//! synchronous-round barrier, straggler injection, metric reporting, and
//! the leader's early-stop protocol. Per round it
//!
//! 1. asks the algorithm for its broadcast vector ([`NodeAlgorithm::outgoing`]),
//! 2. encodes it with the wire codec and unicasts the frame to every
//!    gossip neighbor,
//! 3. gathers exactly one frame per neighbor for the current round
//!    (buffering ahead-of-round frames from fast neighbors), decodes them,
//!    and
//! 4. hands the decoded round back ([`NodeAlgorithm::update`]).
//!
//! **Bit-exactness.** The engine's gossip is a W·X product whose kernels
//! (dense blocked ikj and CSR SpMM) accumulate each output entry over
//! ascending column index, skipping zero weights. [`WeightRow::mix_into`]
//! reproduces exactly that order — neighbors ascending with the diagonal
//! spliced at j = node — so a node-thread round is bit-identical to the
//! engine's row arithmetic whenever the codec round-trips exactly
//! (`Dense64`). Frames are therefore collected into per-neighbor slots
//! *before* mixing; arrival order never touches the arithmetic.
//!
//! The synchronous-round barrier: a fast neighbor may already have sent its
//! round-(k+1) frame while this node still collects round k (it only needed
//! OUR round-k frame to advance, not our slow neighbor's), so ahead-of-round
//! frames are buffered; behind-round frames indicate a protocol violation
//! and panic.
//!
//! **Early stop (leader gating).** When the run's
//! [`crate::runner::StopSet`] carries a criterion the leader must observe
//! (target suboptimality, bits/grad-evals budget, deadline), every node
//! blocks after each `record_every`-checkpoint report until the leader
//! broadcasts continue-or-stop over the per-node control channel. All
//! nodes checkpoint at the same steps, so they all receive the same
//! decision and a stopped run ends on the same round network-wide — which
//! is what makes budget stops deterministic and bit-comparable to the
//! engine. Between checkpoints nodes free-run exactly as in the ungated
//! case.

use super::wire::Frame;
use super::{CoordConfig, NodeReport};
use crate::graph::MixingOp;
use crate::linalg::Mat;
use crate::util::rng::Rng;
use std::sync::mpsc::{Receiver, Sender};

/// One node's half of a decentralized algorithm (see the module docs).
/// Implementations live in [`super::algorithms`]; the name-dispatching
/// factory is `exp::registry::build_node_algorithm`.
pub trait NodeAlgorithm: Send {
    /// Wire exchanges that happen *before* step counting starts (P2D2's
    /// init round mixes W̃(X⁰ − η∇F(X⁰)), which the matrix engine performs
    /// at construction). Default: none.
    fn setup_rounds(&self) -> usize {
        0
    }

    /// Compute this round's broadcast vector into `out` (length p). Local
    /// gradient work happens here.
    fn outgoing(&mut self, out: &mut [f64]);

    /// Consume the round: `q_own` is the node's own payload as both wire
    /// endpoints decode it, `peers` the decoded neighbor payloads aligned
    /// with the gossip row (ascending neighbor id).
    fn update(&mut self, q_own: &[f64], peers: &[(usize, Vec<f64>)]);

    /// The node's current iterate xᵢ.
    fn x(&self) -> &[f64];

    /// Cumulative batch-gradient evaluations (including any VR/init cost).
    fn grad_evals(&self) -> u64;
}

/// Node i's row of a mixing operator: the self weight plus the (j, w_ij)
/// gossip neighbors, ascending j — the structure the per-edge channels and
/// every node-side mix are derived from.
#[derive(Clone, Debug)]
pub struct WeightRow {
    pub node: usize,
    pub self_weight: f64,
    /// (neighbor id, w_ij), ascending id, zero weights excluded.
    pub neighbors: Vec<(usize, f64)>,
}

impl WeightRow {
    /// Extract row `node` from the mixing operator (one CSR row walk on
    /// sparse graphs).
    pub fn from_op(w: &MixingOp, node: usize) -> WeightRow {
        WeightRow { node, self_weight: w.self_weight(node), neighbors: w.neighbors(node) }
    }

    /// The W̃ = (I + W)/2 row, with the same f64 operations as
    /// [`MixingOp::half_lazy`] (scale by 0.5, then +0.5 on the diagonal) so
    /// NIDS / PG-EXTRA / P2D2 node mixes stay bit-identical to the engine.
    pub fn half_lazy(&self) -> WeightRow {
        WeightRow {
            node: self.node,
            self_weight: self.self_weight * 0.5 + 0.5,
            neighbors: self.neighbors.iter().map(|&(j, w)| (j, w * 0.5)).collect(),
        }
    }

    /// The W − I row (Choco's consensus correction), mirroring
    /// [`MixingOp::minus_identity`].
    pub fn minus_identity(&self) -> WeightRow {
        WeightRow {
            node: self.node,
            self_weight: self.self_weight - 1.0,
            neighbors: self.neighbors.clone(),
        }
    }

    /// out ← Σⱼ w_ij·vⱼ with `own` at j = node and `peers[k]` at the k-th
    /// gossip neighbor. Accumulates in ascending-j order and skips zero
    /// weights — the exact summation order of the engine's matmul/SpMM
    /// kernels, which makes node mixes bit-identical to W·X rows.
    pub fn mix_into(&self, out: &mut [f64], own: &[f64], peers: &[(usize, Vec<f64>)]) {
        debug_assert_eq!(peers.len(), self.neighbors.len());
        debug_assert!(
            peers.iter().zip(&self.neighbors).all(|((pj, _), &(j, _))| *pj == j),
            "peer slots misaligned with the gossip row"
        );
        self.mix_with(out, own, |k| peers[k].1.as_slice());
    }

    /// [`WeightRow::mix_into`] over the rows of a shared matrix (init-time
    /// products every node can compute locally, e.g. W·X⁰ from the common
    /// start iterate).
    pub fn mix_rows_into(&self, out: &mut [f64], x: &Mat) {
        self.mix_with(out, x.row(self.node), |k| x.row(self.neighbors[k].0));
    }

    /// The one copy of the order-sensitive accumulation loop both mixes
    /// share: diagonal spliced before the first neighbor with j > node,
    /// ascending j throughout, zero weights skipped.
    fn mix_with<'a>(&self, out: &mut [f64], own: &[f64], peer: impl Fn(usize) -> &'a [f64]) {
        out.iter_mut().for_each(|o| *o = 0.0);
        let mut placed = false;
        for (k, &(j, wij)) in self.neighbors.iter().enumerate() {
            if !placed && self.node < j {
                acc(out, self.self_weight, own);
                placed = true;
            }
            acc(out, wij, peer(k));
        }
        if !placed {
            acc(out, self.self_weight, own);
        }
    }
}

/// out += w·v, skipping zero weights exactly like the engine kernels do.
#[inline]
fn acc(out: &mut [f64], w: f64, v: &[f64]) {
    if w == 0.0 {
        return;
    }
    for (o, &x) in out.iter_mut().zip(v) {
        *o += w * x;
    }
}

/// Everything a node thread needs besides its algorithm half.
pub struct NodeConfig {
    pub id: usize,
    /// (neighbor id, sender into that neighbor's inbox), ascending id —
    /// aligned with the algorithm's [`WeightRow`].
    pub neighbors: Vec<(usize, Sender<Vec<u8>>)>,
    pub inbox: Receiver<Vec<u8>>,
    pub reports: Sender<NodeReport>,
    /// Leader gating channel (`Some` when the run's stop set needs leader
    /// observation): `true` = continue past the checkpoint, `false` = stop.
    pub control: Option<Receiver<bool>>,
    /// Wire-level knobs: codec, straggler model, RNG seed.
    pub wire: CoordConfig,
    /// Counted algorithm rounds (setup rounds excluded).
    pub rounds: usize,
    /// Report (and, when gated, checkpoint) every this many rounds.
    pub record_every: usize,
    /// Parameter dimension p (frame payloads decode to this length).
    pub dim: usize,
}

/// Drive one node's algorithm through `setup + rounds` wire exchanges.
///
/// Reporting follows the engine's record rule: a report at round 0 (the
/// post-init state, after any setup exchanges — mirroring the engine's
/// round-0 sample), at every `record_every`-th step, AND always at step
/// `rounds`, so leader totals (wire bytes, payload bits, grad evals)
/// cover the whole run even when `rounds % record_every != 0`.
pub fn run_node(mut alg: Box<dyn NodeAlgorithm>, nc: NodeConfig) {
    let me = nc.id;
    let p = nc.dim;
    let wire = &nc.wire;
    // deterministic per-node streams: compression dither + straggler coin
    let mut comp_rng = Rng::new(wire.seed).fork(me as u64);
    let mut fault_rng = Rng::new(wire.seed ^ 0x5747_4C52).fork(me as u64);

    let setup = alg.setup_rounds();
    let total = setup + nc.rounds;
    let deg = nc.neighbors.len();
    let mut payload = vec![0.0; p];
    // decoded neighbor payloads for the current round, one slot per gossip
    // neighbor (ascending id); an empty vec marks "not yet received"
    let mut peers: Vec<(usize, Vec<f64>)> =
        nc.neighbors.iter().map(|&(j, _)| (j, Vec::new())).collect();
    // frames from neighbors that are a round ahead of us
    let mut future: std::collections::HashMap<u32, Vec<Frame>> = std::collections::HashMap::new();
    let (mut bytes_sent, mut payload_bits) = (0u64, 0u64);

    for k in 0..total {
        if k == setup {
            // round-0 report: the post-initialization state (engine: the
            // sample taken before the first step). Setup-round wire costs
            // (P2D2's init exchange) are already in the counters.
            nc.reports
                .send(NodeReport {
                    node: me,
                    round: 0,
                    x: alg.x().to_vec(),
                    bytes_sent,
                    payload_bits,
                    grad_evals: alg.grad_evals(),
                })
                .expect("leader gone");
        }
        alg.outgoing(&mut payload);
        let (frame_bytes, q_own, bits) = wire.codec.encode(&payload, &mut comp_rng);
        payload_bits += bits;
        let frame = Frame { round: k as u32, from: me as u16, payload: frame_bytes };
        let buf = frame.to_bytes(&wire.codec);
        for (_, tx) in &nc.neighbors {
            if let Some(s) = wire.straggler {
                if fault_rng.bernoulli(s.prob) {
                    std::thread::sleep(s.delay);
                }
            }
            bytes_sent += buf.len() as u64;
            tx.send(buf.clone()).expect("peer inbox closed");
        }

        // barrier: exactly one frame per neighbor, slotted by sender id so
        // arrival order never reaches the arithmetic
        for (_, v) in peers.iter_mut() {
            v.clear();
        }
        let mut got = 0usize;
        let mut take = |f: Frame, peers: &mut Vec<(usize, Vec<f64>)>, got: &mut usize| {
            let slot = peers
                .binary_search_by_key(&(f.from as usize), |&(j, _)| j)
                .unwrap_or_else(|_| panic!("frame from non-neighbor {}", f.from));
            assert!(peers[slot].1.is_empty(), "duplicate frame from node {}", f.from);
            peers[slot].1 = wire.codec.decode(&f.payload, p);
            *got += 1;
        };
        for f in future.remove(&(k as u32)).unwrap_or_default() {
            take(f, &mut peers, &mut got);
        }
        while got < deg {
            let raw = nc.inbox.recv().expect("inbox closed mid-round");
            let (_, f) = Frame::from_bytes(&raw).expect("malformed frame");
            if (f.round as usize) > k {
                future.entry(f.round).or_default().push(f);
            } else {
                assert_eq!(f.round as usize, k, "stale frame from node {}", f.from);
                take(f, &mut peers, &mut got);
            }
        }

        alg.update(&q_own, &peers);

        if k >= setup {
            let step = k - setup + 1;
            if step % nc.record_every == 0 || step == nc.rounds {
                nc.reports
                    .send(NodeReport {
                        node: me,
                        round: step,
                        x: alg.x().to_vec(),
                        bytes_sent,
                        payload_bits,
                        grad_evals: alg.grad_evals(),
                    })
                    .expect("leader gone");
            }
            // checkpoint gate: wait for the leader's continue/stop verdict
            // (sent for every flushed multiple of record_every before the
            // final round — the same set of steps on every node, so a stop
            // lands network-wide on one round)
            if step % nc.record_every == 0 && step < nc.rounds {
                if let Some(ctrl) = &nc.control {
                    if !ctrl.recv().expect("leader gone at checkpoint") {
                        break;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Graph, MixingRule};

    #[test]
    fn weight_row_mix_matches_matmul_bitwise() {
        // the contract everything rests on: a WeightRow mix reproduces the
        // engine's W·X row bit for bit, through both representations and
        // all three derived operators
        let g = Graph::grid(16);
        let mut rng = Rng::new(5);
        let mut x = Mat::zeros(16, 7);
        rng.fill_normal(&mut x.data);
        for op in [
            MixingOp::dense_from(&g, MixingRule::Metropolis),
            MixingOp::sparse_from(&g, MixingRule::Metropolis),
        ] {
            for derived in ["w", "half_lazy", "minus_identity"] {
                let full_op = match derived {
                    "w" => op.clone(),
                    "half_lazy" => op.half_lazy(),
                    _ => op.minus_identity(),
                };
                let expect = full_op.apply(&x);
                for i in 0..16 {
                    let base = WeightRow::from_op(&op, i);
                    let row = match derived {
                        "w" => base.clone(),
                        "half_lazy" => base.half_lazy(),
                        _ => base.minus_identity(),
                    };
                    let peers: Vec<(usize, Vec<f64>)> =
                        row.neighbors.iter().map(|&(j, _)| (j, x.row(j).to_vec())).collect();
                    let mut out = vec![0.0; 7];
                    row.mix_into(&mut out, x.row(i), &peers);
                    for (a, b) in out.iter().zip(expect.row(i)) {
                        assert_eq!(a.to_bits(), b.to_bits(), "{derived}: node {i}");
                    }
                    let mut out2 = vec![0.0; 7];
                    row.mix_rows_into(&mut out2, &x);
                    assert_eq!(out, out2, "{derived}: matrix-form mix differs at node {i}");
                }
            }
        }
    }

    #[test]
    fn weight_row_derivations_match_operator_entries() {
        let g = Graph::ring(8);
        let op = MixingOp::dense_from(&g, MixingRule::UniformMaxDegree);
        let row = WeightRow::from_op(&op, 3);
        assert_eq!(row.self_weight, op.self_weight(3));
        assert_eq!(row.neighbors, op.neighbors(3));
        let lazy = row.half_lazy();
        let wl = op.half_lazy();
        assert_eq!(lazy.self_weight.to_bits(), wl.self_weight(3).to_bits());
        assert_eq!(lazy.neighbors, wl.neighbors(3));
        let mi = row.minus_identity();
        assert_eq!(mi.self_weight.to_bits(), op.minus_identity().self_weight(3).to_bits());
    }
}
