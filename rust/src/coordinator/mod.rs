//! The message-passing coordinator — the "real" distributed runtime.
//!
//! Each node is a thread owning one [`NodeAlgorithm`] (the per-node half of
//! any registry algorithm — Prox-LEAD, DGD, Choco, NIDS, PG-EXTRA, P2D2,
//! PDGM, DualGD); neighbors exchange *serialized* compressed frames over
//! per-edge channels (the paper's 8-machine ring becomes 8 node threads;
//! see DESIGN.md §4). The leader thread collects per-round metrics and
//! assembles the same history the matrix engine produces — under the exact
//! `Dense64` codec the two backends are pinned **bit for bit** for every
//! registry algorithm (`rust/tests/coordinator_parity.rs`), which is what
//! lets the wire-bytes bench compare algorithms on actual framed bytes
//! rather than the engine's accounting model.
//!
//! Construction is a factory call per node: [`run`] takes any
//! `Fn(node, WeightRow) -> Box<dyn NodeAlgorithm>`; the name-dispatching
//! factory lives in `exp::registry::build_node_algorithm` so
//! `Experiment::coordinator()`, the CLI `train`, and sweeps accept every
//! `algorithm=` value. [`run_prox_lead`] keeps the historical hand-wired
//! entry point.
//!
//! Fault injection: an optional straggler model (per-message delay with
//! probability `p`) exercises the synchronous-round barrier under skew.

pub mod algorithms;
pub mod node;
pub mod wire;

pub use algorithms::{
    ChocoNode, DgdNode, DualGdNode, NidsNode, NodeComm, P2d2Node, PdgmNode, PgExtraNode,
    ProxLeadNode,
};
pub use node::{NodeAlgorithm, NodeConfig, WeightRow};
pub use wire::{Frame, WireCodec};

use crate::graph::MixingOp;
use crate::linalg::Mat;
use crate::oracle::OracleKind;
use crate::problem::Problem;
use crate::prox::Prox;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

/// Straggler fault model: each outgoing message is delayed by `delay`
/// with probability `prob`.
#[derive(Clone, Copy, Debug)]
pub struct Straggler {
    pub prob: f64,
    pub delay: Duration,
}

/// Coordinator run configuration.
#[derive(Clone)]
pub struct CoordConfig {
    pub rounds: usize,
    pub record_every: usize,
    pub eta: f64,
    pub alpha: f64,
    pub gamma: f64,
    pub codec: WireCodec,
    pub oracle: OracleKind,
    pub seed: u64,
    pub straggler: Option<Straggler>,
}

impl CoordConfig {
    pub fn new(rounds: usize, eta: f64, codec: WireCodec) -> CoordConfig {
        CoordConfig {
            rounds,
            record_every: 1,
            eta,
            alpha: 0.5,
            gamma: 1.0,
            codec,
            oracle: OracleKind::Full,
            seed: 42,
            straggler: None,
        }
    }
}

/// What one node reports to the leader at a recorded round.
#[derive(Clone, Debug)]
pub struct NodeReport {
    pub node: usize,
    pub round: usize,
    pub x: Vec<f64>,
    pub bytes_sent: u64,
    pub payload_bits: u64,
    pub grad_evals: u64,
}

/// Leader-side aggregated history.
#[derive(Clone, Debug)]
pub struct CoordResult {
    /// (round, stacked X, cumulative payload bits, cumulative grad evals).
    pub snapshots: Vec<(usize, Mat, u64, u64)>,
    /// Total wall-clock.
    pub elapsed: Duration,
    /// Total framed wire bytes (headers included) across all nodes.
    pub wire_bytes: u64,
}

impl CoordResult {
    /// The stacked iterate at the last recorded round. `run` guarantees at
    /// least one snapshot (the final round is always reported), so this is
    /// total for every completed run.
    pub fn final_x(&self) -> &Mat {
        &self.snapshots.last().expect("run() guarantees at least one snapshot").1
    }

    /// Suboptimality trace vs a reference solution.
    pub fn suboptimality(&self, x_star: &[f64]) -> Vec<(usize, f64)> {
        self.snapshots
            .iter()
            .map(|(r, x, _, _)| (*r, crate::algorithm::suboptimality(x, x_star)))
            .collect()
    }
}

/// Run a decentralized algorithm over node threads. `build` constructs the
/// per-node halves — one call per node with that node's gossip row (derived
/// from the mixing operator's structure: one CSR row walk per node on
/// sparse graphs, so setup is O(nnz), not O(n²)). Construction runs
/// *inside* each node's thread (scoped), so per-node init work — a full
/// gradient at X⁰, SAGA's m-sample table — overlaps across nodes instead
/// of serializing on the leader. The name-dispatching factory over an
/// `Experiment` is `exp::registry::build_node_algorithm`.
pub fn run(
    w: &MixingOp,
    x0: &Mat,
    cfg: &CoordConfig,
    build: impl Fn(usize, WeightRow) -> Box<dyn NodeAlgorithm> + Sync,
) -> CoordResult {
    let n = w.n();
    assert_eq!(x0.rows, n);
    assert!(
        cfg.rounds > 0,
        "coordinator run needs rounds >= 1 (rounds = 0 would record no snapshots)"
    );
    assert!(cfg.record_every > 0, "record_every must be >= 1");
    let start = Instant::now();

    // per-node inboxes; every node gets a Sender clone for each neighbor
    let mut txs = Vec::with_capacity(n);
    let mut rxs = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = mpsc::channel::<Vec<u8>>();
        txs.push(tx);
        rxs.push(rx);
    }
    let (report_tx, report_rx) = mpsc::channel::<NodeReport>();
    let build = &build;

    let (snapshots, wire_bytes) = thread::scope(|scope| {
        let mut handles = Vec::with_capacity(n);
        for (i, rx) in rxs.into_iter().enumerate() {
            let row = WeightRow::from_op(w, i);
            // per-edge senders, aligned with the gossip row (ascending j)
            let neighbors: Vec<(usize, mpsc::Sender<Vec<u8>>)> =
                row.neighbors.iter().map(|&(j, _)| (j, txs[j].clone())).collect();
            let node_cfg = NodeConfig {
                id: i,
                neighbors,
                inbox: rx,
                reports: report_tx.clone(),
                cfg: cfg.clone(),
                dim: x0.cols,
            };
            handles.push(
                thread::Builder::new()
                    .name(format!("node-{i}"))
                    .spawn_scoped(scope, move || node::run_node(build(i, row), node_cfg))
                    .expect("spawn node thread"),
            );
        }
        drop(report_tx);
        drop(txs);

        // leader: gather reports until every node finished every recorded
        // round
        let mut pending: std::collections::BTreeMap<usize, Vec<Option<NodeReport>>> =
            std::collections::BTreeMap::new();
        let mut snapshots = Vec::new();
        let mut wire_bytes = 0u64;
        while let Ok(rep) = report_rx.recv() {
            let slot = pending.entry(rep.round).or_insert_with(|| vec![None; n]);
            let node = rep.node;
            assert!(slot[node].is_none(), "duplicate report from node {node}");
            slot[node] = Some(rep);
            // flush completed rounds in order
            while let Some((&round, slots)) = pending.iter().next() {
                if !slots.iter().all(|s| s.is_some()) {
                    break;
                }
                let slots = pending.remove(&round).unwrap();
                let mut x = Mat::zeros(n, x0.cols);
                let (mut bits, mut evals, mut bytes) = (0u64, 0u64, 0u64);
                for s in slots.into_iter().map(Option::unwrap) {
                    x.row_mut(s.node).copy_from_slice(&s.x);
                    bits += s.payload_bits;
                    evals += s.grad_evals;
                    bytes += s.bytes_sent;
                }
                // per-node counters are cumulative: the latest snapshot's
                // sum is the run total so far (the final round is always
                // reported, so this covers every frame even when
                // rounds % record_every != 0)
                wire_bytes = bytes;
                snapshots.push((round, x, bits, evals));
            }
        }
        for h in handles {
            h.join().expect("node thread panicked");
        }
        (snapshots, wire_bytes)
    });
    assert!(!snapshots.is_empty(), "no snapshots recorded — node threads died before reporting");

    CoordResult { snapshots, elapsed: start.elapsed(), wire_bytes }
}

/// Distributed Prox-LEAD over node threads — the historical entry point,
/// now a thin [`ProxLeadNode`] factory over the algorithm-generic [`run`].
/// `problem` supplies every node's data (as the per-machine shards would in
/// a real deployment); `prox` is the shared non-smooth term; `x0` the
/// common start iterate.
pub fn run_prox_lead(
    problem: Arc<dyn Problem>,
    w: &MixingOp,
    x0: &Mat,
    prox: Arc<dyn Prox>,
    cfg: &CoordConfig,
) -> CoordResult {
    assert_eq!(problem.num_nodes(), w.n());
    run(w, x0, cfg, |_, row| {
        Box::new(ProxLeadNode::new(Arc::clone(&problem), Arc::clone(&prox), x0, row, cfg))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithm::testkit::{ring_logreg, safe_eta};
    use crate::algorithm::{solve_reference, suboptimality, Algorithm, ProxLead};
    use crate::compress::Identity;
    use crate::prox::{Zero, L1};

    #[test]
    fn leader_matches_matrix_engine_bit_for_bit() {
        // exact codec + full gradient: node-thread iterates must equal the
        // Experiment-built matrix engine's bit for bit (the slots-before-
        // mixing barrier makes the gossip summation order identical to the
        // engine kernels; the 9-algorithm matrix version of this test lives
        // in rust/tests/coordinator_parity.rs)
        let exp = crate::algorithm::testkit::ring_exp();
        let cfg = CoordConfig::new(40, exp.hyper.eta, WireCodec::Dense64);
        let res =
            run_prox_lead(Arc::clone(&exp.problem), &exp.mixing, &exp.x0, Arc::new(Zero), &cfg);

        let mut matrix =
            ProxLead::builder(&exp).compressor(Box::new(Identity::f64())).seed(1).build();
        for _ in 0..40 {
            matrix.step(exp.problem.as_ref());
        }
        let coord_x = res.final_x();
        for (i, (a, b)) in coord_x.data.iter().zip(&matrix.x().data).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "entry {i}: {a:?} vs {b:?}");
        }
    }

    #[test]
    fn experiment_coordinator_matches_explicit_wiring() {
        // the Experiment-level coordinator entry point drives the same run
        // the hand-wired CoordConfig produces, bit for bit
        let mut cfg = crate::config::Config::parse(
            "nodes = 4\nsamples_per_node = 24\ndim = 5\nclasses = 3\nbatches = 4\n\
             separation = 1.0\nseed = 33\nlambda1 = 0.005\nlambda2 = 0.1\nbits = 2\n",
        )
        .unwrap();
        cfg.rounds = 60;
        cfg.record_every = 20;
        let exp = crate::exp::Experiment::from_config(&cfg).unwrap();
        let via_exp = exp.coordinator();

        let mut ccfg = CoordConfig::new(60, exp.hyper.eta, WireCodec::Quant(2, 256));
        ccfg.record_every = 20;
        ccfg.seed = 33;
        let explicit = run_prox_lead(
            Arc::clone(&exp.problem),
            &exp.mixing,
            &exp.x0,
            Arc::new(L1::new(5e-3)),
            &ccfg,
        );
        assert_eq!(via_exp.snapshots.len(), explicit.snapshots.len());
        for ((ra, xa, ba, ea), (rb, xb, bb, eb)) in
            via_exp.snapshots.iter().zip(&explicit.snapshots)
        {
            assert_eq!((ra, ba, ea), (rb, bb, eb));
            for (a, b) in xa.data.iter().zip(&xb.data) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn sparse_and_dense_channels_yield_identical_runs() {
        // CSR-derived per-edge channels must reproduce the dense-derived
        // run bit for bit (same neighbor order, same weights)
        let (p, _) = ring_logreg();
        use crate::problem::Problem;
        let g = crate::graph::Graph::ring(4);
        let rule = crate::graph::MixingRule::UniformMaxDegree;
        let x0 = Mat::zeros(4, p.dim());
        let eta = safe_eta(&p);
        let p_arc: Arc<dyn crate::problem::Problem> = Arc::new(p);
        let mut cfg = CoordConfig::new(200, eta, WireCodec::Quant(2, 256));
        cfg.record_every = 50;
        let dense = run_prox_lead(
            Arc::clone(&p_arc),
            &crate::graph::MixingOp::dense_from(&g, rule),
            &x0,
            Arc::new(Zero),
            &cfg,
        );
        let sparse = run_prox_lead(
            Arc::clone(&p_arc),
            &crate::graph::MixingOp::sparse_from(&g, rule),
            &x0,
            Arc::new(Zero),
            &cfg,
        );
        assert_eq!(dense.snapshots.len(), sparse.snapshots.len());
        for ((rd, xd, bd, ed), (rs, xs, bs, es)) in
            dense.snapshots.iter().zip(&sparse.snapshots)
        {
            assert_eq!((rd, bd, ed), (rs, bs, es));
            for (a, b) in xd.data.iter().zip(&xs.data) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn quantized_coordinator_converges_composite() {
        let (p, w) = ring_logreg();
        use crate::problem::Problem;
        let x_star = solve_reference(&p, 5e-3, 40_000, 1e-13);
        let x0 = Mat::zeros(4, p.dim());
        let eta = safe_eta(&p);
        let p_arc: Arc<dyn crate::problem::Problem> = Arc::new(p);
        let mut cfg = CoordConfig::new(3000, eta, WireCodec::Quant(2, 256));
        cfg.record_every = 500;
        let res = run_prox_lead(p_arc, &w, &x0, Arc::new(L1::new(5e-3)), &cfg);
        let s = suboptimality(res.final_x(), &x_star);
        assert!(s < 1e-12, "distributed Prox-LEAD 2bit suboptimality: {s}");
        assert!(res.wire_bytes > 0);
        // trace is decreasing overall
        let trace = res.suboptimality(&x_star);
        assert!(trace.last().unwrap().1 < trace.first().unwrap().1 * 1e-6);
    }

    #[test]
    fn straggler_injection_slows_but_converges() {
        let (p, w) = ring_logreg();
        use crate::problem::Problem;
        let x_star = solve_reference(&p, 0.0, 40_000, 1e-13);
        let x0 = Mat::zeros(4, p.dim());
        let eta = safe_eta(&p);
        let p_arc: Arc<dyn crate::problem::Problem> = Arc::new(p);
        let mut cfg = CoordConfig::new(150, eta, WireCodec::Quant(2, 256));
        cfg.record_every = 150;
        cfg.straggler = Some(Straggler { prob: 0.05, delay: Duration::from_micros(300) });
        let res = run_prox_lead(p_arc, &w, &x0, Arc::new(Zero), &cfg);
        let s = suboptimality(res.final_x(), &x_star);
        assert!(s.is_finite() && s < 1.0, "straggler run must stay sound: {s}");
        assert_eq!(res.snapshots.len(), 1);
    }

    #[test]
    fn stochastic_oracles_work_across_threads() {
        let (p, w) = ring_logreg();
        use crate::problem::Problem;
        let x_star = solve_reference(&p, 0.0, 40_000, 1e-13);
        let x0 = Mat::zeros(4, p.dim());
        let p_arc: Arc<dyn crate::problem::Problem> = Arc::new(p);
        let mut cfg =
            CoordConfig::new(4000, 1.0 / (6.0 * p_arc.smoothness()), WireCodec::Quant(2, 256));
        cfg.record_every = 1000;
        cfg.oracle = OracleKind::Saga;
        let res = run_prox_lead(p_arc, &w, &x0, Arc::new(Zero), &cfg);
        let s = suboptimality(res.final_x(), &x_star);
        assert!(s < 1e-8, "distributed LEAD-SAGA suboptimality: {s}");
        // grad evals include per-node SAGA init (m per node)
        let (_, _, _, evals) = res.snapshots.last().unwrap();
        assert!(*evals >= 4000);
    }

    #[test]
    #[should_panic(expected = "rounds >= 1")]
    fn zero_rounds_is_a_clear_error_at_entry() {
        // regression: rounds = 0 used to run to completion with an empty
        // snapshot list, deferring the panic to CoordResult::final_x
        let (p, w) = ring_logreg();
        use crate::problem::Problem;
        let x0 = Mat::zeros(4, p.dim());
        let cfg = CoordConfig::new(0, 0.05, WireCodec::Dense64);
        let _ = run_prox_lead(Arc::new(p), &w, &x0, Arc::new(Zero), &cfg);
    }

    #[test]
    fn final_round_reported_when_rounds_not_divisible_by_record_every() {
        // bookkeeping pin: the run totals (wire bytes, payload bits, grad
        // evals) must cover every round — nodes always report round
        // `rounds`, like the engine's `k + 1 == cfg.rounds` rule
        let (p, w) = ring_logreg();
        use crate::problem::Problem;
        let x0 = Mat::zeros(4, p.dim());
        let eta = safe_eta(&p);
        let p_arc: Arc<dyn crate::problem::Problem> = Arc::new(p);
        let mk = |record_every: usize| {
            let mut cfg = CoordConfig::new(7, eta, WireCodec::Quant(2, 256));
            cfg.record_every = record_every;
            run_prox_lead(Arc::clone(&p_arc), &w, &x0, Arc::new(Zero), &cfg)
        };
        let thinned = mk(3); // 7 % 3 != 0: rounds 3, 6, then the final 7
        let dense = mk(1); // every round: ground truth totals
        let rounds: Vec<usize> = thinned.snapshots.iter().map(|(r, ..)| *r).collect();
        assert_eq!(rounds, vec![3, 6, 7]);
        assert_eq!(thinned.wire_bytes, dense.wire_bytes, "wire byte totals must not undercount");
        let (_, xt, bt, et) = thinned.snapshots.last().unwrap();
        let (_, xd, bd, ed) = dense.snapshots.last().unwrap();
        assert_eq!((bt, et), (bd, ed), "payload bits / grad evals must cover all 7 rounds");
        for (a, b) in xt.data.iter().zip(&xd.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
