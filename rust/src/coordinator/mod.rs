//! The message-passing coordinator — the "real" distributed runtime.
//!
//! Each node is a thread owning one [`NodeAlgorithm`] (the per-node half of
//! any registry algorithm — Prox-LEAD, DGD, Choco, NIDS, PG-EXTRA, P2D2,
//! PDGM, DualGD); neighbors exchange *serialized* compressed frames over
//! per-edge channels (the paper's 8-machine ring becomes 8 node threads;
//! see DESIGN.md §4). The leader thread collects per-round metrics,
//! samples suboptimality/consensus/wall-clock per snapshot, evaluates the
//! run's [`crate::runner::StopSet`] (broadcasting an early stop to every
//! node thread when a criterion hits — see [`node`]), and assembles the same
//! [`RunResult`]/[`MetricPoint`] history the matrix engine produces. Under
//! the exact `Dense64` codec the two backends are pinned **bit for bit**
//! for every registry algorithm (`rust/tests/coordinator_parity.rs`),
//! which is what lets the wire-bytes bench compare algorithms on actual
//! framed bytes rather than the engine's accounting model.
//!
//! Configuration is split by concern:
//! - [`CoordConfig`] — wire-only knobs (codec, straggler model, seed);
//! - [`NodeHyper`] — the algorithm-side hyperparameters a node half needs
//!   (η, α, γ, oracle), the engine's `Hyper` + oracle restated per node;
//! - [`crate::runner::RunSpec`] — rounds, sampling, and stop criteria,
//!   shared verbatim with the engine.
//!
//! Construction is a factory call per node: [`run`] takes any
//! `Fn(node, WeightRow) -> Box<dyn NodeAlgorithm>`; the name-dispatching
//! factory lives in `exp::registry::build_node_algorithm` so
//! `Experiment::run_coordinator`, the CLI `train`, and sweeps accept every
//! `algorithm=` value.
//!
//! Fault injection: an optional straggler model (per-message delay with
//! probability `p`) exercises the synchronous-round barrier under skew,
//! and an optional [`FrameTamper`] corrupts one prescribed broadcast to
//! exercise the malformed-frame path end to end.
//!
//! **Wire faults.** The receive path is panic-free: a malformed or
//! protocol-violating frame surfaces as a typed [`WireError`], the
//! detecting node floods an ABORT teardown wave (so the synchronous
//! barrier never deadlocks on a dead peer), and the run returns normally
//! with [`StopReason::WireFault`] — the history holds every snapshot
//! completed before the fault (or a synthesized round-0 state when the
//! fault hit before the first one).
//!
//! All channels and thread spawns go through the [`crate::runtime::sync`]
//! shim layer, so `proxlead-check` (see [`crate::check`] and DESIGN.md
//! §6b) can replay the teardown protocol under controlled schedules; in
//! production the shims are transparent `mpsc`/`thread` wrappers.

pub mod algorithms;
pub mod node;
pub mod wire;

pub use algorithms::{
    ChocoNode, DgdNode, DualGdNode, NidsNode, NodeComm, P2d2Node, PdgmNode, PgExtraNode,
    ProxLeadNode,
};
pub use node::{NodeAlgorithm, NodeConfig, WeightRow};
pub use wire::{Frame, FrameRef, WireCodec, WireError, WireFault};

use crate::algorithm::suboptimality;
use crate::graph::MixingOp;
use crate::linalg::Mat;
use crate::oracle::OracleKind;
use crate::problem::Problem;
use crate::prox::Prox;
use crate::runner::{Backend, MetricPoint, Probe, RunResult, RunSpec, StopReason};
use crate::runtime::sync;
use crate::transport::{socket, Hello, InProcLink, Transport, TransportError};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

/// Straggler fault model: each outgoing message is delayed by `delay`
/// with probability `prob`.
#[derive(Clone, Copy, Debug)]
pub struct Straggler {
    pub prob: f64,
    pub delay: Duration,
}

/// Deterministic frame-corruption hook (tests/chaos): node `node` corrupts
/// its round-`round` broadcast in the prescribed way. Every neighbor
/// receives the same corrupt bytes, detects the same typed
/// [`WireError`], and the run tears down into
/// [`StopReason::WireFault`] instead of crashing a thread.
#[derive(Clone, Copy, Debug)]
pub struct FrameTamper {
    pub node: usize,
    /// Wire round (setup rounds included) whose broadcast is corrupted.
    pub round: usize,
    pub kind: TamperKind,
}

/// The corrupt-frame matrix: each variant exercises one arm of
/// [`WireError`] end to end. See `rust/tests/wire_errors.rs`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TamperKind {
    /// Ship only the first 6 header bytes → `TruncatedHeader`.
    TruncateHeader,
    /// Drop the last payload byte (header untouched) → `TruncatedPayload`.
    ShortPayload,
    /// Append 8 zero bytes and re-patch the length → a codec-level size
    /// error (`PayloadSize` dense, `TrailingBytes` quant).
    OverlongPayload,
    /// Append bytes beyond the framed length → `TrailingBytes`.
    TrailingGarbage,
    /// Tag byte no codec owns → `UnknownTag`.
    UnknownTag,
    /// A *valid* codec tag that isn't this run's codec → `TagMismatch`.
    WrongCodecTag,
    /// Overwrite the first quant block norm with NaN → `BadBlockNorm`
    /// (meaningful for `WireCodec::Quant` payloads).
    BadQuantNorm,
}

/// What a node thread sends the leader over the report channel.
#[derive(Clone, Debug)]
pub enum NodeEvent {
    Report(NodeReport),
    /// A malformed/protocol-violating frame was detected; the sender has
    /// flooded ABORT and exited.
    Fault(WireFault),
}

/// Wire-level coordinator knobs — codec, fault model, RNG seed. Rounds,
/// sampling, and stop criteria live in the shared
/// [`crate::runner::RunSpec`]; algorithm hyperparameters in [`NodeHyper`].
#[derive(Clone, Debug)]
pub struct CoordConfig {
    pub codec: WireCodec,
    /// Drives the per-node compression dither, the straggler coin, and the
    /// node algorithms' oracle streams (the engine algorithm seed).
    pub seed: u64,
    pub straggler: Option<Straggler>,
    /// Deterministic corrupt-frame injection (tests/chaos); `None` in
    /// every production path.
    pub tamper: Option<FrameTamper>,
}

impl CoordConfig {
    pub fn new(codec: WireCodec) -> CoordConfig {
        CoordConfig { codec, seed: 42, straggler: None, tamper: None }
    }

    pub fn seed(mut self, seed: u64) -> CoordConfig {
        self.seed = seed;
        self
    }

    pub fn straggler(mut self, s: Straggler) -> CoordConfig {
        self.straggler = Some(s);
        self
    }

    pub fn tamper(mut self, t: FrameTamper) -> CoordConfig {
        self.tamper = Some(t);
        self
    }
}

/// Algorithm-side hyperparameters a node half draws from — the engine's
/// `Hyper` (η, α, γ) plus the gradient oracle, restated for per-node
/// construction. Lossiness is derived from the wire codec, not stored.
#[derive(Clone, Copy, Debug)]
pub struct NodeHyper {
    pub eta: f64,
    /// COMM blending weight α (Prox-LEAD/LEAD and the LessBit family).
    pub alpha: f64,
    /// γ: Prox-LEAD's consensus stepsize / Choco's gossip stepsize γ_c.
    pub gamma: f64,
    pub oracle: OracleKind,
}

impl NodeHyper {
    /// η with the paper's α = 0.5, γ = 1, full gradient.
    pub fn new(eta: f64) -> NodeHyper {
        NodeHyper { eta, alpha: 0.5, gamma: 1.0, oracle: OracleKind::Full }
    }

    pub fn alpha(mut self, alpha: f64) -> NodeHyper {
        self.alpha = alpha;
        self
    }

    pub fn gamma(mut self, gamma: f64) -> NodeHyper {
        self.gamma = gamma;
        self
    }

    pub fn oracle(mut self, oracle: OracleKind) -> NodeHyper {
        self.oracle = oracle;
        self
    }
}

/// What one node reports to the leader at a recorded round.
#[derive(Clone, Debug)]
pub struct NodeReport {
    pub node: usize,
    pub round: usize,
    pub x: Vec<f64>,
    pub bytes_sent: u64,
    pub payload_bits: u64,
    pub grad_evals: u64,
}

/// Run a decentralized algorithm over node threads and return the unified
/// [`RunResult`] (identical shape to the matrix engine's). `build`
/// constructs the per-node halves — one call per node with that node's
/// gossip row (derived from the mixing operator's structure: one CSR row
/// walk per node on sparse graphs, so setup is O(nnz), not O(n²)).
/// Construction runs *inside* each node's thread (scoped), so per-node
/// init work — a full gradient at X⁰, SAGA's m-sample table — overlaps
/// across nodes instead of serializing on the leader.
///
/// The leader measures suboptimality against `x_star` at every snapshot
/// and evaluates `spec.stop` there — stop criteria beyond the round cap
/// therefore fire at `record_every` granularity (the leader cannot observe
/// rounds it never sees; use `record_every = 1` for round-exact stops).
/// `spec.schedule` is engine-only and rejected here; `spec.seed` is
/// resolved by the caller into `wire.seed`.
///
/// Divergence: a *gated* run (any stop criterion beyond the round cap)
/// stops the fleet at the next checkpoint with `StopReason::Diverged`,
/// beating every other criterion. An ungated run has no control channels
/// by design (zero leader round-trips on the fast path) — it completes
/// the round budget and labels a non-finite final iterate `Diverged`
/// post-hoc, unlike the engine, which truncates immediately.
///
/// The name-dispatching factory over an `Experiment` is
/// `exp::registry::build_node_algorithm`.
#[allow(clippy::too_many_arguments)]
pub fn run(
    w: &MixingOp,
    x0: &Mat,
    name: &str,
    wire: &CoordConfig,
    spec: &RunSpec,
    x_star: &[f64],
    probes: &mut [&mut dyn Probe],
    build: impl Fn(usize, WeightRow) -> Box<dyn NodeAlgorithm> + Sync,
) -> RunResult {
    run_with_transport(w, x0, name, wire, spec, x_star, probes, build, Transport::InProc)
}

/// [`run`], generic over the byte-stream transport. `Transport::InProc`
/// spawns node threads over [`sync`] channels — byte-identical to the
/// historical coordinator and fully visible to `proxlead-check`.
/// `Transport::Socket` instead accepts `n` node *processes* on a
/// pre-bound TCP/Unix listener (handshake: node id + config fingerprint
/// + run shape; mismatch → typed reject), relays their frames along the
/// mixing graph's edges, and folds every socket failure into the same
/// typed teardown ([`WireError::Transport`] →
/// [`StopReason::WireFault`]) — a dead peer yields a stop reason, never
/// a hang. See DESIGN.md §4e.
#[allow(clippy::too_many_arguments)]
pub fn run_with_transport(
    w: &MixingOp,
    x0: &Mat,
    name: &str,
    wire: &CoordConfig,
    spec: &RunSpec,
    x_star: &[f64],
    probes: &mut [&mut dyn Probe],
    build: impl Fn(usize, WeightRow) -> Box<dyn NodeAlgorithm> + Sync,
    transport: Transport,
) -> RunResult {
    let n = w.n();
    let rounds = spec.stop.max_rounds;
    assert_eq!(x0.rows, n);
    assert_eq!(x_star.len(), x0.cols, "x_star dimension must match the iterate width");
    assert!(rounds > 0, "coordinator run needs rounds >= 1 (0 would record no snapshots)");
    assert!(spec.record_every > 0, "record_every must be >= 1");
    assert!(
        spec.schedule.is_none(),
        "stepsize schedules are engine-only (node halves run fixed hyperparameters)"
    );
    // the wire header's `from` field is u16 — same bound as run_sim. The
    // typed-error guard lives in exp::validate_runtime_factories; this is
    // the library-level backstop.
    assert!(n <= u16::MAX as usize, "coordinator backend supports at most 65535 nodes (u16 ids)");
    let gated = spec.stop.leader_gated();
    #[allow(clippy::disallowed_methods)] // wall-clock run timing (see clippy.toml)
    let start = Instant::now();

    let out = match transport {
        Transport::InProc => {
            leader_inproc(w, x0, wire, spec, x_star, gated, start, probes, &build)
        }
        Transport::Socket { listener, fingerprint, accept_timeout } => leader_socket(
            w,
            x0,
            spec,
            x_star,
            gated,
            start,
            probes,
            listener,
            fingerprint,
            accept_timeout,
        ),
    };
    let LeaderOutcome { mut history, mut final_x, stopped_by, faults } = out;
    // deterministic fault resolution: several neighbors may report the
    // same corrupt broadcast — pick the earliest round, lowest node id
    let fault = faults.into_iter().min_by_key(|f| (f.round, f.node));
    if history.is_empty() {
        // a wire fault before the first complete snapshot: synthesize the
        // round-0 state from x0 so the RunResult invariants (non-empty
        // history, final iterate) hold and the fault is still reportable
        assert!(fault.is_some(), "no snapshots recorded — node threads died before reporting");
        let x = x0.clone();
        let m = MetricPoint {
            round: 0,
            grad_evals: 0,
            bits: 0,
            wire_bytes: 0,
            suboptimality: suboptimality(&x, x_star),
            consensus: x.consensus_error(),
            wall_ns: start.elapsed().as_nanos(),
        };
        crate::runner::emit(m, &x, &mut history, probes);
        final_x = Some(x);
    }
    let final_x = final_x.expect("final iterate tracked with every snapshot");
    let stopped_by = match (fault, stopped_by) {
        // a faulted run's history is truncated mid-flight; reporting any
        // other stop reason would misrepresent it
        (Some(f), _) => StopReason::WireFault(f),
        (None, Some(reason)) => reason,
        // ungated runs always complete the round budget; flag a
        // non-finite landing state as a divergence after the fact
        (None, None) if final_x.is_finite() => StopReason::MaxRounds,
        (None, None) => StopReason::Diverged,
    };

    let result = RunResult {
        name: name.to_string(),
        backend: Backend::Coordinator,
        history,
        stopped_by,
        elapsed: start.elapsed(),
        final_x,
    };
    crate::runner::finish(&result, probes);
    result
}

/// What a leader loop hands back to [`run_with_transport`]: everything the
/// shared RunResult-assembly tail needs, transport-agnostic.
struct LeaderOutcome {
    history: Vec<MetricPoint>,
    final_x: Option<Mat>,
    stopped_by: Option<StopReason>,
    faults: Vec<WireFault>,
}

/// The transport-agnostic leader: gather [`NodeEvent`]s until every node
/// finished every recorded round, flushing completed rounds in order and
/// issuing checkpoint verdicts. `next_event` returns `None` when all node
/// event sources have hung up; `send_verdict` delivers one go/stop verdict
/// to every node.
#[allow(clippy::too_many_arguments)]
fn leader_loop(
    n: usize,
    x0: &Mat,
    x_star: &[f64],
    spec: &RunSpec,
    gated: bool,
    start: Instant,
    probes: &mut [&mut dyn Probe],
    mut next_event: impl FnMut() -> Option<NodeEvent>,
    mut send_verdict: impl FnMut(bool),
) -> LeaderOutcome {
    let rounds = spec.stop.max_rounds;
    let mut pending: std::collections::BTreeMap<usize, Vec<Option<NodeReport>>> =
        std::collections::BTreeMap::new();
    let mut history: Vec<MetricPoint> = Vec::new();
    let mut final_x: Option<Mat> = None;
    let mut stopped_by: Option<StopReason> = None;
    // wire faults (possibly several nodes detecting the same corrupt
    // broadcast); resolved deterministically after the drain
    let mut faults: Vec<WireFault> = Vec::new();
    let mut released_on_fault = false;
    while let Some(ev) = next_event() {
        let rep = match ev {
            NodeEvent::Report(r) => r,
            NodeEvent::Fault(fa) => {
                faults.push(fa);
                // release checkpoint-blocked nodes, now and at their
                // next checkpoint: one queued `false` per node is
                // enough, a node stops at the first false it consumes
                if gated && !released_on_fault {
                    released_on_fault = true;
                    send_verdict(false);
                }
                continue;
            }
        };
        let slot = pending.entry(rep.round).or_insert_with(|| vec![None; n]);
        let node = rep.node;
        assert!(slot[node].is_none(), "duplicate report from node {node}");
        slot[node] = Some(rep);
        while let Some((&round, slots)) = pending.iter().next() {
            if !slots.iter().all(|s| s.is_some()) {
                break;
            }
            let slots = pending.remove(&round).unwrap();
            let mut x = Mat::zeros(n, x0.cols);
            let (mut bits, mut evals, mut bytes) = (0u64, 0u64, 0u64);
            for s in slots.into_iter().map(Option::unwrap) {
                x.row_mut(s.node).copy_from_slice(&s.x);
                // per-node counters are cumulative: the latest
                // snapshot's sum is the run total so far (the final
                // round is always reported, so this covers every frame
                // even when rounds % record_every != 0)
                bits += s.payload_bits;
                evals += s.grad_evals;
                bytes += s.bytes_sent;
            }
            // per-snapshot leader sampling: suboptimality vs the
            // reference, consensus, wall-clock — the engine's row
            let elapsed = start.elapsed();
            let m = MetricPoint {
                round,
                grad_evals: evals,
                bits,
                wire_bytes: bytes,
                suboptimality: suboptimality(&x, x_star),
                consensus: x.consensus_error(),
                wall_ns: elapsed.as_nanos(),
            };
            crate::runner::emit(m, &x, &mut history, probes);
            if gated && round > 0 {
                // first-hit-wins, divergence beating the budget checks
                // (a non-finite iterate can't recover — stop the fleet)
                let hit = if !x.is_finite() {
                    Some(StopReason::Diverged)
                } else {
                    spec.stop.check(round, bits, evals, m.suboptimality, elapsed)
                };
                if let Some(reason) = hit {
                    // MaxRounds is the natural end, not an early stop
                    if stopped_by.is_none() && reason != StopReason::MaxRounds {
                        stopped_by = Some(reason);
                    }
                }
                // checkpoint verdict: every node blocks after a
                // record_every-multiple before the final round
                if round % spec.record_every == 0 && round < rounds {
                    let go = stopped_by.is_none() && faults.is_empty();
                    send_verdict(go);
                }
            }
            final_x = Some(x);
        }
    }
    LeaderOutcome { history, final_x, stopped_by, faults }
}

/// In-process leader: node threads over [`sync`] channels, the historical
/// coordinator wiring. Stays fully visible to `proxlead-check` (channel
/// site labels `coord.inbox` / `coord.ctrl` / `coord.reports`).
#[allow(clippy::too_many_arguments)]
fn leader_inproc(
    w: &MixingOp,
    x0: &Mat,
    wire: &CoordConfig,
    spec: &RunSpec,
    x_star: &[f64],
    gated: bool,
    start: Instant,
    probes: &mut [&mut dyn Probe],
    build: &(impl Fn(usize, WeightRow) -> Box<dyn NodeAlgorithm> + Sync),
) -> LeaderOutcome {
    let n = w.n();
    // per-node inboxes; every node gets a Sender clone for each neighbor.
    // Frames travel as Arc<[u8]>: one refcounted buffer per broadcast
    // instead of one Vec clone per neighbor.
    let mut txs = Vec::with_capacity(n);
    let mut rxs = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = sync::channel::<Arc<[u8]>>("coord.inbox");
        txs.push(tx);
        rxs.push(rx);
    }
    // leader → node control channels (only wired when gating is on)
    let mut ctrl_txs = Vec::with_capacity(n);
    let mut ctrl_rxs: Vec<Option<sync::Receiver<bool>>> = Vec::with_capacity(n);
    for _ in 0..n {
        if gated {
            let (tx, rx) = sync::channel::<bool>("coord.ctrl");
            ctrl_txs.push(tx);
            ctrl_rxs.push(Some(rx));
        } else {
            ctrl_rxs.push(None);
        }
    }
    let (report_tx, report_rx) = sync::channel::<NodeEvent>("coord.reports");

    thread::scope(|scope| {
        let mut handles = Vec::with_capacity(n);
        for (i, (rx, ctrl)) in rxs.into_iter().zip(ctrl_rxs).enumerate() {
            let row = WeightRow::from_op(w, i);
            // per-edge senders, aligned with the gossip row (ascending j)
            let edge_txs: Vec<sync::Sender<Arc<[u8]>>> =
                row.neighbors.iter().map(|&(j, _)| txs[j].clone()).collect();
            let neighbors: Vec<usize> = row.neighbors.iter().map(|&(j, _)| j).collect();
            let link = InProcLink::new(edge_txs, rx, report_tx.clone(), ctrl);
            let node_cfg = NodeConfig {
                id: i,
                neighbors,
                link: Box::new(link),
                wire: wire.clone(),
                rounds: spec.stop.max_rounds,
                record_every: spec.record_every,
                dim: x0.cols,
            };
            handles.push(sync::spawn_scoped(scope, &format!("node-{i}"), move || {
                node::run_node(build(i, row), node_cfg)
            }));
        }
        drop(report_tx);
        drop(txs);

        let out = leader_loop(
            n,
            x0,
            x_star,
            spec,
            gated,
            start,
            probes,
            || report_rx.recv().ok(),
            |go| {
                for tx in &ctrl_txs {
                    // a node that already exited is not an error
                    let _ = tx.send(go);
                }
            },
        );
        // under proxlead-check: wait for every node thread to exit so the
        // joins below never block the schedule token
        sync::pre_join();
        for h in handles {
            h.join().expect("node thread panicked");
        }
        out
    })
}

/// Socket leader: accept `n` remote node processes, then relay frames
/// between them along the mixing graph while feeding reports/faults into
/// the shared [`leader_loop`]. The kernel does the buffering a [`sync`]
/// channel would — these reader threads deliberately bypass the
/// checker-visible shim (a socket `read` can't be scheduled by
/// `proxlead-check`); the InProc arm keeps full checker coverage.
#[allow(clippy::too_many_arguments)]
fn leader_socket(
    w: &MixingOp,
    x0: &Mat,
    spec: &RunSpec,
    x_star: &[f64],
    gated: bool,
    start: Instant,
    probes: &mut [&mut dyn Probe],
    listener: socket::Listener,
    fingerprint: u64,
    accept_timeout: Duration,
) -> LeaderOutcome {
    let n = w.n();
    let hello = Hello {
        fingerprint,
        n: n as u32,
        dim: x0.cols as u32,
        rounds: spec.stop.max_rounds as u32,
        record_every: spec.record_every as u32,
        gated,
    };
    // setup failures surface as a round-0 wire fault on the node that
    // failed to join: the shared tail turns it into StopReason::WireFault
    let fail = |te: TransportError, node: u16| LeaderOutcome {
        history: Vec::new(),
        final_x: None,
        stopped_by: None,
        faults: vec![WireFault { node, round: 0, error: WireError::Transport(te) }],
    };
    let streams = match socket::accept_nodes(&listener, &hello, accept_timeout) {
        Ok(s) => s,
        Err(te) => {
            let node = match te {
                TransportError::HandshakeTimeout { missing } => missing,
                _ => 0,
            };
            return fail(te, node);
        }
    };
    let (readers, writers) = match socket::split(streams) {
        Ok(rw) => rw,
        Err(te) => return fail(te, 0),
    };
    let (ev_tx, ev_rx) = std::sync::mpsc::channel::<NodeEvent>();
    thread::scope(|scope| {
        for (i, reader) in readers.into_iter().enumerate() {
            let neighbors: Vec<usize> = w.neighbors(i).iter().map(|&(j, _)| j).collect();
            let writers = &writers;
            let ev_tx = ev_tx.clone();
            thread::Builder::new()
                .name(format!("uplink-{i}"))
                .spawn_scoped(scope, move || {
                    socket::run_uplink(i as u16, reader, &neighbors, writers, &ev_tx);
                })
                .expect("spawn uplink thread");
        }
        // each uplink thread holds a clone; dropping ours makes ev_rx hang
        // up exactly when the last socket closes
        drop(ev_tx);
        let mut vbuf = Vec::new();
        leader_loop(n, x0, x_star, spec, gated, start, probes, || ev_rx.recv().ok(), |go| {
            socket::send_verdicts(&writers, go, &mut vbuf)
        })
    })
}

/// Distributed Prox-LEAD over node threads — the historical hand-wired
/// entry point, kept as a thin shim over the algorithm-generic [`run`] for
/// sequence-pinning tests. `problem` supplies every node's data (as the
/// per-machine shards would in a real deployment); `prox` is the shared
/// non-smooth term; `x0` the common start iterate.
#[deprecated(note = "use Experiment::run_coordinator(&RunSpec), or coordinator::run with a \
                     node factory — this shim exists for sequence-pinning tests")]
#[allow(clippy::too_many_arguments)]
pub fn run_prox_lead(
    problem: Arc<dyn Problem>,
    w: &MixingOp,
    x0: &Mat,
    prox: Arc<dyn Prox>,
    hyper: &NodeHyper,
    wire: &CoordConfig,
    spec: &RunSpec,
    x_star: &[f64],
) -> RunResult {
    assert_eq!(problem.num_nodes(), w.n());
    run(w, x0, "prox-lead", wire, spec, x_star, &mut [], |_, row| {
        Box::new(ProxLeadNode::new(Arc::clone(&problem), Arc::clone(&prox), x0, row, hyper, wire))
    })
}

#[cfg(test)]
#[allow(deprecated)] // the pins below intentionally drive the run_prox_lead shim
mod tests {
    use super::*;
    use crate::algorithm::testkit::{ring_logreg, safe_eta};
    use crate::algorithm::{solve_reference, Algorithm, ProxLead};
    use crate::compress::Identity;
    use crate::prox::{Zero, L1};

    /// Sub-sampled suboptimality trace from the unified history.
    fn trace(res: &RunResult) -> Vec<(usize, f64)> {
        res.history.iter().map(|m| (m.round, m.suboptimality)).collect()
    }

    #[test]
    fn leader_matches_matrix_engine_bit_for_bit() {
        // exact codec + full gradient: node-thread iterates must equal the
        // Experiment-built matrix engine's bit for bit (the slots-before-
        // mixing barrier makes the gossip summation order identical to the
        // engine kernels; the 9-algorithm matrix version of this test lives
        // in rust/tests/coordinator_parity.rs)
        let exp = crate::algorithm::testkit::ring_exp();
        let x_star = vec![0.0; exp.problem.dim()];
        let wire = CoordConfig::new(WireCodec::Dense64).seed(42);
        let res = run_prox_lead(
            Arc::clone(&exp.problem),
            &exp.mixing,
            &exp.x0,
            Arc::new(Zero),
            &NodeHyper::new(exp.hyper.eta),
            &wire,
            &RunSpec::fixed(40).every(40),
            &x_star,
        );

        let mut matrix =
            ProxLead::builder(&exp).compressor(Box::new(Identity::f64())).seed(42).build();
        for _ in 0..40 {
            matrix.step(exp.problem.as_ref());
        }
        for (i, (a, b)) in res.final_x.data.iter().zip(&matrix.x().data).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "entry {i}: {a:?} vs {b:?}");
        }
        assert_eq!(res.backend, Backend::Coordinator);
        assert_eq!(res.stopped_by, StopReason::MaxRounds);
    }

    #[test]
    fn experiment_coordinator_matches_explicit_wiring() {
        // the Experiment-level coordinator entry point drives the same run
        // the hand-wired shim produces, bit for bit, through the unified
        // RunResult
        let mut cfg = crate::config::Config::parse(
            "nodes = 4\nsamples_per_node = 24\ndim = 5\nclasses = 3\nbatches = 4\n\
             separation = 1.0\nseed = 33\nlambda1 = 0.005\nlambda2 = 0.1\nbits = 2\n",
        )
        .unwrap();
        cfg.rounds = 60;
        cfg.record_every = 20;
        let exp = crate::exp::Experiment::from_config(&cfg).unwrap();
        let via_exp = exp.run_coordinator(&exp.run_spec());

        let x_star = exp.reference();
        let wire = CoordConfig::new(WireCodec::Quant(2, 256)).seed(33);
        let explicit = run_prox_lead(
            Arc::clone(&exp.problem),
            &exp.mixing,
            &exp.x0,
            Arc::new(L1::new(5e-3)),
            &NodeHyper::new(exp.hyper.eta),
            &wire,
            &RunSpec::fixed(60).every(20),
            &x_star,
        );
        assert_eq!(via_exp.history.len(), explicit.history.len());
        for (a, b) in via_exp.history.iter().zip(&explicit.history) {
            assert_eq!((a.round, a.bits, a.grad_evals), (b.round, b.bits, b.grad_evals));
            assert_eq!(a.wire_bytes, b.wire_bytes);
            assert_eq!(a.suboptimality.to_bits(), b.suboptimality.to_bits());
        }
        for (a, b) in via_exp.final_x.data.iter().zip(&explicit.final_x.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn sparse_and_dense_channels_yield_identical_runs() {
        // CSR-derived per-edge channels must reproduce the dense-derived
        // run bit for bit (same neighbor order, same weights)
        let (p, _) = ring_logreg();
        use crate::problem::Problem;
        let g = crate::graph::Graph::ring(4);
        let rule = crate::graph::MixingRule::UniformMaxDegree;
        let x0 = Mat::zeros(4, p.dim());
        let x_star = vec![0.0; p.dim()];
        let hyper = NodeHyper::new(safe_eta(&p));
        let p_arc: Arc<dyn crate::problem::Problem> = Arc::new(p);
        let wire = CoordConfig::new(WireCodec::Quant(2, 256));
        let spec = RunSpec::fixed(200).every(50);
        let dense = run_prox_lead(
            Arc::clone(&p_arc),
            &crate::graph::MixingOp::dense_from(&g, rule),
            &x0,
            Arc::new(Zero),
            &hyper,
            &wire,
            &spec,
            &x_star,
        );
        let sparse = run_prox_lead(
            Arc::clone(&p_arc),
            &crate::graph::MixingOp::sparse_from(&g, rule),
            &x0,
            Arc::new(Zero),
            &hyper,
            &wire,
            &spec,
            &x_star,
        );
        assert_eq!(dense.history.len(), sparse.history.len());
        for (a, b) in dense.history.iter().zip(&sparse.history) {
            assert_eq!((a.round, a.bits, a.grad_evals), (b.round, b.bits, b.grad_evals));
            assert_eq!(a.suboptimality.to_bits(), b.suboptimality.to_bits());
        }
        for (a, b) in dense.final_x.data.iter().zip(&sparse.final_x.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn quantized_coordinator_converges_composite() {
        let (p, w) = ring_logreg();
        use crate::problem::Problem;
        let x_star = solve_reference(&p, 5e-3, 40_000, 1e-13);
        let x0 = Mat::zeros(4, p.dim());
        let hyper = NodeHyper::new(safe_eta(&p));
        let p_arc: Arc<dyn crate::problem::Problem> = Arc::new(p);
        let wire = CoordConfig::new(WireCodec::Quant(2, 256));
        let res = run_prox_lead(
            p_arc,
            &w,
            &x0,
            Arc::new(L1::new(5e-3)),
            &hyper,
            &wire,
            &RunSpec::fixed(3000).every(500),
            &x_star,
        );
        let s = res.final_subopt();
        assert!(s < 1e-12, "distributed Prox-LEAD 2bit suboptimality: {s}");
        assert!(res.wire_bytes() > 0);
        // trace is decreasing overall (round 0 is the descent baseline)
        let t = trace(&res);
        assert_eq!(t.first().unwrap().0, 0);
        assert!(t.last().unwrap().1 < t[1].1 * 1e-6);
    }

    #[test]
    fn straggler_injection_slows_but_converges() {
        let (p, w) = ring_logreg();
        use crate::problem::Problem;
        let x_star = solve_reference(&p, 0.0, 40_000, 1e-13);
        let x0 = Mat::zeros(4, p.dim());
        let hyper = NodeHyper::new(safe_eta(&p));
        let p_arc: Arc<dyn crate::problem::Problem> = Arc::new(p);
        let wire = CoordConfig::new(WireCodec::Quant(2, 256))
            .straggler(Straggler { prob: 0.05, delay: Duration::from_micros(300) });
        let res = run_prox_lead(
            p_arc,
            &w,
            &x0,
            Arc::new(Zero),
            &hyper,
            &wire,
            &RunSpec::fixed(150).every(150),
            &x_star,
        );
        let s = res.final_subopt();
        assert!(s.is_finite() && s < 1.0, "straggler run must stay sound: {s}");
        assert_eq!(res.history.len(), 2); // round 0 + the final round
    }

    #[test]
    fn stochastic_oracles_work_across_threads() {
        let (p, w) = ring_logreg();
        use crate::problem::Problem;
        let x_star = solve_reference(&p, 0.0, 40_000, 1e-13);
        let x0 = Mat::zeros(4, p.dim());
        let p_arc: Arc<dyn crate::problem::Problem> = Arc::new(p);
        let hyper =
            NodeHyper::new(1.0 / (6.0 * p_arc.smoothness())).oracle(OracleKind::Saga);
        let wire = CoordConfig::new(WireCodec::Quant(2, 256));
        let res = run_prox_lead(
            p_arc,
            &w,
            &x0,
            Arc::new(Zero),
            &hyper,
            &wire,
            &RunSpec::fixed(4000).every(1000),
            &x_star,
        );
        let s = res.final_subopt();
        assert!(s < 1e-8, "distributed LEAD-SAGA suboptimality: {s}");
        // grad evals include per-node SAGA init (m per node)
        assert!(res.history.last().unwrap().grad_evals >= 4000);
    }

    #[test]
    #[should_panic(expected = "rounds >= 1")]
    fn zero_rounds_is_a_clear_error_at_entry() {
        // regression: rounds = 0 used to run to completion with an empty
        // snapshot list, deferring the panic to the final-iterate accessor
        let (p, w) = ring_logreg();
        use crate::problem::Problem;
        let x0 = Mat::zeros(4, p.dim());
        let x_star = vec![0.0; p.dim()];
        let _ = run_prox_lead(
            Arc::new(p),
            &w,
            &x0,
            Arc::new(Zero),
            &NodeHyper::new(0.05),
            &CoordConfig::new(WireCodec::Dense64),
            &RunSpec::fixed(0),
            &x_star,
        );
    }

    #[test]
    fn final_round_reported_when_rounds_not_divisible_by_record_every() {
        // bookkeeping pin: the run totals (wire bytes, payload bits, grad
        // evals) must cover every round — nodes always report round
        // `rounds`, like the engine's final-round rule
        let (p, w) = ring_logreg();
        use crate::problem::Problem;
        let x0 = Mat::zeros(4, p.dim());
        let x_star = vec![0.0; p.dim()];
        let hyper = NodeHyper::new(safe_eta(&p));
        let p_arc: Arc<dyn crate::problem::Problem> = Arc::new(p);
        let wire = CoordConfig::new(WireCodec::Quant(2, 256));
        let mk = |record_every: usize| {
            run_prox_lead(
                Arc::clone(&p_arc),
                &w,
                &x0,
                Arc::new(Zero),
                &hyper,
                &wire,
                &RunSpec::fixed(7).every(record_every),
                &x_star,
            )
        };
        let thinned = mk(3); // 7 % 3 != 0: rounds 0, 3, 6, then the final 7
        let dense = mk(1); // every round: ground truth totals
        let rounds: Vec<usize> = thinned.history.iter().map(|m| m.round).collect();
        assert_eq!(rounds, vec![0, 3, 6, 7]);
        let (t, d) = (thinned.history.last().unwrap(), dense.history.last().unwrap());
        assert_eq!(t.wire_bytes, d.wire_bytes, "wire byte totals must not undercount");
        assert_eq!((t.bits, t.grad_evals), (d.bits, d.grad_evals), "totals must cover 7 rounds");
        for (a, b) in thinned.final_x.data.iter().zip(&dense.final_x.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn round_zero_snapshot_is_the_post_init_state() {
        // the coordinator history now starts at round 0 like the engine's:
        // the post-construction iterate, zero wire traffic (for setup-free
        // algorithms), init-cost grad evals
        let (p, w) = ring_logreg();
        use crate::problem::Problem;
        let x0 = Mat::zeros(4, p.dim());
        let x_star = vec![0.0; p.dim()];
        let p_arc: Arc<dyn crate::problem::Problem> = Arc::new(p);
        let res = run_prox_lead(
            Arc::clone(&p_arc),
            &w,
            &x0,
            Arc::new(Zero),
            &NodeHyper::new(0.05),
            &CoordConfig::new(WireCodec::Dense64),
            &RunSpec::fixed(5),
            &x_star,
        );
        let first = res.history.first().unwrap();
        assert_eq!(first.round, 0);
        assert_eq!(first.bits, 0);
        assert_eq!(first.wire_bytes, 0);
        assert!(first.grad_evals > 0, "round 0 carries the init gradient cost");
        assert_eq!(res.history.len(), 6); // rounds 0..=5
    }
}
