//! The message-passing coordinator — the "real" distributed runtime.
//!
//! Each node is a thread owning its Prox-LEAD state (x, z, d, h, h_w) and
//! a single-node SGO; neighbors exchange *serialized* compressed frames
//! over per-edge channels (the paper's 8-machine ring becomes 8 node
//! threads; see DESIGN.md §4 on why this preserves the iterate sequence).
//! The leader thread collects per-round metrics and assembles the same
//! history the matrix engine produces — `leader_matches_matrix_engine`
//! pins the two implementations to identical iterates.
//!
//! Fault injection: an optional straggler model (per-message delay with
//! probability `p`) exercises the synchronous-round barrier under skew.

pub mod node;
pub mod wire;

pub use node::NodeConfig;
pub use wire::{Frame, WireCodec};

use crate::graph::MixingOp;
use crate::linalg::Mat;
use crate::oracle::OracleKind;
use crate::problem::Problem;
use crate::prox::Prox;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

/// Straggler fault model: each outgoing message is delayed by `delay`
/// with probability `prob`.
#[derive(Clone, Copy, Debug)]
pub struct Straggler {
    pub prob: f64,
    pub delay: Duration,
}

/// Coordinator run configuration.
#[derive(Clone)]
pub struct CoordConfig {
    pub rounds: usize,
    pub record_every: usize,
    pub eta: f64,
    pub alpha: f64,
    pub gamma: f64,
    pub codec: WireCodec,
    pub oracle: OracleKind,
    pub seed: u64,
    pub straggler: Option<Straggler>,
}

impl CoordConfig {
    pub fn new(rounds: usize, eta: f64, codec: WireCodec) -> CoordConfig {
        CoordConfig {
            rounds,
            record_every: 1,
            eta,
            alpha: 0.5,
            gamma: 1.0,
            codec,
            oracle: OracleKind::Full,
            seed: 42,
            straggler: None,
        }
    }
}

/// What one node reports to the leader at a recorded round.
#[derive(Clone, Debug)]
pub struct NodeReport {
    pub node: usize,
    pub round: usize,
    pub x: Vec<f64>,
    pub bytes_sent: u64,
    pub payload_bits: u64,
    pub grad_evals: u64,
}

/// Leader-side aggregated history.
#[derive(Clone, Debug)]
pub struct CoordResult {
    /// (round, stacked X, cumulative payload bits, cumulative grad evals).
    pub snapshots: Vec<(usize, Mat, u64, u64)>,
    /// Total wall-clock.
    pub elapsed: Duration,
    /// Total framed wire bytes (headers included) across all nodes.
    pub wire_bytes: u64,
}

impl CoordResult {
    pub fn final_x(&self) -> &Mat {
        &self.snapshots.last().expect("at least one snapshot").1
    }

    /// Suboptimality trace vs a reference solution.
    pub fn suboptimality(&self, x_star: &[f64]) -> Vec<(usize, f64)> {
        self.snapshots
            .iter()
            .map(|(r, x, _, _)| (*r, crate::algorithm::suboptimality(x, x_star)))
            .collect()
    }
}

/// Run distributed Prox-LEAD over node threads. `problem` supplies every
/// node's data (as the per-machine shards would in a real deployment);
/// `prox` is the shared non-smooth term; `x0` the common start iterate.
/// Per-edge channels and neighbor weights are derived from the mixing
/// operator's structure — one CSR row walk per node on sparse graphs, so
/// setup is O(nnz), not O(n²).
pub fn run(
    problem: Arc<dyn Problem>,
    w: &MixingOp,
    x0: &Mat,
    prox: Arc<dyn Prox>,
    cfg: &CoordConfig,
) -> CoordResult {
    let n = problem.num_nodes();
    assert_eq!(w.n(), n);
    assert_eq!(x0.rows, n);
    let start = Instant::now();

    // per-node inboxes; every node gets a Sender clone for each neighbor
    let mut txs = Vec::with_capacity(n);
    let mut rxs = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = mpsc::channel::<Vec<u8>>();
        txs.push(tx);
        rxs.push(rx);
    }
    let (report_tx, report_rx) = mpsc::channel::<NodeReport>();

    let mut handles = Vec::with_capacity(n);
    for (i, rx) in rxs.into_iter().enumerate() {
        // neighbor senders + mixing weights (w_ij ≠ 0, j ≠ i), ascending j
        let neighbors: Vec<(usize, f64, mpsc::Sender<Vec<u8>>)> = w
            .neighbors(i)
            .into_iter()
            .map(|(j, wij)| (j, wij, txs[j].clone()))
            .collect();
        let node_cfg = NodeConfig {
            id: i,
            self_weight: w.self_weight(i),
            neighbors,
            inbox: rx,
            reports: report_tx.clone(),
            cfg: cfg.clone(),
        };
        let problem = Arc::clone(&problem);
        let prox = Arc::clone(&prox);
        let x0_all = x0.clone();
        handles.push(
            thread::Builder::new()
                .name(format!("node-{i}"))
                .spawn(move || node::run_node(problem, prox, &x0_all, node_cfg))
                .expect("spawn node thread"),
        );
    }
    drop(report_tx);
    drop(txs);

    // leader: gather reports until every node finished every recorded round
    let mut pending: std::collections::BTreeMap<usize, Vec<Option<NodeReport>>> =
        std::collections::BTreeMap::new();
    let mut snapshots = Vec::new();
    let mut wire_bytes = 0u64;
    while let Ok(rep) = report_rx.recv() {
        let slot = pending.entry(rep.round).or_insert_with(|| vec![None; n]);
        let node = rep.node;
        assert!(slot[node].is_none(), "duplicate report from node {node}");
        slot[node] = Some(rep);
        // flush completed rounds in order
        while let Some((&round, slots)) = pending.iter().next() {
            if !slots.iter().all(|s| s.is_some()) {
                break;
            }
            let slots = pending.remove(&round).unwrap();
            let mut x = Mat::zeros(n, x0.cols);
            let (mut bits, mut evals, mut bytes) = (0u64, 0u64, 0u64);
            for s in slots.into_iter().map(Option::unwrap) {
                x.row_mut(s.node).copy_from_slice(&s.x);
                bits += s.payload_bits;
                evals += s.grad_evals;
                bytes += s.bytes_sent;
            }
            // per-node counters are cumulative: the latest snapshot's sum
            // is the run total so far
            wire_bytes = bytes;
            snapshots.push((round, x, bits, evals));
        }
    }
    for h in handles {
        h.join().expect("node thread panicked");
    }

    CoordResult { snapshots, elapsed: start.elapsed(), wire_bytes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithm::testkit::{ring_logreg, safe_eta};
    use crate::algorithm::{solve_reference, suboptimality, Algorithm, ProxLead};
    use crate::compress::Identity;
    use crate::prox::{Zero, L1};

    #[test]
    fn leader_matches_matrix_engine_exactly() {
        // identity codec + full gradient is deterministic: node-thread
        // iterates must equal the Experiment-built matrix engine's bit
        // for bit (the fixture's auto-η is the same 1/(2L))
        let exp = crate::algorithm::testkit::ring_exp();
        let cfg = CoordConfig::new(40, exp.hyper.eta, WireCodec::Dense64);
        let res = run(Arc::clone(&exp.problem), &exp.mixing, &exp.x0, Arc::new(Zero), &cfg);

        let mut matrix =
            ProxLead::builder(&exp).compressor(Box::new(Identity::f64())).seed(1).build();
        for _ in 0..40 {
            matrix.step(exp.problem.as_ref());
        }
        let coord_x = res.final_x();
        let diff = coord_x.dist_sq(matrix.x());
        assert!(diff < 1e-22, "coordinator vs matrix engine drift: {diff}");
    }

    #[test]
    fn experiment_coordinator_matches_explicit_wiring() {
        // the Experiment-level coordinator entry point drives the same run
        // the hand-wired CoordConfig produces, bit for bit
        let mut cfg = crate::config::Config::parse(
            "nodes = 4\nsamples_per_node = 24\ndim = 5\nclasses = 3\nbatches = 4\n\
             separation = 1.0\nseed = 33\nlambda1 = 0.005\nlambda2 = 0.1\nbits = 2\n",
        )
        .unwrap();
        cfg.rounds = 60;
        cfg.record_every = 20;
        let exp = crate::exp::Experiment::from_config(&cfg).unwrap();
        let via_exp = exp.coordinator();

        let mut ccfg = CoordConfig::new(60, exp.hyper.eta, WireCodec::Quant(2, 256));
        ccfg.record_every = 20;
        ccfg.seed = 33;
        let explicit = run(
            Arc::clone(&exp.problem),
            &exp.mixing,
            &exp.x0,
            Arc::new(L1::new(5e-3)),
            &ccfg,
        );
        assert_eq!(via_exp.snapshots.len(), explicit.snapshots.len());
        for ((ra, xa, ba, ea), (rb, xb, bb, eb)) in
            via_exp.snapshots.iter().zip(&explicit.snapshots)
        {
            assert_eq!((ra, ba, ea), (rb, bb, eb));
            for (a, b) in xa.data.iter().zip(&xb.data) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn sparse_and_dense_channels_yield_identical_runs() {
        // CSR-derived per-edge channels must reproduce the dense-derived
        // run bit for bit (same neighbor order, same weights)
        let (p, _) = ring_logreg();
        use crate::problem::Problem;
        let g = crate::graph::Graph::ring(4);
        let rule = crate::graph::MixingRule::UniformMaxDegree;
        let x0 = Mat::zeros(4, p.dim());
        let eta = safe_eta(&p);
        let p_arc: Arc<dyn crate::problem::Problem> = Arc::new(p);
        let mut cfg = CoordConfig::new(200, eta, WireCodec::Quant(2, 256));
        cfg.record_every = 50;
        let dense = run(
            Arc::clone(&p_arc),
            &crate::graph::MixingOp::dense_from(&g, rule),
            &x0,
            Arc::new(Zero),
            &cfg,
        );
        let sparse = run(
            Arc::clone(&p_arc),
            &crate::graph::MixingOp::sparse_from(&g, rule),
            &x0,
            Arc::new(Zero),
            &cfg,
        );
        assert_eq!(dense.snapshots.len(), sparse.snapshots.len());
        for ((rd, xd, bd, ed), (rs, xs, bs, es)) in
            dense.snapshots.iter().zip(&sparse.snapshots)
        {
            assert_eq!((rd, bd, ed), (rs, bs, es));
            for (a, b) in xd.data.iter().zip(&xs.data) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn quantized_coordinator_converges_composite() {
        let (p, w) = ring_logreg();
        use crate::problem::Problem;
        let x_star = solve_reference(&p, 5e-3, 40_000, 1e-13);
        let x0 = Mat::zeros(4, p.dim());
        let eta = safe_eta(&p);
        let p_arc: Arc<dyn crate::problem::Problem> = Arc::new(p);
        let mut cfg = CoordConfig::new(3000, eta, WireCodec::Quant(2, 256));
        cfg.record_every = 500;
        let res = run(p_arc, &w, &x0, Arc::new(L1::new(5e-3)), &cfg);
        let s = suboptimality(res.final_x(), &x_star);
        assert!(s < 1e-12, "distributed Prox-LEAD 2bit suboptimality: {s}");
        assert!(res.wire_bytes > 0);
        // trace is decreasing overall
        let trace = res.suboptimality(&x_star);
        assert!(trace.last().unwrap().1 < trace.first().unwrap().1 * 1e-6);
    }

    #[test]
    fn straggler_injection_slows_but_converges() {
        let (p, w) = ring_logreg();
        use crate::problem::Problem;
        let x_star = solve_reference(&p, 0.0, 40_000, 1e-13);
        let x0 = Mat::zeros(4, p.dim());
        let eta = safe_eta(&p);
        let p_arc: Arc<dyn crate::problem::Problem> = Arc::new(p);
        let mut cfg = CoordConfig::new(150, eta, WireCodec::Quant(2, 256));
        cfg.record_every = 150;
        cfg.straggler = Some(Straggler { prob: 0.05, delay: Duration::from_micros(300) });
        let res = run(p_arc, &w, &x0, Arc::new(Zero), &cfg);
        let s = suboptimality(res.final_x(), &x_star);
        assert!(s.is_finite() && s < 1.0, "straggler run must stay sound: {s}");
        assert_eq!(res.snapshots.len(), 1);
    }

    #[test]
    fn stochastic_oracles_work_across_threads() {
        let (p, w) = ring_logreg();
        use crate::problem::Problem;
        let x_star = solve_reference(&p, 0.0, 40_000, 1e-13);
        let x0 = Mat::zeros(4, p.dim());
        let p_arc: Arc<dyn crate::problem::Problem> = Arc::new(p);
        let mut cfg =
            CoordConfig::new(4000, 1.0 / (6.0 * p_arc.smoothness()), WireCodec::Quant(2, 256));
        cfg.record_every = 1000;
        cfg.oracle = OracleKind::Saga;
        let res = run(p_arc, &w, &x0, Arc::new(Zero), &cfg);
        let s = suboptimality(res.final_x(), &x_star);
        assert!(s < 1e-8, "distributed LEAD-SAGA suboptimality: {s}");
        // grad evals include per-node SAGA init (m per node)
        let (_, _, _, evals) = res.snapshots.last().unwrap();
        assert!(*evals >= 4000);
    }
}
