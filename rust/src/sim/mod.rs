//! Event-driven massive-n simulation backend: the third runner.
//!
//! The message-passing coordinator spawns one OS thread per node — faithful
//! to a real deployment, and hard-capped around n ≤ 64. This backend drives
//! the *same* per-node [`NodeAlgorithm`] halves over the *same* zero-alloc
//! wire codec path at n = 100k–1M by replacing threads-and-channels with a
//! sharded round loop over the CSR mixing structure:
//!
//! ```text
//!   round k (all participants, fixed pool of min(cores, n) threads):
//!     phase A — claim contiguous node shards; per node i:
//!                 outgoing → frame_begin/encode_into/frame_end →
//!                 FrameRef::parse → decode_into → shared slot row q[i]
//!     ── barrier ──
//!     phase B — claim shards; per node i:
//!                 copy neighbor slot rows q[j] into reused peer scratch,
//!                 alg.update(q[i], peers)
//!     ── barrier ──
//!     main thread only: snapshot / StopSet / probes on the engine's
//!     record grid, then release the pool into round k+1
//! ```
//!
//! **Bit-parity with engine and coordinator.** Two contracts compose:
//! [`WeightRow::mix_into`] reproduces the engine's ascending-j summation
//! order, and the codec decode path is deterministic — the sender-side
//! decode of a frame and every receiver's decode agree bit-exactly (see
//! [`crate::coordinator::wire`]). The second contract is the lever that
//! makes an O(n·d)-memory simulation possible at all: instead of decoding
//! each broadcast once per *edge* (what the coordinator's receivers do),
//! the sim parses and decodes each frame exactly once per *broadcast* into
//! a shared n×p slot matrix, and phase B reads neighbor rows from there.
//! Per-node compression dither streams are reproduced exactly
//! (`Rng::new(seed).fork(i)`, same as `run_node`), so under `Dense64` the
//! sim is bit-identical to both other backends, and under lossy codecs it
//! is bit-identical to the coordinator's arithmetic (`rust/tests/
//! sim_parity.rs` pins the full 9-algorithm matrix).
//!
//! **Memory is O(nnz + n·d).** Per node: the algorithm half's own state
//! (O(d) each), one reused frame buffer, one slot row, one RNG. Per run:
//! the CSR neighbor structure (nnz ids + n+1 offsets) and one n×d snapshot
//! matrix. Per participant: O(max_degree·d) peer scratch. No per-node
//! threads, no per-node channels, no per-node history.
//!
//! **Zero allocation per warmed-up round.** All buffers above are
//! allocated before the round loop; the loop itself runs on reused scratch,
//! atomics, and `Barrier::wait`. Snapshots are the documented exception
//! (they push one `MetricPoint` into a pre-sized history and may touch
//! probe code); `rust/tests/sim_zero_alloc.rs` pins the non-snapshot
//! rounds at exactly zero allocations via a counting global allocator.
//!
//! **What is simulated away.** Stragglers (`CoordConfig::straggler`) are a
//! wall-clock transport phenomenon with no arithmetic effect, so the sim
//! ignores them. Frame tamper *is* honored, but detection happens at the
//! broadcast site (the one shared decode) rather than at each receiver:
//! the resulting [`WireFault`] carries the *sender's* id, the faulted
//! round is discarded exactly like the coordinator's (history truncates at
//! the last complete snapshot), and `stopped_by` reports the fault the
//! same way. Node ids ride the frame format's u16 `from` field, so the sim
//! refuses n > 65535 outright: config-driven runs get a typed
//! [`crate::exp::ConfigError`] at validation and [`run_with_workers`]
//! asserts at entry — a truncated sender id must never reach a
//! [`WireFault`] report.
//!
//! **Checked synchronization.** Every atomic, barrier, and spawn below
//! goes through the [`crate::runtime::sync`] shim layer, so
//! `proxlead-check` (see [`crate::check`] and DESIGN.md §6b) can replay
//! the whole phase protocol under controlled schedules; in production the
//! shims are transparent wrappers. Each `Ordering::Relaxed` call site
//! carries a `lint:allow(atomic-ordering)` justification tied to the
//! happens-before argument the checker verifies.

use crate::algorithm::suboptimality;
use crate::coordinator::node;
use crate::coordinator::wire::{self, Frame, FrameRef, WireCodec, WireError, WireFault};
use crate::coordinator::{CoordConfig, FrameTamper, NodeAlgorithm, WeightRow};
use crate::graph::MixingOp;
use crate::linalg::Mat;
use crate::runner::{Backend, MetricPoint, Probe, RunResult, RunSpec, StopReason};
use crate::runtime::sync::{self, AtomicBool, AtomicUsize, Barrier};
use crate::util::rng::Rng;
use std::cell::UnsafeCell;
use std::sync::atomic::Ordering;
use std::sync::Mutex;
use std::thread;
use std::time::Instant;

/// Shard granularity for the work-claiming counters: big enough that a
/// `fetch_add` amortizes over cache-friendly contiguous work, small enough
/// that a ring at n = 1024 still load-balances across a desktop's cores.
const CHUNK: usize = 64;

/// A `Vec` whose *elements* are individually handed out as `&mut` across
/// the worker pool.
///
/// SAFETY contract (upheld by the round loop, not the type): during any
/// phase, element i is touched only by the single participant that claimed
/// the shard containing i from that phase's atomic counter, and phases are
/// separated by `Barrier::wait` (which establishes happens-before in both
/// directions). Outside the phases, only the main thread touches elements,
/// and only while every worker is parked on the round barrier.
struct SlotVec<T> {
    slots: Vec<UnsafeCell<T>>,
}

// SAFETY: see the struct docs — element access is externally synchronized
// by shard ownership + barriers.
unsafe impl<T: Send> Sync for SlotVec<T> {}

impl<T> SlotVec<T> {
    fn new(items: Vec<T>) -> SlotVec<T> {
        SlotVec { slots: items.into_iter().map(UnsafeCell::new).collect() }
    }

    /// SAFETY: caller must hold exclusive claim on index `i` (see struct
    /// docs).
    #[allow(clippy::mut_from_ref)]
    unsafe fn get_mut(&self, i: usize) -> &mut T {
        &mut *self.slots[i].get()
    }
}

/// Row-sliced view of a dense n×p matrix shared across the pool: phase A
/// writes row i under the same exclusive-claim discipline as [`SlotVec`],
/// phase B reads rows concurrently (no writers exist then — the phases are
/// barrier-separated).
struct RowMat {
    ptr: *mut f64,
    rows: usize,
    cols: usize,
}

// SAFETY: access discipline documented on the struct; the pointee outlives
// the worker scope (it is a stack local of `run_with_workers`).
unsafe impl Send for RowMat {}
unsafe impl Sync for RowMat {}

impl RowMat {
    fn new(m: &mut Mat) -> RowMat {
        RowMat { ptr: m.data.as_mut_ptr(), rows: m.rows, cols: m.cols }
    }

    /// SAFETY: caller must hold exclusive claim on row `i` and no shared
    /// readers may exist (phase A discipline).
    #[allow(clippy::mut_from_ref)]
    unsafe fn row_mut(&self, i: usize) -> &mut [f64] {
        debug_assert!(i < self.rows);
        std::slice::from_raw_parts_mut(self.ptr.add(i * self.cols), self.cols)
    }

    /// SAFETY: no `&mut` to row `i` may exist (phase B discipline).
    unsafe fn row(&self, i: usize) -> &[f64] {
        debug_assert!(i < self.rows);
        std::slice::from_raw_parts(self.ptr.add(i * self.cols), self.cols)
    }
}

/// Per-participant cumulative wire accounting; slot `pid` is written only
/// by participant `pid` during phases, read only by main between rounds.
#[derive(Default)]
struct Counter {
    bits: u64,
    bytes: u64,
}

/// Everything the phase kernels need, shared immutably across the pool.
struct Shared<'a> {
    n: usize,
    codec: &'a WireCodec,
    tag: u8,
    tamper: Option<FrameTamper>,
    /// CSR neighbor structure: node i's gossip neighbors (ascending j,
    /// zero weights excluded — the same ids `WeightRow` carries) are
    /// `ids[off[i]..off[i+1]]`.
    off: &'a [usize],
    ids: &'a [u32],
    algs: &'a SlotVec<Option<Box<dyn NodeAlgorithm>>>,
    rngs: &'a SlotVec<Rng>,
    frames: &'a SlotVec<Vec<u8>>,
    counters: &'a SlotVec<Counter>,
    q: &'a RowMat,
    /// Wire round index, published by main before each round's first
    /// barrier.
    round: &'a AtomicUsize,
    next_build: &'a AtomicUsize,
    next_a: &'a AtomicUsize,
    next_b: &'a AtomicUsize,
    done: &'a AtomicBool,
    fault_flag: &'a AtomicBool,
    faults: &'a Mutex<Vec<WireFault>>,
    bar: &'a Barrier,
}

/// Per-participant reused scratch (the only per-thread state).
struct Scratch {
    /// `outgoing` destination (p).
    payload: Vec<f64>,
    /// `encode_into`'s sender-side decode destination (p); the value the
    /// network consumes is re-derived through `parse`/`decode_into`.
    enc: Vec<f64>,
    /// Peer slots handed to `update`, pre-sized to the global max degree.
    peers: Vec<(usize, Vec<f64>)>,
}

impl Scratch {
    fn new(p: usize, max_deg: usize) -> Scratch {
        Scratch {
            payload: vec![0.0; p],
            enc: vec![0.0; p],
            peers: (0..max_deg).map(|_| (0usize, vec![0.0; p])).collect(),
        }
    }
}

/// Claim contiguous [`CHUNK`]-sized shards from `counter` until the index
/// space `0..n` is drained, running `f` on every claimed index. Each
/// participant over-claims at most once, and main resets the counter
/// before the next phase begins.
fn drain(counter: &AtomicUsize, n: usize, mut f: impl FnMut(usize)) {
    loop {
        // lint:allow(atomic-ordering): atomicity-only shard claim — no data rides on its order
        let s = counter.fetch_add(CHUNK, Ordering::Relaxed);
        if s >= n {
            break;
        }
        for i in s..(s + CHUNK).min(n) {
            f(i);
        }
    }
}

/// Parse + validate + decode one self-produced frame — the broadcast-site
/// equivalent of the coordinator's receive path (`node::absorb`), minus
/// the checks that cannot fire without a transport (neighbor identity,
/// round skew, duplicates).
fn parse_decode(sh: &Shared, buf: &[u8], out: &mut [f64]) -> Result<(), WireError> {
    let f = FrameRef::parse(buf)?;
    if f.tag != sh.tag {
        return Err(if WireCodec::known_tag(f.tag) {
            WireError::TagMismatch { expected: sh.tag, got: f.tag }
        } else {
            WireError::UnknownTag { tag: f.tag }
        });
    }
    sh.codec.decode_into(f.payload, out)
}

/// Phase A for one claimed node: broadcast — encode the outgoing payload
/// into the node's reused frame buffer, account bits/bytes, then parse +
/// decode the frame once into the shared slot row (every receiver's decode
/// by the codec determinism contract).
fn phase_a(sh: &Shared, sc: &mut Scratch, pid: usize, i: usize, k: usize) {
    // SAFETY: shard claim makes this participant the only one touching
    // node i's slots this phase; barriers order phases (see SlotVec docs).
    let alg = unsafe { sh.algs.get_mut(i) }.as_mut().expect("alg built");
    alg.outgoing(&mut sc.payload);
    let buf = unsafe { sh.frames.get_mut(i) };
    wire::frame_begin(buf, sh.tag, k as u32, i as u16);
    let rng = unsafe { sh.rngs.get_mut(i) };
    let bits = sh.codec.encode_into(&sc.payload, rng, &mut sc.enc, buf);
    wire::frame_end(buf);
    if let Some(t) = &sh.tamper {
        if t.node == i && t.round == k {
            node::apply_tamper(buf, t.kind);
        }
    }
    let deg = (sh.off[i + 1] - sh.off[i]) as u64;
    // same accounting as run_node: payload bits once per broadcast, frame
    // bytes once per neighbor unicast (tampered length counts, as there)
    let c = unsafe { sh.counters.get_mut(pid) };
    c.bits += bits;
    c.bytes += buf.len() as u64 * deg;
    let q_row = unsafe { sh.q.row_mut(i) };
    if let Err(error) = parse_decode(sh, buf, q_row) {
        // keep processing the shard: the round is discarded wholesale by
        // main after the phase-B barrier, and fault resolution is
        // deterministic (min round, then min node) regardless of which
        // participants pushed
        // lint:allow(atomic-ordering): idempotent monotone raise, read only after the phase barrier
        sh.fault_flag.raise(Ordering::Relaxed);
        sh.faults
            .lock()
            .expect("fault sink poisoned")
            .push(WireFault { node: i as u16, round: k as u32, error });
    }
}

/// Phase B for one claimed node: gather — copy the neighbor slot rows into
/// the participant's peer scratch (ascending j, exactly the coordinator's
/// per-neighbor slot layout) and hand the decoded round to the algorithm.
fn phase_b(sh: &Shared, sc: &mut Scratch, i: usize) {
    let (s, e) = (sh.off[i], sh.off[i + 1]);
    let deg = e - s;
    for (slot, &j) in sc.peers[..deg].iter_mut().zip(&sh.ids[s..e]) {
        slot.0 = j as usize;
        // SAFETY: phase B has no writers to q (barrier-separated from
        // phase A), so shared row reads are sound.
        slot.1.copy_from_slice(unsafe { sh.q.row(j as usize) });
    }
    // SAFETY: exclusive shard claim on node i (see SlotVec docs).
    let alg = unsafe { sh.algs.get_mut(i) }.as_mut().expect("alg built");
    alg.update(unsafe { sh.q.row(i) }, &sc.peers[..deg]);
}

/// One participant's whole life: parallel build pass, then the barrier-
/// stepped round loop until main raises `done`.
fn participate(
    sh: &Shared,
    w: &MixingOp,
    build: &(impl Fn(usize, WeightRow) -> Box<dyn NodeAlgorithm> + Sync),
    pid: usize,
    p: usize,
    max_deg: usize,
    seed: u64,
) {
    let mut sc = Scratch::new(p, max_deg);
    let frame_cap = Frame::HEADER_LEN + p * 8 + 8;
    drain(sh.next_build, sh.n, |i| {
        let row = WeightRow::from_op(w, i);
        // SAFETY: exclusive shard claim on node i during the build pass.
        unsafe {
            *sh.algs.get_mut(i) = Some(build(i, row));
            // the coordinator's per-node dither stream, reproduced exactly
            *sh.rngs.get_mut(i) = Rng::new(seed).fork(i as u64);
            sh.frames.get_mut(i).reserve_exact(frame_cap);
        }
    });
    sh.bar.wait();
    loop {
        sh.bar.wait();
        // published by main before releasing the barrier (happens-before
        // via the barrier itself, hence Relaxed)
        // lint:allow(atomic-ordering): main's store happens-before via the round barrier
        if sh.done.load(Ordering::Relaxed) {
            break;
        }
        // lint:allow(atomic-ordering): written only in main's barrier-guarded exclusive window
        let k = sh.round.load(Ordering::Relaxed);
        drain(sh.next_a, sh.n, |i| phase_a(sh, &mut sc, pid, i, k));
        sh.bar.wait();
        drain(sh.next_b, sh.n, |i| phase_b(sh, &mut sc, i));
        sh.bar.wait();
    }
}

/// Run `name` through the sim backend — the same signature as
/// [`crate::coordinator::run`], so [`crate::exp::Experiment`] dispatches
/// to either interchangeably. Uses one worker per available core (capped
/// at n); [`run_with_workers`] pins the pool size explicitly.
pub fn run(
    w: &MixingOp,
    x0: &Mat,
    name: &str,
    wire: &CoordConfig,
    spec: &RunSpec,
    x_star: &[f64],
    probes: &mut [&mut dyn Probe],
    build: impl Fn(usize, WeightRow) -> Box<dyn NodeAlgorithm> + Sync,
) -> RunResult {
    run_with_workers(w, x0, name, wire, spec, x_star, probes, build, 0)
}

/// [`run`] with an explicit participant count (`0` = one per core). The
/// result is bit-identical for every pool size — shard claiming reorders
/// only *which thread* runs a node's arithmetic, never the arithmetic or
/// the per-node RNG streams — which `rust/tests/sim_parity.rs` pins.
#[allow(clippy::too_many_arguments)]
pub fn run_with_workers(
    w: &MixingOp,
    x0: &Mat,
    name: &str,
    wire: &CoordConfig,
    spec: &RunSpec,
    x_star: &[f64],
    probes: &mut [&mut dyn Probe],
    build: impl Fn(usize, WeightRow) -> Box<dyn NodeAlgorithm> + Sync,
    workers: usize,
) -> RunResult {
    let n = w.n();
    let p = x0.cols;
    let rounds = spec.stop.max_rounds;
    assert_eq!(x0.rows, n);
    assert_eq!(x_star.len(), p, "x_star dimension must match the iterate width");
    assert!(rounds > 0, "sim run needs rounds >= 1 (0 would record no snapshots)");
    // config-driven runs are rejected earlier with a typed ConfigError
    // (exp::validate); this guards direct callers of the sim API
    assert!(
        n <= u16::MAX as usize,
        "sim backend: n = {n} exceeds 65535 — node ids must fit the wire format's u16 `from` field"
    );
    assert!(spec.record_every > 0, "record_every must be >= 1");
    assert!(
        spec.schedule.is_none(),
        "stepsize schedules are engine-only (node halves run fixed hyperparameters)"
    );
    let gated = spec.stop.leader_gated();
    #[allow(clippy::disallowed_methods)] // wall-clock run timing (see clippy.toml)
    let start = Instant::now();

    let participants = if workers > 0 {
        workers
    } else {
        thread::available_parallelism().map(|c| c.get()).unwrap_or(1)
    }
    .clamp(1, n);

    // CSR neighbor structure (ascending j, zero weights excluded — the
    // exact id sequence WeightRow::from_op produces, shared by every
    // algorithm's mix).
    let mut off = Vec::with_capacity(n + 1);
    let mut ids: Vec<u32> = Vec::with_capacity(w.nnz());
    off.push(0usize);
    for i in 0..n {
        for (j, _) in w.neighbors(i) {
            ids.push(j as u32);
        }
        off.push(ids.len());
    }
    let max_deg = (0..n).map(|i| off[i + 1] - off[i]).max().unwrap_or(0);

    let mut q = Mat::zeros(n, p);
    let mut snap = Mat::zeros(n, p);
    let mut history: Vec<MetricPoint> = Vec::with_capacity(rounds / spec.record_every + 2);
    let mut stopped_by: Option<StopReason> = None;

    let algs = SlotVec::new((0..n).map(|_| None).collect::<Vec<Option<Box<dyn NodeAlgorithm>>>>());
    let rngs = SlotVec::new((0..n).map(|_| Rng::new(0)).collect::<Vec<Rng>>());
    let frames = SlotVec::new(vec![Vec::<u8>::new(); n]);
    let counters = SlotVec::new((0..participants).map(|_| Counter::default()).collect::<Vec<_>>());
    let q_view = RowMat::new(&mut q);
    let round = AtomicUsize::new(0, "sim.round");
    let next_build = AtomicUsize::new(0, "sim.next_build");
    let next_a = AtomicUsize::new(0, "sim.next_a");
    let next_b = AtomicUsize::new(0, "sim.next_b");
    let done = AtomicBool::new(false, "sim.done");
    let fault_flag = AtomicBool::new(false, "sim.fault_flag");
    let faults: Mutex<Vec<WireFault>> = Mutex::new(Vec::new());
    let bar = Barrier::new(participants, "sim.round_barrier");
    let sh = Shared {
        n,
        codec: &wire.codec,
        tag: wire.codec.tag(),
        tamper: wire.tamper,
        off: &off,
        ids: &ids,
        algs: &algs,
        rngs: &rngs,
        frames: &frames,
        counters: &counters,
        q: &q_view,
        round: &round,
        next_build: &next_build,
        next_a: &next_a,
        next_b: &next_b,
        done: &done,
        fault_flag: &fault_flag,
        faults: &faults,
        bar: &bar,
    };
    let sh = &sh;
    let build = &build;
    let seed = wire.seed;

    thread::scope(|scope| {
        for pid in 1..participants {
            sync::spawn_scoped(scope, &format!("sim-{pid}"), move || {
                participate(sh, w, build, pid, p, max_deg, seed)
            });
        }
        // the caller thread is participant 0 AND the leader: it works the
        // phases like everyone else and owns the exclusive windows between
        // a round's last barrier and the next round's first
        let mut sc = Scratch::new(p, max_deg);
        let frame_cap = Frame::HEADER_LEN + p * 8 + 8;
        drain(sh.next_build, n, |i| {
            let row = WeightRow::from_op(w, i);
            // SAFETY: exclusive shard claim on node i during the build pass.
            unsafe {
                *sh.algs.get_mut(i) = Some(build(i, row));
                *sh.rngs.get_mut(i) = Rng::new(seed).fork(i as u64);
                sh.frames.get_mut(i).reserve_exact(frame_cap);
            }
        });
        sh.bar.wait();
        // exclusive window: all workers are parked on the round barrier
        // SAFETY: main-exclusive access between barriers (see SlotVec docs).
        let setup = unsafe { sh.algs.get_mut(0) }.as_ref().expect("alg built").setup_rounds();
        debug_assert!(
            (0..n).all(|i| unsafe { sh.algs.get_mut(i) }.as_ref().unwrap().setup_rounds() == setup),
            "heterogeneous setup_rounds across nodes"
        );
        let total = setup + rounds;

        // main-only snapshot: copy every node's iterate, sum the cumulative
        // counters, emit on the shared record grid, evaluate the StopSet
        let take = |step: usize,
                        snap: &mut Mat,
                        history: &mut Vec<MetricPoint>,
                        probes: &mut [&mut dyn Probe],
                        stopped_by: &mut Option<StopReason>| {
            let (mut bits, mut bytes, mut evals) = (0u64, 0u64, 0u64);
            for pid in 0..participants {
                // SAFETY: main-exclusive window.
                let c = unsafe { sh.counters.get_mut(pid) };
                bits += c.bits;
                bytes += c.bytes;
            }
            for i in 0..n {
                // SAFETY: main-exclusive window.
                let alg = unsafe { sh.algs.get_mut(i) }.as_ref().expect("alg built");
                evals += alg.grad_evals();
                snap.row_mut(i).copy_from_slice(alg.x());
            }
            let elapsed = start.elapsed();
            let m = MetricPoint {
                round: step,
                grad_evals: evals,
                bits,
                wire_bytes: bytes,
                suboptimality: suboptimality(snap, x_star),
                consensus: snap.consensus_error(),
                wall_ns: elapsed.as_nanos(),
            };
            crate::runner::emit(m, snap, history, probes);
            if gated && step > 0 {
                // first-hit-wins, divergence beating the budget checks —
                // the coordinator leader's exact rule
                let hit = if !snap.is_finite() {
                    Some(StopReason::Diverged)
                } else {
                    spec.stop.check(step, bits, evals, m.suboptimality, elapsed)
                };
                if let Some(reason) = hit {
                    // MaxRounds is the natural end, not an early stop
                    if stopped_by.is_none() && reason != StopReason::MaxRounds {
                        *stopped_by = Some(reason);
                    }
                }
            }
        };

        for k in 0..total {
            if k == setup {
                // the engine's round-0 sample: post-init state, setup wire
                // costs already on the counters
                take(0, &mut snap, &mut history, probes, &mut stopped_by);
            }
            // lint:allow(atomic-ordering): main-exclusive window; the barrier publishes the reset
            sh.next_a.store(0, Ordering::Relaxed);
            // lint:allow(atomic-ordering): same barrier-published exclusive-window reset as next_a
            sh.next_b.store(0, Ordering::Relaxed);
            // lint:allow(atomic-ordering): same barrier-published exclusive-window store as next_a
            sh.round.store(k, Ordering::Relaxed);
            sh.bar.wait();
            drain(sh.next_a, n, |i| phase_a(sh, &mut sc, 0, i, k));
            sh.bar.wait();
            drain(sh.next_b, n, |i| phase_b(sh, &mut sc, i));
            sh.bar.wait();
            // exclusive window again
            // lint:allow(atomic-ordering): every raise happens-before via the phase-B barrier
            if sh.fault_flag.load(Ordering::Relaxed) {
                // the faulted round is discarded — same truncation as the
                // coordinator, whose leader never completes that snapshot
                break;
            }
            if k >= setup {
                let step = k - setup + 1;
                if step % spec.record_every == 0 || step == rounds {
                    take(step, &mut snap, &mut history, probes, &mut stopped_by);
                    if stopped_by.is_some() {
                        break;
                    }
                }
            }
        }
        // lint:allow(atomic-ordering): the final barrier publishes `done` to every worker
        sh.done.store(true, Ordering::Relaxed);
        sh.bar.wait();
        // under proxlead-check: wait for every worker to exit so the
        // scope's implicit join below never blocks the schedule token
        sync::pre_join();
    });

    // deterministic fault resolution — earliest round, lowest node id
    let fault =
        sh.faults.lock().expect("fault sink poisoned").drain(..).min_by_key(|f| (f.round, f.node));
    if history.is_empty() {
        // a wire fault before the first complete snapshot: synthesize the
        // round-0 state from x0 so the RunResult invariants hold
        assert!(fault.is_some(), "no snapshots recorded on a fault-free sim run");
        snap = x0.clone();
        let m = MetricPoint {
            round: 0,
            grad_evals: 0,
            bits: 0,
            wire_bytes: 0,
            suboptimality: suboptimality(&snap, x_star),
            consensus: snap.consensus_error(),
            wall_ns: start.elapsed().as_nanos(),
        };
        crate::runner::emit(m, &snap, &mut history, probes);
    }
    let final_x = snap;
    let stopped_by = match (fault, stopped_by) {
        // a faulted run's history is truncated mid-flight; any other stop
        // reason would misrepresent it
        (Some(f), _) => StopReason::WireFault(f),
        (None, Some(reason)) => reason,
        // ungated runs always complete the round budget; flag a
        // non-finite landing state as a divergence after the fact
        (None, None) if final_x.is_finite() => StopReason::MaxRounds,
        (None, None) => StopReason::Diverged,
    };

    let result = RunResult {
        name: name.to_string(),
        backend: Backend::Sim,
        history,
        stopped_by,
        elapsed: start.elapsed(),
        final_x,
    };
    crate::runner::finish(&result, probes);
    result
}
