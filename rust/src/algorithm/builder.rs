//! Typed builders for every algorithm — the replacement for the
//! positional-argument constructors.
//!
//! Each builder starts from an [`Experiment`]'s resolved defaults
//! (problem, mixing operator, start iterate, auto-η hyperparameters,
//! oracle, compressor, prox, seed) and lets call sites override exactly
//! the knobs they care about:
//!
//! ```text
//! let alg = ProxLead::builder(&experiment)
//!     .oracle(OracleKind::Saga)
//!     .seed(7)
//!     .build();
//! ```
//!
//! The old `X::new(...)` constructors remain as deprecated shims for the
//! tests that pin iterate sequences bit-for-bit; everything else
//! constructs through these builders (usually via
//! [`Experiment::algorithm`], the name-dispatching registry). The
//! message-passing coordinator's per-node halves follow the same
//! per-family parameter conventions — `exp::registry::build_node_algorithm`
//! is the node-side twin of this module's dispatch, and
//! `rust/tests/coordinator_parity.rs` pins the two construction paths to
//! identical iterates under an exact codec.

use super::{Choco, Dgd, DualGd, Hyper, Nids, P2d2, Pdgm, PgExtra, ProxLead};
use crate::compress::Compressor;
use crate::exp::Experiment;
use crate::graph::MixingOp;
use crate::linalg::Mat;
use crate::oracle::OracleKind;
use crate::problem::Problem;
use crate::prox::Prox;

/// Warm-started inner dual-solve iterations for the DualGD/LessBit-A
/// family (the §4.3 comparison's convention).
pub const DUALGD_INNER_ITERS: usize = 40;

/// Inner-solve gradient-norm tolerance shared by the engine's [`DualGd`]
/// and the coordinator's `DualGdNode` (one constant, so the two backends
/// cannot drift apart).
pub const DUALGD_INNER_TOL: f64 = 1e-12;

/// The DualGD/LessBit-A theory-default dual stepsize: μ/2, or μ/4 when the
/// communication is compressed. Both registries (engine builder and
/// coordinator node factory) derive θ through this one function.
pub fn dualgd_default_theta(mu: f64, compressed: bool) -> f64 {
    if compressed {
        mu / 4.0
    } else {
        mu / 2.0
    }
}

/// The PDGM/LessBit-B default dual stepsize θ = γ/(2η) (the PDHG view),
/// shared by both registries.
pub fn pdgm_default_theta(eta: f64, gamma: f64) -> f64 {
    gamma / (2.0 * eta)
}

/// The construction surface every algorithm shares, pre-resolved from an
/// [`Experiment`]. Builders embed one of these and expose chainable
/// overrides on top.
pub struct AlgorithmParts<'a> {
    pub problem: &'a dyn Problem,
    pub w: &'a MixingOp,
    pub x0: &'a Mat,
    pub hyper: Hyper,
    pub oracle: OracleKind,
    pub comp: Box<dyn Compressor>,
    pub prox: Box<dyn Prox>,
    pub seed: u64,
}

impl<'a> AlgorithmParts<'a> {
    /// Defaults from a resolved experiment: its problem, mixing operator,
    /// x0 = 0, auto-η hyperparameters, configured oracle / compressor /
    /// prox, and the config seed.
    pub fn from_experiment(exp: &'a Experiment) -> AlgorithmParts<'a> {
        AlgorithmParts {
            problem: exp.problem.as_ref(),
            w: &exp.mixing,
            x0: &exp.x0,
            hyper: exp.hyper,
            oracle: exp.oracle(),
            comp: exp.compressor(),
            prox: exp.prox(),
            seed: exp.config.seed,
        }
    }
}

/// Chainable overrides shared by every algorithm builder.
macro_rules! common_setters {
    () => {
        /// Override the primal stepsize η.
        pub fn eta(mut self, eta: f64) -> Self {
            self.parts.hyper.eta = eta;
            self
        }

        /// Override the compression-state blending rate α.
        pub fn alpha(mut self, alpha: f64) -> Self {
            self.parts.hyper.alpha = alpha;
            self
        }

        /// Override the dual stepsize scale γ (Choco reads it as the
        /// gossip stepsize γ_c).
        pub fn gamma(mut self, gamma: f64) -> Self {
            self.parts.hyper.gamma = gamma;
            self
        }

        /// Override all three hyperparameters at once.
        pub fn hyper(mut self, h: Hyper) -> Self {
            self.parts.hyper = h;
            self
        }

        /// Override the stochastic gradient oracle.
        pub fn oracle(mut self, kind: OracleKind) -> Self {
            self.parts.oracle = kind;
            self
        }

        /// Override the compression operator.
        pub fn compressor(mut self, comp: Box<dyn Compressor>) -> Self {
            self.parts.comp = comp;
            self
        }

        /// Override the shared non-smooth term r(x).
        pub fn prox(mut self, prox: Box<dyn Prox>) -> Self {
            self.parts.prox = prox;
            self
        }

        /// Override the algorithm RNG seed.
        pub fn seed(mut self, seed: u64) -> Self {
            self.parts.seed = seed;
            self
        }
    };
}

/// Builder for [`ProxLead`] (Algorithm 1; LEAD when the prox is `Zero`).
pub struct ProxLeadBuilder<'a> {
    parts: AlgorithmParts<'a>,
    tag: String,
}

impl<'a> ProxLeadBuilder<'a> {
    common_setters!();

    /// Attach a display tag, e.g. `"2bit"`.
    pub fn tag(mut self, tag: &str) -> Self {
        self.tag = tag.to_string();
        self
    }

    #[allow(deprecated)]
    pub fn build(self) -> ProxLead {
        let p = self.parts;
        let alg = ProxLead::new(p.problem, p.w, p.x0, p.hyper, p.oracle, p.comp, p.prox, p.seed);
        if self.tag.is_empty() {
            alg
        } else {
            alg.with_tag(&self.tag)
        }
    }
}

impl ProxLead {
    /// Typed builder over an experiment's resolved defaults.
    pub fn builder(exp: &Experiment) -> ProxLeadBuilder<'_> {
        ProxLeadBuilder { parts: AlgorithmParts::from_experiment(exp), tag: String::new() }
    }
}

/// Builder for [`Dgd`] (DGD / D-PSGD / Prox-DGD).
pub struct DgdBuilder<'a> {
    parts: AlgorithmParts<'a>,
}

impl<'a> DgdBuilder<'a> {
    common_setters!();

    #[allow(deprecated)]
    pub fn build(self) -> Dgd {
        let p = self.parts;
        Dgd::new(p.problem, p.w, p.x0, p.hyper.eta, p.oracle, p.comp, p.prox, p.seed)
    }
}

impl Dgd {
    /// Typed builder over an experiment's resolved defaults.
    pub fn builder(exp: &Experiment) -> DgdBuilder<'_> {
        DgdBuilder { parts: AlgorithmParts::from_experiment(exp) }
    }
}

/// Builder for [`Choco`]. The experiment's γ doubles as Choco's gossip
/// stepsize γ_c (the sweep registry's convention).
pub struct ChocoBuilder<'a> {
    parts: AlgorithmParts<'a>,
}

impl<'a> ChocoBuilder<'a> {
    common_setters!();

    #[allow(deprecated)]
    pub fn build(self) -> Choco {
        let p = self.parts;
        Choco::new(
            p.problem,
            p.w,
            p.x0,
            p.hyper.eta,
            p.hyper.gamma,
            p.oracle,
            p.comp,
            p.prox,
            p.seed,
        )
    }
}

impl Choco {
    /// Typed builder over an experiment's resolved defaults.
    pub fn builder(exp: &Experiment) -> ChocoBuilder<'_> {
        ChocoBuilder { parts: AlgorithmParts::from_experiment(exp) }
    }
}

/// Builder for [`Nids`] (uncompressed; the compressor override is unused).
pub struct NidsBuilder<'a> {
    parts: AlgorithmParts<'a>,
}

impl<'a> NidsBuilder<'a> {
    common_setters!();

    #[allow(deprecated)]
    pub fn build(self) -> Nids {
        let p = self.parts;
        Nids::new(p.problem, p.w, p.x0, p.hyper.eta, p.oracle, p.prox, p.seed)
    }
}

impl Nids {
    /// Typed builder over an experiment's resolved defaults.
    pub fn builder(exp: &Experiment) -> NidsBuilder<'_> {
        NidsBuilder { parts: AlgorithmParts::from_experiment(exp) }
    }
}

/// Builder for [`P2d2`] (uncompressed; the compressor override is unused).
pub struct P2d2Builder<'a> {
    parts: AlgorithmParts<'a>,
}

impl<'a> P2d2Builder<'a> {
    common_setters!();

    #[allow(deprecated)]
    pub fn build(self) -> P2d2 {
        let p = self.parts;
        P2d2::new(p.problem, p.w, p.x0, p.hyper.eta, p.oracle, p.prox, p.seed)
    }
}

impl P2d2 {
    /// Typed builder over an experiment's resolved defaults.
    pub fn builder(exp: &Experiment) -> P2d2Builder<'_> {
        P2d2Builder { parts: AlgorithmParts::from_experiment(exp) }
    }
}

/// Builder for [`PgExtra`] (uncompressed; the compressor override is
/// unused).
pub struct PgExtraBuilder<'a> {
    parts: AlgorithmParts<'a>,
}

impl<'a> PgExtraBuilder<'a> {
    common_setters!();

    #[allow(deprecated)]
    pub fn build(self) -> PgExtra {
        let p = self.parts;
        PgExtra::new(p.problem, p.w, p.x0, p.hyper.eta, p.oracle, p.prox, p.seed)
    }
}

impl PgExtra {
    /// Typed builder over an experiment's resolved defaults.
    pub fn builder(exp: &Experiment) -> PgExtraBuilder<'_> {
        PgExtraBuilder { parts: AlgorithmParts::from_experiment(exp) }
    }
}

/// Builder for [`Pdgm`] (PDGM / LessBit-B). The dual stepsize θ defaults
/// to the PDHG view's γ/(2η).
pub struct PdgmBuilder<'a> {
    parts: AlgorithmParts<'a>,
    theta: Option<f64>,
}

impl<'a> PdgmBuilder<'a> {
    common_setters!();

    /// Override the dual stepsize θ (default γ/(2η)).
    pub fn theta(mut self, theta: f64) -> Self {
        self.theta = Some(theta);
        self
    }

    #[allow(deprecated)]
    pub fn build(self) -> Pdgm {
        let p = self.parts;
        let theta = self.theta.unwrap_or_else(|| pdgm_default_theta(p.hyper.eta, p.hyper.gamma));
        Pdgm::new(p.problem, p.w, p.x0, p.hyper.eta, theta, p.oracle, p.comp, p.hyper.alpha, p.seed)
    }
}

impl Pdgm {
    /// Typed builder over an experiment's resolved defaults.
    pub fn builder(exp: &Experiment) -> PdgmBuilder<'_> {
        PdgmBuilder { parts: AlgorithmParts::from_experiment(exp), theta: None }
    }
}

/// Builder for [`DualGd`] (DualGD / LessBit-A). The dual stepsize θ
/// defaults to the theory-driven μ/2 (μ/4 when the compressor is noisy),
/// with [`DUALGD_INNER_ITERS`] warm-started inner iterations.
pub struct DualGdBuilder<'a> {
    parts: AlgorithmParts<'a>,
    theta: Option<f64>,
    inner_iters: usize,
}

impl<'a> DualGdBuilder<'a> {
    common_setters!();

    /// Override the dual stepsize θ (default μ/2, or μ/4 when compressed).
    pub fn theta(mut self, theta: f64) -> Self {
        self.theta = Some(theta);
        self
    }

    /// Override the warm-started inner-solve iteration budget.
    pub fn inner_iters(mut self, iters: usize) -> Self {
        self.inner_iters = iters;
        self
    }

    #[allow(deprecated)]
    pub fn build(self) -> DualGd {
        let p = self.parts;
        let theta = self.theta.unwrap_or_else(|| {
            dualgd_default_theta(p.problem.strong_convexity(), p.comp.variance_bound() > 0.0)
        });
        DualGd::new(p.problem, p.w, p.x0, theta, self.inner_iters, p.comp, p.hyper.alpha, p.seed)
    }
}

impl DualGd {
    /// Typed builder over an experiment's resolved defaults.
    pub fn builder(exp: &Experiment) -> DualGdBuilder<'_> {
        DualGdBuilder {
            parts: AlgorithmParts::from_experiment(exp),
            theta: None,
            inner_iters: DUALGD_INNER_ITERS,
        }
    }
}
