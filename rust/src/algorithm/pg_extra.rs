//! PG-EXTRA (Shi, Ling, Wu, Yin 2015) — the classic decentralized proximal
//! gradient with the EXTRA double-mixing correction. Sublinear on composite
//! problems (the rate Prox-LEAD improves to linear); included as the
//! historical baseline and for the Table 3 ablations.
//!
//! With W̃ = (I+W)/2:
//!
//! ```text
//! Z¹    = W X⁰ − η ∇F(X⁰),  X¹ = prox_ηR(Z¹)
//! Zᵏ⁺¹  = Zᵏ + W Xᵏ − W̃ Xᵏ⁻¹ − η(∇F(Xᵏ) − ∇F(Xᵏ⁻¹))
//! Xᵏ⁺¹  = prox_ηR(Zᵏ⁺¹)
//! ```
//!
//! (Setting R ≡ 0 recovers EXTRA.)
//!
//! Per-node counterpart: [`crate::coordinator::PgExtraNode`] — the only
//! node half needing two weight rows (W for Xᵏ, W̃ for the cached Xᵏ⁻¹
//! broadcasts of the previous round).

use super::{Algorithm, RoundStats};
use crate::graph::MixingOp;
use crate::linalg::Mat;
use crate::oracle::{OracleKind, Sgo};
use crate::problem::Problem;
use crate::prox::{prox_rows_into, Prox};
use crate::util::rng::Rng;

pub struct PgExtra {
    x: Mat,
    x_prev: Mat,
    z: Mat,
    g_prev: Mat,
    w: MixingOp,
    w_tilde: MixingOp,
    pub eta: f64,
    oracle: Sgo,
    prox: Box<dyn Prox>,
    bits: u64,
    g: Mat,
    wx: Mat,       // scratch: W Xᵏ
    wtx_prev: Mat, // scratch: W̃ Xᵏ⁻¹
}

impl PgExtra {
    /// Deprecated shim kept for tests that pin iterate sequences; new
    /// code constructs via [`PgExtra::builder`] / `Experiment::algorithm`.
    #[deprecated(note = "construct via PgExtra::builder(&experiment) or Experiment::algorithm()")]
    pub fn new(
        problem: &dyn Problem,
        w: &MixingOp,
        x0: &Mat,
        eta: f64,
        oracle_kind: OracleKind,
        prox: Box<dyn Prox>,
        seed: u64,
    ) -> PgExtra {
        let mut rng = Rng::new(seed);
        let mut oracle = Sgo::new(oracle_kind, problem, x0, rng.next_u64());
        let n = x0.rows;
        let w_tilde = w.half_lazy();
        let mut g0 = Mat::zeros(n, x0.cols);
        oracle.sample_all(problem, x0, &mut g0);
        let mut z = w.apply(x0);
        z.axpy(-eta, &g0);
        let mut x1 = z.clone();
        prox_rows_into(prox.as_ref(), &mut x1, eta);
        PgExtra {
            x: x1,
            x_prev: x0.clone(),
            z,
            g_prev: g0,
            w: w.clone(),
            w_tilde,
            eta,
            oracle,
            prox,
            bits: 0,
            g: Mat::zeros(n, x0.cols),
            wx: Mat::zeros(n, x0.cols),
            wtx_prev: Mat::zeros(n, x0.cols),
        }
    }
}

impl Algorithm for PgExtra {
    fn step(&mut self, problem: &dyn Problem) -> RoundStats {
        self.oracle.sample_all(problem, &self.x, &mut self.g);

        // Zᵏ⁺¹ = Zᵏ + WXᵏ − W̃Xᵏ⁻¹ − η(Gᵏ − Gᵏ⁻¹)
        self.w.apply_into(&self.x, &mut self.wx);
        self.w_tilde.apply_into(&self.x_prev, &mut self.wtx_prev);
        self.z += &self.wx;
        self.z -= &self.wtx_prev;
        self.z.axpy(-self.eta, &self.g);
        self.z.axpy(self.eta, &self.g_prev);

        // one 32-bit broadcast of Xᵏ per node (W̃Xᵏ⁻¹ uses cached values)
        let bits = 32 * (self.x.rows * self.x.cols) as u64;
        self.bits += bits;

        self.x_prev = self.x.clone();
        self.g_prev = self.g.clone();
        let mut xn = self.z.clone();
        prox_rows_into(self.prox.as_ref(), &mut xn, self.eta);
        self.x = xn;
        RoundStats { bits }
    }

    fn x(&self) -> &Mat {
        &self.x
    }

    fn name(&self) -> String {
        let base = if self.prox.is_zero() { "EXTRA" } else { "PG-EXTRA" };
        format!("{base} (32bit, {})", self.oracle.name())
    }

    fn grad_evals(&self) -> u64 {
        self.oracle.grad_evals()
    }

    fn bits(&self) -> u64 {
        self.bits
    }

    fn set_eta(&mut self, eta: f64) {
        self.eta = eta;
    }
}

#[cfg(test)]
mod tests {
    // these tests pin the constructor-built iterate sequence directly
    #![allow(deprecated)]
    use super::*;
    use crate::algorithm::testkit::{ring_logreg, run_to};
    use crate::algorithm::solve_reference;
    use crate::problem::Problem;
    use crate::prox::{Zero, L1};

    #[test]
    fn extra_converges_smooth() {
        let (p, w) = ring_logreg();
        let x_star = solve_reference(&p, 0.0, 40_000, 1e-13);
        let x0 = Mat::zeros(4, p.dim());
        let eta = crate::algorithm::testkit::safe_eta(&p);
        let mut alg = PgExtra::new(&p, &w, &x0, eta, OracleKind::Full, Box::new(Zero), 3);
        let s = run_to(&mut alg, &p, 4000, &x_star);
        assert!(s < 1e-16, "EXTRA suboptimality: {s}");
    }

    #[test]
    fn pg_extra_converges_composite() {
        let (p, w) = ring_logreg();
        let lam = 5e-3;
        let x_star = solve_reference(&p, lam, 40_000, 1e-13);
        let x0 = Mat::zeros(4, p.dim());
        let eta = crate::algorithm::testkit::safe_eta(&p);
        let mut alg = PgExtra::new(&p, &w, &x0, eta, OracleKind::Full, Box::new(L1::new(lam)), 3);
        let s = run_to(&mut alg, &p, 5000, &x_star);
        assert!(s < 1e-12, "PG-EXTRA composite suboptimality: {s}");
    }
}
