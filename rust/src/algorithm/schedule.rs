//! Stepsize schedules — notably Theorem 7's diminishing schedule that gives
//! Prox-LEAD exact O(1/k) convergence under plain stochastic gradients.
//!
//! Theorem 7 sets, with B = 16(1+C)²·κ_g·κ_f,
//!
//! ```text
//! ηᵏ = (B/2) / (k + B) · (1/L)
//! αᵏ = ηᵏ μ / (1+C)
//! γᵏ = ηᵏ μ / (2 (1+C)² λmax(I−W))
//! ```

use super::Hyper;

/// A (possibly time-varying) hyperparameter schedule.
#[derive(Clone, Debug)]
pub enum Schedule {
    /// Fixed parameters (Theorems 5, 8, 9).
    Constant(Hyper),
    /// Theorem 7's O(1/k) schedule.
    Theorem7 {
        /// Compression variance bound C (Assumption 2).
        c: f64,
        /// Smoothness L and strong convexity μ.
        l: f64,
        mu: f64,
        /// Network condition number κ_g and λmax(I − W).
        kappa_g: f64,
        lmax_iw: f64,
    },
    /// Generic η₀/(1 + rate·k) decay with α, γ fixed (DGD-style ablation).
    InverseK { eta0: f64, rate: f64, alpha: f64, gamma: f64 },
}

impl Schedule {
    /// Parameters at iteration k (0-based).
    pub fn hyper_at(&self, k: u64) -> Hyper {
        match *self {
            Schedule::Constant(h) => h,
            Schedule::Theorem7 { c, l, mu, kappa_g, lmax_iw } => {
                let kf = l / mu;
                let b = 16.0 * (1.0 + c) * (1.0 + c) * kappa_g * kf;
                let eta = (b / 2.0) / (k as f64 + b) / l;
                let alpha = eta * mu / (1.0 + c);
                let gamma = eta * mu / (2.0 * (1.0 + c) * (1.0 + c) * lmax_iw);
                Hyper { eta, alpha, gamma }
            }
            Schedule::InverseK { eta0, rate, alpha, gamma } => Hyper {
                eta: eta0 / (1.0 + rate * k as f64),
                alpha,
                gamma,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn theorem7_parameters_feasible_and_decaying() {
        let s = Schedule::Theorem7 { c: 0.3, l: 10.0, mu: 0.1, kappa_g: 5.0, lmax_iw: 1.8 };
        let h0 = s.hyper_at(0);
        // η⁰ = 1/(2L) as in the theorem's k=0 value
        assert!((h0.eta - 0.05).abs() < 1e-12);
        // feasibility: α < min{ημ/√C, 1/(1+C)}
        let c: f64 = 0.3;
        assert!(h0.alpha < (h0.eta * 0.1 / c.sqrt()).min(1.0 / 1.3));
        // monotone decay, η^k → 0 like 1/k
        let h_big = s.hyper_at(10_000_000);
        assert!(h_big.eta < h0.eta * 1e-2);
        let (a, b) = (s.hyper_at(100).eta, s.hyper_at(200).eta);
        assert!(b < a);
        // the k·η^k product approaches the constant B/(2L)·1 ⇒ 1/k rate
        let k = 1e8;
        let eta_k = s.hyper_at(k as u64).eta;
        let kf = 100.0;
        let bb = 16.0 * 1.3 * 1.3 * 5.0 * kf;
        assert!((eta_k * (k + bb) - bb / 2.0 / 10.0).abs() < 1e-6);
    }

    #[test]
    fn constant_schedule_is_constant() {
        let h = Hyper::paper_default(0.1);
        let s = Schedule::Constant(h);
        assert_eq!(s.hyper_at(0).eta, s.hyper_at(999).eta);
    }

    #[test]
    fn inverse_k_decays() {
        let s = Schedule::InverseK { eta0: 0.1, rate: 0.01, alpha: 0.5, gamma: 1.0 };
        assert!((s.hyper_at(0).eta - 0.1).abs() < 1e-15);
        assert!((s.hyper_at(100).eta - 0.05).abs() < 1e-15);
        assert_eq!(s.hyper_at(100).alpha, 0.5);
    }
}
