//! DGD — decentralized gradient descent (Nedic–Ozdaglar 2009), plus its
//! stochastic (D-PSGD, Lian et al. 2017) and proximal variants.
//!
//! ```text
//! Xᵏ⁺¹ = prox_ηr( W Xᵏ − η Gᵏ )
//! ```
//!
//! With a fixed stepsize DGD converges only to a O(η)-neighborhood (the
//! "convergence bias" the paper's Fig. 1a shows); the exact solution needs
//! a diminishing stepsize. Compressing X directly (as DCD-SGD did) is
//! unstable under aggressive compression — the [`super::prox_lead`]
//! difference-compression COMM is the fix this paper inherits from LEAD.
//!
//! Per-node counterpart: [`crate::coordinator::DgdNode`] (the coordinator
//! quantizes the X broadcast with its wire codec, which is exactly the
//! DCD-SGD-style raw-iterate compression this note warns about).

use super::{Algorithm, RoundStats};
use crate::compress::Compressor;
use crate::graph::MixingOp;
use crate::linalg::Mat;
use crate::oracle::{OracleKind, Sgo};
use crate::problem::Problem;
use crate::prox::{prox_rows_into, Prox};
use crate::util::rng::Rng;

pub struct Dgd {
    x: Mat,
    w: MixingOp,
    pub eta: f64,
    oracle: Sgo,
    comp: Box<dyn Compressor>,
    prox: Box<dyn Prox>,
    rng: Rng,
    bits: u64,
    g: Mat,
    x_hat: Mat, // scratch: decoded broadcasts
    wx: Mat,    // scratch: W · X̂ (becomes the next iterate via swap)
}

impl Dgd {
    #[allow(clippy::too_many_arguments)]
    /// Deprecated shim kept for tests that pin iterate sequences; new
    /// code constructs via [`Dgd::builder`] / `Experiment::algorithm`.
    #[deprecated(note = "construct via Dgd::builder(&experiment) or Experiment::algorithm()")]
    pub fn new(
        problem: &dyn Problem,
        w: &MixingOp,
        x0: &Mat,
        eta: f64,
        oracle_kind: OracleKind,
        comp: Box<dyn Compressor>,
        prox: Box<dyn Prox>,
        seed: u64,
    ) -> Dgd {
        let mut rng = Rng::new(seed);
        let oracle = Sgo::new(oracle_kind, problem, x0, rng.next_u64());
        Dgd {
            x: x0.clone(),
            w: w.clone(),
            eta,
            oracle,
            comp,
            prox,
            rng,
            bits: 0,
            g: Mat::zeros(x0.rows, x0.cols),
            x_hat: Mat::zeros(x0.rows, x0.cols),
            wx: Mat::zeros(x0.rows, x0.cols),
        }
    }
}

impl Algorithm for Dgd {
    fn step(&mut self, problem: &dyn Problem) -> RoundStats {
        self.oracle.sample_all(problem, &self.x, &mut self.g);

        // each node broadcasts its (possibly compressed) iterate
        let mut bits = 0u64;
        for i in 0..self.x.rows {
            let c = self.comp.compress(self.x.row(i), &mut self.rng);
            bits += c.bits;
            self.x_hat.row_mut(i).copy_from_slice(&c.decoded);
        }
        self.bits += bits;

        self.w.apply_into(&self.x_hat, &mut self.wx);
        self.wx.axpy(-self.eta, &self.g);
        prox_rows_into(self.prox.as_ref(), &mut self.wx, self.eta);
        std::mem::swap(&mut self.x, &mut self.wx);
        RoundStats { bits }
    }

    fn x(&self) -> &Mat {
        &self.x
    }

    fn name(&self) -> String {
        let base = if self.oracle.is_exact() { "DGD" } else { "D-PSGD" };
        format!("{base} ({}, {})", self.comp.name(), self.oracle.name())
    }

    fn grad_evals(&self) -> u64 {
        self.oracle.grad_evals()
    }

    fn bits(&self) -> u64 {
        self.bits
    }

    fn set_eta(&mut self, eta: f64) {
        self.eta = eta;
    }
}

#[cfg(test)]
mod tests {
    // these tests pin the constructor-built iterate sequence directly
    #![allow(deprecated)]
    use super::*;
    use crate::algorithm::testkit::{ring_logreg, run_to};
    use crate::algorithm::{solve_reference, suboptimality};
    use crate::compress::Identity;
    use crate::problem::Problem;
    use crate::prox::Zero;

    #[test]
    fn dgd_has_convergence_bias_with_fixed_stepsize() {
        let (p, w) = ring_logreg();
        let x_star = solve_reference(&p, 0.0, 40_000, 1e-13);
        let x0 = Mat::zeros(4, p.dim());
        let mut alg = Dgd::new(
            &p,
            &w,
            &x0,
            0.05,
            OracleKind::Full,
            Box::new(Identity::f32()),
            Box::new(Zero),
            3,
        );
        let s = run_to(&mut alg, &p, 4000, &x_star);
        // converges to a neighborhood, NOT to zero (heterogeneous data)
        assert!(s < 1e-1, "should reach the bias ball: {s}");
        assert!(s > 1e-12, "fixed-stepsize DGD must not be exact: {s}");
    }

    #[test]
    fn diminishing_stepsize_removes_bias() {
        let (p, w) = ring_logreg();
        let x_star = solve_reference(&p, 0.0, 40_000, 1e-13);
        let x0 = Mat::zeros(4, p.dim());
        let mut alg = Dgd::new(
            &p,
            &w,
            &x0,
            0.05,
            OracleKind::Full,
            Box::new(Identity::f32()),
            Box::new(Zero),
            3,
        );
        let mut biased = Dgd::new(
            &p,
            &w,
            &x0,
            0.05,
            OracleKind::Full,
            Box::new(Identity::f32()),
            Box::new(Zero),
            3,
        );
        for k in 0..6000u64 {
            alg.set_eta(0.05 / (1.0 + k as f64 * 0.01));
            alg.step(&p);
            biased.step(&p);
        }
        let s_dim = suboptimality(alg.x(), &x_star);
        let s_fix = suboptimality(biased.x(), &x_star);
        assert!(s_dim < s_fix * 0.2, "diminishing should beat fixed: {s_dim} vs {s_fix}");
    }
}
