//! NIDS (Li, Shi, Yan 2019) — network-independent-stepsize decentralized
//! proximal gradient. One of the paper's uncompressed baselines; per §4.3,
//! LEAD's extra inexact-subproblem step is exactly what NIDS adds over
//! PDGM, which is why LEAD matches NIDS's O(κ_f + κ_g) complexity.
//!
//! Composite form with W̃ = (I+W)/2:
//!
//! ```text
//! Z¹    = X⁰ − η ∇F(X⁰),  X¹ = prox_ηR(Z¹)
//! Zᵏ⁺¹  = Zᵏ − Xᵏ + W̃ ( 2Xᵏ − Xᵏ⁻¹ − η(∇F(Xᵏ) − ∇F(Xᵏ⁻¹)) )
//! Xᵏ⁺¹  = prox_ηR(Zᵏ⁺¹)
//! ```
//!
//! One broadcast per node per round (the matrix W̃ multiplies).
//!
//! Per-node counterpart: [`crate::coordinator::NidsNode`] broadcasts the W̃
//! operand 2Xᵏ − Xᵏ⁻¹ − η(Gᵏ − Gᵏ⁻¹) and mixes with its (I+W)/2 row.

use super::{Algorithm, RoundStats};
use crate::graph::MixingOp;
use crate::linalg::Mat;
use crate::oracle::{OracleKind, Sgo};
use crate::problem::Problem;
use crate::prox::{prox_rows_into, Prox};
use crate::util::rng::Rng;

pub struct Nids {
    x: Mat,
    x_prev: Mat,
    z: Mat,
    g_prev: Mat,
    w_tilde: MixingOp,
    pub eta: f64,
    oracle: Sgo,
    prox: Box<dyn Prox>,
    bits: u64,
    bits_per_entry: u64,
    g: Mat,
    mixed: Mat, // scratch: W̃ · inner
}

impl Nids {
    /// Deprecated shim kept for tests that pin iterate sequences; new
    /// code constructs via [`Nids::builder`] / `Experiment::algorithm`.
    #[deprecated(note = "construct via Nids::builder(&experiment) or Experiment::algorithm()")]
    pub fn new(
        problem: &dyn Problem,
        w: &MixingOp,
        x0: &Mat,
        eta: f64,
        oracle_kind: OracleKind,
        prox: Box<dyn Prox>,
        seed: u64,
    ) -> Nids {
        let mut rng = Rng::new(seed);
        let mut oracle = Sgo::new(oracle_kind, problem, x0, rng.next_u64());
        let n = x0.rows;
        let w_tilde = w.half_lazy();
        // init: Z¹ = X⁰ − η∇F(X⁰); X¹ = prox(Z¹)
        let mut g0 = Mat::zeros(n, x0.cols);
        oracle.sample_all(problem, x0, &mut g0);
        let mut z = x0.clone();
        z.axpy(-eta, &g0);
        let mut x1 = z.clone();
        prox_rows_into(prox.as_ref(), &mut x1, eta);
        Nids {
            x: x1,
            x_prev: x0.clone(),
            z,
            g_prev: g0,
            w_tilde,
            eta,
            oracle,
            prox,
            bits: 0,
            bits_per_entry: 32, // uncompressed f32 wire format (paper's label)
            g: Mat::zeros(n, x0.cols),
            mixed: Mat::zeros(n, x0.cols),
        }
    }
}

impl Algorithm for Nids {
    fn step(&mut self, problem: &dyn Problem) -> RoundStats {
        self.oracle.sample_all(problem, &self.x, &mut self.g);

        // inner = 2Xᵏ − Xᵏ⁻¹ − η(Gᵏ − Gᵏ⁻¹)
        let mut inner = &self.x * 2.0;
        inner -= &self.x_prev;
        inner.axpy(-self.eta, &self.g);
        inner.axpy(self.eta, &self.g_prev);

        // Zᵏ⁺¹ = Zᵏ − Xᵏ + W̃ · inner  (the broadcast is `inner`)
        self.w_tilde.apply_into(&inner, &mut self.mixed);
        self.z -= &self.x;
        self.z += &self.mixed;

        let bits = self.bits_per_entry * (self.x.rows * self.x.cols) as u64;
        self.bits += bits;

        self.x_prev = self.x.clone();
        self.g_prev = self.g.clone();
        let mut xn = self.z.clone();
        prox_rows_into(self.prox.as_ref(), &mut xn, self.eta);
        self.x = xn;
        RoundStats { bits }
    }

    fn x(&self) -> &Mat {
        &self.x
    }

    fn name(&self) -> String {
        format!("NIDS (32bit, {})", self.oracle.name())
    }

    fn grad_evals(&self) -> u64 {
        self.oracle.grad_evals()
    }

    fn bits(&self) -> u64 {
        self.bits
    }

    fn set_eta(&mut self, eta: f64) {
        self.eta = eta;
    }
}

#[cfg(test)]
mod tests {
    // these tests pin the constructor-built iterate sequence directly
    #![allow(deprecated)]
    use super::*;
    use crate::algorithm::testkit::{ring_logreg, run_to};
    use crate::algorithm::solve_reference;
    use crate::problem::Problem;
    use crate::prox::{Zero, L1};

    #[test]
    fn nids_converges_linearly_smooth() {
        let (p, w) = ring_logreg();
        let x_star = solve_reference(&p, 0.0, 40_000, 1e-13);
        let x0 = Mat::zeros(4, p.dim());
        let eta = crate::algorithm::testkit::safe_eta(&p);
        let mut alg = Nids::new(&p, &w, &x0, eta, OracleKind::Full, Box::new(Zero), 3);
        let s = run_to(&mut alg, &p, 3500, &x_star);
        assert!(s < 1e-18, "NIDS smooth suboptimality: {s}");
    }

    #[test]
    fn nids_converges_composite() {
        let (p, w) = ring_logreg();
        let lam = 5e-3;
        let x_star = solve_reference(&p, lam, 40_000, 1e-13);
        let x0 = Mat::zeros(4, p.dim());
        let eta = crate::algorithm::testkit::safe_eta(&p);
        let mut alg = Nids::new(&p, &w, &x0, eta, OracleKind::Full, Box::new(L1::new(lam)), 3);
        let s = run_to(&mut alg, &p, 4000, &x_star);
        assert!(s < 1e-16, "NIDS composite suboptimality: {s}");
    }
}
