//! Decentralized optimization algorithms: Prox-LEAD (Algorithm 1) and every
//! baseline the paper compares against (Figures 1–2, Table 3).
//!
//! All algorithms share the [`Algorithm`] trait: one synchronous round per
//! [`Algorithm::step`] over the stacked n×p iterate matrix, with exact
//! accounting of communicated bits and gradient evaluations. The matrix
//! form runs on one thread (the bench engine); the message-passing
//! [`crate::coordinator`] runs the same arithmetic on node threads — every
//! algorithm here has a per-node half in `coordinator::algorithms`, pinned
//! bit-for-bit against this matrix form under the exact `Dense64` codec.
//!
//! | Module | Algorithms |
//! |---|---|
//! | [`prox_lead`] | Prox-LEAD (= LEAD when r≡0, = PUDA when C=0), all SGO variants |
//! | [`dgd`] | DGD / D-PSGD / Prox-DGD |
//! | [`choco`] | Choco-Gossip / Choco-SGD |
//! | [`nids`] | NIDS (composite form, Li–Shi–Yan 2019) |
//! | [`pg_extra`] | PG-EXTRA (Shi et al. 2015) |
//! | [`p2d2`] | P2D2 / proximal exact diffusion |
//! | [`dual`] | Dual gradient descent, PDGM, LessBit options A/B/C/D |
//! | [`schedule`] | Theorem 7 diminishing-stepsize schedule |
//! | [`reference`] | Centralized FISTA solver for the ground-truth x* |

pub mod builder;
pub mod choco;
pub mod dgd;
pub mod dual;
pub mod nids;
pub mod p2d2;
pub mod pg_extra;
pub mod prox_lead;
pub mod reference;
pub mod schedule;

pub use builder::{
    dualgd_default_theta, pdgm_default_theta, AlgorithmParts, ChocoBuilder, DgdBuilder,
    DualGdBuilder, NidsBuilder, P2d2Builder, PdgmBuilder, PgExtraBuilder, ProxLeadBuilder,
    DUALGD_INNER_ITERS, DUALGD_INNER_TOL,
};
pub use choco::Choco;
pub use dgd::Dgd;
pub use dual::{DualGd, Pdgm};
pub use nids::Nids;
pub use p2d2::P2d2;
pub use pg_extra::PgExtra;
pub use prox_lead::ProxLead;
pub use reference::solve_reference;
pub use schedule::Schedule;

use crate::compress::Compressor;
use crate::graph::MixingOp;
use crate::linalg::Mat;
use crate::util::rng::Rng;

/// What one synchronous round cost.
#[derive(Clone, Copy, Debug, Default)]
pub struct RoundStats {
    /// Wire bits communicated by all nodes this round.
    pub bits: u64,
}

/// A decentralized algorithm in stacked matrix form.
pub trait Algorithm: Send {
    /// Run one synchronous round (gradient → communication → update).
    fn step(&mut self, problem: &dyn crate::problem::Problem) -> RoundStats;

    /// Current stacked iterates (row i = node i's x).
    fn x(&self) -> &Mat;

    /// Display name, e.g. `"Prox-LEAD (2bit, saga)"`.
    fn name(&self) -> String;

    /// Cumulative batch-gradient evaluations (from the SGO).
    fn grad_evals(&self) -> u64;

    /// Cumulative communicated bits.
    fn bits(&self) -> u64;

    /// Update the stepsize (diminishing-stepsize schedules, Theorem 7).
    /// Algorithms that also scale α/γ with η override this.
    fn set_eta(&mut self, _eta: f64) {}

    /// Update all hyperparameters at once (Theorem 7 sets ηᵏ, αᵏ, γᵏ
    /// together). Default: only the stepsize is adjustable.
    fn apply_hyper(&mut self, h: Hyper) {
        self.set_eta(h.eta);
    }
}

/// Shared hyperparameters. The paper's §5 defaults: η tuned in [0.01, 0.1],
/// α = 0.5, γ = 1.0 ("very robust to parameter settings").
#[derive(Clone, Copy, Debug)]
pub struct Hyper {
    /// Primal stepsize η.
    pub eta: f64,
    /// Compression-state blending rate α ∈ (0, (1+C)⁻¹).
    pub alpha: f64,
    /// Dual stepsize scale γ (λ = γ/η in the PDHG view).
    pub gamma: f64,
}

impl Hyper {
    pub fn paper_default(eta: f64) -> Hyper {
        Hyper { eta, alpha: 0.5, gamma: 1.0 }
    }

    /// Theory-driven parameters from Theorem 5 given (L, μ, C, λmax(I−W)).
    pub fn theorem5(l: f64, mu: f64, c: f64, lmax_iw: f64) -> Hyper {
        let eta = 0.5 / l;
        let alpha = 0.9 * (eta * mu / c.sqrt().max(1e-12)).min(1.0 / (1.0 + c));
        let delta = alpha - (1.0 + c) * alpha * alpha;
        let gamma = if c == 0.0 {
            1.0
        } else {
            (1.0 / lmax_iw)
                * ((2.0 * eta * mu - 2.0 * c.sqrt() * alpha) / (eta * mu)).min(delta / c.sqrt())
        };
        Hyper { eta, alpha, gamma }
    }
}

/// The COMM procedure of Algorithm 1: difference compression against the
/// running state H, with both endpoints tracking H and H_w = WH.
///
/// ```text
/// Qᵏ    = Q(Z − H)              (compress, one vector per node row)
/// Ẑ     = H + Qᵏ
/// Ẑ_w   = H_w + W Qᵏ            (the only actual communication)
/// H     ← (1−α) H + α Ẑ   (= H + αQᵏ)
/// H_w   ← (1−α) H_w + α Ẑ_w (= H_w + αWQᵏ)
/// ```
///
/// Returns (Ẑ, Ẑ_w) and the exact wire bits of the encoded Qᵏ rows. The
/// W·Q product runs through [`MixingOp::apply_into`] over preallocated
/// scratch — O(nnz·p) per round on sparse topologies, with no allocation
/// in the product itself. (The returned Ẑ/Ẑ_w estimates are freshly built
/// Mats each round; they are handed to the caller by value.)
pub struct CommState {
    pub h: Mat,
    pub h_w: Mat,
    pub alpha: f64,
    /// Scratch: the decoded compressed differences Qᵏ (every row is
    /// overwritten each round).
    q: Mat,
    /// Scratch: W · Qᵏ.
    wq: Mat,
    /// Scratch: one row of Z − H handed to the compressor.
    diff: Vec<f64>,
}

impl CommState {
    /// Initialize with H¹ and H_w¹ = W H¹ (Algorithm 1 line 1).
    pub fn new(h1: Mat, w: &MixingOp, alpha: f64) -> CommState {
        let h_w = w.apply(&h1);
        let (n, p) = (h1.rows, h1.cols);
        CommState {
            h: h1,
            h_w,
            alpha,
            q: Mat::zeros(n, p),
            wq: Mat::zeros(n, p),
            diff: vec![0.0; p],
        }
    }

    /// One compressed communication round over the rows of `z`.
    pub fn comm(
        &mut self,
        z: &Mat,
        w: &MixingOp,
        comp: &dyn Compressor,
        rng: &mut Rng,
    ) -> (Mat, Mat, u64) {
        let n = z.rows;
        let mut bits = 0u64;
        for i in 0..n {
            for ((d, &zi), &hi) in self.diff.iter_mut().zip(z.row(i)).zip(self.h.row(i)) {
                *d = zi - hi;
            }
            let c = comp.compress(&self.diff, rng);
            bits += c.bits;
            self.q.row_mut(i).copy_from_slice(&c.decoded);
        }
        w.apply_into(&self.q, &mut self.wq);
        let z_hat = &self.h + &self.q;
        let zw_hat = &self.h_w + &self.wq;
        self.h.axpy(self.alpha, &self.q);
        self.h_w.axpy(self.alpha, &self.wq);
        (z_hat, zw_hat, bits)
    }
}

/// Suboptimality ‖X − 1(x*)ᵀ‖²_F / n against a reference solution — the
/// y-axis of every figure in §5.
pub fn suboptimality(x: &Mat, x_star: &[f64]) -> f64 {
    let mut acc = 0.0;
    for i in 0..x.rows {
        acc += crate::linalg::matrix::vdist_sq(x.row(i), x_star);
    }
    acc / x.rows as f64
}

#[cfg(test)]
pub(crate) mod testkit {
    //! Shared fixtures for per-algorithm convergence tests.
    use crate::graph::{Graph, MixingOp, MixingRule};
    use crate::problem::data::{blobs, BlobSpec};
    use crate::problem::LogReg;

    /// Small, well-conditioned 4-node ring logreg problem + uniform mixing
    /// operator (κ_f ≈ 20 so convergence tests finish in a few thousand
    /// rounds; the bench harness exercises the paper-scale conditioning).
    pub fn ring_logreg() -> (LogReg, MixingOp) {
        let spec = BlobSpec {
            nodes: 4,
            samples_per_node: 24,
            dim: 5,
            classes: 3,
            separation: 1.0,
            seed: 33,
            ..Default::default()
        };
        let p = LogReg::new(blobs(&spec), 3, 0.1, 4);
        let g = Graph::ring(4);
        let w = MixingOp::dense_from(&g, MixingRule::UniformMaxDegree);
        (p, w)
    }

    /// A stepsize at the Theorem 5 bound η = 1/(2L) for this problem.
    pub fn safe_eta(p: &LogReg) -> f64 {
        use crate::problem::Problem;
        0.5 / p.smoothness()
    }

    /// The [`ring_logreg`] fixture as a resolved [`crate::exp::Experiment`]
    /// — identical problem, graph, mixing operator, and auto-η (the config
    /// below renders the exact same BlobSpec and ring), so builders started
    /// from it reproduce the fixture-built algorithms bit for bit.
    pub fn ring_exp() -> crate::exp::Experiment {
        let cfg = crate::config::Config::parse(
            "nodes = 4\nsamples_per_node = 24\ndim = 5\nclasses = 3\nbatches = 4\n\
             separation = 1.0\nseed = 33\nlambda1 = 0\nlambda2 = 0.1\nbits = 2\n",
        )
        .expect("ring_exp config");
        crate::exp::Experiment::from_config(&cfg).expect("ring_exp experiment")
    }

    /// Run `alg` for `rounds` and return final suboptimality vs `x_star`.
    pub fn run_to(
        alg: &mut dyn super::Algorithm,
        problem: &dyn crate::problem::Problem,
        rounds: usize,
        x_star: &[f64],
    ) -> f64 {
        for _ in 0..rounds {
            alg.step(problem);
        }
        super::suboptimality(alg.x(), x_star)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Identity;
    use crate::graph::{Graph, MixingRule};

    #[test]
    fn comm_identity_is_transparent() {
        // with identity compression, Ẑ = Z and Ẑ_w = WZ regardless of H
        let g = Graph::ring(4);
        let w = MixingOp::dense_from(&g, MixingRule::UniformMaxDegree);
        let mut rng = Rng::new(4);
        let mut z = Mat::zeros(4, 6);
        rng.fill_normal(&mut z.data);
        let mut h1 = Mat::zeros(4, 6);
        rng.fill_normal(&mut h1.data);
        let mut comm = CommState::new(h1, &w, 0.5);
        let id = Identity::f64();
        let (z_hat, zw_hat, bits) = comm.comm(&z, &w, &id, &mut rng);
        assert!(z_hat.dist_sq(&z) < 1e-24);
        assert!(zw_hat.dist_sq(&w.apply(&z)) < 1e-20);
        assert_eq!(bits, 4 * 6 * 64);
    }

    #[test]
    fn comm_h_converges_to_fixed_z() {
        // repeatedly communicating the same Z must drive H → Z (the error-
        // vanishing property that makes compression "free" asymptotically)
        let g = Graph::ring(4);
        let w = MixingOp::dense_from(&g, MixingRule::UniformMaxDegree);
        let mut rng = Rng::new(5);
        let mut z = Mat::zeros(4, 64);
        rng.fill_normal(&mut z.data);
        let comp = crate::compress::InfNormQuantizer::new(2, 64);
        let mut comm = CommState::new(Mat::zeros(4, 64), &w, 0.5);
        let mut last = f64::MAX;
        for it in 0..200 {
            comm.comm(&z, &w, &comp, &mut rng);
            let err = comm.h.dist_sq(&z);
            if it % 50 == 49 {
                assert!(err < last, "H not approaching Z: {err} vs {last}");
                last = err;
            }
        }
        assert!(comm.h.dist_sq(&z) < 1e-6 * z.norm_sq());
        // h_w must track W·H exactly (both sides apply the same updates)
        assert!(comm.h_w.dist_sq(&w.apply(&comm.h)) < 1e-18);
    }

    #[test]
    fn comm_identical_through_dense_and_sparse_mixing() {
        // the same COMM round through both representations, bit for bit
        let g = Graph::ring(16);
        let dense = MixingOp::dense_from(&g, MixingRule::UniformMaxDegree);
        let sparse = MixingOp::sparse_from(&g, MixingRule::UniformMaxDegree);
        let comp = crate::compress::InfNormQuantizer::new(2, 64);
        let mut z = Mat::zeros(16, 24);
        Rng::new(8).fill_normal(&mut z.data);
        let mut comm_d = CommState::new(Mat::zeros(16, 24), &dense, 0.5);
        let mut comm_s = CommState::new(Mat::zeros(16, 24), &sparse, 0.5);
        let (mut rng_d, mut rng_s) = (Rng::new(9), Rng::new(9));
        for _ in 0..50 {
            let (zd, zwd, bd) = comm_d.comm(&z, &dense, &comp, &mut rng_d);
            let (zs, zws, bs) = comm_s.comm(&z, &sparse, &comp, &mut rng_s);
            assert_eq!(bd, bs);
            assert_eq!(zd.data, zs.data);
            for (a, b) in zwd.data.iter().zip(&zws.data) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        for (a, b) in comm_d.h_w.data.iter().zip(&comm_s.h_w.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn hyper_theorem5_feasible() {
        let h = Hyper::theorem5(10.0, 0.1, 0.3, 2.0);
        assert!(h.eta > 0.0 && h.eta <= 0.5 / 10.0 + 1e-15);
        assert!(h.alpha > 0.0 && h.alpha < 1.0 / 1.3);
        assert!(h.gamma > 0.0);
        // C = 0 degenerates to the uncompressed choice γ = 1
        let h0 = Hyper::theorem5(10.0, 0.1, 0.0, 2.0);
        assert_eq!(h0.gamma, 1.0);
    }

    #[test]
    fn suboptimality_zero_at_consensus() {
        let star = vec![1.0, -2.0];
        let x = Mat::broadcast_row(5, &star);
        assert_eq!(suboptimality(&x, &star), 0.0);
    }
}
