//! Choco-SGD / Choco-Gossip (Koloskova, Stich, Jaggi 2019) — the paper's
//! main compressed baseline.
//!
//! Each node keeps a public replica x̂ of its own iterate; only compressed
//! *differences* against the replica are transmitted:
//!
//! ```text
//! X½  = Xᵏ − η Gᵏ                        (gradient step; absent ⇒ gossip)
//! Qᵏ  = Q(X½ − X̂ᵏ)
//! X̂ᵏ⁺¹ = X̂ᵏ + Qᵏ                         (all neighbors update replicas)
//! Xᵏ⁺¹ = X½ + γ_c (W − I) X̂ᵏ⁺¹
//! ```
//!
//! Choco converges sublinearly (under bounded-gradient assumptions the
//! paper's algorithms avoid) and inherits DGD's fixed-stepsize bias — both
//! visible in Fig. 1a.
//!
//! Per-node counterpart: [`crate::coordinator::ChocoNode`] — each node
//! tracks the public replicas x̂ⱼ of itself and its gossip neighbors and
//! advances them by the decoded wire differences.

use super::{Algorithm, RoundStats};
use crate::compress::Compressor;
use crate::graph::MixingOp;
use crate::linalg::Mat;
use crate::oracle::{OracleKind, Sgo};
use crate::problem::Problem;
use crate::prox::{prox_rows_into, Prox};
use crate::util::rng::Rng;

pub struct Choco {
    x: Mat,
    x_hat: Mat,
    w_minus_i: MixingOp,
    pub eta: f64,
    /// Consensus stepsize γ_c (tuned in {0.01 … 1.0} per §5).
    pub gamma_c: f64,
    oracle: Sgo,
    comp: Box<dyn Compressor>,
    prox: Box<dyn Prox>,
    rng: Rng,
    bits: u64,
    g: Mat,
    corr: Mat, // scratch: (W − I) X̂
}

impl Choco {
    #[allow(clippy::too_many_arguments)]
    /// Deprecated shim kept for tests that pin iterate sequences; new
    /// code constructs via [`Choco::builder`] / `Experiment::algorithm`.
    #[deprecated(note = "construct via Choco::builder(&experiment) or Experiment::algorithm()")]
    pub fn new(
        problem: &dyn Problem,
        w: &MixingOp,
        x0: &Mat,
        eta: f64,
        gamma_c: f64,
        oracle_kind: OracleKind,
        comp: Box<dyn Compressor>,
        prox: Box<dyn Prox>,
        seed: u64,
    ) -> Choco {
        let mut rng = Rng::new(seed);
        let oracle = Sgo::new(oracle_kind, problem, x0, rng.next_u64());
        Choco {
            x: x0.clone(),
            x_hat: Mat::zeros(x0.rows, x0.cols),
            w_minus_i: w.minus_identity(),
            eta,
            gamma_c,
            oracle,
            comp,
            prox,
            rng,
            bits: 0,
            g: Mat::zeros(x0.rows, x0.cols),
            corr: Mat::zeros(x0.rows, x0.cols),
        }
    }
}

impl Algorithm for Choco {
    fn step(&mut self, problem: &dyn Problem) -> RoundStats {
        self.oracle.sample_all(problem, &self.x, &mut self.g);

        // gradient half-step
        let mut x_half = self.x.clone();
        x_half.axpy(-self.eta, &self.g);

        // compressed replica update
        let mut bits = 0u64;
        let mut diff = vec![0.0; self.x.cols];
        for i in 0..self.x.rows {
            for ((d, &xi), &hi) in diff.iter_mut().zip(x_half.row(i)).zip(self.x_hat.row(i)) {
                *d = xi - hi;
            }
            let c = self.comp.compress(&diff, &mut self.rng);
            bits += c.bits;
            for (h, &q) in self.x_hat.row_mut(i).iter_mut().zip(&c.decoded) {
                *h += q;
            }
        }
        self.bits += bits;

        // consensus correction through the replicas
        self.w_minus_i.apply_into(&self.x_hat, &mut self.corr);
        x_half.axpy(self.gamma_c, &self.corr);
        prox_rows_into(self.prox.as_ref(), &mut x_half, self.eta);
        self.x = x_half;
        RoundStats { bits }
    }

    fn x(&self) -> &Mat {
        &self.x
    }

    fn name(&self) -> String {
        let base = if self.oracle.is_exact() { "Choco" } else { "Choco-SGD" };
        format!("{base} ({}, {})", self.comp.name(), self.oracle.name())
    }

    fn grad_evals(&self) -> u64 {
        self.oracle.grad_evals()
    }

    fn bits(&self) -> u64 {
        self.bits
    }

    fn set_eta(&mut self, eta: f64) {
        self.eta = eta;
    }
}

#[cfg(test)]
mod tests {
    // these tests pin the constructor-built iterate sequence directly
    #![allow(deprecated)]
    use super::*;
    use crate::algorithm::testkit::{ring_logreg, run_to};
    use crate::algorithm::solve_reference;
    use crate::compress::InfNormQuantizer;
    use crate::problem::Problem;
    use crate::prox::Zero;

    #[test]
    fn choco_reaches_neighborhood_with_2bit() {
        let (p, w) = ring_logreg();
        let x_star = solve_reference(&p, 0.0, 40_000, 1e-13);
        let x0 = Mat::zeros(4, p.dim());
        let mut alg = Choco::new(
            &p,
            &w,
            &x0,
            0.05,
            0.2,
            OracleKind::Full,
            Box::new(InfNormQuantizer::new(2, 256)),
            Box::new(Zero),
            5,
        );
        let s = run_to(&mut alg, &p, 4000, &x_star);
        assert!(s.is_finite() && s < 1e-1, "Choco should be stable and near: {s}");
        assert!(s > 1e-13, "Choco has DGD's bias, must not be exact: {s}");
    }

    #[test]
    fn replicas_track_iterates() {
        let (p, w) = ring_logreg();
        let x0 = Mat::zeros(4, p.dim());
        let mut alg = Choco::new(
            &p,
            &w,
            &x0,
            0.05,
            0.2,
            OracleKind::Full,
            Box::new(InfNormQuantizer::new(4, 256)),
            Box::new(Zero),
            5,
        );
        for _ in 0..1500 {
            alg.step(&p);
        }
        // once near the fixed point the replica error is small relative scale
        let rel = alg.x_hat.dist_sq(&alg.x) / alg.x.norm_sq().max(1e-300);
        assert!(rel < 1e-2, "replica divergence: {rel}");
    }
}
