//! P2D2 (Alghunaim, Yuan, Sayed 2019) — the linearly-convergent proximal
//! decentralized baseline of the paper's Fig. 2. Exact-diffusion-style
//! tracking applied to the *pre-prox* variable Z so the proximal map sits
//! at the fixed point the theory demands (x* = prox_ηr(x* − η∇f̄(x*))):
//!
//! ```text
//! Z¹    = W̃ ( X⁰ − η ∇F(X⁰) ),            X¹ = prox_ηR(Z¹)
//! Zᵏ⁺¹  = W̃ ( Zᵏ + Xᵏ − Xᵏ⁻¹ − η(∇F(Xᵏ) − ∇F(Xᵏ⁻¹)) )
//! Xᵏ⁺¹  = prox_ηR(Zᵏ⁺¹)
//! ```
//!
//! with W̃ = (I+W)/2. Averaging over nodes telescopes to
//! z̄ᵏ = x̄ᵏ − η ḡᵏ (W̃ preserves row means), so the consensual fixed point
//! is exactly the composite optimum; the W̃ contraction on the disagreement
//! subspace gives the linear rate. One broadcast per node per round.
//!
//! Per-node counterpart: [`crate::coordinator::P2d2Node`] — the init
//! product Z¹ = W̃(X⁰ − η∇F(X⁰)) needs the neighbors' gradients, so the
//! node half declares one *setup round* the coordinator driver exchanges
//! before step counting starts (the engine performs it at construction).

use super::{Algorithm, RoundStats};
use crate::graph::MixingOp;
use crate::linalg::Mat;
use crate::oracle::{OracleKind, Sgo};
use crate::problem::Problem;
use crate::prox::{prox_rows_into, Prox};
use crate::util::rng::Rng;

pub struct P2d2 {
    x: Mat,
    x_prev: Mat,
    z: Mat,
    g_prev: Mat,
    w_tilde: MixingOp,
    pub eta: f64,
    oracle: Sgo,
    prox: Box<dyn Prox>,
    bits: u64,
    g: Mat,
}

impl P2d2 {
    /// Deprecated shim kept for tests that pin iterate sequences; new
    /// code constructs via [`P2d2::builder`] / `Experiment::algorithm`.
    #[deprecated(note = "construct via P2d2::builder(&experiment) or Experiment::algorithm()")]
    pub fn new(
        problem: &dyn Problem,
        w: &MixingOp,
        x0: &Mat,
        eta: f64,
        oracle_kind: OracleKind,
        prox: Box<dyn Prox>,
        seed: u64,
    ) -> P2d2 {
        let mut rng = Rng::new(seed);
        let mut oracle = Sgo::new(oracle_kind, problem, x0, rng.next_u64());
        let n = x0.rows;
        let w_tilde = w.half_lazy();
        // init: Z¹ = W̃(X⁰ − η∇F(X⁰)), X¹ = prox(Z¹)
        let mut g0 = Mat::zeros(n, x0.cols);
        oracle.sample_all(problem, x0, &mut g0);
        let mut pre = x0.clone();
        pre.axpy(-eta, &g0);
        let z = w_tilde.apply(&pre);
        let mut x1 = z.clone();
        prox_rows_into(prox.as_ref(), &mut x1, eta);
        P2d2 {
            x: x1,
            x_prev: x0.clone(),
            z,
            g_prev: g0,
            w_tilde,
            eta,
            oracle,
            prox,
            bits: 0,
            g: Mat::zeros(n, x0.cols),
        }
    }
}

impl Algorithm for P2d2 {
    fn step(&mut self, problem: &dyn Problem) -> RoundStats {
        self.oracle.sample_all(problem, &self.x, &mut self.g);

        // inner = Zᵏ + Xᵏ − Xᵏ⁻¹ − η(Gᵏ − Gᵏ⁻¹); broadcast and combine
        let mut inner = self.z.clone();
        inner += &self.x;
        inner -= &self.x_prev;
        inner.axpy(-self.eta, &self.g);
        inner.axpy(self.eta, &self.g_prev);

        let bits = 32 * (self.x.rows * self.x.cols) as u64;
        self.bits += bits;
        // Z is overwritten in place: `inner` is a distinct buffer
        self.w_tilde.apply_into(&inner, &mut self.z);

        self.x_prev = self.x.clone();
        self.g_prev = self.g.clone();
        let mut xn = self.z.clone();
        prox_rows_into(self.prox.as_ref(), &mut xn, self.eta);
        self.x = xn;
        RoundStats { bits }
    }

    fn x(&self) -> &Mat {
        &self.x
    }

    fn name(&self) -> String {
        format!("P2D2 (32bit, {})", self.oracle.name())
    }

    fn grad_evals(&self) -> u64 {
        self.oracle.grad_evals()
    }

    fn bits(&self) -> u64 {
        self.bits
    }

    fn set_eta(&mut self, eta: f64) {
        self.eta = eta;
    }
}

#[cfg(test)]
mod tests {
    // these tests pin the constructor-built iterate sequence directly
    #![allow(deprecated)]
    use super::*;
    use crate::algorithm::testkit::{ring_logreg, run_to};
    use crate::algorithm::solve_reference;
    use crate::problem::Problem;
    use crate::prox::{Zero, L1};

    #[test]
    fn p2d2_converges_smooth() {
        let (p, w) = ring_logreg();
        let x_star = solve_reference(&p, 0.0, 40_000, 1e-13);
        let x0 = Mat::zeros(4, p.dim());
        let eta = crate::algorithm::testkit::safe_eta(&p);
        let mut alg = P2d2::new(&p, &w, &x0, eta, OracleKind::Full, Box::new(Zero), 3);
        let s = run_to(&mut alg, &p, 4000, &x_star);
        assert!(s < 1e-16, "P2D2 smooth suboptimality: {s}");
    }

    #[test]
    fn p2d2_converges_composite_linearly() {
        let (p, w) = ring_logreg();
        let lam = 5e-3;
        let x_star = solve_reference(&p, lam, 40_000, 1e-13);
        let x0 = Mat::zeros(4, p.dim());
        let eta = crate::algorithm::testkit::safe_eta(&p);
        let mut alg = P2d2::new(&p, &w, &x0, eta, OracleKind::Full, Box::new(L1::new(lam)), 3);
        let s = run_to(&mut alg, &p, 4500, &x_star);
        assert!(s < 1e-14, "P2D2 composite suboptimality: {s}");
    }
}
