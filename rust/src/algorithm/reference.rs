//! Centralized reference solver — computes the ground-truth x* that every
//! figure's suboptimality axis ‖Xᵏ − 1(x*)ᵀ‖² is measured against
//! (the paper solves the same problem to high precision offline).
//!
//! FISTA with adaptive restart (O'Donoghue–Candès) on
//! min (1/n) Σᵢ f_i(x) + r(x), stepsize 1/L, run until the prox-gradient
//! mapping is below `tol`.

use crate::linalg::matrix::vdist_sq;
use crate::problem::Problem;
use crate::prox::{Prox, Zero, L1};

/// Solve min (1/n)Σ f_i + r by FISTA-with-restart. Returns x*.
pub fn solve_reference_prox(
    problem: &dyn Problem,
    r: &dyn Prox,
    max_iter: usize,
    tol: f64,
) -> Vec<f64> {
    let p = problem.dim();
    let eta = 1.0 / problem.smoothness();
    let mut x = vec![0.0; p];
    let mut x_prev = x.clone();
    let mut y = x.clone();
    let mut g = vec![0.0; p];
    let mut t = 1.0f64;

    for _ in 0..max_iter {
        problem.global_grad(&y, &mut g);
        // x⁺ = prox_{ηr}(y − η∇f(y))
        let mut x_next: Vec<f64> = y.iter().zip(&g).map(|(yi, gi)| yi - eta * gi).collect();
        r.prox(&mut x_next, eta);

        // prox-gradient mapping ‖x⁺ − y‖/η is the stationarity measure
        let mapping = vdist_sq(&x_next, &y).sqrt() / eta;

        // adaptive restart: momentum is hurting when ⟨y − x⁺, x⁺ − x⟩ > 0
        let restart: f64 = y
            .iter()
            .zip(&x_next)
            .zip(x_next.iter().zip(&x))
            .map(|((yi, xn), (xn2, xi))| (yi - xn) * (xn2 - xi))
            .sum();
        if restart > 0.0 {
            t = 1.0;
        }
        let t_next = 0.5 * (1.0 + (1.0 + 4.0 * t * t).sqrt());
        let beta = (t - 1.0) / t_next;
        for ((yi, &xn), &xp) in y.iter_mut().zip(&x_next).zip(&x) {
            *yi = xn + beta * (xn - xp);
        }
        x_prev.copy_from_slice(&x);
        x.copy_from_slice(&x_next);
        t = t_next;

        if mapping < tol {
            break;
        }
    }
    let _ = x_prev;
    x
}

/// Convenience wrapper: r = λ₁‖x‖₁ (λ₁ = 0 ⇒ smooth problem).
pub fn solve_reference(problem: &dyn Problem, lambda1: f64, max_iter: usize, tol: f64) -> Vec<f64> {
    if lambda1 == 0.0 {
        solve_reference_prox(problem, &Zero, max_iter, tol)
    } else {
        solve_reference_prox(problem, &L1::new(lambda1), max_iter, tol)
    }
}

/// Sanity measure: ‖prox-gradient mapping‖ at x for the composite problem.
pub fn stationarity(problem: &dyn Problem, r: &dyn Prox, x: &[f64]) -> f64 {
    let eta = 1.0 / problem.smoothness();
    let mut g = vec![0.0; problem.dim()];
    problem.global_grad(x, &mut g);
    let mut xp: Vec<f64> = x.iter().zip(&g).map(|(xi, gi)| xi - eta * gi).collect();
    r.prox(&mut xp, eta);
    vdist_sq(&xp, x).sqrt() / eta
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::{vaxpy, vnorm};
    use crate::problem::data::sparse_regression;
    use crate::problem::{LeastSquares, Problem};

    #[test]
    fn ridge_matches_closed_form() {
        let (shards, _) = sparse_regression(3, 30, 8, 3, 0.1, 3);
        let p = LeastSquares::new(shards, 0.05, 3);
        let x = solve_reference(&p, 0.0, 20_000, 1e-13);
        // closed form: (H + 2λI)x = c with H = (1/n)Σ AᵀA/m
        let n = p.num_nodes();
        let dim = p.dim();
        let mut h = crate::linalg::Mat::zeros(dim, dim);
        let mut c = vec![0.0; dim];
        for s in p.shards() {
            let m = s.targets.len() as f64;
            h.axpy(1.0 / (n as f64 * m), &s.features.t_matmul(&s.features));
            for (r, &t) in s.targets.iter().enumerate() {
                vaxpy(&mut c, t / (n as f64 * m), s.features.row(r));
            }
        }
        for i in 0..dim {
            h[(i, i)] += 2.0 * p.lambda2;
        }
        let (evals, vecs) = crate::linalg::eigen::sym_eigen(&h);
        let mut x_cf = vec![0.0; dim];
        for (j, &lam) in evals.iter().enumerate() {
            let vj = vecs.col(j);
            let coef = crate::linalg::matrix::vdot(&vj, &c) / lam;
            vaxpy(&mut x_cf, coef, &vj);
        }
        assert!(vdist_sq(&x, &x_cf).sqrt() < 1e-8, "FISTA vs closed form");
    }

    #[test]
    fn lasso_solution_is_stationary_and_sparse() {
        let (shards, x_true) = sparse_regression(4, 40, 20, 4, 0.01, 8);
        let p = LeastSquares::new(shards, 0.0, 4).with_mu(1e-3);
        let lam = 0.05;
        let x = solve_reference(&p, lam, 50_000, 1e-12);
        let r = L1::new(lam);
        assert!(stationarity(&p, &r, &x) < 1e-9);
        // lasso recovers the support pattern approximately
        let nnz = x.iter().filter(|v| v.abs() > 1e-6).count();
        assert!(nnz <= 2 * x_true.iter().filter(|v| **v != 0.0).count() + 2);
        assert!(vnorm(&x) > 0.1, "lasso should not collapse to zero");
    }
}
