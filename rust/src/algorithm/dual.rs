//! Dual-space algorithms from §4.3: dual gradient descent, PDGM, and the
//! LessBit family (Kovalev et al. 2021) recovered by adding COMM
//! compression to their communication step.
//!
//! - [`DualGd`] — exact dual gradient descent
//!   `Dᵏ⁺¹ = Dᵏ + θ(I−W)·∇F*(−Dᵏ)` where ∇F*(−Dᵏ) = argmin F(X) + ⟨Dᵏ, X⟩
//!   is solved per node by an inner gradient loop. Compressing the X
//!   broadcast gives **LessBit Option A**. Complexity Õ(κ_f·κ_g) — the
//!   worst row of Table 3.
//! - [`Pdgm`] — one inexact primal GD step per dual update
//!   (Alghunaim–Sayed 2020). Compressing the X broadcast gives **LessBit
//!   Option B**; with an SGD oracle **Option C**; with LSVRG **Option D**.
//!
//! LEAD/Prox-LEAD add a *second* primal step (free: the gradient is
//! reused), which is the whole Õ(κ_f·κ_g) → Õ(κ_f + κ_g) improvement the
//! paper's Table 3 tracks.
//!
//! Per-node counterparts: [`crate::coordinator::DualGdNode`] /
//! [`crate::coordinator::PdgmNode`] — a lossy wire codec switches them onto
//! the shared compressed-comm node half (`NodeComm`), recovering LessBit
//! options A and B/C/D on real frames.

use super::{Algorithm, CommState, RoundStats};
use crate::compress::{Compressor, Identity};
use crate::graph::MixingOp;
use crate::linalg::Mat;
use crate::oracle::{OracleKind, Sgo};
use crate::problem::Problem;
use crate::util::rng::Rng;

/// Exact dual gradient ascent with an inner primal solver.
pub struct DualGd {
    x: Mat,
    d: Mat,
    w: MixingOp,
    /// Dual stepsize θ.
    pub theta: f64,
    /// Inner GD stepsize (1/L) and iteration budget.
    pub inner_eta: f64,
    pub inner_iters: usize,
    pub inner_tol: f64,
    comm: Option<CommState>,
    comp: Box<dyn Compressor>,
    rng: Rng,
    bits: u64,
    inner_grad_evals: u64,
    label: String,
    /// Scratch W·X for the uncompressed path only; empty when `comm` is
    /// Some (compressed runs gossip through CommState's own buffers).
    wx: Mat,
}

impl DualGd {
    /// Deprecated shim kept for tests that pin iterate sequences; new
    /// code constructs via [`DualGd::builder`] / `Experiment::algorithm`.
    #[deprecated(note = "construct via DualGd::builder(&experiment) or Experiment::algorithm()")]
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        problem: &dyn Problem,
        w: &MixingOp,
        x0: &Mat,
        theta: f64,
        inner_iters: usize,
        comp: Box<dyn Compressor>,
        alpha: f64,
        seed: u64,
    ) -> DualGd {
        let compressed = comp.variance_bound() > 0.0;
        let comm = compressed.then(|| CommState::new(x0.clone(), w, alpha));
        let label = if compressed { "LessBit-A".to_string() } else { "DualGD".to_string() };
        DualGd {
            x: x0.clone(),
            d: Mat::zeros(x0.rows, x0.cols),
            w: w.clone(),
            theta,
            inner_eta: 1.0 / problem.smoothness(),
            inner_iters,
            inner_tol: super::DUALGD_INNER_TOL,
            comm,
            comp,
            rng: Rng::new(seed),
            bits: 0,
            inner_grad_evals: 0,
            label,
            wx: if compressed { Mat::zeros(0, 0) } else { Mat::zeros(x0.rows, x0.cols) },
        }
    }
}

impl Algorithm for DualGd {
    fn step(&mut self, problem: &dyn Problem) -> RoundStats {
        let n = problem.num_nodes();
        let p = problem.dim();
        let m = problem.num_batches() as u64;

        // inner solve: x_i = argmin f_i(x) + ⟨d_i, x⟩ per node (∇F*(−D))
        let mut g = vec![0.0; p];
        for i in 0..n {
            let mut xi = self.x.row(i).to_vec();
            for _ in 0..self.inner_iters {
                problem.grad(i, &xi, &mut g);
                self.inner_grad_evals += m;
                let mut sq = 0.0;
                for (gj, &dj) in g.iter_mut().zip(self.d.row(i)) {
                    *gj += dj;
                    sq += *gj * *gj;
                }
                if sq.sqrt() < self.inner_tol {
                    break;
                }
                for (xj, &gj) in xi.iter_mut().zip(&g) {
                    *xj -= self.inner_eta * gj;
                }
            }
            self.x.row_mut(i).copy_from_slice(&xi);
        }

        // communicate X (compressed ⇒ LessBit-A) and ascend the dual
        let bits = match &mut self.comm {
            Some(c) => {
                let (x_hat, xw_hat, bits) =
                    c.comm(&self.x, &self.w, self.comp.as_ref(), &mut self.rng);
                let mut resid = x_hat;
                resid -= &xw_hat; // (I−W)X̂
                self.d.axpy(self.theta, &resid);
                bits
            }
            None => {
                // D += θ(I−W)X, fused over the preallocated W·X scratch
                self.w.apply_into(&self.x, &mut self.wx);
                for ((d, &x), &wx) in
                    self.d.data.iter_mut().zip(&self.x.data).zip(&self.wx.data)
                {
                    *d += self.theta * (x - wx);
                }
                32 * (n * p) as u64
            }
        };
        self.bits += bits;
        RoundStats { bits }
    }

    fn x(&self) -> &Mat {
        &self.x
    }

    fn name(&self) -> String {
        format!("{} ({})", self.label, self.comp.name())
    }

    fn grad_evals(&self) -> u64 {
        self.inner_grad_evals
    }

    fn bits(&self) -> u64 {
        self.bits
    }
}

/// Primal-dual gradient method: one primal GD step per dual ascent step.
pub struct Pdgm {
    x: Mat,
    d: Mat,
    w: MixingOp,
    pub eta: f64,
    pub theta: f64,
    comm: Option<CommState>,
    comp: Box<dyn Compressor>,
    oracle: Sgo,
    rng: Rng,
    bits: u64,
    g: Mat,
    label: String,
    /// Scratch W·X for the uncompressed path only; empty when `comm` is
    /// Some (compressed runs gossip through CommState's own buffers).
    wx: Mat,
}

impl Pdgm {
    /// Deprecated shim kept for tests that pin iterate sequences; new
    /// code constructs via [`Pdgm::builder`] / `Experiment::algorithm`.
    #[deprecated(note = "construct via Pdgm::builder(&experiment) or Experiment::algorithm()")]
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        problem: &dyn Problem,
        w: &MixingOp,
        x0: &Mat,
        eta: f64,
        theta: f64,
        oracle_kind: OracleKind,
        comp: Box<dyn Compressor>,
        alpha: f64,
        seed: u64,
    ) -> Pdgm {
        let mut rng = Rng::new(seed);
        let oracle = Sgo::new(oracle_kind, problem, x0, rng.next_u64());
        let compressed = comp.variance_bound() > 0.0;
        let comm = compressed.then(|| CommState::new(x0.clone(), w, alpha));
        let label = match (compressed, oracle_kind) {
            (false, _) => "PDGM".to_string(),
            (true, OracleKind::Full) => "LessBit-B".to_string(),
            (true, OracleKind::Sgd) => "LessBit-SGD".to_string(),
            (true, OracleKind::Lsvrg { .. }) => "LessBit-LSVRG".to_string(),
            (true, OracleKind::Saga) => "LessBit-SAGA".to_string(),
        };
        Pdgm {
            x: x0.clone(),
            d: Mat::zeros(x0.rows, x0.cols),
            w: w.clone(),
            eta,
            theta,
            comm,
            comp,
            oracle,
            rng,
            bits: 0,
            g: Mat::zeros(x0.rows, x0.cols),
            label,
            wx: if compressed { Mat::zeros(0, 0) } else { Mat::zeros(x0.rows, x0.cols) },
        }
    }

    /// Uncompressed PDGM with θ = γ/(2η) (matching LEAD's dual scale).
    #[deprecated(note = "construct via Pdgm::builder(&experiment) or Experiment::algorithm()")]
    #[allow(deprecated)]
    pub fn plain(
        problem: &dyn Problem,
        w: &MixingOp,
        x0: &Mat,
        eta: f64,
        gamma: f64,
        seed: u64,
    ) -> Pdgm {
        Pdgm::new(
            problem,
            w,
            x0,
            eta,
            gamma / (2.0 * eta),
            OracleKind::Full,
            Box::new(Identity::f32()),
            0.5,
            seed,
        )
    }
}

impl Pdgm {
    /// LessBit Option B: full gradient + compressed communication.
    #[deprecated(note = "construct via Pdgm::builder(&experiment) or Experiment::algorithm()")]
    #[allow(deprecated)]
    #[allow(clippy::too_many_arguments)]
    pub fn lessbit_b(
        problem: &dyn Problem,
        w: &MixingOp,
        x0: &Mat,
        eta: f64,
        gamma: f64,
        comp: Box<dyn Compressor>,
        alpha: f64,
        seed: u64,
    ) -> Pdgm {
        Pdgm::new(problem, w, x0, eta, gamma / (2.0 * eta), OracleKind::Full, comp, alpha, seed)
    }
}

impl Algorithm for Pdgm {
    fn step(&mut self, problem: &dyn Problem) -> RoundStats {
        // primal: X ← X − η∇F(X) − ηD
        self.oracle.sample_all(problem, &self.x, &mut self.g);
        self.x.axpy(-self.eta, &self.g);
        let d_scaled = &self.d * self.eta;
        self.x -= &d_scaled;

        // dual: D ← D + θ(I−W)X̂ (compressed ⇒ LessBit B/C/D)
        let bits = match &mut self.comm {
            Some(c) => {
                let (x_hat, xw_hat, bits) =
                    c.comm(&self.x, &self.w, self.comp.as_ref(), &mut self.rng);
                let mut resid = x_hat;
                resid -= &xw_hat;
                self.d.axpy(self.theta, &resid);
                bits
            }
            None => {
                self.w.apply_into(&self.x, &mut self.wx);
                for ((d, &x), &wx) in
                    self.d.data.iter_mut().zip(&self.x.data).zip(&self.wx.data)
                {
                    *d += self.theta * (x - wx);
                }
                32 * (self.x.rows * self.x.cols) as u64
            }
        };
        self.bits += bits;
        RoundStats { bits }
    }

    fn x(&self) -> &Mat {
        &self.x
    }

    fn name(&self) -> String {
        format!("{} ({}, {})", self.label, self.comp.name(), self.oracle.name())
    }

    fn grad_evals(&self) -> u64 {
        self.oracle.grad_evals()
    }

    fn bits(&self) -> u64 {
        self.bits
    }

    fn set_eta(&mut self, eta: f64) {
        self.eta = eta;
    }
}

#[cfg(test)]
mod tests {
    // these tests pin the constructor-built iterate sequence directly
    #![allow(deprecated)]
    use super::*;
    use crate::algorithm::testkit::{ring_logreg, run_to};
    use crate::algorithm::solve_reference;
    use crate::compress::InfNormQuantizer;
    use crate::problem::Problem;

    #[test]
    fn dual_gd_converges_with_exact_inner_solve() {
        let (p, w) = ring_logreg();
        let x_star = solve_reference(&p, 0.0, 40_000, 1e-13);
        let x0 = Mat::zeros(4, p.dim());
        // dual smoothness is λmax(I−W)/μ ⇒ θ ≈ μ/λmax(I−W); warm-started
        // inner loops make the ∇F* evaluation effectively exact
        let theta = p.strong_convexity() / 2.0;
        let mut alg = DualGd::new(&p, &w, &x0, theta, 200, Box::new(Identity::f32()), 0.5, 3);
        let s = run_to(&mut alg, &p, 1500, &x_star);
        assert!(s < 1e-8, "DualGD suboptimality: {s}");
    }

    #[test]
    fn lessbit_a_converges_with_compression() {
        let (p, w) = ring_logreg();
        let x_star = solve_reference(&p, 0.0, 40_000, 1e-13);
        let x0 = Mat::zeros(4, p.dim());
        let theta = p.strong_convexity() / 4.0;
        let mut alg = DualGd::new(
            &p,
            &w,
            &x0,
            theta,
            200,
            Box::new(InfNormQuantizer::new(2, 256)),
            0.25,
            3,
        );
        assert!(alg.name().starts_with("LessBit-A"));
        let s = run_to(&mut alg, &p, 2500, &x_star);
        assert!(s < 1e-8, "LessBit-A suboptimality: {s}");
    }

    #[test]
    fn pdgm_converges_smooth() {
        let (p, w) = ring_logreg();
        let x_star = solve_reference(&p, 0.0, 40_000, 1e-13);
        let x0 = Mat::zeros(4, p.dim());
        let eta = crate::algorithm::testkit::safe_eta(&p);
        let mut alg = Pdgm::plain(&p, &w, &x0, eta, 1.0, 3);
        let s = run_to(&mut alg, &p, 4000, &x_star);
        assert!(s < 1e-16, "PDGM suboptimality: {s}");
    }

    #[test]
    fn lessbit_b_converges_with_2bit() {
        let (p, w) = ring_logreg();
        let x_star = solve_reference(&p, 0.0, 40_000, 1e-13);
        let x0 = Mat::zeros(4, p.dim());
        let eta = crate::algorithm::testkit::safe_eta(&p);
        let mut alg = Pdgm::lessbit_b(
            &p,
            &w,
            &x0,
            eta,
            0.5,
            Box::new(InfNormQuantizer::new(2, 256)),
            0.5,
            3,
        );
        assert!(alg.name().starts_with("LessBit-B"));
        let s = run_to(&mut alg, &p, 6000, &x_star);
        assert!(s < 1e-12, "LessBit-B suboptimality: {s}");
    }
}
