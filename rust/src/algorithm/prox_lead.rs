//! Prox-LEAD — Algorithm 1 of the paper, in stacked matrix form.
//!
//! One round (lines 5–10):
//!
//! ```text
//! Gᵏ    = SGO(Xᵏ)                                (Table 1 oracle)
//! Zᵏ⁺¹  = Xᵏ − ηGᵏ − ηDᵏ
//! (Ẑ, Ẑ_w) = COMM(Zᵏ⁺¹, Hᵏ, H_wᵏ, α)            (compressed gossip)
//! Dᵏ⁺¹  = Dᵏ + γ/(2η) (Ẑ − Ẑ_w)
//! Vᵏ⁺¹  = Zᵏ⁺¹ − γ/2 (Ẑ − Ẑ_w)
//! Xᵏ⁺¹  = prox_ηR(Vᵏ⁺¹)
//! ```
//!
//! Specializations covered by this one struct:
//! - **LEAD** (Algorithm 3): `prox = Zero` — line 10 becomes the identity
//!   and the iteration reduces exactly to LEAD's X-update;
//! - **PUDA / Corollary 6**: `comp = Identity` (C = 0);
//! - **NIDS**: `comp = Identity`, `prox = Zero`, γ = 1 (see §4.3);
//! - **SGD / LSVRG / SAGA variants**: choice of [`OracleKind`].
//!
//! Per-node counterpart: [`crate::coordinator::ProxLeadNode`] runs the same
//! arithmetic on node threads over serialized frames (bit-identical under
//! the exact `Dense64` codec — see `rust/tests/coordinator_parity.rs`).

use super::{Algorithm, CommState, Hyper, RoundStats};
use crate::compress::Compressor;
use crate::graph::MixingOp;
use crate::linalg::Mat;
use crate::oracle::{OracleKind, Sgo};
use crate::problem::Problem;
use crate::prox::{prox_rows_into, Prox};
use crate::util::rng::Rng;

pub struct ProxLead {
    x: Mat,
    d: Mat,
    comm: CommState,
    w: MixingOp,
    pub hyper: Hyper,
    oracle: Sgo,
    comp: Box<dyn Compressor>,
    prox: Box<dyn Prox>,
    rng: Rng,
    bits: u64,
    g: Mat, // gradient scratch
    /// Optional label suffix in `name()` (e.g. "2bit").
    pub tag: String,
}

impl ProxLead {
    /// Build and run the initialization (Algorithm 1 lines 1–3): H¹ = X⁰,
    /// Z¹ = X⁰ − η·SGO(X⁰), X¹ = prox_ηR(Z¹), D¹ = 0.
    ///
    /// Deprecated shim kept for tests that pin iterate sequences; new code
    /// constructs via [`ProxLead::builder`] / `Experiment::algorithm`.
    #[deprecated(note = "construct via ProxLead::builder(&experiment) or Experiment::algorithm()")]
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        problem: &dyn Problem,
        w: &MixingOp,
        x0: &Mat,
        hyper: Hyper,
        oracle_kind: OracleKind,
        comp: Box<dyn Compressor>,
        prox: Box<dyn Prox>,
        seed: u64,
    ) -> ProxLead {
        let n = problem.num_nodes();
        let p = problem.dim();
        assert_eq!(x0.rows, n);
        assert_eq!(x0.cols, p);
        assert_eq!(w.n(), n);
        let mut rng = Rng::new(seed);
        let mut oracle = Sgo::new(oracle_kind, problem, x0, rng.next_u64());

        // lines 1–3
        let mut g = Mat::zeros(n, p);
        oracle.sample_all(problem, x0, &mut g);
        let mut z1 = x0.clone();
        z1.axpy(-hyper.eta, &g);
        let mut x1 = z1.clone();
        prox_rows_into(prox.as_ref(), &mut x1, hyper.eta);
        let comm = CommState::new(x0.clone(), w, hyper.alpha);

        ProxLead {
            x: x1,
            d: Mat::zeros(n, p),
            comm,
            w: w.clone(),
            hyper,
            oracle,
            comp,
            prox,
            rng,
            bits: 0,
            g,
            tag: String::new(),
        }
    }

    /// Attach a display tag, e.g. `"2bit"`.
    pub fn with_tag(mut self, tag: &str) -> ProxLead {
        self.tag = tag.to_string();
        self
    }

    /// Update all three parameters (diminishing-stepsize schedules set
    /// ηᵏ, αᵏ, γᵏ together — Theorem 7).
    pub fn set_hyper(&mut self, h: Hyper) {
        self.hyper = h;
        self.comm.alpha = h.alpha;
    }

    /// The dual variable D (for tests of Lemma 3 quantities).
    pub fn d(&self) -> &Mat {
        &self.d
    }

    /// The compression state H (its convergence to Z* kills the error).
    pub fn h(&self) -> &Mat {
        &self.comm.h
    }
}

impl Algorithm for ProxLead {
    fn step(&mut self, problem: &dyn Problem) -> RoundStats {
        let (eta, gamma) = (self.hyper.eta, self.hyper.gamma);

        // line 5: G = SGO(X)
        self.oracle.sample_all(problem, &self.x, &mut self.g);

        // line 6: Z = X − ηG − ηD
        let mut z = self.x.clone();
        z.axpy(-eta, &self.g);
        z.axpy(-eta, &self.d);

        // line 7: compressed communication
        let (z_hat, zw_hat, bits) = self.comm.comm(&z, &self.w, self.comp.as_ref(), &mut self.rng);
        self.bits += bits;

        // lines 8–9: the gossip residual Ẑ − Ẑ_w drives both updates
        let resid = &z_hat - &zw_hat;
        self.d.axpy(gamma / (2.0 * eta), &resid);
        let mut v = z;
        v.axpy(-gamma / 2.0, &resid);

        // line 10: X = prox_ηR(V)
        prox_rows_into(self.prox.as_ref(), &mut v, eta);
        self.x = v;

        RoundStats { bits }
    }

    fn x(&self) -> &Mat {
        &self.x
    }

    fn name(&self) -> String {
        let base = if self.prox.is_zero() { "LEAD" } else { "Prox-LEAD" };
        let oracle = self.oracle.name();
        let comp = self.comp.name();
        let tag = if self.tag.is_empty() { String::new() } else { format!(" {}", self.tag) };
        format!("{base} ({comp}, {oracle}){tag}")
    }

    fn grad_evals(&self) -> u64 {
        self.oracle.grad_evals()
    }

    fn bits(&self) -> u64 {
        self.bits
    }

    fn set_eta(&mut self, eta: f64) {
        self.hyper.eta = eta;
    }

    fn apply_hyper(&mut self, h: Hyper) {
        self.set_hyper(h);
    }
}

#[cfg(test)]
mod tests {
    // these tests pin the constructor-built iterate sequence directly
    #![allow(deprecated)]
    use super::*;
    use crate::algorithm::testkit::{ring_logreg, run_to};
    use crate::algorithm::{solve_reference, suboptimality};
    use crate::compress::{Identity, InfNormQuantizer};
    use crate::prox::{Zero, L1};

    fn reference(problem: &crate::problem::LogReg, l1: f64) -> Vec<f64> {
        solve_reference(problem, l1, 40_000, 1e-13)
    }

    #[test]
    fn converges_linearly_full_gradient_no_compression() {
        let (p, w) = ring_logreg();
        let x_star = reference(&p, 0.0);
        use crate::problem::Problem;
        let x0 = Mat::zeros(4, p.dim());
        let mut alg = ProxLead::new(
            &p,
            &w,
            &x0,
            Hyper::paper_default(crate::algorithm::testkit::safe_eta(&p)),
            OracleKind::Full,
            Box::new(Identity::f64()),
            Box::new(Zero),
            7,
        );
        let mut subopts = vec![];
        for _ in 0..6 {
            subopts.push(run_to(&mut alg, &p, 200, &x_star));
        }
        // geometric decay to machine-precision territory
        assert!(subopts[5] < 1e-18, "final subopt {:?}", subopts);
        assert!(subopts[5] < subopts[0] * 1e-8, "no decay: {:?}", subopts);
    }

    #[test]
    fn converges_with_2bit_compression() {
        let (p, w) = ring_logreg();
        let x_star = reference(&p, 0.0);
        use crate::problem::Problem;
        let x0 = Mat::zeros(4, p.dim());
        let mut alg = ProxLead::new(
            &p,
            &w,
            &x0,
            Hyper::paper_default(crate::algorithm::testkit::safe_eta(&p)),
            OracleKind::Full,
            Box::new(InfNormQuantizer::new(2, 256)),
            Box::new(Zero),
            7,
        );
        let s = run_to(&mut alg, &p, 4000, &x_star);
        assert!(s < 1e-16, "2bit LEAD should still converge linearly: {s}");
        // compression state H must have converged too (error → 0)
        let h_err = alg.h().dist_sq(alg.x()) / alg.x().norm_sq();
        assert!(h_err < 1e-12, "H − X relative residual {h_err}");
    }

    #[test]
    fn composite_l1_converges_to_prox_reference() {
        let (p, w) = ring_logreg();
        let lambda1 = 5e-3;
        let x_star = reference(&p, lambda1);
        use crate::problem::Problem;
        let x0 = Mat::zeros(4, p.dim());
        let mut alg = ProxLead::new(
            &p,
            &w,
            &x0,
            Hyper::paper_default(crate::algorithm::testkit::safe_eta(&p)),
            OracleKind::Full,
            Box::new(InfNormQuantizer::new(2, 256)),
            Box::new(L1::new(lambda1)),
            7,
        );
        let s = run_to(&mut alg, &p, 4500, &x_star);
        assert!(s < 1e-14, "Prox-LEAD 2bit non-smooth suboptimality: {s}");
        // the l1 solution must actually be sparse-ish vs the smooth one
        let smooth_star = reference(&p, 0.0);
        let nnz = |v: &[f64]| v.iter().filter(|&&x| x.abs() > 1e-8).count();
        assert!(nnz(&x_star) <= nnz(&smooth_star));
    }

    #[test]
    fn saga_variant_converges_linearly() {
        let (p, w) = ring_logreg();
        let x_star = reference(&p, 5e-3);
        use crate::problem::Problem;
        let x0 = Mat::zeros(4, p.dim());
        let mut alg = ProxLead::new(
            &p,
            &w,
            &x0,
            Hyper::paper_default(1.0 / (6.0 * crate::problem::Problem::smoothness(&p))),
            OracleKind::Saga,
            Box::new(InfNormQuantizer::new(2, 256)),
            Box::new(L1::new(5e-3)),
            11,
        );
        let s = run_to(&mut alg, &p, 9000, &x_star);
        assert!(s < 1e-12, "Prox-LEAD SAGA suboptimality: {s}");
    }

    #[test]
    fn lsvrg_variant_converges_linearly() {
        let (p, w) = ring_logreg();
        let x_star = reference(&p, 5e-3);
        use crate::problem::Problem;
        let x0 = Mat::zeros(4, p.dim());
        let mut alg = ProxLead::new(
            &p,
            &w,
            &x0,
            Hyper::paper_default(1.0 / (6.0 * crate::problem::Problem::smoothness(&p))),
            OracleKind::Lsvrg { p: 1.0 / 4.0 },
            Box::new(InfNormQuantizer::new(2, 256)),
            Box::new(L1::new(5e-3)),
            11,
        );
        let s = run_to(&mut alg, &p, 9000, &x_star);
        assert!(s < 1e-12, "Prox-LEAD LSVRG suboptimality: {s}");
    }

    #[test]
    fn sgd_variant_reaches_noise_ball_only() {
        // Theorem 5: fixed stepsize + plain SGD ⇒ linear to a σ²-ball, NOT
        // to zero; VR variants beat it by orders of magnitude.
        let (p, w) = ring_logreg();
        let x_star = reference(&p, 0.0);
        use crate::problem::Problem;
        let x0 = Mat::zeros(4, p.dim());
        let mk = |kind| {
            ProxLead::new(
                &p,
                &w,
                &x0,
                Hyper::paper_default(0.02),
                kind,
                Box::new(Identity::f64()),
                Box::new(Zero),
                13,
            )
        };
        let mut sgd = mk(OracleKind::Sgd);
        let mut saga = mk(OracleKind::Saga);
        let s_sgd = run_to(&mut sgd, &p, 3000, &x_star);
        let s_saga = run_to(&mut saga, &p, 3000, &x_star);
        assert!(s_sgd > 1e-9, "plain SGD should stall at the noise ball: {s_sgd}");
        assert!(s_saga < s_sgd * 1e-3, "VR must beat SGD: {s_saga} vs {s_sgd}");
    }

    #[test]
    fn compression_saves_bits_at_same_accuracy() {
        let (p, w) = ring_logreg();
        let x_star = reference(&p, 0.0);
        use crate::problem::Problem;
        let x0 = Mat::zeros(4, p.dim());
        let target = 1e-10;
        let bits_to_target = |comp: Box<dyn Compressor>| {
            let mut alg = ProxLead::new(
                &p,
                &w,
                &x0,
                Hyper::paper_default(crate::algorithm::testkit::safe_eta(&p)),
                OracleKind::Full,
                comp,
                Box::new(Zero),
                7,
            );
            for _ in 0..5000 {
                alg.step(&p);
                if suboptimality(alg.x(), &x_star) < target {
                    return alg.bits();
                }
            }
            u64::MAX
        };
        let b32 = bits_to_target(Box::new(Identity::f32()));
        let b2 = bits_to_target(Box::new(InfNormQuantizer::new(2, 256)));
        assert!(b2 < u64::MAX && b32 < u64::MAX);
        assert!(
            (b2 as f64) < 0.5 * b32 as f64,
            "2bit should need far fewer bits: {b2} vs {b32}"
        );
    }

    #[test]
    fn name_reflects_configuration() {
        let (p, w) = ring_logreg();
        use crate::problem::Problem;
        let x0 = Mat::zeros(4, p.dim());
        let alg = ProxLead::new(
            &p,
            &w,
            &x0,
            Hyper::paper_default(0.1),
            OracleKind::Saga,
            Box::new(InfNormQuantizer::new(2, 256)),
            Box::new(L1::new(0.005)),
            1,
        );
        assert_eq!(alg.name(), "Prox-LEAD (2bit, saga)");
    }
}
