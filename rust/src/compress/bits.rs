//! Wire format for compressed messages: a real bit-packed codec.
//!
//! The matrix-form engine only needs the decoded vector + a bit count, but
//! the message-passing coordinator serializes actual bytes, so the decoded
//! values in Figures 1b/1d/2b/2d go through a real codec. Format for the
//! ∞-norm quantizer (eq. 21, L = 2^{b−1} levels), per block:
//!
//!   [f32 norm] [entry codes: 1 sign bit + b magnitude bits each]
//!
//! Magnitude codes span [0, L] = [0, 2^{b−1}], which needs a b-bit field;
//! the raw wire therefore spends b+1 bits per entry. The *accounted* bits
//! (what the figures plot) follow the paper's/QSGD's convention of b bits
//! per entry — the boundary code and the sign of zero are redundancies an
//! entropy coder removes (QSGD uses Elias coding); we keep the fixed-width
//! codec for simplicity and charge the entropy-coded size.
//! An all-zero block is encoded as norm = 0 with no entry codes.
//!
//! # Scratch-buffer API and errors
//!
//! The hot-path entry points are [`encode_inf_quantized_into`] and
//! [`decode_inf_quantized_into`]: both work over caller-provided scratch
//! (an append-only `Vec<u8>` on the encode side, a fixed `&mut [f64]` on
//! the decode side) and allocate nothing once the scratch has warmed up.
//! Decoding is *total*: any byte slice either decodes or returns a
//! [`QuantError`] — it never panics and never reads out of bounds. The
//! allocating `encode_inf_quantized` / `decode_inf_quantized` wrappers
//! remain for tests and benches that want the one-shot shape.

use super::quantize::levels_for_bits;
use crate::util::rng::Rng;
use std::fmt;

/// Why a quantized bitstream failed to decode. Maps 1:1 onto
/// [`crate::coordinator::wire::WireError`] at the frame layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantError {
    /// The stream ended before the advertised entries were all read.
    Truncated { need_bits: usize, have_bits: usize },
    /// A block header norm that is NaN or negative — not a value
    /// `encode_inf_quantized` can emit for any input (+∞ is accepted: a
    /// diverging sender legitimately produces it, and the resulting ±∞
    /// entries surface as divergence at the algorithm layer).
    BadBlockNorm { block: usize },
    /// Whole unread bytes remain after the final block (at most 7 bits of
    /// zero-padding are legal).
    TrailingBytes { used_bytes: usize, got_bytes: usize },
}

impl fmt::Display for QuantError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            QuantError::Truncated { need_bits, have_bits } => {
                write!(f, "quant stream truncated: need {need_bits} bits, have {have_bits}")
            }
            QuantError::BadBlockNorm { block } => {
                write!(f, "quant block {block} has a NaN or negative norm")
            }
            QuantError::TrailingBytes { used_bytes, got_bytes } => {
                write!(f, "quant stream has trailing bytes: used {used_bytes} of {got_bytes}")
            }
        }
    }
}

impl std::error::Error for QuantError {}

/// Largest field width the chunked writer/reader accept. The accumulator
/// keeps < 8 carried bits between calls, so `7 + width` must fit in a u64;
/// the codec itself never exceeds 32 (an f32 norm).
pub const MAX_FIELD_BITS: u32 = 56;

/// MSB-first bit writer appending to a caller-provided byte buffer.
///
/// Bits collect in a u64 accumulator and flush to the buffer a whole byte
/// at a time, so the per-field cost is one shift/or plus at most
/// `width/8 + 1` byte pushes — no per-bit loop. The byte stream is
/// identical to the historical bit-at-a-time writer's. Call
/// [`BitWriter::finish`] to pad the final partial byte with zeros.
pub struct BitWriter<'a> {
    buf: &'a mut Vec<u8>,
    /// Low `fill` bits are pending output; higher bits are stale garbage
    /// that the flush masks away.
    acc: u64,
    fill: u32,
    written: usize,
}

impl<'a> BitWriter<'a> {
    /// Start writing at the current end of `buf` (append-only: existing
    /// bytes, e.g. a frame header, are left untouched).
    pub fn new(buf: &'a mut Vec<u8>) -> Self {
        BitWriter { buf, acc: 0, fill: 0, written: 0 }
    }

    #[inline]
    pub fn write_bits(&mut self, value: u64, width: u32) {
        debug_assert!(width <= MAX_FIELD_BITS, "field wider than the accumulator allows");
        debug_assert!(width == 64 || value < (1u64 << width), "value overflows field");
        self.acc = (self.acc << width) | value;
        self.fill += width;
        self.written += width as usize;
        while self.fill >= 8 {
            self.fill -= 8;
            // `as u8` keeps exactly bits [fill, fill+8) — the oldest
            // pending byte; stale bits above never reach the output.
            self.buf.push((self.acc >> self.fill) as u8);
        }
    }

    #[inline]
    pub fn write_f32(&mut self, x: f32) {
        self.write_bits(x.to_bits() as u64, 32);
    }

    /// Total bits written so far (excluding final padding).
    pub fn bit_len(&self) -> usize {
        self.written
    }

    /// Flush the trailing partial byte, zero-padded in the low positions
    /// (same padding the historical writer produced implicitly).
    pub fn finish(self) {
        if self.fill > 0 {
            self.buf.push((self.acc << (8 - self.fill)) as u8);
        }
    }
}

/// MSB-first bit reader with checked, non-panicking reads.
pub struct BitReader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> BitReader<'a> {
    pub fn new(bytes: &'a [u8]) -> Self {
        BitReader { bytes, pos: 0 }
    }

    /// Read `width` bits, or `None` when fewer remain. Consumes whole
    /// bytes through the accumulator rather than looping per bit.
    #[inline]
    pub fn try_read_bits(&mut self, width: u32) -> Option<u64> {
        debug_assert!(width <= MAX_FIELD_BITS, "field wider than the accumulator allows");
        let end = self.pos.checked_add(width as usize)?;
        if end > self.bytes.len() * 8 {
            return None;
        }
        let mut v = 0u64;
        let mut rem = width as usize;
        let mut p = self.pos;
        // head: finish the current partial byte
        let head = (8 - p % 8) % 8;
        if head > 0 {
            let take = head.min(rem);
            let byte = self.byte_at(p);
            v = (byte >> (head - take)) & ((1u64 << take) - 1);
            p += take;
            rem -= take;
        }
        // body: whole bytes
        while rem >= 8 {
            v = (v << 8) | self.byte_at(p);
            p += 8;
            rem -= 8;
        }
        // tail: top bits of the next byte
        if rem > 0 {
            v = (v << rem) | (self.byte_at(p) >> (8 - rem));
            p += rem;
        }
        self.pos = p;
        Some(v)
    }

    /// Byte holding bit position `bit_pos`, as the accumulator type. Total:
    /// the bounds pre-check in `try_read_bits` makes the out-of-range arm
    /// unreachable, but decode-path code never bare-indexes (lint rule
    /// `panic-freedom`), so a short stream reads as zero rather than
    /// panicking.
    #[inline]
    fn byte_at(&self, bit_pos: usize) -> u64 {
        self.bytes.get(bit_pos / 8).copied().unwrap_or(0) as u64
    }

    /// Panicking convenience for streams known to be well-formed. Test-only:
    /// wire-path callers must use [`Self::try_read_bits`].
    #[cfg(test)]
    pub fn read_bits(&mut self, width: u32) -> u64 {
        self.try_read_bits(width).expect("bitstream exhausted")
    }

    #[inline]
    pub fn try_read_f32(&mut self) -> Option<f32> {
        self.try_read_bits(32).map(|b| f32::from_bits(b as u32))
    }

    /// Panicking convenience for streams known to be well-formed. Test-only:
    /// wire-path callers must use [`Self::try_read_f32`].
    #[cfg(test)]
    pub fn read_f32(&mut self) -> f32 {
        self.try_read_f32().expect("bitstream exhausted")
    }

    pub fn bits_read(&self) -> usize {
        self.pos
    }

    /// Bits remaining in the stream.
    pub fn bits_left(&self) -> usize {
        self.bytes.len() * 8 - self.pos
    }
}

/// Encode `x` with the b-bit ∞-norm quantizer, appending wire bytes to
/// `out` and writing the dequantized values (bit-identical to what the
/// receiver recovers — both sides go through the f32 norm) into `decoded`.
/// Returns the exact *accounted* payload bits. Allocates nothing beyond
/// `out`'s growth; with a warmed-up `out` the hot path is allocation-free.
pub fn encode_inf_quantized_into(
    x: &[f64],
    bits: u32,
    block: usize,
    rng: &mut Rng,
    decoded: &mut [f64],
    out: &mut Vec<u8>,
) -> u64 {
    assert_eq!(decoded.len(), x.len(), "decoded scratch length mismatch");
    let levels = levels_for_bits(bits);
    let mut w = BitWriter::new(out);
    let mut accounted = 0u64;
    for (chunk, dec) in x.chunks(block).zip(decoded.chunks_mut(block)) {
        let norm = chunk.iter().fold(0.0f64, |m, &v| m.max(v.abs()));
        w.write_f32(norm as f32);
        if norm == 0.0 {
            dec.fill(0.0);
            accounted += 32;
            continue;
        }
        let norm32 = norm as f32 as f64; // receiver sees the f32 norm
        let scale = norm32 / levels;
        let inv_scale = levels / norm; // hoisted: one divide per block
        for (&v, d) in chunk.iter().zip(dec.iter_mut()) {
            // dither against the f64 norm (what the sender holds), with the
            // same hoisted-reciprocal expression as InfNormQuantizer so the
            // two paths draw code-identical magnitudes; the floor can
            // exceed `levels` only through reciprocal rounding when
            // |v| ≈ norm and u ≈ 1 — the clamp keeps the code in-field
            // (the clamped case has dither-tail probability, preserving
            // unbiasedness up to O(ulp)).
            let mag = (v.abs() * inv_scale + rng.f64()).floor().min(levels);
            let code = mag as u64;
            let sign = (v < 0.0) as u64;
            w.write_bits((sign << bits) | code, bits + 1);
            *d = (1.0 - 2.0 * sign as f64) * scale * mag;
        }
        accounted += 32 + bits as u64 * chunk.len() as u64;
    }
    w.finish();
    accounted
}

/// Decode wire bytes produced by the ∞-norm encoder into `out` (whose
/// length fixes the expected entry count). Total over arbitrary input:
/// any malformed stream returns a [`QuantError`]; nothing panics and
/// nothing allocates.
pub fn decode_inf_quantized_into(
    bytes: &[u8],
    bits: u32,
    block: usize,
    out: &mut [f64],
) -> Result<(), QuantError> {
    let levels = levels_for_bits(bits);
    let mag_mask = (1u64 << bits) - 1;
    let mut r = BitReader::new(bytes);
    let have_bits = bytes.len() * 8;
    for (bi, chunk) in out.chunks_mut(block).enumerate() {
        let norm32 = r.try_read_f32().ok_or(QuantError::Truncated {
            need_bits: r.bits_read() + 32,
            have_bits,
        })?;
        // accepts +∞ (a diverging sender), rejects NaN and negatives —
        // `!(x >= 0.0)` is false for +∞, true for NaN
        if !(norm32 >= 0.0) {
            return Err(QuantError::BadBlockNorm { block: bi });
        }
        let norm = norm32 as f64;
        if norm == 0.0 {
            chunk.fill(0.0);
            continue;
        }
        let scale = norm / levels;
        for slot in chunk.iter_mut() {
            let code = r.try_read_bits(bits + 1).ok_or(QuantError::Truncated {
                need_bits: r.bits_read() + (bits + 1) as usize,
                have_bits,
            })?;
            let sign = (code >> bits) & 1;
            let mag = (code & mag_mask) as f64;
            *slot = (1.0 - 2.0 * sign as f64) * scale * mag;
        }
    }
    // at most 7 bits of zero-padding may remain; a whole spare byte means
    // the payload is longer than this vector's encoding
    if r.bits_left() >= 8 {
        return Err(QuantError::TrailingBytes {
            used_bytes: (r.bits_read() + 7) / 8,
            got_bytes: bytes.len(),
        });
    }
    Ok(())
}

/// Allocating convenience wrapper over [`encode_inf_quantized_into`]:
/// returns (bytes, decoded vector, exact accounted payload bits).
pub fn encode_inf_quantized(
    x: &[f64],
    bits: u32,
    block: usize,
    rng: &mut Rng,
) -> (Vec<u8>, Vec<f64>, u64) {
    let mut bytes = Vec::new();
    let mut decoded = vec![0.0; x.len()];
    let accounted = encode_inf_quantized_into(x, bits, block, rng, &mut decoded, &mut bytes);
    (bytes, decoded, accounted)
}

/// Allocating wrapper over [`decode_inf_quantized_into`] (tests/benches).
/// Total like the `_into` form: malformed input is a typed [`QuantError`],
/// never a panic.
pub fn decode_inf_quantized(
    bytes: &[u8],
    n: usize,
    bits: u32,
    block: usize,
) -> Result<Vec<f64>, QuantError> {
    let mut out = vec![0.0; n];
    decode_inf_quantized_into(bytes, bits, block, &mut out)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bit_writer_reader_roundtrip() {
        let mut buf = Vec::new();
        let mut w = BitWriter::new(&mut buf);
        w.write_bits(0b101, 3);
        w.write_bits(0xFFFF, 16);
        w.write_f32(1.25);
        w.write_bits(0, 1);
        let nbits = w.bit_len();
        w.finish();
        let mut r = BitReader::new(&buf);
        assert_eq!(r.read_bits(3), 0b101);
        assert_eq!(r.read_bits(16), 0xFFFF);
        assert_eq!(r.read_f32(), 1.25);
        assert_eq!(r.read_bits(1), 0);
        assert_eq!(r.bits_read(), nbits);
    }

    #[test]
    fn chunked_writer_matches_bit_at_a_time_reference() {
        // the accumulator flush must reproduce the historical per-bit
        // writer's byte stream exactly (wire compatibility)
        fn reference_write(fields: &[(u64, u32)]) -> Vec<u8> {
            let mut bytes = Vec::new();
            let mut nbits = 0usize;
            for &(value, width) in fields {
                for i in (0..width).rev() {
                    let bit = (value >> i) & 1;
                    if nbits / 8 == bytes.len() {
                        bytes.push(0);
                    }
                    if bit == 1 {
                        bytes[nbits / 8] |= 1 << (7 - nbits % 8);
                    }
                    nbits += 1;
                }
            }
            bytes
        }
        let mut rng = Rng::new(41);
        for _ in 0..200 {
            let nfields = 1 + rng.below(12);
            let fields: Vec<(u64, u32)> = (0..nfields)
                .map(|_| {
                    let width = 1 + rng.below(32) as u32;
                    let value = rng.next_u64() & ((1u64 << width) - 1);
                    (value, width)
                })
                .collect();
            let mut buf = Vec::new();
            let mut w = BitWriter::new(&mut buf);
            for &(v, wid) in &fields {
                w.write_bits(v, wid);
            }
            w.finish();
            assert_eq!(buf, reference_write(&fields), "fields {fields:?}");
        }
    }

    #[test]
    fn writer_appends_after_existing_bytes() {
        let mut buf = vec![0xAB, 0xCD];
        let mut w = BitWriter::new(&mut buf);
        w.write_bits(0xF0, 8);
        w.finish();
        assert_eq!(buf, vec![0xAB, 0xCD, 0xF0]);
    }

    #[test]
    fn reader_refuses_overrun() {
        let bytes = [0xFFu8; 2];
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.try_read_bits(12), Some(0xFFF));
        assert_eq!(r.try_read_bits(5), None, "only 4 bits left");
        assert_eq!(r.try_read_bits(4), Some(0xF));
        assert_eq!(r.try_read_bits(1), None);
        assert_eq!(r.bits_left(), 0);
    }

    #[test]
    fn encode_decode_agree() {
        let mut rng = Rng::new(31);
        for bits in [2u32, 4, 8] {
            for n in [1usize, 5, 256, 300] {
                let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                let mut rng2 = Rng::new(99);
                let (bytes, decoded, nbits) = encode_inf_quantized(&x, bits, 256, &mut rng2);
                let recovered =
                    decode_inf_quantized(&bytes, n, bits, 256).expect("well-formed stream");
                assert_eq!(decoded.len(), n);
                assert_eq!(recovered.len(), n);
                for (i, (&d, &r)) in decoded.iter().zip(&recovered).enumerate() {
                    assert_eq!(d, r, "bits={bits} n={n} idx={i}: sender {d} vs receiver {r}");
                }
                // raw wire spends (b+1)/b × the accounted (entropy-coded) bits
                assert!(bytes.len() * 8 <= (nbits as usize) * 2 + 64);
            }
        }
    }

    #[test]
    fn into_variants_reuse_scratch_across_rounds() {
        let mut rng = Rng::new(55);
        let x: Vec<f64> = (0..300).map(|_| rng.normal()).collect();
        let mut out = Vec::new();
        let mut decoded = vec![0.0; 300];
        let mut recv = vec![0.0; 300];
        for _ in 0..3 {
            out.clear();
            let nbits = encode_inf_quantized_into(&x, 4, 128, &mut rng, &mut decoded, &mut out);
            assert!(nbits > 0);
            decode_inf_quantized_into(&out, 4, 128, &mut recv).unwrap();
            assert_eq!(decoded, recv);
        }
    }

    #[test]
    fn decode_rejects_truncated_stream() {
        let mut rng = Rng::new(56);
        let x: Vec<f64> = (0..64).map(|_| rng.normal()).collect();
        let (bytes, _, _) = encode_inf_quantized(&x, 4, 64, &mut rng);
        let mut out = vec![0.0; 64];
        for cut in [0, 3, 4, bytes.len() - 1] {
            let e = decode_inf_quantized_into(&bytes[..cut], 4, 64, &mut out);
            assert!(
                matches!(e, Err(QuantError::Truncated { .. })),
                "cut={cut}: {e:?}"
            );
        }
    }

    #[test]
    fn decode_rejects_bad_norm_and_trailing_bytes() {
        let mut rng = Rng::new(57);
        let x: Vec<f64> = (0..64).map(|_| rng.normal()).collect();
        let (bytes, _, _) = encode_inf_quantized(&x, 4, 64, &mut rng);
        let mut out = vec![0.0; 64];

        let mut nan = bytes.clone();
        nan[..4].copy_from_slice(&f32::NAN.to_bits().to_be_bytes());
        assert_eq!(
            decode_inf_quantized_into(&nan, 4, 64, &mut out),
            Err(QuantError::BadBlockNorm { block: 0 })
        );

        let mut neg = bytes.clone();
        neg[..4].copy_from_slice(&(-1.0f32).to_bits().to_be_bytes());
        assert_eq!(
            decode_inf_quantized_into(&neg, 4, 64, &mut out),
            Err(QuantError::BadBlockNorm { block: 0 })
        );

        let mut long = bytes.clone();
        long.push(0x00);
        assert!(matches!(
            decode_inf_quantized_into(&long, 4, 64, &mut out),
            Err(QuantError::TrailingBytes { .. })
        ));

        // +∞ norm is legal (diverging sender): decodes to ±∞/0 entries
        let mut inf = bytes;
        inf[..4].copy_from_slice(&f32::INFINITY.to_bits().to_be_bytes());
        assert_eq!(decode_inf_quantized_into(&inf, 4, 64, &mut out), Ok(()));
    }

    #[test]
    fn wire_bits_match_accounting() {
        // one full block of 256 at b=2: 32 + 2*256 bits
        let x = vec![1.0; 256];
        let mut rng = Rng::new(32);
        let (_, _, nbits) = encode_inf_quantized(&x, 2, 256, &mut rng);
        assert_eq!(nbits, 32 + 2 * 256);
    }

    #[test]
    fn zero_vector_cheap() {
        let mut rng = Rng::new(33);
        let (bytes, decoded, nbits) = encode_inf_quantized(&[0.0; 512], 2, 256, &mut rng);
        assert_eq!(decoded, vec![0.0; 512]);
        assert_eq!(nbits, 64); // two block norms only
        assert_eq!(bytes.len(), 8);
    }

    #[test]
    fn error_bounded_by_scale() {
        // per-entry error ≤ scale = ‖x‖∞/L (+f32 norm rounding)
        let mut rng = Rng::new(34);
        let x: Vec<f64> = (0..256).map(|_| rng.normal()).collect();
        let norm = x.iter().fold(0.0f64, |m, &v| m.max(v.abs()));
        for bits in [2u32, 4, 8] {
            let scale = norm / levels_for_bits(bits);
            let (_, decoded, _) = encode_inf_quantized(&x, bits, 256, &mut rng);
            for (a, b) in x.iter().zip(&decoded) {
                assert!((a - b).abs() <= scale * (1.0 + 1e-6), "b={bits}");
            }
        }
    }

    #[test]
    fn wire_codec_matches_analytic_compressor() {
        // same rng seed ⇒ the wire codec and InfNormQuantizer share the
        // dither stream, the magnitude expression, and the boundary clamp,
        // so they draw *code-identical* magnitudes — the decoded values
        // differ only in the norm the decode scales by (f64 vs the
        // transmitted f32)
        use crate::compress::{Compressor, InfNormQuantizer};
        let mut rng = Rng::new(35);
        let x: Vec<f64> = (0..300).map(|_| rng.normal()).collect();
        let q = InfNormQuantizer::new(4, 256);
        let a = q.compress(&x, &mut Rng::new(7));
        let (_, b, nbits) = encode_inf_quantized(&x, 4, 256, &mut Rng::new(7));
        assert_eq!(a.bits, nbits);
        let levels = levels_for_bits(4);
        let mut idx = 0;
        for chunk in x.chunks(256) {
            let norm = chunk.iter().fold(0.0f64, |m, &v| m.max(v.abs()));
            let scale64 = norm / levels;
            let scale32 = norm as f32 as f64 / levels;
            for _ in chunk {
                let code_a = (a.decoded[idx] / scale64).round();
                let code_b = (b[idx] / scale32).round();
                assert_eq!(code_a, code_b, "idx {idx}: signed codes diverged");
                idx += 1;
            }
        }
    }

    #[test]
    fn wire_codec_bit_identical_when_norm_is_f32_exact() {
        // when the block ∞-norm is exactly representable in f32, the f64
        // and f32 scales coincide and the two paths must agree bit for bit
        use crate::compress::{Compressor, InfNormQuantizer};
        let mut rng = Rng::new(36);
        let mut x: Vec<f64> = (0..256).map(|_| rng.range(-3.0, 3.0)).collect();
        x[17] = 4.0; // the block norm: exact in f32
        let q = InfNormQuantizer::new(4, 256);
        let a = q.compress(&x, &mut Rng::new(9));
        let (bytes, b, _) = encode_inf_quantized(&x, 4, 256, &mut Rng::new(9));
        for (i, (&u, &v)) in a.decoded.iter().zip(&b).enumerate() {
            assert_eq!(u.to_bits(), v.to_bits(), "idx {i}: {u:?} vs {v:?}");
        }
        // and the receiving side decodes the same vector
        let recv = decode_inf_quantized(&bytes, 256, 4, 256).expect("well-formed stream");
        assert_eq!(recv, b);
    }
}
