//! Wire format for compressed messages: a real bit-packed codec.
//!
//! The matrix-form engine only needs the decoded vector + a bit count, but
//! the message-passing coordinator serializes actual bytes, so the decoded
//! values in Figures 1b/1d/2b/2d go through a real codec. Format for the
//! ∞-norm quantizer (eq. 21, L = 2^{b−1} levels), per block:
//!
//!   [f32 norm] [entry codes: 1 sign bit + b magnitude bits each]
//!
//! Magnitude codes span [0, L] = [0, 2^{b−1}], which needs a b-bit field;
//! the raw wire therefore spends b+1 bits per entry. The *accounted* bits
//! (what the figures plot) follow the paper's/QSGD's convention of b bits
//! per entry — the boundary code and the sign of zero are redundancies an
//! entropy coder removes (QSGD uses Elias coding); we keep the fixed-width
//! codec for simplicity and charge the entropy-coded size.
//! An all-zero block is encoded as norm = 0 with no entry codes.

use super::quantize::levels_for_bits;
use crate::util::rng::Rng;

/// MSB-first bit writer.
pub struct BitWriter {
    pub bytes: Vec<u8>,
    nbits: usize,
}

impl BitWriter {
    pub fn new() -> Self {
        BitWriter {
            bytes: Vec::new(),
            nbits: 0,
        }
    }

    pub fn write_bits(&mut self, value: u64, width: u32) {
        debug_assert!(width <= 64);
        debug_assert!(width == 64 || value < (1u64 << width), "value overflows field");
        for i in (0..width).rev() {
            let bit = (value >> i) & 1;
            let byte_idx = self.nbits / 8;
            if byte_idx == self.bytes.len() {
                self.bytes.push(0);
            }
            if bit == 1 {
                self.bytes[byte_idx] |= 1 << (7 - self.nbits % 8);
            }
            self.nbits += 1;
        }
    }

    pub fn write_f32(&mut self, x: f32) {
        self.write_bits(x.to_bits() as u64, 32);
    }

    pub fn bit_len(&self) -> usize {
        self.nbits
    }
}

impl Default for BitWriter {
    fn default() -> Self {
        Self::new()
    }
}

/// MSB-first bit reader.
pub struct BitReader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> BitReader<'a> {
    pub fn new(bytes: &'a [u8]) -> Self {
        BitReader { bytes, pos: 0 }
    }

    pub fn read_bits(&mut self, width: u32) -> u64 {
        let mut v = 0u64;
        for _ in 0..width {
            let byte_idx = self.pos / 8;
            let bit = (self.bytes[byte_idx] >> (7 - self.pos % 8)) & 1;
            v = (v << 1) | bit as u64;
            self.pos += 1;
        }
        v
    }

    pub fn read_f32(&mut self) -> f32 {
        f32::from_bits(self.read_bits(32) as u32)
    }

    pub fn bits_read(&self) -> usize {
        self.pos
    }
}

/// Encode `x` with the b-bit ∞-norm quantizer into wire bytes.
/// Returns (bytes, decoded vector, exact payload bits). The decoded vector
/// is bit-identical to what [`decode_inf_quantized`] recovers on the
/// receiving side (both go through the f32 norm).
pub fn encode_inf_quantized(
    x: &[f64],
    bits: u32,
    block: usize,
    rng: &mut Rng,
) -> (Vec<u8>, Vec<f64>, u64) {
    let levels = levels_for_bits(bits);
    let mut w = BitWriter::new();
    let mut decoded = Vec::with_capacity(x.len());
    let mut accounted = 0u64;
    for chunk in x.chunks(block) {
        let norm = chunk.iter().fold(0.0f64, |m, &v| m.max(v.abs()));
        w.write_f32(norm as f32);
        if norm == 0.0 {
            decoded.extend(std::iter::repeat(0.0).take(chunk.len()));
            accounted += 32;
            continue;
        }
        let norm32 = norm as f32 as f64; // receiver sees the f32 norm
        let scale = norm32 / levels;
        let inv_scale = levels / norm; // hoisted: one divide per block
        for &v in chunk {
            // dither against the f64 norm (what the sender holds), with the
            // same hoisted-reciprocal expression as InfNormQuantizer so the
            // two paths draw code-identical magnitudes; the floor can
            // exceed `levels` only through reciprocal rounding when
            // |v| ≈ norm and u ≈ 1 — the clamp keeps the code in-field
            // (the clamped case has dither-tail probability, preserving
            // unbiasedness up to O(ulp)).
            let mag = (v.abs() * inv_scale + rng.f64()).floor().min(levels);
            let code = mag as u64;
            let sign = if v < 0.0 { 1u64 } else { 0u64 };
            w.write_bits((sign << bits) | code, bits + 1);
            decoded.push((1.0 - 2.0 * sign as f64) * scale * mag);
        }
        accounted += 32 + bits as u64 * chunk.len() as u64;
    }
    (w.bytes, decoded, accounted)
}

/// Decode wire bytes produced by [`encode_inf_quantized`].
pub fn decode_inf_quantized(bytes: &[u8], n: usize, bits: u32, block: usize) -> Vec<f64> {
    let levels = levels_for_bits(bits);
    let mag_mask = (1u64 << bits) - 1;
    let mut r = BitReader::new(bytes);
    let mut out = Vec::with_capacity(n);
    let mut remaining = n;
    while remaining > 0 {
        let chunk = remaining.min(block);
        let norm = r.read_f32() as f64;
        if norm == 0.0 {
            out.extend(std::iter::repeat(0.0).take(chunk));
        } else {
            let scale = norm / levels;
            for _ in 0..chunk {
                let code = r.read_bits(bits + 1);
                let sign = (code >> bits) & 1;
                let mag = (code & mag_mask) as f64;
                out.push((1.0 - 2.0 * sign as f64) * scale * mag);
            }
        }
        remaining -= chunk;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bit_writer_reader_roundtrip() {
        let mut w = BitWriter::new();
        w.write_bits(0b101, 3);
        w.write_bits(0xFFFF, 16);
        w.write_f32(1.25);
        w.write_bits(0, 1);
        let mut r = BitReader::new(&w.bytes);
        assert_eq!(r.read_bits(3), 0b101);
        assert_eq!(r.read_bits(16), 0xFFFF);
        assert_eq!(r.read_f32(), 1.25);
        assert_eq!(r.read_bits(1), 0);
        assert_eq!(r.bits_read(), w.bit_len());
    }

    #[test]
    fn encode_decode_agree() {
        let mut rng = Rng::new(31);
        for bits in [2u32, 4, 8] {
            for n in [1usize, 5, 256, 300] {
                let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                let mut rng2 = Rng::new(99);
                let (bytes, decoded, nbits) = encode_inf_quantized(&x, bits, 256, &mut rng2);
                let recovered = decode_inf_quantized(&bytes, n, bits, 256);
                assert_eq!(decoded.len(), n);
                assert_eq!(recovered.len(), n);
                for (i, (&d, &r)) in decoded.iter().zip(&recovered).enumerate() {
                    assert_eq!(d, r, "bits={bits} n={n} idx={i}: sender {d} vs receiver {r}");
                }
                // raw wire spends (b+1)/b × the accounted (entropy-coded) bits
                assert!(bytes.len() * 8 <= (nbits as usize) * 2 + 64);
            }
        }
    }

    #[test]
    fn wire_bits_match_accounting() {
        // one full block of 256 at b=2: 32 + 2*256 bits
        let x = vec![1.0; 256];
        let mut rng = Rng::new(32);
        let (_, _, nbits) = encode_inf_quantized(&x, 2, 256, &mut rng);
        assert_eq!(nbits, 32 + 2 * 256);
    }

    #[test]
    fn zero_vector_cheap() {
        let mut rng = Rng::new(33);
        let (bytes, decoded, nbits) = encode_inf_quantized(&[0.0; 512], 2, 256, &mut rng);
        assert_eq!(decoded, vec![0.0; 512]);
        assert_eq!(nbits, 64); // two block norms only
        assert_eq!(bytes.len(), 8);
    }

    #[test]
    fn error_bounded_by_scale() {
        // per-entry error ≤ scale = ‖x‖∞/L (+f32 norm rounding)
        let mut rng = Rng::new(34);
        let x: Vec<f64> = (0..256).map(|_| rng.normal()).collect();
        let norm = x.iter().fold(0.0f64, |m, &v| m.max(v.abs()));
        for bits in [2u32, 4, 8] {
            let scale = norm / levels_for_bits(bits);
            let (_, decoded, _) = encode_inf_quantized(&x, bits, 256, &mut rng);
            for (a, b) in x.iter().zip(&decoded) {
                assert!((a - b).abs() <= scale * (1.0 + 1e-6), "b={bits}");
            }
        }
    }

    #[test]
    fn wire_codec_matches_analytic_compressor() {
        // same rng seed ⇒ the wire codec and InfNormQuantizer share the
        // dither stream, the magnitude expression, and the boundary clamp,
        // so they draw *code-identical* magnitudes — the decoded values
        // differ only in the norm the decode scales by (f64 vs the
        // transmitted f32)
        use crate::compress::{Compressor, InfNormQuantizer};
        let mut rng = Rng::new(35);
        let x: Vec<f64> = (0..300).map(|_| rng.normal()).collect();
        let q = InfNormQuantizer::new(4, 256);
        let a = q.compress(&x, &mut Rng::new(7));
        let (_, b, nbits) = encode_inf_quantized(&x, 4, 256, &mut Rng::new(7));
        assert_eq!(a.bits, nbits);
        let levels = levels_for_bits(4);
        let mut idx = 0;
        for chunk in x.chunks(256) {
            let norm = chunk.iter().fold(0.0f64, |m, &v| m.max(v.abs()));
            let scale64 = norm / levels;
            let scale32 = norm as f32 as f64 / levels;
            for _ in chunk {
                let code_a = (a.decoded[idx] / scale64).round();
                let code_b = (b[idx] / scale32).round();
                assert_eq!(code_a, code_b, "idx {idx}: signed codes diverged");
                idx += 1;
            }
        }
    }

    #[test]
    fn wire_codec_bit_identical_when_norm_is_f32_exact() {
        // when the block ∞-norm is exactly representable in f32, the f64
        // and f32 scales coincide and the two paths must agree bit for bit
        use crate::compress::{Compressor, InfNormQuantizer};
        let mut rng = Rng::new(36);
        let mut x: Vec<f64> = (0..256).map(|_| rng.range(-3.0, 3.0)).collect();
        x[17] = 4.0; // the block norm: exact in f32
        let q = InfNormQuantizer::new(4, 256);
        let a = q.compress(&x, &mut Rng::new(9));
        let (bytes, b, _) = encode_inf_quantized(&x, 4, 256, &mut Rng::new(9));
        for (i, (&u, &v)) in a.decoded.iter().zip(&b).enumerate() {
            assert_eq!(u.to_bits(), v.to_bits(), "idx {i}: {u:?} vs {v:?}");
        }
        // and the receiving side decodes the same vector
        let recv = decode_inf_quantized(&bytes, 256, 4, 256);
        assert_eq!(recv, b);
    }
}
