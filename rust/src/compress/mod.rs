//! Communication compression operators (Assumption 2) and exact bit
//! accounting.
//!
//! The central trait is [`Compressor`]: a stochastic map Q with
//! E[Q(x)] = x and E‖Q(x) − x‖² ≤ C‖x‖² for unbiased operators. Each
//! compressor reports (a) the *decoded* vector used by the algorithm and
//! (b) the exact number of wire bits its encoding would occupy, so the
//! figures' communication-bit axes are measured rather than modeled.

pub mod bits;
pub mod quantize;
pub mod sparsify;

pub use quantize::{InfNormQuantizer, L2NormQuantizer};
pub use sparsify::{RandK, TopK};

use crate::util::rng::Rng;

/// Result of compressing one vector: the decoded (lossy) payload plus the
/// exact encoded size in bits.
#[derive(Clone, Debug)]
pub struct Compressed {
    pub decoded: Vec<f64>,
    pub bits: u64,
}

/// A (possibly stochastic) compression operator over ℝ^p.
pub trait Compressor: Send + Sync {
    /// Compress `x`, drawing any randomness from `rng`.
    fn compress(&self, x: &[f64], rng: &mut Rng) -> Compressed;

    /// Upper bound C on the noise-to-signal ratio E‖Q(x)−x‖²/‖x‖²
    /// (Assumption 2). Identity has C = 0.
    fn variance_bound(&self) -> f64;

    /// True if E[Q(x)] = x (top-k is the one biased operator we ship,
    /// included for the ablation study only).
    fn is_unbiased(&self) -> bool {
        true
    }

    /// Human-readable tag for tables/figures, e.g. "2bit".
    fn name(&self) -> String;
}

/// The identity "compressor": exact communication, 64 bits per entry
/// (we transmit f64 in the simulator; the paper's "32bit" baseline label is
/// kept by [`Identity::f32`], which rounds through f32 and counts 32).
#[derive(Clone, Copy, Debug)]
pub struct Identity {
    pub bits_per_entry: u32,
}

impl Identity {
    /// Full f64 precision.
    pub fn f64() -> Identity {
        Identity { bits_per_entry: 64 }
    }
    /// f32 wire format — the paper's uncompressed "32bit" baselines.
    pub fn f32() -> Identity {
        Identity { bits_per_entry: 32 }
    }
}

impl Compressor for Identity {
    fn compress(&self, x: &[f64], _rng: &mut Rng) -> Compressed {
        let decoded = if self.bits_per_entry == 32 {
            x.iter().map(|&v| v as f32 as f64).collect()
        } else {
            x.to_vec()
        };
        Compressed {
            decoded,
            bits: self.bits_per_entry as u64 * x.len() as u64,
        }
    }
    fn variance_bound(&self) -> f64 {
        0.0
    }
    fn name(&self) -> String {
        format!("{}bit", self.bits_per_entry)
    }
}

/// Empirically estimate the noise-to-signal ratio E‖Q(x)−x‖²/‖x‖² of a
/// compressor on random gaussian vectors — used by tests to confirm each
/// operator respects its declared [`Compressor::variance_bound`].
pub fn empirical_nsr(c: &dyn Compressor, dim: usize, trials: usize, rng: &mut Rng) -> f64 {
    let mut worst: f64 = 0.0;
    for _ in 0..trials {
        let x: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
        let norm_sq: f64 = x.iter().map(|v| v * v).sum();
        let mut err_acc = 0.0;
        let inner = 30;
        for _ in 0..inner {
            let q = c.compress(&x, rng);
            err_acc += x
                .iter()
                .zip(&q.decoded)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>();
        }
        worst = worst.max(err_acc / inner as f64 / norm_sq);
    }
    worst
}

/// Empirically check unbiasedness: ‖mean_k Q(x) − x‖ / ‖x‖ over k trials.
pub fn empirical_bias(c: &dyn Compressor, x: &[f64], trials: usize, rng: &mut Rng) -> f64 {
    let mut acc = vec![0.0; x.len()];
    for _ in 0..trials {
        let q = c.compress(x, rng);
        for (a, b) in acc.iter_mut().zip(&q.decoded) {
            *a += b;
        }
    }
    let inv = 1.0 / trials as f64;
    let num: f64 = acc
        .iter()
        .zip(x)
        .map(|(a, b)| (a * inv - b) * (a * inv - b))
        .sum::<f64>();
    let den: f64 = x.iter().map(|v| v * v).sum();
    (num / den.max(1e-300)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_is_exact() {
        let id = Identity::f64();
        let mut rng = Rng::new(1);
        let x = vec![1.5, -2.25, 0.0, 1e-9];
        let q = id.compress(&x, &mut rng);
        assert_eq!(q.decoded, x);
        assert_eq!(q.bits, 64 * 4);
        assert_eq!(id.variance_bound(), 0.0);
    }

    #[test]
    fn f32_identity_rounds() {
        let id = Identity::f32();
        let mut rng = Rng::new(1);
        let x = vec![std::f64::consts::PI];
        let q = id.compress(&x, &mut rng);
        assert!((q.decoded[0] - std::f64::consts::PI).abs() < 1e-6);
        assert_ne!(q.decoded[0], std::f64::consts::PI);
        assert_eq!(q.bits, 32);
    }
}
