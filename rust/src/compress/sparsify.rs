//! Sparsification compressors.
//!
//! [`RandK`] keeps k random coordinates scaled by p/k — unbiased with
//! C = p/k − 1, so it satisfies Assumption 2 and can be used with
//! Prox-LEAD at *any* aggressiveness ("arbitrary compression precision").
//! [`TopK`] keeps the k largest-magnitude coordinates — biased, violating
//! Assumption 2; shipped only for the ablation benchmark that shows why
//! the theory asks for unbiasedness.

use super::{Compressed, Compressor};
use crate::util::rng::Rng;

/// Unbiased random-k sparsifier: Q(x)_i = (p/k)·x_i for k uniformly chosen
/// coordinates, 0 elsewhere.
#[derive(Clone, Copy, Debug)]
pub struct RandK {
    pub k: usize,
}

impl RandK {
    pub fn new(k: usize) -> Self {
        assert!(k >= 1);
        RandK { k }
    }
}

impl Compressor for RandK {
    fn compress(&self, x: &[f64], rng: &mut Rng) -> Compressed {
        let p = x.len();
        let k = self.k.min(p);
        let idx = rng.sample_indices(p, k);
        let mut decoded = vec![0.0; p];
        let scale = p as f64 / k as f64;
        for &i in &idx {
            decoded[i] = scale * x[i];
        }
        // wire: k × (index + f32 value). Index width = ceil(log2 p).
        let idx_bits = (usize::BITS - (p.max(2) - 1).leading_zeros()) as u64;
        Compressed {
            decoded,
            bits: k as u64 * (idx_bits + 32),
        }
    }

    fn variance_bound(&self) -> f64 {
        // E‖Q(x)−x‖² = (p/k − 1)‖x‖² exactly, for p entries
        // (dimension-dependent; we report the bound for the dims we use —
        // callers with fixed p should use `variance_bound_for_dim`).
        f64::NAN // dimension-dependent; see variance_bound_for_dim
    }

    fn name(&self) -> String {
        format!("rand{}", self.k)
    }
}

impl RandK {
    /// Exact C for vectors of dimension p: C = p/k − 1.
    pub fn variance_bound_for_dim(&self, p: usize) -> f64 {
        p as f64 / self.k.min(p) as f64 - 1.0
    }
}

/// Biased top-k sparsifier (keeps the k largest |x_i| unscaled).
#[derive(Clone, Copy, Debug)]
pub struct TopK {
    pub k: usize,
}

impl TopK {
    pub fn new(k: usize) -> Self {
        assert!(k >= 1);
        TopK { k }
    }
}

impl Compressor for TopK {
    fn compress(&self, x: &[f64], _rng: &mut Rng) -> Compressed {
        let p = x.len();
        let k = self.k.min(p);
        let mut order: Vec<usize> = (0..p).collect();
        // total_cmp: behavior-identical to partial_cmp on non-NaN input
        // (keys are |x_i|, so ±0.0 tie-breaking cannot differ) and total on
        // NaN — a diverged iterate ranks NaN above every finite magnitude
        // and propagates it to the consensus layer instead of panicking.
        order.sort_by(|&a, &b| x[b].abs().total_cmp(&x[a].abs()));
        let mut decoded = vec![0.0; p];
        for &i in &order[..k] {
            decoded[i] = x[i];
        }
        let idx_bits = (usize::BITS - (p.max(2) - 1).leading_zeros()) as u64;
        Compressed {
            decoded,
            bits: k as u64 * (idx_bits + 32),
        }
    }

    fn variance_bound(&self) -> f64 {
        f64::NAN // biased: no Assumption-2 constant exists
    }

    fn is_unbiased(&self) -> bool {
        false
    }

    fn name(&self) -> String {
        format!("top{}", self.k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::empirical_bias;

    #[test]
    fn topk_nan_input_does_not_panic() {
        // regression: the magnitude sort used partial_cmp().unwrap(), which
        // panicked the moment a diverged iterate carried a NaN. total_cmp
        // ranks NaN above every finite |x_i|, so it is *kept* and surfaces
        // downstream where divergence checks can see it.
        let q = TopK::new(2);
        let x = [1.0, f64::NAN, -3.0, 2.0];
        let c = q.compress(&x, &mut Rng::new(27));
        assert!(c.decoded[1].is_nan(), "NaN entry must survive top-k selection");
        assert_eq!(c.decoded[2], -3.0, "largest finite magnitude kept alongside NaN");
        assert_eq!(c.decoded[0], 0.0);
        assert_eq!(c.decoded[3], 0.0);
    }

    #[test]
    fn randk_unbiased() {
        let q = RandK::new(4);
        let mut rng = Rng::new(21);
        let x: Vec<f64> = (0..16).map(|_| rng.normal()).collect();
        let bias = empirical_bias(&q, &x, 60_000, &mut rng);
        assert!(bias < 0.02, "bias {bias}");
    }

    #[test]
    fn randk_variance_exact() {
        // E‖Q(x)−x‖² = (p/k − 1)‖x‖² — verify by Monte Carlo
        let q = RandK::new(2);
        let mut rng = Rng::new(22);
        let x: Vec<f64> = (0..8).map(|_| rng.normal()).collect();
        let norm_sq: f64 = x.iter().map(|v| v * v).sum();
        let mut err = 0.0;
        let trials = 40_000;
        for _ in 0..trials {
            let c = q.compress(&x, &mut rng);
            err += x
                .iter()
                .zip(&c.decoded)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>();
        }
        let measured_c = err / trials as f64 / norm_sq;
        let exact_c = q.variance_bound_for_dim(8);
        assert!(
            (measured_c - exact_c).abs() < 0.1 * exact_c,
            "measured {measured_c} vs exact {exact_c}"
        );
    }

    #[test]
    fn randk_keeps_k_entries() {
        let q = RandK::new(3);
        let mut rng = Rng::new(23);
        let x = vec![1.0; 10];
        let c = q.compress(&x, &mut rng);
        let nonzero = c.decoded.iter().filter(|&&v| v != 0.0).count();
        assert_eq!(nonzero, 3);
        for &v in &c.decoded {
            assert!(v == 0.0 || (v - 10.0 / 3.0).abs() < 1e-12);
        }
    }

    #[test]
    fn topk_selects_largest() {
        let q = TopK::new(2);
        let mut rng = Rng::new(24);
        let x = vec![0.1, -5.0, 0.3, 4.0, -0.2];
        let c = q.compress(&x, &mut rng);
        assert_eq!(c.decoded, vec![0.0, -5.0, 0.0, 4.0, 0.0]);
        assert!(!q.is_unbiased());
    }

    #[test]
    fn bit_accounting() {
        let q = RandK::new(4);
        let mut rng = Rng::new(25);
        let c = q.compress(&vec![1.0; 256], &mut rng);
        // 256 entries -> 8-bit indices, 4 × (8 + 32)
        assert_eq!(c.bits, 4 * 40);
    }

    #[test]
    fn k_larger_than_dim_is_identity() {
        let q = RandK::new(100);
        let mut rng = Rng::new(26);
        let x = vec![1.0, 2.0, 3.0];
        let c = q.compress(&x, &mut rng);
        assert_eq!(c.decoded, x);
    }
}
