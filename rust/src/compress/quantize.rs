//! Unbiased stochastic quantizers.
//!
//! [`InfNormQuantizer`] is the paper's equation (21): b-bit quantization
//! scaled by the ∞-norm with uniform dithering, applied blockwise
//! (block = 256 in §5). Only the sign vector, one norm scalar per block,
//! and the magnitude integers cross the wire. The ∞-norm scaling is the
//! paper's improvement over QSGD's 2-norm scaling, which we also implement
//! as [`L2NormQuantizer`] for the ablation.
//!
//! **Level convention.** Eq. (21) uses L = 2^{b−1} magnitude levels — the
//! paper's convention, which we follow exactly (with L = 1 a 2-bit code
//! would be sign-only and its noise-to-signal ratio C blows up; the
//! experiments' α = 0.5 is only feasible at the paper's L = 2). Following
//! QSGD's standard accounting we charge b bits per entry (1 sign bit +
//! b−1 magnitude bits; the dither's rare boundary code ⌊L+u⌋ = L is
//! absorbed by the entropy-coding slack, as in the QSGD paper).

use super::{Compressed, Compressor};
use crate::util::rng::Rng;

/// Number of magnitude levels for a b-bit code (b ≥ 2): L = 2^{b−1}
/// (eq. 21's scale factor).
pub fn levels_for_bits(bits: u32) -> f64 {
    assert!((2..=16).contains(&bits), "bits must be in 2..=16");
    (1u64 << (bits - 1)) as f64
}

/// b-bit ∞-norm stochastic quantizer (eq. 21 with the L-level convention):
///
///   Q∞(x) = (‖x‖∞ / L) · sign(x) ⊙ ⌊ L·|x| / ‖x‖∞ + u ⌋,  u ~ U[0,1)^p.
#[derive(Clone, Copy, Debug)]
pub struct InfNormQuantizer {
    pub bits: u32,
    pub block: usize,
}

impl InfNormQuantizer {
    pub fn new(bits: u32, block: usize) -> Self {
        let _ = levels_for_bits(bits); // validates range
        assert!(block >= 1);
        InfNormQuantizer { bits, block }
    }

    /// The paper's experimental default: 2-bit, block 256.
    pub fn paper_default() -> Self {
        InfNormQuantizer::new(2, 256)
    }
}

impl Compressor for InfNormQuantizer {
    fn compress(&self, x: &[f64], rng: &mut Rng) -> Compressed {
        let levels = levels_for_bits(self.bits);
        let mut decoded = Vec::with_capacity(x.len());
        let mut bits = 0u64;
        for chunk in x.chunks(self.block) {
            let norm = chunk.iter().fold(0.0f64, |m, &v| m.max(v.abs()));
            if norm == 0.0 {
                decoded.extend(std::iter::repeat(0.0).take(chunk.len()));
                bits += 32; // the zero norm still crosses the wire
                continue;
            }
            let scale = norm / levels;
            let inv_scale = levels / norm; // hoisted: one divide per block
            for &v in chunk {
                // the same magnitude expression and boundary clamp as the
                // wire codec (compress::bits::encode_inf_quantized), so both
                // paths draw code-identical magnitudes from the same dither
                // stream — they differ only in the norm the decode scales by
                // (f64 here, the transmitted f32 on the wire)
                let mag = (v.abs() * inv_scale + rng.f64()).floor().min(levels);
                decoded.push(v.signum() * scale * mag);
            }
            bits += 32 + (self.bits as u64) * chunk.len() as u64;
        }
        Compressed { decoded, bits }
    }

    fn variance_bound(&self) -> f64 {
        // per-entry error ≤ scale·U[0,1) ⇒ E err² ≤ scale²/4 with
        // scale = ‖x‖∞/L; summed over ≤ block entries and divided by
        // ‖x‖² ≥ ‖x‖∞²:  C ≤ block / (4 L²).
        let l = levels_for_bits(self.bits);
        self.block as f64 / (4.0 * l * l)
    }

    fn name(&self) -> String {
        format!("{}bit", self.bits)
    }
}

/// QSGD-style b-bit quantizer with 2-norm scaling (Alistarh et al., 2017),
/// included to ablate the ∞-norm improvement of eq. (21).
#[derive(Clone, Copy, Debug)]
pub struct L2NormQuantizer {
    pub bits: u32,
    pub block: usize,
}

impl L2NormQuantizer {
    pub fn new(bits: u32, block: usize) -> Self {
        let _ = levels_for_bits(bits);
        assert!(block >= 1);
        L2NormQuantizer { bits, block }
    }
}

impl Compressor for L2NormQuantizer {
    fn compress(&self, x: &[f64], rng: &mut Rng) -> Compressed {
        let levels = levels_for_bits(self.bits);
        let mut decoded = Vec::with_capacity(x.len());
        let mut bits = 0u64;
        for chunk in x.chunks(self.block) {
            let norm = chunk.iter().map(|v| v * v).sum::<f64>().sqrt();
            if norm == 0.0 {
                decoded.extend(std::iter::repeat(0.0).take(chunk.len()));
                bits += 32;
                continue;
            }
            let scale = norm / levels;
            for &v in chunk {
                let mag = (levels * v.abs() / norm + rng.f64()).floor();
                decoded.push(v.signum() * scale * mag);
            }
            bits += 32 + (self.bits as u64) * chunk.len() as u64;
        }
        Compressed { decoded, bits }
    }

    fn variance_bound(&self) -> f64 {
        // QSGD Lemma 3.1: C ≤ min(p/L², √p/L) for p = block entries
        let l = levels_for_bits(self.bits);
        let p = self.block as f64;
        (p / (l * l)).min(p.sqrt() / l)
    }

    fn name(&self) -> String {
        format!("qsgd{}bit", self.bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{empirical_bias, empirical_nsr};
    use crate::util::qc::assert_prop;

    #[test]
    fn infnorm_unbiased() {
        let q = InfNormQuantizer::paper_default();
        let mut rng = Rng::new(7);
        let x: Vec<f64> = (0..300).map(|_| rng.normal()).collect();
        let bias = empirical_bias(&q, &x, 4000, &mut rng);
        assert!(bias < 0.03, "bias {bias}");
    }

    #[test]
    fn l2_unbiased() {
        let q = L2NormQuantizer::new(2, 256);
        let mut rng = Rng::new(8);
        let x: Vec<f64> = (0..300).map(|_| rng.normal()).collect();
        let bias = empirical_bias(&q, &x, 4000, &mut rng);
        assert!(bias < 0.05, "bias {bias}");
    }

    #[test]
    fn nsr_within_declared_bound() {
        let mut rng = Rng::new(9);
        for bits in [2u32, 3, 4, 8] {
            let q = InfNormQuantizer::new(bits, 64);
            let nsr = empirical_nsr(&q, 64, 20, &mut rng);
            assert!(
                nsr <= q.variance_bound() * 1.2 + 1e-12,
                "b={bits}: nsr {nsr} > C {}",
                q.variance_bound()
            );
        }
    }

    #[test]
    fn infnorm_beats_l2_on_dense_vectors() {
        // the paper's Appendix-C claim: ∞-norm scaling has lower error on
        // dense vectors at the same bit budget
        let mut rng = Rng::new(10);
        let qi = InfNormQuantizer::new(4, 256);
        let ql = L2NormQuantizer::new(4, 256);
        let nsr_i = empirical_nsr(&qi, 256, 15, &mut rng);
        let nsr_l = empirical_nsr(&ql, 256, 15, &mut rng);
        assert!(nsr_i < nsr_l, "inf {nsr_i} vs l2 {nsr_l}");
    }

    #[test]
    fn bit_accounting_formula() {
        let q = InfNormQuantizer::new(2, 256);
        let mut rng = Rng::new(11);
        // 600 entries = blocks of 256+256+88: 3 norms + 2 bits/entry
        let x: Vec<f64> = (0..600).map(|_| rng.normal()).collect();
        let c = q.compress(&x, &mut rng);
        assert_eq!(c.bits, 3 * 32 + 2 * 600);
        assert_eq!(c.decoded.len(), 600);
    }

    #[test]
    fn zero_block_cheap_and_exact() {
        let q = InfNormQuantizer::new(2, 4);
        let mut rng = Rng::new(12);
        let c = q.compress(&[0.0; 8], &mut rng);
        assert_eq!(c.decoded, vec![0.0; 8]);
        assert_eq!(c.bits, 2 * 32);
    }

    #[test]
    fn more_bits_less_error() {
        let mut rng = Rng::new(13);
        let x: Vec<f64> = (0..256).map(|_| rng.normal()).collect();
        let mut last = f64::INFINITY;
        for bits in [2u32, 3, 4, 6, 8] {
            let q = InfNormQuantizer::new(bits, 256);
            let mut err = 0.0;
            for _ in 0..50 {
                let c = q.compress(&x, &mut rng);
                err += x
                    .iter()
                    .zip(&c.decoded)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<f64>();
            }
            assert!(err < last, "error should drop with bits (b={bits})");
            last = err;
        }
    }

    #[test]
    fn quantized_values_on_grid() {
        assert_prop("quantized magnitudes are multiples of scale", 50, |g| {
            let bits = *g.choose(&[2u32, 3, 4]);
            let q = InfNormQuantizer::new(bits, 512);
            let x = g.vec_f64(32, 10.0);
            let norm = x.iter().fold(0.0f64, |m, &v| m.max(v.abs()));
            if norm == 0.0 {
                return Ok(());
            }
            let scale = norm / levels_for_bits(bits);
            let mut rng = Rng::new(g.rng.next_u64());
            let c = q.compress(&x, &mut rng);
            for (i, &v) in c.decoded.iter().enumerate() {
                let ratio = v.abs() / scale;
                if (ratio - ratio.round()).abs() > 1e-9 {
                    return Err(format!("entry {i}: {v} not on grid {scale}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "bits must be in 2..=16")]
    fn rejects_one_bit() {
        let _ = InfNormQuantizer::new(1, 256);
    }
}
